"""Paper Figs 1/14: accuracy-vs-FLOPs frontier across policies.

FLOPs are exact analytic counts for the *full* LLaMA-7B config at seq 2048
(the paper's setting); fidelity comes from the tiny-LM proxy (see
bench_accuracy_proxy). Also reports full-model decode-attention FLOPs for
CHAI vs MHA per cluster fraction, the CHAI-QKV (share_values) ablation
whose AV term shrinks to R·S·hd, and the windowed-attention variant whose
effective S is min(S, window)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import save_result
from repro.configs.base import get_config
from repro.kernels.ops import decode_flop_estimate


def run():
    cfg = get_config("chai-llama-7b")
    b, s, hd, h = 1, 2048, cfg.head_dim, cfg.n_heads
    window = 1024
    counts = cfg.chai_cluster_counts()

    # per-layer decode-attention FLOPs at the paper's seq length
    mha = sum(decode_flop_estimate(b, h, h, s, hd)
              for _ in range(cfg.n_attn_layers))
    chai = sum(decode_flop_estimate(b, h, k, s, hd) for k in counts)
    # CHAI-QKV ablation (Table 4): V rows pruned too -> AV is R·S·hd
    chai_qkv = sum(decode_flop_estimate(b, h, k, s, hd, share_values=True)
                   for k in counts)
    # sliding-window variant: effective S = min(S, window)
    chai_win = sum(decode_flop_estimate(b, h, k, s, hd, window=window)
                   for k in counts)
    random_ks = {f"random-{n}": sum(
        decode_flop_estimate(b, h, max(h - n, 1), s, hd)
        for _ in range(cfg.n_attn_layers)) for n in (4, 8, 16, 24)}

    result = {
        "config": "chai-llama-7b @ seq 2048 (paper Figs 1/14 setting)",
        "per_layer_cluster_counts": list(counts),
        "decode_attention_flops": {
            "mha": mha, "chai": chai, "chai_qkv_share_values": chai_qkv,
            f"chai_window_{window}": chai_win, **random_ks},
        "chai_flop_fraction_of_mha": chai / mha,
        "paper_claim": "CHAI reduces self-attention compute; best "
                       "accuracy-flops tradeoff among runtime methods",
        "claim_check": {
            "chai_fewer_flops": chai < mha,
            # share_values prunes the AV term (R rows, not H)
            "chai_qkv_fewer_than_chai": chai_qkv < chai,
            # windowed FLOPs scale with min(S, window)/S exactly
            "window_scales_effective_s":
                abs(chai_win / chai - window / s) < 1e-9,
        },
    }
    save_result("bench_flops", result)
    return result


if __name__ == "__main__":
    print(run())
