"""System-level test: full CHAI pipeline — offline elbow -> serve with the
engine -> fidelity of CHAI vs MHA generations on a *trained* tiny model.

This is the CPU-scale analogue of the paper's accuracy tables: after
training a small LM on the synthetic Markov corpus, CHAI decode must track
MHA decode closely (greedy tokens mostly equal), while random head
clustering (Fig 1 baseline) degrades more.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, reduced
from repro.core import clustering
from repro.core.elbow import offline_cluster_counts
from repro.data.pipeline import DataConfig, calibration_batches
from repro.models import transformer as tfm
from repro.serving.engine import EngineConfig, ServingEngine
from repro.train.trainer import Trainer, TrainerConfig

pytestmark = pytest.mark.slow   # trains a model; CI runs it in the slow lane


@pytest.fixture(scope="module")
def trained(tmp_path_factory):
    cfg = reduced(get_config("chai-llama-7b"), n_layers=2, d_model=64,
                  n_heads=8, d_ff=128, vocab=128).replace(dtype="float32")
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)
    tr = Trainer(cfg, data, TrainerConfig(
        total_steps=60, ckpt_every=1000, log_every=1000,
        ckpt_dir=str(tmp_path_factory.mktemp("ck")),
        lr_kw=dict(peak=3e-3, warmup=6, total=60)))
    state, metrics = tr.run()
    assert float(metrics["loss"]) < 4.0   # well under ln(128)=4.85
    return cfg, state["params"], tr.pipe


def _greedy(cfg, params, pipe, *, use_chai, n_req=4, max_new=16):
    eng = ServingEngine(cfg, params,
                        EngineConfig(batch_slots=2, max_seq=128,
                                     use_chai=use_chai))
    for i in range(n_req):
        prompt = pipe.batch(100 + i)["tokens"][0, :24]
        eng.submit(prompt, max_new_tokens=max_new, uid=i)
    return {r.uid: r.generated for r in eng.run()}


def test_offline_elbow_on_real_activations(trained):
    """Offline phase end-to-end: collect per-head scores on calibration
    data, elbow-select k per layer."""
    cfg, params, _ = trained
    feats = []
    for toks in calibration_batches(cfg.vocab_size, 32, n_samples=8):
        toks = jnp.asarray(toks)
        # per-head feature: accumulated attention of a decode step, via the
        # warmup score-buffer path (prefill then one decode)
        state = tfm.init_decode_state(cfg, toks.shape[0], 64)
        from repro.core.cache import add_score_buffer, pop_score_buffer
        _, state, _ = tfm.forward_fullseq(params, cfg, toks, state=state)
        state = add_score_buffer(state, cfg, toks.shape[0])
        _, state = tfm.decode_step(params, cfg, toks[:, -1], state)
        state, scores = pop_score_buffer(state)   # (nA, B, H, Wf)
        feats.append(np.asarray(scores).mean(axis=1))   # avg over batch
    per_layer = np.mean(feats, axis=0)            # (nA, H, Wf)
    ks = offline_cluster_counts(
        [clustering.standardize(jnp.asarray(f)) for f in per_layer],
        cfg.n_heads)
    assert len(ks) == cfg.n_attn_layers
    assert all(1 <= k <= cfg.n_heads for k in ks)


def test_chai_tracks_mha_generations(trained):
    cfg, params, pipe = trained
    cfg_chai = cfg.with_chai(enabled=True, cluster_counts=(6, 6))
    mha = _greedy(cfg, params, pipe, use_chai=False)
    chai = _greedy(cfg_chai, params, pipe, use_chai=True)
    agree = np.mean([
        np.mean(np.asarray(mha[u]) == np.asarray(chai[u])) for u in mha])
    # paper: <=3.2% accuracy deviation; tiny-model greedy-token proxy
    assert agree > 0.7, agree


def test_chai_beats_random_clustering(trained):
    """CHAI (correlation clustering) should track MHA at least as well as
    round-robin membership with the same k (paper Fig 1 baselines)."""
    cfg, params, pipe = trained
    mha = _greedy(cfg, params, pipe, use_chai=False)

    cfg_chai = cfg.with_chai(enabled=True, cluster_counts=(4, 4))
    chai = _greedy(cfg_chai, params, pipe, use_chai=True)

    # random baseline: round-robin shared_ctx (ignores activations)
    eng = ServingEngine(cfg_chai, params,
                        EngineConfig(batch_slots=2, max_seq=128))
    rand_ctx = clustering.shared_ctx(cfg_chai)
    rand_ctx = jax.tree.map(
        lambda a: jnp.repeat(a[:, None], 2, axis=1), rand_ctx)
    eng._identify = lambda sc: rand_ctx
    for i in range(4):
        prompt = pipe.batch(100 + i)["tokens"][0, :24]
        eng.submit(prompt, max_new_tokens=16, uid=i)
    rand = {r.uid: r.generated for r in eng.run()}

    def score(gen):
        return np.mean([np.mean(np.asarray(mha[u]) == np.asarray(gen[u]))
                        for u in mha])

    assert score(chai) >= score(rand) - 0.05
