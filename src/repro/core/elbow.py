"""Offline cluster-count selection via elbow analysis (paper §3.2, Fig 8).

Run once per model on calibration activations: for each layer, sweep k,
record K-Means error, and pick the smallest k where the marginal error
reduction plateaus. The result feeds ``ModelConfig.chai.cluster_counts``.
"""
from __future__ import annotations

import numpy as np

from repro.core.kmeans import kmeans


def elbow_curve(features, k_values):
    """features: (H, F) np/jnp. Returns np.array of errors per k."""
    errs = []
    for k in k_values:
        _, _, e = kmeans(features, int(k))
        errs.append(float(e))
    return np.asarray(errs)


def select_k(errors, k_values, plateau_tol=0.05):
    """Smallest k whose marginal improvement over the previous k drops below
    ``plateau_tol`` of the total error range (the paper's 'error plateaus')."""
    errors = np.asarray(errors, dtype=np.float64)
    k_values = list(k_values)
    total = max(errors[0] - errors[-1], 1e-12)
    for i in range(1, len(k_values)):
        gain = (errors[i - 1] - errors[i]) / total
        if gain < plateau_tol:
            return k_values[i - 1]
    return k_values[-1]


def offline_cluster_counts(per_layer_features, n_heads, plateau_tol=0.05,
                           min_k=1, group_floor=1):
    """Full offline phase: per-layer elbow-selected k.

    per_layer_features: iterable of (H, F) arrays (one per attention layer).
    Returns list[int] cluster counts.
    """
    ks = [k for k in range(1, n_heads + 1)
          if k in (1, 2) or k % 2 == 0 or k == n_heads]
    out = []
    for feats in per_layer_features:
        errs = elbow_curve(feats, ks)
        k = select_k(errs, ks, plateau_tol)
        out.append(int(max(min_k, group_floor, k)))
    return out
