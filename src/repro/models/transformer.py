"""Heterogeneous scan-over-layers decoder supporting all assigned archs.

Design (DESIGN.md §3.5):
  * Parameters live in **stacked groups** — one stack per layer family
    (attention / dense-FFN / MoE / RG-LRU / RWKV), stacked over the layers
    that use that family. HLO size is therefore layer-count independent.
  * A single ``lax.scan`` walks layers; per-layer int32 arrays carry the
    mixer/FFN kind and the index into each group stack; ``lax.switch``
    dispatches (only kinds present in the config are lowered).
  * A declarative **param table** generates params, ShapeDtypeStructs (for
    the allocation-free dry-run) and logical sharding axes from one source.

Three entry points: ``forward_fullseq`` (train & prefill), ``decode_step``
(one token against a mutable state), and ``init_decode_state``.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (ATTN_GLOBAL, ATTN_LOCAL, FFN_DENSE, FFN_MOE,
                                RGLRU, RWKV, ModelConfig)
from repro.models import attention as attn_mod
from repro.models import frontends, mlp, moe, rglru, rwkv
from repro.models.layers import embed_lookup, rms_norm, softcap, unembed
from repro.sharding.rules import Ax

# ---------------------------------------------------------------------------
# Param table
# ---------------------------------------------------------------------------

MIXER_KINDS = {ATTN_GLOBAL: 0, ATTN_LOCAL: 1, RGLRU: 2, RWKV: 3}
FFN_KIND_DENSE, FFN_KIND_MOE, FFN_KIND_CMIX = 0, 1, 2


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def param_table(cfg: ModelConfig):
    """Returns {group: {name: (shape, Ax(logical...), init_scale)}}."""
    d, hd = cfg.d_model, cfg.head_dim
    h, kv = cfg.n_heads, cfg.n_kv_heads
    nA, nG, nL = cfg.n_attn_layers, cfg.n_global_layers, cfg.n_local_layers
    nR, nW = cfg.n_rec_layers, cfg.n_rwkv_layers
    nD = sum(1 for lt, ft in zip(cfg.layer_types, cfg.ffn_types)
             if ft == FFN_DENSE and lt != RWKV)
    nM = cfg.n_moe_ffn
    t: Dict[str, Dict[str, tuple]] = {}

    t["embed"] = {"tok": ((cfg.vocab_size, d), Ax("vocab", "embed"), 0.02)}
    if cfg.frontend != "none":
        t["frontend"] = {"adapter": ((d, d), Ax("embed", "embed_tp"),
                                     d ** -0.5)}
    if not cfg.tie_embeddings:
        t["unembed"] = {"w": ((d, cfg.vocab_size), Ax("embed", "vocab"),
                              d ** -0.5)}
    t["final_norm"] = {"scale": ((d,), Ax("embed"), 0.0)}

    if nA:
        g = {
            "ln": ((nA, d), Ax("layers", "embed"), 0.0),
            "wq": ((nA, d, h, hd), Ax("layers", "embed", "heads", "head_dim"),
                   d ** -0.5),
            "wk": ((nA, d, kv, hd),
                   Ax("layers", "embed", "kv_heads", "head_dim"), d ** -0.5),
            "wv": ((nA, d, kv, hd),
                   Ax("layers", "embed", "kv_heads", "head_dim"), d ** -0.5),
            "wo": ((nA, h, hd, d), Ax("layers", "heads", "head_dim", "embed"),
                   (h * hd) ** -0.5),
        }
        if cfg.qk_norm:
            g["q_norm"] = ((nA, hd), Ax("layers", "head_dim"), 0.0)
            g["k_norm"] = ((nA, hd), Ax("layers", "head_dim"), 0.0)
        t["attn"] = g

    if nD:
        g = {
            "ln": ((nD, d), Ax("layers", "embed"), 0.0),
            "w_up": ((nD, d, cfg.d_ff), Ax("layers", "embed", "mlp"),
                     d ** -0.5),
            "w_down": ((nD, cfg.d_ff, d), Ax("layers", "mlp", "embed"),
                       cfg.d_ff ** -0.5),
        }
        if cfg.gated_mlp:
            g["w_gate"] = ((nD, d, cfg.d_ff), Ax("layers", "embed", "mlp"),
                           d ** -0.5)
        t["ffn"] = g

    if nM:
        fe, e = cfg.moe_d_ff, cfg.n_experts
        g = {
            "ln": ((nM, d), Ax("layers", "embed"), 0.0),
            "router": ((nM, d, e), Ax("layers", "embed", "experts"),
                       d ** -0.5),
            "w_gate": ((nM, e, d, fe),
                       Ax("layers", "experts", "embed", "expert_mlp"),
                       d ** -0.5),
            "w_up": ((nM, e, d, fe),
                     Ax("layers", "experts", "embed", "expert_mlp"),
                     d ** -0.5),
            "w_down": ((nM, e, fe, d),
                       Ax("layers", "experts", "expert_mlp", "embed"),
                       fe ** -0.5),
        }
        if cfg.n_shared_experts:
            sf = cfg.n_shared_experts * fe
            g["shared_gate"] = ((nM, d, sf), Ax("layers", "embed", "mlp"),
                                d ** -0.5)
            g["shared_up"] = ((nM, d, sf), Ax("layers", "embed", "mlp"),
                              d ** -0.5)
            g["shared_down"] = ((nM, sf, d), Ax("layers", "mlp", "embed"),
                                sf ** -0.5)
        t["moe"] = g

    if nR:
        rw_, cw = cfg.rnn_width, cfg.conv_width
        t["rglru"] = {
            "ln": ((nR, d), Ax("layers", "embed"), 0.0),
            "w_x": ((nR, d, rw_), Ax("layers", "embed", "rnn"), d ** -0.5),
            "w_gate": ((nR, d, rw_), Ax("layers", "embed", "rnn"), d ** -0.5),
            "conv_w": ((nR, cw, rw_), Ax("layers", "conv", "rnn"),
                       cw ** -0.5),
            "conv_b": ((nR, rw_), Ax("layers", "rnn"), 0.0),
            "w_a": ((nR, rw_, rw_), Ax("layers", "rnn", "embed_tp"),
                    rw_ ** -0.5),
            "w_i": ((nR, rw_, rw_), Ax("layers", "rnn", "embed_tp"),
                    rw_ ** -0.5),
            "log_lambda": ((nR, rw_), Ax("layers", "rnn"), 0.5),
            "w_out": ((nR, rw_, d), Ax("layers", "rnn", "embed"),
                      rw_ ** -0.5),
        }

    if nW:
        lora = 64
        t["rwkv"] = {
            "ln1": ((nW, d), Ax("layers", "embed"), 0.0),
            "ln2": ((nW, d), Ax("layers", "embed"), 0.0),
            "mu": ((nW, 5, d), Ax("layers", None, "embed"), 0.3),
            "w_r": ((nW, d, d), Ax("layers", "embed", "embed_tp"), d ** -0.5),
            "w_k": ((nW, d, d), Ax("layers", "embed", "embed_tp"), d ** -0.5),
            "w_v": ((nW, d, d), Ax("layers", "embed", "embed_tp"), d ** -0.5),
            "w_g": ((nW, d, d), Ax("layers", "embed", "embed_tp"), d ** -0.5),
            "w_decay_a": ((nW, d, lora), Ax("layers", "embed", "lora"),
                          d ** -0.5),
            "w_decay_b": ((nW, lora, d), Ax("layers", "lora", "embed"), 0.01),
            "decay_base": ((nW, d), Ax("layers", "embed"), 0.5),
            "u": ((nW, cfg.n_rwkv_heads, cfg.rwkv_head_dim),
                  Ax("layers", "heads", "head_dim"), 0.5),
            "w_o": ((nW, d, d), Ax("layers", "embed_tp", "embed"), d ** -0.5),
            "ln_x": ((nW, d), Ax("layers", "embed"), 0.0),
            "cmu": ((nW, 2, d), Ax("layers", None, "embed"), 0.3),
            "c_k": ((nW, d, cfg.d_ff), Ax("layers", "embed", "mlp"),
                    d ** -0.5),
            "c_v": ((nW, cfg.d_ff, d), Ax("layers", "mlp", "embed"),
                    cfg.d_ff ** -0.5),
            "c_r": ((nW, d, d), Ax("layers", "embed", "embed_tp"), d ** -0.5),
        }
    return t


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    table = param_table(cfg)
    dt = _dtype(cfg)
    params: Dict[str, Any] = {}
    leaves = [(g, n) for g, grp in table.items() for n in grp]
    keys = jax.random.split(key, len(leaves))
    for (g, n), k in zip(leaves, keys):
        shape, _, scale = table[g][n]
        params.setdefault(g, {})
        if scale == 0.0:
            params[g][n] = jnp.zeros(shape, dt)
        else:
            params[g][n] = (jax.random.normal(k, shape, jnp.float32)
                            * scale).astype(dt)
    return params


def param_structs(cfg: ModelConfig):
    """(ShapeDtypeStruct pytree, Ax pytree) — no allocation (dry-run)."""
    table = param_table(cfg)
    dt = _dtype(cfg)
    shapes = {g: {n: jax.ShapeDtypeStruct(s, dt)
                  for n, (s, _, _) in grp.items()}
              for g, grp in table.items()}
    logical = {g: {n: ax for n, (_, ax, _) in grp.items()}
               for g, grp in table.items()}
    return shapes, logical


# ---------------------------------------------------------------------------
# Per-layer routing arrays
# ---------------------------------------------------------------------------

def layer_plan(cfg: ModelConfig):
    """Static per-layer routing: kinds + per-group indices (numpy int32)."""
    L = cfg.n_layers
    mixer = np.zeros(L, np.int32)
    ffn = np.zeros(L, np.int32)
    idx: Dict[str, np.ndarray] = {k: np.zeros(L, np.int32) for k in
                                  ("attn", "global", "local", "dense", "moe",
                                   "rec", "rwkv")}
    counters = dict(attn=0, glob=0, loc=0, dense=0, moe=0, rec=0, rwkv=0)
    for i, (lt, ft) in enumerate(zip(cfg.layer_types, cfg.ffn_types)):
        mixer[i] = MIXER_KINDS[lt]
        if lt in (ATTN_GLOBAL, ATTN_LOCAL):
            idx["attn"][i] = counters["attn"]
            counters["attn"] += 1
            if lt == ATTN_GLOBAL:
                idx["global"][i] = counters["glob"]
                counters["glob"] += 1
            else:
                idx["local"][i] = counters["loc"]
                counters["loc"] += 1
        elif lt == RGLRU:
            idx["rec"][i] = counters["rec"]
            counters["rec"] += 1
        elif lt == RWKV:
            idx["rwkv"][i] = counters["rwkv"]
            counters["rwkv"] += 1
        if lt == RWKV:
            ffn[i] = FFN_KIND_CMIX
        elif ft == FFN_MOE:
            ffn[i] = FFN_KIND_MOE
            idx["moe"][i] = counters["moe"]
            counters["moe"] += 1
        else:
            ffn[i] = FFN_KIND_DENSE
            idx["dense"][i] = counters["dense"]
            counters["dense"] += 1
    present_mixers = sorted(set(mixer.tolist()))
    present_ffns = sorted(set(ffn.tolist()))
    mixer_compact = np.array([present_mixers.index(m) for m in mixer],
                             np.int32)
    ffn_compact = np.array([present_ffns.index(f) for f in ffn], np.int32)
    return dict(mixer=mixer, ffn=ffn, mixer_compact=mixer_compact,
                ffn_compact=ffn_compact, present_mixers=present_mixers,
                present_ffns=present_ffns, **idx)


def tree_index(tree, i):
    return jax.tree.map(
        lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False), tree)


def tree_update(tree, i, new):
    return jax.tree.map(
        lambda a, n: jax.lax.dynamic_update_index_in_dim(a, n.astype(a.dtype),
                                                         i, 0), tree, new)


# ---------------------------------------------------------------------------
# Decode state
# ---------------------------------------------------------------------------

def decode_state_structs(cfg: ModelConfig, batch: int, max_seq: int):
    """(ShapeDtypeStruct pytree, Ax pytree) for the decode state."""
    dt = _dtype(cfg)
    hd, kv = cfg.head_dim, cfg.n_kv_heads
    w = min(cfg.window_size, max_seq)
    shapes: Dict[str, Any] = {"pos": jax.ShapeDtypeStruct((batch,),
                                                          jnp.int32)}
    logical: Dict[str, Any] = {"pos": Ax("batch")}
    cache_ax = Ax("layers", "batch", "kv_heads", "seq", "head_dim")
    cache_dt = jnp.int8 if cfg.kv_cache_dtype == "int8" else dt
    if cfg.n_global_layers:
        s = (cfg.n_global_layers, batch, kv, max_seq, hd)
        shapes["kg"] = jax.ShapeDtypeStruct(s, cache_dt)
        shapes["vg"] = jax.ShapeDtypeStruct(s, cache_dt)
        logical["kg"] = cache_ax
        logical["vg"] = cache_ax
        if cfg.kv_cache_dtype == "int8":
            ss = (cfg.n_global_layers, batch, kv, max_seq)
            sax = Ax("layers", "batch", "kv_heads", "seq")
            shapes["kg_scale"] = jax.ShapeDtypeStruct(ss, jnp.float32)
            shapes["vg_scale"] = jax.ShapeDtypeStruct(ss, jnp.float32)
            logical["kg_scale"] = sax
            logical["vg_scale"] = sax
    if cfg.n_local_layers:
        s = (cfg.n_local_layers, batch, kv, w, hd)
        shapes["kl"] = jax.ShapeDtypeStruct(s, dt)
        shapes["vl"] = jax.ShapeDtypeStruct(s, dt)
        logical["kl"] = Ax("layers", "batch", "kv_heads", "seq_nosplit",
                           "head_dim")
        logical["vl"] = Ax("layers", "batch", "kv_heads", "seq_nosplit",
                           "head_dim")
    if cfg.n_rec_layers:
        shapes["rg_h"] = jax.ShapeDtypeStruct(
            (cfg.n_rec_layers, batch, cfg.rnn_width), dt)
        shapes["rg_conv"] = jax.ShapeDtypeStruct(
            (cfg.n_rec_layers, batch, cfg.conv_width - 1, cfg.rnn_width), dt)
        logical["rg_h"] = Ax("layers", "batch", "rnn")
        logical["rg_conv"] = Ax("layers", "batch", None, "rnn")
    if cfg.n_rwkv_layers:
        nh, rhd = cfg.n_rwkv_heads, cfg.rwkv_head_dim
        shapes["rwkv_wkv"] = jax.ShapeDtypeStruct(
            (cfg.n_rwkv_layers, batch, nh, rhd, rhd), dt)
        shapes["rwkv_shift"] = jax.ShapeDtypeStruct(
            (cfg.n_rwkv_layers, batch, cfg.d_model), dt)
        shapes["rwkv_cshift"] = jax.ShapeDtypeStruct(
            (cfg.n_rwkv_layers, batch, cfg.d_model), dt)
        logical["rwkv_wkv"] = Ax("layers", "batch", "heads", None, None)
        logical["rwkv_shift"] = Ax("layers", "batch", "embed")
        logical["rwkv_cshift"] = Ax("layers", "batch", "embed")
    return shapes, logical


def init_decode_state(cfg: ModelConfig, batch: int, max_seq: int):
    shapes, _ = decode_state_structs(cfg, batch, max_seq)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)


# ---------------------------------------------------------------------------
# Full-sequence forward (train + prefill)
# ---------------------------------------------------------------------------

def _tile_size(n, cap):
    """Largest divisor of ``n`` not exceeding ``cap`` (kernel tiling)."""
    for d in range(min(cap, n), 0, -1):
        if n % d == 0:
            return d
    return 1


def _mixer_fullseq_branch(kind, cfg, params, plan_arrays, positions,
                          write_cache, valid_len=None, prefix_len=None,
                          prefix_kv=None):
    """Returns branch fn(operand) -> (y, state) for lax.switch.

    ``valid_len`` (traced, bucketed prefill; scalar or per-example (B,)):
    tokens at positions >= valid_len are padding. Global-cache writes of
    padding rows are harmless (masked by ``pos`` validity at decode and
    overwritten as the sequence advances), but the LOCAL ring cache wraps
    modulo the window — the real tail [valid_len - w, valid_len) must
    land in the ring, not the padded tail — so the ring is rebuilt
    functionally: slot s takes the LATEST real position ≡ s (mod w),
    exactly the invariant the unpadded write path establishes. (The
    valid_len path assumes ``positions == prefix + arange(T)``, which is
    how the engine prefills.)

    ``prefix_kv`` (+ traced ``prefix_len``): cached-prefix suffix
    prefill. ``prefix_kv`` is {"pool": (nG, nP, KV, page, hd) paged KV
    pool, "scale": matching int8 scale pool or None, "bt_k"/"bt_v":
    (B, P) block tables} addressing the pages that already hold
    positions [0, prefix_len). The new tokens' queries (at absolute
    positions ``prefix_len + t``) take a non-causal paged pass over the
    real prefix pages plus a causal flash pass over the fresh suffix,
    merged by online-softmax state (logit softcap applied in both
    kernels). Only global layers support a prefix — the engine gates
    the prefix cache to local-free archs."""

    def attn_branch(op, *, local):
        x, state, idxs = op
        p = tree_index(params["attn"], idxs["attn"])
        xn = rms_norm(x, p["ln"], cfg.norm_eps)
        q, k, v = attn_mod.project_qkv(xn, p, cfg, positions)
        window = cfg.window_size if local else 0
        if prefix_kv is not None and not local:
            # Suffix prefill, two passes merged via online-softmax state:
            # (1) a paged prefix pass streams ONLY the real cached pages
            # through scalar-prefetched block tables (non-causal — every
            # suffix query sits past the whole prefix), (2) a causal
            # flash pass over the fresh suffix at relative offset 0.
            # Each emits unfinalized (m, l, acc); the merge rescales by
            # exp(m_i - m) and the finalize normalizes once. plen == 0
            # (cold first chunk) leaves the prefix state at the exact
            # merge identity (m = -inf, l = acc = 0).
            from repro.kernels import flash_attention as fk
            from repro.kernels import ops as kops
            pool = tree_index(prefix_kv["pool"], idxs["global"])
            spool = (tree_index(prefix_kv["scale"], idxs["global"])
                     if prefix_kv.get("scale") is not None else None)
            t_q = q.shape[1]
            cap = float(cfg.attn_logit_softcap or 0.0)
            plen_vec = jnp.broadcast_to(
                jnp.asarray(prefix_len, jnp.int32), (q.shape[0],))
            st_p = fk.paged_prefix_attend(
                q, pool, prefix_kv["bt_k"], prefix_kv["bt_v"], plen_vec,
                k_scale_pool=spool, v_scale_pool=spool, softcap=cap,
                tq=_tile_size(t_q, 256))
            st_s = fk.flash_prefill(q, k, v, offset=0,
                                    tq=_tile_size(t_q, 256),
                                    ts=_tile_size(t_q, 512),
                                    softcap=cap, emit_state=True)
            y = kops.finalize_prefill_state(
                kops.merge_prefill_states(st_s, st_p), dtype=q.dtype)
        else:
            y = attn_mod.attention_fullseq(
                q, k, v, positions, positions, window=window,
                attn_softcap=cfg.attn_logit_softcap)
        y = attn_mod.output_proj(y, p)
        if write_cache and state:
            t = x.shape[1]
            if local and "kl" in state:
                w = state["kl"].shape[3]
                kc = state["kl"]
                kn = tree_index(kc, idxs["local"])
                vn = tree_index(state["vl"], idxs["local"])
                if valid_len is None:
                    n = min(t, w)
                    slots = jnp.mod(positions[-n:], w)
                    kn = kn.at[:, :, slots, :].set(
                        k[:, -n:].transpose(0, 2, 1, 3).astype(kn.dtype))
                    vn = vn.at[:, :, slots, :].set(
                        v[:, -n:].transpose(0, 2, 1, 3).astype(vn.dtype))
                else:
                    # Latest real position per ring slot: p(s) is the
                    # largest p < valid_len with p ≡ s (mod w); slots
                    # with no such p (valid_len < w tail) keep old rows.
                    # valid_len may be per-example (B,) — the cohort
                    # scheduler right-pads ragged prompts to one bucket.
                    vl = jnp.asarray(valid_len, jnp.int32)
                    bN = kn.shape[0]
                    vl_b = (jnp.broadcast_to(vl, (bN,)) if vl.ndim == 0
                            else vl)
                    s_arr = jnp.arange(w, dtype=jnp.int32)
                    p_s = s_arr[None, :] + w * (
                        (vl_b[:, None] - 1 - s_arr[None, :]) // w)
                    keep = (p_s >= 0)[:, None, :, None]
                    p_c = jnp.clip(p_s, 0, t - 1)         # (B, w)
                    k_rows = jnp.take_along_axis(
                        k, p_c[:, :, None, None], axis=1)  # (B, w, KV, hd)
                    v_rows = jnp.take_along_axis(
                        v, p_c[:, :, None, None], axis=1)
                    k_rows = k_rows.transpose(0, 2, 1, 3)
                    v_rows = v_rows.transpose(0, 2, 1, 3)
                    kn = jnp.where(keep, k_rows.astype(kn.dtype), kn)
                    vn = jnp.where(keep, v_rows.astype(vn.dtype), vn)
                state = dict(state)
                state["kl"] = tree_update(kc, idxs["local"], kn)
                state["vl"] = tree_update(state["vl"], idxs["local"], vn)
            elif not local and "kg" in state:
                kn = tree_index(state["kg"], idxs["global"])
                vn = tree_index(state["vg"], idxs["global"])
                state = dict(state)
                kt = k.transpose(0, 2, 1, 3)          # (B, KV, T, hd)
                vt = v.transpose(0, 2, 1, 3)
                if cfg.kv_cache_dtype == "int8":
                    from repro.core.cache import quant_rows
                    kq, ks = quant_rows(kt)
                    vq, vs = quant_rows(vt)
                    kn = kn.at[:, :, positions, :].set(kq)
                    vn = vn.at[:, :, positions, :].set(vq)
                    ksn = tree_index(state["kg_scale"], idxs["global"])
                    vsn = tree_index(state["vg_scale"], idxs["global"])
                    ksn = ksn.at[:, :, positions].set(ks)
                    vsn = vsn.at[:, :, positions].set(vs)
                    state["kg_scale"] = tree_update(
                        state["kg_scale"], idxs["global"], ksn)
                    state["vg_scale"] = tree_update(
                        state["vg_scale"], idxs["global"], vsn)
                else:
                    kn = kn.at[:, :, positions, :].set(kt.astype(kn.dtype))
                    vn = vn.at[:, :, positions, :].set(vt.astype(vn.dtype))
                state["kg"] = tree_update(state["kg"], idxs["global"], kn)
                state["vg"] = tree_update(state["vg"], idxs["global"], vn)
        return x + y, state

    def rglru_branch(op):
        x, state, idxs = op
        p = tree_index(params["rglru"], idxs["rec"])
        xn = rms_norm(x, p["ln"], cfg.norm_eps)
        if state and "rg_h" in state:
            h0 = tree_index(state["rg_h"], idxs["rec"])
            tail = tree_index(state["rg_conv"], idxs["rec"])
            y, (h1, tail1) = rglru.rglru_fullseq(xn, p, cfg, h0=h0,
                                                 conv_tail=tail)
            state = dict(state)
            state["rg_h"] = tree_update(state["rg_h"], idxs["rec"], h1)
            state["rg_conv"] = tree_update(state["rg_conv"], idxs["rec"],
                                           tail1)
        else:
            y, _ = rglru.rglru_fullseq(xn, p, cfg)
        return x + y, state

    def rwkv_branch(op):
        x, state, idxs = op
        p = tree_index(params["rwkv"], idxs["rwkv"])
        xn = rms_norm(x, p["ln1"], cfg.norm_eps)
        if state and "rwkv_wkv" in state:
            st = {"shift": tree_index(state["rwkv_shift"], idxs["rwkv"]),
                  "wkv": tree_index(state["rwkv_wkv"], idxs["rwkv"])}
        else:
            b = x.shape[0]
            st = {"shift": jnp.zeros((b, cfg.d_model), x.dtype),
                  "wkv": jnp.zeros((b, cfg.n_rwkv_heads, cfg.rwkv_head_dim,
                                    cfg.rwkv_head_dim), x.dtype)}
        y, st1 = rwkv.rwkv_time_mix_fullseq(xn, p, cfg, st)
        if state and "rwkv_wkv" in state:
            state = dict(state)
            state["rwkv_shift"] = tree_update(state["rwkv_shift"],
                                              idxs["rwkv"], st1["shift"])
            state["rwkv_wkv"] = tree_update(state["rwkv_wkv"], idxs["rwkv"],
                                            st1["wkv"])
        return x + y, state

    if kind == MIXER_KINDS[ATTN_GLOBAL]:
        return functools.partial(attn_branch, local=False)
    if kind == MIXER_KINDS[ATTN_LOCAL]:
        return functools.partial(attn_branch, local=True)
    if kind == MIXER_KINDS[RGLRU]:
        return rglru_branch
    return rwkv_branch


def _ffn_fullseq_branch(kind, cfg, params, moe_impl="capacity"):
    def dense_branch(op):
        x, state, idxs, aux = op
        p = tree_index(params["ffn"], idxs["dense"])
        xn = rms_norm(x, p["ln"], cfg.norm_eps)
        return x + mlp.dense_ffn(xn, p, cfg), state, aux

    def moe_branch(op):
        x, state, idxs, aux = op
        p = tree_index(params["moe"], idxs["moe"])
        xn = rms_norm(x, p["ln"], cfg.norm_eps)
        if moe_impl == "ragged":
            y = moe.moe_ffn_ragged(xn, p, cfg)
        elif moe_impl == "ep":
            from repro.sharding.context import current_ctx
            ctx = current_ctx()
            if ctx is None:
                y, a = moe.moe_ffn(xn, p, cfg, return_aux=True)
            else:
                y, a = moe.moe_ffn_ep(xn, p, cfg, ctx, return_aux=True)
            aux = {"load_balance": aux["load_balance"] + a["load_balance"],
                   "router_z": aux["router_z"] + a["router_z"]}
        else:
            y, a = moe.moe_ffn(xn, p, cfg, return_aux=True)
            aux = {"load_balance": aux["load_balance"] + a["load_balance"],
                   "router_z": aux["router_z"] + a["router_z"]}
        return x + y, state, aux

    def cmix_branch(op):
        x, state, idxs, aux = op
        p = tree_index(params["rwkv"], idxs["rwkv"])
        xn = rms_norm(x, p["ln2"], cfg.norm_eps)
        if state and "rwkv_cshift" in state:
            last = tree_index(state["rwkv_cshift"], idxs["rwkv"])
        else:
            last = jnp.zeros((x.shape[0], cfg.d_model), x.dtype)
        y, last1 = rwkv.rwkv_channel_mix_fullseq(xn, p, last)
        if state and "rwkv_cshift" in state:
            state = dict(state)
            state["rwkv_cshift"] = tree_update(state["rwkv_cshift"],
                                               idxs["rwkv"], last1)
        return x + y, state, aux

    return {FFN_KIND_DENSE: dense_branch, FFN_KIND_MOE: moe_branch,
            FFN_KIND_CMIX: cmix_branch}[kind]


def forward_fullseq(params, cfg: ModelConfig, inputs, *, state=None,
                    positions=None, remat=False, logits_slice=None,
                    moe_impl=None, unroll=False, valid_len=None,
                    prefix_len=None, prefix_kv=None):
    """inputs: tokens (B, T) int32, or embeddings (B, T, d) for stub
    frontends. state: decode-state pytree to fill (prefill) or None (train).

    Returns (logits, state, aux). ``logits_slice``: if "last", only the final
    position's logits are computed (prefill saves the unembed matmul).
    ``unroll``: unroll the layer scan — identical math, layer-count-sized
    HLO; used by the dry-run so cost_analysis counts every layer (XLA
    counts a while body ONCE — measured in EXPERIMENTS.md §Roofline).
    ``valid_len`` (traced int32, scalar or per-example (B,)): bucketed
    prefill — tokens at index >= valid_len within this call are
    right-padding. "last" logits then come from index valid_len - 1, the
    decode state's ``pos`` starts at valid_len, and local ring-cache
    writes mask the padding tail (the engine's power-of-two prompt
    buckets reuse one jit per bucket; the cohort scheduler passes a
    per-example vector for ragged cohorts).
    ``prefix_len``/``prefix_kv`` (traced scalar + paged pool/block-table
    dict, see ``_mixer_fullseq_branch``): cached-prefix suffix prefill —
    this call's tokens sit at absolute positions ``prefix_len +
    arange(T)`` and attend over the cached prefix pages; global-cache
    writes land at those absolute positions, and ``pos`` starts at
    ``prefix_len + valid_len``.
    """
    plan = layer_plan(cfg)
    if inputs.dtype in (jnp.int32, jnp.int64):
        h = embed_lookup(params["embed"]["tok"], inputs).astype(_dtype(cfg))
    else:
        h = frontends.adapt(inputs.astype(_dtype(cfg)), params["frontend"])
    b, t = h.shape[0], h.shape[1]
    if positions is None:
        positions = jnp.arange(t, dtype=jnp.int32)
        if prefix_len is not None:
            positions = jnp.asarray(prefix_len, jnp.int32) + positions

    xs = {
        "mixer_compact": jnp.asarray(plan["mixer_compact"]),
        "ffn_compact": jnp.asarray(plan["ffn_compact"]),
        "idxs": {k: jnp.asarray(plan[k]) for k in
                 ("attn", "global", "local", "dense", "moe", "rec", "rwkv")},
    }
    mixer_branches = [
        _mixer_fullseq_branch(k, cfg, params, plan, positions,
                              write_cache=state is not None,
                              valid_len=valid_len, prefix_len=prefix_len,
                              prefix_kv=prefix_kv)
        for k in plan["present_mixers"]]
    if moe_impl is None:
        # inference paths (prefill) default to the exact dropless MoE
        moe_impl = "capacity" if state is None else "ragged"
    ffn_branches = [_ffn_fullseq_branch(k, cfg, params, moe_impl)
                    for k in plan["present_ffns"]]

    empty_state = state if state is not None else {}

    from repro.sharding.context import pin_activations

    def body(carry, x_i):
        hh, st, aux = carry
        hh, st = jax.lax.switch(x_i["mixer_compact"], mixer_branches,
                                (hh, st, x_i["idxs"]))
        hh, st, aux = jax.lax.switch(x_i["ffn_compact"], ffn_branches,
                                     (hh, st, x_i["idxs"], aux))
        return (pin_activations(hh), st, aux), None

    if remat:
        body = jax.checkpoint(body)

    aux0 = {"load_balance": jnp.zeros((), jnp.float32),
            "router_z": jnp.zeros((), jnp.float32)}
    (h, out_state, aux), _ = jax.lax.scan(body, (h, empty_state, aux0), xs,
                                          unroll=cfg.n_layers if unroll
                                          else 1)

    h = rms_norm(h, params["final_norm"]["scale"], cfg.norm_eps)
    if logits_slice == "last":
        if valid_len is None:
            h = h[:, -1:]
        else:   # bucketed prefill: last REAL token, not last padded one
            vl = jnp.asarray(valid_len, jnp.int32)
            if vl.ndim == 0:
                h = jax.lax.dynamic_slice_in_dim(h, vl - 1, 1, axis=1)
            else:   # ragged cohort: per-example last real token
                h = jnp.take_along_axis(h, (vl - 1)[:, None, None], axis=1)
    w_un = (params["embed"]["tok"].T if cfg.tie_embeddings
            else params["unembed"]["w"])
    logits = unembed(h, w_un, cfg.final_logit_softcap)
    if state is not None and "pos" in out_state:
        out_state = dict(out_state)
        fill = t if valid_len is None else jnp.asarray(valid_len, jnp.int32)
        if prefix_len is not None:
            fill = fill + jnp.asarray(prefix_len, jnp.int32)
        out_state["pos"] = jnp.broadcast_to(
            jnp.asarray(fill, jnp.int32), (b,))
    return logits, (out_state if state is not None else None), aux


# ---------------------------------------------------------------------------
# Decode step (one token). CHAI hooks: see repro/core/chai_attention.py
# ---------------------------------------------------------------------------

def _mixer_decode_branch(kind, cfg, params, chai_ctx, mixed_phase=False,
                         decode_ts=0, relay=None):
    from repro.core import chai_attention as chai_mod

    def attn_branch(op, *, local):
        x, state, idxs = op     # x: (B, d)
        p = tree_index(params["attn"], idxs["attn"])
        xn = rms_norm(x, p["ln"], cfg.norm_eps)
        pos = state["pos"]      # (B,)
        if chai_ctx is not None and mixed_phase:
            # Continuous batching: warmup and steady slots share the batch.
            # Run both attention paths in one jit and mask-and-select per
            # slot (static shapes). Each path commits its cache writes only
            # for its own slots (write_mask), so every buffer keeps a
            # single linear update chain — donation aliases in place, no
            # whole-buffer merge copies.
            from repro.core import cache as chai_cache
            steady = state["phase"] >= chai_cache.PHASE_STEADY   # (B,)
            y_m, state = _plain_decode_attention(xn, p, cfg, state, idxs,
                                                 local=local,
                                                 write_mask=~steady)
            y_c, state = chai_mod.chai_decode_attention(
                xn, p, cfg, state, idxs, chai_ctx, local=local,
                write_mask=steady, decode_ts=decode_ts, relay=relay)
            y = jnp.where(steady[:, None, None], y_c, y_m)
        elif chai_ctx is not None:
            y, state = chai_mod.chai_decode_attention(
                xn, p, cfg, state, idxs, chai_ctx, local=local,
                decode_ts=decode_ts, relay=relay)
        else:
            y, state = _plain_decode_attention(xn, p, cfg, state, idxs,
                                               local=local)
        y = jnp.einsum("bhe,hed->bd", y, p["wo"])
        return x + y, state

    def rglru_branch(op):
        x, state, idxs = op
        p = tree_index(params["rglru"], idxs["rec"])
        xn = rms_norm(x, p["ln"], cfg.norm_eps)
        h0 = tree_index(state["rg_h"], idxs["rec"])
        tail = tree_index(state["rg_conv"], idxs["rec"])
        y, (h1, tail1) = rglru.rglru_decode(xn, p, cfg, h0, tail)
        state = dict(state)
        state["rg_h"] = tree_update(state["rg_h"], idxs["rec"], h1)
        state["rg_conv"] = tree_update(state["rg_conv"], idxs["rec"], tail1)
        return x + y, state

    def rwkv_branch(op):
        x, state, idxs = op
        p = tree_index(params["rwkv"], idxs["rwkv"])
        xn = rms_norm(x, p["ln1"], cfg.norm_eps)
        st = {"shift": tree_index(state["rwkv_shift"], idxs["rwkv"]),
              "wkv": tree_index(state["rwkv_wkv"], idxs["rwkv"])}
        y, st1 = rwkv.rwkv_time_mix_decode(xn, p, cfg, st)
        state = dict(state)
        state["rwkv_shift"] = tree_update(state["rwkv_shift"], idxs["rwkv"],
                                          st1["shift"])
        state["rwkv_wkv"] = tree_update(state["rwkv_wkv"], idxs["rwkv"],
                                        st1["wkv"])
        return x + y, state

    if kind == MIXER_KINDS[ATTN_GLOBAL]:
        return functools.partial(attn_branch, local=False)
    if kind == MIXER_KINDS[ATTN_LOCAL]:
        return functools.partial(attn_branch, local=True)
    if kind == MIXER_KINDS[RGLRU]:
        return rglru_branch
    return rwkv_branch


def _masked_rows(write_mask, new, old):
    """Commit ``new`` only for slots in ``write_mask`` (mixed-phase step);
    identity when no mask. new/old: (B, ...)."""
    if write_mask is None:
        return new
    m = write_mask.reshape((-1,) + (1,) * (new.ndim - 1))
    return jnp.where(m, new, old)


def paged_token_coords(bt, pos, page):
    """(physical page, in-page row) for each slot's current write position.
    bt: (B, P) block table; pos: (B,). Unallocated logical pages map to
    the null sink page 0 — writes there are harmless by construction."""
    b = pos.shape[0]
    return bt[jnp.arange(b), pos // page], pos % page


def _paged_write_rows(pool, page_idx, row, new, old_masker):
    """Commit one token's rows into pool pages. pool: (nP, rows, page, hd)
    or (nP, rows, page); page_idx/row: (B,)."""
    if pool.ndim == 4:
        old = pool[page_idx, :, row, :]
        return pool.at[page_idx, :, row, :].set(
            old_masker(new.astype(pool.dtype), old))
    old = pool[page_idx, :, row]
    return pool.at[page_idx, :, row].set(old_masker(new, old))


def _paged_global_write(state, idxs, k, v, pos, write_mask, cfg):
    """Paged-layout global-cache decode write: commit one token's K/V
    rows into each slot's current page of the shared dense pool. Returns
    (state, pool, scale_pool-or-None) WITHOUT densifying — the fused
    decode kernel streams the pool through its block tables directly."""
    from repro.core.cache import quant_rows
    pool = tree_index(state["kvp"], idxs["global"])   # (nP, KV, page, hd)
    page = pool.shape[2]
    pk, row = paged_token_coords(state["bt_kg"], pos, page)
    pv, _ = paged_token_coords(state["bt_vg"], pos, page)
    mask = functools.partial(_masked_rows, write_mask)
    state = dict(state)
    spool = None
    if cfg.kv_cache_dtype == "int8":
        kq, ks = quant_rows(k)
        vq, vs = quant_rows(v)
        pool = _paged_write_rows(pool, pk, row, kq, mask)
        pool = _paged_write_rows(pool, pv, row, vq, mask)
        spool = tree_index(state["kvp_scale"], idxs["global"])
        spool = _paged_write_rows(spool, pk, row, ks, mask)
        spool = _paged_write_rows(spool, pv, row, vs, mask)
        state["kvp_scale"] = tree_update(state["kvp_scale"],
                                         idxs["global"], spool)
    else:
        pool = _paged_write_rows(pool, pk, row, k, mask)
        pool = _paged_write_rows(pool, pv, row, v, mask)
    state["kvp"] = tree_update(state["kvp"], idxs["global"], pool)
    return state, pool, spool


def _paged_global_update(state, idxs, k, v, pos, write_mask, cfg):
    """``_paged_global_write`` + dense logical views (B, KV, S, hd)
    gathered through the block tables — the jnp fallback's interface
    (the attention math downstream is identical to the dense layout's).
    """
    from repro.core.cache import dequant_rows, gather_pages
    state, pool, spool = _paged_global_write(state, idxs, k, v, pos,
                                             write_mask, cfg)
    kc_f = gather_pages(pool, state["bt_kg"])
    vc_f = gather_pages(pool, state["bt_vg"])
    if spool is not None:
        kc_f = dequant_rows(kc_f, gather_pages(spool, state["bt_kg"]))
        vc_f = dequant_rows(vc_f, gather_pages(spool, state["bt_vg"]))
    return state, kc_f, vc_f


def _plain_decode_attention(xn, p, cfg, state, idxs, *, local,
                            write_mask=None):
    """MHA/GQA decode for one token. xn: (B, d). Returns ((B, H, hd), state).

    ``write_mask`` (B,) bool: cache rows are committed only for masked
    slots (the mixed-phase step runs this path alongside the CHAI path)."""
    b = xn.shape[0]
    pos = state["pos"]
    ar = jnp.arange(b)
    # positions (B, 1): per-example rotary phase for the new token
    q, k, v = attn_mod.project_qkv(xn[:, None], p, cfg, pos[:, None])
    q = q[:, 0]      # (B, H, hd)
    k = k[:, 0]      # (B, KV, hd)
    v = v[:, 0]
    if local:
        w = state["kl"].shape[3]
        kc = tree_index(state["kl"], idxs["local"])
        vc = tree_index(state["vl"], idxs["local"])
        slot = jnp.mod(pos, w)
        kc = kc.at[ar, :, slot, :].set(
            _masked_rows(write_mask, k.astype(kc.dtype), kc[ar, :, slot, :]))
        vc = vc.at[ar, :, slot, :].set(
            _masked_rows(write_mask, v.astype(vc.dtype), vc[ar, :, slot, :]))
        kv_pos = jax.vmap(lambda pp: attn_mod.ring_positions(pp + 1, w))(pos)
        state = dict(state)
        state["kl"] = tree_update(state["kl"], idxs["local"], kc)
        state["vl"] = tree_update(state["vl"], idxs["local"], vc)
        window = cfg.window_size
    elif "kvp" in state:
        # Paged layout: same math over block-table-gathered views.
        state, kc_f, vc_f = _paged_global_update(state, idxs, k, v, pos,
                                                 write_mask, cfg)
        s = kc_f.shape[2]
        kv_pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        window = 0
        kc, vc = kc_f, vc_f
    else:
        s = state["kg"].shape[3]
        kc = tree_index(state["kg"], idxs["global"])
        vc = tree_index(state["vg"], idxs["global"])
        state = dict(state)
        if cfg.kv_cache_dtype == "int8":
            from repro.core.cache import dequant_rows, quant_rows
            kq, ks = quant_rows(k)              # (B, KV, hd), (B, KV)
            vq, vs = quant_rows(v)
            kc = kc.at[ar, :, pos, :].set(
                _masked_rows(write_mask, kq, kc[ar, :, pos, :]))
            vc = vc.at[ar, :, pos, :].set(
                _masked_rows(write_mask, vq, vc[ar, :, pos, :]))
            ksc = tree_index(state["kg_scale"], idxs["global"])
            vsc = tree_index(state["vg_scale"], idxs["global"])
            ksc = ksc.at[ar, :, pos].set(
                _masked_rows(write_mask, ks, ksc[ar, :, pos]))
            vsc = vsc.at[ar, :, pos].set(
                _masked_rows(write_mask, vs, vsc[ar, :, pos]))
            state["kg_scale"] = tree_update(state["kg_scale"],
                                            idxs["global"], ksc)
            state["vg_scale"] = tree_update(state["vg_scale"],
                                            idxs["global"], vsc)
            kc_f, vc_f = dequant_rows(kc, ksc), dequant_rows(vc, vsc)
        else:
            kc = kc.at[ar, :, pos, :].set(
                _masked_rows(write_mask, k.astype(kc.dtype),
                             kc[ar, :, pos, :]))
            vc = vc.at[ar, :, pos, :].set(
                _masked_rows(write_mask, v.astype(vc.dtype),
                             vc[ar, :, pos, :]))
            kc_f, vc_f = kc, vc
        kv_pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        state["kg"] = tree_update(state["kg"], idxs["global"], kc)
        state["vg"] = tree_update(state["vg"], idxs["global"], vc)
        window = 0
        kc, vc = kc_f, vc_f
    y, probs = _decode_attention_batched(q, kc, vc, kv_pos, pos, window,
                                         cfg.attn_logit_softcap)
    if "chai_scores" in state:
        # CHAI warmup: accumulate attention over the first feature_window
        # prefix positions as clustering features (paper §3.3).
        wf = state["chai_scores"].shape[-1]
        pw = probs.reshape(b, -1, probs.shape[-1])[:, :, :wf]  # (B, H, Wf)
        if pw.shape[-1] < wf:   # local ring narrower than feature window
            pw = jnp.pad(pw, ((0, 0), (0, 0), (0, wf - pw.shape[-1])))
        if write_mask is not None:   # steady slots: features stay frozen
            pw = pw * write_mask[:, None, None]
        buf = tree_index(state["chai_scores"], idxs["attn"])
        state["chai_scores"] = tree_update(state["chai_scores"],
                                           idxs["attn"], buf + pw)
    return y, state


def _decode_attention_batched(q, kc, vc, kv_pos, pos, window, cap):
    """Per-example-position decode attention. q: (B,H,hd); kc/vc: (B,KV,S,hd);
    kv_pos: (B,S); pos: (B,)."""
    b, h, hd = q.shape
    n_kv, s = kc.shape[1], kc.shape[2]
    qs = q.reshape(b, n_kv, h // n_kv, hd).astype(jnp.float32)
    scale = 1.0 / math.sqrt(hd)
    sc = jnp.einsum("bkgd,bksd->bkgs", qs, kc.astype(jnp.float32)) * scale
    sc = softcap(sc, cap)
    valid = (kv_pos >= 0) & (kv_pos <= pos[:, None])
    if window:
        valid &= (pos[:, None] - kv_pos) < window
    sc = jnp.where(valid[:, None, None, :], sc, attn_mod.NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bkgs,bksd->bkgd", p, vc.astype(jnp.float32))
    return out.reshape(b, h, hd).astype(q.dtype), p


def _ffn_decode_branch(kind, cfg, params, moe_impl="ragged"):
    def dense_branch(op):
        x, state, idxs = op
        p = tree_index(params["ffn"], idxs["dense"])
        xn = rms_norm(x, p["ln"], cfg.norm_eps)
        return x + mlp.dense_ffn(xn[:, None], p, cfg)[:, 0], state

    def moe_branch(op):
        x, state, idxs = op
        p = tree_index(params["moe"], idxs["moe"])
        xn = rms_norm(x, p["ln"], cfg.norm_eps)
        if moe_impl == "ragged":
            y = moe.moe_ffn_ragged(xn[:, None], p, cfg)[:, 0]
        else:
            y = moe.moe_ffn(xn[:, None], p, cfg)[:, 0]
        return x + y, state

    def cmix_branch(op):
        x, state, idxs = op
        p = tree_index(params["rwkv"], idxs["rwkv"])
        xn = rms_norm(x, p["ln2"], cfg.norm_eps)
        last = tree_index(state["rwkv_cshift"], idxs["rwkv"])
        y, last1 = rwkv.rwkv_channel_mix_decode(xn, p, last)
        state = dict(state)
        state["rwkv_cshift"] = tree_update(state["rwkv_cshift"],
                                           idxs["rwkv"], last1)
        return x + y, state

    return {FFN_KIND_DENSE: dense_branch, FFN_KIND_MOE: moe_branch,
            FFN_KIND_CMIX: cmix_branch}[kind]


def decode_step(params, cfg: ModelConfig, tokens, state, *, chai_ctx=None,
                mixed_phase=False, embeddings=None, moe_impl="ragged",
                unroll=False, decode_ts=0, relay=None):
    """One decode step. tokens: (B,) int32 (or embeddings (B, d) for stub
    frontends). Returns (logits (B, V), new_state).

    Logits are float32 regardless of the model dtype (``unembed``
    promotes before the optional softcap) — the contract the batched
    sampler (``repro.launch.steps.make_sampler``) relies on: its
    ``temperature=0`` lane takes ``argmax`` over these exact values, so
    greedy serving is bitwise-stable across engine versions, and its
    sampling lanes get full-precision softmax/cumsum mass.

    ``mixed_phase``: with a ``chai_ctx``, route each batch slot through the
    MHA or CHAI attention path according to ``state["phase"]`` (unified
    per-slot layout — continuous batching). ``decode_ts``: S-tile size for
    the fused CHAI decode kernel on dense layouts (the engine passes its
    page size so every KV layout tiles — and therefore rounds —
    identically).

    ``relay`` (shared-prefix relay decode, pytree of group-batched
    arrays built by the engine): STEADY slots grouped by their deepest
    shared radix node skip their prefix pages in the fused decode and
    instead share ONE group-batched prefix-attention pass per layer over
    a contiguous resident copy of the shared pages. Both passes run on
    the online-softmax side-output contract: ``emit_state=True`` makes
    the fused decode kernels return the unfinalized triple
    (m (B, R), l (B, R), acc (B, A, hd)) — running row-max, running
    exp-sum, and UNNORMALIZED weighted-V accumulator, one row per rep
    (m, l) / per accumulator row (acc) — instead of finalized outputs.
    Triples combine associatively: m' = max(m1, m2), each side rescaled
    by exp(m_i - m'), and a single finalize divides acc by the gathered
    l and applies the head->cluster broadcast. The empty state
    (m = NEG_INF, l = 0, acc = 0) is the exact (bitwise) merge identity
    because in-kernel m is clamped >= -1e30 whenever any tile computed,
    so non-grouped slots ride through the same merge unchanged. See
    ``repro.kernels.ops.merge_decode_states`` / ``finalize_decode_state``
    and ``repro.core.chai_attention`` for the relay dict layout.
    """
    plan = layer_plan(cfg)
    if embeddings is not None:
        h = frontends.adapt(embeddings[:, None].astype(_dtype(cfg)),
                            params["frontend"])[:, 0]
    else:
        h = embed_lookup(params["embed"]["tok"], tokens).astype(_dtype(cfg))

    xs = {
        "mixer_compact": jnp.asarray(plan["mixer_compact"]),
        "ffn_compact": jnp.asarray(plan["ffn_compact"]),
        "idxs": {k: jnp.asarray(plan[k]) for k in
                 ("attn", "global", "local", "dense", "moe", "rec", "rwkv")},
    }
    mixer_branches = [_mixer_decode_branch(k, cfg, params, chai_ctx,
                                           mixed_phase, decode_ts, relay)
                      for k in plan["present_mixers"]]
    ffn_branches = [_ffn_decode_branch(k, cfg, params, moe_impl)
                    for k in plan["present_ffns"]]

    def body(carry, x_i):
        hh, st = carry
        hh, st = jax.lax.switch(x_i["mixer_compact"], mixer_branches,
                                (hh, st, x_i["idxs"]))
        hh, st = jax.lax.switch(x_i["ffn_compact"], ffn_branches,
                                (hh, st, x_i["idxs"]))
        return (hh, st), None

    (h, state), _ = jax.lax.scan(body, (h, state), xs,
                                 unroll=cfg.n_layers if unroll else 1)
    h = rms_norm(h, params["final_norm"]["scale"], cfg.norm_eps)
    w_un = (params["embed"]["tok"].T if cfg.tie_embeddings
            else params["unembed"]["w"])
    logits = unembed(h, w_un, cfg.final_logit_softcap)
    state = dict(state)
    state["pos"] = state["pos"] + 1
    return logits, state
