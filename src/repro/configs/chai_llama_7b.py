"""LLaMA-7B — the paper's primary evaluation model (Tables 2, Figs 1-13).

True MHA (32 Q = 32 KV heads): CHAI's full regime. Used by the benchmark
harness to mirror the paper's own tables.
"""
from repro.configs.base import ModelConfig, CHAIConfig, register

CONFIG = register(ModelConfig(
    name="chai-llama-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab_size=32000,
    activation="silu",
    rope_theta=10000.0,
    chai=CHAIConfig(enabled=True),
))
