"""Paper Fig 9: cluster-membership stability vs number of observed tokens.

Replays the engine's warmup on a trained tiny model: identify membership
after n = 1..N decode steps and measure churn vs the previous n. The
paper's claim: membership stabilizes after ~5 tokens."""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import save_result, tiny_trained
from repro.core.cache import add_score_buffer, pop_score_buffer
from repro.core.clustering import identify_membership, membership_churn
from repro.models import transformer as tfm


def run(max_tokens=10):
    cfg, params, pipe, _ = tiny_trained()
    cfg = cfg.with_chai(enabled=True, cluster_counts=(5,) * cfg.n_attn_layers)
    b, t0, s = 4, 24, 64
    toks = jnp.asarray(pipe.batch(800)["tokens"][:b, :t0])

    state = tfm.init_decode_state(cfg, b, s)
    _, state, _ = tfm.forward_fullseq(params, cfg, toks, state=state)
    state = add_score_buffer(state, cfg, b)

    churns, prev = [], None
    nxt = toks[:, -1]
    for n in range(1, max_tokens + 1):
        logits, state = tfm.decode_step(params, cfg, nxt, state)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        _, scores = pop_score_buffer(dict(state))   # peek, don't consume
        ctx = identify_membership(scores, cfg)
        if prev is not None:
            churns.append(float(membership_churn(prev, ctx)))
        prev = ctx

    result = {
        "proxy_note": "membership churn per added observed token "
                      "(trained tiny LM; paper Fig 9)",
        "churn_after_n_tokens": {str(i + 2): c
                                 for i, c in enumerate(churns)},
        "paper_claim": "after ~5 tokens membership rarely changes",
        "claim_check": {
            "late_churn_low": float(np.mean(churns[4:])) <=
                              float(np.mean(churns[:3])) + 1e-9,
            "tail_churn_small": float(np.mean(churns[-3:])) < 0.25,
        },
    }
    save_result("bench_membership", result)
    return result


if __name__ == "__main__":
    print(run())
