"""Generate the EXPERIMENTS.md §Roofline table from dry-run JSON results.

  PYTHONPATH=src python -m benchmarks.roofline_report [--unrolled]

Reads benchmarks/results/dryrun_single[_unrolled].json and prints a
markdown table: three roofline terms, dominant bottleneck, MODEL_FLOPS
ratio, per (arch x shape x step).
"""
from __future__ import annotations

import argparse
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def load(mesh="single", unrolled=True):
    suffix = "_unrolled" if unrolled else ""
    with open(os.path.join(RESULTS, f"dryrun_{mesh}{suffix}.json")) as f:
        return json.load(f)


def fmt_s(x):
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.0f}us"


def table(results, *, only_steps=None):
    rows = []
    for key in sorted(results):
        v = results[key]
        if "error" in v:
            rows.append(f"| {key} | ERROR | | | | | |")
            continue
        if only_steps and v["step"] not in only_steps:
            continue
        r = v["roofline"]
        tc, tm, tx = r["t_compute_s"], r["t_memory_s"], r["t_collective_s"]
        rows.append(
            f"| {v['arch']} | {v['shape']} | {v['step']} | {fmt_s(tc)} | "
            f"{fmt_s(tm)} | {fmt_s(tx)} | **{r['bottleneck']}** | "
            f"{v['useful_flop_ratio']:.3f} |")
    header = ("| arch | shape | step | t_compute | t_memory | t_collective "
              "| bottleneck | MODEL/HLO |\n"
              "|---|---|---|---|---|---|---|---|")
    return header + "\n" + "\n".join(rows)


def merged(mesh="single"):
    """Unrolled cells (exact per-layer counts) preferred; cells whose
    unrolled compile hasn't completed fall back to scanned artifacts,
    flagged with a trailing '*': their in-loop terms are lower bounds
    (XLA counts a while body once — §Roofline)."""
    out = {}
    try:
        scanned = load(mesh, unrolled=False)
    except FileNotFoundError:
        scanned = {}
    try:
        unrolled = load(mesh, unrolled=True)
    except FileNotFoundError:
        unrolled = {}
    for k, v in scanned.items():
        out[k] = dict(v, source="scanned*")
    for k, v in unrolled.items():
        if "error" not in v:
            out[k] = dict(v, source="unrolled")
    # perf-iteration variants lowered unrolled into results/perf/
    try:
        with open(os.path.join(RESULTS, "perf",
                               f"dryrun_{mesh}_unrolled.json")) as f:
            for k, v in json.load(f).items():
                if "error" not in v:
                    out[k] = dict(v, source="unrolled")
    except FileNotFoundError:
        pass
    return out


def merged_table(mesh="single"):
    results = merged(mesh)
    rows = []
    for key in sorted(results):
        v = results[key]
        if "error" in v:
            continue
        r = v["roofline"]
        tc, tm, tx = r["t_compute_s"], r["t_memory_s"], r["t_collective_s"]
        rows.append(
            f"| {v['arch']} | {v['shape']} | {v['step']} | {fmt_s(tc)} | "
            f"{fmt_s(tm)} | {fmt_s(tx)} | **{r['bottleneck']}** | "
            f"{v['useful_flop_ratio']:.3f} | {v['source']} |")
    header = ("| arch | shape | step | t_compute | t_memory | t_collective "
              "| bottleneck | MODEL/HLO | source |\n"
              "|---|---|---|---|---|---|---|---|---|")
    return header + "\n" + "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scanned", action="store_true",
                    help="use the scanned (loop-once-counted) artifacts")
    ap.add_argument("--merged", action="store_true",
                    help="unrolled preferred, scanned fallback (flagged)")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    if args.merged:
        print(merged_table(args.mesh))
        return
    results = load(args.mesh, unrolled=not args.scanned)
    print(table(results))
    n_ok = sum(1 for v in results.values() if "error" not in v)
    print(f"\n{n_ok}/{len(results)} cells ok")


if __name__ == "__main__":
    main()
