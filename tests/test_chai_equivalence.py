"""CHAI decode-path correctness against plain MHA decode.

The decisive invariants:
  1. **k == H, identity membership** -> CHAI decode == MHA decode exactly
     (every head is its own representative; nothing is pruned).
  2. **Duplicated heads** (wq/wk rows copied) -> CHAI with those heads
     clustered matches MHA to numerical tolerance, because the pruned
     heads' scores were genuinely redundant — the paper's core claim.
  3. CHAI-QKV ablation runs and differs (V sharing changes the output).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, reduced
from repro.core import cache as chai_cache
from repro.core import clustering
from repro.launch import steps as steps_mod
from repro.models import transformer as tfm


def _mha_arch(n_heads=8):
    cfg = reduced(get_config("musicgen-large"), n_heads=n_heads,
                  d_model=64, vocab=128, n_layers=2)
    return cfg.replace(frontend="none", dtype="float32")


def _setup(cfg, rng, b=2, t=8, s=32):
    params = tfm.init_params(cfg, jax.random.PRNGKey(1))
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(b, t)),
                       jnp.int32)
    prefill = steps_mod.make_serve_prefill(cfg, b, s)
    _, state = prefill(params, {"tokens": toks})
    return params, toks, state


def _identity_ctx(cfg, b):
    """Every head its own cluster: h2c = reps = arange(H)."""
    na, h = cfg.n_attn_layers, cfg.n_heads
    ar = jnp.tile(jnp.arange(h, dtype=jnp.int32), (na, b, 1))
    return {"h2c": ar, "reps": ar}


def test_chai_equals_mha_with_identity_clusters(rng):
    cfg = _mha_arch().with_chai(enabled=True,
                                cluster_counts=(8, 8))   # k == H
    b = 2
    params, toks, state = _setup(cfg, rng, b=b)
    ctx = _identity_ctx(cfg, b)

    mha_step = steps_mod.make_serve_step(cfg, chai=False)
    chai_step = steps_mod.make_serve_step(cfg, chai=True)
    state_chai = chai_cache.compact_kv(dict(state), ctx, cfg)

    nxt = jnp.asarray([5, 7], jnp.int32)
    logits_mha, st_m = mha_step(params, {"tokens": nxt}, dict(state))
    logits_chai, st_c = chai_step(params, {"tokens": nxt}, state_chai, ctx)
    np.testing.assert_allclose(np.asarray(logits_mha),
                               np.asarray(logits_chai), rtol=2e-4, atol=2e-4)
    # multi-step agreement
    for tok in ((1, 2), (3, 4)):
        nxt = jnp.asarray(tok, jnp.int32)
        logits_mha, st_m = mha_step(params, {"tokens": nxt}, st_m)
        logits_chai, st_c = chai_step(params, {"tokens": nxt}, st_c, ctx)
        np.testing.assert_allclose(np.asarray(logits_mha),
                                   np.asarray(logits_chai),
                                   rtol=2e-4, atol=2e-4)


def test_chai_exact_on_duplicated_heads(rng):
    """Duplicate head 0's Q/K into heads 1..3: clustering those four heads
    to one representative must reproduce MHA exactly (scores identical)."""
    cfg = _mha_arch().with_chai(enabled=True, cluster_counts=(5, 5))
    b = 2
    params = tfm.init_params(cfg, jax.random.PRNGKey(1))
    # duplicate q/k projections of head 0 into heads 1-3, all layers
    for nm in ("wq", "wk"):
        w = params["attn"][nm]
        for hdup in (1, 2, 3):
            w = w.at[:, :, hdup].set(w[:, :, 0])
        params["attn"][nm] = w
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(b, 8)),
                       jnp.int32)
    prefill = steps_mod.make_serve_prefill(cfg, b, 32)
    _, state = prefill(params, {"tokens": toks})

    # heads {0,1,2,3} -> cluster 0 (rep 0); heads 4..7 singleton clusters
    na, h = cfg.n_attn_layers, cfg.n_heads
    h2c = jnp.asarray([0, 0, 0, 0, 1, 2, 3, 4], jnp.int32)
    reps = jnp.asarray([0, 4, 5, 6, 7], jnp.int32)
    ctx = {"h2c": jnp.tile(h2c, (na, b, 1)),
           "reps": jnp.tile(reps, (na, b, 1))}

    mha_step = steps_mod.make_serve_step(cfg, chai=False)
    chai_step = steps_mod.make_serve_step(cfg, chai=True)
    state_chai = chai_cache.compact_kv(dict(state), ctx, cfg)
    nxt = jnp.asarray([5, 7], jnp.int32)
    lm, _ = mha_step(params, {"tokens": nxt}, dict(state))
    lc, _ = chai_step(params, {"tokens": nxt}, state_chai, ctx)
    np.testing.assert_allclose(np.asarray(lm), np.asarray(lc),
                               rtol=2e-4, atol=2e-4)


def test_chai_qkv_ablation_shares_values(rng):
    cfg = _mha_arch().with_chai(enabled=True, cluster_counts=(4, 4),
                                share_values=True)
    b = 2
    params, toks, state = _setup(cfg, rng, b=b)
    na, h = cfg.n_attn_layers, cfg.n_heads
    h2c = jnp.tile(jnp.arange(h, dtype=jnp.int32) % 4, (na, b, 1))
    reps = jnp.tile(jnp.arange(4, dtype=jnp.int32), (na, b, 1))
    ctx = {"h2c": h2c, "reps": reps}
    state_chai = chai_cache.compact_kv(dict(state), ctx, cfg)
    assert "vg_chai" in state_chai and "vg" not in state_chai
    chai_step = steps_mod.make_serve_step(cfg, chai=True)
    logits, st = chai_step(params, {"tokens": jnp.asarray([5, 7])},
                           state_chai, ctx)
    assert logits.shape == (b, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())


def test_gqa_chai_decode_runs_and_matches_identity(rng):
    """GQA arch with identity within-group clustering == plain decode."""
    cfg = reduced(get_config("nemotron-4-15b"), n_heads=8, d_model=64,
                  vocab=128, n_layers=2).replace(dtype="float32")
    cfg = cfg.with_chai(enabled=True)
    b = 2
    params, toks, state = _setup(cfg, rng, b=b)
    na, kv, qpk = cfg.n_attn_layers, cfg.n_kv_heads, cfg.q_per_kv
    ar = jnp.tile(jnp.arange(qpk, dtype=jnp.int32), (na, b, kv, 1))
    ctx = {"cluster_of": ar, "reps": ar}
    mha_step = steps_mod.make_serve_step(cfg, chai=False)
    chai_step = steps_mod.make_serve_step(cfg, chai=True)
    nxt = jnp.asarray([5, 7], jnp.int32)
    lm, _ = mha_step(params, {"tokens": nxt}, dict(state))
    lc, _ = chai_step(params, {"tokens": nxt}, dict(state), ctx)
    np.testing.assert_allclose(np.asarray(lm), np.asarray(lc),
                               rtol=2e-4, atol=2e-4)
