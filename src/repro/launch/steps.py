"""Jit-able step functions: train_step, serve_prefill, serve_step (+CHAI).

These are the exact functions the dry-run lowers and the drivers execute.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import cache as chai_cache
from repro.models import transformer as tfm
from repro.optim import adamw

LB_COEF = 0.01
Z_COEF = 1e-3


def cross_entropy(logits, labels):
    """logits (B, T, V) fp32; labels (B, T) int32 -> mean loss."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def make_loss_fn(cfg: ModelConfig, *, remat=True, moe_impl="capacity",
                 unroll=False):
    def loss_fn(params, batch):
        inputs = batch.get("tokens", batch.get("embeddings"))
        logits, _, aux = tfm.forward_fullseq(params, cfg, inputs,
                                             remat=remat, moe_impl=moe_impl,
                                             unroll=unroll)
        ce = cross_entropy(logits, batch["labels"])
        loss = ce + LB_COEF * aux["load_balance"] + Z_COEF * aux["router_z"]
        return loss, {"ce": ce, **aux}
    return loss_fn


def make_train_step(cfg: ModelConfig, *, remat=True, moe_impl="capacity",
                    lr_kw: Optional[dict] = None, unroll=False,
                    grad_dtype=None, grad_shardings=None):
    """``grad_dtype='bfloat16'`` casts gradients before the optimizer.
    ``grad_shardings``: pin gradients to the ZeRO (data-sharded) layout —
    without it XLA lowers the data-axis grad all-reduce as
    reduce-scatter + ALL-GATHER of the full f32 gradients, then re-slices
    for the sharded moments; the constraint deletes the gather
    (EXPERIMENTS.md §Perf iteration 4)."""
    loss_fn = make_loss_fn(cfg, remat=remat, moe_impl=moe_impl,
                           unroll=unroll)
    lr_kw = lr_kw or {}

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        if grad_dtype is not None:
            grads = jax.tree.map(
                lambda g: g.astype(jnp.dtype(grad_dtype)), grads)
        if grad_shardings is not None:
            grads = jax.lax.with_sharding_constraint(grads, grad_shardings)
        lr = adamw.cosine_lr(opt_state.step, **lr_kw) if lr_kw else None
        params, opt_state, om = adamw.update(grads, opt_state, params, lr=lr)
        return params, opt_state, {"loss": loss, **metrics, **om}

    return train_step


def make_serve_prefill(cfg: ModelConfig, batch: int, max_seq: int, *,
                       moe_impl="capacity", unroll=False):
    """Whole-cohort prefill. ``batch_inputs`` may carry ``true_lens``
    ((B,) int32): prompts are then right-padded to one power-of-two
    bucket and each row's padding tail is masked per example (last-real
    logits, per-example ``pos``), so the cohort scheduler compiles one
    prefill per BUCKET instead of one per padded cohort length."""
    def serve_prefill(params, batch_inputs):
        inputs = batch_inputs.get("tokens", batch_inputs.get("embeddings"))
        state = tfm.init_decode_state(cfg, batch, max_seq)
        logits, state, _ = tfm.forward_fullseq(
            params, cfg, inputs, state=state, logits_slice="last",
            moe_impl=moe_impl, unroll=unroll,
            valid_len=batch_inputs.get("true_lens"))
        return logits[:, 0], state

    return serve_prefill


def make_serve_step(cfg: ModelConfig, *, chai=False, moe_impl="capacity",
                    unroll=False, decode_ts=0):
    """``decode_ts``: S-tile size for the fused CHAI decode kernel on
    dense layouts — the engine passes its page size so the cohort/dense
    schedulers round exactly like the paged one (token parity)."""
    def serve_step(params, batch_inputs, state, chai_ctx=None):
        kw = {}
        if "embeddings" in batch_inputs:
            kw["embeddings"] = batch_inputs["embeddings"]
            tokens = None
        else:
            tokens = batch_inputs["tokens"]
        logits, state = tfm.decode_step(params, cfg, tokens, state,
                                        chai_ctx=chai_ctx if chai else None,
                                        moe_impl=moe_impl, unroll=unroll,
                                        decode_ts=decode_ts, **kw)
        return logits, state

    return serve_step


def make_sampler(top_k_cap: int = 256):
    """Batched per-slot token sampler — the single device-side sampling
    path shared by the continuous and cohort schedulers.

    ``sample(logits, temperature, top_k, top_p, seed, count)``:

    * ``logits`` (B, V); per-slot vectors ``temperature`` (B,) f32,
      ``top_k`` (B,) i32 (0 = widest support), ``top_p`` (B,) f32,
      ``seed`` (B,) u32, ``count`` (B,) i32 — tokens the slot's request
      has sampled so far.
    * Slots with ``temperature == 0`` take ``argmax(logits)`` — computed
      on the raw logits exactly as the engine's historical greedy path,
      so greedy decode stays BITWISE identical (CHAI snapshot replay and
      every cross-layout parity test rest on this). The whole sampling
      lane sits behind one batch-level ``lax.cond``: an all-greedy batch
      never pays for it, and greedy rows inside a mixed batch feed the
      lane a zeroed row instead of their (discarded) logits.
    * Sampling slots draw from ``fold_in(PRNGKey(seed), count)``: token
      n of a request depends only on (seed, n, logits) — never the slot
      id or engine step — so seeded runs reproduce across schedulers.
    * The candidate set is ``lax.top_k(scaled, min(top_k_cap, V))`` — an
      O(V·cap) selection instead of the old full-vocab argsort. top-k /
      top-p masks apply in descending order within the candidates (top-p
      after top-k, rank 0 always kept); probabilities are normalized
      against the FULL vocab (logsumexp over the row), so the nucleus
      mass matches the unsorted distribution exactly. ``top_k == 0`` and
      any nucleus extending past ``top_k_cap`` truncate to the cap.
    """
    def sample(logits, temperature, top_k, top_p, seed, count):
        lg = logits.astype(jnp.float32)
        greedy_tok = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        v = lg.shape[-1]
        cap = min(top_k_cap, v)

        def one(row, t, k, p, s, c):
            key = jax.random.fold_in(jax.random.PRNGKey(s), c)
            scaled = row / jnp.maximum(t, 1e-6)
            sl, idx = jax.lax.top_k(scaled, cap)       # descending, stable
            probs = jnp.exp(sl - jax.nn.logsumexp(scaled))
            cum = jnp.cumsum(probs)
            ranks = jnp.arange(cap)
            keep = ranks < jnp.where(k > 0, k, cap)    # top-k
            keep &= (cum - probs) < p                  # top-p (nucleus)
            keep = keep.at[0].set(True)                # never mask rank 0
            masked = jnp.where(keep, sl, -jnp.inf)
            pick = jax.random.categorical(key, masked)
            return jnp.take(idx, pick).astype(jnp.int32)

        def sampling_lane(_):
            # Greedy rows contribute a dead zero row — their draw is
            # discarded by the final select, so don't feed it real work.
            live = temperature > 0.0
            rows = jnp.where(live[:, None], lg, 0.0)
            return jax.vmap(one)(rows, temperature, top_k, top_p, seed,
                                 count)

        sampled = jax.lax.cond(jnp.any(temperature > 0.0), sampling_lane,
                               lambda _: greedy_tok, None)
        return jnp.where(temperature > 0.0, sampled, greedy_tok)

    return sample


def make_compact_step(cfg: ModelConfig):
    def compact(state, chai_ctx):
        return chai_cache.compact_kv(state, chai_ctx, cfg)
    return compact


# ---------------------------------------------------------------------------
# Continuous batching (slot-level) steps
# ---------------------------------------------------------------------------

def make_mixed_step(cfg: ModelConfig, *, moe_impl="ragged", unroll=False,
                    decode_ts=0):
    """Mixed-phase decode step: each batch slot is routed to the MHA path
    (WARMUP) or the CHAI path (STEADY) by ``state["phase"]`` — one jit,
    static shapes, mask-and-select inside the attention branch. The CHAI
    side runs the fused one-launch decode kernel (``decode_ts`` as in
    ``make_serve_step``)."""
    def mixed_step(params, batch_inputs, state, chai_ctx):
        kw = {}
        if "embeddings" in batch_inputs:
            kw["embeddings"] = batch_inputs["embeddings"]
            tokens = None
        else:
            tokens = batch_inputs["tokens"]
        logits, state = tfm.decode_step(params, cfg, tokens, state,
                                        chai_ctx=chai_ctx, mixed_phase=True,
                                        moe_impl=moe_impl, unroll=unroll,
                                        decode_ts=decode_ts, **kw)
        return logits, state

    return mixed_step


def make_relay_step(cfg: ModelConfig, *, moe_impl="ragged", unroll=False,
                    decode_ts=0):
    """Shared-prefix relay decode step: ``make_mixed_step`` plus a
    ``relay`` pytree of group-batched arrays (resident prefix K/V copies,
    row-routing maps, membership) built host-side by the engine. Grouped
    STEADY slots run ONE prefix-attention pass per group per layer and a
    suffix-only fused decode, merged by online-softmax state inside the
    attention branch; non-grouped slots ride through unchanged (their
    prefix state is the exact merge identity). Always mixed-phase — a
    relay batch may carry WARMUP slots, which are never grouped.
    Shape-specialized per (groups, max members, max prefix) signature."""
    def relay_step(params, batch_inputs, state, chai_ctx, relay):
        kw = {}
        if "embeddings" in batch_inputs:
            kw["embeddings"] = batch_inputs["embeddings"]
            tokens = None
        else:
            tokens = batch_inputs["tokens"]
        logits, state = tfm.decode_step(params, cfg, tokens, state,
                                        chai_ctx=chai_ctx, mixed_phase=True,
                                        moe_impl=moe_impl, unroll=unroll,
                                        decode_ts=decode_ts, relay=relay,
                                        **kw)
        return logits, state

    return relay_step


def make_slot_prefill(cfg: ModelConfig, max_seq: int, *,
                      moe_impl="capacity", unroll=False):
    """Prefill ONE request (batch=1 forward) and insert it into batch slot
    ``slot`` of a unified decode state. Donate the state when jitting.

    The returned callable is shape-specialized to the PADDED length of
    ``tokens`` — the engine right-pads prompts to power-of-two buckets
    and passes the real length as the traced ``true_len``, so retraces
    are O(log max_seq) instead of O(distinct prompt lengths). Padding
    rows beyond ``true_len`` are masked out of the logits, the decode
    ``pos``, and the local ring caches (``forward_fullseq`` valid_len).
    """
    def slot_prefill(params, tokens, true_len, state, slot):
        mini = tfm.init_decode_state(cfg, 1, max_seq)
        logits, mini, _ = tfm.forward_fullseq(
            params, cfg, tokens, state=mini, logits_slice="last",
            moe_impl=moe_impl, unroll=unroll, valid_len=true_len)
        state = chai_cache.insert_slot(state, mini, slot)
        return logits[:, 0], state

    return slot_prefill


def make_slot_cluster(cfg: ModelConfig, identify_fn):
    """CLUSTER transition for one slot: identify membership from the
    slot's accumulated warmup scores (via ``identify_fn``, the engine's
    batched identification hook), scatter it into the batched ctx, and
    compact the slot's dense K rows into the clustered cache."""
    def cluster_slot(state, ctx, slot):
        # Batch-of-1 through the batched hook: K-Means runs only for this
        # slot, and monkeypatched hooks (CHAI-static, tests) still apply.
        from repro.core import clustering
        scores = jax.lax.dynamic_slice_in_dim(state["chai_scores"], slot, 1,
                                              axis=1)[:, 0]
        slot_ctx = clustering.identify_membership_slot(scores, cfg,
                                                       identify_fn)
        ctx = clustering.update_ctx_slot(ctx, slot_ctx, slot)
        state = chai_cache.compact_kv_slot(state, slot_ctx, cfg, slot)
        return state, ctx

    return cluster_slot


def make_slot_reset(cfg: ModelConfig):
    def reset(state, slot):
        return chai_cache.reset_slot(state, slot)
    return reset


# ---------------------------------------------------------------------------
# Paged KV layout (continuous batching over a block-table page pool)
# ---------------------------------------------------------------------------

def make_paged_slot_prefill(cfg: ModelConfig, max_seq: int, *,
                            moe_impl="capacity", unroll=False):
    """Paged ``make_slot_prefill``: the batch=1 forward fills a dense mini
    state, which is then scattered into the slot's freshly allocated
    pages (``kg_pages``/``vg_pages``: (P,) int32, null-padded). Donate
    the state when jitting; shape-specialized per power-of-two prompt
    BUCKET (padding rows beyond ``true_len`` land either inside the
    slot's own pages — masked by ``pos`` — or in the null sink page)."""
    def slot_prefill(params, tokens, true_len, state, slot, kg_pages,
                     vg_pages):
        mini = tfm.init_decode_state(cfg, 1, max_seq)
        logits, mini, _ = tfm.forward_fullseq(
            params, cfg, tokens, state=mini, logits_slice="last",
            moe_impl=moe_impl, unroll=unroll, valid_len=true_len)
        state = chai_cache.insert_slot_paged(state, mini, slot, kg_pages,
                                             vg_pages)
        return logits[:, 0], state

    return slot_prefill


def _paged_prefix_kv(state, bt_kg_row, bt_vg_row):
    """Paged prefix_kv dict for a suffix/chunk prefill: the pool and
    block tables go to the kernel as-is — the paged prefix pass streams
    only the real pages through scalar-prefetched tables instead of
    gathering the whole slot-capacity view per layer."""
    return {"pool": state["kvp"],
            "scale": state.get("kvp_scale"),
            "bt_k": bt_kg_row[None],             # (1, P)
            "bt_v": bt_vg_row[None]}


def make_paged_suffix_prefill(cfg: ModelConfig, max_seq: int, *,
                              moe_impl="capacity", unroll=False):
    """Cached-aware prefill: forward ONLY the uncached suffix of a
    prompt whose first ``prefix_len`` tokens (a whole number of pages)
    already live in shared pages aliased into the slot's block tables.

    ``tokens`` (1, Tb) is the right-padded suffix bucket; ``true_len``
    its real length; ``bt_kg_row``/``bt_vg_row`` the FULL logical page
    mapping (aliased prefix + fresh suffix pages); ``kg_scatter``/
    ``vg_scatter`` the same rows with the aliased entries nulled so the
    mini state's scatter cannot touch shared pages (copy-on-write: the
    suffix writes only into the slot's own pages). Suffix queries take a
    paged non-causal pass over the cached prefix pages plus a causal
    flash pass over the suffix, merged by online-softmax state; shape-
    specialized per suffix bucket only. Donate the state when jitting."""
    def suffix_prefill(params, tokens, true_len, prefix_len, state, slot,
                       kg_scatter, vg_scatter, bt_kg_row, bt_vg_row):
        prefix_kv = _paged_prefix_kv(state, bt_kg_row, bt_vg_row)
        mini = tfm.init_decode_state(cfg, 1, max_seq)
        logits, mini, _ = tfm.forward_fullseq(
            params, cfg, tokens, state=mini, logits_slice="last",
            moe_impl=moe_impl, unroll=unroll, valid_len=true_len,
            prefix_len=prefix_len, prefix_kv=prefix_kv)
        state = chai_cache.insert_slot_paged(
            state, mini, slot, kg_scatter, vg_scatter,
            bt_kg_row=bt_kg_row, bt_vg_row=bt_vg_row)
        return logits[:, 0], state

    return suffix_prefill


def make_paged_chunk_prefill(cfg: ModelConfig, max_seq: int, *,
                             moe_impl="capacity", unroll=False):
    """Chunked (Sarathi-style) prefill: forward ONE page-aligned chunk of
    a long prompt, treating everything the slot has already prefilled —
    radix-aliased prefix pages AND earlier chunks — as the cached prefix
    of a suffix prefill. ``prefix_len`` is the chunk's start position;
    ``kg_scatter``/``vg_scatter`` null every page outside the chunk's
    range, so the mini state touches only the pages this chunk fills.

    ``phase`` distinguishes the final chunk (``PHASE_WARMUP``: the slot
    joins the decode batch next step) from intermediate ones
    (``PHASE_FREE``: the interleaved batched decode treats the slot as
    empty — its stray write at ``pos`` lands in a page the NEXT chunk's
    whole-page scatter overwrites, and ``insert_slot_paged`` re-anchors
    ``pos`` and zeroes the clustering features every chunk). Donate the
    state when jitting; shape-specialized per chunk bucket."""
    def chunk_prefill(params, tokens, true_len, prefix_len, state, slot,
                      kg_scatter, vg_scatter, bt_kg_row, bt_vg_row, phase):
        prefix_kv = _paged_prefix_kv(state, bt_kg_row, bt_vg_row)
        mini = tfm.init_decode_state(cfg, 1, max_seq)
        logits, mini, _ = tfm.forward_fullseq(
            params, cfg, tokens, state=mini, logits_slice="last",
            moe_impl=moe_impl, unroll=unroll, valid_len=true_len,
            prefix_len=prefix_len, prefix_kv=prefix_kv)
        state = chai_cache.insert_slot_paged(
            state, mini, slot, kg_scatter, vg_scatter,
            bt_kg_row=bt_kg_row, bt_vg_row=bt_vg_row)
        state["phase"] = state["phase"].at[slot].set(phase)
        return logits[:, 0], state

    return chunk_prefill


def make_slot_swap(cfg: ModelConfig):
    """Preemption KV swap (out, in): a preempted slot's per-slot state
    and page CONTENTS move to the host so its physical pages can be
    reclaimed, and move back verbatim into fresh pages at resume.
    Resume-by-recompute cannot be output-identical here: CHAI decode is
    an approximation of full attention, so a re-prefill would produce
    different K/V rows for the generated tokens than the original decode
    wrote (and re-running identify could change membership outright).
    Swapping the actual rows makes resume bitwise."""
    def swap_out(state, slot, kg_pages, vg_pages, kc_pages, vc_pages):
        return chai_cache.save_slot_paged(state, slot, kg_pages, vg_pages,
                                          kc_pages, vc_pages)

    def swap_in(state, slot, cols, pools, kg_pages, vg_pages, kc_pages,
                vc_pages, bt_kg_row, bt_vg_row, bt_kc_row, bt_vc_row):
        return chai_cache.load_slot_paged(
            state, slot, cols, pools, kg_pages, vg_pages, kc_pages,
            vc_pages, bt_kg_row, bt_vg_row, bt_kc_row, bt_vc_row)

    return swap_out, swap_in


def make_snapshot_restore(cfg: ModelConfig):
    """CHAI snapshot resume: alias the snapshot's clustered + dense-V
    pages into the slot's block tables and enter STEADY directly."""
    def restore(state, slot, bt_kg_row, bt_vg_row, bt_kc_row, bt_vc_row,
                pos):
        return chai_cache.restore_slot_snapshot(
            state, slot, bt_kg_row, bt_vg_row, bt_kc_row, bt_vc_row, pos)

    return restore


def make_page_copy(cfg: ModelConfig, kind: str):
    """Copy-on-write page copy inside one pool (``kind``: dense|chai)."""
    def copy(state, src, dst):
        return chai_cache.copy_pool_page(state, src, dst, kind=kind)

    return copy


def make_page_fetch(cfg: ModelConfig, kind: str):
    """Gather ONE physical page's contents (all global layers) out of a
    pool — the host-offload demotion read (``kind``: dense|chai).
    Returns a payload dict ``{"data": (nG, rows, ps, hd)}`` plus a
    ``"scale"`` plane under int8 KV. One trace per kind: the page id is
    a traced scalar."""
    key, skey = (("kvp", "kvp_scale") if kind == "dense"
                 else ("cp", "cp_scale"))

    def fetch(state, page):
        out = {"data": jax.lax.dynamic_index_in_dim(state[key], page, 1,
                                                    keepdims=False)}
        if skey in state:
            out["scale"] = jax.lax.dynamic_index_in_dim(
                state[skey], page, 1, keepdims=False)
        return out

    return fetch


def make_page_put(cfg: ModelConfig, kind: str):
    """Scatter a host payload back into ONE physical page — the tier
    promotion write, the exact inverse of ``make_page_fetch``. Donate
    ``state`` when jitting."""
    key, skey = (("kvp", "kvp_scale") if kind == "dense"
                 else ("cp", "cp_scale"))

    def put(state, page, payload):
        state = dict(state)
        state[key] = jax.lax.dynamic_update_index_in_dim(
            state[key], payload["data"].astype(state[key].dtype), page, 1)
        if skey in state:
            state[skey] = jax.lax.dynamic_update_index_in_dim(
                state[skey], payload["scale"].astype(state[skey].dtype),
                page, 1)
        return state

    return put


def make_paged_slot_cluster(cfg: ModelConfig, identify_fn):
    """Paged CLUSTER transition: identify membership, scatter it into the
    batched ctx, gather the slot's representative K rows from its dense
    pages into the clustered pages, and null the dense block-table row —
    the engine frees those dense pages host-side right after this jit."""
    def cluster_slot(state, ctx, slot, kc_pages, vc_pages):
        from repro.core import clustering
        scores = jax.lax.dynamic_slice_in_dim(state["chai_scores"], slot, 1,
                                              axis=1)[:, 0]
        slot_ctx = clustering.identify_membership_slot(scores, cfg,
                                                       identify_fn)
        ctx = clustering.update_ctx_slot(ctx, slot_ctx, slot)
        state = chai_cache.compact_kv_slot_paged(state, slot_ctx, cfg, slot,
                                                 kc_pages, vc_pages)
        return state, ctx

    return cluster_slot


def make_paged_slot_reset(cfg: ModelConfig):
    def reset(state, slot):
        return chai_cache.reset_slot_paged(state, slot)
    return reset


def jaxpr_text(fn, *example_args):
    """Canonical jaxpr text of ``fn`` traced at ``example_args``.

    Introspection only (telemetry overhead claims, kernel-coverage
    tests): proves two callables lower to the same computation without
    executing either. ``fn`` may be a ``jax.jit`` wrapper — tracing goes
    through it; compare jit-wrapped against jit-wrapped (the pjit
    equation wraps the inner jaxpr either way). Memory addresses of
    embedded thunks (``custom_jvp`` prints ``<function ... at 0x...>``)
    are scrubbed so two independently built but identical programs
    compare equal.
    """
    import re
    txt = str(jax.make_jaxpr(fn)(*example_args))
    return re.sub(r"<(function|bound method) .+? at 0x[0-9a-f]+>",
                  r"<\1>", txt)
