"""End-to-end driver: batched serving with the CHAI engine.

Trains a small model on the synthetic corpus (so generations are
meaningful), then serves a queue of requests through the full CHAI phase
machine, comparing CHAI vs plain MHA on latency, tokens/s, KV bytes, and
greedy-token agreement.

  PYTHONPATH=src python examples/serve_batched.py
"""
import time

import numpy as np

from repro.configs.base import get_config, reduced
from repro.data.pipeline import DataConfig
from repro.serving.engine import EngineConfig, ServingEngine
from repro.train.trainer import Trainer, TrainerConfig


def serve(cfg, params, pipe, *, use_chai, n_req=8, max_new=24):
    eng = ServingEngine(cfg, params,
                        EngineConfig(batch_slots=4, max_seq=128,
                                     use_chai=use_chai))
    for i in range(n_req):
        eng.submit(pipe.batch(2000 + i)["tokens"][0, :32],
                   max_new_tokens=max_new, uid=i)
    t0 = time.time()
    done = eng.run()
    wall = time.time() - t0
    n_tok = sum(len(r.generated) for r in done)
    return {
        "gen": {r.uid: r.generated for r in done},
        "wall_s": wall, "tok_per_s": n_tok / wall,
        "ttft_ms": 1e3 * float(np.mean([r.ttft for r in done])),
        "kv_bytes": int(eng.kv_bytes()),
    }


def main():
    cfg = reduced(get_config("chai-llama-7b"), n_layers=2, d_model=64,
                  n_heads=8, d_ff=128, vocab=256).replace(dtype="float32")
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)
    print("training a small LM on the synthetic corpus ...")
    tr = Trainer(cfg, data, TrainerConfig(
        total_steps=80, ckpt_every=10**9, log_every=40,
        ckpt_dir="/tmp/serve_batched_ckpt",
        lr_kw=dict(peak=3e-3, warmup=8, total=80)))
    state, metrics = tr.run()
    params = state["params"]

    cfg_chai = cfg.with_chai(enabled=True,
                             cluster_counts=(5,) * cfg.n_attn_layers)
    print("\nserving with plain MHA ...")
    mha = serve(cfg, params, tr.pipe, use_chai=False)
    print("serving with CHAI ...")
    chai = serve(cfg_chai, params, tr.pipe, use_chai=True)

    agree = np.mean([np.mean(np.asarray(mha["gen"][u]) ==
                             np.asarray(chai["gen"][u]))
                     for u in mha["gen"]])
    print(f"\n{'':14}{'MHA':>12}{'CHAI':>12}")
    for key in ("wall_s", "tok_per_s", "ttft_ms", "kv_bytes"):
        print(f"{key:14}{mha[key]:>12.2f}{chai[key]:>12.2f}")
    print(f"\ngreedy-token agreement CHAI vs MHA: {agree:.1%}")
    print(f"KV saving: {1 - chai['kv_bytes'] / mha['kv_bytes']:.1%}")


if __name__ == "__main__":
    main()
