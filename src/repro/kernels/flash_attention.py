"""Pallas TPU flash attention: decode (one query token), paged decode, and
prefill.

Decode: grid (B, H, S/Ts), online-softmax carried in VMEM scratch across the
sequentially-iterated S-tile axis; K/V stream HBM->VMEM via BlockSpecs; the
GQA group map (h -> h // q_per_kv) is a static index_map. Valid-length
masking uses a scalar-prefetched per-example ``pos`` vector.

Paged decode: same online softmax, but K/V live in a shared page pool
(nP, KV, page, hd) and each slot's pages are located through
scalar-prefetched int32 block tables — the tables drive the K/V BlockSpec
index_maps, so the pool pages stream HBM->VMEM exactly like dense tiles
(the paged-attention idiom; one S-tile == one page).

Prefill: grid (B, H, Tq/Tb, S/Ts) with causal block skipping.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _interpret_default():
    return jax.default_backend() == "cpu"


# ------------------------------------------------------------------ decode
def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, scale, window, ts, n_tiles):
    b = pl.program_id(0)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0, :].astype(jnp.float32)[None, :]          # (1, hd)
    k = k_ref[0, 0].astype(jnp.float32)                      # (Ts, hd)
    sc = jnp.dot(k, q.T, preferred_element_type=jnp.float32) * scale
    idx = s * ts + jax.lax.broadcasted_iota(jnp.int32, (ts, 1), 0)
    pos = pos_ref[b]
    valid = idx <= pos
    if window:
        valid &= (pos - idx) < window
    sc = jnp.where(valid, sc, NEG_INF)                       # (Ts, 1)

    m_prev = m_scr[0, 0]
    m_new = jnp.maximum(jnp.maximum(m_prev, jnp.max(sc)), -1e30)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(sc - m_new)                                  # (Ts, 1)
    l_new = l_scr[0, 0] * alpha + jnp.sum(p)
    v = v_ref[0, 0].astype(jnp.float32)                      # (Ts, hd)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p.T, v, preferred_element_type=jnp.float32)          # (1, hd)
    m_scr[0, 0] = m_new
    l_scr[0, 0] = l_new

    @pl.when(s == n_tiles - 1)
    def _fin():
        o_ref[0, 0, :] = (acc_scr[0, :]
                          / jnp.maximum(l_scr[0, 0], 1e-37)).astype(
                              o_ref.dtype)


def flash_decode(q, k_cache, v_cache, pos, *, window=0, ts=512,
                 interpret=None):
    """q: (B, H, hd); k/v_cache: (B, KV, S, hd); pos: (B,) int32.
    Returns (B, H, hd) fp32."""
    if interpret is None:
        interpret = _interpret_default()
    b, h, hd = q.shape
    n_kv, s = k_cache.shape[1], k_cache.shape[2]
    qpk = h // n_kv
    ts = min(ts, s)
    assert s % ts == 0, (s, ts)
    n_tiles = s // ts
    scale = 1.0 / math.sqrt(hd)

    grid = (b, h, n_tiles)
    kernel = functools.partial(_decode_kernel, scale=scale, window=window,
                               ts=ts, n_tiles=n_tiles)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, hd), lambda bb, hh, ss, pos_r:
                             (bb, hh, 0)),
                pl.BlockSpec((1, 1, ts, hd), lambda bb, hh, ss, pos_r:
                             (bb, hh // qpk, ss, 0)),
                pl.BlockSpec((1, 1, ts, hd), lambda bb, hh, ss, pos_r:
                             (bb, hh // qpk, ss, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, hd), lambda bb, hh, ss, pos_r:
                                   (bb, hh, 0)),
            scratch_shapes=[
                pltpu.VMEM((1, 1), jnp.float32),
                pltpu.VMEM((1, 1), jnp.float32),
                pltpu.VMEM((1, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, hd), jnp.float32),
        interpret=interpret,
    )(pos.astype(jnp.int32), q, k_cache, v_cache)


# ------------------------------------------------------------ paged decode
def _paged_decode_kernel(pos_ref, btk_ref, btv_ref, q_ref, k_ref, v_ref,
                         o_ref, m_scr, l_scr, acc_scr, *, scale, window,
                         page, n_pages):
    b = pl.program_id(0)
    s = pl.program_id(2)               # logical page index

    @pl.when(s == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0, :].astype(jnp.float32)[None, :]          # (1, hd)
    k = k_ref[0, 0].astype(jnp.float32)                      # (page, hd)
    sc = jnp.dot(k, q.T, preferred_element_type=jnp.float32) * scale
    idx = s * page + jax.lax.broadcasted_iota(jnp.int32, (page, 1), 0)
    pos = pos_ref[b]
    valid = idx <= pos
    if window:
        valid &= (pos - idx) < window
    sc = jnp.where(valid, sc, NEG_INF)                       # (page, 1)

    m_prev = m_scr[0, 0]
    m_new = jnp.maximum(jnp.maximum(m_prev, jnp.max(sc)), -1e30)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(sc - m_new)
    l_new = l_scr[0, 0] * alpha + jnp.sum(p)
    v = v_ref[0, 0].astype(jnp.float32)                      # (page, hd)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p.T, v, preferred_element_type=jnp.float32)          # (1, hd)
    m_scr[0, 0] = m_new
    l_scr[0, 0] = l_new

    @pl.when(s == n_pages - 1)
    def _fin():
        o_ref[0, 0, :] = (acc_scr[0, :]
                          / jnp.maximum(l_scr[0, 0], 1e-37)).astype(
                              o_ref.dtype)


def paged_decode(q, kv_pool, bt_k, bt_v, pos, *, window=0, interpret=None):
    """Paged flash decode. q: (B, H, hd); kv_pool: (nP, KV, page, hd)
    shared K/V page pool; bt_k/bt_v: (B, P) int32 block tables (a slot's
    logical page j lives in physical page bt[b, j]; null entries point at
    the reserved page 0 and are masked by ``pos``); pos: (B,) int32.
    Logical sequence length is P * page. Returns (B, H, hd) fp32."""
    if interpret is None:
        interpret = _interpret_default()
    b, h, hd = q.shape
    n_kv, page = kv_pool.shape[1], kv_pool.shape[2]
    n_pages = bt_k.shape[1]
    assert bt_v.shape == bt_k.shape == (b, n_pages)
    qpk = h // n_kv
    scale = 1.0 / math.sqrt(hd)

    grid = (b, h, n_pages)
    kernel = functools.partial(_paged_decode_kernel, scale=scale,
                               window=window, page=page, n_pages=n_pages)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, hd),
                             lambda bb, hh, ss, pos_r, btk_r, btv_r:
                             (bb, hh, 0)),
                pl.BlockSpec((1, 1, page, hd),
                             lambda bb, hh, ss, pos_r, btk_r, btv_r:
                             (btk_r[bb, ss], hh // qpk, 0, 0)),
                pl.BlockSpec((1, 1, page, hd),
                             lambda bb, hh, ss, pos_r, btk_r, btv_r:
                             (btv_r[bb, ss], hh // qpk, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, hd),
                                   lambda bb, hh, ss, pos_r, btk_r, btv_r:
                                   (bb, hh, 0)),
            scratch_shapes=[
                pltpu.VMEM((1, 1), jnp.float32),
                pltpu.VMEM((1, 1), jnp.float32),
                pltpu.VMEM((1, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, hd), jnp.float32),
        interpret=interpret,
    )(pos.astype(jnp.int32), bt_k.astype(jnp.int32), bt_v.astype(jnp.int32),
      q, kv_pool, kv_pool)


# ------------------------------------------------------------------ prefill
def _prefill_kernel(off_ref, q_ref, k_ref, v_ref, out_refs, m_scr, l_scr,
                    acc_scr, *, scale, window, tq, ts, n_tiles,
                    softcap=0.0, emit_state=False):
    i = pl.program_id(2)           # q tile
    j = pl.program_id(3)           # kv tile

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Query offset arrives as a scalar-prefetched value so it may be
    # TRACED — the prefix cache's suffix prefill runs one jit per suffix
    # bucket with the cached-prefix length varying per request.
    q_start = off_ref[0] + i * tq
    kv_start = j * ts
    # causal block skip: this kv tile intersects the causal triangle iff
    # kv_start <= q_end; window skip iff kv_end > q_start - window
    q_end = q_start + tq - 1
    relevant = kv_start <= q_end
    if window:
        relevant &= (kv_start + ts - 1) > (q_start - window)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                  # (Tq, hd)
        k = k_ref[0, 0].astype(jnp.float32)                  # (Ts, hd)
        sc = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if softcap:
            # tanh logit softcap (gemma2): after QK-scale, before the
            # causal mask — the jnp oracle's exact insertion point.
            sc = softcap * jnp.tanh(sc / softcap)
        qi = q_start + jax.lax.broadcasted_iota(jnp.int32, (tq, ts), 0)
        ki = kv_start + jax.lax.broadcasted_iota(jnp.int32, (tq, ts), 1)
        valid = ki <= qi
        if window:
            valid &= (qi - ki) < window
        sc = jnp.where(valid, sc, NEG_INF)
        m_prev = m_scr[:, 0]
        m_new = jnp.maximum(jnp.maximum(m_prev, jnp.max(sc, -1)), -1e30)
        alpha = jnp.exp(m_prev - m_new)                      # (Tq,)
        p = jnp.exp(sc - m_new[:, None])                     # (Tq, Ts)
        l_new = l_scr[:, 0] * alpha + jnp.sum(p, -1)
        v = v_ref[0, 0].astype(jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[:, 0] = m_new
        l_scr[:, 0] = l_new

    @pl.when(j == n_tiles - 1)
    def _fin():
        if emit_state:
            m_ref, l_ref, acc_ref = out_refs
            m_ref[0, 0] = m_scr[:, 0]
            l_ref[0, 0] = l_scr[:, 0]
            acc_ref[0, 0] = acc_scr[...]
        else:
            (o_ref,) = out_refs
            o_ref[0, 0] = (acc_scr[...]
                           / jnp.maximum(l_scr[:, 0],
                                         1e-37)[:, None]).astype(
                               o_ref.dtype)


def flash_prefill(q, k, v, *, offset=0, window=0, tq=256, ts=512,
                  softcap=0.0, emit_state=False, interpret=None):
    """q: (B, T, H, hd); k/v: (B, S, KV, hd) (time-major KV, as projected).
    Causal: query t at absolute position offset+t. ``offset`` may be a
    python int OR a traced int32 scalar (it rides in via scalar prefetch)
    — the prefix-cache suffix prefill attends new tokens over cached
    prefix KV with a per-request offset under one jit per suffix bucket.
    Returns (B, T, H, hd).

    ``emit_state``: return the raw head-major online-softmax triple
    (m (B, H, T), l (B, H, T), acc (B, H, T, hd)) f32 instead of the
    finalized output — the paged suffix prefill merges this causal
    self-attention pass with a ``paged_prefix_attend`` pass over the
    cached prefix pages via ``ops.merge_prefill_states``."""
    if interpret is None:
        interpret = _interpret_default()
    b, t, h, hd = q.shape
    s, n_kv = k.shape[1], k.shape[2]
    qpk = h // n_kv
    tq = min(tq, t)
    ts = min(ts, s)
    assert t % tq == 0 and s % ts == 0, (t, tq, s, ts)
    n_tiles = s // ts
    scale = 1.0 / math.sqrt(hd)
    # kernels want head-major layouts: (B, H, T, hd)
    qh = q.transpose(0, 2, 1, 3)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    off = jnp.asarray(offset, jnp.int32).reshape((1,))

    grid = (b, h, t // tq, n_tiles)
    base = functools.partial(_prefill_kernel, scale=scale, window=window,
                             tq=tq, ts=ts, n_tiles=n_tiles,
                             softcap=softcap, emit_state=emit_state)
    n_out = 3 if emit_state else 1

    def kernel(off_ref, q_ref, k_ref, v_ref, *rest):
        base(off_ref, q_ref, k_ref, v_ref, tuple(rest[:n_out]),
             *rest[n_out:])

    if emit_state:
        out_specs = [
            pl.BlockSpec((1, 1, tq), lambda bb, hh, ii, jj, off_r:
                         (bb, hh, ii)),
            pl.BlockSpec((1, 1, tq), lambda bb, hh, ii, jj, off_r:
                         (bb, hh, ii)),
            pl.BlockSpec((1, 1, tq, hd), lambda bb, hh, ii, jj, off_r:
                         (bb, hh, ii, 0)),
        ]
        out_shape = [
            jax.ShapeDtypeStruct((b, h, t), jnp.float32),
            jax.ShapeDtypeStruct((b, h, t), jnp.float32),
            jax.ShapeDtypeStruct((b, h, t, hd), jnp.float32),
        ]
    else:
        out_specs = pl.BlockSpec((1, 1, tq, hd),
                                 lambda bb, hh, ii, jj, off_r:
                                 (bb, hh, ii, 0))
        out_shape = jax.ShapeDtypeStruct((b, h, t, hd), q.dtype)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, tq, hd), lambda bb, hh, ii, jj, off_r:
                             (bb, hh, ii, 0)),
                pl.BlockSpec((1, 1, ts, hd), lambda bb, hh, ii, jj, off_r:
                             (bb, hh // qpk, jj, 0)),
                pl.BlockSpec((1, 1, ts, hd), lambda bb, hh, ii, jj, off_r:
                             (bb, hh // qpk, jj, 0)),
            ],
            out_specs=out_specs,
            scratch_shapes=[
                pltpu.VMEM((tq, 1), jnp.float32),
                pltpu.VMEM((tq, 1), jnp.float32),
                pltpu.VMEM((tq, hd), jnp.float32),
            ],
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(off, qh, kh, vh)
    if emit_state:
        return out
    return out.transpose(0, 2, 1, 3)


# --------------------------------------------------- paged prefix attend
def _paged_prefix_kernel(plen_ref, btk_ref, btv_ref, q_ref, k_ref, ks_ref,
                         v_ref, vs_ref, m_ref, l_ref, acc_ref, m_scr,
                         l_scr, acc_scr, *, scale, page, n_pages,
                         softcap=0.0):
    """Suffix-prefill prefix pass: every suffix query attends every cached
    prefix position (< plen) — no causal constraint inside the prefix.
    Pages beyond the prefix are redirected to the null sink page by the
    index map and skipped here; emits the mergeable m/l/acc triple."""
    b = pl.program_id(0)
    j = pl.program_id(3)               # logical page index

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    plen = plen_ref[b]

    @pl.when(j * page < plen)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)              # (Tq, hd)
        k = k_ref[0, 0].astype(jnp.float32)              # (page, hd)
        sc = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        if ks_ref is not None:   # int8: per-(row, pos) K scales
            sc = sc * ks_ref[0, 0].astype(jnp.float32)[None, :]
        sc = sc * scale
        if softcap:
            sc = softcap * jnp.tanh(sc / softcap)
        tq = q.shape[0]
        ki = j * page + jax.lax.broadcasted_iota(jnp.int32, (tq, page), 1)
        sc = jnp.where(ki < plen, sc, NEG_INF)
        m_prev = m_scr[:, 0]
        m_new = jnp.maximum(jnp.maximum(m_prev, jnp.max(sc, -1)), -1e30)
        alpha = jnp.exp(m_prev - m_new)                  # (Tq,)
        p = jnp.exp(sc - m_new[:, None])                 # (Tq, page)
        l_new = l_scr[:, 0] * alpha + jnp.sum(p, -1)
        v = v_ref[0, 0].astype(jnp.float32)              # (page, hd)
        if vs_ref is not None:
            v = v * vs_ref[0, 0].astype(jnp.float32)[:, None]
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[:, 0] = m_new
        l_scr[:, 0] = l_new

    @pl.when(j == n_pages - 1)
    def _fin():
        m_ref[0, 0] = m_scr[:, 0]
        l_ref[0, 0] = l_scr[:, 0]
        acc_ref[0, 0] = acc_scr[...]


def paged_prefix_attend(q, kv_pool, bt_k, bt_v, plen, *, k_scale_pool=None,
                        v_scale_pool=None, softcap=0.0, tq=256,
                        interpret=None):
    """Attend suffix queries over cached prefix pages, streamed via
    scalar-prefetched block tables (no densifying slot-capacity gather).

    q: (B, T, H, hd) suffix queries; kv_pool: (nP, KV, page, hd) dense
    page pool; bt_k/bt_v: (B, P) int32 block tables; plen: (B,) int32
    cached-prefix token counts (entries past the prefix are redirected to
    the null sink page and masked). int8 pools pass the mirror-shaped
    scale pools. Returns the HEAD-MAJOR mergeable triple (m (B, H, T),
    l (B, H, T), acc (B, H, T, hd)) f32 — combine with the suffix
    ``flash_prefill(..., emit_state=True)`` pass via
    ``ops.merge_prefill_states``."""
    if interpret is None:
        interpret = _interpret_default()
    b, t, h, hd = q.shape
    n_kv, page = kv_pool.shape[1], kv_pool.shape[2]
    n_pages = bt_k.shape[1]
    assert bt_v.shape == bt_k.shape == (b, n_pages)
    qpk = h // n_kv
    tq = min(tq, t)
    assert t % tq == 0, (t, tq)
    scale = 1.0 / math.sqrt(hd)
    qh = q.transpose(0, 2, 1, 3)       # (B, H, T, hd)

    def _k_page(bb, ss, plen_r, btk_r, btv_r):
        # Null-sink redirect past the prefix: the fetch is cheap (page 0)
        # and the compute is skipped in-kernel.
        return jnp.where(ss * page < plen_r[bb], btk_r[bb, ss], 0)

    def _v_page(bb, ss, plen_r, btk_r, btv_r):
        return jnp.where(ss * page < plen_r[bb], btv_r[bb, ss], 0)

    in_specs = [
        pl.BlockSpec((1, 1, tq, hd),
                     lambda bb, hh, ii, jj, plen_r, btk_r, btv_r:
                     (bb, hh, ii, 0)),
        pl.BlockSpec((1, 1, page, hd),
                     lambda bb, hh, ii, jj, plen_r, btk_r, btv_r:
                     (_k_page(bb, jj, plen_r, btk_r, btv_r),
                      hh // qpk, 0, 0)),
    ]
    inputs = [qh, kv_pool]
    if k_scale_pool is not None:
        in_specs.append(pl.BlockSpec(
            (1, 1, page), lambda bb, hh, ii, jj, plen_r, btk_r, btv_r:
            (_k_page(bb, jj, plen_r, btk_r, btv_r), hh // qpk, 0)))
        inputs.append(k_scale_pool)
    in_specs.append(pl.BlockSpec(
        (1, 1, page, hd), lambda bb, hh, ii, jj, plen_r, btk_r, btv_r:
        (_v_page(bb, jj, plen_r, btk_r, btv_r), hh // qpk, 0, 0)))
    inputs.append(kv_pool)
    if v_scale_pool is not None:
        in_specs.append(pl.BlockSpec(
            (1, 1, page), lambda bb, hh, ii, jj, plen_r, btk_r, btv_r:
            (_v_page(bb, jj, plen_r, btk_r, btv_r), hh // qpk, 0)))
        inputs.append(v_scale_pool)

    has_ks = k_scale_pool is not None
    has_vs = v_scale_pool is not None

    def kernel(plen_ref, btk_ref, btv_ref, *refs):
        rest = list(refs)
        q_ref = rest.pop(0)
        k_ref = rest.pop(0)
        ks_ref = rest.pop(0) if has_ks else None
        v_ref = rest.pop(0)
        vs_ref = rest.pop(0) if has_vs else None
        m_ref, l_ref, acc_ref, m_scr, l_scr, acc_scr = rest
        _paged_prefix_kernel(plen_ref, btk_ref, btv_ref, q_ref, k_ref,
                             ks_ref, v_ref, vs_ref, m_ref, l_ref, acc_ref,
                             m_scr, l_scr, acc_scr, scale=scale, page=page,
                             n_pages=n_pages, softcap=softcap)

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(b, h, t // tq, n_pages),
            in_specs=in_specs,
            out_specs=[
                pl.BlockSpec((1, 1, tq),
                             lambda bb, hh, ii, jj, plen_r, btk_r, btv_r:
                             (bb, hh, ii)),
                pl.BlockSpec((1, 1, tq),
                             lambda bb, hh, ii, jj, plen_r, btk_r, btv_r:
                             (bb, hh, ii)),
                pl.BlockSpec((1, 1, tq, hd),
                             lambda bb, hh, ii, jj, plen_r, btk_r, btv_r:
                             (bb, hh, ii, 0)),
            ],
            scratch_shapes=[
                pltpu.VMEM((tq, 1), jnp.float32),
                pltpu.VMEM((tq, 1), jnp.float32),
                pltpu.VMEM((tq, hd), jnp.float32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((b, h, t), jnp.float32),
            jax.ShapeDtypeStruct((b, h, t), jnp.float32),
            jax.ShapeDtypeStruct((b, h, t, hd), jnp.float32),
        ],
        interpret=interpret,
    )(plen.astype(jnp.int32), bt_k.astype(jnp.int32),
      bt_v.astype(jnp.int32), *inputs)
