"""Atomic, mesh-reshardable checkpointing (fault tolerance substrate).

Design targets (DESIGN.md §8):
  * **Atomicity** — a step directory is written under ``<step>.tmp`` and
    renamed into place only after every tensor + the manifest are fsynced;
    a crash mid-save never corrupts the latest restorable step.
  * **Mesh-reshardable restore** — the manifest stores *logical* metadata
    (pytree paths, shapes, dtypes), never device layouts. Restore takes
    target shardings for whatever mesh exists at restart, so a 512-chip
    checkpoint restores onto 256 chips (elastic scaling) unchanged.
  * **Keep-N GC** + ``latest_step`` discovery for the restart loop.
  * **Multi-host**: only process 0 writes (single-controller container);
    on a real fleet, writes shard by ``jax.process_index()`` — the layout
    keeps one file per leaf so that change is local to ``save``.

Storage is one ``.npy`` per pytree leaf + a JSON manifest. No external
checkpoint libraries (offline container), but the same on-disk contract as
a Tensorstore-backed store: swap ``_write_leaf``/``_read_leaf`` to scale
I/O without touching callers.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Callable, Optional

import jax
import numpy as np


def _flatten_with_path(tree):
    try:
        return jax.tree.flatten_with_path(tree)
    except AttributeError:              # older jax: tree_util spelling
        return jax.tree_util.tree_flatten_with_path(tree)


def _leaf_paths(tree):
    """[(path-string, leaf)] with '/'-joined dict/tuple keys."""
    flat, treedef = _flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        keys = []
        for p in path:
            if hasattr(p, "key"):
                keys.append(str(p.key))
            elif hasattr(p, "idx"):
                keys.append(str(p.idx))
            elif hasattr(p, "name"):
                keys.append(str(p.name))
            else:
                keys.append(str(p))
        out.append(("/".join(keys) or "_root", leaf))
    return out, treedef


def _fname(leaf_path: str) -> str:
    return leaf_path.replace("/", "__") + ".bin"


def _np_dtype(name: str):
    """Resolve dtype strings incl. ml_dtypes (bfloat16, float8_*)."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # -- discovery ---------------------------------------------------------
    def all_steps(self):
        steps = []
        for name in os.listdir(self.directory):
            full = os.path.join(self.directory, name)
            if (name.isdigit() and os.path.isdir(full)
                    and os.path.exists(os.path.join(full, "manifest.json"))):
                steps.append(int(name))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # -- save ----------------------------------------------------------------
    def save(self, step: int, tree, *, extra: Optional[dict] = None):
        """Atomic save of ``tree`` at ``step``. ``extra``: JSON metadata
        (data-pipeline index, config digest, ...)."""
        final = os.path.join(self.directory, str(step))
        if os.path.exists(final):
            shutil.rmtree(final)
        tmp = tempfile.mkdtemp(prefix=f"{step}.tmp.", dir=self.directory)
        try:
            leaves, _ = _leaf_paths(tree)
            manifest = {"step": step, "extra": extra or {}, "leaves": {}}
            for path, leaf in leaves:
                arr = np.asarray(jax.device_get(leaf))
                fn = _fname(path)
                # raw bytes + manifest dtype: .npy chokes on ml_dtypes
                with open(os.path.join(tmp, fn), "wb") as f:
                    f.write(np.ascontiguousarray(arr).tobytes())
                    f.flush()
                    os.fsync(f.fileno())
                manifest["leaves"][path] = {
                    "file": fn, "shape": list(arr.shape),
                    "dtype": str(arr.dtype)}
            mpath = os.path.join(tmp, "manifest.json")
            with open(mpath, "w") as f:
                json.dump(manifest, f, indent=1)
                f.flush()
                os.fsync(f.fileno())
            os.rename(tmp, final)          # atomic publish
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, str(s)),
                          ignore_errors=True)
        # stale tmp dirs from crashed saves
        for name in os.listdir(self.directory):
            if ".tmp." in name:
                shutil.rmtree(os.path.join(self.directory, name),
                              ignore_errors=True)

    # -- restore -------------------------------------------------------------
    def restore(self, step: int, target_tree, *,
                shardings: Any = None, strict: bool = True):
        """Restore into the structure of ``target_tree`` (shapes validated).

        ``shardings``: optional pytree of NamedSharding for the *current*
        mesh — reshard-on-restore (elastic scaling). Leaves restore
        replicated when None.
        """
        d = os.path.join(self.directory, str(step))
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        leaves, treedef = _leaf_paths(target_tree)
        sh_leaves = (jax.tree.leaves(shardings)
                     if shardings is not None else [None] * len(leaves))
        assert len(sh_leaves) == len(leaves)
        out = []
        for (path, ref), sh in zip(leaves, sh_leaves):
            meta = manifest["leaves"].get(path)
            if meta is None:
                if strict:
                    raise KeyError(f"checkpoint {step} missing leaf {path}")
                out.append(ref)
                continue
            with open(os.path.join(d, meta["file"]), "rb") as f:
                arr = np.frombuffer(f.read(), dtype=_np_dtype(meta["dtype"]))
            arr = arr.reshape(meta["shape"])
            want = tuple(ref.shape) if hasattr(ref, "shape") else None
            if want is not None and tuple(arr.shape) != want:
                raise ValueError(
                    f"leaf {path}: checkpoint shape {arr.shape} != {want}")
            if hasattr(ref, "dtype") and arr.dtype != ref.dtype:
                arr = arr.astype(_np_dtype(str(ref.dtype)))
            out.append(jax.device_put(arr, sh) if sh is not None
                       else jax.numpy.asarray(arr))
        return jax.tree.unflatten(treedef, out), manifest["extra"]

    def restore_latest(self, target_tree, **kw):
        step = self.latest_step()
        if step is None:
            return None
        tree, extra = self.restore(step, target_tree, **kw)
        return step, tree, extra
