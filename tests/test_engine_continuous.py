"""Continuous-batching engine: scheduler parity + slot lifecycle.

(a) Token-for-token parity between ``scheduler="continuous"`` and
    ``scheduler="cohort"`` on greedy decode, across an MHA arch (clustered
    K cache) and a GQA arch (compute-only saving) — the per-slot phase
    machine must reproduce the lockstep cohort path exactly.
(b) A short request admitted beside a long one retires early and its slot
    is reused by a queued request while the long one is still running —
    the head-of-line-blocking fix the scheduler exists for.
"""
import numpy as np
import pytest

import jax

from repro.configs.base import get_config, reduced
from repro.core import cache as chai_cache
from repro.models import transformer as tfm
from repro.serving.engine import EngineConfig, ServingEngine

MHA_ARCH = "chai-llama-7b"      # n_heads == n_kv_heads
GQA_ARCH = "nemotron-4-15b"     # grouped KV heads


def _cfg(arch, **chai_kw):
    cfg = reduced(get_config(arch), n_layers=2, d_model=32, d_ff=64,
                  vocab=64).replace(dtype="float32")
    return cfg.with_chai(enabled=True, warmup_tokens=3, **chai_kw)


def _run(cfg, scheduler, submissions, *, use_chai=True, slots=2,
         max_seq=64):
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params,
                        EngineConfig(batch_slots=slots, max_seq=max_seq,
                                     scheduler=scheduler,
                                     use_chai=use_chai))
    for i, (prompt, max_new) in enumerate(submissions):
        eng.submit(prompt, max_new_tokens=max_new, uid=i)
    done = eng.run()
    assert len(done) == len(submissions)
    return {r.uid: r for r in done}, eng


def _submissions(cfg, n_req=5, prompt_len=8, seed=0):
    rng = np.random.default_rng(seed)
    lens = [12, 5, 9, 12, 7, 4, 11][:n_req]
    return [(rng.integers(0, cfg.vocab_size, size=prompt_len), m)
            for m in lens]


@pytest.mark.slow
@pytest.mark.parametrize("arch", [MHA_ARCH, GQA_ARCH])
def test_greedy_parity_continuous_vs_cohort(arch):
    """Identical greedy tokens per request under both schedulers, through
    all phases (warmup_tokens=3 < several max_new): PREFILL/WARMUP/
    CLUSTER/STEADY all exercised."""
    cfg = _cfg(arch)
    subs = _submissions(cfg)
    cont, eng = _run(cfg, "continuous", subs)
    coh, _ = _run(cfg, "cohort", subs)
    for uid in coh:
        assert cont[uid].generated == coh[uid].generated, uid
        assert len(cont[uid].generated) == subs[uid][1]
    # slot scheduling actually interleaved phases (not one-at-a-time)
    assert eng.steps_executed < sum(m for _, m in subs)


@pytest.mark.slow
def test_greedy_parity_without_chai():
    """use_chai=False: the continuous scheduler reduces to plain MHA
    continuous decode and still matches the cohort path."""
    cfg = _cfg(MHA_ARCH)
    subs = _submissions(cfg, n_req=4)
    cont, _ = _run(cfg, "continuous", subs, use_chai=False)
    coh, _ = _run(cfg, "cohort", subs, use_chai=False)
    for uid in coh:
        assert cont[uid].generated == coh[uid].generated, uid


def test_short_request_retires_early_and_slot_is_reused():
    cfg = _cfg(MHA_ARCH)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, size=8) for _ in range(3)]
    subs = [(prompts[0], 24),   # long: holds its slot for 24 tokens
            (prompts[1], 4),    # short: retires after 4
            (prompts[2], 4)]    # queued: must reuse the short one's slot
    done, eng = _run(cfg, "continuous", subs, slots=2)
    long_req, short_req, queued = done[0], done[1], done[2]
    assert short_req.retire_step < long_req.retire_step
    assert queued.slot == short_req.slot
    assert queued.admit_step >= short_req.retire_step
    # the queued request ran while the long one was still decoding —
    # no cohort barrier
    assert queued.admit_step < long_req.retire_step
    assert queued.retire_step < long_req.retire_step
    # per-request timing is recorded
    for r in done.values():
        assert r.ttft >= 0 and r.latency >= r.ttft > 0


def test_phase_vector_tracks_slot_lifecycle():
    """The unified state's per-slot phase vector drives the machine:
    zero-init state is all FREE; constants are ordered FREE < PREFILL <
    WARMUP < CLUSTER < STEADY (the mixed step's mask relies on it)."""
    assert (chai_cache.PHASE_FREE < chai_cache.PHASE_PREFILL
            < chai_cache.PHASE_WARMUP < chai_cache.PHASE_CLUSTER
            < chai_cache.PHASE_STEADY)
    cfg = _cfg(MHA_ARCH)
    state = chai_cache.init_unified_state(cfg, 2, 16)
    assert state["phase"].shape == (2,)
    assert (np.asarray(state["phase"]) == chai_cache.PHASE_FREE).all()
    # unified layout: dense and clustered K caches resident side by side
    assert "kg" in state and "kg_chai" in state and "chai_scores" in state


def test_prompt_bucket_rounding():
    from repro.serving.engine import ServingEngine
    assert ServingEngine._prompt_bucket(1, 64) == 1
    assert ServingEngine._prompt_bucket(3, 64) == 4
    assert ServingEngine._prompt_bucket(8, 64) == 8
    assert ServingEngine._prompt_bucket(9, 64) == 16
    assert ServingEngine._prompt_bucket(33, 64) == 64
    assert ServingEngine._prompt_bucket(60, 64) == 64   # capped at max_seq


@pytest.mark.slow
def test_prefill_jit_bucketing_compiles_per_bucket_not_per_length():
    """Regression: BOTH schedulers must key one prefill jit per
    power-of-two prompt BUCKET (tail masked), not per exact/padded
    length — and the padded prefill must not change a single greedy
    token. The cohort scheduler reuses the continuous scheduler's
    bucketing (right-pad + per-example ``true_lens``), so single-request
    cohorts are numerically exact references for the continuous path."""
    cfg = _cfg(MHA_ARCH)
    rng = np.random.default_rng(3)
    lengths = [3, 5, 6, 7, 9, 12]          # buckets: {4, 8, 8, 8, 16, 16}
    subs = [(rng.integers(0, cfg.vocab_size, size=t), 8) for t in lengths]
    cont, eng = _run(cfg, "continuous", subs)
    assert set(eng._slot_prefills) == {4, 8, 16}
    assert len(eng._slot_prefills) == 3    # O(log max_seq), not 6
    coh, eng_coh = _run(cfg, "cohort", subs, slots=1)
    for uid in coh:
        assert cont[uid].generated == coh[uid].generated, uid
    # cohort prefill no longer retraces per padded cohort length: one jit
    # whose shape cache is keyed by the pow2 bucket set (3 compiles, not
    # one per distinct prompt length)
    assert eng_coh._cohort_buckets == {4, 8, 16}
    assert eng_coh._prefill._cache_size() == 3


@pytest.mark.slow
def test_cohort_ragged_prefill_matches_single_cohorts():
    """Ragged cohorts (mixed prompt lengths admitted together) right-pad
    to one bucket with per-example masking — tokens must match the same
    requests run in single-request cohorts (no cross-contamination from
    padding)."""
    cfg = _cfg(MHA_ARCH)
    rng = np.random.default_rng(5)
    lengths = [5, 9, 12, 7]
    subs = [(rng.integers(0, cfg.vocab_size, size=t), 6) for t in lengths]
    ragged, eng = _run(cfg, "cohort", subs, slots=4)   # one ragged cohort
    assert eng._cohort_buckets == {16}                 # one bucket shape
    single, _ = _run(cfg, "cohort", subs, slots=1)
    for uid in single:
        assert ragged[uid].generated == single[uid].generated, uid


def test_cohort_redispatch_regenerates_cleanly():
    """Regression: a request re-dispatched after a blown cohort deadline
    restarts from its prompt — partial tokens from the aborted attempt
    are dropped, so the final output equals an uninterrupted run (the
    old behaviour appended the fresh decode onto the stale prefix)."""
    cfg = _cfg(MHA_ARCH)
    rng = np.random.default_rng(9)
    prompt = rng.integers(0, cfg.vocab_size, size=8)
    clean, _ = _run(cfg, "cohort", [(prompt, 8)], slots=1)

    import jax as _jax
    from repro.models import transformer as _tfm
    params = _tfm.init_params(cfg, _jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params,
                        EngineConfig(batch_slots=1, max_seq=64,
                                     scheduler="cohort",
                                     cohort_deadline_s=0.0))
    req = eng.submit(prompt, max_new_tokens=8, uid=0)
    try:        # deadline 0: times out mid-cohort, leaving partial tokens
        eng._run_cohort([req])
    except TimeoutError:
        pass
    assert req.generated                       # the stale partial prefix
    eng.ecfg.cohort_deadline_s = 300.0
    done = eng.run()
    assert len(done) == 1
    assert done[0].generated == clean[0].generated


@pytest.mark.slow
def test_mixed_workload_throughput_beats_cohort():
    """Mixed-length workload: continuous batching needs strictly fewer
    batched decode steps than the cohort scheduler (the step count is the
    hardware-independent throughput proxy; bench_latency measures wall
    time)."""
    cfg = _cfg(MHA_ARCH)
    rng = np.random.default_rng(2)
    subs = [(rng.integers(0, cfg.vocab_size, size=8), int(m))
            for m in rng.integers(4, 25, size=6)]
    _, eng_cont = _run(cfg, "continuous", subs, slots=2, max_seq=64)
    # cohort lower bound on decode steps: each cohort runs max(max_new)
    sizes = [m for _, m in subs]
    cohort_steps = sum(max(sizes[i:i + 2]) for i in range(0, len(sizes), 2))
    assert eng_cont.steps_executed < cohort_steps
