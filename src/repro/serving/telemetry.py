"""Engine-wide telemetry: metrics registry, step traces, request timelines.

Zero-dependency observability substrate for the serving engine, tiered by
``EngineConfig.telemetry``:

  off    null object; every hook is a no-op and the decode hot path is
         provably untouched (jaxpr-identical step — see
         benchmarks/bench_telemetry_overhead.py).
  basic  MetricsRegistry counters/gauges/histograms + per-request
         lifecycle timelines (enqueue → admit → phase transitions →
         first token → finish).  No spans.
  trace  everything in basic, plus structured spans for every
         ``EngineCore.step()`` stage, exportable as a Chrome trace.

The registry is snapshot-able (JSON-ready dict) and mergeable so a future
sharded EngineCore can aggregate per-shard registries into one scrape.
Export formats (Prometheus text, Chrome trace JSON, JSONL event logs)
live in ``serving/exporters.py``.

All instrumentation hooks that allocate or format are guarded engine-side
by ``tel.enabled`` / handed a shared null context manager, so the "off"
tier costs at most a handful of attribute reads per step.
"""
from __future__ import annotations

import collections
import math
import threading
import time
from typing import Any, Dict, Iterable, List, Optional, Tuple

TIERS = ("off", "basic", "trace")

# Default histogram buckets (seconds scale — covers sub-ms CPU decode
# steps through multi-second prefill/queue waits).  +Inf is implicit.
DEFAULT_BUCKETS = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
                   0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0)


def _label_key(labels: Optional[Dict[str, Any]]) -> Tuple[Tuple[str, str], ...]:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class MetricsRegistry:
    """Counters, gauges and bounded-bucket histograms with label sets.

    Names follow Prometheus conventions (``snake_case``, counters end in
    ``_total``, timings in ``_seconds``).  A (name, label-set) pair is one
    series.  ``snapshot()`` returns a plain JSON-ready dict; ``merge()``
    folds another snapshot in (counters and histogram buckets add;
    gauges add too, i.e. merged gauges read as cross-shard totals).
    """

    def __init__(self):
        self._lock = threading.Lock()
        # name -> {"help": str, "series": {lkey: float}}
        self._counters: Dict[str, Dict[str, Any]] = {}
        self._gauges: Dict[str, Dict[str, Any]] = {}
        # name -> {"help", "buckets": tuple, "series":
        #          {lkey: {"counts": [int]*(nb+1), "sum": f, "count": n}}}
        self._histograms: Dict[str, Dict[str, Any]] = {}

    # -- write side ------------------------------------------------------
    def counter(self, name: str, value: float = 1.0,
                labels: Optional[Dict[str, Any]] = None, help: str = ""):
        if value < 0:
            raise ValueError(f"counter {name} increment must be >= 0")
        key = _label_key(labels)
        with self._lock:
            m = self._counters.setdefault(name, {"help": help, "series": {}})
            m["series"][key] = m["series"].get(key, 0.0) + value

    def gauge(self, name: str, value: float,
              labels: Optional[Dict[str, Any]] = None, help: str = ""):
        key = _label_key(labels)
        with self._lock:
            m = self._gauges.setdefault(name, {"help": help, "series": {}})
            m["series"][key] = float(value)

    def observe(self, name: str, value: float,
                labels: Optional[Dict[str, Any]] = None,
                buckets: Optional[Tuple[float, ...]] = None, help: str = ""):
        key = _label_key(labels)
        with self._lock:
            m = self._histograms.get(name)
            if m is None:
                bks = tuple(buckets) if buckets else DEFAULT_BUCKETS
                if list(bks) != sorted(bks):
                    raise ValueError(f"histogram {name} buckets not sorted")
                m = self._histograms[name] = {
                    "help": help, "buckets": bks, "series": {}}
            s = m["series"].get(key)
            if s is None:
                s = m["series"][key] = {
                    "counts": [0] * (len(m["buckets"]) + 1),
                    "sum": 0.0, "count": 0}
            v = float(value)
            if math.isnan(v):
                return
            # First bucket whose upper bound >= v; last slot is +Inf.
            idx = len(m["buckets"])
            for i, ub in enumerate(m["buckets"]):
                if v <= ub:
                    idx = i
                    break
            s["counts"][idx] += 1
            s["sum"] += v
            s["count"] += 1

    # -- read side -------------------------------------------------------
    @staticmethod
    def _series_list(series, render):
        return [{"labels": dict(k), **render(v)} for k, v in
                sorted(series.items())]

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "counters": {
                    n: {"help": m["help"],
                        "series": self._series_list(
                            m["series"], lambda v: {"value": v})}
                    for n, m in sorted(self._counters.items())},
                "gauges": {
                    n: {"help": m["help"],
                        "series": self._series_list(
                            m["series"], lambda v: {"value": v})}
                    for n, m in sorted(self._gauges.items())},
                "histograms": {
                    n: {"help": m["help"], "buckets": list(m["buckets"]),
                        "series": self._series_list(
                            m["series"],
                            lambda s: {"counts": list(s["counts"]),
                                       "sum": s["sum"],
                                       "count": s["count"]})}
                    for n, m in sorted(self._histograms.items())},
            }

    def merge(self, snap: Dict[str, Any]) -> None:
        """Fold another registry's ``snapshot()`` into this one."""
        for name, m in snap.get("counters", {}).items():
            for s in m["series"]:
                self.counter(name, s["value"], labels=s["labels"],
                             help=m.get("help", ""))
        for name, m in snap.get("gauges", {}).items():
            for s in m["series"]:
                key = _label_key(s["labels"])
                with self._lock:
                    g = self._gauges.setdefault(
                        name, {"help": m.get("help", ""), "series": {}})
                    g["series"][key] = g["series"].get(key, 0.0) + s["value"]
        for name, m in snap.get("histograms", {}).items():
            bks = tuple(m["buckets"])
            with self._lock:
                h = self._histograms.setdefault(
                    name, {"help": m.get("help", ""), "buckets": bks,
                           "series": {}})
                if tuple(h["buckets"]) != bks:
                    raise ValueError(
                        f"histogram {name}: bucket mismatch on merge")
                for s in m["series"]:
                    key = _label_key(s["labels"])
                    t = h["series"].get(key)
                    if t is None:
                        t = h["series"][key] = {
                            "counts": [0] * (len(bks) + 1),
                            "sum": 0.0, "count": 0}
                    for i, c in enumerate(s["counts"]):
                        t["counts"][i] += c
                    t["sum"] += s["sum"]
                    t["count"] += s["count"]


class _Span:
    """Context manager recording one closed span into the telemetry sink."""

    __slots__ = ("_tel", "name", "step", "args", "t0")

    def __init__(self, tel, name, step, args):
        self._tel, self.name, self.step, self.args = tel, name, step, args
        self.t0 = 0.0

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        t1 = time.perf_counter()
        self._tel._add_span(self.name, self.step, self.t0, t1,
                            self.args, error=exc_type is not None)
        return False


class _NullCM:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_CM = _NullCM()


def summarize_timeline(events: List[Dict[str, Any]]) -> Dict[str, Any]:
    """Derive TTFT / queue time / ITL / counts from a raw event list."""
    first = {}
    for ev in events:
        first.setdefault(ev["ev"], ev["t"])
    t_enq = first.get("enqueue")
    t_admit = first.get("admit")
    t_first = first.get("first_token")
    t_fin = first.get("finish")
    tok_ts = [ev["t"] for ev in events if ev["ev"] == "tokens"]
    itl = [b - a for a, b in zip(tok_ts, tok_ts[1:])]
    out: Dict[str, Any] = {
        "n_events": len(events),
        "n_tokens": sum(int(ev.get("n", 1)) for ev in events
                        if ev["ev"] == "tokens"),
        "preemptions": sum(1 for ev in events if ev["ev"] == "preempt"),
        "phases": [ev["phase"] for ev in events if ev["ev"] == "phase"],
        "itl_s": itl,
    }
    if t_enq is not None and t_admit is not None:
        out["queue_s"] = t_admit - t_enq
    if t_enq is not None and t_first is not None:
        out["ttft_s"] = t_first - t_enq
    if t_enq is not None and t_fin is not None:
        out["latency_s"] = t_fin - t_enq
    fin = [ev for ev in events if ev["ev"] == "finish"]
    if fin:
        out["finish_reason"] = fin[-1].get("reason")
    return out


class Telemetry:
    """Live telemetry sink for one EngineCore (basic and trace tiers).

    Spans (trace tier) are bounded: once ``max_spans`` are held, further
    spans are counted in ``spans_dropped`` instead of stored.  Finished
    request timelines are kept in an LRU of ``max_timelines``; in-flight
    timelines are unbounded but naturally small (≤ queue + slots).
    """

    def __init__(self, tier: str = "basic", *, max_spans: int = 1 << 16,
                 max_timelines: int = 1024):
        if tier not in TIERS or tier == "off":
            raise ValueError(f"Telemetry tier must be basic|trace, got {tier}")
        self.tier = tier
        self.enabled = True
        self.tracing = tier == "trace"
        self.registry = MetricsRegistry()
        self.max_spans = max_spans
        self.max_timelines = max_timelines
        self.spans: List[Dict[str, Any]] = []
        self.spans_dropped = 0
        self._active: Dict[str, List[Dict[str, Any]]] = {}
        self._finished: "collections.OrderedDict[str, List[Dict[str, Any]]]" \
            = collections.OrderedDict()
        self._last_token_t: Dict[str, float] = {}

    # -- metrics passthrough --------------------------------------------
    def counter(self, name, value=1.0, help="", **labels):
        self.registry.counter(name, value, labels=labels or None, help=help)

    def gauge(self, name, value, help="", **labels):
        self.registry.gauge(name, value, labels=labels or None, help=help)

    def observe(self, name, value, help="", buckets=None, **labels):
        self.registry.observe(name, value, labels=labels or None,
                              buckets=buckets, help=help)

    # -- spans -----------------------------------------------------------
    def span(self, name: str, step: int = -1, **args):
        if not self.tracing:
            return _NULL_CM
        return _Span(self, name, step, args or None)

    def _add_span(self, name, step, t0, t1, args, error=False):
        if len(self.spans) >= self.max_spans:
            self.spans_dropped += 1
            return
        sp = {"name": name, "step": step, "t0": t0, "t1": t1}
        if args:
            sp["args"] = args
        if error:
            sp["error"] = True
        self.spans.append(sp)

    # -- request timelines ----------------------------------------------
    def event(self, uid: str, name: str, t: Optional[float] = None, **data):
        ev = {"uid": uid, "ev": name,
              "t": time.time() if t is None else t}
        if data:
            ev.update(data)
        tl = self._active.get(uid)
        if tl is None:
            if name == "enqueue":
                # Re-submitted uid: restart its timeline rather than
                # append to a sealed one.
                self._finished.pop(uid, None)
            tl = self._active[uid] = []
        tl.append(ev)

    def token(self, uid: str, n: int = 1, t: Optional[float] = None):
        """Record n tokens emitted for uid; feeds the ITL histogram."""
        now = time.time() if t is None else t
        last = self._last_token_t.get(uid)
        if last is not None and n == 1:
            self.observe("request_itl_seconds", now - last,
                         help="Inter-token latency (per decode token)")
        self._last_token_t[uid] = now
        self.event(uid, "tokens", t=now, n=n)

    def finish(self, uid: str):
        """Seal uid's timeline (moves it to the bounded finished LRU)."""
        self._last_token_t.pop(uid, None)
        tl = self._active.pop(uid, None)
        if tl is None:
            return
        self._finished[uid] = tl
        self._finished.move_to_end(uid)
        while len(self._finished) > self.max_timelines:
            self._finished.popitem(last=False)

    def timeline(self, uid: str) -> Optional[Dict[str, Any]]:
        tl = self._active.get(uid) or self._finished.get(uid)
        if tl is None:
            return None
        return {"uid": uid, "events": list(tl),
                "summary": summarize_timeline(tl)}

    def timelines(self) -> List[Dict[str, Any]]:
        out = [self.timeline(uid) for uid in
               list(self._finished) + list(self._active)]
        return [t for t in out if t is not None]

    def iter_events(self) -> Iterable[Dict[str, Any]]:
        for tl in list(self._finished.values()) + list(self._active.values()):
            for ev in tl:
                yield ev

    # -- snapshots -------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        snap = self.registry.snapshot()
        snap["meta"] = {"tier": self.tier, "spans": len(self.spans),
                        "spans_dropped": self.spans_dropped,
                        "timelines": len(self._active) + len(self._finished)}
        return snap


class NullTelemetry:
    """The "off" tier: every hook is a no-op; ``enabled`` gates all
    engine-side formatting/allocation so the hot path is untouched."""

    tier = "off"
    enabled = False
    tracing = False
    registry = None
    spans: List[Dict[str, Any]] = []
    spans_dropped = 0

    def counter(self, *a, **k):
        pass

    def gauge(self, *a, **k):
        pass

    def observe(self, *a, **k):
        pass

    def span(self, *a, **k):
        return _NULL_CM

    def event(self, *a, **k):
        pass

    def token(self, *a, **k):
        pass

    def finish(self, *a, **k):
        pass

    def timeline(self, uid):
        return None

    def timelines(self):
        return []

    def iter_events(self):
        return iter(())

    def snapshot(self):
        return None


def make_telemetry(tier: str):
    """Factory: ``off`` → shared-shape NullTelemetry, else a live sink."""
    if tier not in TIERS:
        raise ValueError(f"telemetry tier must be one of {TIERS}, got {tier!r}")
    if tier == "off":
        return NullTelemetry()
    return Telemetry(tier)
