"""jax API-surface compatibility shims.

The codebase targets recent jax; pinned container images may lag by a few
releases. Every shim resolves the new-style API when present and falls
back to the older spelling otherwise, so the same source runs on both.
"""
from __future__ import annotations

import inspect

import jax

try:                                    # jax >= 0.5 top-level alias
    _shard_map = jax.shard_map
except AttributeError:                  # older: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map

_SM_PARAMS = frozenset(inspect.signature(_shard_map).parameters)


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check_vma=None):
    """shard_map accepting the new-style kwargs on every jax version.

    ``axis_names`` (manual axes; the rest stay Auto) maps to the legacy
    ``auto`` complement set; ``check_vma`` maps to legacy ``check_rep``.
    """
    kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    if "check_vma" in _SM_PARAMS:       # new API
        if axis_names is not None:
            kw["axis_names"] = axis_names
        if check_vma is not None:
            kw["check_vma"] = check_vma
    else:                               # legacy experimental API
        if check_vma is not None:
            kw["check_rep"] = check_vma
        if axis_names is not None:
            kw["auto"] = frozenset(mesh.axis_names) - set(axis_names)
    return _shard_map(f, **kw)


def make_mesh(shape, axes, *, devices=None):
    """jax.make_mesh with explicit-Auto axis types where supported."""
    try:
        from jax.sharding import AxisType
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes),
                             devices=devices)
    except (ImportError, TypeError):
        return jax.make_mesh(shape, axes, devices=devices)
    except AttributeError:      # pre-0.4.35: no jax.make_mesh at all
        from jax.sharding import Mesh
        import numpy as _np
        devs = devices if devices is not None else jax.devices()
        return Mesh(_np.asarray(devs).reshape(shape), axes)


@jax.custom_jvp
def opt_barrier(x):
    """``lax.optimization_barrier`` that is transparent to autodiff.

    Older jax releases ship no differentiation rule for the barrier
    primitive; training paths that barrier activations (rms_norm, rwkv
    mixes) would fail to trace under grad. The custom JVP keeps the
    barrier in the primal computation and passes tangents straight
    through (the barrier is semantically the identity).
    """
    return jax.lax.optimization_barrier(x)


@opt_barrier.defjvp
def _opt_barrier_jvp(primals, tangents):
    (x,), (t,) = primals, tangents
    return jax.lax.optimization_barrier(x), t
