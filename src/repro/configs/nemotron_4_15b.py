"""Nemotron-4 15B [arXiv:2402.16819]: dense GQA, squared-ReLU MLP."""
from repro.configs.base import ModelConfig, CHAIConfig, register

CONFIG = register(ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=256000,
    activation="relu2",          # squared ReLU
    gated_mlp=False,             # nemotron MLP: up + down only
    rope_theta=10000.0,
    chai=CHAIConfig(enabled=True),
))
