"""Jit'd dispatch wrappers over the Pallas kernels.

On CPU (this container) kernels run with interpret=True; on TPU they lower
to Mosaic. ``chai_decode_attention`` is the fused public op: clustered
scores -> masked row softmax -> broadcast AV.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import chai_attention as ck
from repro.kernels import flash_attention as fk


@functools.partial(jax.jit, static_argnames=("window", "ts", "interpret"))
def flash_decode_attention(q, k_cache, v_cache, pos, *, window=0, ts=512,
                           interpret=None):
    return fk.flash_decode(q, k_cache, v_cache, pos, window=window, ts=ts,
                           interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("offset", "window", "tq", "ts",
                                    "interpret"))
def flash_prefill_attention(q, k, v, *, offset=0, window=0, tq=256, ts=512,
                            interpret=None):
    return fk.flash_prefill(q, k, v, offset=offset, window=window, tq=tq,
                            ts=ts, interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("reps_per_group", "window", "ts",
                                    "interpret"))
def chai_decode_attention(q_rep, k_cache, v_cache, h2c, pos, *,
                          reps_per_group=1, window=0, ts=512,
                          interpret=None):
    """The paper's decode op. q_rep: (B, R, hd) rep-head queries;
    k_cache: (B, KV, S, hd) (clustered for MHA: KV==R); v_cache:
    (B, H, S, hd) full per-head V; h2c: (B, H) or (H,) head->rep-row map;
    pos: (B,). Returns (B, H, hd) fp32."""
    sc = ck.chai_qk(q_rep, k_cache, pos, reps_per_group=reps_per_group,
                    window=window, ts=ts, interpret=interpret)
    a = ck.row_softmax(sc, interpret=interpret)
    return ck.chai_av(a, v_cache, h2c, ts=ts, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_decode_attention(q, kv_pool, bt_k, bt_v, pos, *, window=0,
                           interpret=None):
    """Paged flash decode over a block-table page pool. q: (B, H, hd);
    kv_pool: (nP, KV, page, hd); bt_k/bt_v: (B, P) int32; pos: (B,).
    Returns (B, H, hd) fp32."""
    return fk.paged_decode(q, kv_pool, bt_k, bt_v, pos, window=window,
                           interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("reps_per_group", "window", "interpret"))
def paged_chai_decode_attention(q_rep, k_pool, bt_k, v_pool, bt_v, h2c,
                                pos, *, reps_per_group=1, window=0,
                                interpret=None):
    """The paper's decode op over the serving engine's paged layout.
    q_rep: (B, R, hd); k_pool: (nP, KV, page, hd) clustered pages (MHA:
    KV == k_max); v_pool: (nP, H, page, hd) per-head V pages; bt_k/bt_v:
    (B, P) int32 block tables; h2c: (B, H) or (H,). Returns (B, H, hd)."""
    sc = ck.paged_chai_qk(q_rep, k_pool, bt_k, pos,
                          reps_per_group=reps_per_group, window=window,
                          interpret=interpret)
    a = ck.row_softmax(sc, interpret=interpret)
    return ck.paged_chai_av(a, v_pool, bt_v, h2c, interpret=interpret)


def decode_flop_estimate(b, h, r, s, hd):
    """Analytic decode-attention FLOPs: clustered scores + full AV."""
    scores = 2.0 * b * r * s * hd
    av = 2.0 * b * h * s * hd
    return scores + av
