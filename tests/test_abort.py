"""Abort/cancellation: the ABORT edge of the phase machine.

``EngineCore.abort(uid)`` must (a) cancel a request at ANY lifecycle
point — still queued (pre-PREFILL), freshly prefilled (WARMUP entry),
mid-WARMUP, and STEADY (post-CLUSTER, dense K pages already freed) —
(b) return every page the request held to the pools refcount-exactly
(allocator counters back to their pre-admission baseline, no leaks),
and (c) never corrupt concurrent slots: a survivor decoding beside an
aborted request produces exactly its solo-run tokens.
"""
import numpy as np
import pytest

import jax

from repro.configs.base import get_config, reduced
from repro.core import cache as chai_cache
from repro.models import transformer as tfm
from repro.serving.engine import EngineConfig, EngineCore
from repro.serving.sampling import SamplingParams

MHA_ARCH = "chai-llama-7b"
WARM = 3


def _cfg(arch=MHA_ARCH):
    cfg = reduced(get_config(arch), n_layers=2, d_model=32, d_ff=64,
                  vocab=64).replace(dtype="float32")
    return cfg.with_chai(enabled=True, warmup_tokens=WARM)


def _core(cfg, **ecfg_kw):
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    return EngineCore(cfg, params,
                      EngineConfig(batch_slots=2, max_seq=64,
                                   page_size=16, **ecfg_kw))


def _counters(core):
    out = {"dense": core.dense_pool.counters()}
    if core.chai_pool is not None:
        out["chai"] = core.chai_pool.counters()
    return out


def _prompt(rng, cfg, n=8):
    return rng.integers(0, cfg.vocab_size, size=n)


# Steps to reach each phase: after add_request, step k leaves the slot
# with k+1 generated tokens; CLUSTER fires at the START of the step where
# slot_count == WARM + 1, so:
#   0 steps  -> queued (pre-PREFILL)
#   1 step   -> WARMUP (freshly prefilled)
#   2 steps  -> mid-WARMUP
#   WARM + 2 -> STEADY (dense K pages already freed at compaction)
PHASE_STEPS = {"queued": 0, "prefill": 1, "warmup": 2, "steady": WARM + 2}


@pytest.mark.parametrize("phase", list(PHASE_STEPS))
def test_abort_returns_all_pages(phase):
    """Abort at every lifecycle point: allocator counters return to the
    pre-admission baseline (refcount-exact, zero leaks)."""
    cfg = _cfg()
    core = _core(cfg)
    rng = np.random.default_rng(0)
    base = _counters(core)
    req = core.add_request(_prompt(rng, cfg), max_new_tokens=16, uid=7)
    for _ in range(PHASE_STEPS[phase]):
        core.step()
    if phase == "steady":
        assert core._phases[req.slot] == chai_cache.PHASE_STEADY
    assert core.abort(7) is True
    assert req.finish_reason == "aborted"
    assert _counters(core) == base
    assert not core.has_work()
    # double-abort and unknown uids are no-ops
    assert core.abort(7) is False
    assert core.abort(999) is False
    # tokens generated before the abort survive on the request
    # (admission emits 1 token, then 1 per decode step)
    steps = PHASE_STEPS[phase]
    assert len(req.generated) == (0 if steps == 0 else steps + 1)


def test_abort_does_not_corrupt_concurrent_slot():
    """A survivor decoding beside an aborted request finishes with its
    solo-run tokens (greedy AND seeded sampling), and the aborted slot
    is immediately reusable."""
    cfg = _cfg()
    rng = np.random.default_rng(1)
    p_a, p_b, p_c = (_prompt(rng, cfg) for _ in range(3))
    for sp in (SamplingParams(max_new_tokens=12),
               SamplingParams(temperature=0.8, top_k=16, top_p=0.95,
                              seed=11, max_new_tokens=12)):
        solo = _core(cfg)
        solo.add_request(p_b, sp, uid=0)
        while solo.has_work():
            solo.step()
        want = solo.done[0].generated

        core = _core(cfg)
        base = _counters(core)
        core.add_request(p_a, sp, uid=0)
        survivor = core.add_request(p_b, sp, uid=1)
        core.step()             # both admitted, one decode step each
        core.step()
        assert core.abort(0) is True
        queued = core.add_request(p_c, sp, uid=2)   # reuses the slot
        while core.has_work():
            core.step()
        assert survivor.generated == want
        assert queued.slot == 0 or queued.slot == 1
        assert len(queued.generated) == 12
        assert _counters(core) == base


def test_abort_queued_request_never_touches_device():
    cfg = _cfg()
    core = _core(cfg)
    rng = np.random.default_rng(2)
    req = core.add_request(_prompt(rng, cfg), max_new_tokens=8, uid=3)
    assert core.abort(3) is True
    assert req.generated == [] and req.finish_reason == "aborted"
    assert core._dev_state is None          # no device work happened
    assert not core.queue


def test_abort_with_prefix_cache_unlocks_pins():
    """Aborting a prefix-hit request drops its cache locks; the cache's
    own references survive (and clear() then drains to zero)."""
    cfg = _cfg()
    core = _core(cfg, prefix_cache=True)
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, size=32)   # 2 full blocks
    core.add_request(prompt, max_new_tokens=8, uid=0)
    while core.has_work():
        core.step()
    warm = core.add_request(np.concatenate([prompt, [1, 2, 3]]),
                            max_new_tokens=8, uid=1)
    core.step()                 # admitted via the cache (locked entries)
    assert warm.cache_hit in ("prefix", "snapshot")
    assert core.abort(1) is True
    assert all(not locked for locked in core._slot_locked)
    core.prefix_cache.clear()
    assert core.dense_pool.pages_in_use == 0
    assert core.chai_pool.pages_in_use == 0
