"""Online cluster-membership identification (paper §3.3, Fig 10b).

After ``warmup_tokens`` MHA decode steps, per-head attention-score features
are clustered with K-Means to decide which heads share a representative.
Features are standardized per head so squared Euclidean distance equals
2*(1 - Pearson correlation) — K-Means then clusters exactly by the paper's
correlation criterion.

Two modes (DESIGN.md §4):
  * MHA (n_kv == n_heads): global clustering across all H heads; enables the
    clustered K-cache.
  * GQA: block-diagonal clustering within each KV group (a representative's
    scores are only valid for heads sharing its K); compute-only saving.

Membership is per *request*: all ctx arrays carry a batch dim
(`nA, B, ...`). A batch-free variant (shared membership) is produced by
``shared_ctx`` for single-request latency paths and the dry-run.
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.kmeans import kmeans, representatives


def standardize(x, eps=1e-12):
    """Per-row standardize: zero mean, unit norm -> correlation geometry."""
    x = x.astype(jnp.float32)
    x = x - x.mean(-1, keepdims=True)
    n = jnp.sqrt(jnp.sum(jnp.square(x), -1, keepdims=True))
    return x / jnp.maximum(n, eps)


def chai_widths(cfg: ModelConfig):
    """(k_max, r_max): static cluster widths. r_max is the per-KV-group
    cluster budget for GQA archs."""
    k_max = cfg.k_max
    if k_max == 0:
        return 0, 0
    if cfg.is_mha:
        return k_max, k_max
    r_max = max(1, math.ceil(k_max / cfg.n_kv_heads))
    r_max = min(r_max, cfg.q_per_kv)
    return k_max, r_max


def identify_membership(scores, cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    """scores: (nA, B, H, F) accumulated warmup attention scores.

    Returns a batched chai_ctx:
      MHA: {"h2c": (nA,B,H) int32, "reps": (nA,B,k) int32}
      GQA: {"cluster_of": (nA,B,KV,qpk) int32, "reps": (nA,B,KV,r) int32}
    """
    k_max, r_max = chai_widths(cfg)
    iters = cfg.chai.kmeans_iters

    if cfg.is_mha:
        def one(feats):                       # (H, F)
            f = standardize(feats)
            assign, centers, _ = kmeans(f, k_max, iters)
            reps, _ = representatives(f, assign, centers, k_max)
            return assign.astype(jnp.int32), reps

        h2c, reps = jax.vmap(jax.vmap(one))(scores)
        return {"h2c": h2c, "reps": reps}

    qpk = cfg.q_per_kv
    na, b, h, f = scores.shape
    grouped = scores.reshape(na, b, cfg.n_kv_heads, qpk, f)

    def one(feats):                           # (qpk, F) within one KV group
        fz = standardize(feats)
        assign, centers, _ = kmeans(fz, r_max, iters)
        reps, _ = representatives(fz, assign, centers, r_max)
        return assign.astype(jnp.int32), reps

    cluster_of, reps = jax.vmap(jax.vmap(jax.vmap(one)))(grouped)
    return {"cluster_of": cluster_of, "reps": reps}


def identify_membership_slot(scores, cfg: ModelConfig, identify_fn=None):
    """Membership identification for ONE request. scores: (nA, H, F).

    Returns a batch-free ctx (MHA: h2c (nA,H) / reps (nA,k); GQA:
    cluster_of (nA,KV,qpk) / reps (nA,KV,r)) — the continuous engine
    scatters it into its batched ctx buffer with ``update_ctx_slot``.

    ``identify_fn``: optional batched identification hook (scores with a
    batch dim -> batched ctx); defaults to ``identify_membership``. The
    engine threads its monkeypatchable hook through here.
    """
    fn = identify_fn if identify_fn is not None else (
        lambda s: identify_membership(s, cfg))
    return jax.tree.map(lambda a: a[:, 0], fn(scores[:, None]))


def init_batched_ctx(cfg: ModelConfig, batch: int):
    """All-zero per-request membership buffers (zeros are valid indices:
    every head in cluster 0, representative head 0). Slots are overwritten
    by ``update_ctx_slot`` before their first STEADY decode."""
    shapes, _ = ctx_structs(cfg, batch)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)


def update_ctx_slot(ctx, slot_ctx, slot):
    """Scatter one request's batch-free ctx into batch slot ``slot``."""
    return jax.tree.map(
        lambda a, u: jax.lax.dynamic_update_index_in_dim(
            a, u.astype(a.dtype), slot, 1), ctx, slot_ctx)


def shared_ctx(cfg: ModelConfig, seed: int = 0):
    """Deterministic shared (batch-free) membership — used by the dry-run
    and by CHAI-static (offline membership, paper §3.3 'CHAI-static').

    Produces a valid ctx without observing activations: heads are assigned
    round-robin to clusters (every cluster non-empty, reps = first member).
    """
    k_max, r_max = chai_widths(cfg)
    na = cfg.n_attn_layers
    if cfg.is_mha:
        h = cfg.n_heads
        h2c = jnp.tile(jnp.arange(h, dtype=jnp.int32) % k_max, (na, 1))
        reps = jnp.tile(jnp.arange(k_max, dtype=jnp.int32), (na, 1))
        return {"h2c": h2c, "reps": reps}
    qpk = cfg.q_per_kv
    cluster_of = jnp.tile(
        jnp.arange(qpk, dtype=jnp.int32)[None, None, :] % r_max,
        (na, cfg.n_kv_heads, 1))
    reps = jnp.tile(jnp.arange(r_max, dtype=jnp.int32)[None, None, :],
                    (na, cfg.n_kv_heads, 1))
    return {"cluster_of": cluster_of, "reps": reps}


def ctx_structs(cfg: ModelConfig, batch: int = 0):
    """ShapeDtypeStructs + logical axes for the chai_ctx (dry-run inputs).

    batch=0 -> shared (batch-free) ctx."""
    from repro.sharding.rules import Ax
    k_max, r_max = chai_widths(cfg)
    na = cfg.n_attn_layers
    bdims = (batch,) if batch else ()
    bax = ("batch",) if batch else ()
    i32 = jnp.int32
    if cfg.is_mha:
        return ({"h2c": jax.ShapeDtypeStruct((na, *bdims, cfg.n_heads), i32),
                 "reps": jax.ShapeDtypeStruct((na, *bdims, k_max), i32)},
                {"h2c": Ax("layers", *bax, None),
                 "reps": Ax("layers", *bax, "clusters")})
    qpk = cfg.q_per_kv
    return ({"cluster_of": jax.ShapeDtypeStruct(
                 (na, *bdims, cfg.n_kv_heads, qpk), i32),
             "reps": jax.ShapeDtypeStruct(
                 (na, *bdims, cfg.n_kv_heads, r_max), i32)},
            {"cluster_of": Ax("layers", *bax, "kv_heads", None),
             "reps": Ax("layers", *bax, "kv_heads", None)})


def membership_churn(prev_ctx, new_ctx):
    """Fraction of heads whose cluster id changed (paper Fig 9 metric)."""
    key = "h2c" if "h2c" in new_ctx else "cluster_of"
    a, b = prev_ctx[key], new_ctx[key]
    return jnp.mean((a != b).astype(jnp.float32))
