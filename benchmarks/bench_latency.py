"""Paper Fig 12: time-to-first-token and time-to-next-token, MHA vs CHAI.

Three measurements:
  1. **CPU wall time** on the trained tiny model through the serving
     engine (real phase machine, real clustering overhead in TTFT).
  2. **Analytic TPU v5e model** for the full LLaMA-7B config: decode
     attention is HBM-bandwidth-bound, so TTNT speedup ≈ KV-bytes-read
     ratio; prefill is compute-bound, so TTFT speedup ≈ score-FLOP ratio.
  3. **Scheduler comparison**: the same mixed-length (8–128 new tokens)
     Poisson-arrival workload through the continuous and cohort
     schedulers — per-request TTFT and request throughput (continuous
     must sustain strictly higher throughput: no head-of-line blocking).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import save_result, tiny_trained
from repro.configs.base import get_config
from repro.core.cache import kv_cache_bytes
from repro.kernels.ops import decode_flop_estimate
from repro.launch.roofline import HBM_BW, PEAK_FLOPS
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.workload import poisson_workload


def _engine_times(cfg, params, pipe, use_chai, n_req=4, max_new=12):
    eng = ServingEngine(cfg, params,
                        EngineConfig(batch_slots=2, max_seq=128,
                                     use_chai=use_chai))
    for i in range(n_req):
        eng.submit(pipe.batch(900 + i)["tokens"][0, :24],
                   max_new_tokens=max_new, uid=i)
    t0 = time.time()
    done = eng.run()
    wall = time.time() - t0
    ttft = float(np.mean([r.ttft for r in done]))
    per_tok = (wall - ttft * (n_req / eng.ecfg.batch_slots)) / (
        n_req * max_new)
    return {"wall_s": wall, "ttft_s": ttft, "per_token_s": per_tok}


def _scheduler_compare(cfg, params, pipe, *, n_req=18, slots=6,
                       prompt_len=16, new_tokens=(8, 128), mean_gap_s=0.01,
                       seed=0):
    """Same Poisson workload (exponential inter-arrival gaps, mixed
    8-128 new tokens) through both schedulers.

    Output lengths are long-tailed (most requests short, a minority
    near the cap — the regime continuous batching exists for: under the
    cohort scheduler every short request in a cohort waits for its
    longest member)."""
    rng = np.random.default_rng(seed)
    arrivals, lens = poisson_workload(rng, n_req, mean_gap_s=mean_gap_s,
                                      new_tokens=new_tokens)
    prompts = [pipe.batch(3000 + i)["tokens"][0, :prompt_len]
               for i in range(n_req)]
    out = {}
    lanes = {
        # paged is the engine default: page-budget admission, dense pages
        # freed at compaction
        "continuous": dict(scheduler="continuous", kv_layout="paged"),
        "continuous_dense": dict(scheduler="continuous",
                                 kv_layout="dense"),
        "cohort": dict(scheduler="cohort"),
    }
    for lane, kw in lanes.items():
        eng = ServingEngine(cfg, params,
                            EngineConfig(batch_slots=slots, max_seq=192,
                                         **kw))
        # Two identical passes; the first warms every jit (prefill per
        # prompt length, all phase-mix step variants) so the measured
        # pass reflects steady-state serving, not compile time.
        for timed in (False, True):
            t0 = time.time()
            batch = [eng.submit(prompts[i], max_new_tokens=int(lens[i]),
                                uid=i, arrival_delay=float(arrivals[i]))
                     for i in range(n_req)]
            steps0 = eng.steps_executed
            eng.run()
            wall = time.time() - t0
        ttfts = np.array([r.ttft for r in batch])
        span = max(r.t_done for r in batch) - min(r.t_arrival for r in batch)
        out[lane] = {
            "wall_s": wall,
            "req_per_s": n_req / span,
            "ttft_s_mean": float(ttfts.mean()),
            "ttft_s_p95": float(np.percentile(ttfts, 95)),
            "decode_steps": eng.steps_executed - steps0,
        }
        if eng.paged:
            out[lane]["kv_bytes_peak"] = int(eng.kv_bytes_peak())
            out[lane]["kv_bytes_capacity"] = int(eng.kv_bytes_capacity())
    out["workload"] = {"n_req": n_req, "slots": slots,
                       "new_tokens": list(map(int, lens)),
                       "arrival_span_s": float(arrivals[-1])}
    out["continuous_strictly_faster"] = bool(
        out["continuous"]["req_per_s"] > out["cohort"]["req_per_s"])
    out["paged_vs_dense_layout_req_per_s_ratio"] = float(
        out["continuous"]["req_per_s"]
        / out["continuous_dense"]["req_per_s"])
    return out


def _analytic_full(seqs=(256, 512, 1024, 2048)):
    cfg = get_config("chai-llama-7b")
    h, hd = cfg.n_heads, cfg.head_dim
    counts = cfg.chai_cluster_counts()
    out = {}
    for s in seqs:
        # TTNT: decode is memory-bound -> bytes of KV read per token
        mha_bytes = kv_cache_bytes(cfg, 1, s, chai=False)
        chai_bytes = kv_cache_bytes(cfg, 1, s, chai=True)
        # TTFT: prefill is compute-bound -> attention score flops
        mha_fl = sum(decode_flop_estimate(1, h, h, s, hd)
                     for _ in counts) * s
        chai_fl = sum(decode_flop_estimate(1, h, k, s, hd)
                      for k in counts) * s
        out[str(s)] = {
            "ttnt_speedup_bound": mha_bytes / chai_bytes,
            "ttft_attention_speedup_bound": mha_fl / chai_fl,
            "ttnt_mha_s_v5e": mha_bytes / HBM_BW,
            "ttnt_chai_s_v5e": chai_bytes / HBM_BW,
        }
    return out


def run():
    cfg, params, pipe, _ = tiny_trained()
    cfg_chai = cfg.with_chai(enabled=True,
                             cluster_counts=(5,) * cfg.n_attn_layers)
    cpu_mha = _engine_times(cfg, params, pipe, use_chai=False)
    cpu_chai = _engine_times(cfg_chai, params, pipe, use_chai=True)
    sched = _scheduler_compare(cfg_chai, params, pipe)

    result = {
        "proxy_note": "CPU wall time on tiny model (engine incl. "
                      "clustering overhead) + analytic v5e model for "
                      "LLaMA-7B (paper Fig 12 ran V100s)",
        "cpu_tiny": {"mha": cpu_mha, "chai": cpu_chai,
                     "per_token_speedup":
                         cpu_mha["per_token_s"] / cpu_chai["per_token_s"]},
        "scheduler_compare_poisson": sched,
        "analytic_llama7b_v5e": _analytic_full(),
        "paper_claim": "TTFT up to 1.73x, TTNT up to 5x at seq 2048",
        "claim_check": {
            "ttnt_bound_exceeds_1": _analytic_full()["2048"]
                ["ttnt_speedup_bound"] > 1.0,
            "ttft_attn_bound_exceeds_1": _analytic_full()["2048"]
                ["ttft_attention_speedup_bound"] > 1.0,
            "continuous_sustains_higher_throughput":
                sched["continuous_strictly_faster"],
            # paged admission keeps the mixed 8-128-token Poisson
            # workload flowing: the page-budget gate never exceeds the
            # pool reservation and does not collapse throughput vs the
            # dense layout
            "paged_peak_within_capacity":
                sched["continuous"]["kv_bytes_peak"]
                <= sched["continuous"]["kv_bytes_capacity"],
            "paged_admission_throughput_holds":
                sched["paged_vs_dense_layout_req_per_s_ratio"] > 0.5,
        },
    }
    save_result("bench_latency", result)
    return result


if __name__ == "__main__":
    print(run())
