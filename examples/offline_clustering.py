"""CHAI offline phase (paper Fig 10a): elbow analysis per layer.

Collects per-head attention-score features over a calibration corpus
(synthetic C4 stand-in), sweeps K-Means k per layer, prints the error
curves and the elbow-selected cluster counts — the `cluster_counts` you
would freeze into the ModelConfig for serving.

  PYTHONPATH=src python examples/offline_clustering.py
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, reduced
from repro.core.cache import add_score_buffer, pop_score_buffer
from repro.core.clustering import standardize
from repro.core.elbow import elbow_curve, select_k
from repro.data.pipeline import calibration_batches
from repro.models import transformer as tfm


def main():
    cfg = reduced(get_config("chai-llama-7b"), n_heads=8,
                  n_layers=4).replace(dtype="float32")
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    print(f"collecting activations on the calibration corpus "
          f"({cfg.n_layers} layers, {cfg.n_heads} heads) ...")

    feats_sum = None
    n = 0
    for toks in calibration_batches(cfg.vocab_size, 24, n_samples=16):
        toks = jnp.asarray(toks)
        state = tfm.init_decode_state(cfg, toks.shape[0], 64)
        _, state, _ = tfm.forward_fullseq(params, cfg, toks, state=state)
        state = add_score_buffer(state, cfg, toks.shape[0])
        nxt = toks[:, -1]
        for _ in range(cfg.chai.warmup_tokens):
            logits, state = tfm.decode_step(params, cfg, nxt, state)
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        state, scores = pop_score_buffer(state)      # (nA, B, H, Wf)
        s = np.asarray(scores).sum(axis=1)
        feats_sum = s if feats_sum is None else feats_sum + s
        n += scores.shape[1]

    per_layer = feats_sum / n                        # (nA, H, Wf)
    ks = list(range(1, cfg.n_heads + 1))
    print(f"\n{'layer':>6} {'selected k':>10}   error curve")
    counts = []
    for li, f in enumerate(per_layer):
        fz = standardize(jnp.asarray(f, jnp.float32))
        errs = elbow_curve(fz, ks)
        k = select_k(errs, ks)
        counts.append(int(k))
        curve = " ".join(f"{e:6.2f}" for e in errs)
        print(f"{li:>6} {k:>10}   {curve}")
    print(f"\ncluster_counts = {tuple(counts)}")
    print("freeze into the config:  cfg.with_chai(enabled=True, "
          f"cluster_counts={tuple(counts)})")


if __name__ == "__main__":
    main()
