"""Mesh context: lets pure-jnp model code opt into shard_map sub-regions.

The transformer is mesh-agnostic (GSPMD partitions it from jit shardings).
A few blocks — expert-parallel MoE dispatch — need *manual* collectives
(all-to-all) that GSPMD will not discover on its own. Those blocks read
the active mesh from this context; when no mesh is set they fall back to
the pure-jnp path (single-device tests, CPU examples).

Usage (launcher / dry-run):
    with sharding_ctx(mesh, batch_axes=("pod", "data"), model_axis="model"):
        lowered = jax.jit(train_step, ...).lower(...)
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ShardingCtx:
    mesh: object
    batch_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"

    def axis_size(self, name):
        return dict(zip(self.mesh.axis_names,
                        self.mesh.devices.shape))[name]


_ACTIVE: list = []


@contextlib.contextmanager
def sharding_ctx(mesh, *, batch_axes=("data",), model_axis="model"):
    ctx = ShardingCtx(mesh=mesh, batch_axes=tuple(batch_axes),
                      model_axis=model_axis)
    _ACTIVE.append(ctx)
    try:
        yield ctx
    finally:
        _ACTIVE.pop()


def current_ctx() -> Optional[ShardingCtx]:
    return _ACTIVE[-1] if _ACTIVE else None


def pin_activations(t):
    """Pin a (B, T, d) activation to (batch-sharded, replicated, replicated).

    Applied to the layer-scan carry: without it GSPMD may settle on a
    d-sharded fixed point for the residual stream, then all-gather it per
    projection (6x/layer measured on rwkv6 — EXPERIMENTS.md §Perf cell 2).
    No-op without an active ctx (CPU tests) or for non-3D values.
    """
    ctx = current_ctx()
    if ctx is None or getattr(t, "ndim", 0) != 3:
        return t
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    ba = ctx.batch_axes if len(ctx.batch_axes) > 1 else ctx.batch_axes[0]
    return jax.lax.with_sharding_constraint(
        t, NamedSharding(ctx.mesh, P(ba, None, None)))
