"""Prefill + N decode steps must equal one full forward pass.

The strongest end-to-end invariant in the system: caches (dense KV, ring
KV, RG-LRU hidden state, RWKV wkv state) and the decode-path math must
reproduce the train-path logits exactly (float32, same MoE impl).
Covers dense-global, GQA, sliding-window, MoE, hybrid-recurrent and SSM
families.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, reduced
from repro.launch import steps as steps_mod
from repro.models import transformer as tfm

# window=8 < s exercises the ring buffer on local-attention archs.
PARITY_ARCHS = ["musicgen-large", "nemotron-4-15b", "gemma2-9b",
                "deepseek-moe-16b", "recurrentgemma-9b", "rwkv6-1.6b"]


@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_prefill_decode_matches_fullseq(arch, rng):
    cfg = reduced(get_config(arch), window=8).replace(dtype="float32")
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    b, t0, n_dec, s = 2, 8, 4, 32
    total = t0 + n_dec
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, total)),
                       jnp.int32)

    # reference: single full forward over all tokens (exact dropless MoE)
    logits_full, _, _ = tfm.forward_fullseq(params, cfg, toks,
                                            moe_impl="ragged")

    # prefill on the first t0, then decode token-by-token
    state = tfm.init_decode_state(cfg, b, s)
    logits_pre, state, _ = tfm.forward_fullseq(
        params, cfg, toks[:, :t0], state=state, moe_impl="ragged")
    np.testing.assert_allclose(np.asarray(logits_pre),
                               np.asarray(logits_full[:, :t0]),
                               rtol=2e-4, atol=2e-4)
    for i in range(n_dec):
        logits_i, state = tfm.decode_step(params, cfg, toks[:, t0 + i],
                                          state, moe_impl="ragged")
        np.testing.assert_allclose(
            np.asarray(logits_i), np.asarray(logits_full[:, t0 + i]),
            rtol=3e-4, atol=3e-4, err_msg=f"{arch} decode step {i}")
