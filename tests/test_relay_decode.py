"""Shared-prefix relay decode: one prefix-attention pass per group.

Correctness contract: relaying is a pure *work-restructuring* layer —
the prefix half of every grouped slot's attention is computed ONCE per
group (batched over members, rep rows only) and merged into the slot's
suffix-only fused decode via online-softmax state. Grouped greedy tokens
must match the per-request decode path token-for-token across
{MHA, GQA} x {fp32, int8} x share_values x group sizes; slots that never
group (no shared chain, evicted node, snapshot entry) must stay
BITWISE on the non-relay path (the empty prefix state is the exact merge
identity). The kernel-level sweeps pin the merge algebra; the engine
sweeps pin group formation, resident-view caching and fallback.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, reduced
from repro.kernels import chai_attention as ck
from repro.kernels import ops as kops
from repro.kernels import ref
from repro.models import transformer as tfm
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.prefix_cache import BlockNode
from repro.serving.sampling import SamplingParams

MHA_ARCH = "chai-llama-7b"
GQA_ARCH = "nemotron-4-15b"
PS = 16
TOL = dict(rtol=2e-3, atol=2e-3)

rng = np.random.default_rng(0)


def _mk(shape, dtype=np.float32):
    if dtype == np.int8:
        return jnp.asarray(rng.integers(-127, 127, shape, dtype=np.int8))
    return jnp.asarray(rng.normal(size=shape).astype(dtype))


# ------------------------------------------------ kernel-level merge parity
def _mha_relay_case(n, *, share=False, int8=False, r=3, h=6, hd=16,
                    sp=32, ssuf=32):
    """One group of ``n`` members sharing clustered prefix rows [0, sp).

    Returns (full fused output, relay-composed output): the full pass
    attends the whole cache; the relay path runs the group-batched
    prefix kernel over the shared rows + the suffix-only fused decode,
    then merges the (m, l, acc) states.
    """
    s = sp + ssuf
    kdt = np.int8 if int8 else np.float32
    kc = np.asarray(rng.integers(-127, 127, (n, r, s, hd))
                    if int8 else rng.normal(size=(n, r, s, hd)), kdt)
    kc[:, :, :sp] = kc[0, :, :sp]           # shared prefix (clustered rows)
    v_rows = r if share else h
    vc = np.asarray(rng.integers(-127, 127, (n, v_rows, s, hd))
                    if int8 else rng.normal(size=(n, v_rows, s, hd)), kdt)
    vc[:, :, :sp] = vc[0, :, :sp]
    ks = vs = None
    if int8:
        ks = np.asarray(rng.normal(size=(n, r, s)), np.float32)
        ks[:, :, :sp] = ks[0, :, :sp]
        ks = jnp.asarray(ks)
        if not share:                       # share_values: codes move
            vs = np.asarray(rng.normal(size=(n, v_rows, s)), np.float32)
            vs[:, :, :sp] = vs[0, :, :sp]
            vs = jnp.asarray(vs)
    kc, vc = jnp.asarray(kc), jnp.asarray(vc)
    q = _mk((n, r, hd))
    h2c = jnp.asarray(rng.integers(0, r, (n, h)), jnp.int32)
    pos = jnp.asarray(rng.integers(sp + 1, s, (n,)), jnp.int32)

    full = ck.chai_fused_decode(q, kc, vc, h2c, pos, k_scale=ks,
                                v_scale=vs, share_values=share, ts=16)

    # group-batched prefix pass over the shared rows
    qg = q.reshape(1, n * r, hd)
    k_row = jnp.asarray(np.tile(np.arange(r), n)[None], jnp.int32)
    if share:
        a_row = jnp.asarray(np.arange(n * r)[None], jnp.int32)
        v_row = k_row
    else:
        a_row = jnp.asarray((np.arange(n)[:, None] * r
                             + np.asarray(h2c)).reshape(1, n * h),
                            jnp.int32)
        v_row = jnp.asarray(np.tile(np.arange(h), n)[None], jnp.int32)
    mp, lp, accp = ck.relay_prefix_decode(
        qg, kc[0:1, :, :sp], vc[0:1, :v_rows, :sp], k_row, a_row, v_row,
        jnp.asarray([sp], jnp.int32),
        k_scale=None if ks is None else ks[0:1, :, :sp],
        v_scale=None if vs is None else vs[0:1, :, :sp], ts=16)
    a_rows = r if share else h
    pref = (mp.reshape(n, r), lp.reshape(n, r),
            accp.reshape(n, a_rows, hd))
    suf = ck.chai_fused_decode(q, kc[:, :, sp:], vc[:, :, sp:], h2c,
                               pos - sp,
                               k_scale=None if ks is None else ks[:, :, sp:],
                               v_scale=None if vs is None else vs[:, :, sp:],
                               share_values=share, ts=16, emit_state=True)
    out = kops.finalize_decode_state(
        kops.merge_decode_states(suf, pref, h2c, share_values=share),
        h2c, share_values=share)
    return np.asarray(full), np.asarray(out)


def _gqa_relay_case(n, *, int8=False, kv=2, rpg=2, qpk=2, hd=16,
                    sp=32, ssuf=32):
    s = sp + ssuf
    h = kv * qpk
    rt = kv * rpg
    kdt = np.int8 if int8 else np.float32
    kc = np.asarray(rng.integers(-127, 127, (n, kv, s, hd))
                    if int8 else rng.normal(size=(n, kv, s, hd)), kdt)
    kc[:, :, :sp] = kc[0, :, :sp]
    vc = np.asarray(rng.integers(-127, 127, (n, kv, s, hd))
                    if int8 else rng.normal(size=(n, kv, s, hd)), kdt)
    vc[:, :, :sp] = vc[0, :, :sp]
    ks = vs = None
    if int8:
        sc = np.asarray(rng.normal(size=(2, n, kv, s)), np.float32)
        sc[:, :, :, :sp] = sc[:, 0:1, :, :sp]
        ks, vs = jnp.asarray(sc[0]), jnp.asarray(sc[1])
    kc, vc = jnp.asarray(kc), jnp.asarray(vc)
    q = _mk((n, rt, hd))
    cl = rng.integers(0, rpg, (n, kv, qpk))
    h2c = jnp.asarray((np.arange(kv)[None, :, None] * rpg
                       + cl).reshape(n, h), jnp.int32)
    pos = jnp.asarray(rng.integers(sp + 1, s, (n,)), jnp.int32)

    full = ck.chai_fused_decode(q, kc, vc, h2c, pos, k_scale=ks,
                                v_scale=vs, reps_per_group=rpg, ts=16)

    qg = q.reshape(1, n * rt, hd)
    k_row = jnp.asarray(
        np.tile(np.repeat(np.arange(kv), rpg), n)[None], jnp.int32)
    a_row = jnp.asarray((np.arange(n)[:, None] * rt
                         + np.asarray(h2c)).reshape(1, n * h), jnp.int32)
    v_row = jnp.asarray(
        np.tile(np.repeat(np.arange(kv), qpk), n)[None], jnp.int32)
    mp, lp, accp = ck.relay_prefix_decode(
        qg, kc[0:1, :, :sp], vc[0:1, :, :sp], k_row, a_row, v_row,
        jnp.asarray([sp], jnp.int32),
        k_scale=None if ks is None else ks[0:1, :, :sp],
        v_scale=None if vs is None else vs[0:1, :, :sp], ts=16)
    pref = (mp.reshape(n, rt), lp.reshape(n, rt), accp.reshape(n, h, hd))
    suf = ck.chai_fused_decode(q, kc[:, :, sp:], vc[:, :, sp:], h2c,
                               pos - sp,
                               k_scale=None if ks is None else ks[:, :, sp:],
                               v_scale=None if vs is None else vs[:, :, sp:],
                               reps_per_group=rpg, ts=16, emit_state=True)
    out = kops.finalize_decode_state(
        kops.merge_decode_states(suf, pref, h2c), h2c)
    return np.asarray(full), np.asarray(out)


@pytest.mark.parametrize("n", [1, 2, 8])
@pytest.mark.parametrize("share", [False, True])
@pytest.mark.parametrize("int8", [False, True])
def test_relay_merge_matches_full_fused_mha(n, share, int8):
    full, out = _mha_relay_case(n, share=share, int8=int8)
    np.testing.assert_allclose(out, full, **TOL)


@pytest.mark.parametrize("n", [1, 2, 8])
@pytest.mark.parametrize("int8", [False, True])
def test_relay_merge_matches_full_fused_gqa(n, int8):
    full, out = _gqa_relay_case(n, int8=int8)
    np.testing.assert_allclose(out, full, **TOL)


@pytest.mark.parametrize("int8", [False, True])
def test_relay_prefix_kernel_vs_oracle(int8):
    g, nmax, kv, r, hd, sp = 2, 3, 8, 3, 16, 64
    nr, h = nmax * r, 8
    a = nmax * h
    q = _mk((g, nr, hd))
    kdt = np.int8 if int8 else np.float32
    k, v = _mk((g, kv, sp, hd), kdt), _mk((g, kv, sp, hd), kdt)
    ks = _mk((g, kv, sp)) if int8 else None
    vs = _mk((g, kv, sp)) if int8 else None
    k_row = jnp.asarray(rng.integers(0, kv, (g, nr)), jnp.int32)
    a_row = jnp.asarray(rng.integers(0, nr, (g, a)), jnp.int32)
    v_row = jnp.asarray(rng.integers(0, kv, (g, a)), jnp.int32)
    plen = jnp.asarray([48, 16], jnp.int32)
    got = ck.relay_prefix_decode(q, k, v, k_row, a_row, v_row, plen,
                                 k_scale=ks, v_scale=vs, ts=16)
    want = ref.relay_prefix_decode_ref(q, k, v, k_row, a_row, v_row, plen,
                                       k_scale=ks, v_scale=vs)
    for a_, b_ in zip(got, want):
        np.testing.assert_allclose(a_, b_, **TOL)


def test_empty_prefix_state_is_bitwise_merge_identity():
    n, r, h, hd, s = 2, 3, 6, 16, 64
    q = _mk((n, r, hd))
    kc, vc = _mk((n, r, s, hd)), _mk((n, h, s, hd))
    h2c = jnp.asarray(rng.integers(0, r, (n, h)), jnp.int32)
    pos = jnp.asarray([40, 63], jnp.int32)
    st = ck.chai_fused_decode(q, kc, vc, h2c, pos, ts=16, emit_state=True)
    empty = (jnp.full((n, r), ck.NEG_INF), jnp.zeros((n, r)),
             jnp.zeros((n, h, hd)))
    merged = kops.finalize_decode_state(
        kops.merge_decode_states(st, empty, h2c), h2c)
    direct = kops.finalize_decode_state(st, h2c)
    assert (np.asarray(merged) == np.asarray(direct)).all()


# ----------------------------------------------------- engine-level parity
def _cfg(arch, chai_kw=(), cfg_kw=()):
    cfg = reduced(get_config(arch), n_layers=2, d_model=32, d_ff=64,
                  vocab=64).replace(dtype="float32", **dict(cfg_kw))
    return cfg.with_chai(enabled=True, warmup_tokens=3, **dict(chai_kw))


def _engine(cfg, params, *, slots=2, relay=True, min_group=2, **kw):
    return ServingEngine(cfg, params,
                         EngineConfig(batch_slots=slots, max_seq=64,
                                      page_size=PS, prefix_cache=True,
                                      relay_decode=relay,
                                      relay_min_group=min_group, **kw))


def _shared_prompts(n, prefix_blocks=2, seed=7):
    r = np.random.default_rng(seed)
    prefix = r.integers(0, 64, size=prefix_blocks * PS).tolist()
    return prefix, [prefix + r.integers(0, 64, size=4 + j).tolist()
                    for j in range(n)]


def _serve(cfg, params, prompts, *, warm, max_new=8, **kw):
    eng = _engine(cfg, params, **kw)
    eng.submit(warm, max_new_tokens=4, uid=0)
    eng.run()
    for j, p in enumerate(prompts):
        eng.submit(p, max_new_tokens=max_new, uid=j + 1)
    done = {r.uid: r for r in eng.run()}
    return [done[j + 1].generated for j in range(len(prompts))], eng


@pytest.mark.slow
@pytest.mark.parametrize("arch,chai_kw,cfg_kw", [
    (MHA_ARCH, {}, {}),
    (MHA_ARCH, {}, {"kv_cache_dtype": "int8"}),
    (MHA_ARCH, {"share_values": True}, {}),
    (MHA_ARCH, {"share_values": True}, {"kv_cache_dtype": "int8"}),
    (GQA_ARCH, {}, {}),
    (GQA_ARCH, {}, {"kv_cache_dtype": "int8"}),
])
def test_relay_engine_token_parity(arch, chai_kw, cfg_kw):
    cfg = _cfg(arch, chai_kw, cfg_kw)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    prefix, prompts = _shared_prompts(2)
    base, _ = _serve(cfg, params, prompts, warm=prefix + [1], relay=False)
    got, eng = _serve(cfg, params, prompts, warm=prefix + [1], relay=True)
    assert eng.relay_steps > 0
    assert got == base


@pytest.mark.slow
@pytest.mark.parametrize("n", [1, 2])
def test_relay_group_sizes_small(n):
    cfg = _cfg(MHA_ARCH)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    prefix, prompts = _shared_prompts(n)
    base, _ = _serve(cfg, params, prompts, warm=prefix + [1], relay=False,
                     min_group=1)
    got, eng = _serve(cfg, params, prompts, warm=prefix + [1], relay=True,
                      min_group=1)
    assert eng.relay_steps > 0
    assert got == base


@pytest.mark.slow
def test_relay_group_size_eight():
    cfg = _cfg(MHA_ARCH)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    prefix, prompts = _shared_prompts(8)
    base, _ = _serve(cfg, params, prompts, warm=prefix + [1], relay=False,
                     slots=8, max_new=6)
    got, eng = _serve(cfg, params, prompts, warm=prefix + [1], relay=True,
                      slots=8, max_new=6)
    assert eng.relay_steps > 0
    # at least one step grouped every slot at once
    assert eng.relay_grouped_slots >= 8
    assert got == base


@pytest.mark.slow
def test_relay_midstream_eviction_dissolves_group():
    """Forced eviction of the grouped node mid-stream: the group stops
    forming (``node.evicted`` guards formation; the resident view is
    dropped) and the remaining tokens still match the per-request path —
    the slots' own block tables never depended on the resident copy."""
    cfg = _cfg(MHA_ARCH)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    prefix, prompts = _shared_prompts(2)
    base, _ = _serve(cfg, params, prompts, warm=prefix + [1], relay=False)
    eng = _engine(cfg, params, relay=True)
    eng.submit(prefix + [1], max_new_tokens=4, uid=0)
    eng.run()
    for j, p in enumerate(prompts):
        eng.submit(p, max_new_tokens=8, uid=j + 1)
    while eng.relay_steps == 0 and eng.has_work():
        eng.step()
    assert eng.relay_steps > 0
    for locked in eng._slot_locked:         # evict the chain mid-group
        for node in locked:
            if isinstance(node, BlockNode):
                node.evicted = True
                node.resident = None
    frozen = eng.relay_steps
    eng.run()
    assert eng.relay_steps == frozen        # no group ever reformed
    done = {r.uid: r for r in eng.done}
    assert [done[j + 1].generated
            for j in range(len(prompts))] == base


@pytest.mark.slow
def test_relay_divergent_slot_left_out_of_group():
    """COW-style divergence: a third request shares only the first block
    (it diverged inside block 2, so admission gave it fresh pages). The
    deepest-shared-node rule groups the two full-chain slots; the
    divergent slot decodes ungrouped. All tokens match the per-request
    path."""
    cfg = _cfg(MHA_ARCH)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    prefix, prompts = _shared_prompts(2)
    div = list(prefix)
    div[PS + 3] ^= 1                        # diverge inside block 2
    prompts = prompts + [div + [9, 9]]
    base, _ = _serve(cfg, params, prompts, warm=prefix + [1], relay=False,
                     slots=3)
    got, eng = _serve(cfg, params, prompts, warm=prefix + [1], relay=True,
                      slots=3)
    assert eng.relay_steps > 0
    # every relay step grouped exactly the two full-chain slots
    assert eng.relay_grouped_slots == 2 * eng.relay_steps
    assert got == base


# ------------------------------------------------------------ jaxpr shape
def _iter_eqns(jaxpr):
    todo = [jaxpr]
    while todo:
        j = todo.pop()
        for eqn in j.eqns:
            yield eqn
            for p in eqn.params.values():
                vals = p if isinstance(p, (list, tuple)) else [p]
                for sub in vals:
                    inner = getattr(sub, "jaxpr", None)
                    if inner is not None:
                        todo.append(inner)
                    elif hasattr(sub, "eqns"):
                        todo.append(sub)


@pytest.mark.slow
def test_relay_jaxpr_prefix_pass_once_per_group():
    """The traced relay step launches the prefix kernel ONCE per layer
    over the group batch — its (G, Nmax*R) state output appears exactly
    n_layers times, independent of how many slots the group holds."""
    cfg = _cfg(MHA_ARCH)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    prefix, prompts = _shared_prompts(2)
    eng = _engine(cfg, params, relay=True)
    eng.submit(prefix + [1], max_new_tokens=4, uid=0)
    eng.run()
    captured = {}
    orig = eng._relay_step

    def spy(p, inputs, state, ctx, relay):
        captured.setdefault("a", (inputs, ctx, relay))
        return orig(p, inputs, state, ctx, relay)

    eng._relay_step = spy
    for j, p in enumerate(prompts):
        eng.submit(p, max_new_tokens=6, uid=j + 1)
    eng.run()
    inputs, ctx, relay = captured["a"]
    g, nr = relay["k_row"].shape[1:]
    nmax = int(relay["members"].shape[1])
    assert nmax == 2                        # both slots grouped
    jaxpr = jax.make_jaxpr(orig)(eng.params, inputs, eng._dev_state,
                                 ctx, relay)
    eqns = [e for e in _iter_eqns(jaxpr.jaxpr)
            if e.primitive.name == "pallas_call"]
    # the GROUP-batched prefix state (G, Nmax*R) is produced once in the
    # layer scan body (or n_layers times if unrolled) — never scaled by
    # the member count
    hits = [e for e in eqns
            if any(tuple(v.aval.shape) == (g, nr) for v in e.outvars)]
    assert 1 <= len(hits) <= cfg.n_layers
    # a per-slot formulation would emit (G, R) prefix states per member;
    # no such kernel exists in the trace
    assert not any(tuple(v.aval.shape) == (g, nr // nmax)
                   for e in eqns for v in e.outvars)


# ------------------------------------- mixed-batch sampling lane skipping
@pytest.mark.slow
def test_mixed_batch_greedy_skips_sampling_lane():
    """Satellite: with greedy slots in the batch, the sampler runs on a
    gathered sub-batch of only the sampling rows; the sampling request's
    tokens are identical to the full-lane run (per-row draws depend only
    on that row's logits/params/seed/count)."""
    cfg = _cfg(MHA_ARCH)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    r = np.random.default_rng(3)
    p_s = r.integers(0, 64, size=8).tolist()
    p_g = r.integers(0, 64, size=9).tolist()
    samp = SamplingParams(temperature=0.8, top_k=8, seed=5)

    def serve(second_sampling):
        eng = ServingEngine(cfg, params,
                            EngineConfig(batch_slots=2, max_seq=64,
                                         page_size=PS))
        sizes = []
        orig = eng._sampler
        eng._sampler = lambda lg, *a: (sizes.append(int(lg.shape[0]))
                                       or orig(lg, *a))
        eng.submit(p_s, max_new_tokens=6, uid=0, sampling=samp)
        kw = ({"sampling": SamplingParams(temperature=1.2, seed=11)}
              if second_sampling else {})
        eng.submit(p_g, max_new_tokens=6, uid=1, **kw)
        done = {q.uid: q for q in eng.run()}
        return done[0].generated, sizes

    mixed_toks, mixed_sizes = serve(False)
    full_toks, full_sizes = serve(True)
    assert mixed_toks == full_toks          # sub-batch is draw-preserving
    assert mixed_sizes and set(mixed_sizes) == {1}   # greedy row skipped
    # the full lane must at some step batch BOTH sampling rows through the
    # sampler; size-1 steps around it are legitimate (staggered admission,
    # early retirement sub-batches the survivor)
    assert max(full_sizes) == 2
