"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode).

Every Pallas kernel is exercised across sequence lengths, head counts,
GQA ratios, windows, tile sizes, and dtypes, asserting allclose against
ref.py. interpret=True executes the kernel body in Python on CPU.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import chai_attention as ck
from repro.kernels import flash_attention as fk
from repro.kernels import ops, ref

TOL = dict(rtol=2e-3, atol=2e-3)
# bf16-valued outputs carry ~2^-8 quantization; oracles compute in f32.
TOL_BF16 = dict(rtol=2e-2, atol=2e-2)


def _tol(dtype):
    return TOL_BF16 if dtype == jnp.bfloat16 else TOL


def _mk(rng, shape, dtype):
    x = rng.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)


# --------------------------------------------------------------- decode ----
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,kv,s,hd,ts,window", [
    (1, 4, 4, 32, 16, 8, 0),
    (2, 8, 2, 64, 32, 16, 0),       # GQA 4:1
    (3, 6, 1, 48, 8, 16, 0),        # MQA
    (2, 4, 4, 64, 32, 64, 0),       # single tile
    (2, 8, 4, 64, 16, 16, 24),      # sliding window
])
def test_flash_decode_sweep(rng, dtype, b, h, kv, s, hd, ts, window):
    q = _mk(rng, (b, h, hd), dtype)
    kc = _mk(rng, (b, kv, s, hd), dtype)
    vc = _mk(rng, (b, kv, s, hd), dtype)
    pos = jnp.asarray(rng.integers(1, s, size=b), jnp.int32)
    out = fk.flash_decode(q, kc, vc, pos, window=window, ts=ts,
                          interpret=True)
    want = ref.flash_decode_ref(q, kc, vc, pos, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,t,h,kv,hd,tq,ts,window,offset", [
    (1, 16, 4, 4, 16, 8, 8, 0, 0),
    (2, 32, 8, 2, 32, 8, 16, 0, 0),
    (1, 16, 4, 1, 16, 16, 16, 0, 0),
    (2, 16, 4, 4, 16, 8, 8, 12, 0),    # windowed
    (1, 8, 4, 4, 16, 8, 8, 0, 8),      # offset continuation (prefill chunk)
])
def test_flash_prefill_sweep(rng, dtype, b, t, h, kv, hd, tq, ts, window,
                             offset):
    q = _mk(rng, (b, t, h, hd), dtype)
    s = t + offset
    k = _mk(rng, (b, s, kv, hd), dtype)
    v = _mk(rng, (b, s, kv, hd), dtype)
    out = fk.flash_prefill(q, k, v, offset=offset, window=window, tq=tq,
                           ts=ts, interpret=True)
    want = ref.flash_prefill_ref(q, k, v, offset=offset, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


# ----------------------------------------------------------------- CHAI ----
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,r,s,hd,ts", [
    (1, 8, 3, 32, 16, 8),
    (2, 16, 5, 64, 32, 16),
    (2, 4, 4, 32, 16, 32),    # k == H (degenerate: no clustering)
    (3, 8, 1, 24, 8, 8),      # single cluster
])
def test_chai_decode_mha_sweep(rng, dtype, b, h, r, s, hd, ts):
    """MHA regime: clustered K cache has R rows; V cache has all H rows."""
    q_rep = _mk(rng, (b, r, hd), dtype)
    kc = _mk(rng, (b, r, s, hd), dtype)
    vc = _mk(rng, (b, h, s, hd), dtype)
    h2c = jnp.asarray(rng.integers(0, r, size=(b, h)), jnp.int32)
    pos = jnp.asarray(rng.integers(1, s, size=b), jnp.int32)
    sc = ck.chai_qk(q_rep, kc, pos, ts=ts, interpret=True)
    a = ck.row_softmax(sc, interpret=True)
    out = ck.chai_av(a, vc, h2c, ts=ts, interpret=True)
    want = ref.chai_decode_ref(q_rep, kc, vc, h2c, pos)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("b,kv,rpg,s,hd,ts", [
    (2, 4, 2, 32, 16, 8),     # GQA: 4 groups x 2 reps each
    (1, 2, 3, 64, 32, 16),
])
def test_chai_qk_gqa_groups(rng, b, kv, rpg, s, hd, ts):
    """GQA regime: rep j reads K of group j // reps_per_group."""
    r_total = kv * rpg
    q_rep = _mk(rng, (b, r_total, hd), jnp.float32)
    kc = _mk(rng, (b, kv, s, hd), jnp.float32)
    pos = jnp.asarray(rng.integers(1, s, size=b), jnp.int32)
    sc = ck.chai_qk(q_rep, kc, pos, reps_per_group=rpg, ts=ts,
                    interpret=True)
    a = ck.row_softmax(sc, interpret=True)
    want = ref.chai_scores_ref(q_rep, kc, pos, reps_per_group=rpg)
    np.testing.assert_allclose(np.asarray(a), np.asarray(want), **TOL)


def test_chai_av_shared_membership(rng):
    """h2c may be (H,) — broadcast across batch."""
    b, h, r, s, hd = 2, 8, 3, 32, 16
    a = jnp.asarray(rng.random((b, r, s)), jnp.float32)
    vc = _mk(rng, (b, h, s, hd), jnp.float32)
    h2c = jnp.asarray(rng.integers(0, r, size=h), jnp.int32)
    out = ops.chai_decode_attention  # noqa: F841  (public API import check)
    got = ck.chai_av(a, vc, h2c, ts=8, interpret=True)
    want = ref.chai_av_ref(a, vc, h2c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_fused_op_matches_ref(rng):
    b, h, r, s, hd = 2, 8, 4, 64, 32
    q_rep = _mk(rng, (b, r, hd), jnp.float32)
    kc = _mk(rng, (b, r, s, hd), jnp.float32)
    vc = _mk(rng, (b, h, s, hd), jnp.float32)
    h2c = jnp.asarray(rng.integers(0, r, size=(b, h)), jnp.int32)
    pos = jnp.asarray([13, 60], jnp.int32)
    got = ops.chai_decode_attention(q_rep, kc, vc, h2c, pos, ts=16,
                                    interpret=True)
    want = ref.chai_decode_ref(q_rep, kc, vc, h2c, pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_decode_masks_future_positions(rng):
    """pos masking: entries beyond pos must not affect the output."""
    b, h, s, hd = 1, 4, 32, 16
    q = _mk(rng, (b, h, hd), jnp.float32)
    kc = _mk(rng, (b, h, s, hd), jnp.float32)
    vc = _mk(rng, (b, h, s, hd), jnp.float32)
    pos = jnp.asarray([10], jnp.int32)
    out1 = fk.flash_decode(q, kc, vc, pos, ts=8, interpret=True)
    kc2 = kc.at[:, :, 11:].set(999.0)
    vc2 = vc.at[:, :, 11:].set(-999.0)
    out2 = fk.flash_decode(q, kc2, vc2, pos, ts=8, interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("b,kv,rpg,s,hd,ts", [
    (2, 4, 1, 32, 16, 8),      # MHA clustered cache (KV == R)
    (1, 2, 3, 64, 32, 16),     # GQA groups
])
def test_chai_qk_i8_fused_dequant(rng, b, kv, rpg, s, hd, ts):
    """Fused int8-dequant scores kernel vs dequant-then-ref oracle."""
    from repro.core.cache import quant_rows
    r_total = kv * rpg
    q_rep = _mk(rng, (b, r_total, hd), jnp.float32)
    kf = _mk(rng, (b, kv, s, hd), jnp.float32)
    kq, ks = quant_rows(kf)
    pos = jnp.asarray(rng.integers(1, s, size=b), jnp.int32)
    sc = ck.chai_qk_i8(q_rep, kq, ks, pos, reps_per_group=rpg, ts=ts,
                       interpret=True)
    a = ck.row_softmax(sc, interpret=True)
    want = ref.chai_scores_i8_ref(q_rep, kq, ks, pos, reps_per_group=rpg)
    np.testing.assert_allclose(np.asarray(a), np.asarray(want), **TOL)
