"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST set the device-count flag before any jax-touching import (jax locks the
device count on first backend init) — hence the first two lines.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --mesh single --out benchmarks/results
  PYTHONPATH=src python -m repro.launch.dryrun --mesh multi --arch gemma2-9b
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs.base import SHAPES, get_config, list_configs  # noqa: E402
from repro.core import cache as chai_cache                   # noqa: E402
from repro.core import clustering                            # noqa: E402
from repro.launch import inputs as inp                       # noqa: E402
from repro.launch import roofline as rl                      # noqa: E402
from repro.launch import steps as steps_mod                  # noqa: E402
from repro.launch.mesh import make_production_mesh           # noqa: E402
from repro.models import transformer as tfm                  # noqa: E402
from repro.optim import adamw                                # noqa: E402
from repro.sharding import rules                             # noqa: E402

# Archs whose every layer is full (unwindowed) attention: long_500k skipped
# per assignment (sub-quadratic required) — see DESIGN.md §5.
FULL_ATTENTION_ONLY = {"nemotron-4-15b", "qwen3-moe-30b-a3b",
                       "deepseek-moe-16b", "musicgen-large", "internvl2-76b",
                       "chai-llama-7b"}

ASSIGNED = [a for a in list_configs() if a != "chai-llama-7b"]


def eligible_shapes(arch):
    out = []
    for s in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
        if s == "long_500k" and arch in FULL_ATTENTION_ONLY:
            continue
        out.append(s)
    return out


def shardings(mesh, shapes_tree, logical_tree):
    return rules.tree_shardings(shapes_tree, logical_tree, mesh)


def _sh(mesh, *names):
    return NamedSharding(mesh, P(*names))


def lower_cell(arch, shape_name, mesh, step_kind, *, unroll=False,
               moe_impl=None, use_ctx=False):
    """step_kind: train | prefill | decode_mha | decode_chai.

    ``unroll``: unroll the layer scan so cost_analysis counts every layer
    (XLA counts a while body once — §Roofline methodology). Same math,
    bigger HLO; used for the roofline table. Returns (record dict)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    pshapes, plog = tfm.param_structs(cfg)
    psh = shardings(mesh, pshapes, plog)
    repl = NamedSharding(mesh, P())
    t0 = time.time()

    import contextlib
    if moe_impl == "ep" or use_ctx:
        from repro.sharding.context import sharding_ctx
        batch_axes = tuple(a for a in ("pod", "data")
                           if a in mesh.axis_names)
        cm = sharding_ctx(mesh, batch_axes=batch_axes, model_axis="model")
    else:
        cm = contextlib.nullcontext()
    with cm:
        lowered = _lower(cfg, shape, mesh, step_kind, pshapes, plog, psh,
                         repl, unroll=unroll, moe_impl=moe_impl)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    return _record(cfg, arch, shape_name, shape, mesh, step_kind, unroll,
                   compiled, t_lower, t_compile)


def _lower(cfg, shape, mesh, step_kind, pshapes, plog, psh, repl, *, unroll,
           moe_impl):
    if step_kind.startswith("train"):
        oshapes, olog = adamw.state_structs(pshapes, plog)
        if "zero" in step_kind:   # ZeRO-1: moments data-sharded
            osh = adamw.AdamWState(
                step=NamedSharding(mesh, P()),
                m=rules.zero_shardings(oshapes.m, olog.m, mesh),
                v=rules.zero_shardings(oshapes.v, olog.v, mesh))
            step_kind_base = step_kind.replace("_zero", "")
        else:
            osh = shardings(mesh, oshapes, olog)
        bshapes, blog = inp.train_input_specs(cfg, shape)
        bsh = shardings(mesh, bshapes, blog)
        kw = dict(moe_impl=moe_impl) if moe_impl else {}
        sk = step_kind.replace("_zero", "")
        if "zero" in step_kind:
            kw["grad_shardings"] = rules.zero_shardings(pshapes, plog, mesh)
        if "bf16g" in sk:
            kw["grad_dtype"] = "bfloat16"
            sk = sk.replace("_bf16g", "")
        if sk.startswith("train_micro"):
            from repro.train.train_step import make_microbatched_train_step
            n_micro = int(sk.rsplit("_", 1)[-1]) if sk[-1].isdigit() else 4
            fn = make_microbatched_train_step(cfg, n_micro=n_micro,
                                              unroll=unroll, **kw)
        else:
            fn = steps_mod.make_train_step(cfg, unroll=unroll, **kw)
        metrics_sh = {k: repl for k in
                      ("loss", "ce", "load_balance", "router_z",
                       "grad_norm", "lr")}
        jfn = jax.jit(fn, in_shardings=(psh, osh, bsh),
                      out_shardings=(psh, osh, metrics_sh),
                      donate_argnums=(0, 1))
        lowered = jfn.lower(pshapes, oshapes, bshapes)
    elif step_kind == "prefill":
        bshapes, blog = inp.prefill_input_specs(cfg, shape)
        bsh = shardings(mesh, bshapes, blog)
        sshapes, slog = tfm.decode_state_structs(cfg, shape.global_batch,
                                                 shape.seq_len)
        ssh = shardings(mesh, sshapes, slog)
        kw = dict(moe_impl=moe_impl) if moe_impl else {}
        fn = steps_mod.make_serve_prefill(cfg, shape.global_batch,
                                          shape.seq_len, unroll=unroll, **kw)
        logits_sh = rules.sharding_for((shape.global_batch, cfg.vocab_size),
                                       ("batch", "vocab"), mesh)
        jfn = jax.jit(fn, in_shardings=(psh, bsh),
                      out_shardings=(logits_sh, ssh))
        lowered = jfn.lower(pshapes, bshapes)
    elif step_kind.startswith(("decode_mha", "decode_chai")):
        if "i8kv" in step_kind:   # int8 KV cache (§Perf cell 3)
            cfg = cfg.replace(kv_cache_dtype="int8")
        chai = step_kind.startswith("decode_chai")
        bshapes, blog = inp.decode_token_specs(cfg, shape)
        bsh = shardings(mesh, bshapes, blog)
        if chai:
            sshapes, slog = chai_cache.chai_state_structs(
                cfg, shape.global_batch, shape.seq_len)
            cshapes, clog = clustering.ctx_structs(cfg, batch=0)
            csh = shardings(mesh, cshapes, clog)
        else:
            sshapes, slog = tfm.decode_state_structs(
                cfg, shape.global_batch, shape.seq_len)
        ssh = shardings(mesh, sshapes, slog)
        fn = steps_mod.make_serve_step(cfg, chai=chai, unroll=unroll)
        logits_sh = rules.sharding_for((shape.global_batch, cfg.vocab_size),
                                       ("batch", "vocab"), mesh)
        if chai:
            jfn = jax.jit(fn, in_shardings=(psh, bsh, ssh, csh),
                          out_shardings=(logits_sh, ssh),
                          donate_argnums=(2,))
            lowered = jfn.lower(pshapes, bshapes, sshapes, cshapes)
        else:
            jfn = jax.jit(fn, in_shardings=(psh, bsh, ssh),
                          out_shardings=(logits_sh, ssh),
                          donate_argnums=(2,))
            lowered = jfn.lower(pshapes, bshapes, sshapes)
    else:
        raise ValueError(step_kind)
    return lowered


def _record(cfg, arch, shape_name, shape, mesh, step_kind, unroll,
            compiled, t_lower, t_compile):
    mem = compiled.memory_analysis()
    roof = rl.analyze(compiled)
    mf = rl.model_flops(cfg, shape)
    n_dev = mesh.size
    rec = {
        "arch": arch, "shape": shape_name, "step": step_kind,
        "unroll": unroll,
        "mesh": "x".join(str(s) for s in mesh.shape.values()),
        "n_devices": n_dev,
        "t_lower_s": round(t_lower, 2), "t_compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_est_bytes": (mem.argument_size_in_bytes
                               + mem.output_size_in_bytes
                               + mem.temp_size_in_bytes
                               - mem.alias_size_in_bytes),
        },
        "roofline": roof.as_dict(),
        "model_flops_total": mf,
        "model_flops_per_dev": mf / n_dev,
        "useful_flop_ratio": (mf / n_dev) / max(roof.flops_per_dev, 1.0),
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=("single", "multi"), default="single")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--steps", default="auto",
                    help="auto | comma list of train,prefill,decode_mha,"
                         "decode_chai")
    ap.add_argument("--out", default="benchmarks/results")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--ctx", action="store_true",
                    help="activate the sharding context: model-code "
                         "with_sharding_constraint pins become live "
                         "(perf iterations)")
    ap.add_argument("--moe", default="",
                    help="MoE impl override: ep = expert-parallel "
                         "shard_map all-to-all (perf iteration)")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll the layer scan: exact cost_analysis "
                         "(roofline table); scanned lowering stays the "
                         "compile-time/SPMD proof")
    ap.add_argument("--include-llama", action="store_true",
                    help="also run the paper's chai-llama-7b config")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.mesh == "multi")
    os.makedirs(args.out, exist_ok=True)
    suffix = "_unrolled" if args.unroll else ""
    if args.moe:
        suffix += f"_moe_{args.moe}"
    if args.ctx:
        suffix += "_ctx"
    path = os.path.join(args.out, f"dryrun_{args.mesh}{suffix}.json")
    results = {}
    if os.path.exists(path):
        with open(path) as f:
            results = json.load(f)

    archs = (ASSIGNED + (["chai-llama-7b"] if args.include_llama else [])
             if args.arch == "all" else args.arch.split(","))
    for arch in archs:
        cfg = get_config(arch)
        shapes = (eligible_shapes(arch) if args.shape == "all"
                  else args.shape.split(","))
        for shape_name in shapes:
            if args.steps == "auto":
                kind = SHAPES[shape_name].kind
                if kind == "train":
                    step_kinds = ["train"]
                elif kind == "prefill":
                    step_kinds = ["prefill"]
                else:
                    step_kinds = ["decode_mha"]
                    if cfg.chai.enabled:
                        step_kinds.append("decode_chai")
            else:
                step_kinds = args.steps.split(",")
            for sk in step_kinds:
                key = f"{arch}/{shape_name}/{sk}"
                if key in results and not args.force:
                    print(f"[skip] {key}")
                    continue
                print(f"[lower+compile] {key} on {args.mesh} ...",
                      flush=True)
                try:
                    rec = lower_cell(arch, shape_name, mesh, sk,
                                     unroll=args.unroll,
                                     moe_impl=args.moe or None,
                                     use_ctx=args.ctx)
                    results[key] = rec
                    r = rec["roofline"]
                    print(f"  ok: compile={rec['t_compile_s']}s "
                          f"flops/dev={r['flops_per_dev']:.3e} "
                          f"bytes/dev={r['bytes_per_dev']:.3e} "
                          f"coll/dev={r['coll_bytes_per_dev']:.3e} "
                          f"bottleneck={r['bottleneck']} "
                          f"peak={rec['memory']['peak_est_bytes']/2**30:.2f}"
                          "GiB", flush=True)
                except Exception as e:  # record failures — they are bugs
                    results[key] = {"arch": arch, "shape": shape_name,
                                    "step": sk, "error": str(e)[:2000],
                                    "traceback":
                                        traceback.format_exc()[-4000:]}
                    print(f"  FAILED: {e}", flush=True)
                with open(path, "w") as f:
                    json.dump(results, f, indent=1)
                jax.clear_caches()
    n_ok = sum(1 for v in results.values() if "error" not in v)
    n_bad = sum(1 for v in results.values() if "error" in v)
    print(f"done: {n_ok} ok, {n_bad} failed -> {path}")
    return 1 if n_bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
