"""Paper Fig 11: K,V-cache memory, MHA vs CHAI, across sequence lengths.

Exact analytic bytes for the full LLaMA-7B config (the paper's model) and
for every assigned MHA-regime arch. The paper's 21.4% saving comes from
dropping non-representative K rows; V is kept (Table 4)."""
from __future__ import annotations

from benchmarks.common import save_result
from repro.configs.base import get_config, list_configs
from repro.core.cache import kv_cache_bytes


def run():
    seqs = [256, 512, 1024, 2048, 4096]
    per_arch = {}
    for arch in list_configs():
        cfg = get_config(arch)
        if cfg.n_attn_layers == 0 or not cfg.is_mha:
            continue                      # GQA/SSM: no K-cache saving
        rows = {}
        for s in seqs:
            full = kv_cache_bytes(cfg, 1, s, chai=False)
            ch = kv_cache_bytes(cfg, 1, s, chai=True)
            rows[str(s)] = {"mha_bytes": full, "chai_bytes": ch,
                            "saving_frac": 1 - ch / full}
        per_arch[arch] = rows

    llama = per_arch["chai-llama-7b"]["2048"]
    result = {
        "note": "exact analytic bytes; MHA-regime archs only (GQA archs "
                "get compute-only wins, DESIGN.md §4)",
        "per_arch": per_arch,
        "paper_claim": "LLaMA-7B seq 2048: ~1.2 GB KV cache, up to 21.4% "
                       "saving",
        "claim_check": {
            "llama_kv_GB_at_2048": llama["mha_bytes"] / 2**30,
            "llama_saving_frac": llama["saving_frac"],
            "saving_in_paper_range": 0.10 <= llama["saving_frac"] <= 0.30,
            "kv_close_to_1.2GB": 0.8 <= llama["mha_bytes"] / 2**30 <= 1.6,
        },
    }
    save_result("bench_kv_memory", result)
    return result


if __name__ == "__main__":
    print(run())
