"""Paper Fig 12: time-to-first-token and time-to-next-token, MHA vs CHAI.

Two measurements:
  1. **CPU wall time** on the trained tiny model through the serving
     engine (real phase machine, real clustering overhead in TTFT).
  2. **Analytic TPU v5e model** for the full LLaMA-7B config: decode
     attention is HBM-bandwidth-bound, so TTNT speedup ≈ KV-bytes-read
     ratio; prefill is compute-bound, so TTFT speedup ≈ score-FLOP ratio.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import save_result, tiny_trained
from repro.configs.base import get_config
from repro.core.cache import kv_cache_bytes
from repro.kernels.ops import decode_flop_estimate
from repro.launch.roofline import HBM_BW, PEAK_FLOPS
from repro.serving.engine import EngineConfig, ServingEngine


def _engine_times(cfg, params, pipe, use_chai, n_req=4, max_new=12):
    eng = ServingEngine(cfg, params,
                        EngineConfig(batch_slots=2, max_seq=128,
                                     use_chai=use_chai))
    for i in range(n_req):
        eng.submit(pipe.batch(900 + i)["tokens"][0, :24],
                   max_new_tokens=max_new, uid=i)
    t0 = time.time()
    done = eng.run()
    wall = time.time() - t0
    ttft = float(np.mean([r.ttft for r in done]))
    per_tok = (wall - ttft * (n_req / eng.ecfg.batch_slots)) / (
        n_req * max_new)
    return {"wall_s": wall, "ttft_s": ttft, "per_token_s": per_tok}


def _analytic_full(seqs=(256, 512, 1024, 2048)):
    cfg = get_config("chai-llama-7b")
    h, hd = cfg.n_heads, cfg.head_dim
    counts = cfg.chai_cluster_counts()
    out = {}
    for s in seqs:
        # TTNT: decode is memory-bound -> bytes of KV read per token
        mha_bytes = kv_cache_bytes(cfg, 1, s, chai=False)
        chai_bytes = kv_cache_bytes(cfg, 1, s, chai=True)
        # TTFT: prefill is compute-bound -> attention score flops
        mha_fl = sum(decode_flop_estimate(1, h, h, s, hd)
                     for _ in counts) * s
        chai_fl = sum(decode_flop_estimate(1, h, k, s, hd)
                      for k in counts) * s
        out[str(s)] = {
            "ttnt_speedup_bound": mha_bytes / chai_bytes,
            "ttft_attention_speedup_bound": mha_fl / chai_fl,
            "ttnt_mha_s_v5e": mha_bytes / HBM_BW,
            "ttnt_chai_s_v5e": chai_bytes / HBM_BW,
        }
    return out


def run():
    cfg, params, pipe, _ = tiny_trained()
    cfg_chai = cfg.with_chai(enabled=True,
                             cluster_counts=(5,) * cfg.n_attn_layers)
    cpu_mha = _engine_times(cfg, params, pipe, use_chai=False)
    cpu_chai = _engine_times(cfg_chai, params, pipe, use_chai=True)

    result = {
        "proxy_note": "CPU wall time on tiny model (engine incl. "
                      "clustering overhead) + analytic v5e model for "
                      "LLaMA-7B (paper Fig 12 ran V100s)",
        "cpu_tiny": {"mha": cpu_mha, "chai": cpu_chai,
                     "per_token_speedup":
                         cpu_mha["per_token_s"] / cpu_chai["per_token_s"]},
        "analytic_llama7b_v5e": _analytic_full(),
        "paper_claim": "TTFT up to 1.73x, TTNT up to 5x at seq 2048",
        "claim_check": {
            "ttnt_bound_exceeds_1": _analytic_full()["2048"]
                ["ttnt_speedup_bound"] > 1.0,
            "ttft_attn_bound_exceeds_1": _analytic_full()["2048"]
                ["ttft_attention_speedup_bound"] > 1.0,
        },
    }
    save_result("bench_latency", result)
    return result


if __name__ == "__main__":
    print(run())
