"""Deterministic synthetic token pipeline (offline container: no C4).

Produces an endless stream of next-token-predictable sequences from a
mixture of Zipfian n-gram Markov streams. Three properties matter:

  * **Deterministic & stateless-seeded**: batch ``i`` is a pure function of
    ``(seed, i)`` — a restarted trainer resumes mid-epoch from the step
    counter alone (no iterator state in checkpoints).
  * **Shard-aware**: each host materializes only its slice of the global
    batch (``host_slice``); `jax.make_array_from_process_local_data` turns
    slices into a sharded global batch on real multi-host fleets.
  * **Learnable**: Markov structure (per-stream transition tables with
    Zipfian fan-out) gives a tiny model a loss floor well below uniform —
    the convergence tests assert on that gap.

The calibration corpus for CHAI's offline phase (elbow analysis) reuses the
same generator with a dedicated seed, standing in for the paper's 1024 C4
samples (DESIGN.md §3 "assumptions changed").
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_streams: int = 8          # distinct Markov streams in the mixture
    branch: int = 4             # out-degree per state (Zipf-weighted)
    zipf_a: float = 1.4


class SyntheticPipeline:
    """batch(i) -> {"tokens": (B, T) int32, "labels": (B, T) int32}."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        root = np.random.default_rng(np.random.SeedSequence([cfg.seed, 0xC4]))
        v = cfg.vocab_size
        # Per-stream transition tables: state -> `branch` candidate tokens,
        # sampled Zipfian so streams share a head vocabulary but differ in
        # structure. Tables are O(n_streams * V * branch) int32 — tiny.
        self.tables = np.empty((cfg.n_streams, v, cfg.branch), np.int32)
        ranks = np.arange(1, v + 1, dtype=np.float64)
        zipf = 1.0 / ranks ** cfg.zipf_a
        zipf /= zipf.sum()
        for s in range(cfg.n_streams):
            rng = np.random.default_rng(root.integers(2**63))
            perm = rng.permutation(v)          # stream-specific token ranks
            probs = zipf[np.argsort(perm)]
            self.tables[s] = rng.choice(v, size=(v, cfg.branch), p=probs)

    # -- core generator ----------------------------------------------------
    def _gen_rows(self, rng: np.random.Generator, rows: int) -> np.ndarray:
        c = self.cfg
        toks = np.empty((rows, c.seq_len + 1), np.int32)
        stream = rng.integers(c.n_streams, size=rows)
        state = rng.integers(c.vocab_size, size=rows)
        toks[:, 0] = state
        # branch choice is biased to index 0 (predictable) with noise.
        bias = np.minimum(rng.geometric(0.6, size=(rows, c.seq_len)) - 1,
                          c.branch - 1)
        for t in range(c.seq_len):
            state = self.tables[stream, state, bias[:, t]]
            toks[:, t + 1] = state
        return toks

    def batch(self, index: int, *, host_id: int = 0, n_hosts: int = 1):
        """Host-local slice of global batch ``index`` (numpy)."""
        c = self.cfg
        assert c.global_batch % n_hosts == 0
        rows = c.global_batch // n_hosts
        rng = np.random.default_rng(
            np.random.SeedSequence([c.seed, index, host_id]))
        toks = self._gen_rows(rng, rows)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def global_batch_array(self, index: int, sharding=None):
        """Full global batch as (sharded) jax arrays.

        Single-process containers materialize globally then device_put; on a
        real fleet each process feeds its local slice via
        ``make_array_from_process_local_data``.
        """
        if jax.process_count() > 1 and sharding is not None:
            local = self.batch(index, host_id=jax.process_index(),
                               n_hosts=jax.process_count())
            return {
                k: jax.make_array_from_process_local_data(sharding[k], v)
                for k, v in local.items()}
        host = self.batch(index)
        if sharding is None:
            return {k: jax.numpy.asarray(v) for k, v in host.items()}
        return {k: jax.device_put(v, sharding[k]) for k, v in host.items()}

    def __iter__(self) -> Iterator[dict]:
        i = 0
        while True:
            yield self.batch(i)
            i += 1


def calibration_batches(vocab_size: int, seq_len: int, n_samples: int,
                        batch: int = 8, seed: int = 0xE1B0):
    """Calibration set for CHAI's offline elbow phase (C4 stand-in)."""
    cfg = DataConfig(vocab_size=vocab_size, seq_len=seq_len,
                     global_batch=batch, seed=seed)
    pipe = SyntheticPipeline(cfg)
    for i in range((n_samples + batch - 1) // batch):
        yield pipe.batch(i)["tokens"]
