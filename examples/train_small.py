"""Train a small LM for a few hundred steps with the fault-tolerant loop.

Demonstrates: deterministic data pipeline, AdamW + cosine schedule,
checkpoint/restart (kill and re-run — it resumes), microbatch gradient
accumulation, straggler detection.

  PYTHONPATH=src python examples/train_small.py
  (ctrl-C it mid-run, run it again: resumes from the last checkpoint)
"""
from repro.configs.base import get_config, reduced
from repro.data.pipeline import DataConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    cfg = reduced(get_config("chai-llama-7b"), n_layers=4, d_model=128,
                  n_heads=8, d_ff=256, vocab=512).replace(dtype="float32")
    n = cfg.param_count()
    print(f"model: {cfg.n_layers}L d={cfg.d_model} ({n/1e6:.2f}M params)")

    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=128, global_batch=8)
    tcfg = TrainerConfig(
        total_steps=300, ckpt_every=50, log_every=25,
        ckpt_dir="/tmp/train_small_ckpt",
        n_micro=2,                       # gradient accumulation
        lr_kw=dict(peak=3e-3, warmup=30, total=300))
    trainer = Trainer(cfg, data, tcfg)
    state, metrics = trainer.run()
    print(f"final loss {float(metrics['loss']):.4f} "
          f"(uniform would be {__import__('math').log(512):.2f}); "
          f"stragglers seen: {len(trainer.straggler_steps)}")


if __name__ == "__main__":
    main()
