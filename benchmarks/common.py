"""Shared benchmark harness: tiny trained model + policy fidelity metrics.

CPU container => the paper's GPU wall-clock/accuracy numbers are reproduced
as *proxies* (clearly labeled in every output):
  - accuracy  -> greedy-token agreement + logit fidelity on a trained tiny LM
  - latency   -> CPU wall time for the tiny model + analytic TPU model for
                 the full config (FLOP/byte counts / v5e peaks)
Paper-claim checks (cluster counts, KV savings %, FLOP ratios) are exact —
they depend only on the algorithm, not the hardware.
"""
from __future__ import annotations

import functools
import json
import os
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, reduced
from repro.data.pipeline import DataConfig
from repro.models import transformer as tfm
from repro.train.trainer import Trainer, TrainerConfig

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


@functools.lru_cache()
def tiny_trained(vocab=128, steps=60):
    """Train a small MHA LM once per process; reused by accuracy benches."""
    import tempfile
    cfg = reduced(get_config("chai-llama-7b"), n_layers=2, d_model=64,
                  n_heads=8, d_ff=128, vocab=vocab).replace(dtype="float32")
    data = DataConfig(vocab_size=vocab, seq_len=64, global_batch=8)
    tr = Trainer(cfg, data, TrainerConfig(
        total_steps=steps, ckpt_every=10**9, log_every=10**9,
        ckpt_dir=tempfile.mkdtemp(prefix="bench_ckpt_"),
        lr_kw=dict(peak=3e-3, warmup=6, total=steps)))
    state, metrics = tr.run()
    return cfg, state["params"], tr.pipe, float(metrics["loss"])


def redundant_model():
    """tiny_trained with *planted head redundancy*: heads {0,1,2} and
    {4,5,6} share Q/K per layer (small perturbation), emulating at tiny
    scale the measured LLaMA-7B property the paper exploits (clusters of
    heads with score correlation > 0.95, Fig 2). Effective patterns: 4
    -> the right cluster count is 4 of 8 heads."""
    cfg, params, pipe, loss = tiny_trained()
    params = jax.tree.map(lambda x: x, params)      # copy
    w = dict(params["attn"])
    for nm in ("wq", "wk"):
        m = w[nm]
        eps = 0.02 * jnp.std(m)
        for src, dups in ((0, (1, 2)), (4, (5, 6))):
            for d in dups:
                m = m.at[:, :, d].set(
                    m[:, :, src] * (1.0 + eps * (d - src)))
        w[nm] = m
    params["attn"] = w
    return cfg, params, pipe, loss


def collect_qkv(cfg, params, toks):
    """Per-layer rotary q, k, v activations for the policy benches.

    Returns [(q, k, v)] per attention layer, each (B, T, H, hd)."""
    from repro.models import attention as attn_mod
    from repro.models.layers import rms_norm
    from repro.models.transformer import layer_plan, tree_index

    # Run the model capturing per-layer inputs via a python-level replay:
    # forward once per layer prefix is wasteful; instead re-run the scan
    # manually at python level (n_layers is tiny here).
    plan = layer_plan(cfg)
    h = jnp.take(params["embed"]["tok"], toks, axis=0).astype(jnp.float32)
    positions = jnp.arange(toks.shape[1], dtype=jnp.int32)
    out = []
    from repro.models import mlp as mlp_mod
    for i in range(cfg.n_layers):
        p = tree_index(params["attn"], plan["attn"][i])
        xn = rms_norm(h, p["ln"], cfg.norm_eps)
        q, k, v = attn_mod.project_qkv(xn, p, cfg, positions)
        out.append((q, k, v))
        y = attn_mod.attention_fullseq(q, k, v, positions, positions,
                                       attn_softcap=cfg.attn_logit_softcap)
        h = h + attn_mod.output_proj(y, p)
        pf = tree_index(params["ffn"], plan["dense"][i])
        xn = rms_norm(h, pf["ln"], cfg.norm_eps)
        h = h + mlp_mod.dense_ffn(xn, pf, cfg)
    return out


def save_result(name: str, payload: dict):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def timer(fn, *args, n=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


# -- engine telemetry consumption (serving/telemetry.py JSONL events) ------
# Bench lanes read per-request TTFT / ITL / queue time from the engine's
# lifecycle event log instead of re-deriving them from hand-placed
# wall-clock stamps around the streaming loop.

def load_events(source):
    """Lifecycle events from a telemetry sink, an EngineCore, JSONL text,
    or an already-decoded event list — normalized to a list of dicts."""
    from repro.serving import exporters
    if isinstance(source, str):
        return exporters.read_jsonl(source)
    if hasattr(source, "tel"):          # EngineCore
        source = source.tel
    if hasattr(source, "iter_events"):  # Telemetry sink
        return list(source.iter_events())
    return list(source)


def lifecycle_metrics(source):
    """Per-uid {ttft_s, queue_s, latency_s, itl_s, n_tokens, preemptions,
    finish_reason} derived from lifecycle events (see
    ``repro.serving.telemetry.summarize_timeline``)."""
    from repro.serving.telemetry import summarize_timeline
    by_uid = {}
    for ev in load_events(source):
        by_uid.setdefault(ev["uid"], []).append(ev)
    return {uid: summarize_timeline(sorted(evs, key=lambda e: e["t"]))
            for uid, evs in by_uid.items()}
