"""Paper Fig 12: time-to-first-token and time-to-next-token, MHA vs CHAI.

Five measurements:
  1. **CPU wall time** on the trained tiny model through the serving
     engine (real phase machine, real clustering overhead in TTFT).
  2. **Analytic TPU v5e model** for the full LLaMA-7B config: decode
     attention is HBM-bandwidth-bound, so TTNT speedup ≈ KV-bytes-read
     ratio; prefill is compute-bound, so TTFT speedup ≈ score-FLOP ratio.
  3. **Scheduler comparison**: the same mixed-length (8–128 new tokens)
     Poisson-arrival workload through the continuous and cohort
     schedulers — per-request TTFT and request throughput (continuous
     must sustain strictly higher throughput: no head-of-line blocking).
  4. **Fused kernel lane**: one decode-attention step through the fused
     one-launch kernel vs the retired three-kernel pipeline — kernel
     launches per step (counted by intercepting ``pallas_call``),
     analytic HBM bytes moved, output parity, and measured step latency.
     ``python -m benchmarks.bench_latency --check-fused`` runs only the
     deterministic claims (parity + 3→1 launch count) and exits non-zero
     on regression — CI gates on it.
  5. **Prefix-reuse lane**: Poisson arrivals over a shared system prompt
     through the radix prefix cache — TTFT cold vs warm (CHAI snapshot
     hits enter STEADY directly), allocator pages saved vs a no-sharing
     engine, and zero-leak refcount checks after the pools drain. Its
     ``relay`` sub-lane gates the shared-prefix relay decode: grouped
     token parity with the per-request path, kernel-launch flatness in
     the group size, and the O(prefix) per-step HBM/MXU cost structure.
     ``python -m benchmarks.bench_latency --check`` runs ALL
     deterministic claim checks (fused + relay) and exits non-zero on
     regression — CI gates on it.
  6. **Streaming lane**: one request through ``LLM.stream()`` (greedy
     and seeded sampling) — TTFT plus inter-token latency (ITL) p50/p99
     from per-chunk arrival stamps, and the deterministic claim that the
     first token arrives strictly before the request completes.
  7. **SLO storm lane**: steady Poisson decode traffic interrupted by a
     long-prompt arrival. Monolithic prefill forwards the storm prompt
     inside ONE ``step()`` — every decoding slot's ITL absorbs it;
     ``prefill_chunk_tokens`` page-slices the prompt across steps so
     decode tokens keep flowing. Gated step-domain claims (monolithic
     stalls decode for the whole prompt, chunked interleaves every
     intermediate step) plus the advisory wall-clock reading: chunked
     ITL p99 within 2x the no-storm baseline where monolithic prefill
     violates it (on paper-scale hardware; see the lane docstring for
     why the CPU proxy inverts the ratio).
"""
from __future__ import annotations

import contextlib
import time

import numpy as np

from benchmarks.common import save_result, tiny_trained
from repro.configs.base import get_config
from repro.core.cache import kv_cache_bytes
from repro.kernels.ops import decode_flop_estimate
from repro.launch.roofline import HBM_BW, PEAK_FLOPS
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.workload import poisson_workload


def _engine_times(cfg, params, pipe, use_chai, n_req=4, max_new=12):
    eng = ServingEngine(cfg, params,
                        EngineConfig(batch_slots=2, max_seq=128,
                                     use_chai=use_chai))
    for i in range(n_req):
        eng.submit(pipe.batch(900 + i)["tokens"][0, :24],
                   max_new_tokens=max_new, uid=i)
    t0 = time.time()
    done = eng.run()
    wall = time.time() - t0
    ttft = float(np.mean([r.ttft for r in done]))
    per_tok = (wall - ttft * (n_req / eng.ecfg.batch_slots)) / (
        n_req * max_new)
    return {"wall_s": wall, "ttft_s": ttft, "per_token_s": per_tok}


def _scheduler_compare(cfg, params, pipe, *, n_req=18, slots=6,
                       prompt_len=16, new_tokens=(8, 128), mean_gap_s=0.01,
                       seed=0):
    """Same Poisson workload (exponential inter-arrival gaps, mixed
    8-128 new tokens) through both schedulers.

    Output lengths are long-tailed (most requests short, a minority
    near the cap — the regime continuous batching exists for: under the
    cohort scheduler every short request in a cohort waits for its
    longest member)."""
    rng = np.random.default_rng(seed)
    arrivals, lens = poisson_workload(rng, n_req, mean_gap_s=mean_gap_s,
                                      new_tokens=new_tokens)
    prompts = [pipe.batch(3000 + i)["tokens"][0, :prompt_len]
               for i in range(n_req)]
    out = {}
    lanes = {
        # paged is the engine default: page-budget admission, dense pages
        # freed at compaction
        "continuous": dict(scheduler="continuous", kv_layout="paged"),
        "continuous_dense": dict(scheduler="continuous",
                                 kv_layout="dense"),
        "cohort": dict(scheduler="cohort"),
    }
    for lane, kw in lanes.items():
        eng = ServingEngine(cfg, params,
                            EngineConfig(batch_slots=slots, max_seq=192,
                                         **kw))
        # Two identical passes; the first warms every jit (prefill per
        # prompt length, all phase-mix step variants) so the measured
        # pass reflects steady-state serving, not compile time.
        for timed in (False, True):
            t0 = time.time()
            batch = [eng.submit(prompts[i], max_new_tokens=int(lens[i]),
                                uid=i, arrival_delay=float(arrivals[i]))
                     for i in range(n_req)]
            steps0 = eng.steps_executed
            eng.run()
            wall = time.time() - t0
        ttfts = np.array([r.ttft for r in batch])
        span = max(r.t_done for r in batch) - min(r.t_arrival for r in batch)
        out[lane] = {
            "wall_s": wall,
            "req_per_s": n_req / span,
            "ttft_s_mean": float(ttfts.mean()),
            "ttft_s_p95": float(np.percentile(ttfts, 95)),
            "decode_steps": eng.steps_executed - steps0,
        }
        if eng.paged:
            out[lane]["kv_bytes_peak"] = int(eng.kv_bytes_peak())
            out[lane]["kv_bytes_capacity"] = int(eng.kv_bytes_capacity())
    out["workload"] = {"n_req": n_req, "slots": slots,
                       "new_tokens": list(map(int, lens)),
                       "arrival_span_s": float(arrivals[-1])}
    # Hardware-independent scheduler claims use batched-decode-STEP
    # counts (the repo's throughput proxy — see
    # tests/test_engine_continuous.py): on this CPU container the decode
    # step itself runs the fused kernel in interpret mode (an emulation,
    # ~3x slower than compiled jnp), so wall clock measures the
    # interpreter, not the scheduler. Wall-clock req/s stays reported
    # (and advisory) for trend-watching.
    out["continuous_strictly_fewer_steps"] = bool(
        out["continuous"]["decode_steps"] < out["cohort"]["decode_steps"])
    out["continuous_wall_clock_faster"] = bool(
        out["continuous"]["req_per_s"] > out["cohort"]["req_per_s"])
    out["paged_vs_dense_layout_req_per_s_ratio"] = float(
        out["continuous"]["req_per_s"]
        / out["continuous_dense"]["req_per_s"])
    out["paged_vs_dense_layout_steps_ratio"] = float(
        out["continuous"]["decode_steps"]
        / max(out["continuous_dense"]["decode_steps"], 1))
    return out


@contextlib.contextmanager
def _count_pallas_launches():
    """Count ``pl.pallas_call`` invocations (== kernel launches per
    un-jitted call) by intercepting the module attribute every kernel
    wrapper resolves at call time."""
    from jax.experimental import pallas as pl
    counter = {"n": 0}
    orig = pl.pallas_call

    def counted(*a, **kw):
        counter["n"] += 1
        return orig(*a, **kw)

    pl.pallas_call = counted
    try:
        yield counter
    finally:
        pl.pallas_call = orig


def _time_best(fn, *args, reps=5):
    import jax
    out = fn(*args)                       # compile
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def _fused_kernel_lane(seed=0, timing=True):
    """Fused one-launch decode vs the retired three-kernel pipeline on a
    representative MHA decode shape: launch count, analytic HBM bytes per
    step, allclose parity, and measured per-step wall time (CPU interpret
    mode — the launch/byte counts are the hardware-independent claims;
    the timing is the advisory proxy, skipped when ``timing=False``,
    e.g. by the deterministic ``--check-fused`` CI gate)."""
    import functools
    import jax
    import jax.numpy as jnp
    from repro.kernels import chai_attention as ck
    from repro.kernels import ops, ref

    rng = np.random.default_rng(seed)
    b, h, r, s, hd, ts = 4, 8, 5, 256, 32, 64
    q = jnp.asarray(rng.normal(size=(b, r, hd)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(b, r, s, hd)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(b, h, s, hd)), jnp.float32)
    h2c = jnp.asarray(rng.integers(0, r, size=(b, h)), jnp.int32)
    pos = jnp.asarray(rng.integers(s // 2, s, size=b), jnp.int32)

    with _count_pallas_launches() as fused_n:
        out_fused = ck.chai_fused_decode(q, kc, vc, h2c, pos, ts=ts,
                                         interpret=True)
    with _count_pallas_launches() as pipe_n:
        out_pipe = ref.chai_three_kernel_decode(q, kc, vc, h2c, pos, ts=ts)
    parity = bool(np.allclose(np.asarray(out_fused), np.asarray(out_pipe),
                              rtol=2e-3, atol=2e-3))

    result = {
        "shape": {"b": b, "h": h, "r": r, "s": s, "hd": hd, "ts": ts},
        "launches_per_step": {"fused": fused_n["n"],
                              "three_kernel": pipe_n["n"]},
        "hbm_bytes_per_step_est": {
            "fused": ops.decode_hbm_bytes_estimate(b, h, r, s, hd,
                                                   fused=True),
            "three_kernel": ops.decode_hbm_bytes_estimate(b, h, r, s, hd,
                                                          fused=False),
        },
        "parity_allclose": parity,
        "claims": {
            # deterministic, EMPIRICAL (CI gates on these via
            # --check-fused): launch counts are observed by interception,
            # parity by execution. The HBM-bytes numbers above are
            # analytic model outputs — reported for the roofline story,
            # never gated (both sides come from one formula, so a
            # boolean on them could not fail).
            "fused_single_launch":
                fused_n["n"] == ops.decode_launch_count(fused=True)
                and pipe_n["n"] == ops.decode_launch_count(fused=False),
            "fused_parity": parity,
        },
    }
    if timing:
        fused_jit = jax.jit(functools.partial(ck.chai_fused_decode, ts=ts,
                                              interpret=True))
        pipe_jit = jax.jit(functools.partial(ref.chai_three_kernel_decode,
                                             ts=ts))
        t_fused = _time_best(fused_jit, q, kc, vc, h2c, pos)
        t_pipe = _time_best(pipe_jit, q, kc, vc, h2c, pos)
        result["step_latency_s"] = {"fused": t_fused,
                                    "three_kernel": t_pipe}
        # advisory (wall clock on shared CPU runners is noisy)
        result["claims"]["fused_latency_no_worse"] = \
            t_fused <= t_pipe * 1.25
    return result


def _prefix_reuse_lane(cfg, params, pipe, *, n_warm=4, prompt_len=96,
                       max_new=16, slots=4, mean_gap_s=0.005, seed=0):
    """Shared-prefix KV reuse (radix prefix cache + CHAI snapshots):
    Poisson arrivals over ONE shared system prompt. Wave 1 is cold (it
    seeds the cache); wave 2 mixes exact repeats (CHAI snapshot hits —
    STEADY entry, zero prefill) and shared-prefix-different-suffix
    requests (partial hits — suffix-only prefill). Reports TTFT cold vs
    warm, allocator pages saved vs a no-sharing engine, and leak-freedom
    after the pools drain."""
    from repro.serving.prefix_cache import PrefixCache  # noqa: F401
    rng = np.random.default_rng(seed)
    sys_prompt = np.asarray(pipe.batch(7000)["tokens"][0, :prompt_len])
    other = np.asarray(pipe.batch(7001)["tokens"][0, :prompt_len])

    def tails(base):
        return [np.concatenate([sys_prompt[:prompt_len - 8],
                                np.asarray(pipe.batch(base + i)["tokens"]
                                           [0, :8])])
                for i in range(n_warm // 2)]

    arrivals = np.cumsum(rng.exponential(mean_gap_s, size=n_warm))

    def fresh(prefix_cache):
        return ServingEngine(cfg, params, EngineConfig(
            batch_slots=slots, max_seq=128, page_size=16,
            prefix_cache=prefix_cache))

    out = {}
    for lane, cached in (("prefix_cache", True), ("no_sharing", False)):
        eng = fresh(cached)
        # wave 0: compiles the cold-prefill jits and seeds the cache with
        # an unrelated prompt, so the measured cold request below is
        # jit-warm but cache-cold (a true miss).
        eng.submit(other, max_new_tokens=max_new, uid=0)
        eng.run()
        cold = eng.submit(sys_prompt, max_new_tokens=max_new, uid=1)
        eng.run()                               # measured miss: seeds cache
        # wave 1 (unmeasured): compiles the suffix-prefill / snapshot
        # restore jits on one warm mix
        for i, p in enumerate([sys_prompt] + tails(7200)):
            eng.submit(p, max_new_tokens=max_new, uid=100 + i)
        eng.run()
        # wave 2 (measured): same mix shape — exact repeats hit the CHAI
        # snapshot, fresh tails partially hit the shared prefix. Pages
        # are compared as the wave's allocation DELTA (pages_in_use also
        # counts the cache's own residency, which is the reservation
        # being traded for the sharing).
        uniq = tails(7300)
        in_use0 = (eng.dense_pool.pages_in_use
                   + (eng.chai_pool.pages_in_use if eng.chai_pool else 0))
        hist0 = len(eng.kv_bytes_history)
        warm_reqs = []
        for i in range(n_warm):
            prompt = sys_prompt if i % 2 == 0 else uniq[i // 2]
            warm_reqs.append(eng.submit(
                prompt, max_new_tokens=max_new, uid=200 + i,
                arrival_delay=float(arrivals[i])))
        eng.run()
        warm_hist = eng.kv_bytes_history[hist0:]
        # single warm request on an idle engine: the TTFT comparison is
        # cold-prefill-alone vs snapshot-resume-alone (both jit-warm);
        # the concurrent wave above measures pages/hits, where TTFT
        # would mostly measure admission queueing behind decode steps.
        warm_alone = eng.submit(sys_prompt, max_new_tokens=max_new,
                                uid=300)
        eng.run()
        out[lane] = {
            "ttft_cold_s": cold.ttft,
            "ttft_warm_s": warm_alone.ttft,
            "ttft_warm_wave_s_mean": float(np.mean([r.ttft
                                                    for r in warm_reqs])),
            "warm_wave_pages_allocated": max(
                h["dense_pages"] + h["chai_pages"] for h in warm_hist)
                - in_use0,
            "hits": {r.uid: r.cache_hit for r in warm_reqs},
            "prefill_tokens": sum(max(r.prefill_tokens, 0)
                                  for r in warm_reqs),
        }
        if cached:
            out[lane]["stats"] = eng.prefix_stats()
            eng.prefix_cache.clear()
        out[lane]["pages_leaked"] = (eng.dense_pool.pages_in_use
                                     + (eng.chai_pool.pages_in_use
                                        if eng.chai_pool else 0))
    cachedl, basel = out["prefix_cache"], out["no_sharing"]
    out["pages_saved"] = (basel["warm_wave_pages_allocated"]
                          - cachedl["warm_wave_pages_allocated"])
    out["claims"] = {
        # a fully-cached warm request skips prefill AND warmup/cluster:
        # TTFT must beat the cold request's (deterministic work skipped,
        # but still a wall-clock measure — advisory in CI)
        "warm_ttft_below_cold":
            cachedl["ttft_warm_s"] < cachedl["ttft_cold_s"],
        # >= 2 concurrent shared-prefix requests allocate strictly fewer
        # pages than the no-sharing baseline (deterministic)
        "pages_saved_vs_no_sharing": out["pages_saved"] > 0,
        # refcounts drain to zero after eviction + slot reset
        "no_page_leaks": cachedl["pages_leaked"] == 0
                         and basel["pages_leaked"] == 0,
        # snapshot fast path actually exercised
        "snapshot_hit_observed":
            "snapshot" in cachedl["hits"].values()
            or "replay" in cachedl["hits"].values(),
    }
    return out


def _relay_lane(cfg, params, pipe, *, prefix_blocks=4, max_new=8, seed=0):
    """Shared-prefix relay decode: the system prompt's attention is
    computed ONCE per group of STEADY slots and merged into each slot's
    suffix-only fused decode via online-softmax state.

    Deterministic gated claims (``--check`` runs these in CI):

    * token parity — grouped greedy tokens match the per-request decode
      path exactly;
    * launch flatness — tracing the relay step for a 1-member and a
      2-member group constructs the SAME number of kernel launches (the
      prefix pass is grid-batched over groups, never per slot);
    * O(prefix) cost — per-step prefix HBM bytes take no member count at
      all and double when the prefix doubles, and the MXU pass estimate
      stays flat across group sizes 1/2/8 (member rep rows batch along
      the systolic row axis) while the per-request baseline pays
      N x the single-slot cost.
    """
    import jax
    from repro.kernels import ops
    from repro.launch import steps as steps_mod

    ps = 16
    plen = prefix_blocks * ps
    prefix = np.asarray(pipe.batch(8100)["tokens"][0, :plen])
    tails = [np.concatenate([prefix,
                             np.asarray(pipe.batch(8200 + i)["tokens"]
                                        [0, :4 + i])])
             for i in range(2)]

    def serve(relay, prompts, min_group):
        eng = ServingEngine(cfg, params, EngineConfig(
            batch_slots=2, max_seq=128, page_size=ps, prefix_cache=True,
            relay_decode=relay, relay_min_group=min_group))
        captured = {}
        if relay:
            orig = eng._relay_step

            def spy(p, inputs, state, ctx, rel):
                if "sds" not in captured:   # shapes only, no host copy
                    captured["sds"] = jax.tree.map(
                        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        (inputs, state, ctx, rel))
                return orig(p, inputs, state, ctx, rel)

            eng._relay_step = spy
        eng.submit(prefix, max_new_tokens=max_new, uid=0)   # seed cache
        eng.run()
        for j, p in enumerate(prompts):
            eng.submit(p, max_new_tokens=max_new, uid=j + 1)
        done = {r.uid: r for r in eng.run()}
        toks = [done[j + 1].generated for j in range(len(prompts))]
        return toks, eng, captured.get("sds")

    base, _, _ = serve(False, tails, 2)
    toks, eng2, sds2 = serve(True, tails, 2)          # 2-member group
    _, eng1, sds1 = serve(True, tails[:1], 1)         # 1-member group

    # Launch flatness: trace the relay step over the captured shapes and
    # count ``pallas_call`` equations in the (recursively walked) jaxpr —
    # the compiled step launches exactly what the trace contains. Eqn
    # counting (not ``pallas_call`` interception) because the engine runs
    # above already populated the nested-jit trace caches.
    step_fn = steps_mod.make_relay_step(cfg, decode_ts=ps)
    p_sds = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)

    def trace_launches(sds):
        inputs, state, ctx, rel = sds
        jaxpr = jax.make_jaxpr(step_fn)(p_sds, inputs, state, ctx, rel)
        n, todo = 0, [jaxpr.jaxpr]
        while todo:
            j = todo.pop()
            for eqn in j.eqns:
                n += eqn.primitive.name == "pallas_call"
                for p in eqn.params.values():
                    for sub in (p if isinstance(p, (list, tuple))
                                else [p]):
                        inner = getattr(sub, "jaxpr", None)
                        if inner is not None:
                            todo.append(inner)
                        elif hasattr(sub, "eqns"):
                            todo.append(sub)
        return n

    launches = {"group_of_1": trace_launches(sds1),
                "group_of_2": trace_launches(sds2)}

    # O(prefix) cost model over the ACTUAL resident-view geometry
    k_rows, v_rows = sds2[3]["k"].shape[2], sds2[3]["v"].shape[2]
    hd = sds2[3]["k"].shape[-1]
    r = sds2[3]["k_row"].shape[-1] // sds2[3]["members"].shape[-1]
    int8 = "k_scale" in sds2[3]
    hbm = {s: ops.relay_prefix_hbm_bytes_estimate(
               k_rows, v_rows, s, hd, cache_bytes=1 if int8 else 4,
               int8_scales=int8) for s in (plen, 2 * plen)}
    mxu = {n: ops.relay_prefix_mxu_pass_estimate(n, r, plen, ts=ps)
           for n in (1, 2, 8)}

    out = {
        "prefix_len": plen,
        "relay_steps": eng2.relay_steps,
        "grouped_slots": eng2.relay_grouped_slots,
        "launches_per_trace": launches,
        "prefix_hbm_bytes_per_step": hbm[plen],
        "prefix_hbm_bytes_per_step_2x_prefix": hbm[2 * plen],
        "per_request_baseline_hbm_bytes_n2": 2 * hbm[plen],
        "mxu_passes_by_group_size": mxu,
        "per_request_baseline_mxu_passes_n8": 8 * mxu[1],
        "claims": {
            # grouped greedy tokens == per-request decode path
            "relay_tokens_match_per_request":
                toks == base and eng2.relay_steps > 0
                and eng1.relay_steps > 0,
            # one grid-batched prefix pass per layer, not one per slot
            "relay_launches_flat_in_group_size":
                0 < launches["group_of_1"] == launches["group_of_2"],
            # per-step prefix HBM bytes: member-count-free by
            # construction, linear in the prefix length
            "relay_prefix_hbm_o_prefix":
                hbm[2 * plen] == 2 * hbm[plen],
            # QK passes flat while N*R fits one MXU tile; the
            # per-request baseline pays N x the single-slot passes
            "relay_mxu_passes_flat_in_n":
                mxu[1] == mxu[2] == mxu[8]
                and 8 * mxu[1] > mxu[8],
        },
    }
    return out


def _streaming_lane(cfg, params, pipe, *, prompt_len=16, max_new=24,
                    slots=2):
    """Per-token streaming latency through the ``LLM.stream`` frontend:
    TTFT (enqueue -> first token) and inter-token latency (ITL) p50/p99
    for greedy and seeded sampling, read from the ENGINE's lifecycle
    telemetry (``telemetry="basic"`` events summarized by
    ``common.lifecycle_metrics``) instead of client-side stamps around
    the streaming loop — the engine stamps first-token and per-token
    times at the step that produced them, so the numbers exclude
    frontend queue hand-off. The incremental-delivery claim (first
    token strictly before the last, more than one chunk) is
    deterministic; the latency numbers are wall-clock and advisory on
    shared runners."""
    from benchmarks.common import lifecycle_metrics
    from repro.serving.api import LLM
    from repro.serving.engine import EngineConfig
    from repro.serving.sampling import SamplingParams

    llm = LLM(cfg, params, EngineConfig(batch_slots=slots, max_seq=128,
                                        telemetry="basic"))
    prompt = pipe.batch(8000)["tokens"][0, :prompt_len]
    out = {}
    lanes = {
        "greedy": SamplingParams(max_new_tokens=max_new),
        "sampled": SamplingParams(temperature=0.8, top_k=16, top_p=0.95,
                                  seed=7, max_new_tokens=max_new),
    }
    for sp in lanes.values():       # warm BOTH samplers' jits (the
        llm.generate(prompt, sp)    # batched sampler traces separately)
    uids = {}
    for lane, sp in lanes.items():
        n_chunks, finished, uid = 0, False, None
        for chunk in llm.stream(prompt, sp):
            n_chunks += 1
            finished = chunk.finished
            uid = chunk.uid
        uids[lane] = uid
        out[lane] = {"n_chunks": n_chunks, "finished": finished}
    summaries = lifecycle_metrics(llm.core)
    for lane, uid in uids.items():
        s = summaries[uid]
        itl = np.asarray(s["itl_s"]) if s["itl_s"] else np.zeros(1)
        out[lane].update({
            "n_tokens": s["n_tokens"],
            "ttft_s": s["ttft_s"],
            "queue_s": s.get("queue_s"),
            "itl_s_p50": float(np.percentile(itl, 50)),
            "itl_s_p99": float(np.percentile(itl, 99)),
            "total_s": s["latency_s"],
            "finish_reason": s["finish_reason"],
        })
    out["claims"] = {
        # deterministic: streaming delivered the first token in its own
        # chunk, strictly before the request completed (engine-stamped)
        "stream_first_token_before_completion": all(
            v["n_chunks"] > 1 and v["ttft_s"] < v["total_s"]
            and v["finished"] and v["n_tokens"] == max_new
            for v in (out["greedy"], out["sampled"])),
    }
    return out


def _slo_storm_lane(cfg, params, pipe, *, n_decode=3, decode_prompt=16,
                    storm_len=96, max_new=24, storm_new=8, chunk=16,
                    slots=4, mean_gap_s=0.002, seed=0):
    """SLO under a long-prompt storm: steady Poisson decode traffic is
    interrupted by a long-prompt arrival once every decoder has produced
    a few tokens. Three lanes on identical jit-warm engines:

    * ``baseline``   — decode traffic alone (no storm): the ITL floor.
    * ``monolithic`` — storm admitted with ``prefill_chunk_tokens=0``:
      the whole storm prompt forwards inside ONE ``step()``, so every
      decoding slot's next token waits for the full prefill.
    * ``chunked``    — ``prefill_chunk_tokens=chunk``: the storm prefill
      page-slices across steps, decode tokens flow between chunks.

    The GATED claims are step-domain and deterministic: monolithic
    prefill emits the storm's first token in its admission step (zero
    intermediate steps — the whole prompt's work lands inside one decode
    interval), while chunked prefill spans ``storm_pages`` steps with
    every decoder emitting a token in each intermediate step.

    The wall-clock reading — chunked ITL p99 within 2x the no-storm
    baseline where monolithic violates it — is what those facts mean on
    paper-scale hardware (prefill FLOPs dwarf one decode step). It is
    reported here but NOT gated: on this CPU container the cost ratio
    INVERTS (the monolithic prefill is compiled jnp, a few ms, while
    every decode step pays the interpret-mode Pallas kernel), so the
    tiny-model wall clock measures the interpreter, not the storm."""
    from repro.serving.api import LLM
    from repro.serving.sampling import SamplingParams

    rng = np.random.default_rng(seed)
    gaps = np.cumsum(rng.exponential(mean_gap_s, size=n_decode))
    prompts = [pipe.batch(9000 + i)["tokens"][0, :decode_prompt]
               for i in range(n_decode)]
    # pipe rows are shorter than the storm prompt — concatenate two
    storm = np.concatenate([np.asarray(pipe.batch(9100)["tokens"][0]),
                            np.asarray(pipe.batch(9101)["tokens"][0])
                            ])[:storm_len]
    assert len(storm) == storm_len, len(storm)
    sp = SamplingParams(max_new_tokens=max_new)
    storm_sp = SamplingParams(max_new_tokens=storm_new)

    def run_lane(chunk_tokens, with_storm):
        llm = LLM(cfg, params, EngineConfig(
            batch_slots=slots, max_seq=128, page_size=16,
            prefill_chunk_tokens=chunk_tokens))
        core = llm.core
        # Warm every jit variant the measured pass can hit. Arrival
        # staggering is wall-clock nondeterministic, so the decode step
        # must be warm for EVERY phase mix: the all-MHA and all-CHAI
        # fast paths warm on a plain generate; the general mixed jit
        # needs a STEADY slot coexisting with a WARMUP one — force that
        # by admitting a second request (and the storm prompt, warming
        # its monolithic bucket / chunk bucket) after the first request
        # reaches STEADY.
        ra = core.add_request(prompts[0], sp, uid=900)
        for _ in range(cfg.chai.warmup_tokens + 2):
            core.step()
        rb = core.add_request(prompts[1], sp, uid=901)
        rs = core.add_request(storm, storm_sp, uid=902)
        while not (ra.finished and rb.finished and rs.finished):
            core.step()
        core.reap_done()

        reqs = [core.add_request(p, sp, uid=i,
                                 arrival_delay=float(gaps[i]))
                for i, p in enumerate(prompts)]
        stamps = {r.uid: [] for r in reqs}   # per decode uid: (step, t)
        storm_req, storm_submit_step, storm_first_step = None, None, None
        n_steps = 0
        while (not all(r.finished for r in reqs)
               or (storm_req is not None and not storm_req.finished)):
            outs = core.step()
            n_steps += 1
            now = time.time()
            for o in outs:
                if o.uid in stamps:
                    stamps[o.uid].extend([(n_steps, now)]
                                         * len(o.token_ids))
                elif storm_req is not None and o.uid == storm_req.uid \
                        and storm_first_step is None:
                    storm_first_step = n_steps
            if (with_storm and storm_req is None
                    and all(len(r.generated) >= 4 for r in reqs)):
                storm_req = core.add_request(storm, storm_sp, uid=99)
                storm_submit_step = n_steps
            if not outs and not core.has_active:
                time.sleep(1e-4)    # waiting on a Poisson arrival
        core.reap_done()

        itl = np.concatenate([np.diff([t for _, t in s])
                              for s in stamps.values() if len(s) > 1])
        out = {
            "n_itl_samples": int(itl.size),
            "itl_s_p50": float(np.percentile(itl, 50)),
            "itl_s_p99": float(np.percentile(itl, 99)),
            "itl_s_max": float(itl.max()),
        }
        if with_storm:
            # steps strictly between the storm's admission step and the
            # step that emitted its first token — the prefill window a
            # decoder could starve in
            window = range(storm_submit_step + 2, storm_first_step)
            out["storm_prefill_intermediate_steps"] = len(window)
            out["decode_tokens_during_storm_prefill"] = sum(
                1 for s in stamps.values() for step, _ in s
                if step in window)
        return out

    storm_pages = -(-storm_len // 16)
    out = {
        "workload": {"n_decode": n_decode, "decode_prompt": decode_prompt,
                     "storm_len": storm_len, "storm_pages": storm_pages,
                     "max_new": max_new, "chunk": chunk, "slots": slots},
        "baseline": run_lane(0, with_storm=False),
        "monolithic": run_lane(0, with_storm=True),
        "chunked": run_lane(chunk, with_storm=True),
    }
    bound = 2.0 * out["baseline"]["itl_s_p99"]
    mono, chnk = out["monolithic"], out["chunked"]
    out["itl_p99_2x_baseline_bound_s"] = bound
    out["claims"] = {
        # -- deterministic, gated ------------------------------------
        # one-shot prefill has NO intermediate steps: the storm's first
        # token arrives in its admission step, so every decoder's next
        # token absorbed the whole prompt's forward
        "monolithic_prefill_stalls_decode":
            mono["storm_prefill_intermediate_steps"] == 0,
        # chunked prefill spans the page-sliced window and every
        # decoder emits a token in every intermediate step
        "chunked_decode_flows_during_prefill":
            chnk["storm_prefill_intermediate_steps"] >= storm_pages - 2
            and chnk["decode_tokens_during_storm_prefill"]
                >= n_decode * (storm_pages - 2),
        # -- wall-clock, advisory (see docstring: the CPU proxy
        # inverts the prefill/decode cost ratio) ---------------------
        "chunked_itl_p99_within_2x_baseline":
            chnk["itl_s_p99"] <= bound,
        "monolithic_violates_2x_baseline":
            mono["itl_s_p99"] > bound,
    }
    return out


def _analytic_full(seqs=(256, 512, 1024, 2048)):
    cfg = get_config("chai-llama-7b")
    h, hd = cfg.n_heads, cfg.head_dim
    counts = cfg.chai_cluster_counts()
    out = {}
    for s in seqs:
        # TTNT: decode is memory-bound -> bytes of KV read per token
        mha_bytes = kv_cache_bytes(cfg, 1, s, chai=False)
        chai_bytes = kv_cache_bytes(cfg, 1, s, chai=True)
        # TTFT: prefill is compute-bound -> attention score flops
        mha_fl = sum(decode_flop_estimate(1, h, h, s, hd)
                     for _ in counts) * s
        chai_fl = sum(decode_flop_estimate(1, h, k, s, hd)
                      for k in counts) * s
        out[str(s)] = {
            "ttnt_speedup_bound": mha_bytes / chai_bytes,
            "ttft_attention_speedup_bound": mha_fl / chai_fl,
            "ttnt_mha_s_v5e": mha_bytes / HBM_BW,
            "ttnt_chai_s_v5e": chai_bytes / HBM_BW,
        }
    return out


def run():
    cfg, params, pipe, _ = tiny_trained()
    cfg_chai = cfg.with_chai(enabled=True,
                             cluster_counts=(5,) * cfg.n_attn_layers)
    cpu_mha = _engine_times(cfg, params, pipe, use_chai=False)
    cpu_chai = _engine_times(cfg_chai, params, pipe, use_chai=True)
    sched = _scheduler_compare(cfg_chai, params, pipe)
    fused = _fused_kernel_lane()
    prefix = _prefix_reuse_lane(cfg_chai, params, pipe)
    prefix["relay"] = _relay_lane(cfg_chai, params, pipe)
    streaming = _streaming_lane(cfg_chai, params, pipe)
    slo = _slo_storm_lane(cfg_chai, params, pipe)

    result = {
        "proxy_note": "CPU wall time on tiny model (engine incl. "
                      "clustering overhead) + analytic v5e model for "
                      "LLaMA-7B (paper Fig 12 ran V100s)",
        "cpu_tiny": {"mha": cpu_mha, "chai": cpu_chai,
                     "per_token_speedup":
                         cpu_mha["per_token_s"] / cpu_chai["per_token_s"]},
        "scheduler_compare_poisson": sched,
        "fused_kernel_lane": fused,
        "prefix_reuse": prefix,
        "streaming": streaming,
        "slo_storm": slo,
        "analytic_llama7b_v5e": _analytic_full(),
        "paper_claim": "TTFT up to 1.73x, TTNT up to 5x at seq 2048",
        "claim_check": {
            # fused decode: 3 launches -> 1 (observed), same outputs
            "fused_single_launch": fused["claims"]["fused_single_launch"],
            "fused_parity": fused["claims"]["fused_parity"],
            "ttnt_bound_exceeds_1": _analytic_full()["2048"]
                ["ttnt_speedup_bound"] > 1.0,
            "ttft_attn_bound_exceeds_1": _analytic_full()["2048"]
                ["ttft_attention_speedup_bound"] > 1.0,
            # scheduler claims on the step-count proxy (deterministic;
            # wall clock on a CPU interpret-mode container is advisory)
            "continuous_sustains_higher_throughput":
                sched["continuous_strictly_fewer_steps"],
            # paged admission keeps the mixed 8-128-token Poisson
            # workload flowing: the page-budget gate never exceeds the
            # pool reservation and does not serialize the workload vs
            # the dense layout (equal step counts when pages suffice)
            "paged_peak_within_capacity":
                sched["continuous"]["kv_bytes_peak"]
                <= sched["continuous"]["kv_bytes_capacity"],
            "paged_admission_throughput_holds":
                sched["paged_vs_dense_layout_steps_ratio"] <= 1.1,
            # prefix-reuse lane: deterministic allocator claims + the
            # (advisory-in-CI) cold-vs-warm TTFT ordering
            "prefix_warm_ttft_below_cold":
                prefix["claims"]["warm_ttft_below_cold"],
            "prefix_pages_saved_vs_no_sharing":
                prefix["claims"]["pages_saved_vs_no_sharing"],
            "prefix_no_page_leaks": prefix["claims"]["no_page_leaks"],
            "prefix_snapshot_hit_observed":
                prefix["claims"]["snapshot_hit_observed"],
            # relay decode lane: deterministic (token parity is executed,
            # launch flatness is trace-counted, the cost-model booleans
            # encode the O(prefix) / flat-in-N structure CI must keep)
            "relay_tokens_match_per_request":
                prefix["relay"]["claims"]["relay_tokens_match_per_request"],
            "relay_launches_flat_in_group_size":
                prefix["relay"]["claims"]
                    ["relay_launches_flat_in_group_size"],
            "relay_prefix_hbm_o_prefix":
                prefix["relay"]["claims"]["relay_prefix_hbm_o_prefix"],
            "relay_mxu_passes_flat_in_n":
                prefix["relay"]["claims"]["relay_mxu_passes_flat_in_n"],
            # streaming frontend: tokens arrive incrementally
            # (deterministic; the ITL percentiles above are advisory)
            "stream_first_token_before_completion":
                streaming["claims"]["stream_first_token_before_completion"],
            # SLO storm lane, deterministic step-domain claims (the
            # wall-clock ITL booleans stay advisory inside the lane —
            # the CPU proxy inverts the prefill/decode cost ratio):
            # one-shot prefill absorbs the whole storm prompt inside a
            # single decode interval; chunked prefill keeps every
            # decoder emitting through the storm's prefill window
            "slo_storm_monolithic_prefill_stalls_decode":
                slo["claims"]["monolithic_prefill_stalls_decode"],
            "slo_storm_chunked_decode_flows_during_prefill":
                slo["claims"]["chunked_decode_flows_during_prefill"],
        },
    }
    save_result("bench_latency", result)
    return result


def check_fused():
    """Deterministic fused-decode gate (CI): parity with the three-kernel
    pipeline, the 3 -> 1 launch-count drop, and the HBM-bytes ordering.
    Exits non-zero on any regression; never times anything."""
    lane = _fused_kernel_lane(timing=False)
    gated = {k: lane["claims"][k] for k in
             ("fused_single_launch", "fused_parity")}
    print({"fused_kernel_lane": lane, "gated": gated})
    return 0 if all(gated.values()) else 1


def check():
    """Full deterministic claim gate (CI): the fused-decode checks plus
    the relay-decode lane (token parity, launch flatness, O(prefix) cost
    structure). Exits non-zero on any regression; never times anything."""
    rc = check_fused()
    cfg, params, pipe, _ = tiny_trained()
    cfg_chai = cfg.with_chai(enabled=True,
                             cluster_counts=(5,) * cfg.n_attn_layers)
    lane = _relay_lane(cfg_chai, params, pipe)
    print({"relay_lane": lane, "gated": lane["claims"]})
    return 1 if (rc or not all(lane["claims"].values())) else 0


if __name__ == "__main__":
    import argparse
    import sys
    ap = argparse.ArgumentParser()
    ap.add_argument("--check-fused", action="store_true",
                    help="run only the deterministic fused-decode claim "
                         "checks (CI gate); exit 1 on regression")
    ap.add_argument("--check", action="store_true",
                    help="run every deterministic claim check (fused "
                         "decode + relay lane); exit 1 on regression")
    args = ap.parse_args()
    if args.check:
        sys.exit(check())
    if args.check_fused:
        sys.exit(check_fused())
    print(run())
