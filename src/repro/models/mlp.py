"""Dense gated MLP (silu/gelu/relu2)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.layers import activation_fn


def dense_ffn(x, p, cfg):
    """x: (B, T, d); gated (w_gate/w_up/w_down) or 2-matrix (w_up/w_down)."""
    act = activation_fn(cfg.activation)
    u = jnp.einsum("btd,df->btf", x, p["w_up"])
    if cfg.gated_mlp:
        g = jnp.einsum("btd,df->btf", x, p["w_gate"])
        h = act(g) * u
    else:
        h = act(u)
    return jnp.einsum("btf,fd->btd", h, p["w_down"])
