"""Jit'd dispatch wrappers over the Pallas kernels.

On CPU (this container) kernels run with interpret=True; on TPU they lower
to Mosaic. ``chai_decode_attention`` / ``paged_chai_decode_attention`` are
the public decode ops: ONE fused Pallas launch per decode step (online
softmax over rep-head scores + h2c-broadcast AV, int8 dequant in VMEM) —
the pre-fusion three-kernel pipeline survives only as the oracle in
``repro.kernels.ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import chai_attention as ck
from repro.kernels import flash_attention as fk


@functools.partial(jax.jit, static_argnames=("window", "ts", "interpret"))
def flash_decode_attention(q, k_cache, v_cache, pos, *, window=0, ts=512,
                           interpret=None):
    return fk.flash_decode(q, k_cache, v_cache, pos, window=window, ts=ts,
                           interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("window", "tq", "ts", "softcap",
                                    "interpret"))
def flash_prefill_attention(q, k, v, offset=0, *, window=0, tq=256, ts=512,
                            softcap=0.0, interpret=None):
    """``offset`` is a regular (traceable) argument: the prefix-cache
    suffix prefill varies it per request without retracing. ``softcap``
    is static — a python float baked into the kernel (0 = off)."""
    return fk.flash_prefill(q, k, v, offset=offset, window=window, tq=tq,
                            ts=ts, softcap=softcap, interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("reps_per_group", "share_values",
                                    "window", "ts", "softcap", "interpret"))
def chai_decode_attention(q_rep, k_cache, v_cache, h2c, pos, *,
                          k_scale=None, v_scale=None, reps_per_group=1,
                          share_values=False, window=0, ts=512, softcap=0.0,
                          interpret=None):
    """The paper's decode op — ONE fused Pallas launch. q_rep: (B, R, hd)
    rep-head queries; k_cache: (B, KVk, S, hd) (clustered for MHA:
    KVk==R); v_cache: (B, KVv, S, hd) per-head / per-group / clustered
    (share_values) V; h2c: (B, H) or (H,) flat head->rep-row map; pos:
    (B,). int8 caches pass per-row ``k_scale``/``v_scale`` (B, rows, S).
    Returns (B, H, hd) fp32; no (B, R, S) scores touch HBM."""
    return ck.chai_fused_decode(q_rep, k_cache, v_cache, h2c, pos,
                                k_scale=k_scale, v_scale=v_scale,
                                reps_per_group=reps_per_group,
                                share_values=share_values, window=window,
                                ts=ts, softcap=softcap,
                                interpret=interpret)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_decode_attention(q, kv_pool, bt_k, bt_v, pos, *, window=0,
                           interpret=None):
    """Paged flash decode over a block-table page pool. q: (B, H, hd);
    kv_pool: (nP, KV, page, hd); bt_k/bt_v: (B, P) int32; pos: (B,).
    Returns (B, H, hd) fp32."""
    return fk.paged_decode(q, kv_pool, bt_k, bt_v, pos, window=window,
                           interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("reps_per_group", "share_values",
                                    "window", "softcap", "interpret"))
def paged_chai_decode_attention(q_rep, k_pool, bt_k, v_pool, bt_v, h2c,
                                pos, *, k_scale_pool=None,
                                v_scale_pool=None, reps_per_group=1,
                                share_values=False, window=0, softcap=0.0,
                                interpret=None):
    """The paper's decode op over the serving engine's paged layout — ONE
    fused Pallas launch streaming pages through VMEM (no densifying
    gather). q_rep: (B, R, hd); k_pool: (nP, KVk, page, hd) clustered
    pages (MHA: KVk == k_max) or the dense pool (GQA); v_pool:
    (nP, KVv, page, hd) per-head V pages, or the clustered pool under
    ``share_values``; bt_k/bt_v: (B, P) int32 block tables; h2c: (B, H)
    or (H,). int8 pools pass the mirror-shaped scale pools. Returns
    (B, H, hd) fp32."""
    return ck.paged_chai_fused_decode(
        q_rep, k_pool, bt_k, v_pool, bt_v, h2c, pos,
        k_scale_pool=k_scale_pool, v_scale_pool=v_scale_pool,
        reps_per_group=reps_per_group, share_values=share_values,
        window=window, softcap=softcap, interpret=interpret)


def decode_flop_estimate(b, h, r, s, hd, *, share_values=False, window=0):
    """Analytic decode-attention FLOPs: clustered scores + AV.

    ``share_values``: the CHAI-QKV ablation prunes V rows too, so AV is
    R·S·hd, not H·S·hd. ``window``: sliding-window attention touches at
    most ``window`` positions, so effective S = min(S, window)."""
    s_eff = min(s, window) if window else s
    av_rows = r if share_values else h
    scores = 2.0 * b * r * s_eff * hd
    av = 2.0 * b * av_rows * s_eff * hd
    return scores + av


# --- fused-vs-pipeline analytic lane (benchmarks/bench_latency.py) ---------
def decode_launch_count(fused=True):
    """Kernel launches per CHAI decode step: the fused path is ONE
    ``pallas_call``; the retired pipeline was QK -> row softmax -> AV."""
    return 1 if fused else 3


def decode_hbm_bytes_estimate(b, h, r, s, hd, *, cache_bytes=4,
                              share_values=False, window=0, fused=True):
    """Analytic HBM bytes moved by one CHAI decode-attention step.

    Both paths stream the same cache tiles (K: R rep rows; V: H per-head
    rows, or R under ``share_values``) plus the (negligible) q/out
    vectors. The three-kernel pipeline additionally round-trips the
    (B, R, S) fp32 score tensor through HBM three times (QK write,
    softmax read+write) and re-reads the normalized rows per member head
    (B, H, S) in AV — exactly the traffic fusion deletes."""
    s_eff = min(s, window) if window else s
    v_rows = r if share_values else h
    cache = b * (r + v_rows) * s_eff * hd * cache_bytes
    qout = b * (r + h) * hd * 4
    total = cache + qout
    if not fused:
        total += b * r * s_eff * 4 * 3        # scores: write, read, write
        total += b * h * s_eff * 4            # AV reads A row per head
    return float(total)
