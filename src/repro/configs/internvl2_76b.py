"""InternVL2-76B [arXiv:2404.16821]: InternViT + InternLM2 backbone.

The assignment specifies the LM transformer backbone only; the InternViT
frontend is a stub providing precomputed patch embeddings.
"""
from repro.configs.base import ModelConfig, CHAIConfig, register

CONFIG = register(ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    activation="silu",
    frontend="vision",
    rope_theta=1000000.0,
    chai=CHAIConfig(enabled=True),
))
