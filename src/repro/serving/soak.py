"""Deterministic fault-injection soak over the step-driven engine.

The soak drives ``EngineCore.step()`` through a SCRIPTED workload — every
request's arrival, priority, and abort are keyed to an engine step
number, never wall clock — so two runs over the same (workload seed,
fault plan seed) take byte-for-byte identical paths. The workload mixes
everything the robustness layer must survive at once:

* shared-prefix families (radix hits; relay groups when
  ``relay_decode``) and exact-duplicate greedy prompts (CHAI snapshot
  capture, restore, and host-side replay),
* priority-1 arrivals into a full slot pool (preemption KV swap-out /
  swap-in, the ``swap.corrupt`` / ``swap.in`` fault surface),
* scripted aborts mid-flight,
* an optional ``FaultInjector`` plan threaded through every engine site.

``run_soak`` returns a JSON-ready report: per-request outcomes (every
request must end completed or typed-failed), the pool counters (must
show zero leaks), the idle-engine leak audit, and the engine's fault
stats including the injector's replayable firing log.

``run_soak_pair`` runs the SAME workload fault-free and under a plan and
computes the bitwise token-parity set: completed requests not named by
any injector firing must generate identical tokens in both runs (greedy
tokens are schedule-invariant, so quarantines perturbing the batch
composition never perturb surviving requests' outputs).
"""
from __future__ import annotations

from collections import deque
from typing import List, Optional

import numpy as np

from repro.serving.engine import EngineConfig, EngineCore
from repro.serving.faults import CapacityError, FaultInjector
from repro.serving.sampling import SamplingParams

_MAX_STEPS = 20_000


def build_workload(seed: int = 0, n_requests: int = 24, *,
                   page_size: int = 8, vocab: int = 128,
                   arrival_span: int = 40) -> List[dict]:
    """Scripted request list: dicts of {step, prompt, max_new, priority,
    abort_at}. Two shared-prefix families (2 whole pages each) seed the
    radix tree / relay groups; every 6th request duplicates the family-A
    base prompt exactly (snapshot capture on the first, restore/replay
    on the rest); every 5th arrives at priority 1 (slot preemption);
    every 7th is aborted a few steps after arrival."""
    rng = np.random.default_rng(seed)
    fam_a = rng.integers(1, vocab, size=2 * page_size).tolist()
    fam_b = rng.integers(1, vocab, size=2 * page_size).tolist()
    dup = fam_a + rng.integers(1, vocab, size=3).tolist()
    wl = []
    for j in range(n_requests):
        if j % 6 == 2:
            prompt = list(dup)              # exact duplicate: snapshot
        else:
            fam = [fam_a, fam_b, None][j % 3]
            suffix = rng.integers(1, vocab, size=int(rng.integers(2, 7)))
            prompt = ((fam or []) + suffix.tolist()
                      if fam is not None else
                      rng.integers(1, vocab,
                                   size=int(rng.integers(4, 12))).tolist())
        w = {"step": int(rng.integers(0, arrival_span)),
             "prompt": prompt,
             "max_new": int(rng.integers(6, 14)),
             "priority": 1 if j % 5 == 4 else 0,
             "abort_at": None}
        if j % 7 == 3:
            w["abort_at"] = w["step"] + int(rng.integers(3, 9))
        wl.append(w)
    wl.sort(key=lambda w: w["step"])
    return wl


def run_soak(cfg, params, ecfg: EngineConfig, *,
             faults: Optional[FaultInjector] = None,
             workload: Optional[List[dict]] = None,
             seed: int = 0, n_requests: int = 24) -> dict:
    """Drive one engine through the scripted workload to drain; returns
    the JSON-ready soak report. Raises if the engine fails to drain
    within ``_MAX_STEPS`` (a stuck scheduler is a soak failure)."""
    from repro.serving import invariants as invariants_mod
    core = EngineCore(cfg, params, ecfg, faults=faults)
    wl = workload if workload is not None else build_workload(
        seed, n_requests, page_size=ecfg.page_size, vocab=cfg.vocab_size)
    pending = deque(wl)
    aborts: List[tuple] = []
    tracked: dict = {}
    step_no = 0
    while pending or core.has_work() or aborts:
        while pending and pending[0]["step"] <= step_no:
            w = pending.popleft()
            r = core.add_request(
                w["prompt"],
                SamplingParams(max_new_tokens=w["max_new"]),
                priority=w["priority"])
            tracked[r.uid] = r
            if w["abort_at"] is not None:
                aborts.append((w["abort_at"], r.uid))
        for s, uid in list(aborts):
            if s <= step_no:
                core.abort(uid)
                aborts.remove((s, uid))
        try:
            core.step()
        except CapacityError as err:
            # The head can never fit: typed-fail it, keep draining.
            core.abort(err.uid)
        step_no += 1
        if step_no > _MAX_STEPS:
            raise RuntimeError(
                f"soak did not drain in {_MAX_STEPS} steps: "
                f"{len(pending)} pending, queue {len(core.queue)}, "
                f"active {core.has_active}")
    counters = {"dense": core.dense_pool.counters() if core.dense_pool
                else None,
                "chai": core.chai_pool.counters() if core.chai_pool
                else None}
    report = {
        "workload_seed": seed,
        "steps": step_no,
        "requests": {
            int(uid): {"finish": r.finish_reason,
                       "tokens": [int(t) for t in r.generated],
                       "error": r.error,
                       "preemptions": r.preemptions,
                       "cache_hit": r.cache_hit}
            for uid, r in sorted(tracked.items())},
        "unfinished": [int(u) for u, r in tracked.items()
                       if not r.finished],
        "counters": counters,
        "leaks": invariants_mod.audit_leaks(core),
        "fault_stats": core.fault_stats(),
        "prefix_stats": core.prefix_stats(),
        "preemptions": core.preemptions,
    }
    if core.tel.enabled:
        # Wall-clock-dependent, so a separate report section: the
        # replay/parity comparisons above read only "requests" and the
        # injector log, which stay byte-deterministic.
        report["telemetry"] = {
            "metrics": core.metrics(),
            "chrome_trace": core.step_trace(),
            "timelines": core.tel.timelines(),
        }
    return report


def affected_uids(report: dict) -> set:
    """Requests a fault plan touched directly: every uid named by an
    injector firing, plus everything that ended quarantined. (Aborted
    requests are schedule-dependent by construction and sit outside the
    parity contract.)"""
    inj = report["fault_stats"]["injector"] or {"fired": []}
    named = {f["uid"] for f in inj["fired"] if f["uid"] >= 0}
    named |= {uid for uid, r in report["requests"].items()
              if r["finish"] == "error"}
    return named


def run_soak_pair(cfg, params, ecfg: EngineConfig, *, specs,
                  fault_seed: int = 0, seed: int = 0,
                  n_requests: int = 24) -> dict:
    """Fault-free run vs the same workload under ``specs``; returns
    {"clean", "faulted", "parity"} where parity lists every uid that was
    required to match bitwise, and "mismatches" any that failed to."""
    wl = build_workload(seed, n_requests, page_size=ecfg.page_size,
                        vocab=cfg.vocab_size)
    clean = run_soak(cfg, params, ecfg, workload=[dict(w) for w in wl],
                     seed=seed)
    faulted = run_soak(cfg, params, ecfg,
                       faults=FaultInjector(list(specs), seed=fault_seed),
                       workload=[dict(w) for w in wl], seed=seed)
    touched = affected_uids(faulted)
    done = ("length", "stop")
    parity = [uid for uid, r in faulted["requests"].items()
              if uid not in touched and r["finish"] in done
              and clean["requests"][uid]["finish"] in done]
    mismatches = [uid for uid in parity
                  if faulted["requests"][uid]["tokens"]
                  != clean["requests"][uid]["tokens"]]
    return {"clean": clean, "faulted": faulted,
            "parity": sorted(parity), "mismatches": sorted(mismatches)}
