"""Attention: GQA/MQA/MHA, full-sequence (flash-style chunked) + decode paths.

Full-sequence attention streams KV in chunks with a running-softmax carry
(pure-JAX flash; also the oracle for the Pallas kernels). Decode reads a
dense or ring-buffer cache. CHAI's clustered decode path lives in
``repro.core.chai_attention`` and shares these primitives.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, rms_norm, softcap

NEG_INF = -2.0e38


def _gqa_split(q, n_kv):
    """(B, T, H, hd) -> (B, T, KV, qpk, hd)."""
    b, t, h, d = q.shape
    return q.reshape(b, t, n_kv, h // n_kv, d)


def attention_fullseq(q, k, v, q_positions, kv_positions, *,
                      window=0, attn_softcap=0.0, chunk=1024):
    """Causal (optionally windowed) attention over a full K/V sequence.

    q: (B, Tq, H, hd); k, v: (B, S, KV, hd).
    q_positions: (Tq,) absolute positions of queries.
    kv_positions: (S,) absolute positions of keys.
    Returns (B, Tq, H, hd).
    """
    b, tq, h, hd = q.shape
    s, n_kv = k.shape[1], k.shape[2]
    qs = _gqa_split(q, n_kv).astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))

    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    n_chunks = s // chunk
    kc = k.reshape(b, n_chunks, chunk, n_kv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, n_kv, hd).transpose(1, 0, 2, 3, 4)
    pc = kv_positions.reshape(n_chunks, chunk)

    def step(carry, xs):
        m, l, acc = carry
        k_i, v_i, p_i = xs
        # scores: (B, Tq, KV, qpk, C)
        sc = jnp.einsum("btkgd,bckd->btkgc", qs, k_i.astype(jnp.float32))
        sc = sc * scale
        sc = softcap(sc, attn_softcap)
        mask = p_i[None, :] <= q_positions[:, None]          # (Tq, C) causal
        if window and window > 0:
            mask &= (q_positions[:, None] - p_i[None, :]) < window
        sc = jnp.where(mask[None, :, None, None, :], sc, NEG_INF)
        # guard: keep m finite so fully-masked rows produce p=0, not p=1
        m_new = jnp.maximum(jnp.maximum(m, sc.max(axis=-1)), -1e30)
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(sc - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "btkgc,bckd->btkgd", p, v_i.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    qpk = h // n_kv
    m0 = jnp.full((b, tq, n_kv, qpk), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, tq, n_kv, qpk), jnp.float32)
    a0 = jnp.zeros((b, tq, n_kv, qpk, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kc, vc, pc))
    out = acc / jnp.maximum(l[..., None], 1e-37)
    return out.reshape(b, tq, h, hd).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, kv_positions, pos, *,
                     window=0, attn_softcap=0.0):
    """One-token decode against a cache.

    q: (B, H, hd); caches: (B, KV, S, hd);
    kv_positions: (S,) absolute position per cache slot (ring-aware);
    pos: scalar int32 — number of tokens already in context (query position).
    Returns (B, H, hd).
    """
    b, h, hd = q.shape
    n_kv, s = k_cache.shape[1], k_cache.shape[2]
    qs = q.reshape(b, n_kv, h // n_kv, hd).astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    sc = jnp.einsum("bkgd,bksd->bkgs", qs, k_cache.astype(jnp.float32)) * scale
    sc = softcap(sc, attn_softcap)
    valid = (kv_positions >= 0) & (kv_positions <= pos)
    if window and window > 0:
        valid &= (pos - kv_positions) < window
    sc = jnp.where(valid[None, None, None, :], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bkgs,bksd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, h, hd).astype(q.dtype)


def ring_positions(pos, size):
    """Absolute position stored in each slot of a ring buffer of ``size``.

    Slot s holds the latest t < pos with t % size == s; -1 if none yet.
    """
    slots = jnp.arange(size, dtype=jnp.int32)
    last = pos - 1 - jnp.mod(pos - 1 - slots, size)
    return jnp.where(last >= 0, last, -1)


def project_qkv(x, p, cfg, positions, layer_slice=None):
    """Project hidden states to rotary-encoded q, k, v.

    x: (B, T, d). p: attention param group (already layer-indexed).
    Returns q: (B, T, H, hd), k/v: (B, T, KV, hd).
    """
    q = jnp.einsum("btd,dhe->bthe", x, p["wq"])
    k = jnp.einsum("btd,dke->btke", x, p["wk"])
    v = jnp.einsum("btd,dke->btke", x, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def output_proj(attn_out, p):
    """(B, T, H, hd) @ (H, hd, d) -> (B, T, d)."""
    return jnp.einsum("bthe,hed->btd", attn_out, p["wo"])
