from repro.core import cache, chai_attention, clustering, correlation, elbow, kmeans, policy  # noqa: F401
