"""CHAI core: K-Means, clustering, correlation, elbow, cache compaction."""
import numpy as np
import pytest

try:    # property tests run when hypothesis is installed (the [test]
        # extra); a bare CPU env still collects and runs everything else.
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, reduced
from repro.core import cache as chai_cache
from repro.core import clustering, correlation, elbow
from repro.core.kmeans import kmeans, representatives


# ---------------------------------------------------------------- kmeans ----
def test_kmeans_recovers_planted_clusters(rng):
    """Three well-separated blobs -> three pure clusters."""
    centers = np.array([[10.0, 0], [0, 10.0], [-10.0, -10.0]])
    x = np.concatenate([c + 0.1 * rng.normal(size=(8, 2)) for c in centers])
    assign, _, err = kmeans(jnp.asarray(x, jnp.float32), 3)
    a = np.asarray(assign)
    groups = [set(a[i * 8:(i + 1) * 8]) for i in range(3)]
    assert all(len(g) == 1 for g in groups)
    assert len(set.union(*groups)) == 3
    assert float(err) < 1.0


def test_kmeans_error_monotone_in_k(rng):
    x = jnp.asarray(rng.normal(size=(16, 8)), jnp.float32)
    errs = [float(kmeans(x, k)[2]) for k in (1, 2, 4, 8, 16)]
    assert all(errs[i] >= errs[i + 1] - 1e-4 for i in range(len(errs) - 1))
    assert errs[-1] < 1e-4          # k == n -> ~zero error (f32 roundoff)


def _kmeans_properties_body(n, f, k, seed):
    """Property: assignments in range; every cluster's rep is a member."""
    k = min(k, n)
    x = jnp.asarray(np.random.default_rng(seed).normal(size=(n, f)),
                    jnp.float32)
    assign, centers, err = kmeans(x, k)
    a = np.asarray(assign)
    assert a.min() >= 0 and a.max() < k
    assert float(err) >= -1e-5
    reps, valid = representatives(x, assign, centers, k)
    r, v = np.asarray(reps), np.asarray(valid)
    for c in range(k):
        if v[c]:
            assert a[r[c]] == c     # rep belongs to its own cluster


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(4, 24), f=st.integers(2, 10), k=st.integers(1, 4),
           seed=st.integers(0, 2**31 - 1))
    def test_kmeans_properties(n, f, k, seed):
        _kmeans_properties_body(n, f, k, seed)
else:
    def test_kmeans_properties():
        pytest.importorskip("hypothesis")   # randomized search needs it;
        # the pinned grid below still exercises the property.


@pytest.mark.parametrize("n,f,k,seed", [
    (4, 2, 1, 10), (9, 4, 2, 11), (20, 6, 4, 12),
])
def test_kmeans_properties_pinned(n, f, k, seed):
    """Hypothesis-free pinned cases so the property holds on bare envs."""
    _kmeans_properties_body(n, f, k, seed)


# ----------------------------------------------------------- clustering ----
def test_standardize_correlation_geometry(rng):
    """|z_i - z_j|^2 == 2(1 - corr_ij) after standardization."""
    x = jnp.asarray(rng.normal(size=(6, 40)), jnp.float32)
    z = clustering.standardize(x)
    corr = correlation.head_correlation(x)
    d2 = jnp.sum(jnp.square(z[:, None] - z[None, :]), -1)
    np.testing.assert_allclose(np.asarray(d2), np.asarray(2 * (1 - corr)),
                               rtol=1e-4, atol=1e-4)


def test_identify_membership_mha_groups_duplicate_heads(rng):
    """Heads with (noisy) duplicated score patterns land in one cluster."""
    cfg = reduced(get_config("musicgen-large"), n_heads=8)   # MHA family
    cfg = cfg.with_chai(enabled=True, cluster_counts=(2,) * cfg.n_attn_layers)
    na, b, h, f = cfg.n_attn_layers, 2, cfg.n_heads, 32
    base = rng.normal(size=(na, b, 2, f))                     # 2 patterns
    pattern_of = np.array([0, 0, 0, 1, 1, 1, 0, 1])
    scores = base[:, :, pattern_of] + 0.01 * rng.normal(size=(na, b, h, f))
    ctx = clustering.identify_membership(jnp.asarray(scores, jnp.float32),
                                         cfg)
    h2c = np.asarray(ctx["h2c"])
    for l in range(na):
        for bb in range(b):
            ids = h2c[l, bb]
            assert (ids[pattern_of == 0] == ids[0]).all()
            assert (ids[pattern_of == 1] == ids[3]).all()
            assert ids[0] != ids[3]
    # reps must point at heads inside their own cluster
    reps = np.asarray(ctx["reps"])
    for l in range(na):
        for bb in range(b):
            for c, rep in enumerate(reps[l, bb]):
                assert h2c[l, bb, rep] == c


def test_identify_membership_gqa_block_diagonal(rng):
    """GQA: clustering stays within KV groups (rep K validity)."""
    cfg = reduced(get_config("nemotron-4-15b"), n_heads=8)    # GQA family
    assert not cfg.is_mha
    cfg = cfg.with_chai(enabled=True)
    na, b = cfg.n_attn_layers, 2
    scores = rng.normal(size=(na, b, cfg.n_heads, 16))
    ctx = clustering.identify_membership(jnp.asarray(scores, jnp.float32),
                                         cfg)
    assert ctx["cluster_of"].shape == (na, b, cfg.n_kv_heads, cfg.q_per_kv)
    r_max = ctx["reps"].shape[-1]
    assert np.asarray(ctx["cluster_of"]).max() < r_max
    assert np.asarray(ctx["reps"]).max() < cfg.q_per_kv


def test_membership_churn():
    a = {"h2c": jnp.asarray([[0, 1, 2, 0]])}
    b = {"h2c": jnp.asarray([[0, 1, 0, 0]])}
    assert float(clustering.membership_churn(a, a)) == 0.0
    assert float(clustering.membership_churn(a, b)) == pytest.approx(0.25)


def test_shared_ctx_valid(rng):
    for arch in ("musicgen-large", "gemma2-9b"):
        cfg = reduced(get_config(arch)).with_chai(enabled=True)
        ctx = clustering.shared_ctx(cfg)
        key = "h2c" if cfg.is_mha else "cluster_of"
        k_max, r_max = clustering.chai_widths(cfg)
        width = k_max if cfg.is_mha else r_max
        assert np.asarray(ctx[key]).max() < width
        # every cluster id referenced by reps is a valid head index
        assert np.asarray(ctx["reps"]).max() < (
            cfg.n_heads if cfg.is_mha else cfg.q_per_kv)


# ---------------------------------------------------------------- elbow ----
def test_select_k_plateau():
    ks = [1, 2, 4, 8, 16]
    errors = [100.0, 30.0, 10.0, 9.5, 9.4]   # plateaus after 4
    assert elbow.select_k(errors, ks) == 4


def test_offline_cluster_counts_planted(rng):
    """Features with exactly 3 planted patterns -> k close to 3."""
    h, f = 16, 64
    base = rng.normal(size=(3, f))
    feats = base[rng.integers(0, 3, size=h)] + 0.01 * rng.normal(size=(h, f))
    feats = clustering.standardize(jnp.asarray(feats, jnp.float32))
    (k,) = elbow.offline_cluster_counts([feats], h)
    assert 2 <= k <= 6


# ---------------------------------------------------------------- cache ----
def test_compact_kv_gathers_rep_rows(rng):
    cfg = reduced(get_config("musicgen-large"), n_heads=8)
    cfg = cfg.with_chai(enabled=True, cluster_counts=(3,) * cfg.n_attn_layers)
    b, s = 2, 16
    from repro.models.transformer import init_decode_state
    state = init_decode_state(cfg, b, s)
    state["kg"] = jnp.asarray(
        rng.normal(size=state["kg"].shape), state["kg"].dtype)
    k_max, _ = clustering.chai_widths(cfg)
    reps = jnp.asarray(
        rng.integers(0, cfg.n_heads, size=(cfg.n_attn_layers, b, k_max)),
        jnp.int32)
    new = chai_cache.compact_kv(state, {"reps": reps}, cfg)
    assert "kg" not in new and "kg_chai" in new
    assert new["kg_chai"].shape == (cfg.n_global_layers, b, k_max, s,
                                    cfg.head_dim)
    kg, out, r = (np.asarray(state["kg"]), np.asarray(new["kg_chai"]),
                  np.asarray(reps))
    for l in range(cfg.n_global_layers):
        for bb in range(b):
            for c in range(k_max):
                np.testing.assert_array_equal(out[l, bb, c],
                                              kg[l, bb, r[l, bb, c]])


def test_kv_cache_bytes_saving():
    """Full-config LLaMA-7B: CHAI K-cache saving in the paper's ballpark
    (K rows drop from H to k_max; V unchanged)."""
    cfg = get_config("chai-llama-7b")
    full = chai_cache.kv_cache_bytes(cfg, 1, 2048, chai=False)
    ch = chai_cache.kv_cache_bytes(cfg, 1, 2048, chai=True)
    saving = 1 - ch / full
    assert 0.10 < saving < 0.50      # paper: up to 21.4%
    assert full == 2 * 32 * 2048 * 128 * 32 * 2  # 2(K+V) H S hd L bytes
