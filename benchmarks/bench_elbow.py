"""Paper Fig 8: clustering error vs number of clusters (elbow), per layer.

Runs the real offline phase on the trained tiny model's attention scores
over calibration data; also verifies the paper's depth profile (later
layers more redundant -> fewer clusters) on the score features."""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from benchmarks.common import collect_qkv, save_result, tiny_trained
from repro.core.clustering import standardize
from repro.core.correlation import head_correlation, mean_abs_offdiag
from repro.core.elbow import elbow_curve, select_k
from repro.core.policy import _full_scores


def _planted_check(h, true_k=3, f=64):
    """Elbow must recover a planted cluster count on synthetic features."""
    rng = np.random.default_rng(0)
    base = rng.normal(size=(true_k, f))
    feats = base[rng.integers(0, true_k, size=h)]
    feats = feats + 0.02 * rng.normal(size=(h, f))
    fz = standardize(jnp.asarray(feats, jnp.float32))
    ks = list(range(1, h + 1))
    errs = elbow_curve(fz, ks)
    return abs(select_k(errs, ks) - true_k) <= 1


def run():
    cfg, params, pipe, _ = tiny_trained()
    toks = jnp.asarray(pipe.batch(700)["tokens"][:4, :32])
    qkvs = collect_qkv(cfg, params, toks)

    ks = [1, 2, 3, 4, 5, 6, 7, 8]
    layers = {}
    redundancy = []
    for li, (q, k, _) in enumerate(qkvs):
        a = _full_scores(q, k)                       # (B, H, T, T)
        feats = np.asarray(a).transpose(1, 0, 2, 3).reshape(cfg.n_heads, -1)
        fz = standardize(jnp.asarray(feats))
        errs = elbow_curve(fz, ks)
        layers[f"layer_{li}"] = {
            "k_values": ks, "errors": errs.tolist(),
            "selected_k": int(select_k(errs, ks)),
        }
        redundancy.append(float(mean_abs_offdiag(head_correlation(
            jnp.asarray(feats)))))

    result = {
        "proxy_note": "elbow on trained tiny LM attention scores "
                      "(paper Fig 8 used 1024 C4 samples on LLaMA-7B)",
        "per_layer": layers,
        "mean_abs_head_correlation_per_layer": redundancy,
        "paper_claim": "error plateaus; redundancy grows toward later "
                       "layers (Figs 6/8)",
        "claim_check": {
            "errors_monotone": all(
                all(np.diff(v["errors"]) <= 1e-3) for v in layers.values()),
            "selected_k_le_H": all(
                v["selected_k"] <= cfg.n_heads for v in layers.values()),
            # the paper's depth trend, visible even on the tiny model
            "later_layer_more_redundant": redundancy[-1] > redundancy[0],
            # sanity: elbow recovers a planted small k exactly
            "planted_k_recovered": _planted_check(cfg.n_heads),
        },
    }
    save_result("bench_elbow", result)
    return result


if __name__ == "__main__":
    print(run())
