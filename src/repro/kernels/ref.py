"""Pure-jnp oracles for every Pallas kernel (allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def _softcap(sc, cap):
    """Gemma2-style tanh logit softcap (identity when cap <= 0), applied
    after QK-scale and before the validity mask — the exact insertion
    point of the fused kernels' static ``softcap`` flag."""
    if cap:
        return cap * jnp.tanh(sc / cap)
    return sc


def flash_decode_ref(q, k_cache, v_cache, pos, *, window=0):
    """q: (B, H, hd); k/v_cache: (B, KV, S, hd); pos: (B,) int32 (number of
    valid tokens - 1 == current position). Returns (B, H, hd) fp32."""
    b, h, hd = q.shape
    n_kv, s = k_cache.shape[1], k_cache.shape[2]
    qpk = h // n_kv
    qs = q.reshape(b, n_kv, qpk, hd).astype(jnp.float32)
    sc = jnp.einsum("bkgd,bksd->bkgs", qs,
                    k_cache.astype(jnp.float32)) / jnp.sqrt(
                        jnp.float32(hd))
    kv_pos = jnp.arange(s, dtype=jnp.int32)
    valid = kv_pos[None, :] <= pos[:, None]
    if window:
        valid &= (pos[:, None] - kv_pos[None, :]) < window
    sc = jnp.where(valid[:, None, None, :], sc, NEG_INF)
    a = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bkgs,bksd->bkgd", a, v_cache.astype(jnp.float32))
    return out.reshape(b, h, hd)


def flash_prefill_ref(q, k, v, *, offset=0, window=0, softcap=0.0):
    """q: (B, T, H, hd); k/v: (B, S, KV, hd); causal with query positions
    offset..offset+T-1 against key positions 0..S-1."""
    b, t, h, hd = q.shape
    s, n_kv = k.shape[1], k.shape[2]
    qpk = h // n_kv
    qs = q.reshape(b, t, n_kv, qpk, hd).astype(jnp.float32)
    sc = jnp.einsum("btkgd,bskd->btkgs", qs,
                    k.astype(jnp.float32)) / jnp.sqrt(jnp.float32(hd))
    sc = _softcap(sc, softcap)
    qp = offset + jnp.arange(t)[:, None]
    kp = jnp.arange(s)[None, :]
    valid = kp <= qp
    if window:
        valid &= (qp - kp) < window
    sc = jnp.where(valid[None, :, None, None, :], sc, NEG_INF)
    a = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("btkgs,bskd->btkgd", a, v.astype(jnp.float32))
    return out.reshape(b, t, h, hd)


def chai_scores_ref(q_rep, k_cache, pos, *, reps_per_group=0, window=0,
                    softcap=0.0):
    """Clustered scores. q_rep: (B, R, hd) representative-head queries;
    k_cache: (B, KV, S, hd). reps_per_group r maps rep j -> KV group j//r
    (MHA clustered cache: KV == R, r == 1). Returns normalized A (B, R, S)."""
    b, r_total, hd = q_rep.shape
    n_kv, s = k_cache.shape[1], k_cache.shape[2]
    r = reps_per_group or 1
    kg = k_cache[:, jnp.arange(r_total) // r]            # (B, R, S, hd)
    sc = jnp.einsum("bre,brse->brs", q_rep.astype(jnp.float32),
                    kg.astype(jnp.float32)) / jnp.sqrt(jnp.float32(hd))
    sc = _softcap(sc, softcap)
    kv_pos = jnp.arange(s, dtype=jnp.int32)
    valid = kv_pos[None, :] <= pos[:, None]
    if window:
        valid &= (pos[:, None] - kv_pos[None, :]) < window
    sc = jnp.where(valid[:, None, :], sc, NEG_INF)
    return jax.nn.softmax(sc, axis=-1)


def chai_scores_i8_ref(q_rep, k_cache_i8, k_scale, pos, *,
                       reps_per_group=0):
    """Oracle for the fused int8-dequant clustered scores."""
    kf = k_cache_i8.astype(jnp.float32) * k_scale[..., None]
    return chai_scores_ref(q_rep, kf, pos, reps_per_group=reps_per_group)


def chai_av_ref(a, v_cache, h2c):
    """a: (B, R, S) normalized clustered scores; v_cache: (B, H, S, hd);
    h2c: (B, H) or (H,) flat head->row map. Returns (B, H, hd) fp32."""
    b, h = v_cache.shape[0], v_cache.shape[1]
    if h2c.ndim == 1:
        h2c = jnp.broadcast_to(h2c, (b, h))
    a_full = jnp.take_along_axis(a, h2c[..., None], axis=1)   # (B, H, S)
    return jnp.einsum("bhs,bhsd->bhd", a_full.astype(jnp.float32),
                      v_cache.astype(jnp.float32))


def chai_decode_ref(q_rep, k_cache, v_cache, h2c, pos, *, reps_per_group=0):
    a = chai_scores_ref(q_rep, k_cache, pos, reps_per_group=reps_per_group)
    return chai_av_ref(a, v_cache, h2c)


# -------------------------------------------------------------- paged ------
def gather_pages_ref(pool, bt):
    """Densify a page pool through block tables. pool: (nP, rows, page,
    hd); bt: (B, P) int32 -> (B, rows, P*page, hd). Null-page entries
    yield garbage rows that the ``pos`` masks of the oracles below hide —
    the same contract the paged kernels rely on. Reuses the production
    gather (its correctness is pinned independently by the
    scatter-then-compare kernel tests); the oracle value here is the
    dense attention math it feeds."""
    from repro.core.cache import gather_pages
    return gather_pages(pool, bt)


def paged_decode_ref(q, kv_pool, bt_k, bt_v, pos, *, window=0):
    """Oracle for ``paged_decode``: densify then flash-decode."""
    return flash_decode_ref(q, gather_pages_ref(kv_pool, bt_k),
                            gather_pages_ref(kv_pool, bt_v), pos,
                            window=window)


def paged_chai_scores_ref(q_rep, k_pool, bt, pos, *, reps_per_group=0):
    """Oracle for ``paged_chai_qk`` + ``row_softmax``."""
    return chai_scores_ref(q_rep, gather_pages_ref(k_pool, bt), pos,
                           reps_per_group=reps_per_group)


def paged_chai_av_ref(a, v_pool, bt_v, h2c):
    """Oracle for ``paged_chai_av``."""
    return chai_av_ref(a, gather_pages_ref(v_pool, bt_v), h2c)


def paged_chai_decode_ref(q_rep, k_pool, bt_k, v_pool, bt_v, h2c, pos, *,
                          reps_per_group=0):
    a = paged_chai_scores_ref(q_rep, k_pool, bt_k, pos,
                              reps_per_group=reps_per_group)
    return paged_chai_av_ref(a, v_pool, bt_v, h2c)


# ------------------------------------------------------ fused decode -------
def chai_fused_decode_ref(q_rep, k_cache, v_cache, h2c, pos, *,
                          k_scale=None, v_scale=None, reps_per_group=0,
                          share_values=False, window=0, softcap=0.0):
    """Oracle for ``chai_fused_decode`` across the full dispatch matrix:
    {MHA, GQA} x {fp32, int8 scale rows} x {share_values} x {window}.

    v_cache rows: H (per-head), a divisor of H (GQA per-group), or R
    (share_values clustered). int8 inputs pass per-row ``k_scale`` /
    ``v_scale`` (B, rows, S); share_values V codes are reinterpreted
    scale-less, matching the engine's clustered-V semantics."""
    b = q_rep.shape[0]
    kf = k_cache.astype(jnp.float32)
    if k_scale is not None:
        kf = kf * k_scale.astype(jnp.float32)[..., None]
    a = chai_scores_ref(q_rep, kf, pos, reps_per_group=reps_per_group,
                        window=window, softcap=softcap)      # (B, R, S)
    vf = v_cache.astype(jnp.float32)
    if v_scale is not None:
        vf = vf * v_scale.astype(jnp.float32)[..., None]
    if h2c.ndim == 1:
        h2c = jnp.broadcast_to(h2c, (b, h2c.shape[0]))
    h = h2c.shape[1]
    if share_values:
        out_rep = jnp.einsum("brs,brsd->brd", a, vf)
        return jnp.take_along_axis(out_rep, h2c[..., None], axis=1)
    if vf.shape[1] != h:         # GQA: head h reads V of group h // qpk
        vf = jnp.repeat(vf, h // vf.shape[1], axis=1)
    return chai_av_ref(a, vf, h2c)


def paged_chai_fused_decode_ref(q_rep, k_pool, bt_k, v_pool, bt_v, h2c,
                                pos, *, k_scale_pool=None,
                                v_scale_pool=None, reps_per_group=0,
                                share_values=False, window=0, softcap=0.0):
    """Oracle for ``paged_chai_fused_decode``: densify then dense-ref."""
    return chai_fused_decode_ref(
        q_rep, gather_pages_ref(k_pool, bt_k),
        gather_pages_ref(v_pool, bt_v), h2c, pos,
        k_scale=(None if k_scale_pool is None
                 else gather_pages_ref(k_scale_pool, bt_k)),
        v_scale=(None if v_scale_pool is None
                 else gather_pages_ref(v_scale_pool, bt_v)),
        reps_per_group=reps_per_group, share_values=share_values,
        window=window, softcap=softcap)


# ------------------------------------- three-kernel pipeline (oracle) ------
def chai_three_kernel_decode(q_rep, k_cache, v_cache, h2c, pos, *,
                             k_scale=None, v_scale=None, reps_per_group=1,
                             share_values=False, window=0, ts=512,
                             interpret=True):
    """The pre-fusion production path — QK kernel -> row softmax kernel ->
    AV kernel, materializing the (B, R, S) score tensor between launches.
    Kept ONLY as the oracle / baseline for the fused kernel (3 launches +
    one HBM round-trip of the scores; see ``ops.decode_launch_count``)."""
    from repro.kernels import chai_attention as ck
    if k_scale is not None:
        sc = ck.chai_qk_i8(q_rep, k_cache, k_scale, pos,
                           reps_per_group=reps_per_group, window=window,
                           ts=ts, interpret=interpret)
    else:
        sc = ck.chai_qk(q_rep, k_cache, pos, reps_per_group=reps_per_group,
                        window=window, ts=ts, interpret=interpret)
    a = ck.row_softmax(sc, interpret=interpret)
    vf = v_cache
    if v_scale is not None:    # no int8 AV kernel existed; dequant outside
        vf = v_cache.astype(jnp.float32) * v_scale[..., None]
    b = q_rep.shape[0]
    if h2c.ndim == 1:
        h2c = jnp.broadcast_to(h2c, (b, h2c.shape[0]))
    h = h2c.shape[1]
    if share_values:
        # Clustered V: AV per rep row, gather members after.
        r = a.shape[1]
        out_rep = ck.chai_av(a, vf, jnp.arange(r, dtype=jnp.int32), ts=ts,
                             interpret=interpret)
        return jnp.take_along_axis(out_rep, h2c[..., None], axis=1)
    if vf.shape[1] != h:       # GQA: expand per-group V to per-head rows
        vf = jnp.repeat(vf, h // vf.shape[1], axis=1)
    return ck.chai_av(a, vf, h2c, ts=ts, interpret=interpret)


def paged_chai_three_kernel_decode(q_rep, k_pool, bt_k, v_pool, bt_v, h2c,
                                   pos, *, reps_per_group=1,
                                   share_values=False, window=0,
                                   interpret=True):
    """Paged three-kernel pipeline (fp32 pools), kept as the fused paged
    kernel's launch-count / parity baseline."""
    from repro.kernels import chai_attention as ck
    sc = ck.paged_chai_qk(q_rep, k_pool, bt_k, pos,
                          reps_per_group=reps_per_group, window=window,
                          interpret=interpret)
    a = ck.row_softmax(sc, interpret=interpret)
    b = q_rep.shape[0]
    if h2c.ndim == 1:
        h2c = jnp.broadcast_to(h2c, (b, h2c.shape[0]))
    if share_values:
        r = a.shape[1]
        out_rep = ck.paged_chai_av(a, v_pool, bt_v,
                                   jnp.arange(r, dtype=jnp.int32),
                                   interpret=interpret)
        return jnp.take_along_axis(out_rep, h2c[..., None], axis=1)
    h = h2c.shape[1]
    if v_pool.shape[1] != h:   # GQA: expand per-group V pool rows
        v_pool = jnp.repeat(v_pool, h // v_pool.shape[1], axis=1)
    return ck.paged_chai_av(a, v_pool, bt_v, h2c, interpret=interpret)


# ------------------------------------------- relay shared-prefix decode ----
def relay_prefix_decode_ref(q, k, v, k_row, a_row, v_row, plen, *,
                            k_scale=None, v_scale=None, softcap=0.0):
    """Oracle for ``relay_prefix_decode``: dense row gathers + the masked
    softmax state computed in one shot. q: (G, NR, hd); k: (G, KV, Sp,
    hd); v: (G, VR, Sp, hd); k_row: (G, NR); a_row/v_row: (G, A); plen:
    (G,). Returns (m (G, NR), l (G, NR), acc (G, A, hd)) f32."""
    g, nr, hd = q.shape
    sp = k.shape[2]
    kf = k.astype(jnp.float32)
    if k_scale is not None:
        kf = kf * k_scale.astype(jnp.float32)[..., None]
    kg = jnp.take_along_axis(
        kf, k_row[:, :, None, None].astype(jnp.int32), axis=1)
    sc = jnp.einsum("gre,grse->grs", q.astype(jnp.float32),
                    kg) / jnp.sqrt(jnp.float32(hd))
    sc = _softcap(sc, softcap)
    idx = jnp.arange(sp, dtype=jnp.int32)
    sc = jnp.where(idx[None, None, :] < plen[:, None, None], sc, NEG_INF)
    m = jnp.maximum(jnp.max(sc, axis=-1), -1e30)          # (G, NR)
    p = jnp.exp(sc - m[..., None])                        # (G, NR, Sp)
    l = jnp.sum(p, axis=-1)                               # (G, NR)
    vf = v.astype(jnp.float32)
    if v_scale is not None:
        vf = vf * v_scale.astype(jnp.float32)[..., None]
    vg = jnp.take_along_axis(
        vf, v_row[:, :, None, None].astype(jnp.int32), axis=1)
    p_a = jnp.take_along_axis(p, a_row[:, :, None].astype(jnp.int32),
                              axis=1)                     # (G, A, Sp)
    acc = jnp.einsum("gas,gasd->gad", p_a, vg)            # (G, A, hd)
    return m, l, acc


def paged_prefix_attend_ref(q, kv_pool, bt_k, bt_v, plen, *,
                            k_scale_pool=None, v_scale_pool=None,
                            softcap=0.0):
    """Oracle for ``paged_prefix_attend``: densify the pool through the
    block tables, then the non-causal masked softmax state. Returns the
    head-major triple (m (B, H, T), l (B, H, T), acc (B, H, T, hd))."""
    b, t, h, hd = q.shape
    kf = gather_pages_ref(kv_pool, bt_k).astype(jnp.float32)
    if k_scale_pool is not None:
        kf = kf * gather_pages_ref(k_scale_pool, bt_k)[..., None]
    vf = gather_pages_ref(kv_pool, bt_v).astype(jnp.float32)
    if v_scale_pool is not None:
        vf = vf * gather_pages_ref(v_scale_pool, bt_v)[..., None]
    qpk = h // kf.shape[1]
    kf = jnp.repeat(kf, qpk, axis=1)                      # (B, H, S, hd)
    vf = jnp.repeat(vf, qpk, axis=1)
    qh = q.transpose(0, 2, 1, 3).astype(jnp.float32)      # (B, H, T, hd)
    sc = jnp.einsum("bhtd,bhsd->bhts", qh, kf) / jnp.sqrt(
        jnp.float32(hd))
    sc = _softcap(sc, softcap)
    s = kf.shape[2]
    idx = jnp.arange(s, dtype=jnp.int32)
    sc = jnp.where(idx[None, None, None, :] < plen[:, None, None, None],
                   sc, NEG_INF)
    # plen == 0 rows never run a tile in-kernel, so their m stays NEG_INF
    # (the merge identity); computed rows clamp at -1e30 like every kernel.
    m = jnp.where(plen[:, None, None] > 0,
                  jnp.maximum(jnp.max(sc, axis=-1), -1e30),
                  NEG_INF)                                # (B, H, T)
    p = jnp.where(plen[:, None, None, None] > 0,
                  jnp.exp(sc - m[..., None]), 0.0)
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bhts,bhsd->bhtd", p, vf)
    return m, l, acc
