"""Paper Tables 1-3 proxy: policy fidelity on a trained tiny LM.

Per policy (MHA baseline, CHAI, CHAI-static, DejaVu at 3 sparsities,
SpAtten, random clustering): attention-output cosine fidelity per layer +
end-to-end greedy-token agreement + perplexity delta on held-out synthetic
data. PROXY for the paper's task accuracies (no C4/PIQA offline).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import (collect_qkv, redundant_model, save_result,
                               tiny_trained)
from repro.core.policy import apply_policy
from repro.models import transformer as tfm


def _cosine(a, b):
    a = np.asarray(a, np.float64).reshape(-1)
    b = np.asarray(b, np.float64).reshape(-1)
    return float(a @ b / (np.linalg.norm(a) * np.linalg.norm(b) + 1e-12))


def _ppl(cfg, params, toks):
    logits, _, _ = tfm.forward_fullseq(params, cfg, toks[:, :-1])
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, toks[:, 1:, None], axis=-1)[..., 0]
    return float(jnp.exp(jnp.mean(logz - gold)))


def run():
    # redundancy-planted trained model: emulates the measured LLaMA-7B
    # head-cluster structure (Fig 2) at tiny scale — see common.py.
    cfg, params, pipe, train_loss = redundant_model()
    toks = jnp.asarray(pipe.batch(500)["tokens"][:4, :48])
    qkvs = collect_qkv(cfg, params, toks)

    k = 4   # true planted cluster count
    policies = {
        "mha": dict(policy="mha"),
        "chai": dict(policy="chai", n_clusters=k),
        "chai-static": dict(policy="chai-static", n_clusters=k,
                            h2c_static=jnp.arange(cfg.n_heads) % k,
                            reps_static=jnp.arange(k)),
        "chai-qkv": dict(policy="chai-qkv", n_clusters=k),
        "dejavu-10%": dict(policy="dejavu", sparsity=0.10),
        "dejavu-30%": dict(policy="dejavu", sparsity=0.30),
        "dejavu-50%": dict(policy="dejavu", sparsity=0.50),
        "spatten": dict(policy="spatten", sparsity=0.25, token_keep=0.7),
        "random": dict(policy="random", n_clusters=k),
    }

    fidelity = {}
    flops = {}
    base_outs = [apply_policy("mha", *qkv).out for qkv in qkvs]
    for name, kw in policies.items():
        cos, fl = [], 0.0
        for qkv, base in zip(qkvs, base_outs):
            out = apply_policy(**kw, q=qkv[0], k=qkv[1], v=qkv[2])
            cos.append(_cosine(out.out, base))
            fl += float(out.score_flops)
        fidelity[name] = float(np.mean(cos))
        flops[name] = fl

    ppl = _ppl(cfg, params, jnp.asarray(pipe.batch(501)["tokens"][:4]))

    result = {
        "proxy_note": "trained tiny LM with planted head redundancy "
                      "(emulating LLaMA-7B's measured >0.95-correlation "
                      "clusters, Fig 2); cosine fidelity of attention "
                      "outputs vs MHA + PPL; stands in for paper Tables "
                      "1-3 task accuracy",
        "train_loss": train_loss,
        "held_out_ppl_mha": ppl,
        "attention_output_cosine_vs_mha": fidelity,
        "score_flops": flops,
        "paper_claim": "CHAI within 3.2% of MHA accuracy; DejaVu>=30% "
                       "degrades heavily on LLaMA-family; activation "
                       "clustering beats random/static head grouping",
        "claim_check": {
            "chai_fidelity_high": fidelity["chai"] > 0.98,
            "chai_beats_random": fidelity["chai"] > fidelity["random"],
            "chai_beats_dejavu50": fidelity["chai"] > fidelity["dejavu-50%"],
            "chai_beats_spatten": fidelity["chai"] > fidelity["spatten"],
            "chai_dynamic_beats_static":
                fidelity["chai"] >= fidelity["chai-static"] - 1e-3,
        },
    }
    save_result("bench_accuracy_proxy", result)
    return result


if __name__ == "__main__":
    print(run())
