"""H2O-Danube 1.8B [arXiv:2401.16818]: llama+mistral mix, sliding-window."""
from repro.configs.base import ModelConfig, CHAIConfig, register, ATTN_LOCAL

CONFIG = register(ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6912,
    vocab_size=32000,
    layer_types=(ATTN_LOCAL,) * 24,   # mistral-style SWA
    window_size=4096,
    activation="silu",
    rope_theta=10000.0,
    chai=CHAIConfig(enabled=True),
))
