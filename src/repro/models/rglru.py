"""RecurrentGemma recurrent block: temporal conv + RG-LRU (Griffin).

Full-sequence path uses ``lax.associative_scan`` (parallel prefix) over the
linear recurrence h_t = a_t * h_{t-1} + b_t — the TPU-native way to lower a
diagonal RNN (log-depth, MXU-free elementwise). Decode is a single fused
step. State: (h, conv_tail).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_C = 8.0  # Griffin's fixed recurrence sharpness constant


def _gates(x_br, p):
    """Recurrence gate a_t and input gate i_t from the x branch."""
    r = jax.nn.sigmoid(jnp.einsum("...d,de->...e", x_br, p["w_a"]))
    i = jax.nn.sigmoid(jnp.einsum("...d,de->...e", x_br, p["w_i"]))
    log_a = -_C * jax.nn.softplus(p["log_lambda"]) * r      # (..., rnn)
    return log_a, i


def _causal_conv_full(x, w, b):
    """x: (B, T, D); w: (cw, D) depthwise causal conv; b: (D,)."""
    cw = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(cw):  # cw is small (4): unrolled taps
        out = out + pad[:, i: i + x.shape[1], :] * w[cw - 1 - i]
    return out + b


def rglru_fullseq(x, p, cfg, h0=None, conv_tail=None):
    """x: (B, T, d) -> (y, (h_T, conv_tail)).

    h0: (B, rnn) initial state; conv_tail: (B, cw-1, rnn) trailing inputs.
    """
    bsz, t, _ = x.shape
    rw = cfg.rnn_width
    xb = jnp.einsum("btd,de->bte", x, p["w_x"])              # (B, T, rnn)
    gate = jax.nn.gelu(jnp.einsum("btd,de->bte", x, p["w_gate"]))

    if conv_tail is not None:
        xb_ext = jnp.concatenate([conv_tail, xb], axis=1)
        xb_conv = _causal_conv_full(xb_ext, p["conv_w"], p["conv_b"])
        xb_conv = xb_conv[:, conv_tail.shape[1]:]
    else:
        xb_conv = _causal_conv_full(xb, p["conv_w"], p["conv_b"])

    log_a, i_gate = _gates(xb_conv, p)                       # (B, T, rnn)
    a = jnp.exp(log_a.astype(jnp.float32))
    gated_x = (i_gate * xb_conv).astype(jnp.float32)
    b_t = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-12)) * gated_x

    if h0 is not None:
        # Fold h0 in as a virtual step at t=-1 with a=0, b=h0.
        a = jnp.concatenate([jnp.zeros_like(a[:, :1]), a], axis=1)
        b_t = jnp.concatenate([h0.astype(jnp.float32)[:, None], b_t], axis=1)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    a_sc, h = jax.lax.associative_scan(combine, (a, b_t), axis=1)
    if h0 is not None:
        h = h[:, 1:]
    h = h.astype(x.dtype)

    y = jnp.einsum("bte,ed->btd", h * gate, p["w_out"])
    new_tail = (jnp.concatenate([conv_tail, xb], axis=1)[:, -(cfg.conv_width - 1):]
                if conv_tail is not None else xb[:, -(cfg.conv_width - 1):])
    return y, (h[:, -1], new_tail)


def rglru_decode(x, p, cfg, h, conv_tail):
    """One-step decode. x: (B, d); h: (B, rnn); conv_tail: (B, cw-1, rnn)."""
    xb = jnp.einsum("bd,de->be", x, p["w_x"])
    gate = jax.nn.gelu(jnp.einsum("bd,de->be", x, p["w_gate"]))
    window = jnp.concatenate([conv_tail, xb[:, None]], axis=1)  # (B, cw, rnn)
    # window is time-ordered (oldest first); conv_w[j] weights the token
    # j steps back -> flip taps to align with the causal full-seq conv.
    xb_conv = jnp.einsum("bcw,cw->bw", window,
                         p["conv_w"][::-1]) + p["conv_b"]
    log_a, i_gate = _gates(xb_conv, p)
    a = jnp.exp(log_a.astype(jnp.float32))
    b_t = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-12)) \
        * (i_gate * xb_conv).astype(jnp.float32)
    h_new = (a * h.astype(jnp.float32) + b_t).astype(x.dtype)
    y = jnp.einsum("be,ed->bd", h_new * gate, p["w_out"])
    return y, (h_new, window[:, 1:])
