"""Continuous-batching serving engine with a per-slot CHAI phase machine.

Request lifecycle (paper Fig 10), tracked PER BATCH SLOT:

    PREFILL  --(batch=1 full forward; KV rows written into the slot)-->
    WARMUP   --(MHA decode steps; per-head attention scores accumulate
                into the slot's clustering-feature buffer)-->
    CLUSTER  --(per-slot K-Means membership identification; the slot's
                dense K rows are compacted to representative rows — the
                paper's 21.4% KV saving — via a donated slot-indexed
                gather)-->
    STEADY   --(Clustered Head Attention decode until max_tokens)

Two schedulers (``EngineConfig.scheduler``):

* ``"continuous"`` (default) — slot-level continuous batching. A fixed
  pool of batch slots (static shapes for XLA) holds requests at
  *different* phases simultaneously: each slot is admitted, warmed up,
  clustered, retired, and reused independently every step, so a short
  request never waits for a long one (no head-of-line blocking). The
  decode step is one jit that routes each slot to the MHA or CHAI
  attention path according to the per-slot ``phase`` vector
  (mask-and-select, static shapes); when no slot is mid-transition the
  engine host-dispatches to the cheaper all-MHA / all-CHAI jits. The
  cache is the *unified per-slot KV layout*
  (``repro.core.cache.unified_state_structs``): dense ``kg``/``vg`` and
  clustered ``kg_chai`` buffers resident side by side.

* ``"cohort"`` — the legacy lockstep path, kept for A/B parity testing:
  requests admitted together move through phases together, with the
  cohort-deadline straggler re-dispatch mitigation.

Every Request records arrival, admission (slot id + engine step), first
token, and completion, so per-request TTFT / latency and engine
throughput fall out directly. On-CPU usage: reduced configs; the same
engine code drives TPU meshes by passing ``mesh`` + shardings.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import cache as chai_cache
from repro.core import clustering
from repro.launch import steps as steps_mod


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # (T,) int32
    max_new_tokens: int = 32
    # -- filled by the engine --
    generated: Optional[List[int]] = None
    t_enqueue: float = 0.0
    t_arrival: float = 0.0             # Poisson workloads: earliest admit
    t_first_token: float = 0.0
    t_done: float = 0.0
    slot: int = -1                     # continuous: slot the request ran in
    admit_step: int = -1               # continuous: engine step at admission
    retire_step: int = -1              # continuous: engine step at retire

    @property
    def ttft(self):
        return self.t_first_token - self.t_arrival

    @property
    def latency(self):
        return self.t_done - self.t_arrival


@dataclasses.dataclass
class EngineConfig:
    batch_slots: int = 4               # slot-pool / cohort size (static)
    max_seq: int = 256                 # KV capacity (static)
    greedy: bool = True
    scheduler: str = "continuous"      # "continuous" | "cohort"
    cohort_deadline_s: float = 120.0   # cohort straggler re-dispatch
    use_chai: bool = True


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, ecfg: EngineConfig):
        assert cfg.n_attn_layers > 0 or not ecfg.use_chai, \
            "CHAI needs attention layers"
        assert ecfg.scheduler in ("continuous", "cohort"), ecfg.scheduler
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        self.queue: deque = deque()
        self.done: List[Request] = []
        self.redispatched = 0
        self.steps_executed = 0        # continuous: batched decode steps
        b, s = ecfg.batch_slots, ecfg.max_seq

        chai_on = ecfg.use_chai and cfg.chai.enabled and cfg.k_max > 0
        self.chai_on = chai_on
        # jax.jit wrappers are lazy (no tracing until the first call), so
        # both schedulers' steps are declared here unconditionally.
        self._mha_step = jax.jit(steps_mod.make_serve_step(cfg, chai=False),
                                 donate_argnums=(2,))
        self._prefill = jax.jit(steps_mod.make_serve_prefill(cfg, b, s))
        self._reset_slot = jax.jit(steps_mod.make_slot_reset(cfg),
                                   donate_argnums=(0,))
        self._slot_prefills: dict = {}       # prompt length -> jit
        self._cluster_slot = None            # built lazily (identify hook)
        if chai_on:
            self._chai_step = jax.jit(
                steps_mod.make_serve_step(cfg, chai=True),
                donate_argnums=(2,))
            self._mixed_step = jax.jit(steps_mod.make_mixed_step(cfg),
                                       donate_argnums=(2,))
            self._compact = jax.jit(steps_mod.make_compact_step(cfg),
                                    donate_argnums=(0,))
            self._identify = jax.jit(
                lambda sc: clustering.identify_membership(sc, cfg))

    # -- public API --------------------------------------------------------
    def submit(self, prompt, max_new_tokens=32, uid=None, *,
               arrival_delay: float = 0.0):
        """Enqueue a request. ``arrival_delay`` (seconds from now) models
        open-loop arrivals: the scheduler will not admit the request
        before its arrival time."""
        req = Request(uid=uid if uid is not None else len(self.queue)
                      + len(self.done),
                      prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=max_new_tokens)
        req.t_enqueue = time.time()
        req.t_arrival = req.t_enqueue + arrival_delay
        req.generated = []
        self.queue.append(req)
        return req

    def run(self):
        """Drain the queue; returns completed requests."""
        if self.ecfg.scheduler == "cohort":
            return self._run_cohort_loop()
        return self._run_continuous()

    # -- continuous scheduler ----------------------------------------------
    def _slot_prefill_fn(self, t: int):
        fn = self._slot_prefills.get(t)
        if fn is None:
            fn = jax.jit(
                steps_mod.make_slot_prefill(self.cfg, self.ecfg.max_seq),
                donate_argnums=(2,))
            self._slot_prefills[t] = fn
        return fn

    def _cluster_fn(self):
        # Built on first use so a monkeypatched ``_identify`` hook (tests,
        # CHAI-static ablations) is honored.
        if self._cluster_slot is None:
            self._cluster_slot = jax.jit(
                steps_mod.make_slot_cluster(self.cfg, self._identify),
                donate_argnums=(0, 1))
        return self._cluster_slot

    def _run_continuous(self):
        cfg, ecfg = self.cfg, self.ecfg
        b = ecfg.batch_slots
        warm = cfg.chai.warmup_tokens if self.chai_on else 0
        state = chai_cache.init_unified_state(cfg, b, ecfg.max_seq,
                                              chai=self.chai_on)
        ctx = clustering.init_batched_ctx(cfg, b) if self.chai_on else None
        slot_req: List[Optional[Request]] = [None] * b
        slot_count = [0] * b            # tokens generated this admission
        next_tok = np.zeros((b,), np.int32)   # host mirror
        next_tok_dev = jnp.zeros((b,), jnp.int32)
        phases = np.full((b,), chai_cache.PHASE_FREE, np.int32)

        def retire(i):
            r = slot_req[i]
            r.generated = r.generated[:r.max_new_tokens]
            r.t_done = time.time()
            r.retire_step = self.steps_executed
            self.done.append(r)
            slot_req[i] = None
            phases[i] = chai_cache.PHASE_FREE
            return self._reset_slot(state, jnp.int32(i))

        while self.queue or any(r is not None for r in slot_req):
            now = time.time()
            # ---- admit: fill free slots from the arrived FIFO prefix ----
            admitted = False
            for i in range(b):
                if slot_req[i] is not None or not self.queue:
                    continue
                if self.queue[0].t_arrival > now:
                    break
                req = self.queue.popleft()
                phases[i] = chai_cache.PHASE_PREFILL
                toks = jnp.asarray(req.prompt[None, :])
                logits, state = self._slot_prefill_fn(len(req.prompt))(
                    self.params, toks, state, jnp.int32(i))
                tok = int(np.asarray(self._sample(logits))[0])
                req.t_first_token = time.time()
                req.generated.append(tok)
                req.slot, req.admit_step = i, self.steps_executed
                next_tok[i] = tok
                admitted = True
                slot_req[i] = req
                slot_count[i] = 1
                phases[i] = chai_cache.PHASE_WARMUP
                if len(req.generated) >= req.max_new_tokens:
                    state = retire(i)

            active = [i for i in range(b) if slot_req[i] is not None]
            if not active:
                if self.queue:      # open-loop idle: wait for next arrival
                    time.sleep(max(1e-4,
                                   self.queue[0].t_arrival - time.time()))
                    continue
                break

            # ---- cluster + compact slots whose warmup just completed ----
            if self.chai_on:
                for i in active:
                    if (slot_count[i] == warm + 1
                            and phases[i] == chai_cache.PHASE_WARMUP):
                        phases[i] = chai_cache.PHASE_CLUSTER
                        state, ctx = self._cluster_fn()(state, ctx,
                                                        jnp.int32(i))
                        phases[i] = chai_cache.PHASE_STEADY

            # ---- one batched decode step; host-dispatch the cheapest jit
            # that covers the current phase mix. The token vector lives on
            # device between steps; the host mirror is re-uploaded only
            # after an admission edited it. ----
            if admitted:
                next_tok_dev = jnp.asarray(next_tok)
            inputs = {"tokens": next_tok_dev}
            occupied = phases[phases != chai_cache.PHASE_FREE]
            if not self.chai_on:
                logits, state = self._mha_step(self.params, inputs, state)
            elif (occupied == chai_cache.PHASE_STEADY).all():
                logits, state = self._chai_step(self.params, inputs, state,
                                                ctx)
            elif (occupied == chai_cache.PHASE_WARMUP).all():
                logits, state = self._mha_step(self.params, inputs, state)
            else:
                logits, state = self._mixed_step(self.params, inputs, state,
                                                 ctx)
            next_tok_dev = self._sample(logits)
            toks = np.asarray(next_tok_dev)
            next_tok[:] = toks
            self.steps_executed += 1
            for i in active:
                r = slot_req[i]
                r.generated.append(int(toks[i]))
                slot_count[i] += 1
                if len(r.generated) >= r.max_new_tokens:
                    state = retire(i)
        return self.done

    # -- cohort scheduler --------------------------------------------------
    def _run_cohort_loop(self):
        while self.queue:
            if self.queue[0].t_arrival > time.time():
                time.sleep(max(1e-4,
                               self.queue[0].t_arrival - time.time()))
                continue
            cohort = []
            while (self.queue and len(cohort) < self.ecfg.batch_slots
                   and self.queue[0].t_arrival <= time.time()):
                cohort.append(self.queue.popleft())
            try:
                self._run_cohort(cohort)
            except TimeoutError:
                # cohort exceeded its deadline: re-dispatch unfinished
                self.redispatched += len(cohort)
                for r in cohort:
                    if len(r.generated) < r.max_new_tokens:
                        self.queue.append(r)
                    else:
                        self.done.append(r)
        return self.done

    def _pad_prompts(self, cohort):
        b, s = self.ecfg.batch_slots, self.ecfg.max_seq
        t = max(len(r.prompt) for r in cohort)
        toks = np.zeros((b, t), np.int32)
        for i, r in enumerate(cohort):
            toks[i, t - len(r.prompt):] = r.prompt    # left-pad
        return jnp.asarray(toks), t

    def _run_cohort(self, cohort):
        cfg, ecfg = self.cfg, self.ecfg
        deadline = time.time() + ecfg.cohort_deadline_s
        tokens, t = self._pad_prompts(cohort)
        logits, state = self._prefill(self.params, {"tokens": tokens})
        t_first = time.time()
        for r in cohort:
            r.t_first_token = t_first
        next_tok = self._sample(logits)
        self._record(cohort, next_tok)

        warm = cfg.chai.warmup_tokens if self.chai_on else 0
        max_new = max(r.max_new_tokens for r in cohort)

        # ---- WARMUP: MHA decode, accumulating clustering features ----
        if self.chai_on:
            state = chai_cache.add_score_buffer(state, cfg,
                                                ecfg.batch_slots)
        step = 1
        while step < max_new and step <= warm:
            if time.time() > deadline:
                raise TimeoutError
            logits, state = self._mha_step(
                self.params, {"tokens": next_tok}, state)
            next_tok = self._sample(logits)
            self._record(cohort, next_tok)
            self.steps_executed += 1
            step += 1

        # ---- CLUSTER + COMPACT: membership ID, K-cache gather ----
        ctx = None
        if self.chai_on and step <= max_new:
            state, scores = chai_cache.pop_score_buffer(state)
            ctx = self._identify(scores)
            state = self._compact(state, ctx)

        # ---- STEADY: Clustered Head Attention decode ----
        while step < max_new:
            if time.time() > deadline:
                raise TimeoutError
            if ctx is not None:
                logits, state = self._chai_step(
                    self.params, {"tokens": next_tok}, state, ctx)
            else:
                logits, state = self._mha_step(
                    self.params, {"tokens": next_tok}, state)
            next_tok = self._sample(logits)
            self._record(cohort, next_tok)
            self.steps_executed += 1
            step += 1

        t_done = time.time()
        for r in cohort:
            r.generated = r.generated[:r.max_new_tokens]
            r.t_done = t_done
            self.done.append(r)

    def _sample(self, logits):
        if self.ecfg.greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        raise NotImplementedError("sampling beyond greedy")

    @staticmethod
    def _record(cohort, next_tok):
        toks = np.asarray(next_tok)
        for i, r in enumerate(cohort):
            r.generated.append(int(toks[i]))

    # -- metrics ------------------------------------------------------------
    def kv_bytes(self, *, chai: Optional[bool] = None):
        """KV-cache bytes. With explicit ``chai=``: the paper's analytic
        steady-state size (Fig 11 A/B comparisons). With no argument:
        this engine's actual resident footprint — for the continuous
        scheduler's unified layout that is dense + clustered buffers
        side by side (MORE than plain MHA; the cohort scheduler frees
        the dense cache at compaction and reports the analytic size)."""
        if chai is None and self.ecfg.scheduler == "continuous":
            return chai_cache.unified_kv_bytes(
                self.cfg, self.ecfg.batch_slots, self.ecfg.max_seq,
                chai=self.chai_on)
        chai = self.chai_on if chai is None else chai
        return chai_cache.kv_cache_bytes(
            self.cfg, self.ecfg.batch_slots, self.ecfg.max_seq, chai=chai)

    def throughput(self):
        """Completed requests per second of engine wall time."""
        if not self.done:
            return 0.0
        t0 = min(r.t_arrival for r in self.done)
        t1 = max(r.t_done for r in self.done)
        return len(self.done) / max(t1 - t0, 1e-9)
