"""Unified per-slot KV layout: quantization round-trips and per-slot
compaction parity with the whole-batch gather."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, reduced
from repro.core import cache as chai_cache
from repro.core import clustering
from repro.models.transformer import init_decode_state


# ------------------------------------------------------------- quant -------
@pytest.mark.parametrize("shape,seed,scale", [
    ((4, 16), 0, 1.0), ((2, 3, 8), 1, 100.0), ((7,  64), 2, 1e-3),
    ((1, 1, 1, 4), 3, 1.0), ((5, 32), 4, 1e4),
])
def test_quant_rows_roundtrip_bound(shape, seed, scale):
    """Property: per-row symmetric int8 reconstructs within half a grid
    step of the row scale, for any shape/magnitude."""
    x = jnp.asarray(np.random.default_rng(seed).normal(size=shape) * scale,
                    jnp.float32)
    q, s = chai_cache.quant_rows(x)
    assert q.dtype == jnp.int8 and s.shape == shape[:-1]
    err = np.abs(np.asarray(chai_cache.dequant_rows(q, s)) - np.asarray(x))
    bound = 0.5 * np.asarray(s)[..., None] + 1e-7
    assert (err <= bound).all()
    # int8 range respected, scale strictly positive
    assert np.asarray(q).min() >= -127 and np.asarray(q).max() <= 127
    assert (np.asarray(s) > 0).all()


def test_quant_rows_zero_row_stable():
    q, s = chai_cache.quant_rows(jnp.zeros((3, 8)))
    assert (np.asarray(q) == 0).all() and np.isfinite(np.asarray(s)).all()
    assert (np.asarray(chai_cache.dequant_rows(q, s)) == 0).all()


# ----------------------------------------------------- per-slot compact ----
def _mha_cfg(share_values, int8):
    cfg = reduced(get_config("chai-llama-7b"), n_layers=2, d_model=32,
                  d_ff=64, vocab=64).replace(dtype="float32")
    if int8:
        cfg = cfg.replace(kv_cache_dtype="int8")
    return cfg.with_chai(enabled=True, share_values=share_values,
                         cluster_counts=(3,) * cfg.n_attn_layers)


@pytest.mark.parametrize("share_values", [False, True])
@pytest.mark.parametrize("int8", [False, True])
def test_compact_kv_slot_matches_whole_batch(rng, share_values, int8):
    """Per-slot donated gather == the cohort path's whole-batch
    ``compact_kv`` for every share_values / int8 cache combination."""
    cfg = _mha_cfg(share_values, int8)
    b, s = 3, 16
    dense = init_decode_state(cfg, b, s)
    for k in dense:
        if k == "pos":
            continue
        if dense[k].dtype == jnp.int8:
            dense[k] = jnp.asarray(
                rng.integers(-127, 128, size=dense[k].shape), jnp.int8)
        else:
            dense[k] = jnp.asarray(rng.normal(size=dense[k].shape),
                                   dense[k].dtype)
    k_max, _ = clustering.chai_widths(cfg)
    reps = jnp.asarray(
        rng.integers(0, cfg.n_heads, size=(cfg.n_attn_layers, b, k_max)),
        jnp.int32)

    whole = chai_cache.compact_kv(dict(dense), {"reps": reps}, cfg)

    unified = chai_cache.init_unified_state(cfg, b, s)
    for k, v in dense.items():
        unified[k] = v
    compact = jax.jit(chai_cache.compact_kv_slot,
                      static_argnames=("cfg",), donate_argnums=(0,))
    for i in range(b):
        slot_ctx = {"reps": reps[:, i]}
        unified = compact(unified, slot_ctx, cfg, jnp.int32(i))

    for key in ("kg_chai", "kg_chai_scale", "vg_chai"):
        if key in whole:
            np.testing.assert_array_equal(np.asarray(whole[key]),
                                          np.asarray(unified[key]), key)
    # phase machine advanced every slot to STEADY
    assert (np.asarray(unified["phase"]) == chai_cache.PHASE_STEADY).all()
    # unified layout: the dense cache stays resident for warmup slots
    assert "kg" in unified and "kg" not in whole


@pytest.mark.parametrize("share_values", [False, True])
@pytest.mark.parametrize("int8", [False, True])
def test_unified_kv_bytes_accounts_both_layouts(share_values, int8):
    """The unified layout is honest about its cost: resident bytes =
    dense cache + the clustered extension (MORE than dense alone; the
    21.4%-style saving is the cohort/steady-state analytic number)."""
    cfg = _mha_cfg(share_values, int8)
    b, s = 2, 32
    dense = chai_cache.kv_cache_bytes(cfg, b, s, chai=False)
    unified = chai_cache.unified_kv_bytes(cfg, b, s)
    assert unified > dense
    # exact: sum of the layout's own KV buffers
    shapes, _ = chai_cache.unified_state_structs(cfg, b, s)
    expect = sum(int(np.prod(st.shape)) * st.dtype.itemsize
                 for k, st in shapes.items()
                 if k not in ("pos", "phase", "chai_scores"))
    assert unified == expect
    # without CHAI the unified layout reduces to the dense cache
    assert chai_cache.unified_kv_bytes(cfg, b, s, chai=False) == dense


@pytest.mark.parametrize("share_values", [False, True])
@pytest.mark.parametrize("int8", [False, True])
def test_compact_kv_slot_paged_matches_whole_batch(rng, share_values, int8):
    """Paged per-slot compaction == the cohort path's whole-batch
    ``compact_kv``: inserting each slot's dense rows into pages,
    compacting, and densifying the clustered pages reproduces
    ``kg_chai`` (and scales / ``vg_chai``) bit-for-bit — while the dense
    block-table rows are nulled (the pages become freeable)."""
    cfg = _mha_cfg(share_values, int8)
    b, s, page = 3, 16, 8
    n_slot = s // page
    dense = init_decode_state(cfg, b, s)
    for k in dense:
        if k == "pos":
            dense[k] = jnp.full((b,), s - 1, jnp.int32)
        elif dense[k].dtype == jnp.int8:
            dense[k] = jnp.asarray(
                rng.integers(-127, 128, size=dense[k].shape), jnp.int8)
        else:
            dense[k] = jnp.asarray(rng.normal(size=dense[k].shape),
                                   dense[k].dtype)
    k_max, _ = clustering.chai_widths(cfg)
    reps = jnp.asarray(
        rng.integers(0, cfg.n_heads, size=(cfg.n_attn_layers, b, k_max)),
        jnp.int32)

    whole = chai_cache.compact_kv(dict(dense), {"reps": reps}, cfg)

    n_chai = (2 if share_values else 1) * b * n_slot + 1
    paged = chai_cache.init_paged_state(
        cfg, b, s, page_size=page, dense_pages=2 * b * n_slot + 1,
        chai_pages=n_chai)
    dense_pool = chai_cache.PagePool(2 * b * n_slot + 1, page)
    chai_pool = chai_cache.PagePool(n_chai, page)
    pages = []
    for i in range(b):
        mini = {k: v[:, i:i + 1] if v.ndim > 1 else v[i:i + 1]
                for k, v in dense.items()}
        pg = {"kg": dense_pool.alloc(n_slot), "vg": dense_pool.alloc(n_slot),
              "kc": chai_pool.alloc(n_slot)}
        if share_values:
            pg["vc"] = chai_pool.alloc(n_slot)
        pages.append(pg)
        paged = chai_cache.insert_slot_paged(
            paged, mini, i, jnp.asarray(pg["kg"], jnp.int32),
            jnp.asarray(pg["vg"], jnp.int32))
    compact = jax.jit(chai_cache.compact_kv_slot_paged,
                      static_argnames=("cfg",), donate_argnums=(0,))
    for i in range(b):
        paged = compact(paged, {"reps": reps[:, i]}, cfg, jnp.int32(i),
                        jnp.asarray(pages[i]["kc"], jnp.int32),
                        jnp.asarray(pages[i].get("vc", pages[i]["kc"]),
                                    jnp.int32))

    def densify(pool, bt):     # (nG, nP, rows, page[,hd]), (b, P) -> slot i
        return np.concatenate(
            [np.asarray(pool[:, bt[i]]).swapaxes(1, 2).reshape(
                pool.shape[0], pool.shape[2], -1, *pool.shape[4:])
             [:, None] for i in range(b)], axis=1)

    bt_kc = np.asarray(paged["bt_kc"])
    np.testing.assert_array_equal(np.asarray(whole["kg_chai"]),
                                  densify(np.asarray(paged["cp"]), bt_kc))
    if int8:
        np.testing.assert_array_equal(
            np.asarray(whole["kg_chai_scale"]),
            densify(np.asarray(paged["cp_scale"]), bt_kc))
    if share_values:
        np.testing.assert_array_equal(
            np.asarray(whole["vg_chai"]),
            densify(np.asarray(paged["cp"]), np.asarray(paged["bt_vc"])))
    # dense K tables nulled (pages freeable); V tables nulled only under
    # share_values; every slot advanced to STEADY
    assert (np.asarray(paged["bt_kg"]) == chai_cache.NULL_PAGE).all()
    assert ((np.asarray(paged["bt_vg"]) == chai_cache.NULL_PAGE).all()
            == share_values)
    assert (np.asarray(paged["phase"]) == chai_cache.PHASE_STEADY).all()


def test_insert_and_reset_slot_roundtrip(rng):
    """insert_slot writes one request's prefill into a slot (phase ->
    WARMUP, scores cleared); reset_slot frees it (phase -> FREE, pos 0);
    other slots are untouched."""
    cfg = _mha_cfg(False, False)
    b, s = 2, 16
    state = chai_cache.init_unified_state(cfg, b, s)
    state["chai_scores"] = jnp.ones_like(state["chai_scores"])
    mini = init_decode_state(cfg, 1, s)
    mini["kg"] = jnp.asarray(rng.normal(size=mini["kg"].shape),
                             mini["kg"].dtype)
    mini["pos"] = jnp.full((1,), 7, jnp.int32)

    out = chai_cache.insert_slot(state, mini, 1)
    np.testing.assert_array_equal(np.asarray(out["kg"][:, 1]),
                                  np.asarray(mini["kg"][:, 0]))
    assert (np.asarray(out["kg"][:, 0]) == 0).all()      # slot 0 untouched
    assert int(out["pos"][1]) == 7 and int(out["pos"][0]) == 0
    assert int(out["phase"][1]) == chai_cache.PHASE_WARMUP
    assert (np.asarray(out["chai_scores"][:, 1]) == 0).all()
    assert (np.asarray(out["chai_scores"][:, 0]) == 1).all()

    out = chai_cache.reset_slot(out, 1)
    assert int(out["phase"][1]) == chai_cache.PHASE_FREE
    assert int(out["pos"][1]) == 0
