"""Serving driver: ``python -m repro.launch.serve --arch <id> [...]``.

Runs the slot-batched CHAI serving engine on a reduced config with random
weights + synthetic prompts, and reports TTFT / per-token latency / KV
bytes for CHAI vs MHA — the CPU-scale analogue of the paper's Fig 11/12.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_config, reduced
from repro.models import transformer as tfm
from repro.serving.engine import EngineConfig, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="chai-llama-7b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--no-chai", action="store_true")
    args = ap.parse_args(argv)

    cfg = reduced(get_config(args.arch))
    if not args.no_chai:
        cfg = cfg.with_chai(enabled=True)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    ecfg = EngineConfig(batch_slots=args.slots, max_seq=args.max_seq,
                        use_chai=not args.no_chai)
    eng = ServingEngine(cfg, params, ecfg)

    rng = np.random.default_rng(0)
    for i in range(args.requests):
        eng.submit(rng.integers(0, cfg.vocab_size, size=args.prompt_len),
                   max_new_tokens=args.max_new, uid=i)
    t0 = time.time()
    done = eng.run()
    wall = time.time() - t0

    ttfts = [r.ttft for r in done]
    lats = [r.latency for r in done]
    n_tok = sum(len(r.generated) for r in done)
    print(f"[serve] arch={cfg.name} chai={eng.chai_on} "
          f"requests={len(done)} tokens={n_tok}")
    print(f"[serve] wall={wall:.2f}s tok/s={n_tok / wall:.1f} "
          f"ttft_mean={np.mean(ttfts)*1e3:.0f}ms "
          f"lat_mean={np.mean(lats)*1e3:.0f}ms "
          f"redispatched={eng.redispatched}")
    print(f"[serve] kv_bytes chai={eng.kv_bytes(chai=True):,} "
          f"mha={eng.kv_bytes(chai=False):,} "
          f"saving={100*(1-eng.kv_bytes(chai=True)/max(eng.kv_bytes(chai=False),1)):.1f}%")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
