"""Fault-tolerant training loop: checkpoint/restart, straggler detection,
elastic mesh — the control plane over train_step.

The loop is deliberately dumb-restartable: every piece of state is either
(a) in the checkpoint (params, optimizer, compression residual, step) or
(b) a pure function of the step counter (data pipeline). Killing the
process at any point and calling ``Trainer.run`` again resumes exactly.

Straggler mitigation on a single-controller container is *detection* +
policy hooks: per-step wall times feed an EWMA; steps slower than
``straggler_factor``× the EWMA fire ``on_straggler`` (production: swap the
slow host out / re-shard; here: counted + logged, injectable in tests via
``step_delay_hook``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.launch import steps as steps_mod
from repro.models import transformer as tfm
from repro.optim import adamw, compression
from repro.sharding import rules
from repro.train import train_step as ts_mod


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    log_every: int = 10
    seed: int = 0
    n_micro: int = 1                  # >1 => microbatched accumulation
    compress_pods: bool = False       # int8 cross-pod gradient compression
    straggler_factor: float = 3.0
    lr_kw: Optional[dict] = None


class Trainer:
    def __init__(self, cfg: ModelConfig, data_cfg: DataConfig,
                 tcfg: TrainerConfig, *, mesh=None,
                 step_delay_hook: Optional[Callable[[int], float]] = None,
                 on_straggler: Optional[Callable[[int, float], None]] = None):
        self.cfg, self.tcfg = cfg, tcfg
        self.mesh = mesh
        self.pipe = SyntheticPipeline(data_cfg)
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep)
        self.step_delay_hook = step_delay_hook
        self.on_straggler = on_straggler
        self.straggler_steps: list = []
        self._ewma = None

        lr_kw = tcfg.lr_kw or dict(total=tcfg.total_steps,
                                   warmup=max(2, tcfg.total_steps // 10))
        if tcfg.compress_pods:
            assert mesh is not None and "pod" in mesh.axis_names
            self._step_fn = ts_mod.make_compressed_train_step(
                cfg, mesh, lr_kw=lr_kw)
        elif tcfg.n_micro > 1:
            self._step_fn = ts_mod.make_microbatched_train_step(
                cfg, n_micro=tcfg.n_micro, lr_kw=lr_kw)
        else:
            self._step_fn = steps_mod.make_train_step(cfg, lr_kw=lr_kw)
        self._jit_step = None

    # -- state ---------------------------------------------------------------
    def init_state(self):
        params = tfm.init_params(self.cfg, jax.random.PRNGKey(self.tcfg.seed))
        opt = adamw.init(params)
        state = {"params": params, "opt": opt}
        if self.tcfg.compress_pods:
            state["residual"] = compression.compress_residual_init(params)
        return state

    def _shardings(self, state):
        if self.mesh is None:
            return None
        pshapes, plog = tfm.param_structs(self.cfg)
        psh = rules.tree_shardings(pshapes, plog, self.mesh)
        oshapes, olog = adamw.state_structs(pshapes, plog)
        osh = rules.tree_shardings(oshapes, olog, self.mesh)
        sh = {"params": psh, "opt": osh}
        if "residual" in state:
            sh["residual"] = psh
        return sh

    def _batch_sharding(self):
        if self.mesh is None:
            return None
        b, t = self.pipe.cfg.global_batch, self.pipe.cfg.seq_len
        return {k: rules.sharding_for((b, t), ("batch", None), self.mesh)
                for k in ("tokens", "labels")}

    # -- the loop --------------------------------------------------------------
    def run(self, *, max_steps: Optional[int] = None):
        state = self.init_state()
        start = 0
        restored = self.ckpt.restore_latest(state)
        if restored is not None:
            start, state, extra = restored
            print(f"[trainer] restored step {start} "
                  f"(data resumes at batch {start})")
        bsh = self._batch_sharding()
        stop = min(self.tcfg.total_steps,
                   start + max_steps if max_steps else self.tcfg.total_steps)
        # resumed past the end: report a fresh eval step's metrics
        metrics = {"loss": float("nan"), "ce": float("nan")}
        if start >= stop:
            batch = self.pipe.global_batch_array(start, bsh)
            state, metrics = self._one_step(state, batch)
            return state, metrics
        for step in range(start, stop):
            batch = self.pipe.global_batch_array(step, bsh)
            t0 = time.time()
            if self.step_delay_hook is not None:
                time.sleep(self.step_delay_hook(step))
            state, metrics = self._one_step(state, batch)
            dt = time.time() - t0
            self._straggler_check(step, dt)
            if (step + 1) % self.tcfg.log_every == 0:
                print(f"[trainer] step {step + 1} "
                      f"loss={float(metrics['loss']):.4f} "
                      f"ce={float(metrics['ce']):.4f} {dt*1e3:.0f}ms")
            if (step + 1) % self.tcfg.ckpt_every == 0 or step + 1 == stop:
                self.ckpt.save(step + 1, state,
                               extra={"data_batch": step + 1})
        return state, metrics

    def _one_step(self, state, batch):
        if self._jit_step is None:
            if "residual" in state:
                fn = lambda s, b: _pack3(self._step_fn(
                    s["params"], s["opt"], s["residual"], b))
            else:
                fn = lambda s, b: _pack2(self._step_fn(
                    s["params"], s["opt"], b))
            self._jit_step = jax.jit(fn, donate_argnums=(0,))
        return self._jit_step(state, batch)

    def _straggler_check(self, step, dt):
        if self._ewma is None:
            self._ewma = dt          # first step: dominated by compile
            self._compiled = False
            return
        if not getattr(self, "_compiled", True):
            self._ewma = dt          # second step: first steady-state time
            self._compiled = True
            return
        if dt > self.tcfg.straggler_factor * self._ewma and step > 2:
            self.straggler_steps.append((step, dt))
            if self.on_straggler is not None:
                self.on_straggler(step, dt)
            else:
                print(f"[trainer] straggler: step {step} took {dt:.2f}s "
                      f"(ewma {self._ewma:.2f}s)")
        self._ewma = 0.9 * self._ewma + 0.1 * dt


def _pack2(out):
    params, opt, metrics = out
    return {"params": params, "opt": opt}, metrics


def _pack3(out):
    params, opt, residual, metrics = out
    return {"params": params, "opt": opt, "residual": residual}, metrics
