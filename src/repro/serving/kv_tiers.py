"""Hierarchical KV tiers: host-offload pool + int4 compressed tier.

Every byte the paged engine keeps — active slots, radix prefix pages,
CHAI snapshots, preempted victims — historically lived in device HBM, so
prefix-cache capacity and max concurrent sessions were HBM-bound. This
module adds a capacity ladder below the device pools:

    hot (device HBM)  ->  host (exact copy)  ->  compressed (int4)  ->  gone

* ``HostPagePool`` mirrors the device ``PagePool`` allocator (same free
  list / refcount / freed-at-zero semantics, so the invariant auditor's
  pool checks apply unchanged) but each allocated page carries a host
  payload dict — the ``jax.device_get`` of one physical device page
  (``{"data"[, "scale"]}`` per kind, see ``launch.steps.make_page_fetch``).

* ``TierManager`` owns per-kind (dense / clustered) host pools plus an
  optional int4 **compressed** pool. Demotion stores a device page's
  gathered payload into a host page; promotion is the inverse scatter
  (``make_page_put``). Under host pressure the manager walks its own
  LRU: compressible entries (radix block nodes) are re-coded to packed
  int4 (symmetric per-row, ``core.cache.quant_rows_int4``); entries
  that cannot compress (CHAI snapshots — their replay contract is
  bitwise) or that have already compressed are dropped structurally via
  ``drop_hook`` (the prefix cache's ``drop_demoted``).

* Integrity: demotion stamps a CRC32 over the stored payload arrays
  (``faults.checksum_arrays``); promotion verifies it before any byte
  reaches the device. Compression restamps over the packed arrays. A
  mismatch (e.g. the ``offload.out`` corrupt arm) drops the entry and
  the request re-plans cold — corruption never crosses tiers.

The manager is pure host bookkeeping: it never touches jax. The engine
owns the device side (gather/scatter jits, which entries demote, when
to prefetch) and wires ``on_transition`` into telemetry.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.cache import (PagePool, dequant_rows_int4, pack_int4,
                              quant_rows_int4, unpack_int4)
from repro.serving.faults import checksum_arrays

# Tier names (also the ``tier`` label values in telemetry).
TIER_HOT = "hot"
TIER_HOST = "host"
TIER_COMP = "compressed"
TIER_GONE = "gone"

#: payload kind -> pool kind (which pool a cached page list lives in)
POOL_OF = {"kg": "dense", "vg": "dense", "kc": "chai", "vc": "chai"}


def payload_crc(payloads: Dict[str, List[dict]]) -> int:
    """Order-stable CRC32 over the ndarray leaves of per-kind payload
    lists (non-array metadata like dtype/width markers is excluded —
    ``checksum_arrays`` only defines a stable digest for arrays)."""
    tree = {
        pk: {str(i): {k: v for k, v in p.items()
                      if isinstance(v, np.ndarray)}
             for i, p in enumerate(plist)}
        for pk, plist in payloads.items()
    }
    return checksum_arrays(tree)


def compress_payload(payload: dict) -> dict:
    """Re-code one host page payload to packed int4: symmetric per-row
    quantization over the head dim, two codes per byte. The int8
    configs' scale plane (small) rides along uncompressed."""
    data = np.asarray(payload["data"])
    q, qscale = quant_rows_int4(data)
    out = {"packed": pack_int4(q), "qscale": qscale,
           "hd": int(data.shape[-1]), "dtype": data.dtype}
    if "scale" in payload:
        out["scale"] = np.asarray(payload["scale"])
    return out


def decompress_payload(cp: dict) -> dict:
    """Inverse of ``compress_payload`` (lossy: int4 resolution)."""
    x = dequant_rows_int4(unpack_int4(cp["packed"], cp["hd"]), cp["qscale"])
    dt = cp["dtype"]
    if np.issubdtype(np.dtype(dt) if isinstance(dt, str) else dt,
                     np.integer):
        x = np.rint(x)
    out = {"data": x.astype(dt)}
    if "scale" in cp:
        out["scale"] = cp["scale"]
    return out


class HostPagePool(PagePool):
    """A ``PagePool`` whose pages carry host payloads.

    Same allocator semantics as the device-side pool (null page, LIFO
    free list, refcounts, freed-at-zero) so ``invariants._audit_pool``
    audits it unchanged; additionally each in-use page maps to its
    payload dict in ``_data``, dropped when the last reference dies.
    """

    def __init__(self, num_pages: int, page_size: int):
        super().__init__(num_pages, page_size)
        self._data: Dict[int, dict] = {}

    def store(self, payload: dict) -> int:
        (page,) = self.alloc(1)
        self._data[page] = payload
        return page

    def fetch(self, page: int) -> dict:
        return self._data[int(page)]

    def replace(self, page: int, payload: dict):
        """Swap a page's payload in place (fault-injection corruption)."""
        assert int(page) in self._data
        self._data[int(page)] = payload

    def free(self, pages):
        for p in pages:
            p = int(p)
            last = self._rc.get(p, 0) == 1
            super().free([p])
            if last:
                self._data.pop(p, None)

    def bytes_stored(self) -> int:
        return int(sum(v.nbytes for payload in self._data.values()
                       for v in payload.values()
                       if isinstance(v, np.ndarray)))


class TierManager:
    """Owns the host + compressed pools and the demoted-entry LRUs.

    ``host_pages`` / ``comp_pages`` map pool kind ("dense" / "chai") to
    usable page counts (0 disables that pool). Demoted cache entries
    (``BlockNode`` / ``ChaiSnapshot`` with ``tier`` != "hot") are filed
    in per-tier LRUs; under pressure ``make_room`` walks hot->host->
    compressed->gone exactly like the device-side cache walks its own
    LRU. The engine supplies:

    ``drop_hook(entry)``       structural drop (``drop_demoted``) — must
                               release the entry's tier pages.
    ``droppable_hook(entry)``  False when a structural drop would strand
                               locked state (e.g. a radix subtree with a
                               locked descendant); compression is always
                               safe, only drops consult this.
    ``on_transition(frm, to, kind, n)``  telemetry callback.
    """

    def __init__(self, page_size: int,
                 host_pages: Optional[Dict[str, int]] = None,
                 comp_pages: Optional[Dict[str, int]] = None,
                 on_transition: Optional[Callable] = None):
        self.page_size = int(page_size)

        def build(spec):
            pools = {}
            for kind in ("dense", "chai"):
                n = int((spec or {}).get(kind, 0))
                pools[kind] = (HostPagePool(n + 1, page_size)
                               if n > 0 else None)
            return pools

        self.host = build(host_pages)
        self.comp = build(comp_pages)
        self._lru = {TIER_HOST: OrderedDict(), TIER_COMP: OrderedDict()}
        self.on_transition = on_transition
        self.drop_hook: Optional[Callable] = None
        self.droppable_hook: Optional[Callable] = None
        self.transitions: Dict[tuple, int] = {}

    # -- pools -------------------------------------------------------------
    def pools_of(self, tier: str) -> dict:
        return self.comp if tier == TIER_COMP else self.host

    def host_capacity(self, kind: str) -> int:
        pool = self.host.get(kind)
        return pool.capacity if pool is not None else 0

    # -- transition ledger -------------------------------------------------
    def record(self, frm: str, to: str, kind: str, n: int):
        if n <= 0:
            return
        key = (frm, to, kind)
        self.transitions[key] = self.transitions.get(key, 0) + int(n)
        if self.on_transition is not None:
            self.on_transition(frm, to, kind, int(n))

    # -- demoted-entry LRU bookkeeping -------------------------------------
    def file(self, entry):
        """(Re-)file a demoted entry at the MRU end of its tier's LRU.
        Locked or already-dropped entries stay out (mirrors the device
        cache's ``_lru_file``)."""
        if getattr(entry, "locks", 0) or getattr(entry, "evicted", False):
            return
        lru = self._lru.get(entry.tier)
        if lru is None:
            return
        lru[id(entry)] = entry
        lru.move_to_end(id(entry))

    def unfile(self, entry):
        for lru in self._lru.values():
            lru.pop(id(entry), None)

    def touch(self, entry):
        lru = self._lru.get(getattr(entry, "tier", None))
        if lru is not None and id(entry) in lru:
            lru.move_to_end(id(entry))

    def pin(self, entry):
        self.unfile(entry)

    def unpin(self, entry):
        self.file(entry)

    # -- page-level ops (preemption payloads, no cache entry) --------------
    def store_pages(self, kind: str, payloads: List[dict]) -> List[int]:
        pool = self.host[kind]
        assert pool is not None, f"no host pool for kind {kind!r}"
        return [pool.store(p) for p in payloads]

    def fetch_pages(self, kind: str, pages) -> List[dict]:
        pool = self.host[kind]
        return [pool.fetch(p) for p in pages]

    def free_pages(self, kind: str, pages):
        if pages:
            self.host[kind].free(pages)

    # -- entry-level ops ---------------------------------------------------
    def store_entry(self, entry, payloads: Dict[str, List[dict]]):
        """Demote: store per-payload-kind page payloads into host pages,
        stamp the CRC, and file the entry in the host LRU. The caller
        (engine) frees the device pages and records hot->host."""
        entry.tier_crc = payload_crc(payloads)
        entry.tier_pages = {
            pk: self.store_pages(POOL_OF[pk], plist)
            for pk, plist in payloads.items() if plist
        }
        entry.tier = TIER_HOST
        self.file(entry)

    def fetch_entry(self, entry) -> Dict[str, List[dict]]:
        """Payloads ready for the device scatter (decompressed if the
        entry rode the int4 tier)."""
        comp = entry.tier == TIER_COMP
        pools = self.pools_of(entry.tier)
        out = {}
        for pk, pages in entry.tier_pages.items():
            raw = [pools[POOL_OF[pk]].fetch(p) for p in pages]
            out[pk] = [decompress_payload(p) for p in raw] if comp else raw
        return out

    def verify_entry(self, entry) -> bool:
        """CRC the RAW stored payloads against the demotion/compression
        stamp — corruption is caught before any dequantize/scatter."""
        pools = self.pools_of(entry.tier)
        raw = {pk: [pools[POOL_OF[pk]].fetch(p) for p in pages]
               for pk, pages in entry.tier_pages.items()}
        return payload_crc(raw) == entry.tier_crc

    def _free_tier_pages(self, entry):
        pools = self.pools_of(entry.tier)
        for pk, pages in (entry.tier_pages or {}).items():
            if pages:
                pools[POOL_OF[pk]].free(pages)
        entry.tier_pages = {}
        self.unfile(entry)

    def release_entry(self, entry):
        """Free tier storage on PROMOTION (the caller re-homes the entry
        to device pages and records host->hot)."""
        self._free_tier_pages(entry)

    def discard_entry(self, entry):
        """Free tier storage on a structural DROP: records ->gone."""
        counts: Dict[str, int] = {}
        for pk, pages in (entry.tier_pages or {}).items():
            kind = POOL_OF[pk]
            counts[kind] = counts.get(kind, 0) + len(pages)
        tier = entry.tier
        self._free_tier_pages(entry)
        for kind, n in counts.items():
            self.record(tier, TIER_GONE, kind, n)

    # -- pressure: the host->compressed->gone ladder -----------------------
    def _entry_page_counts(self, entry) -> Dict[str, int]:
        counts: Dict[str, int] = {}
        for pk, pages in (entry.tier_pages or {}).items():
            kind = POOL_OF[pk]
            counts[kind] = counts.get(kind, 0) + len(pages)
        return counts

    def _short(self, need: Dict[str, int]) -> bool:
        for kind, n in need.items():
            if n <= 0:
                continue
            pool = self.host.get(kind)
            if pool is None or pool.free_pages < n:
                return True
        return False

    def _droppable(self, entry) -> bool:
        if self.drop_hook is None:
            return False
        if self.droppable_hook is not None and not self.droppable_hook(entry):
            return False
        return True

    def _comp_room(self, need: Dict[str, int]) -> bool:
        """Make room in the compressed pool by dropping ITS LRU tail."""
        def short():
            for kind, n in need.items():
                if n <= 0:
                    continue
                pool = self.comp.get(kind)
                if pool is None:
                    return None          # can never fit
                if pool.free_pages < n:
                    return True
            return False

        s = short()
        while s:
            victim = next((e for e in self._lru[TIER_COMP].values()
                           if self._droppable(e)), None)
            if victim is None:
                return False
            self.drop_hook(victim)
            s = short()
        return s is not None and not s

    def compress_entry(self, entry) -> bool:
        """Re-code a host-tier entry to the int4 pool. Returns False if
        the compressed pool cannot cover it (after shedding its own
        LRU tail) — the caller falls through to a structural drop."""
        if entry.tier != TIER_HOST or not getattr(entry, "compressible",
                                                  False):
            return False
        counts = self._entry_page_counts(entry)
        if not self._comp_room(counts):
            return False
        packed = {pk: [compress_payload(self.host[POOL_OF[pk]].fetch(p))
                       for p in pages]
                  for pk, pages in entry.tier_pages.items()}
        crc = payload_crc(packed)
        old = dict(entry.tier_pages)
        new_pages = {pk: [self.comp[POOL_OF[pk]].store(p) for p in plist]
                     for pk, plist in packed.items()}
        for pk, pages in old.items():
            self.host[POOL_OF[pk]].free(pages)
        self.unfile(entry)
        entry.tier_pages = new_pages
        entry.tier_crc = crc
        entry.tier = TIER_COMP
        self.file(entry)
        for kind, n in counts.items():
            self.record(TIER_HOST, TIER_COMP, kind, n)
        return True

    def make_room(self, need: Dict[str, int]) -> bool:
        """Free host pages until ``need`` fits: walk the host LRU from
        the front, compress compressible victims into the int4 pool,
        structurally drop the rest (and compressed-tier residents when
        their pool overflows). Returns False when the ladder runs dry —
        the caller falls back to dropping outright."""
        for kind, n in need.items():
            pool = self.host.get(kind)
            if n > 0 and (pool is None or n > pool.capacity):
                return False
        while self._short(need):
            progress = False
            for entry in list(self._lru[TIER_HOST].values()):
                counts = self._entry_page_counts(entry)
                helps = any(need.get(k, 0) > 0
                            and self.host[k].free_pages < need[k]
                            and counts.get(k, 0) > 0
                            for k in ("dense", "chai"))
                if not helps:
                    continue
                if self.compress_entry(entry):
                    progress = True
                elif self._droppable(entry):
                    self.drop_hook(entry)
                    progress = True
                if progress:
                    break
            if not progress:
                return False
        return True

    # -- introspection -----------------------------------------------------
    def tier_pages(self) -> Dict[tuple, int]:
        """{(tier, kind): pages in use} for the host-side tiers."""
        out = {}
        for tier, pools in ((TIER_HOST, self.host), (TIER_COMP, self.comp)):
            for kind, pool in pools.items():
                if pool is not None:
                    out[(tier, kind)] = pool.pages_in_use
        return out

    def tier_bytes(self) -> Dict[str, int]:
        return {tier: sum(p.bytes_stored() for p in pools.values()
                          if p is not None)
                for tier, pools in ((TIER_HOST, self.host),
                                    (TIER_COMP, self.comp))}

    def stats(self) -> dict:
        out = {"tier_pages": {f"{t}/{k}": v
                              for (t, k), v in self.tier_pages().items()},
               "tier_bytes": self.tier_bytes(),
               "transitions": {f"{f}->{t}/{k}": n
                               for (f, t, k), n in self.transitions.items()},
               "demoted_entries": {t: len(lru)
                                   for t, lru in self._lru.items()}}
        for tier, pools in (("host", self.host), ("compressed", self.comp)):
            for kind, pool in pools.items():
                if pool is not None:
                    out[f"{tier}_{kind}"] = pool.counters()
        return out
