"""User-facing serving API over the step-driven ``EngineCore``.

Three entry points, all driving the same core (and therefore the same
slots, page pools, and prefix cache):

* ``LLM.generate(prompts, params)`` — synchronous batch: submit every
  prompt, loop ``step()`` until all finish, return ``RequestOutput``s in
  submission order.
* ``LLM.stream(prompt, params)`` — incremental iterator: yields a
  ``StepOutput`` the moment the request emits tokens (the first chunk
  arrives at admission, long before completion). Other in-flight
  requests keep decoding on the shared core while a stream is consumed —
  their tokens accumulate on their Requests and are collected whenever
  their own ``generate``/``stream`` call drains.
* ``LLM.abort(uid)`` — cancel a queued or running request; its pages
  return to the pools refcount-exactly and any open stream for it ends.

``Session`` layers multi-turn chat on top: each ``send()`` submits
history + new user tokens as one prompt, so with the engine's prefix
cache on, turn N+1 aliases the pages turn N left behind and prefills
only the uncached suffix (retiring slots index their full sequence —
prompt AND generated tokens — into the radix tree when their dense pages
survive to retirement).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterator, List, Optional, Sequence, Union

import numpy as np

from repro.serving.engine import (EngineConfig, EngineCore, Request,
                                  StepOutput)
from repro.serving.sampling import SamplingParams


@dataclasses.dataclass
class RequestOutput:
    """Completed request: generated token ids + finish metadata."""
    uid: int
    prompt_token_ids: List[int]
    token_ids: List[int]
    finish_reason: str
    text: str = ""                 # detokenized (engines with a detokenizer)
    cached_tokens: int = 0         # prompt tokens served from the cache
    prefill_tokens: int = 0        # prompt tokens actually forwarded
    request: Optional[Request] = None   # timings (ttft/latency), slot, hits


def _is_single_prompt(prompts) -> bool:
    if isinstance(prompts, np.ndarray):
        return prompts.ndim == 1
    if isinstance(prompts, (list, tuple)) and prompts:
        return isinstance(prompts[0], (int, np.integer))
    return False


class LLM:
    """High-level frontend owning one ``EngineCore``.

    ``detokenizer``: optional ``List[int] -> str``; enables
    ``SamplingParams.stop`` strings and fills ``RequestOutput.text``.
    Extra keyword arguments build the ``EngineConfig`` when ``ecfg`` is
    not given (e.g. ``LLM(cfg, params, batch_slots=4, max_seq=128)``).
    """

    def __init__(self, cfg, params, ecfg: Optional[EngineConfig] = None, *,
                 detokenizer: Optional[Callable] = None, faults=None,
                 **ecfg_kw):
        if ecfg is None:
            ecfg = EngineConfig(**ecfg_kw)
        elif ecfg_kw:
            raise ValueError(f"pass ecfg OR EngineConfig kwargs, not both "
                             f"({sorted(ecfg_kw)})")
        if ecfg.scheduler != "continuous":
            raise ValueError("LLM drives EngineCore.step(): continuous "
                             "scheduler only (use ServingEngine for the "
                             "legacy cohort path)")
        self.core = EngineCore(cfg, params, ecfg, detokenizer=detokenizer,
                               faults=faults)
        self.detokenizer = detokenizer

    # -- driving -----------------------------------------------------------
    def _drive(self) -> List[StepOutput]:
        """One engine step; when nothing is admissible yet (open-loop
        arrivals), sleep until the next arrival so callers simply loop."""
        outs = self.core.step()
        if not outs and not self.core.has_active:
            t = self.core.next_arrival()
            if t is not None:
                time.sleep(max(1e-4, t - time.time()))
        return outs

    def _output_of(self, req: Request) -> RequestOutput:
        text = (self.detokenizer(list(req.generated))
                if self.detokenizer is not None else "")
        return RequestOutput(
            uid=req.uid, prompt_token_ids=list(map(int, req.prompt)),
            token_ids=list(req.generated), finish_reason=req.finish_reason,
            text=text, cached_tokens=req.cached_tokens,
            prefill_tokens=max(req.prefill_tokens, 0), request=req)

    # -- public API --------------------------------------------------------
    def generate(self, prompts,
                 params: Union[SamplingParams, Sequence[SamplingParams],
                               None] = None, *,
                 priority: int = 0) -> List[RequestOutput]:
        """Submit one prompt (flat token sequence) or a batch of prompts
        and block until all finish. ``params``: one ``SamplingParams``
        shared by every prompt, or one per prompt. ``priority``: the
        engine's preemption class (see ``EngineCore.add_request``)."""
        single = _is_single_prompt(prompts)
        batch = [prompts] if single else list(prompts)
        if params is None or isinstance(params, SamplingParams):
            plist = [params] * len(batch)
        else:
            plist = list(params)
            if len(plist) != len(batch):
                raise ValueError(f"{len(plist)} SamplingParams for "
                                 f"{len(batch)} prompts")
        reqs = [self.core.add_request(p, sp, priority=priority)
                for p, sp in zip(batch, plist)]
        while any(not r.finished for r in reqs):
            self._drive()
        outs = [self._output_of(r) for r in reqs]
        self.core.reap_done()   # keep the long-lived core's memory bounded
        return outs

    def stream(self, prompt, params: Optional[SamplingParams] = None, *,
               max_new_tokens: Optional[int] = None,
               priority: int = 0) -> Iterator[StepOutput]:
        """Submit one prompt and yield its tokens incrementally: one
        ``StepOutput`` per engine step that emitted tokens for THIS
        request (the admission chunk carries the first token; the final
        chunk always has ``finished=True`` — after an ``abort(uid)``
        between chunks it is an empty terminal chunk carrying
        ``finish_reason="aborted"``; every chunk carries the request's
        ``uid``). The request is submitted when iteration BEGINS (first
        ``__next__``), and abandoning the iterator (break / close / GC)
        aborts it — so a dropped stream, started or not, can never pin a
        batch slot, its pages, or a queue position.

        Chunks are cut against the Request's own token list, not this
        iterator's engine steps — tokens generated while ANOTHER
        frontend call (a concurrent ``generate``, or an interleaved
        second stream) drives the shared core are caught up on the next
        ``__next__``, never dropped."""
        def _gen():
            # Submitted HERE, not in stream(): an abandoned generator
            # that was never started has enqueued nothing (close()/GC on
            # an unstarted generator never runs the body, so an eager
            # add_request would orphan a queued request).
            req = self.core.add_request(prompt, params,
                                        max_new_tokens=max_new_tokens,
                                        priority=priority)
            emitted = 0
            delivered_fin = False
            try:
                while True:
                    new = [int(t) for t in req.generated[emitted:]]
                    fin = req.finished
                    if new:
                        emitted += len(new)
                        delivered_fin = fin
                        yield StepOutput(req.uid, new, fin,
                                         req.finish_reason)
                    if fin:
                        if not delivered_fin:   # out-of-band abort():
                            yield StepOutput(req.uid, [], True,
                                             req.finish_reason)
                        self.core.reap_done()   # bounded long-lived core
                        return
                    self._drive()
            finally:
                # abandoned mid-flight: release the slot and its pages
                if not req.finished:
                    self.core.abort(req.uid)

        return _gen()

    def abort(self, uid) -> bool:
        return self.core.abort(uid)


class Session:
    """Multi-turn chat session over one ``LLM``.

    Each ``send(user_tokens)`` submits ``history + user_tokens`` as the
    prompt and appends the reply to the history, so with
    ``EngineConfig.prefix_cache=True`` turn N+1 aliases the pages earlier
    turns already filled and prefills only the new user message
    (``RequestOutput.cached_tokens`` / ``prefill_tokens`` report the
    split; pages-saved shows up in the engine's allocator counters)."""

    def __init__(self, llm: LLM, params: Optional[SamplingParams] = None):
        self.llm = llm
        self.params = params
        self.history: List[int] = []       # prompt + reply tokens so far
        self.turns: List[RequestOutput] = []

    def send(self, user_tokens,
             params: Optional[SamplingParams] = None) -> RequestOutput:
        prompt = self.history + list(map(int, user_tokens))
        out = self.llm.generate(np.asarray(prompt, np.int32),
                                params if params is not None
                                else self.params)[0]
        self.history = prompt + list(out.token_ids)
        self.turns.append(out)
        return out
