"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode).

Every Pallas kernel is exercised across sequence lengths, head counts,
GQA ratios, windows, tile sizes, and dtypes, asserting allclose against
ref.py. interpret=True executes the kernel body in Python on CPU.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import chai_attention as ck
from repro.kernels import flash_attention as fk
from repro.kernels import ops, ref

TOL = dict(rtol=2e-3, atol=2e-3)
# bf16-valued outputs carry ~2^-8 quantization; oracles compute in f32.
TOL_BF16 = dict(rtol=2e-2, atol=2e-2)


def _tol(dtype):
    return TOL_BF16 if dtype == jnp.bfloat16 else TOL


def _mk(rng, shape, dtype):
    x = rng.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)


# --------------------------------------------------------------- decode ----
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,kv,s,hd,ts,window", [
    (1, 4, 4, 32, 16, 8, 0),
    (2, 8, 2, 64, 32, 16, 0),       # GQA 4:1
    (3, 6, 1, 48, 8, 16, 0),        # MQA
    (2, 4, 4, 64, 32, 64, 0),       # single tile
    (2, 8, 4, 64, 16, 16, 24),      # sliding window
])
def test_flash_decode_sweep(rng, dtype, b, h, kv, s, hd, ts, window):
    q = _mk(rng, (b, h, hd), dtype)
    kc = _mk(rng, (b, kv, s, hd), dtype)
    vc = _mk(rng, (b, kv, s, hd), dtype)
    pos = jnp.asarray(rng.integers(1, s, size=b), jnp.int32)
    out = fk.flash_decode(q, kc, vc, pos, window=window, ts=ts,
                          interpret=True)
    want = ref.flash_decode_ref(q, kc, vc, pos, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,t,h,kv,hd,tq,ts,window,offset", [
    (1, 16, 4, 4, 16, 8, 8, 0, 0),
    (2, 32, 8, 2, 32, 8, 16, 0, 0),
    (1, 16, 4, 1, 16, 16, 16, 0, 0),
    (2, 16, 4, 4, 16, 8, 8, 12, 0),    # windowed
    (1, 8, 4, 4, 16, 8, 8, 0, 8),      # offset continuation (prefill chunk)
])
def test_flash_prefill_sweep(rng, dtype, b, t, h, kv, hd, tq, ts, window,
                             offset):
    q = _mk(rng, (b, t, h, hd), dtype)
    s = t + offset
    k = _mk(rng, (b, s, kv, hd), dtype)
    v = _mk(rng, (b, s, kv, hd), dtype)
    out = fk.flash_prefill(q, k, v, offset=offset, window=window, tq=tq,
                           ts=ts, interpret=True)
    want = ref.flash_prefill_ref(q, k, v, offset=offset, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


# ----------------------------------------------------------------- CHAI ----
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,r,s,hd,ts", [
    (1, 8, 3, 32, 16, 8),
    (2, 16, 5, 64, 32, 16),
    (2, 4, 4, 32, 16, 32),    # k == H (degenerate: no clustering)
    (3, 8, 1, 24, 8, 8),      # single cluster
])
def test_chai_decode_mha_sweep(rng, dtype, b, h, r, s, hd, ts):
    """MHA regime: clustered K cache has R rows; V cache has all H rows."""
    q_rep = _mk(rng, (b, r, hd), dtype)
    kc = _mk(rng, (b, r, s, hd), dtype)
    vc = _mk(rng, (b, h, s, hd), dtype)
    h2c = jnp.asarray(rng.integers(0, r, size=(b, h)), jnp.int32)
    pos = jnp.asarray(rng.integers(1, s, size=b), jnp.int32)
    sc = ck.chai_qk(q_rep, kc, pos, ts=ts, interpret=True)
    a = ck.row_softmax(sc, interpret=True)
    out = ck.chai_av(a, vc, h2c, ts=ts, interpret=True)
    want = ref.chai_decode_ref(q_rep, kc, vc, h2c, pos)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("b,kv,rpg,s,hd,ts", [
    (2, 4, 2, 32, 16, 8),     # GQA: 4 groups x 2 reps each
    (1, 2, 3, 64, 32, 16),
])
def test_chai_qk_gqa_groups(rng, b, kv, rpg, s, hd, ts):
    """GQA regime: rep j reads K of group j // reps_per_group."""
    r_total = kv * rpg
    q_rep = _mk(rng, (b, r_total, hd), jnp.float32)
    kc = _mk(rng, (b, kv, s, hd), jnp.float32)
    pos = jnp.asarray(rng.integers(1, s, size=b), jnp.int32)
    sc = ck.chai_qk(q_rep, kc, pos, reps_per_group=rpg, ts=ts,
                    interpret=True)
    a = ck.row_softmax(sc, interpret=True)
    want = ref.chai_scores_ref(q_rep, kc, pos, reps_per_group=rpg)
    np.testing.assert_allclose(np.asarray(a), np.asarray(want), **TOL)


def test_chai_av_shared_membership(rng):
    """h2c may be (H,) — broadcast across batch."""
    b, h, r, s, hd = 2, 8, 3, 32, 16
    a = jnp.asarray(rng.random((b, r, s)), jnp.float32)
    vc = _mk(rng, (b, h, s, hd), jnp.float32)
    h2c = jnp.asarray(rng.integers(0, r, size=h), jnp.int32)
    out = ops.chai_decode_attention  # noqa: F841  (public API import check)
    got = ck.chai_av(a, vc, h2c, ts=8, interpret=True)
    want = ref.chai_av_ref(a, vc, h2c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_fused_op_matches_ref(rng):
    b, h, r, s, hd = 2, 8, 4, 64, 32
    q_rep = _mk(rng, (b, r, hd), jnp.float32)
    kc = _mk(rng, (b, r, s, hd), jnp.float32)
    vc = _mk(rng, (b, h, s, hd), jnp.float32)
    h2c = jnp.asarray(rng.integers(0, r, size=(b, h)), jnp.int32)
    pos = jnp.asarray([13, 60], jnp.int32)
    got = ops.chai_decode_attention(q_rep, kc, vc, h2c, pos, ts=16,
                                    interpret=True)
    want = ref.chai_decode_ref(q_rep, kc, vc, h2c, pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_decode_masks_future_positions(rng):
    """pos masking: entries beyond pos must not affect the output."""
    b, h, s, hd = 1, 4, 32, 16
    q = _mk(rng, (b, h, hd), jnp.float32)
    kc = _mk(rng, (b, h, s, hd), jnp.float32)
    vc = _mk(rng, (b, h, s, hd), jnp.float32)
    pos = jnp.asarray([10], jnp.int32)
    out1 = fk.flash_decode(q, kc, vc, pos, ts=8, interpret=True)
    kc2 = kc.at[:, :, 11:].set(999.0)
    vc2 = vc.at[:, :, 11:].set(-999.0)
    out2 = fk.flash_decode(q, kc2, vc2, pos, ts=8, interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------- paged ----
def _mk_tables(rng, b, n_pages, n_pool, n=1):
    """n block tables of distinct physical pages (page 0 = null sink,
    never allocated; no two slots/tables share a page)."""
    assert n_pool - 1 >= n * b * n_pages
    perm = rng.permutation(np.arange(1, n_pool))[:n * b * n_pages]
    tables = perm.reshape(n, b, n_pages)
    out = tuple(jnp.asarray(t, jnp.int32) for t in tables)
    return out[0] if n == 1 else out


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,kv,n_pages,page,hd,window", [
    (1, 4, 4, 4, 8, 16, 0),
    (2, 8, 2, 4, 16, 32, 0),      # GQA 4:1
    (3, 6, 1, 6, 8, 8, 0),        # MQA
    (2, 4, 4, 1, 64, 32, 0),      # single page
    (2, 8, 4, 4, 16, 16, 24),     # sliding window
])
def test_paged_decode_sweep(rng, dtype, b, h, kv, n_pages, page, hd,
                            window):
    n_pool = 2 * b * n_pages + 1
    pool = _mk(rng, (n_pool, kv, page, hd), dtype)
    bt_k, bt_v = _mk_tables(rng, b, n_pages, n_pool, n=2)
    q = _mk(rng, (b, h, hd), dtype)
    pos = jnp.asarray(rng.integers(1, n_pages * page, size=b), jnp.int32)
    out = fk.paged_decode(q, pool, bt_k, bt_v, pos, window=window,
                          interpret=True)
    want = ref.paged_decode_ref(q, pool, bt_k, bt_v, pos, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_paged_decode_matches_dense_flash_decode(rng):
    """Scatter a dense cache into pool pages: the paged kernel must
    reproduce the dense kernel on the same logical contents."""
    b, h, kv, n_pages, page, hd = 2, 4, 4, 4, 8, 16
    s = n_pages * page
    kc = _mk(rng, (b, kv, s, hd), jnp.float32)
    vc = _mk(rng, (b, kv, s, hd), jnp.float32)
    n_pool = 2 * b * n_pages + 1
    bt_k, bt_v = _mk_tables(rng, b, n_pages, n_pool, n=2)
    pool = jnp.asarray(rng.normal(size=(n_pool, kv, page, hd)), jnp.float32)
    kp = kc.reshape(b, kv, n_pages, page, hd).transpose(2, 0, 1, 3, 4)
    vp = vc.reshape(b, kv, n_pages, page, hd).transpose(2, 0, 1, 3, 4)
    for i in range(b):
        for j in range(n_pages):
            pool = pool.at[bt_k[i, j]].set(kp[j, i])
            pool = pool.at[bt_v[i, j]].set(vp[j, i])
    q = _mk(rng, (b, h, hd), jnp.float32)
    pos = jnp.asarray([s - 1, 13], jnp.int32)
    got = fk.paged_decode(q, pool, bt_k, bt_v, pos, interpret=True)
    want = fk.flash_decode(q, kc, vc, pos, ts=page, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_paged_decode_null_pages_masked(rng):
    """Unallocated block-table entries point at the null sink page 0;
    whatever garbage lives there must not affect the output."""
    b, h, n_pages, page, hd = 1, 4, 4, 8, 16
    n_pool = 2 * n_pages + 1
    pool = _mk(rng, (n_pool, h, page, hd), jnp.float32)
    bt = _mk_tables(rng, b, n_pages, n_pool)
    # only the first 2 logical pages are allocated; pos stays inside them
    bt_trunc = bt.at[:, 2:].set(0)
    pos = jnp.asarray([2 * page - 1], jnp.int32)
    q = _mk(rng, (b, h, hd), jnp.float32)
    out1 = fk.paged_decode(q, pool, bt_trunc, bt_trunc, pos, interpret=True)
    poisoned = pool.at[0].set(999.0)
    out2 = fk.paged_decode(q, poisoned, bt_trunc, bt_trunc, pos,
                           interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("b,kv,rpg,n_pages,page,hd", [
    (2, 3, 1, 4, 8, 16),       # MHA clustered pool (KV == R == k_max)
    (1, 2, 3, 4, 16, 32),      # GQA groups
])
def test_paged_chai_qk_sweep(rng, b, kv, rpg, n_pages, page, hd):
    r_total = kv * rpg
    n_pool = b * n_pages + 1
    k_pool = _mk(rng, (n_pool, kv, page, hd), jnp.float32)
    bt = _mk_tables(rng, b, n_pages, n_pool)
    q_rep = _mk(rng, (b, r_total, hd), jnp.float32)
    pos = jnp.asarray(rng.integers(1, n_pages * page, size=b), jnp.int32)
    sc = ck.paged_chai_qk(q_rep, k_pool, bt, pos, reps_per_group=rpg,
                          interpret=True)
    a = ck.row_softmax(sc, interpret=True)
    want = ref.paged_chai_scores_ref(q_rep, k_pool, bt, pos,
                                     reps_per_group=rpg)
    np.testing.assert_allclose(np.asarray(a), np.asarray(want), **TOL)


@pytest.mark.parametrize("b,h,r,n_pages,page,hd", [
    (2, 8, 3, 4, 8, 16),
    (1, 4, 4, 2, 16, 32),      # k == H (degenerate)
])
def test_paged_chai_av_sweep(rng, b, h, r, n_pages, page, hd):
    s = n_pages * page
    n_pool = b * n_pages + 1
    a = jnp.asarray(rng.random((b, r, s)), jnp.float32)
    v_pool = _mk(rng, (n_pool, h, page, hd), jnp.float32)
    bt_v = _mk_tables(rng, b, n_pages, n_pool)
    h2c = jnp.asarray(rng.integers(0, r, size=(b, h)), jnp.int32)
    got = ck.paged_chai_av(a, v_pool, bt_v, h2c, interpret=True)
    want = ref.paged_chai_av_ref(a, v_pool, bt_v, h2c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_paged_chai_pipeline_matches_ref(rng):
    """Full paged CHAI decode: paged QK -> row softmax -> paged AV vs the
    densify-then-reference oracle (clustered K pool + per-head V pool)."""
    b, h, r, n_pages, page, hd = 2, 8, 4, 4, 8, 16
    nk, nv = b * n_pages + 1, b * n_pages + 1
    k_pool = _mk(rng, (nk, r, page, hd), jnp.float32)
    v_pool = _mk(rng, (nv, h, page, hd), jnp.float32)
    bt_k = _mk_tables(rng, b, n_pages, nk)
    bt_v = _mk_tables(rng, b, n_pages, nv)
    q_rep = _mk(rng, (b, r, hd), jnp.float32)
    h2c = jnp.asarray(rng.integers(0, r, size=(b, h)), jnp.int32)
    pos = jnp.asarray([n_pages * page - 1, 11], jnp.int32)
    sc = ck.paged_chai_qk(q_rep, k_pool, bt_k, pos, interpret=True)
    a = ck.row_softmax(sc, interpret=True)
    got = ck.paged_chai_av(a, v_pool, bt_v, h2c, interpret=True)
    want = ref.paged_chai_decode_ref(q_rep, k_pool, bt_k, v_pool, bt_v,
                                     h2c, pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


@pytest.mark.parametrize("b,kv,rpg,s,hd,ts", [
    (2, 4, 1, 32, 16, 8),      # MHA clustered cache (KV == R)
    (1, 2, 3, 64, 32, 16),     # GQA groups
])
def test_chai_qk_i8_fused_dequant(rng, b, kv, rpg, s, hd, ts):
    """Fused int8-dequant scores kernel vs dequant-then-ref oracle."""
    from repro.core.cache import quant_rows
    r_total = kv * rpg
    q_rep = _mk(rng, (b, r_total, hd), jnp.float32)
    kf = _mk(rng, (b, kv, s, hd), jnp.float32)
    kq, ks = quant_rows(kf)
    pos = jnp.asarray(rng.integers(1, s, size=b), jnp.int32)
    sc = ck.chai_qk_i8(q_rep, kq, ks, pos, reps_per_group=rpg, ts=ts,
                       interpret=True)
    a = ck.row_softmax(sc, interpret=True)
    want = ref.chai_scores_i8_ref(q_rep, kq, ks, pos, reps_per_group=rpg)
    np.testing.assert_allclose(np.asarray(a), np.asarray(want), **TOL)


# ------------------------------------------------- fused one-pass decode ---
def _fused_case(rng, *, b=2, kv=3, rpg=1, s=128, hd=16, int8=False,
                share_values=False, qpk=None):
    """Build one fused-decode problem. MHA: rpg == 1, H chosen freely;
    GQA: H = kv * qpk, R = kv * rpg, h2c flat = group*rpg + within-group
    cluster. pos is ragged (one slot near the end, the rest random)."""
    from repro.core.cache import quant_rows
    r_total = kv * rpg
    if rpg == 1 and qpk is None:        # MHA: clustered cache, k_max rows
        h = 8
        h2c = rng.integers(0, r_total, size=(b, h))
    else:                               # GQA: within-group membership
        qpk = qpk or 4
        h = kv * qpk
        cluster_of = rng.integers(0, rpg, size=(b, kv, qpk))
        h2c = (np.arange(kv)[None, :, None] * rpg + cluster_of).reshape(b, h)
    q_rep = _mk(rng, (b, r_total, hd), jnp.float32)
    kc = _mk(rng, (b, kv, s, hd), jnp.float32)
    v_rows = r_total if share_values else (h if rpg == 1 else kv)
    vc = _mk(rng, (b, v_rows, s, hd), jnp.float32)
    pos = np.asarray(rng.integers(1, s, size=b))
    pos[0] = s - 1                      # ragged: one slot at full length
    kw = dict(reps_per_group=rpg, share_values=share_values)
    if int8:
        kq, ks = quant_rows(kc)
        kc, kw["k_scale"] = kq, ks
        if not share_values:            # clustered V codes stay scale-less
            vq, vs = quant_rows(vc)
            vc, kw["v_scale"] = vq, vs
    return (q_rep, kc, vc, jnp.asarray(h2c, jnp.int32),
            jnp.asarray(pos, jnp.int32)), kw


@pytest.mark.parametrize("window", [0, 64])
@pytest.mark.parametrize("int8", [False, True])
@pytest.mark.parametrize("mode", ["mha", "mha_share", "gqa"])
def test_chai_fused_decode_matrix(rng, mode, int8, window):
    """The full dispatch matrix the engine serves: {MHA, GQA} x
    {fp32, int8} x {share_values} x {window} x ragged pos — fused kernel
    vs the pure-jnp oracle AND the retired three-kernel pipeline."""
    kw_case = dict(share_values=(mode == "mha_share"))
    if mode == "gqa":
        kw_case.update(rpg=3, qpk=4)
    args, kw = _fused_case(rng, int8=int8, **kw_case)
    got = ck.chai_fused_decode(*args, ts=32, window=window, interpret=True,
                               **kw)
    want = ref.chai_fused_decode_ref(*args, window=window, **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)
    # the three-kernel pipeline survives as the second, independent oracle
    pipe = ref.chai_three_kernel_decode(*args, ts=32, window=window, **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(pipe), **TOL)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_chai_fused_decode_cache_dtypes(rng, dtype):
    """bf16 caches stream through the fused kernel (f32 accumulation)."""
    (q, kc, vc, h2c, pos), kw = _fused_case(rng)
    kc, vc = kc.astype(dtype), vc.astype(dtype)
    got = ck.chai_fused_decode(q, kc, vc, h2c, pos, ts=32, interpret=True,
                               **kw)
    want = ref.chai_fused_decode_ref(q, kc, vc, h2c, pos, **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               **_tol(dtype))


@pytest.mark.parametrize("int8", [False, True])
@pytest.mark.parametrize("mode", ["mha", "mha_share", "gqa"])
def test_paged_chai_fused_decode_matrix(rng, mode, int8):
    """Paged fused decode across the same matrix: pools + block tables +
    scale pools vs the densify-then-reference oracle."""
    from repro.core.cache import quant_rows
    b, n_pages, page, hd = 2, 4, 16, 16
    kv, rpg = (2, 3) if mode == "gqa" else (3, 1)
    share = mode == "mha_share"
    r_total = kv * rpg
    if mode == "gqa":
        qpk = 4
        h = kv * qpk
        cluster_of = rng.integers(0, rpg, size=(b, kv, qpk))
        h2c = (np.arange(kv)[None, :, None] * rpg
               + cluster_of).reshape(b, h)
        v_rows = kv
    else:
        h = 8
        h2c = rng.integers(0, r_total, size=(b, h))
        v_rows = r_total if share else h
    nk = b * n_pages + 1
    nv = b * n_pages + 1
    k_pool = _mk(rng, (nk, kv, page, hd), jnp.float32)
    v_pool = _mk(rng, (nv, v_rows, page, hd), jnp.float32)
    bt_k = _mk_tables(rng, b, n_pages, nk)
    bt_v = _mk_tables(rng, b, n_pages, nv)
    q_rep = _mk(rng, (b, r_total, hd), jnp.float32)
    pos = np.asarray(rng.integers(1, n_pages * page, size=b))
    pos[0] = n_pages * page - 1
    kw = dict(reps_per_group=rpg, share_values=share)
    if int8:
        kq, ksp = quant_rows(k_pool)
        k_pool, kw["k_scale_pool"] = kq, ksp
        if not share:
            vq, vsp = quant_rows(v_pool)
            v_pool, kw["v_scale_pool"] = vq, vsp
    args = (q_rep, k_pool, bt_k, v_pool, bt_v,
            jnp.asarray(h2c, jnp.int32), jnp.asarray(pos, jnp.int32))
    got = ck.paged_chai_fused_decode(*args, interpret=True, **kw)
    want = ref.paged_chai_fused_decode_ref(*args, **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)
    if not int8:   # second oracle: the retired paged three-kernel path
        pipe = ref.paged_chai_three_kernel_decode(
            *args, reps_per_group=rpg, share_values=share)
        np.testing.assert_allclose(np.asarray(got), np.asarray(pipe),
                                   **TOL)


def test_paged_fused_matches_dense_fused_bitwise(rng):
    """Same logical cache contents, equal tile size: the paged fused
    kernel must reproduce the dense fused kernel BIT-FOR-BIT (this is
    what pins cross-KV-layout greedy token parity in the engine)."""
    b, h, r, n_pages, page, hd = 2, 8, 4, 4, 16, 16
    s = n_pages * page
    nk = 2 * b * n_pages + 1
    k_pool = _mk(rng, (nk, r, page, hd), jnp.float32)
    v_pool = _mk(rng, (nk, h, page, hd), jnp.float32)
    bt_k, bt_v = _mk_tables(rng, b, n_pages, nk, n=2)
    kc = np.zeros((b, r, s, hd), np.float32)
    vc = np.zeros((b, h, s, hd), np.float32)
    for i in range(b):
        for j in range(n_pages):
            kc[i, :, j * page:(j + 1) * page] = np.asarray(
                k_pool)[np.asarray(bt_k)[i, j]]
            vc[i, :, j * page:(j + 1) * page] = np.asarray(
                v_pool)[np.asarray(bt_v)[i, j]]
    q = _mk(rng, (b, r, hd), jnp.float32)
    h2c = jnp.asarray(rng.integers(0, r, size=(b, h)), jnp.int32)
    pos = jnp.asarray([s - 1, 23], jnp.int32)
    dense = ck.chai_fused_decode(q, jnp.asarray(kc), jnp.asarray(vc), h2c,
                                 pos, ts=page, interpret=True)
    paged = ck.paged_chai_fused_decode(q, k_pool, bt_k, v_pool, bt_v, h2c,
                                       pos, interpret=True)
    assert (np.asarray(dense) == np.asarray(paged)).all()


def test_paged_fused_null_pages_masked(rng):
    """Unallocated block-table entries point at the null sink page 0;
    its contents must not leak into the fused output."""
    b, h, r, n_pages, page, hd = 1, 4, 2, 4, 8, 16
    n_pool = 2 * n_pages + 1
    k_pool = _mk(rng, (n_pool, r, page, hd), jnp.float32)
    v_pool = _mk(rng, (n_pool, h, page, hd), jnp.float32)
    bt = _mk_tables(rng, b, n_pages, n_pool).at[:, 2:].set(0)
    q = _mk(rng, (b, r, hd), jnp.float32)
    h2c = jnp.asarray(rng.integers(0, r, size=(b, h)), jnp.int32)
    pos = jnp.asarray([2 * page - 1], jnp.int32)
    out1 = ck.paged_chai_fused_decode(q, k_pool, bt, v_pool, bt, h2c, pos,
                                      interpret=True)
    out2 = ck.paged_chai_fused_decode(q, k_pool.at[0].set(999.0), bt,
                                      v_pool.at[0].set(-999.0), bt, h2c,
                                      pos, interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-6, atol=1e-6)


# --------------------------------------------------- tanh logit softcap ----
@pytest.mark.parametrize("softcap", [5.0, 50.0])
@pytest.mark.parametrize("mode", ["mha", "mha_share", "gqa"])
def test_chai_fused_decode_softcap_matches_oracle(rng, mode, softcap):
    """gemma2-style softcap inside the fused kernel (between QK-scale and
    the online-softmax update) vs the jnp oracle, across the dispatch
    matrix — this is what lets softcap archs stay on the fused path."""
    kw_case = dict(share_values=(mode == "mha_share"))
    if mode == "gqa":
        kw_case.update(rpg=3, qpk=4)
    args, kw = _fused_case(rng, **kw_case)
    got = ck.chai_fused_decode(*args, ts=32, softcap=softcap,
                               interpret=True, **kw)
    want = ref.chai_fused_decode_ref(*args, softcap=softcap, **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)
    # the flag is live: capping must actually change the output
    uncapped = ck.chai_fused_decode(*args, ts=32, interpret=True, **kw)
    assert not np.allclose(np.asarray(got), np.asarray(uncapped))


@pytest.mark.parametrize("mode", ["mha", "gqa"])
def test_paged_chai_fused_decode_softcap_matches_oracle(rng, mode):
    b, n_pages, page, hd, cap = 2, 4, 16, 16, 30.0
    kv, rpg = (2, 3) if mode == "gqa" else (3, 1)
    r_total = kv * rpg
    if mode == "gqa":
        qpk = 4
        h = kv * qpk
        cluster_of = rng.integers(0, rpg, size=(b, kv, qpk))
        h2c = (np.arange(kv)[None, :, None] * rpg
               + cluster_of).reshape(b, h)
        v_rows = kv
    else:
        h = 8
        h2c = rng.integers(0, r_total, size=(b, h))
        v_rows = h
    n_pool = b * n_pages + 1
    k_pool = _mk(rng, (n_pool, kv, page, hd), jnp.float32)
    v_pool = _mk(rng, (n_pool, v_rows, page, hd), jnp.float32)
    bt_k = _mk_tables(rng, b, n_pages, n_pool)
    bt_v = _mk_tables(rng, b, n_pages, n_pool)
    q_rep = _mk(rng, (b, r_total, hd), jnp.float32)
    pos = np.asarray(rng.integers(1, n_pages * page, size=b))
    pos[0] = n_pages * page - 1
    args = (q_rep, k_pool, bt_k, v_pool, bt_v,
            jnp.asarray(h2c, jnp.int32), jnp.asarray(pos, jnp.int32))
    got = ck.paged_chai_fused_decode(*args, reps_per_group=rpg,
                                     softcap=cap, interpret=True)
    want = ref.paged_chai_fused_decode_ref(*args, reps_per_group=rpg,
                                           softcap=cap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)


@pytest.mark.parametrize("offset", [0, 8])
def test_flash_prefill_softcap_matches_oracle(rng, offset):
    """The prefix cache's flash suffix path under a logit softcap (the
    gemma2 suffix prefill no longer falls back to jnp)."""
    b, t, h, kv, hd, cap = 2, 16, 4, 4, 16, 20.0
    s = t + offset
    q = _mk(rng, (b, t, h, hd), jnp.float32)
    k = _mk(rng, (b, s, kv, hd), jnp.float32)
    v = _mk(rng, (b, s, kv, hd), jnp.float32)
    got = fk.flash_prefill(q, k, v, offset=offset, tq=8, ts=8, softcap=cap,
                           interpret=True)
    want = ref.flash_prefill_ref(q, k, v, offset=offset, softcap=cap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)
    uncapped = fk.flash_prefill(q, k, v, offset=offset, tq=8, ts=8,
                                interpret=True)
    assert not np.allclose(np.asarray(got), np.asarray(uncapped))


def _all_avals(jaxpr):
    """Every aval in a (recursively closed) jaxpr."""
    seen = []
    todo = [jaxpr]
    while todo:
        j = todo.pop()
        for eqn in j.eqns:
            for v in list(eqn.invars) + list(eqn.outvars):
                if hasattr(v, "aval"):
                    seen.append(v.aval)
            for p in eqn.params.values():
                vals = p if isinstance(p, (list, tuple)) else [p]
                for sub in vals:
                    inner = getattr(sub, "jaxpr", None)
                    if inner is not None:
                        todo.append(inner)
                    elif hasattr(sub, "eqns"):
                        todo.append(sub)
    return seen


def test_fused_decode_materializes_no_brs_scores(rng):
    """Acceptance criterion: the fused path allocates NO (B, R, S) score
    tensor anywhere in its jaxpr — while the three-kernel pipeline
    provably does (the check has teeth)."""
    from repro.kernels import ops
    (q, kc, vc, h2c, pos), kw = _fused_case(rng, b=2, kv=3, s=128)
    b, r, s = 2, 3, 128

    def fused(q, kc, vc, h2c, pos):
        return ops.chai_decode_attention(q, kc, vc, h2c, pos, ts=32,
                                         interpret=True)

    def pipeline(q, kc, vc, h2c, pos):
        return ref.chai_three_kernel_decode(q, kc, vc, h2c, pos, ts=32)

    fused_avals = _all_avals(jax.make_jaxpr(fused)(q, kc, vc, h2c, pos))
    pipe_avals = _all_avals(jax.make_jaxpr(pipeline)(q, kc, vc, h2c, pos))
    assert not any(getattr(a, "shape", None) == (b, r, s)
                   for a in fused_avals)
    assert any(getattr(a, "shape", None) == (b, r, s) for a in pipe_avals)
