"""Production mesh construction (single-pod 16x16, multi-pod 2x16x16).

Functions, not module constants: importing this module never touches jax
device state (jax locks the device count on first backend init).
"""
from __future__ import annotations

import math

import jax

from repro import compat


def _mk(shape, axes):
    n = math.prod(shape)
    devs = jax.devices()[:n]
    if len(devs) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devs)} — set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax (launch/dryrun.py does this)")
    return compat.make_mesh(shape, axes, devices=devs)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh for tests / elastic scaling."""
    return _mk(tuple(shape), tuple(axes))


def elastic_mesh(*, model_parallel: int = 16):
    """Derive a mesh from whatever devices exist (elastic scaling): model
    axis fixed at ``model_parallel``, everything else data-parallel."""
    n = jax.device_count()
    mp = math.gcd(model_parallel, n)
    return _mk((n // mp, mp), ("data", "model"))
