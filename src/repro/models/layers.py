"""Shared low-level layers: norms, rotary embeddings, softcap, activations."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.compat import opt_barrier


def rms_norm(x, scale, eps=1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    out = (y * (1.0 + scale.astype(jnp.float32))).astype(dt)
    # barrier: pin the f32->model-dtype cast so SPMD reshardings after
    # the norm move 2-byte values, not the hoisted f32 intermediates
    # (halves activation all-gathers; EXPERIMENTS.md §Perf cell 2).
    return opt_barrier(out)


def softcap(x, cap):
    """Gemma-2 style tanh softcap; identity when cap <= 0."""
    if cap and cap > 0:
        return cap * jnp.tanh(x / cap)
    return x


def activation_fn(name):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return lambda x: jax.nn.gelu(x, approximate=True)
    if name == "relu2":  # squared ReLU (nemotron)
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(name)


# ---------------------------------------------------------------- rotary ----
def rope_freqs(head_dim, theta):
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)  # (head_dim/2,)


def apply_rope(x, positions, theta):
    """LLaMA-style half-rotation RoPE.

    x: (..., T, n_heads, head_dim); positions: broadcastable to (..., T).
    """
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., T, hd/2)
    cos = jnp.cos(angles)[..., None, :]                        # (..., T, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def embed_lookup(table, tokens):
    return jnp.take(table, tokens, axis=0)


def unembed(x, w, softcap_value=0.0):
    logits = jnp.einsum("...d,dv->...v", x, w).astype(jnp.float32)
    return softcap(logits, softcap_value)
