"""Int8 error-feedback gradient compression for the cross-pod axis.

At 1000+ nodes the scarce resource is the *cross-pod* link (data-center
network or optical ICI wraparound), not the in-pod ICI. The standard trick
(1-bit Adam / error-feedback SGD lineage) is:

  1. reduce gradients **within** a pod at full precision (cheap links),
  2. quantize to int8 with a per-tensor scale, carrying the quantization
     error into the next step's gradient (error feedback keeps the scheme
     unbiased in the long run — plain int8 rounding stalls convergence),
  3. all-reduce the int8 payload **across** pods only (8x fewer bytes on
     the slow axis), dequantize, and hand the mean gradient to AdamW.

Implemented with ``shard_map`` over the pod axis so XLA sees an int8
``psum`` on the wire. On the single-pod mesh this module is a no-op
passthrough (``compress_over=None``).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat


def _quantize(g):
    """Symmetric per-tensor int8. Returns (q int8, scale f32)."""
    amax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compress_residual_init(params):
    """Error-feedback residual buffer, same shapes as params (f32)."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(grads, residual, axis_name: str):
    """Inside-shard_map body: error-feedback int8 psum over ``axis_name``.

    grads/residual: local (already in-pod-reduced) f32 pytrees.
    Returns (mean_grads f32, new_residual f32).
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, r):
        g = g.astype(jnp.float32) + r           # fold in carried error
        q, scale = _quantize(g)
        err = g - _dequantize(q, scale)          # local quantization error
        # int32 accumulate avoids wraparound for up to 2^23 pods.
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        s_tot = jax.lax.psum(scale, axis_name)   # shared mean scale
        mean = total.astype(jnp.float32) * (s_tot / n) / n
        return mean, err

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    means = tdef.unflatten([m for m, _ in out])
    errs = tdef.unflatten([e for _, e in out])
    return means, errs


def wrap_pod_manual(fn, mesh, in_specs, out_specs, *, pod_axis: str = "pod"):
    """shard_map ``fn`` manually over the pod axis only; all in-pod axes
    (data/model) stay Auto so GSPMD keeps partitioning the body.

    ``in_specs``/``out_specs`` mention only the pod axis (P() = replicated
    across pods, P('pod') on the batch dim = pod-split). This is the
    mechanism that lets the train step intercept the cross-pod gradient
    reduction and run it int8 (see repro.train.train_step).
    """
    return compat.shard_map(fn, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, axis_names={pod_axis},
                         check_vma=False)
