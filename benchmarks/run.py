"""Benchmark harness: one bench per paper table/figure.

  python -m benchmarks.run            # all benches
  python -m benchmarks.run --only bench_kv_memory,bench_flops

Each bench saves JSON under benchmarks/results/ and returns a dict with a
``claim_check`` section verifying the paper's claims (or their CPU-proxy
analogues — labeled). Exit code is non-zero if any claim check fails.
"""
from __future__ import annotations

import argparse
import importlib
import json
import time
import traceback

BENCHES = [
    "bench_accuracy_proxy",    # Tables 1-3
    "bench_qkv_ablation",      # Table 4
    "bench_flops",             # Figs 1/14
    "bench_elbow",             # Fig 8
    "bench_membership",        # Fig 9
    "bench_kv_memory",         # Fig 11
    "bench_latency",           # Fig 12
    "bench_cluster_dist",      # Fig 13
]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args(argv)
    names = args.only.split(",") if args.only else BENCHES

    failures, summaries = [], {}
    for name in names:
        t0 = time.time()
        print(f"=== {name} ===", flush=True)
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            result = mod.run()
            checks = result.get("claim_check", {})
            bad = {k: v for k, v in checks.items()
                   if isinstance(v, bool) and not v}
            status = "ok" if not bad else f"CLAIM-FAIL {sorted(bad)}"
            if bad:
                failures.append(name)
            summaries[name] = {"status": status, "checks": checks,
                               "seconds": round(time.time() - t0, 1)}
            print(f"  {status} ({summaries[name]['seconds']}s)")
            for k, v in checks.items():
                print(f"    {k}: {v}")
        except Exception as e:
            failures.append(name)
            summaries[name] = {"status": f"ERROR {e}"}
            traceback.print_exc()
    print("\n=== summary ===")
    print(json.dumps(summaries, indent=1, default=str))
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
