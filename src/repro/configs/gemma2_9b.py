"""Gemma-2 9B [arXiv:2408.00118]: local+global alternating, logit softcaps."""
from repro.configs.base import (ModelConfig, CHAIConfig, register,
                                ATTN_LOCAL, ATTN_GLOBAL)

_LAYERS = tuple(ATTN_LOCAL if i % 2 == 0 else ATTN_GLOBAL for i in range(42))

CONFIG = register(ModelConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=256000,
    layer_types=_LAYERS,
    window_size=4096,
    activation="gelu",
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    rope_theta=10000.0,
    chai=CHAIConfig(enabled=True),
))
