"""OpenAI-style HTTP completions server over ``AsyncLLM`` — stdlib only.

POST /v1/completions with a JSON body::

    {"prompt": [3, 14, 15, 9], "max_tokens": 16, "temperature": 0.0,
     "stream": false, "priority": 0}

``prompt`` is a list of token ids (this repo ships no tokenizer; the
demo detokenizer renders ids as space-joined integers). Non-streaming
requests get one JSON object; ``"stream": true`` gets Server-Sent
Events (``data: {...}\\n\\n`` per chunk, ``data: [DONE]`` at the end),
each chunk carrying the tokens that step produced. GET /v1/stats
returns engine counters (steps, preemptions, pool occupancy).

Because the server rides ``AsyncLLM``, every connection shares ONE
continuous batch: concurrent requests are co-scheduled by the engine's
SLO knobs (chunked prefill bounds ITL stalls; ``priority`` classes
preempt under page pressure).

Run (serves until Ctrl-C)::

    python examples/serve_http.py --port 8080

Self-test (starts the server in-process, runs a scripted client,
exits)::

    python examples/serve_http.py --selftest
"""
import argparse
import asyncio
import json
import sys

import numpy as np

import jax

from repro.configs.base import get_config, reduced
from repro.models import transformer as tfm
from repro.serving.async_api import AsyncLLM
from repro.serving.engine import EngineConfig
from repro.serving.sampling import SamplingParams


def build_llm(arch: str = "chai-llama-7b") -> AsyncLLM:
    """A tiny demo model (random weights) behind a full serving stack."""
    cfg = reduced(get_config(arch), n_layers=2, d_model=64, d_ff=128,
                  vocab=256).replace(dtype="float32")
    cfg = cfg.with_chai(enabled=True, warmup_tokens=8)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    ecfg = EngineConfig(batch_slots=4, max_seq=256, page_size=16,
                        prefix_cache=True, prefill_chunk_tokens=32)
    detok = lambda ids: " ".join(map(str, ids))
    return AsyncLLM(cfg, params, ecfg, detokenizer=detok)


def _params_of(body: dict) -> SamplingParams:
    return SamplingParams(
        max_new_tokens=int(body.get("max_tokens", 16)),
        temperature=float(body.get("temperature", 0.0)),
        top_k=int(body.get("top_k", 0)),
        top_p=float(body.get("top_p", 1.0)),
        seed=int(body.get("seed", 0)))


async def _read_request(reader) -> tuple:
    """Minimal HTTP/1.1 parse: (method, path, body-bytes)."""
    line = await reader.readline()
    if not line:
        return None, None, b""
    method, path, _ = line.decode("latin1").split(" ", 2)
    length = 0
    while True:
        h = await reader.readline()
        if h in (b"\r\n", b"\n", b""):
            break
        name, _, val = h.decode("latin1").partition(":")
        if name.strip().lower() == "content-length":
            length = int(val.strip())
    body = await reader.readexactly(length) if length else b""
    return method, path, body


def _response(code: int, payload: bytes, ctype: str = "application/json",
              extra: str = "") -> bytes:
    reason = {200: "OK", 400: "Bad Request", 404: "Not Found",
              503: "Service Unavailable"}[code]
    return (f"HTTP/1.1 {code} {reason}\r\nContent-Type: {ctype}\r\n"
            f"Content-Length: {len(payload)}\r\nConnection: close\r\n"
            f"{extra}\r\n").encode("latin1") + payload


class Server:
    def __init__(self, llm: AsyncLLM):
        self.llm = llm

    async def handle(self, reader, writer):
        try:
            method, path, raw = await _read_request(reader)
            if method is None:
                return
            if method == "GET" and path == "/v1/stats":
                await self._stats(writer)
            elif method == "POST" and path == "/v1/completions":
                await self._completions(writer, raw)
            else:
                writer.write(_response(404, b'{"error": "not found"}'))
        except (asyncio.IncompleteReadError, ConnectionResetError):
            pass
        except Exception as err:  # noqa: BLE001 — report, keep serving
            msg = json.dumps({"error": str(err)}).encode()
            try:
                writer.write(_response(400, msg))
            except Exception:   # noqa: BLE001
                pass
        finally:
            try:
                await writer.drain()
                writer.close()
            except Exception:   # noqa: BLE001
                pass

    async def _stats(self, writer):
        core = self.llm.core
        stats = {"steps": core.steps_executed,
                 "preemptions": core.preemptions,
                 "cluster_transitions": core.cluster_transitions,
                 "dense_pages_in_use": core.dense_pool.pages_in_use,
                 "prefix_cache": core.prefix_stats()}
        writer.write(_response(200, json.dumps(stats).encode()))

    async def _completions(self, writer, raw: bytes):
        body = json.loads(raw or b"{}")
        prompt = np.asarray(body["prompt"], np.int32)
        sp = _params_of(body)
        priority = int(body.get("priority", 0))
        if body.get("stream"):
            head = ("HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\n"
                    "Cache-Control: no-cache\r\nConnection: close\r\n\r\n")
            writer.write(head.encode("latin1"))
            await writer.drain()
            async for chunk in self.llm.stream(prompt, sp,
                                               priority=priority):
                data = {"tokens": chunk.token_ids,
                        "finished": chunk.finished,
                        "finish_reason": chunk.finish_reason or None}
                writer.write(f"data: {json.dumps(data)}\n\n".encode())
                await writer.drain()
            writer.write(b"data: [DONE]\n\n")
        else:
            out = await self.llm.generate(prompt, sp, priority=priority)
            payload = {"tokens": out.token_ids, "text": out.text,
                       "finish_reason": out.finish_reason,
                       "cached_tokens": out.cached_tokens,
                       "prefill_tokens": out.prefill_tokens}
            writer.write(_response(200, json.dumps(payload).encode()))


async def serve(host: str, port: int, llm=None, ready=None):
    llm = llm or build_llm()
    async with llm:
        server = await asyncio.start_server(Server(llm).handle, host, port)
        addr = server.sockets[0].getsockname()
        print(f"serving on http://{addr[0]}:{addr[1]}  "
              f"(POST /v1/completions, GET /v1/stats)")
        if ready is not None:
            ready.set_result(addr)
        async with server:
            await server.serve_forever()


async def _client(host, port, body) -> dict:
    reader, writer = await asyncio.open_connection(host, port)
    payload = json.dumps(body).encode()
    writer.write((f"POST /v1/completions HTTP/1.1\r\nHost: {host}\r\n"
                  f"Content-Type: application/json\r\n"
                  f"Content-Length: {len(payload)}\r\n\r\n"
                  ).encode() + payload)
    await writer.drain()
    data = await reader.read()
    writer.close()
    head, _, tail = data.partition(b"\r\n\r\n")
    if b"text/event-stream" in head:
        chunks = [json.loads(ln[6:]) for ln in tail.split(b"\n")
                  if ln.startswith(b"data: ") and b"[DONE]" not in ln]
        return {"stream": chunks}
    return json.loads(tail)


async def selftest(port: int = 8181):
    loop = asyncio.get_running_loop()
    ready = loop.create_future()
    task = loop.create_task(serve("127.0.0.1", port, ready=ready))
    await ready
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, 256, size=24).tolist()
    out = await _client("127.0.0.1", port,
                        {"prompt": prompt, "max_tokens": 8})
    assert len(out["tokens"]) == 8, out
    srm = await _client("127.0.0.1", port,
                        {"prompt": prompt, "max_tokens": 8,
                         "stream": True})
    got = [t for c in srm["stream"] for t in c["tokens"]]
    assert got == out["tokens"], (got, out)
    both = await asyncio.gather(
        _client("127.0.0.1", port, {"prompt": prompt, "max_tokens": 8}),
        _client("127.0.0.1", port,
                {"prompt": rng.integers(0, 256, size=16).tolist(),
                 "max_tokens": 8, "priority": 1}))
    assert both[0]["tokens"] == out["tokens"]
    print("selftest OK:", out["tokens"])
    task.cancel()


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8080)
    ap.add_argument("--selftest", action="store_true",
                    help="start the server in-process, run a scripted "
                         "client, exit")
    args = ap.parse_args(argv)
    if args.selftest:
        asyncio.run(selftest(args.port))
    else:
        try:
            asyncio.run(serve(args.host, args.port))
        except KeyboardInterrupt:
            pass


if __name__ == "__main__":
    main(sys.argv[1:])
