"""CHAI KV-cache layouts: cohort (dense -> clustered), unified per-slot,
and the *paged* layout the continuous-batching engine serves from.

Three layouts, one phase machine (PREFILL -> WARMUP -> CLUSTER -> STEADY):

1. **Cohort** (``chai_state_structs`` / ``compact_kv``) — the paper's
   batch-lockstep flow. ``compact_kv`` is §3.5's "remove the Key tokens
   associated [with pruned heads]": after membership identification the
   dense K cache is gathered down to representative rows. Run it as a
   donated jit so the full cache's buffer is released on device.

2. **Unified per-slot** (``unified_state_structs``) — the legacy
   continuous-batching layout (``EngineConfig.kv_layout="dense"``). Dense
   ``kg``/``vg`` AND clustered ``kg_chai`` rectangles stay resident side
   by side for the whole ``batch x max_seq`` envelope, with a per-slot
   ``phase`` vector; ``insert_slot`` / ``compact_kv_slot`` /
   ``reset_slot`` move one slot through its lifecycle. Honest but
   wasteful: resident bytes EXCEED plain MHA.

3. **Paged** (``paged_state_structs``, the engine default) — fixed-size
   pages of ``page_size`` tokens spanning all global layers, drawn from
   two device pools (``kvp``: dense K/V rows, ``n_kv_heads`` wide;
   ``cp``: clustered rows, ``k_max`` wide), addressed through per-slot
   int32 block tables (``bt_kg``/``bt_vg`` -> ``kvp``, ``bt_kc``/
   ``bt_vc`` -> ``cp``). Page 0 of every pool is a reserved *null sink*:
   unallocated block-table entries point at it, so masked/oob writes land
   harmlessly and reads from it are always masked by ``pos`` validity.
   ``PagePool`` is the host-side allocator (free list, page 0 excluded).
   ``insert_slot_paged`` scatters a prefilled request into its pages,
   ``compact_kv_slot_paged`` gathers the representative rows into
   clustered pages and *nulls the dense block-table row* — the engine
   then returns the dense pages to the pool, realizing the paper's KV
   saving at the allocator level (``paged_kv_bytes``) instead of only
   analytically. int8 caches keep per-row scales in mirror-shaped scale
   pools (``kvp_scale``/``cp_scale``) indexed by the same block tables.

``quant_rows``/``dequant_rows`` implement the per-(head, position)
symmetric int8 cache quantization shared by all layouts.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.clustering import chai_widths
from repro.models.transformer import decode_state_structs
from repro.sharding.rules import Ax

# Per-slot lifecycle phases (paper Fig 10). PREFILL and CLUSTER are
# transient (they happen synchronously inside a host-driven jit call); the
# device-resident ``phase`` vector only ever holds FREE / WARMUP / STEADY.
PHASE_FREE = 0
PHASE_PREFILL = 1
PHASE_WARMUP = 2
PHASE_CLUSTER = 3
PHASE_STEADY = 4


def quant_rows(x):
    """Symmetric int8 over the last axis. x: (..., hd) ->
    (int8 same-shape, f32 scale (...))."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequant_rows(q, scale):
    return q.astype(jnp.float32) * scale[..., None]


# -- int4 row quantization (host-side: the compressed KV tier) --------------
#
# The serving tiers (serving/kv_tiers.py) store cold KV pages in host
# memory; under host pressure radix-cached pages drop to an int4 packed
# representation — the same symmetric per-row scheme as ``quant_rows``
# with the int4 extreme ±7 and two codes packed per byte. These run on
# demoted (host-resident) payloads, so they are numpy, not jnp.

def quant_rows_int4(x):
    """Symmetric int4 over the last axis. x: (..., hd) ->
    (int8 codes in [-7, 7] same-shape, f32 scale (...))."""
    x = np.asarray(x, np.float32)
    amax = np.max(np.abs(x), axis=-1)
    scale = np.maximum(amax, 1e-6) / 7.0
    q = np.clip(np.rint(x / scale[..., None]), -7, 7).astype(np.int8)
    return q, scale.astype(np.float32)


def dequant_rows_int4(q, scale):
    return (np.asarray(q, np.int8).astype(np.float32)
            * np.asarray(scale, np.float32)[..., None])


def pack_int4(q):
    """Pack int4 codes (int8 values in [-8, 7]) two per byte along the
    last axis; odd lengths zero-pad. (..., n) int8 -> (..., ceil(n/2))
    uint8, low nibble = even index."""
    q = np.asarray(q, np.int8)
    if q.shape[-1] % 2:
        q = np.concatenate(
            [q, np.zeros(q.shape[:-1] + (1,), np.int8)], axis=-1)
    lo = (q[..., 0::2] & 0x0F).astype(np.uint8)
    hi = (q[..., 1::2] & 0x0F).astype(np.uint8)
    return lo | (hi << 4)


def unpack_int4(packed, n):
    """Inverse of ``pack_int4``: (..., ceil(n/2)) uint8 -> (..., n) int8
    codes, sign-extending each nibble."""
    p = np.asarray(packed, np.uint8)
    lo = (p & 0x0F).astype(np.int8)
    hi = ((p >> 4) & 0x0F).astype(np.int8)
    lo = np.where(lo >= 8, lo - 16, lo).astype(np.int8)
    hi = np.where(hi >= 8, hi - 16, hi).astype(np.int8)
    out = np.empty(p.shape[:-1] + (2 * p.shape[-1],), np.int8)
    out[..., 0::2] = lo
    out[..., 1::2] = hi
    return out[..., :n]


def chai_state_structs(cfg: ModelConfig, batch: int, max_seq: int):
    """Decode-state structs with the clustered K cache (MHA archs only --
    GQA archs keep the plain state)."""
    shapes, logical = decode_state_structs(cfg, batch, max_seq)
    if not (cfg.is_mha and cfg.chai.enabled):
        return shapes, logical
    k_max, _ = chai_widths(cfg)
    dt = shapes["kg"].dtype
    ng, b, _, s, hd = shapes["kg"].shape
    shapes = dict(shapes)
    logical = dict(logical)
    shapes.pop("kg")
    kg_ax = logical.pop("kg")
    shapes["kg_chai"] = jax.ShapeDtypeStruct((ng, b, k_max, s, hd), dt)
    logical["kg_chai"] = Ax("layers", "batch", "clusters", "seq", "head_dim")
    if cfg.kv_cache_dtype == "int8":
        shapes.pop("kg_scale")
        logical.pop("kg_scale")
        shapes["kg_chai_scale"] = jax.ShapeDtypeStruct((ng, b, k_max, s),
                                                       jnp.float32)
        logical["kg_chai_scale"] = Ax("layers", "batch", "clusters", "seq")
    if cfg.chai.share_values:
        shapes.pop("vg")
        logical.pop("vg")
        shapes["vg_chai"] = jax.ShapeDtypeStruct((ng, b, k_max, s, hd), dt)
        logical["vg_chai"] = Ax("layers", "batch", "clusters", "seq",
                                "head_dim")
    return shapes, logical


def init_chai_state(cfg: ModelConfig, batch: int, max_seq: int):
    shapes, _ = chai_state_structs(cfg, batch, max_seq)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)


def add_score_buffer(state, cfg: ModelConfig, batch: int):
    """Attach the warmup score-accumulation buffer (nA, B, H, Wf)."""
    s = state["kg"].shape[3] if "kg" in state else state["kl"].shape[3]
    wf = min(cfg.chai.feature_window, int(s))
    state = dict(state)
    state["chai_scores"] = jnp.zeros(
        (cfg.n_attn_layers, batch, cfg.n_heads, wf), jnp.float32)
    return state


def pop_score_buffer(state):
    state = dict(state)
    scores = state.pop("chai_scores")
    return state, scores


def compact_kv(state, chai_ctx, cfg: ModelConfig):
    """Convert a full MHA decode state into the clustered layout.

    state["kg"]: (nG, B, H, S, hd); ctx reps: (nA, B, k) or (nA, k).
    Returns a new state with kg_chai (and vg_chai under share_values).
    Donate ``state`` when jitting to free the dense K cache in place.
    """
    if not (cfg.is_mha and cfg.chai.enabled):
        return state
    reps = chai_ctx["reps"]
    batched = reps.ndim == 3
    kg = state["kg"]                                  # (nG, B, H, S, hd)
    ng, b, h, s, hd = kg.shape
    k_max = reps.shape[-1]
    # All-global MHA archs: attention layer i == global layer i.
    r = reps if batched else jnp.broadcast_to(reps[:, None, :], (ng, b, k_max))
    idx = r[..., None, None]                          # (nG, B, k, 1, 1)
    kg_chai = jnp.take_along_axis(kg, idx, axis=2)
    new_state = {k: v for k, v in state.items()
                 if k not in ("kg", "kg_scale")}
    new_state["kg_chai"] = kg_chai
    if cfg.kv_cache_dtype == "int8" and "kg_scale" in state:
        new_state["kg_chai_scale"] = jnp.take_along_axis(
            state["kg_scale"], r[..., None], axis=2)
    if cfg.chai.share_values:
        vg_chai = jnp.take_along_axis(state["vg"], idx, axis=2)
        new_state.pop("vg")
        new_state["vg_chai"] = vg_chai
    return new_state


# ---------------------------------------------------------------------------
# Unified per-slot layout (continuous batching)
# ---------------------------------------------------------------------------

def unified_state_structs(cfg: ModelConfig, batch: int, max_seq: int, *,
                          chai: bool = True):
    """Decode-state structs for the continuous-batching engine.

    Dense (``kg``/``vg``) and clustered (``kg_chai``) caches are BOTH
    resident so warmup and steady slots coexist in one batch; ``phase``
    tracks each slot's lifecycle stage and ``chai_scores`` accumulates
    warmup clustering features per slot.
    """
    shapes, logical = decode_state_structs(cfg, batch, max_seq)
    shapes, logical = dict(shapes), dict(logical)
    shapes["phase"] = jax.ShapeDtypeStruct((batch,), jnp.int32)
    logical["phase"] = Ax("batch")
    if not (chai and cfg.chai.enabled and cfg.k_max > 0):
        return shapes, logical
    wf = min(cfg.chai.feature_window, max_seq)
    shapes["chai_scores"] = jax.ShapeDtypeStruct(
        (cfg.n_attn_layers, batch, cfg.n_heads, wf), jnp.float32)
    logical["chai_scores"] = Ax("layers", "batch", "heads", None)
    if cfg.is_mha and "kg" in shapes:
        k_max, _ = chai_widths(cfg)
        dt = shapes["kg"].dtype
        ng, b, _, s, hd = shapes["kg"].shape
        shapes["kg_chai"] = jax.ShapeDtypeStruct((ng, b, k_max, s, hd), dt)
        logical["kg_chai"] = Ax("layers", "batch", "clusters", "seq",
                                "head_dim")
        if cfg.kv_cache_dtype == "int8":
            shapes["kg_chai_scale"] = jax.ShapeDtypeStruct(
                (ng, b, k_max, s), jnp.float32)
            logical["kg_chai_scale"] = Ax("layers", "batch", "clusters",
                                          "seq")
        if cfg.chai.share_values:
            shapes["vg_chai"] = jax.ShapeDtypeStruct((ng, b, k_max, s, hd),
                                                     dt)
            logical["vg_chai"] = Ax("layers", "batch", "clusters", "seq",
                                    "head_dim")
    return shapes, logical


def init_unified_state(cfg: ModelConfig, batch: int, max_seq: int, *,
                       chai: bool = True):
    shapes, _ = unified_state_structs(cfg, batch, max_seq, chai=chai)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)


def insert_slot(state, mini, slot, *, phase=PHASE_WARMUP):
    """Write a freshly prefilled batch=1 decode state into batch slot
    ``slot`` of a unified state and reset the slot's CHAI bookkeeping.
    Donate ``state`` when jitting (in-place slot update on device).
    """
    state = dict(state)
    for k, v in mini.items():
        axis = 0 if state[k].ndim == 1 else 1
        state[k] = jax.lax.dynamic_update_index_in_dim(
            state[k], v.astype(state[k].dtype), slot, axis)
    if "chai_scores" in state:
        nA, _, h, wf = state["chai_scores"].shape
        state["chai_scores"] = jax.lax.dynamic_update_index_in_dim(
            state["chai_scores"], jnp.zeros((nA, 1, h, wf), jnp.float32),
            slot, 1)
    state["phase"] = state["phase"].at[slot].set(phase)
    return state


def compact_kv_slot(state, slot_ctx, cfg: ModelConfig, slot):
    """Per-slot compaction (unified layout): gather ONE batch slot's
    representative K rows from the dense cache into the clustered cache
    and advance that slot's phase to STEADY.

    ``slot_ctx``: batch-free ctx for this request (reps (nA, k)). Donate
    ``state`` when jitting — the gather updates the clustered buffers in
    place; the dense buffers stay resident for the other slots.
    """
    state = dict(state)
    if cfg.is_mha and cfg.chai.enabled and "kg_chai" in state:
        reps = slot_ctx["reps"]                           # (nA, k)

        def gather(dense, clustered, tail_dims):
            row = jax.lax.dynamic_index_in_dim(dense, slot, 1,
                                               keepdims=False)
            idx = reps.reshape(reps.shape + (1,) * tail_dims)
            g = jnp.take_along_axis(row, idx, axis=1)
            return jax.lax.dynamic_update_index_in_dim(clustered, g, slot, 1)

        # All-global MHA archs: attention layer i == global layer i.
        state["kg_chai"] = gather(state["kg"], state["kg_chai"], 2)
        if cfg.kv_cache_dtype == "int8":
            state["kg_chai_scale"] = gather(state["kg_scale"],
                                            state["kg_chai_scale"], 1)
        if cfg.chai.share_values:
            state["vg_chai"] = gather(state["vg"], state["vg_chai"], 2)
    state["phase"] = state["phase"].at[slot].set(PHASE_STEADY)
    return state


def reset_slot(state, slot):
    """Retire a slot: mark FREE and rewind its write position."""
    state = dict(state)
    state["phase"] = state["phase"].at[slot].set(PHASE_FREE)
    state["pos"] = state["pos"].at[slot].set(0)
    return state


def unified_kv_bytes(cfg: ModelConfig, batch: int, seq: int, *,
                     chai: bool = True):
    """Resident KV bytes of the continuous engine's unified layout.

    Unlike the analytic ``kv_cache_bytes`` (cohort steady state: the
    dense cache is freed after compaction), the unified layout keeps
    dense AND clustered buffers allocated — summed exactly from the
    layout's own structs."""
    import numpy as np
    shapes, _ = unified_state_structs(cfg, batch, seq, chai=chai)
    kv_keys = ("kg", "vg", "kg_scale", "vg_scale", "kl", "vl",
               "kg_chai", "kg_chai_scale", "vg_chai")
    return int(sum(np.prod(s.shape) * s.dtype.itemsize
                   for k, s in shapes.items() if k in kv_keys))


# ---------------------------------------------------------------------------
# Paged layout (continuous batching, EngineConfig.kv_layout="paged")
# ---------------------------------------------------------------------------

NULL_PAGE = 0   # reserved per-pool sink; never allocated, never read valid


class PagePool:
    """Host-side page allocator for one device pool.

    ``num_pages`` is the pool array's page dimension; page ``NULL_PAGE``
    is reserved as the sink for unallocated block-table entries, so the
    usable capacity is ``num_pages - 1``. Allocation state lives on the
    host (the device only ever sees block tables); ``alloc``/``free``
    are O(n) list ops on the free list.

    Pages are **reference counted** so the prefix cache can alias one
    physical page into many block tables (and its own radix index):
    ``alloc`` hands out pages at refcount 1, ``incref`` adds a sharer,
    ``free`` drops one reference and returns the page to the free list
    only when the count reaches zero (freed-at-zero semantics). Shared
    pages are read-only by convention — a writer must copy first
    (copy-on-write, ``copy_pool_page``).
    """

    def __init__(self, num_pages: int, page_size: int):
        assert num_pages >= 2, "pool needs the null page plus capacity"
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        # LIFO free list (reuse-hot pages first); page 0 excluded.
        self._free = list(range(self.num_pages - 1, NULL_PAGE, -1))
        self._rc: dict = {}            # page id -> reference count

    @property
    def capacity(self):
        return self.num_pages - 1

    @property
    def free_pages(self):
        return len(self._free)

    @property
    def pages_in_use(self):
        return self.capacity - len(self._free)

    def refcount(self, page: int) -> int:
        return self._rc.get(int(page), 0)

    def counters(self) -> dict:
        """Refcount-exact allocator snapshot: free pages, pages in use,
        and total outstanding references. The abort path's no-leak
        guarantee is checked against this — after a mid-flight abort the
        counters must return to their pre-admission values."""
        return {"free": len(self._free),
                "in_use": self.pages_in_use,
                "refs": int(sum(self._rc.values()))}

    def alloc(self, n: int):
        """Pop ``n`` pages at refcount 1; raises if the pool cannot
        cover them."""
        if n > len(self._free):
            raise MemoryError(
                f"page pool exhausted: want {n}, have {len(self._free)} "
                f"of {self.capacity}")
        pages, self._free = self._free[:n], self._free[n:]
        for p in pages:
            self._rc[p] = 1
        return pages

    def incref(self, pages):
        """Add one reference per page (aliasing an allocated page)."""
        for p in pages:
            p = int(p)
            assert self._rc.get(p, 0) > 0, f"incref of free page {p}"
            self._rc[p] += 1

    def free(self, pages):
        """Drop one reference per page; a page returns to the free list
        when its count reaches zero (double-free / null-free guarded)."""
        for p in pages:
            p = int(p)
            assert p != NULL_PAGE, "freeing the null page"
            assert 0 < p < self.num_pages, p
            rc = self._rc.get(p, 0)
            assert rc > 0, f"double free of page {p}"
            if rc == 1:
                del self._rc[p]
                self._free.append(p)
            else:
                self._rc[p] = rc - 1


def pages_needed(tokens: int, page_size: int):
    return -(-int(tokens) // int(page_size))


def gather_pages(pool, bt):
    """Dense logical view of one pool through block tables.

    pool: (nP, rows, page[, hd]); bt: (B, P) int32 ->
    (B, rows, P*page[, hd]). Entries pointing at the null page yield
    garbage rows — callers mask by ``pos`` validity, exactly as the dense
    rectangles mask their zero tail."""
    g = pool[bt]                                  # (B, P, rows, page[, hd])
    m = jnp.moveaxis(g, 2, 1)                     # (B, rows, P, page[, hd])
    b, rows, p, ps = m.shape[:4]
    return m.reshape((b, rows, p * ps) + m.shape[4:])


def paged_state_structs(cfg: ModelConfig, batch: int, max_seq: int, *,
                        page_size: int, dense_pages: int,
                        chai_pages: int = 0, chai: bool = True):
    """Decode-state structs for the paged continuous-batching layout.

    The dense per-slot ``kg``/``vg`` rectangles are replaced by one
    shared pool ``kvp`` of ``dense_pages`` pages (page = ``page_size``
    tokens x all global layers x ``n_kv_heads`` rows) plus per-slot
    block tables ``bt_kg``/``bt_vg``; MHA+CHAI archs add the clustered
    pool ``cp`` (``k_max`` rows) with tables ``bt_kc`` (and ``bt_vc``
    under ``share_values``). Everything else (local ring caches,
    recurrent state, ``pos``/``phase``/``chai_scores``) matches the
    unified layout."""
    from repro.models.transformer import decode_state_structs as _structs
    assert max_seq % page_size == 0, (max_seq, page_size)
    n_slot_pages = max_seq // page_size
    shapes, logical = _structs(cfg, batch, max_seq)
    shapes, logical = dict(shapes), dict(logical)
    shapes["phase"] = jax.ShapeDtypeStruct((batch,), jnp.int32)
    logical["phase"] = Ax("batch")
    chai_on = chai and cfg.chai.enabled and cfg.k_max > 0
    int8 = cfg.kv_cache_dtype == "int8"
    bt_sds = jax.ShapeDtypeStruct((batch, n_slot_pages), jnp.int32)
    if cfg.n_global_layers:
        ng, kv, hd = cfg.n_global_layers, cfg.n_kv_heads, cfg.head_dim
        cache_dt = shapes["kg"].dtype
        for k in ("kg", "vg", "kg_scale", "vg_scale"):
            shapes.pop(k, None)
            logical.pop(k, None)
        shapes["kvp"] = jax.ShapeDtypeStruct(
            (ng, dense_pages, kv, page_size, hd), cache_dt)
        logical["kvp"] = Ax("layers", None, "kv_heads", None, "head_dim")
        if int8:
            shapes["kvp_scale"] = jax.ShapeDtypeStruct(
                (ng, dense_pages, kv, page_size), jnp.float32)
            logical["kvp_scale"] = Ax("layers", None, "kv_heads", None)
        shapes["bt_kg"] = bt_sds
        shapes["bt_vg"] = bt_sds
        logical["bt_kg"] = Ax("batch", None)
        logical["bt_vg"] = Ax("batch", None)
    if not chai_on:
        return shapes, logical
    wf = min(cfg.chai.feature_window, max_seq)
    shapes["chai_scores"] = jax.ShapeDtypeStruct(
        (cfg.n_attn_layers, batch, cfg.n_heads, wf), jnp.float32)
    logical["chai_scores"] = Ax("layers", "batch", "heads", None)
    if cfg.is_mha and "kvp" in shapes:
        k_max, _ = chai_widths(cfg)
        ng, hd = cfg.n_global_layers, cfg.head_dim
        cache_dt = shapes["kvp"].dtype
        shapes["cp"] = jax.ShapeDtypeStruct(
            (ng, chai_pages, k_max, page_size, hd), cache_dt)
        logical["cp"] = Ax("layers", None, "clusters", None, "head_dim")
        if int8:
            shapes["cp_scale"] = jax.ShapeDtypeStruct(
                (ng, chai_pages, k_max, page_size), jnp.float32)
            logical["cp_scale"] = Ax("layers", None, "clusters", None)
        shapes["bt_kc"] = bt_sds
        logical["bt_kc"] = Ax("batch", None)
        if cfg.chai.share_values:
            shapes["bt_vc"] = bt_sds
            logical["bt_vc"] = Ax("batch", None)
    return shapes, logical


def init_paged_state(cfg: ModelConfig, batch: int, max_seq: int, *,
                     page_size: int, dense_pages: int, chai_pages: int = 0,
                     chai: bool = True):
    shapes, _ = paged_state_structs(cfg, batch, max_seq,
                                    page_size=page_size,
                                    dense_pages=dense_pages,
                                    chai_pages=chai_pages, chai=chai)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)


def _scatter_pages(pool, x, pages):
    """Scatter a dense batch-1 rectangle into pool pages.

    pool: (nG, nP, rows, page[, hd]); x: (nG, 1, rows, S[, hd]);
    pages: (P,) int32 with null-padding (trailing writes land in the
    null sink). S == P * page."""
    ng, _, rows, s = x.shape[:4]
    page = pool.shape[3]
    p = s // page
    m = x.reshape((ng, rows, p, page) + x.shape[4:])
    m = jnp.moveaxis(m, 2, 1)                    # (nG, P, rows, page[, hd])
    return pool.at[:, pages].set(m.astype(pool.dtype))


def insert_slot_paged(state, mini, slot, kg_pages, vg_pages, *,
                      bt_kg_row=None, bt_vg_row=None):
    """Paged ``insert_slot``: write a prefilled batch=1 dense decode state
    into slot ``slot``, scattering its global K/V rows into the slot's
    freshly allocated pages and recording the block tables. Donate
    ``state`` when jitting.

    Prefix-cache admissions alias shared pages: ``kg_pages``/``vg_pages``
    are then the SCATTER vectors (``NULL_PAGE`` for the cached-prefix
    logical pages, so the mini state's zero rows land in the null sink)
    while ``bt_kg_row``/``bt_vg_row`` carry the full logical->physical
    mapping including the aliased pages. Default (cold path): block
    tables == scatter vectors."""
    state = dict(state)
    paged_keys = ("kg", "vg", "kg_scale", "vg_scale")
    for k, v in mini.items():
        if k in paged_keys:
            continue
        axis = 0 if state[k].ndim == 1 else 1
        state[k] = jax.lax.dynamic_update_index_in_dim(
            state[k], v.astype(state[k].dtype), slot, axis)
    if "kvp" in state and "kg" in mini:
        state["kvp"] = _scatter_pages(state["kvp"], mini["kg"], kg_pages)
        state["kvp"] = _scatter_pages(state["kvp"], mini["vg"], vg_pages)
        if "kvp_scale" in state:
            state["kvp_scale"] = _scatter_pages(
                state["kvp_scale"], mini["kg_scale"], kg_pages)
            state["kvp_scale"] = _scatter_pages(
                state["kvp_scale"], mini["vg_scale"], vg_pages)
        state["bt_kg"] = state["bt_kg"].at[slot].set(
            kg_pages if bt_kg_row is None else bt_kg_row)
        state["bt_vg"] = state["bt_vg"].at[slot].set(
            vg_pages if bt_vg_row is None else bt_vg_row)
    if "chai_scores" in state:
        nA, _, h, wf = state["chai_scores"].shape
        state["chai_scores"] = jax.lax.dynamic_update_index_in_dim(
            state["chai_scores"], jnp.zeros((nA, 1, h, wf), jnp.float32),
            slot, 1)
    state["phase"] = state["phase"].at[slot].set(PHASE_WARMUP)
    return state


def compact_kv_slot_paged(state, slot_ctx, cfg: ModelConfig, slot,
                          kc_pages, vc_pages=None):
    """Paged per-slot compaction: gather slot ``slot``'s representative K
    rows out of its dense pages into the clustered pages ``kc_pages``,
    then *null the dense block-table row* — after this jit returns, the
    engine hands the dense pages back to the ``PagePool`` (the
    allocator-level realization of the paper's §3.5 KV saving).

    Under ``share_values`` the dense V pages are compacted into
    ``vc_pages`` and freed the same way; otherwise V stays page-resident
    in the dense pool until retire. Donate ``state`` when jitting."""
    state = dict(state)
    if cfg.is_mha and cfg.chai.enabled and "cp" in state:
        reps = slot_ctx["reps"]                              # (nA, k)
        null_row = jnp.zeros_like(kc_pages)

        def gather(pool_key, scale_key, bt_key, dst_pages):
            bt_row = jax.lax.dynamic_index_in_dim(
                state[bt_key], slot, 0, keepdims=False)      # (P,)
            rows = state["kvp"][:, bt_row]       # (nG, P, KV, page, hd)
            idx = reps[:, None, :, None, None]
            g = jnp.take_along_axis(rows, idx, axis=2)
            state[pool_key] = state[pool_key].at[:, dst_pages].set(
                g.astype(state[pool_key].dtype))
            if scale_key in state:
                srows = state["kvp_scale"][:, bt_row]
                sg = jnp.take_along_axis(srows, reps[:, None, :, None],
                                         axis=2)
                state[scale_key] = state[scale_key].at[:, dst_pages].set(sg)
            state[bt_key] = state[bt_key].at[slot].set(null_row)

        gather("cp", "cp_scale", "bt_kg", kc_pages)
        state["bt_kc"] = state["bt_kc"].at[slot].set(kc_pages)
        if cfg.chai.share_values:
            # V codes move scale-less, mirroring the unified layout's
            # vg -> vg_chai gather (int8 codes are reinterpreted).
            vd_pages = kc_pages if vc_pages is None else vc_pages
            bt_row = jax.lax.dynamic_index_in_dim(
                state["bt_vg"], slot, 0, keepdims=False)
            rows = state["kvp"][:, bt_row]
            g = jnp.take_along_axis(rows, reps[:, None, :, None, None],
                                    axis=2)
            state["cp"] = state["cp"].at[:, vd_pages].set(
                g.astype(state["cp"].dtype))
            state["bt_vc"] = state["bt_vc"].at[slot].set(vd_pages)
            state["bt_vg"] = state["bt_vg"].at[slot].set(null_row)
    state["phase"] = state["phase"].at[slot].set(PHASE_STEADY)
    return state


def copy_pool_page(state, src, dst, *, kind):
    """Copy ONE physical page (all global layers) inside a pool — the
    copy-on-write primitive for the prefix cache. ``kind="dense"`` copies
    ``kvp`` (+ ``kvp_scale``), ``kind="chai"`` copies ``cp`` (+
    ``cp_scale``). ``src``/``dst`` are traced int32 scalars; donate
    ``state`` when jitting."""
    keys = (("kvp", "kvp_scale") if kind == "dense"
            else ("cp", "cp_scale"))
    state = dict(state)
    for k in keys:
        if k in state:
            row = jax.lax.dynamic_index_in_dim(state[k], src, 1,
                                               keepdims=False)
            state[k] = jax.lax.dynamic_update_index_in_dim(state[k], row,
                                                           dst, 1)
    return state


def restore_slot_snapshot(state, slot, bt_kg_row, bt_vg_row, bt_kc_row,
                          bt_vc_row, pos):
    """Prefix-cache snapshot resume: point slot ``slot``'s block tables at
    the (shared / copied) snapshot pages, rewind ``pos`` to the snapshot's
    STEADY-entry position, and enter STEADY directly — the warm request
    skips PREFILL, WARMUP and CLUSTER entirely. Donate ``state`` when
    jitting."""
    state = dict(state)
    for key, row in (("bt_kg", bt_kg_row), ("bt_vg", bt_vg_row),
                     ("bt_kc", bt_kc_row), ("bt_vc", bt_vc_row)):
        if key in state:
            state[key] = state[key].at[slot].set(row)
    state["pos"] = state["pos"].at[slot].set(pos)
    if "chai_scores" in state:
        nA, _, h, wf = state["chai_scores"].shape
        state["chai_scores"] = jax.lax.dynamic_update_index_in_dim(
            state["chai_scores"], jnp.zeros((nA, 1, h, wf), jnp.float32),
            slot, 1)
    state["phase"] = state["phase"].at[slot].set(PHASE_STEADY)
    return state


_PAGED_POOL_KEYS = ("kvp", "kvp_scale", "cp", "cp_scale")


def save_slot_paged(state, slot, kg_pages, vg_pages, kc_pages, vc_pages):
    """Preemption swap-out: gather slot ``slot``'s entire per-slot state
    — every per-slot column (``pos``, ``phase``, ``chai_scores``, local
    rings, …) plus the CONTENTS of its pool pages — so the engine can
    free the physical pages and later restore the slot bitwise
    (``load_slot_paged``). Recompute-based resume cannot be exact here:
    CHAI decode is an approximation of full attention, so the K/V rows a
    re-prefill would produce for generated tokens differ from the rows
    the original decode wrote.

    Page vectors are the null-padded ``(P,)`` logical->physical maps; a
    pool kind the slot does not hold (e.g. dense K after compaction)
    passes an all-null vector and round-trips null-sink garbage, keeping
    one trace per arch. Returns ``(cols, pools)`` pytrees."""
    cols = {}
    for k, v in state.items():
        if k in _PAGED_POOL_KEYS or k.startswith("bt_"):
            continue
        axis = 0 if v.ndim == 1 else 1
        cols[k] = jax.lax.dynamic_index_in_dim(v, slot, axis,
                                               keepdims=True)
    pools = {}
    if "kvp" in state:
        pools["kg"] = state["kvp"][:, kg_pages]
        pools["vg"] = state["kvp"][:, vg_pages]
        if "kvp_scale" in state:
            pools["kg_scale"] = state["kvp_scale"][:, kg_pages]
            pools["vg_scale"] = state["kvp_scale"][:, vg_pages]
    if "cp" in state:
        pools["kc"] = state["cp"][:, kc_pages]
        if "cp_scale" in state:
            pools["kc_scale"] = state["cp_scale"][:, kc_pages]
        if "bt_vc" in state:
            pools["vc"] = state["cp"][:, vc_pages]
            if "cp_scale" in state:
                pools["vc_scale"] = state["cp_scale"][:, vc_pages]
    return cols, pools


def load_slot_paged(state, slot, cols, pools, kg_pages, vg_pages,
                    kc_pages, vc_pages, bt_kg_row, bt_vg_row, bt_kc_row,
                    bt_vc_row):
    """Preemption swap-in: the inverse of ``save_slot_paged`` against
    freshly allocated pages. Per-slot columns are written back verbatim,
    saved page contents are scattered at the new physical ids, and the
    block tables are rebuilt from the new logical->physical maps
    (null-padded vectors land their tails in the null sink, as every
    paged write does). Donate ``state`` when jitting."""
    state = dict(state)
    for k, v in cols.items():
        axis = 0 if state[k].ndim == 1 else 1
        state[k] = jax.lax.dynamic_update_index_in_dim(
            state[k], v.astype(state[k].dtype), slot, axis)
    if "kvp" in state:
        state["kvp"] = state["kvp"].at[:, kg_pages].set(pools["kg"])
        state["kvp"] = state["kvp"].at[:, vg_pages].set(pools["vg"])
        if "kvp_scale" in state:
            state["kvp_scale"] = state["kvp_scale"].at[:, kg_pages].set(
                pools["kg_scale"])
            state["kvp_scale"] = state["kvp_scale"].at[:, vg_pages].set(
                pools["vg_scale"])
        state["bt_kg"] = state["bt_kg"].at[slot].set(bt_kg_row)
        state["bt_vg"] = state["bt_vg"].at[slot].set(bt_vg_row)
    if "cp" in state:
        state["cp"] = state["cp"].at[:, kc_pages].set(pools["kc"])
        if "cp_scale" in state:
            state["cp_scale"] = state["cp_scale"].at[:, kc_pages].set(
                pools["kc_scale"])
        state["bt_kc"] = state["bt_kc"].at[slot].set(bt_kc_row)
        if "bt_vc" in state:
            state["cp"] = state["cp"].at[:, vc_pages].set(pools["vc"])
            if "cp_scale" in state:
                state["cp_scale"] = state["cp_scale"].at[:, vc_pages].set(
                    pools["vc_scale"])
            state["bt_vc"] = state["bt_vc"].at[slot].set(bt_vc_row)
    return state


def reset_slot_paged(state, slot):
    """Paged retire: phase -> FREE, rewind ``pos``, null every block-table
    row (the engine frees the physical pages host-side)."""
    state = dict(state)
    state["phase"] = state["phase"].at[slot].set(PHASE_FREE)
    state["pos"] = state["pos"].at[slot].set(0)
    for key in ("bt_kg", "bt_vg", "bt_kc", "bt_vc"):
        if key in state:
            state[key] = state[key].at[slot].set(
                jnp.zeros((state[key].shape[1],), jnp.int32))
    return state


def paged_page_bytes(cfg: ModelConfig, page_size: int, *, kind: str):
    """Bytes of ONE page (``page_size`` tokens x all global layers).

    kind="dense": ``n_kv_heads`` rows (+ f32 scales under int8);
    kind="chai": ``k_max`` clustered rows (+ scales)."""
    if cfg.n_global_layers == 0:
        return 0
    if kind == "dense":
        rows = cfg.n_kv_heads
    else:
        rows, _ = chai_widths(cfg)
    int8 = cfg.kv_cache_dtype == "int8"
    esize = 1 if int8 else jnp.dtype(cfg.dtype).itemsize
    n = cfg.n_global_layers * rows * page_size * cfg.head_dim * esize
    if int8:
        n += cfg.n_global_layers * rows * page_size * 4      # f32 scales
    return int(n)


def paged_kv_bytes(cfg: ModelConfig, page_size: int, dense_in_use: int,
                   chai_in_use: int = 0, *, batch: int = 0,
                   max_seq: int = 0):
    """ACTUAL allocated KV bytes of the paged layout: pages in use times
    page bytes, plus the (non-paged) local ring caches. This is the
    number the continuous engine reports — it falls when dense pages are
    freed at compaction, unlike the unified layout's constant
    dense+clustered residency."""
    total = (dense_in_use * paged_page_bytes(cfg, page_size, kind="dense")
             + chai_in_use * paged_page_bytes(cfg, page_size, kind="chai"))
    if batch and cfg.n_local_layers:
        w = min(cfg.window_size, max_seq)
        dt = jnp.dtype(cfg.dtype).itemsize
        total += int(2 * cfg.n_local_layers * batch * cfg.n_kv_heads
                     * w * cfg.head_dim * dt)
    return int(total)


def kv_cache_bytes(cfg: ModelConfig, batch: int, seq: int, *,
                   chai: bool = False):
    """Analytic steady-state KV-cache size in bytes (paper Fig 11)."""
    if cfg.n_attn_layers == 0:
        return 0
    if cfg.kv_cache_dtype == "int8":
        esize = 1 + 4 / cfg.head_dim      # int8 row + f32 scale per row
    else:
        esize = jnp.dtype(cfg.dtype).itemsize
    hd = cfg.head_dim
    k_max, _ = chai_widths(cfg)
    total = 0
    for lt in cfg.layer_types:
        if lt == "attn_global":
            k_rows = k_max if (chai and cfg.is_mha and cfg.chai.enabled) \
                else cfg.n_kv_heads
            v_rows = (k_max if (chai and cfg.is_mha and
                                cfg.chai.share_values) else cfg.n_kv_heads)
            total += int(batch * (k_rows + v_rows) * seq * hd * esize)
        elif lt == "attn_local":
            w = min(cfg.window_size, seq)
            total += int(batch * 2 * cfg.n_kv_heads * w * hd * esize)
    return total
