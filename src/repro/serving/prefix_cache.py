"""Shared-prefix KV reuse: a radix tree over token blocks + CHAI snapshots.

Production traffic is dominated by requests sharing long prefixes (system
prompts, few-shot templates, multi-turn history). This module indexes the
engine's ``PagePool`` pages by prompt content so a new request can alias
the pages an earlier request already filled and prefill only its uncached
suffix:

* **Radix tree of blocks.** A block is ``page_size`` tokens — exactly one
  physical page per pool — so one radix node maps one token block to the
  (dense K, dense V) page pair holding it. Children are keyed by the next
  block's token tuple, so prompts diverging anywhere inside a block get
  separate nodes while common whole-block prefixes share one chain.
  Matching is capped at ``(len(prompt) - 1) // page_size`` blocks so at
  least one suffix token is always forwarded (its logits seed decode).

* **Reference counting + copy-on-write.** Cached pages are aliased into
  slot block tables with ``PagePool.incref``; ``free`` drops references
  and returns a page to the free list only at zero. Shared pages are
  read-only by convention: suffix prefill scatters through NULLed scatter
  vectors, and decode never writes below a slot's admission position —
  the only writable shared page (a snapshot's partial tail) is copied at
  capture/resume time (``copy_pool_page``).

* **CHAI snapshots** — the CHAI-specific fast path. Clustering features,
  membership, the compacted clustered pages AND the greedy warmup tokens
  are all pure functions of the prompt, so when a request finishes its
  CLUSTER transition the engine captures {membership ctx, clustered K
  pages, dense V pages, warmup tokens, STEADY-entry ``pos``} keyed by the
  FULL prompt. A warm request with an identical prompt replays the warmup
  tokens from the host and enters STEADY directly — zero prefill
  attention FLOPs, zero WARMUP/CLUSTER steps, token-for-token parity with
  the cold path (greedy decode is deterministic).

* **Ordered-LRU eviction, pinned while in use.** Nodes/snapshots
  referenced by an active slot carry a lock count and are never evicted.
  Evictable entries (unlocked leaves + unlocked snapshots) live in ONE
  ``OrderedDict`` kept in last-use order — ``_touch`` is a
  ``move_to_end``, eviction pops the first FRONT entry matching the
  pressured pool — so the admission-path victim search costs the skipped
  prefix of un-wanted-kind entries (O(1) when kinds are not segregated
  at the front; worst case the count of the other kind) instead of the
  old unconditional O(entries) radix walk + snapshot scan per victim.
  Membership is maintained at the
  edges: ``lock`` removes an entry, ``unlock`` (count reaching zero)
  re-files it, growing a child removes the parent (no longer a leaf),
  and evicting a node's last sibling re-files the newly-leaf parent (at
  the MRU end — the one deliberate approximation, documented at
  ``_evict_one``). Dropping an entry drops the cache's page references —
  a page shared with a still-active slot stays allocated until that slot
  retires (freed-at-zero).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import numpy as np


def _block_key(tokens) -> Tuple[int, ...]:
    return tuple(int(t) for t in tokens)


@dataclasses.dataclass
class BlockNode:
    """One cached token block -> its (dense K, dense V) physical pages."""
    key: Tuple[int, ...]
    kg_page: int
    vg_page: int
    parent: Optional["BlockNode"]
    children: Dict[Tuple[int, ...], "BlockNode"] = \
        dataclasses.field(default_factory=dict)
    locks: int = 0                 # active slots aliasing this node
    # Relay decode (engine-owned): ``resident`` caches a packed
    # contiguous copy of the chain's prefix pages, keyed by the page
    # lists it was built from; ``evicted`` marks a node dropped from the
    # tree so in-flight relay groups referencing it stop re-forming
    # (slots still hold their own page references — only the shared
    # resident view dies with the node).
    resident: Optional[tuple] = None
    evicted: bool = False
    # KV tiering (serving/kv_tiers.py): ``tier`` says where this block's
    # payload lives. "hot" = ``kg_page``/``vg_page`` are live device
    # pages; a demoted node keeps its place in the radix tree (still
    # matchable) but its payload sits in host/compressed tier pages
    # (``tier_pages``, CRC-stamped at demotion) and the device page ids
    # are stale until promotion rewrites them.
    tier: str = "hot"
    tier_pages: Dict[str, list] = dataclasses.field(default_factory=dict)
    tier_crc: int = 0
    prefetched: bool = False
    compressible: bool = True   # int4 ladder allowed (a hit re-plans cold)

    @property
    def is_leaf(self):
        return not self.children

    @property
    def hot_leaf(self):
        """No hot children: the node holds the deepest DEVICE pages on
        its path, so evicting/demoting it strands nothing. Demoted
        children stay in the tree (their payloads live tier-side), so
        plain ``is_leaf`` would freeze ancestors of demoted leaves out
        of the eviction order forever."""
        return not any(c.tier == "hot" for c in self.children.values())

    def chain(self) -> List["BlockNode"]:
        """Root-first list of nodes from the root (exclusive) to here."""
        out: List[BlockNode] = []
        node = self
        while node is not None and node.parent is not None:
            out.append(node)
            node = node.parent
        out.reverse()
        return out


@dataclasses.dataclass
class ChaiSnapshot:
    """STEADY-entry state of a fully-processed prompt (CHAI fast path).

    ``pos`` is the decode position at STEADY entry (prompt + warmup);
    ``tokens`` the greedy tokens generated through warmup (replayed on a
    hit); ``ctx`` the host-side batch-free membership arrays; the page
    lists cover positions [0, pos) — full pages shared, the partial tail
    page a cache-owned copy."""
    prompt: Tuple[int, ...]
    pos: int
    tokens: List[int]
    ctx: Dict[str, np.ndarray]
    vg_pages: List[int]            # dense pool ([] under share_values)
    kc_pages: List[int]            # clustered pool
    vc_pages: List[int]            # clustered pool (share_values only)
    locks: int = 0
    evicted: bool = False
    # KV tiering: snapshots ride the host tier only — their replay
    # contract is bitwise, so the lossy int4 rung is off-limits.
    tier: str = "hot"
    tier_pages: Dict[str, list] = dataclasses.field(default_factory=dict)
    tier_crc: int = 0
    prefetched: bool = False
    compressible: bool = False


class PrefixCache:
    """Radix-tree prefix index over one engine's page pools."""

    def __init__(self, dense_pool, chai_pool, page_size: int):
        self.dense_pool = dense_pool
        self.chai_pool = chai_pool
        self.page_size = int(page_size)
        self.root = BlockNode(key=(), kg_page=-1, vg_page=-1, parent=None)
        self._snapshots: Dict[Tuple[int, ...], ChaiSnapshot] = {}
        # Evictable entries (unlocked leaf nodes + unlocked snapshots) in
        # last-use order: front = LRU victim. Keyed by id(entry) — the
        # entry objects are the values; O(1) touch / add / discard.
        self._lru: "OrderedDict[int, object]" = OrderedDict()
        # "partial_hits" counts every block-prefix reuse (the radix match
        # is capped below a full prompt by construction); full-prompt
        # reuse shows up as "snapshot_hits".
        self.stats = {"partial_hits": 0, "misses": 0,
                      "snapshot_hits": 0, "tokens_reused": 0,
                      "tokens_prefilled": 0, "inserted_blocks": 0,
                      "evicted_blocks": 0, "evicted_snapshots": 0,
                      "demoted_blocks": 0, "demoted_snapshots": 0,
                      "promoted_blocks": 0, "promoted_snapshots": 0}
        # KV tiering (serving/kv_tiers.py), wired by the engine:
        # ``tiers`` owns the host/compressed pools and the demoted-entry
        # LRUs; ``demote_hook`` (engine._demote_entry) turns eviction
        # into demotion when host offload is enabled.
        self.tiers = None
        self.demote_hook = None

    # -- bookkeeping -------------------------------------------------------
    def _touch(self, entry):
        # the OrderedDict IS the recency order (locked / interior
        # entries are outside it and re-file on unlock / leaf-ification)
        if id(entry) in self._lru:
            self._lru.move_to_end(id(entry))
        elif self.tiers is not None and entry.tier != "hot":
            self.tiers.touch(entry)     # demoted: recency lives tier-side

    def _lru_file(self, entry):
        """(Re-)file an entry at the MRU end if it is currently
        evictable: unlocked, not already dropped, and a snapshot or a
        leaf node. Demoted entries file in THEIR tier's LRU instead —
        the device-side LRU only ever holds hot entries."""
        if entry.locks or getattr(entry, "evicted", False):
            return
        if entry.tier != "hot":
            if self.tiers is not None:
                self.tiers.unpin(entry)
            return
        if isinstance(entry, BlockNode) and not entry.hot_leaf:
            return
        self._lru[id(entry)] = entry
        self._lru.move_to_end(id(entry))

    def _lru_drop(self, entry):
        self._lru.pop(id(entry), None)

    @property
    def num_blocks(self):
        n, stack = 0, [self.root]
        while stack:
            node = stack.pop()
            n += len(node.children)
            stack.extend(node.children.values())
        return n

    @property
    def num_snapshots(self):
        return len(self._snapshots)

    def held_pages(self):
        """(dense, chai) DEVICE page references currently held by the
        cache. Demoted entries hold none — their payloads live in tier
        pages, accounted by the tier pools themselves."""
        dense = chai = 0
        stack = [self.root]
        while stack:
            node = stack.pop()
            for c in node.children.values():
                if c.tier == "hot":
                    dense += 2         # kg + vg
                stack.append(c)
        for snap in self._snapshots.values():
            if snap.tier != "hot":
                continue
            dense += len(snap.vg_pages)
            chai += len(snap.kc_pages) + len(snap.vc_pages)
        return dense, chai

    # -- dense block index -------------------------------------------------
    def match(self, prompt) -> List[BlockNode]:
        """Longest cached whole-block prefix of ``prompt``, capped so at
        least one token remains for the suffix prefill. Matched nodes are
        LRU-touched; the caller locks the ones it aliases."""
        ps = self.page_size
        max_blocks = (len(prompt) - 1) // ps
        out: List[BlockNode] = []
        node = self.root
        for j in range(max_blocks):
            child = node.children.get(_block_key(prompt[j * ps:(j + 1) * ps]))
            if child is None:
                break
            self._touch(child)
            out.append(child)
            node = child
        return out

    def insert(self, prompt, kg_pages, vg_pages) -> int:
        """Index every full block of ``prompt``; ``kg_pages``/``vg_pages``
        are the prompt's logical page lists (aliased prefix + the slot's
        fresh pages, in logical order). Newly created nodes take a cache
        reference on their pages (``incref``); existing nodes are
        untouched. Returns the number of new nodes."""
        ps = self.page_size
        n_blocks = len(prompt) // ps
        node, created = self.root, 0
        for j in range(n_blocks):
            key = _block_key(prompt[j * ps:(j + 1) * ps])
            child = node.children.get(key)
            if child is None:
                kg, vg = int(kg_pages[j]), int(vg_pages[j])
                self.dense_pool.incref([kg])
                self.dense_pool.incref([vg])
                child = BlockNode(key=key, kg_page=kg, vg_page=vg,
                                  parent=node)
                node.children[key] = child
                created += 1
                if node is not self.root:
                    self._lru_drop(node)    # grew a child: not a leaf
                self._lru_file(child)
            self._touch(child)
            node = child
        self.stats["inserted_blocks"] += created
        return created

    # -- CHAI snapshots ----------------------------------------------------
    def snapshot_for(self, prompt) -> Optional[ChaiSnapshot]:
        snap = self._snapshots.get(_block_key(prompt))
        if snap is not None:
            self._touch(snap)
        return snap

    def add_snapshot(self, snap: ChaiSnapshot):
        """Register a snapshot (pages must already carry the cache's
        references). One snapshot per exact prompt."""
        assert snap.prompt not in self._snapshots
        self._snapshots[snap.prompt] = snap
        self._lru_file(snap)
        self._touch(snap)

    def drop_snapshot(self, snap: ChaiSnapshot):
        """Remove a snapshot whose restore failed (fault recovery): its
        page references return to the pools and the prompt re-plans cold
        next admission. No-op if the snapshot is not registered; a
        snapshot still locked by ANOTHER slot is left alone (that slot's
        restore already succeeded — the entry is not provably damaged,
        and dropping it would strand the lock)."""
        if self._snapshots.get(snap.prompt) is not snap or snap.locks:
            return
        self._lru_drop(snap)
        del self._snapshots[snap.prompt]
        snap.evicted = True
        self._release_entry_pages(snap)
        self.stats["evicted_snapshots"] += 1

    # -- pinning -----------------------------------------------------------
    def lock(self, entries):
        for e in entries:
            e.locks += 1
            self._lru_drop(e)           # pinned: never a victim
            if self.tiers is not None and e.tier != "hot":
                self.tiers.pin(e)       # ...in any tier

    def unlock(self, entries):
        for e in entries:
            assert e.locks > 0
            e.locks -= 1
            if e.locks == 0:
                self._lru_file(e)       # evictable again (if leaf/snap)

    # -- eviction / tier ladder --------------------------------------------
    def _release_entry_pages(self, entry):
        """Return an entry's pages wherever they live: device pools for
        a hot entry (recording the hot->gone transition when a tier
        manager is attached), tier storage otherwise."""
        if entry.tier != "hot":
            self.tiers.discard_entry(entry)     # records ->gone itself
            entry.tier = "gone"
            return
        if isinstance(entry, ChaiSnapshot):
            dense, chai = len(entry.vg_pages), (len(entry.kc_pages)
                                                + len(entry.vc_pages))
            if entry.vg_pages:
                self.dense_pool.free(entry.vg_pages)
            if entry.kc_pages:
                self.chai_pool.free(entry.kc_pages)
            if entry.vc_pages:
                self.chai_pool.free(entry.vc_pages)
        else:
            dense, chai = 2, 0
            self.dense_pool.free([entry.kg_page])
            self.dense_pool.free([entry.vg_page])
        if self.tiers is not None:
            self.tiers.record("hot", "gone", "dense", dense)
            self.tiers.record("hot", "gone", "chai", chai)

    def _droppable(self, entry) -> bool:
        """True when a structural drop of ``entry`` (for a node: its
        whole subtree) would not strand a lock — the TierManager's
        pressure-drop guard (``droppable_hook``)."""
        if isinstance(entry, ChaiSnapshot):
            return not entry.locks
        stack = [entry]
        while stack:
            node = stack.pop()
            if node.locks:
                return False
            stack.extend(node.children.values())
        return True

    def drop_demoted(self, entry):
        """Structurally drop an entry regardless of tier or locks — the
        tier ladder's terminal rung ("gone") and the corruption-recovery
        path (a failed promotion drops the entry; the request re-plans
        cold). A radix node takes its whole subtree (children would be
        unreachable). Locked droppees are tolerated: the lock holder is
        the very plan dropping them, and the ``evicted`` guard keeps its
        ``unlock`` from re-filing a ghost."""
        if isinstance(entry, ChaiSnapshot):
            if self._snapshots.get(entry.prompt) is not entry:
                return
            self._lru_drop(entry)
            del self._snapshots[entry.prompt]
            entry.evicted = True
            self._release_entry_pages(entry)
            self.stats["evicted_snapshots"] += 1
            return
        if entry.evicted:
            return
        parent = entry.parent
        parent.children.pop(entry.key, None)
        stack = [entry]
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            node.children = {}
            node.evicted = True
            node.resident = None
            self._lru_drop(node)
            if self.tiers is not None:
                self.tiers.unfile(node)
            self._release_entry_pages(node)
            self.stats["evicted_blocks"] += 1
        if (parent is not self.root and parent.hot_leaf
                and parent.tier == "hot"):
            self._lru_file(parent)

    def _evict_one(self, want_dense=True, want_chai=True) -> bool:
        """Drop the least-recently-used evictable entry holding
        references in a wanted pool: scan ``_lru`` from the front and pop
        the first match. Skipped non-matching entries stay filed, so the
        per-victim cost is the length of the un-wanted-kind prefix at the
        front (e.g. share_values snapshots under dense pressure) — far
        below the old unconditional full radix walk + snapshot scan, but
        not O(1) when one kind piles up at the LRU end. Returns False if
        pinned solid / nothing matches.

        Pool targeting matters: under share_values, snapshots hold no
        dense pages — evicting them for dense pressure would wipe the
        zero-prefill fast path without freeing a single wanted page.

        A node whose last sibling is evicted re-files its parent at the
        MRU end (an OrderedDict cannot insert mid-order); the parent was
        recently on every matched path anyway, so the approximation only
        delays its eviction."""
        victim = None
        for entry in self._lru.values():
            if isinstance(entry, BlockNode):
                holds = want_dense          # nodes hold dense pages only
            else:
                holds = ((want_dense and bool(entry.vg_pages))
                         or (want_chai and bool(entry.kc_pages
                                                or entry.vc_pages)))
            if holds:
                victim = entry
                break
        if victim is None:
            return False
        self._lru_drop(victim)
        # Host offload on: demote instead of dropping — the entry keeps
        # its index position (radix slot / snapshot key) but its payload
        # moves to the host pool. The engine hook returns False when the
        # tier ladder cannot take it; fall through to a plain drop.
        if (self.demote_hook is not None and victim.tier == "hot"
                and self.demote_hook(victim)):
            if isinstance(victim, ChaiSnapshot):
                self.stats["demoted_snapshots"] += 1
            else:
                self.stats["demoted_blocks"] += 1
                parent = victim.parent
                if parent is not self.root and parent.tier == "hot":
                    self._lru_file(parent)  # no hot children: evictable
            return True
        if isinstance(victim, ChaiSnapshot):
            del self._snapshots[victim.prompt]
            victim.evicted = True
            self._release_entry_pages(victim)
            self.stats["evicted_snapshots"] += 1
        else:
            # The subtree drop also releases any demoted descendants'
            # tier pages and re-files the newly-eligible parent.
            self.drop_demoted(victim)
        return True

    def evict_until(self, dense_free: int = 0, chai_free: int = 0) -> bool:
        """Evict LRU entries until the pools have the requested free
        pages; returns False if eviction ran dry first. Only entries
        holding references in a still-short pool are dropped. (Dropping
        a reference frees a page only when no active slot still shares
        it — freed-at-zero.)"""
        def shortfall():
            dense = self.dense_pool.free_pages < dense_free
            chai = (chai_free and self.chai_pool is not None
                    and self.chai_pool.free_pages < chai_free)
            return dense, chai

        dense_short, chai_short = shortfall()
        while dense_short or chai_short:
            if not self._evict_one(want_dense=dense_short,
                                   want_chai=chai_short):
                return False
            dense_short, chai_short = shortfall()
        return True

    def clear(self):
        """Drop every cache reference (leaks nothing: pages shared with
        active slots survive until those slots retire)."""
        while self._evict_one():
            pass
