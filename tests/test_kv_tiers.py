"""Hierarchical KV tiering: host-offload pool, async prefetch, int4 tier.

Contract under test: under device page pressure unlocked prefix-cache
entries DEMOTE to host pages instead of dropping (hot -> host ->
compressed int4 -> gone); a hit on a demoted entry PROMOTES it back into
fresh device pages and the request's greedy tokens are bitwise identical
to an all-HBM run; preemption swap-out routes its payload through the
same host pool; and every path is leak-free across device AND host pools
(the autouse conftest gate audits both).
"""
import numpy as np
import pytest

import jax

from repro.configs.base import get_config, reduced
from repro.core.cache import (dequant_rows_int4, pack_int4, quant_rows_int4,
                              unpack_int4)
from repro.models import transformer as tfm
from repro.serving import invariants
from repro.serving import kv_tiers
from repro.serving.engine import EngineConfig, EngineCore
from repro.serving.sampling import SamplingParams

ARCH = "chai-llama-7b"          # MHA+CHAI: snapshots + kc/vc pages
GREEDY = SamplingParams(max_new_tokens=8)

_params_cache = {}


def _model():
    if ARCH not in _params_cache:
        cfg = reduced(get_config(ARCH), n_layers=2, d_model=32, d_ff=64,
                      vocab=64).replace(dtype="float32")
        cfg = cfg.with_chai(enabled=True, warmup_tokens=3)
        _params_cache[ARCH] = (cfg,
                               tfm.init_params(cfg, jax.random.PRNGKey(0)))
    return _params_cache[ARCH]


def _ecfg(**kw):
    kw.setdefault("batch_slots", 2)
    kw.setdefault("max_seq", 64)
    kw.setdefault("page_size", 8)
    kw.setdefault("audit_level", "deep")
    return EngineConfig(**kw)


def _drain(core, max_steps=600):
    outs = []
    for _ in range(max_steps):
        if not core.has_work():
            return outs
        outs.extend(core.step())
    raise AssertionError(f"engine did not drain in {max_steps} steps")


def _family_prompts(n, *, prefix_blocks=2, ps=8, seed=0, vocab=64):
    """Prompts sharing a whole-block prefix (radix reuse) with distinct
    suffixes — the tier workload: families overflow the device pool."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(1, vocab, size=prefix_blocks * ps).tolist()
    return [prefix + rng.integers(1, vocab, size=ps).tolist()
            for _ in range(n)]


# ---------------------------------------------------------------------------
# int4 pack/quant units (core/cache.py)
# ---------------------------------------------------------------------------
def test_int4_pack_unpack_roundtrip():
    rng = np.random.default_rng(0)
    for n in (1, 7, 8, 33):                     # odd lengths pad a nibble
        codes = rng.integers(-7, 8, size=(3, n)).astype(np.int8)
        packed = pack_int4(codes)
        assert packed.dtype == np.uint8
        assert packed.shape[-1] == (n + 1) // 2
        out = unpack_int4(packed, n)
        np.testing.assert_array_equal(out, codes)


def test_int4_quant_error_bounded_per_row():
    """Symmetric per-row int4: |x - dq(q(x))| <= scale/2 = amax/14."""
    rng = np.random.default_rng(1)
    x = rng.standard_normal((4, 6, 32)).astype(np.float32) * 3.0
    q, scale = quant_rows_int4(x)
    assert q.dtype == np.int8 and np.abs(q).max() <= 7
    back = dequant_rows_int4(q, scale)
    err = np.abs(back - x)
    bound = np.abs(x).max(axis=-1, keepdims=True) / 14.0 + 1e-6
    assert (err <= bound + 1e-5).all()


def test_compress_payload_roundtrip_shapes_and_dtype():
    rng = np.random.default_rng(2)
    for dt in (np.float32, np.int8):
        data = (rng.standard_normal((2, 4, 8, 16)) * 5).astype(dt)
        payload = {"data": data, "scale": rng.standard_normal(
            (2, 4, 8, 1)).astype(np.float32)}
        cp = kv_tiers.compress_payload(payload)
        assert cp["packed"].nbytes < data.nbytes or dt == np.int8
        out = kv_tiers.decompress_payload(cp)
        assert out["data"].shape == data.shape
        assert out["data"].dtype == data.dtype
        np.testing.assert_array_equal(out["scale"], payload["scale"])
        # int4 resolution: error bounded by half a quantization step
        err = np.abs(out["data"].astype(np.float64)
                     - data.astype(np.float64))
        bound = np.abs(data).max(axis=-1, keepdims=True) / 14.0 + 1.0
        assert (err <= bound).all()


# ---------------------------------------------------------------------------
# HostPagePool / TierManager units
# ---------------------------------------------------------------------------
def test_host_page_pool_semantics():
    pool = kv_tiers.HostPagePool(5, 8)          # 4 usable pages
    payloads = [{"data": np.full((2, 2), i, np.float32)} for i in range(3)]
    pages = [pool.store(p) for p in payloads]
    assert pool.pages_in_use == 3 and pool.bytes_stored() == 3 * 16
    for pg, p in zip(pages, payloads):
        assert pool.fetch(pg) is p
    # aliasing: freed-at-zero keeps the payload until the last ref dies
    pool.incref([pages[0]])
    pool.free([pages[0]])
    assert pool.fetch(pages[0]) is payloads[0]
    pool.free([pages[0]])
    assert pages[0] not in pool._data
    out = []
    invariants._audit_pool("host", pool, out)
    assert out == []
    with pytest.raises(MemoryError):
        pool.alloc(4)


def _entry(tier="hot", compressible=True):
    from repro.serving.prefix_cache import BlockNode
    e = BlockNode(key=(1,), kg_page=1, vg_page=2, parent=None)
    e.tier = tier
    e.compressible = compressible
    return e


def _payloads(rng, n=1):
    return {"kg": [{"data": rng.standard_normal(
                        (2, 3, 8, 4)).astype(np.float32)}
                   for _ in range(n)],
            "vg": [{"data": rng.standard_normal(
                        (2, 3, 8, 4)).astype(np.float32)}
                   for _ in range(n)]}


def test_tier_manager_store_verify_fetch_release():
    rng = np.random.default_rng(3)
    tm = kv_tiers.TierManager(8, host_pages={"dense": 8, "chai": 0},
                              comp_pages={"dense": 8, "chai": 0})
    e = _entry()
    pl = _payloads(rng)
    tm.store_entry(e, pl)
    assert e.tier == kv_tiers.TIER_HOST and e.tier_crc != 0
    assert tm.verify_entry(e)
    got = tm.fetch_entry(e)
    np.testing.assert_array_equal(got["kg"][0]["data"],
                                  pl["kg"][0]["data"])
    # corruption is caught by the CRC
    stored = tm.host["dense"].fetch(e.tier_pages["kg"][0])
    stored["data"] = stored["data"] + 1.0
    assert not tm.verify_entry(e)
    tm.release_entry(e)
    assert tm.host["dense"].pages_in_use == 0
    assert e.tier_pages == {}


def test_tier_manager_ladder_compress_then_drop():
    """make_room walks host->compressed->gone: a compressible victim is
    re-coded to int4, an uncompressible one is structurally dropped."""
    rng = np.random.default_rng(4)
    dropped = []
    tm = kv_tiers.TierManager(8, host_pages={"dense": 2, "chai": 0},
                              comp_pages={"dense": 2, "chai": 0})
    tm.drop_hook = lambda e: (dropped.append(e), tm.discard_entry(e))
    tm.droppable_hook = lambda e: True
    comp = _entry(compressible=True)
    tm.store_entry(comp, _payloads(rng))        # host full (2 pages)
    assert tm.make_room({"dense": 2})           # compresses `comp`
    assert comp.tier == kv_tiers.TIER_COMP
    assert tm.verify_entry(comp)                # restamped over int4
    assert tm.host["dense"].pages_in_use == 0
    assert tm.comp["dense"].pages_in_use == 2
    assert tm.transitions[("host", "compressed", "dense")] == 2
    # an uncompressible entry under the same pressure is dropped
    snap_like = _entry(compressible=False)
    tm.store_entry(snap_like, _payloads(rng))
    assert tm.make_room({"dense": 2})
    assert dropped == [snap_like]
    assert snap_like.tier_pages == {}
    # and a compressed-tier resident sheds when ITS pool overflows
    tm.droppable_hook = lambda e: True
    another = _entry(compressible=True)
    tm.store_entry(another, _payloads(rng))
    assert tm.make_room({"dense": 2})           # comp pool full: drops LRU
    assert comp in dropped
    # impossible requests fail fast
    assert not tm.make_room({"dense": 99})


# ---------------------------------------------------------------------------
# engine integration: demote -> promote, bitwise parity
# ---------------------------------------------------------------------------
def _run_family(ecfg_kw, prompts, max_new=8):
    cfg, params = _model()
    core = EngineCore(cfg, params, _ecfg(**ecfg_kw))
    tokens = {}
    for p in prompts:
        r = core.add_request(list(p), SamplingParams(max_new_tokens=max_new))
        _drain(core)                  # serialize: maximal reuse per prompt
        tokens[r.uid] = list(r.generated)
        assert r.finish_reason == "length"
    return core, tokens


def test_demoted_radix_blocks_promote_bitwise():
    """Prefix-family workload past device capacity: evictions demote to
    host, later family members hit the demoted blocks, promotion yields
    tokens bitwise identical to an unpressured all-HBM run."""
    rng = np.random.default_rng(55)
    base = _family_prompts(4, seed=5)
    # Extending a base prompt by one fresh block routes the match
    # THROUGH its (by then demoted) suffix leaf — snapshots only serve
    # exact-prompt repeats, so this is the block-promotion path.
    extended = [p + rng.integers(1, 64, size=8).tolist() for p in base[:2]]
    workload = base + extended
    # 9 usable dense pages: one 24-token request needs 8 pages of
    # headroom, so cached family suffixes demote between requests.
    tight = dict(batch_slots=1, prefix_cache=True, kv_offload=True,
                 num_pages=12, host_pages=64, tier_prefetch=False)
    core, toks = _run_family(tight, workload)
    st = core.prefix_stats()
    assert st["demoted_blocks"] > 0, "workload never demoted — resize"
    assert st["promoted_blocks"] > 0, "no demoted entry was ever hit"
    ts = core.tier_stats()
    assert ts["transitions"].get("hot->host/dense", 0) > 0
    assert ts["transitions"].get("host->hot/dense", 0) > 0
    # all-HBM reference: same workload, no pressure, no offload
    _, ref = _run_family(dict(batch_slots=1, prefix_cache=True), workload)
    assert toks == ref


def test_demoted_snapshot_promotes_bitwise():
    """A CHAI snapshot demoted under pressure is promoted on the next
    full-prompt hit; the resumed decode matches the unpressured run."""
    cfg, params = _model()
    rng = np.random.default_rng(6)
    prompt = rng.integers(1, 64, size=16).tolist()
    filler = _family_prompts(3, prefix_blocks=2, seed=7)

    def run(**kw):
        core = EngineCore(cfg, params, _ecfg(batch_slots=1,
                                             prefix_cache=True, **kw))
        first = core.add_request(list(prompt),
                                 SamplingParams(max_new_tokens=10))
        _drain(core)
        assert core.prefix_stats()["snapshots"] == 1
        for f in filler:              # pressure: evict/demote the snapshot
            core.add_request(list(f), SamplingParams(max_new_tokens=10))
            _drain(core)
        dup = core.add_request(list(prompt),
                               SamplingParams(max_new_tokens=10))
        _drain(core)
        assert dup.finish_reason == "length"
        return core, list(first.generated), list(dup.generated)

    core, first, dup = run(kv_offload=True, num_pages=12,
                           num_chai_pages=12, tier_prefetch=False)
    st = core.prefix_stats()
    assert st["demoted_snapshots"] > 0, "snapshot never demoted — resize"
    assert st["promoted_snapshots"] > 0
    _, first_ref, dup_ref = run()
    assert first == first_ref and dup == dup_ref


def test_compressed_hit_replans_cold_with_parity():
    """Default (lossy_promote=False): a hit on an int4-compressed block
    drops it and re-plans cold — tokens still match the clean run."""
    prompts = _family_prompts(6, seed=8)
    tight = dict(batch_slots=1, prefix_cache=True, kv_offload=True,
                 num_pages=10, host_pages=2, compressed_pages=16,
                 tier_prefetch=False)
    core, toks = _run_family(tight, prompts)
    ts = core.tier_stats()
    assert ts["transitions"].get("host->compressed/dense", 0) > 0, \
        "host pool never spilled to int4 — resize"
    _, ref = _run_family(dict(batch_slots=1, prefix_cache=True), prompts)
    assert toks == ref


def test_prefetch_promotes_ahead_of_admission():
    """add_request queues demoted-entry promotion; step() drains it so
    the planner finds the entry hot (prefetch_hits counts the save)."""
    rng = np.random.default_rng(9)
    base = _family_prompts(4, seed=9)
    cfg, params = _model()
    core = EngineCore(cfg, params, _ecfg(
        batch_slots=1, prefix_cache=True, kv_offload=True, num_pages=12,
        host_pages=64, telemetry="basic"))
    for p in base:
        core.add_request(list(p), GREEDY)
        _drain(core)
    assert core.prefix_stats()["demoted_blocks"] > 0
    # extending the first prompt routes through its demoted suffix leaf
    core.add_request(base[0] + rng.integers(1, 64, size=8).tolist(),
                     GREEDY)
    _drain(core)
    ts = core.tier_stats()
    assert ts["prefetch_hits"] + ts["prefetch_misses"] > 0
    snap = core.metrics()
    assert "tier_transitions_total" in snap["counters"]
    assert "kv_tier_pages" in snap["gauges"]


def test_preemption_swaps_through_host_pool():
    """The preemption resume payload lives in host-tier pages (no
    bespoke host dict), is freed at swap-in, and the victim resumes."""
    cfg, params = _model()
    rng = np.random.default_rng(10)
    core = EngineCore(cfg, params, _ecfg(batch_slots=1, prefix_cache=True))
    victim = core.add_request(rng.integers(1, 64, size=12).tolist(),
                              SamplingParams(max_new_tokens=12))
    for _ in range(4):
        core.step()
    preemptor = core.add_request(rng.integers(1, 64, size=6).tolist(),
                                 SamplingParams(max_new_tokens=4),
                                 priority=1)
    assert core.step() is not None
    rs = victim.resume_state
    assert rs is not None and rs["tier_pages"], "victim not swapped out"
    assert "pools" not in rs            # the bespoke host dict is gone
    held = sum(p.pages_in_use for p in core.tiers.host.values()
               if p is not None)
    assert held == sum(len(v) for v in rs["tier_pages"].values()) > 0
    assert invariants.audit(core) == []     # cross-tier refs balance
    _drain(core)
    assert preemptor.finish_reason == "length"
    assert victim.finish_reason == "length"
    assert len(victim.generated) == 12
    assert core.preemptions == 1
    ts = core.tier_stats()
    assert ts["transitions"].get("host->hot/chai",
                                 ts["transitions"].get("host->hot/dense",
                                                       0)) > 0
    assert all(p.pages_in_use == 0 for pools in
               (core.tiers.host, core.tiers.comp)
               for p in pools.values() if p is not None)


def test_over_capacity_workload_is_leak_free():
    """A prefix-family workload several times the device pool completes;
    the autouse conftest gate + this explicit audit check device AND
    host pools conserve and hold zero orphans afterwards."""
    cfg, params = _model()
    core = EngineCore(cfg, params, _ecfg(
        batch_slots=2, prefix_cache=True, kv_offload=True, num_pages=14))
    for i in range(3):
        for p in _family_prompts(4, seed=20 + i):
            core.add_request(list(p), GREEDY)
        _drain(core)
    assert core.tier_stats()["transitions"]    # the ladder actually ran
    assert invariants.audit_leaks(core) == []


@pytest.mark.no_leak_gate
def test_orphaned_host_page_fails_the_audit():
    """A host page with no owning entry (simulated leak) is flagged by
    the cross-tier reference audit."""
    cfg, params = _model()
    core = EngineCore(cfg, params, _ecfg(prefix_cache=True,
                                         kv_offload=True))
    core.add_request(_family_prompts(1, seed=30)[0], GREEDY)
    _drain(core)
    core.tiers.store_pages(
        "dense", [{"data": np.zeros((2, 3, 8, 4), np.float32)}])
    problems = invariants.audit_leaks(core)
    assert any("host_pool[dense]" in v for v in problems)


def test_kv_offload_requires_paged_layout():
    cfg, params = _model()
    with pytest.raises(ValueError, match="kv_offload"):
        EngineCore(cfg, params, _ecfg(kv_layout="dense", kv_offload=True))
