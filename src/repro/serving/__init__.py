from repro.serving.engine import EngineConfig, Request, ServingEngine  # noqa: F401
from repro.serving.prefix_cache import ChaiSnapshot, PrefixCache  # noqa: F401
