"""Paper Fig 11: K,V-cache memory, MHA vs CHAI, across sequence lengths.

Two lanes:
  1. **Analytic** — exact steady-state bytes for the full LLaMA-7B config
     (the paper's model) and every assigned MHA-regime arch. The paper's
     21.4% saving comes from dropping non-representative K rows; V is
     kept (Table 4).
  2. **Paged allocator** — the continuous-batching engine with
     ``kv_layout="paged"`` on a tiny MHA model: resident (allocated-page)
     bytes sampled across PREFILL -> WARMUP -> CLUSTER -> STEADY. The
     claim check asserts the saving is *realized by the allocator*:
     steady-state paged-CHAI bytes fall below the dense-MHA rectangle
     the dense layouts keep resident (the unified layout exceeds it)."""
from __future__ import annotations

import numpy as np

import jax

from benchmarks.common import save_result
from repro.configs.base import get_config, list_configs, reduced
from repro.core.cache import kv_cache_bytes, unified_kv_bytes
from repro.models import transformer as tfm
from repro.serving.engine import EngineConfig, ServingEngine


def _paged_allocator_lane(slots=2, max_seq=64, page_size=16, n_req=4):
    """PREFILL->STEADY allocated-bytes trajectory of the paged engine."""
    cfg = reduced(get_config("chai-llama-7b"), n_layers=2, d_model=32,
                  d_ff=64, vocab=64).replace(dtype="float32")
    cfg = cfg.with_chai(enabled=True, warmup_tokens=3)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params,
                        EngineConfig(batch_slots=slots, max_seq=max_seq,
                                     kv_layout="paged",
                                     page_size=page_size))
    rng = np.random.default_rng(0)
    for i in range(n_req):
        eng.submit(rng.integers(0, cfg.vocab_size, size=8),
                   max_new_tokens=24, uid=i)
    eng.run()
    hist = eng.kv_bytes_history
    dense_mha = unified_kv_bytes(cfg, slots, max_seq, chai=False)
    dense_unified = unified_kv_bytes(cfg, slots, max_seq, chai=True)
    # steady state = every occupied slot past CLUSTER (no warmup slot is
    # holding dense K pages); churn steps with a fresh WARMUP admission
    # are transient and excluded. No steady sample means the workload
    # never exercised the saving — fail loudly rather than report a
    # vacuous (drained-engine) number.
    steady = [h for h in hist
              if h.get("n_warmup") == 0 and h.get("n_steady", 0) > 0]
    if not steady:
        raise RuntimeError(
            "paged allocator lane produced no steady-state sample "
            f"(warmup_tokens={cfg.chai.warmup_tokens}, history={hist}); "
            "the claim check would be vacuous")
    steady_bytes = max(h["kv_bytes"] for h in steady)
    return {
        "note": "allocated-page bytes from the serving engine's PagePool "
                "accounting (tiny model; layout-level numbers, not "
                "hardware-level)",
        "workload": {"slots": slots, "max_seq": max_seq,
                     "page_size": page_size, "n_req": n_req,
                     "prompt_len": 8, "max_new": 24},
        "timeline": hist,
        "peak_bytes": eng.kv_bytes_peak(),
        "steady_chai_bytes": steady_bytes,
        "dense_mha_bytes": dense_mha,
        "dense_unified_bytes": dense_unified,
        "paged_steady_saving_vs_dense_mha":
            1 - steady_bytes / dense_mha,
    }


def run():
    seqs = [256, 512, 1024, 2048, 4096]
    per_arch = {}
    for arch in list_configs():
        cfg = get_config(arch)
        if cfg.n_attn_layers == 0 or not cfg.is_mha:
            continue                      # GQA/SSM: no K-cache saving
        rows = {}
        for s in seqs:
            full = kv_cache_bytes(cfg, 1, s, chai=False)
            ch = kv_cache_bytes(cfg, 1, s, chai=True)
            rows[str(s)] = {"mha_bytes": full, "chai_bytes": ch,
                            "saving_frac": 1 - ch / full}
        per_arch[arch] = rows

    paged = _paged_allocator_lane()
    llama = per_arch["chai-llama-7b"]["2048"]
    result = {
        "note": "exact analytic bytes; MHA-regime archs only (GQA archs "
                "get compute-only wins, DESIGN.md §4)",
        "per_arch": per_arch,
        "paged_allocator": paged,
        "paper_claim": "LLaMA-7B seq 2048: ~1.2 GB KV cache, up to 21.4% "
                       "saving",
        "claim_check": {
            "llama_kv_GB_at_2048": llama["mha_bytes"] / 2**30,
            "llama_saving_frac": llama["saving_frac"],
            "saving_in_paper_range": 0.10 <= llama["saving_frac"] <= 0.30,
            "kv_close_to_1.2GB": 0.8 <= llama["mha_bytes"] / 2**30 <= 1.6,
            # the tentpole: the allocator (not just the formula) realizes
            # the saving — steady paged-CHAI below the dense-MHA
            # rectangle, which the unified layout exceeds
            "paged_steady_below_dense_mha":
                paged["steady_chai_bytes"] < paged["dense_mha_bytes"],
            "unified_layout_exceeds_dense_mha":
                paged["dense_unified_bytes"] > paged["dense_mha_bytes"],
            "compaction_frees_pages":
                paged["steady_chai_bytes"] < paged["peak_bytes"],
        },
    }
    save_result("bench_kv_memory", result)
    return result


if __name__ == "__main__":
    print(run())
