"""Legacy lockstep cohort scheduler (``EngineConfig.scheduler="cohort"``).

Requests admitted together move through the CHAI phase machine together
(one prefill, lockstep WARMUP -> CLUSTER -> STEADY decode), with the
cohort-deadline straggler re-dispatch mitigation. Kept for A/B parity
testing against the step-driven continuous core: token-for-token
equality under greedy decode AND under seeded sampling — the batched
sampler keys every draw by ``(request seed, tokens sampled so far)``, so
the same request produces the same tokens whichever scheduler ran it.

Split out of ``serving/engine.py`` when the engine became the
step-driven ``EngineCore``; this mixin only touches the core's public
surface (jits, sampler, queue/done bookkeeping).
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import cache as chai_cache
from repro.serving import sampling as sampling_mod


class CohortSchedulerMixin:
    """Cohort scheduling methods mixed into ``EngineCore``."""

    def _run_cohort_loop(self):
        while self.queue:
            if self.queue[0].t_arrival > time.time():
                time.sleep(max(1e-4,
                               self.queue[0].t_arrival - time.time()))
                continue
            # Priority classes pick cohort membership (lockstep cohorts
            # cannot preempt mid-flight like the continuous core, so the
            # class is honored at formation): over the whole arrived run,
            # highest ``priority`` first, FIFO within a class (stable
            # sort); the overflow goes back to the queue in that order.
            arrived = []
            while self.queue and self.queue[0].t_arrival <= time.time():
                arrived.append(self.queue.popleft())
            arrived.sort(key=lambda r: -r.priority)
            cohort = arrived[:self.ecfg.batch_slots]
            for r in reversed(arrived[self.ecfg.batch_slots:]):
                self.queue.appendleft(r)
            try:
                self._run_cohort(cohort)
            except TimeoutError:
                # cohort exceeded its deadline: finalize what finished,
                # re-dispatch the rest
                self.redispatched += len(cohort)
                for r in cohort:
                    trunc, reason = sampling_mod.scan_finish(
                        r.generated, r.sampling, r.max_new_tokens,
                        self.detokenizer)
                    if reason:
                        r.generated, r.finish_reason = trunc, reason
                        r.t_done = time.time()
                        self._done(r)
                    else:
                        self.queue.append(r)
        return self.done

    def _pad_prompts(self, cohort):
        """Right-pad a (possibly ragged) cohort to ONE power-of-two
        prompt-length bucket (reusing the continuous scheduler's
        bucketing) with per-example ``true_lens`` masking, so the single
        cohort-prefill jit compiles once per BUCKET shape — O(log
        max_seq) — instead of once per padded cohort length."""
        b = self.ecfg.batch_slots
        t = max(len(r.prompt) for r in cohort)
        bucket = self._prompt_bucket(t, self.ecfg.max_seq)
        self._cohort_buckets.add(bucket)
        toks = np.zeros((b, bucket), np.int32)
        lens = np.full((b,), bucket, np.int32)   # idle rows: whole bucket
        for i, r in enumerate(cohort):
            toks[i, :len(r.prompt)] = r.prompt   # right-pad to the bucket
            lens[i] = len(r.prompt)
        return jnp.asarray(toks), jnp.asarray(lens)

    def _cohort_vectors(self, cohort):
        """Per-row SamplingParams device vectors for one cohort (idle
        rows sample greedily — their tokens are never recorded)."""
        b = self.ecfg.batch_slots
        temps = np.zeros((b,), np.float32)
        ks = np.zeros((b,), np.int32)
        ps = np.ones((b,), np.float32)
        seeds = np.zeros((b,), np.uint32)
        for i, r in enumerate(cohort):
            sp = r.sampling
            temps[i], ks[i], ps[i] = sp.temperature, sp.top_k, sp.top_p
            seeds[i] = np.uint32(sp.seed)
        return {"temperature": jnp.asarray(temps), "top_k": jnp.asarray(ks),
                "top_p": jnp.asarray(ps), "seed": jnp.asarray(seeds)}

    def _run_cohort(self, cohort):
        cfg, ecfg = self.cfg, self.ecfg
        deadline = time.time() + ecfg.cohort_deadline_s
        b = ecfg.batch_slots
        all_greedy = all(r.sampling.greedy for r in cohort)
        vecs = None if all_greedy else self._cohort_vectors(cohort)

        def sample(logits, n):
            # n == tokens each live request has sampled so far (lockstep:
            # identical across rows), so draws match the continuous
            # scheduler's per-request counts token for token. All-greedy
            # cohorts take the bare-argmax fast path (bitwise-identical
            # to the sampler's greedy lane).
            if all_greedy:
                return self._argmax(logits)
            return self._sampler(logits, vecs["temperature"],
                                 vecs["top_k"], vecs["top_p"],
                                 vecs["seed"],
                                 jnp.full((b,), n, jnp.int32))

        # A cohort run starts from the prompt: requests re-dispatched
        # after a blown deadline drop their partial tokens and decode
        # afresh (appending onto the stale prefix would corrupt the
        # output — and restarting also restarts the sampler counts, so a
        # re-dispatched seeded request reproduces its uninterrupted run).
        for r in cohort:
            r.generated = []
        tokens, lens = self._pad_prompts(cohort)
        logits, state = self._prefill(
            self.params, {"tokens": tokens, "true_lens": lens})
        t_first = time.time()
        for r in cohort:
            r.t_first_token = t_first
        next_tok = sample(logits, 0)
        self._record(cohort, next_tok)

        warm = cfg.chai.warmup_tokens if self.chai_on else 0
        max_new = max(r.max_new_tokens for r in cohort)

        # ---- WARMUP: MHA decode, accumulating clustering features ----
        if self.chai_on:
            state = chai_cache.add_score_buffer(state, cfg,
                                                ecfg.batch_slots)
        step = 1
        while step < max_new and step <= warm:
            if time.time() > deadline:
                raise TimeoutError
            logits, state = self._mha_step(
                self.params, {"tokens": next_tok}, state)
            next_tok = sample(logits, step)
            self._record(cohort, next_tok)
            self.steps_executed += 1
            step += 1

        # ---- CLUSTER + COMPACT: membership ID, K-cache gather ----
        ctx = None
        if self.chai_on and step <= max_new:
            state, scores = chai_cache.pop_score_buffer(state)
            ctx = self._identify(scores)
            state = self._compact(state, ctx)

        # ---- STEADY: Clustered Head Attention decode ----
        while step < max_new:
            if time.time() > deadline:
                raise TimeoutError
            if ctx is not None:
                logits, state = self._chai_step(
                    self.params, {"tokens": next_tok}, state, ctx)
            else:
                logits, state = self._mha_step(
                    self.params, {"tokens": next_tok}, state)
            next_tok = sample(logits, step)
            self._record(cohort, next_tok)
            self.steps_executed += 1
            step += 1

        t_done = time.time()
        for r in cohort:
            # lockstep rows decode to the cohort's max; stops/budgets are
            # applied by the same front-scan the continuous core uses,
            # so both schedulers finalize identical token lists.
            trunc, reason = sampling_mod.scan_finish(
                r.generated, r.sampling, r.max_new_tokens,
                self.detokenizer)
            r.generated = trunc
            r.finish_reason = reason or sampling_mod.FINISH_LENGTH
            r.t_done = t_done
            self._done(r)

    @staticmethod
    def _record(cohort, next_tok):
        toks = np.asarray(next_tok)
        for i, r in enumerate(cohort):
            r.generated.append(int(toks[i]))
