"""RecurrentGemma 9B [arXiv:2402.19427]: RG-LRU + local attention, 1:2.

Pattern: (recurrent, recurrent, local-attention) repeated; 38 layers =
12 full patterns + 2 recurrent. MQA (1 KV head).
"""
from repro.configs.base import (ModelConfig, CHAIConfig, register,
                                RGLRU, ATTN_LOCAL)

_LAYERS = tuple(ATTN_LOCAL if (i % 3) == 2 else RGLRU for i in range(38))

CONFIG = register(ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    layer_types=_LAYERS,
    window_size=2048,
    rnn_width=4096,
    conv_width=4,
    activation="gelu",
    rope_theta=10000.0,
    chai=CHAIConfig(enabled=True),
))
