"""Open-loop serving workload generators (shared by examples + benches).

One definition of the "mixed-length Poisson" workload so the latency
benchmark and the serving example measure the same distribution:
exponential inter-arrival gaps and a long-tailed output-length mix —
most requests short, a minority near the cap, the regime where cohort
scheduling head-of-line blocks short requests behind long ones.
"""
from __future__ import annotations

import numpy as np


def poisson_workload(rng, n_req, *, mean_gap_s=0.02, new_tokens=(8, 128),
                     tail_frac=0.3):
    """Returns (arrival_delays (n,), max_new_tokens (n,)) numpy arrays.

    ``new_tokens = (lo, hi)``: short requests draw from [lo, lo+8],
    long ones (fraction ``tail_frac``) from [hi-28, hi].
    """
    arrivals = np.cumsum(rng.exponential(mean_gap_s, size=n_req))
    lo, hi = new_tokens
    lens = np.where(rng.random(n_req) >= tail_frac,
                    rng.integers(lo, lo + 9, size=n_req),
                    rng.integers(hi - 28, hi + 1, size=n_req))
    return arrivals, lens
