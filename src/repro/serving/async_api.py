"""Asyncio front door over the step-driven ``EngineCore``.

``AsyncLLM`` owns one core and ONE background driver task that loops
``EngineCore.step()``; each step's ``StepOutput``s fan out to per-request
``asyncio.Queue``s, so any number of concurrent ``generate()`` /
``stream()`` coroutines share the same continuous batch. The engine is
not thread-safe and jit dispatch blocks, so every core call — submit,
step, abort, reap — runs on a single-worker executor thread: engine
access is serialized exactly as in the synchronous frontend, while the
event loop stays responsive between steps (an HTTP server keeps
accepting connections during a long prefill).

Lifecycle of a request:

* ``stream()``/``generate()`` pick a uid and register the fan-out queue
  BEFORE the request reaches the engine, so the admission chunk (which
  carries the first token) can never be dropped.
* The driver pushes every ``StepOutput`` for that uid; the terminal
  chunk has ``finished=True``.
* ``abort(uid)`` cancels the request on the engine (pages return
  refcount-exactly) and pushes the empty terminal chunk itself — the
  engine's abort emits no StepOutput of its own.

The driver is SUPERVISED (failure taxonomy in ``repro.serving.faults``):

* A ``RequestError`` from ``step()`` is routed to the named request's
  queue (or the queue head's, for a legacy bare ``MemoryError``) and
  re-raised from that coroutine; the driver and every other request
  keep running. Engine-side quarantines never even raise — they arrive
  as ordinary terminal chunks with ``finish_reason="error"``.
* Any other ``Exception`` from ``step()`` is retried with bounded
  exponential backoff (``max_restarts``); only when retries run out is
  it escalated to an ``EngineFault``.
* An ``EngineFault`` (invariant breach, exhausted retries, a
  ``MemoryError`` with no queue head to blame) is broadcast to ALL open
  queues and kills the driver — the engine state itself is suspect.

``AsyncLLM`` assumes it is the only frontend driving its core (uids are
chosen by the AsyncLLM side; mixing with direct ``core.add_request``
calls may collide).
"""
from __future__ import annotations

import asyncio
import concurrent.futures
import time
from typing import AsyncIterator, Callable, Optional

from repro.serving.api import RequestOutput
from repro.serving.engine import (EngineConfig, EngineCore, Request,
                                  StepOutput)
from repro.serving.faults import EngineFault, FaultInjector, RequestError
from repro.serving.sampling import FINISH_ABORT, SamplingParams


class AsyncLLM:
    """Asyncio frontend owning one ``EngineCore`` (mirrors ``LLM``).

    Use as an async context manager, or call ``close()`` when done::

        async with AsyncLLM(cfg, params, ecfg) as llm:
            out = await llm.generate(prompt, SamplingParams())
            async for chunk in llm.stream(prompt):
                ...
    """

    def __init__(self, cfg, params, ecfg: Optional[EngineConfig] = None, *,
                 detokenizer: Optional[Callable] = None,
                 faults: Optional[FaultInjector] = None,
                 max_restarts: int = 3,
                 restart_backoff: float = 0.05, **ecfg_kw):
        if ecfg is None:
            ecfg = EngineConfig(**ecfg_kw)
        elif ecfg_kw:
            raise ValueError(f"pass ecfg OR EngineConfig kwargs, not both "
                             f"({sorted(ecfg_kw)})")
        if ecfg.scheduler != "continuous":
            raise ValueError("AsyncLLM drives EngineCore.step(): "
                             "continuous scheduler only")
        self.core = EngineCore(cfg, params, ecfg, detokenizer=detokenizer,
                               faults=faults)
        self._max_restarts = int(max_restarts)
        self._restart_backoff = float(restart_backoff)
        self.restarts = 0                 # cumulative supervised retries
        self.detokenizer = detokenizer
        self._exec = concurrent.futures.ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="engine")
        self._queues: dict = {}           # uid -> asyncio.Queue
        self._uid = 0
        self._driver: Optional[asyncio.Task] = None
        self._wake: Optional[asyncio.Event] = None
        self._error: Optional[BaseException] = None
        self._closed = False

    # -- engine access (single-worker executor = serialized) ---------------
    async def _call(self, fn, *args, **kw):
        loop = asyncio.get_running_loop()
        return await loop.run_in_executor(
            self._exec, lambda: fn(*args, **kw))

    # -- driver ------------------------------------------------------------
    def _ensure_driver(self):
        if self._error is not None:
            raise RuntimeError("AsyncLLM driver died") from self._error
        if self._closed:
            raise RuntimeError("AsyncLLM is closed")
        if self._wake is None:
            self._wake = asyncio.Event()
        if self._driver is None or self._driver.done():
            self._driver = asyncio.get_running_loop().create_task(
                self._drive_forever(), name="asyncllm-driver")

    def _step_once(self):
        """Runs on the executor thread: one engine step + the scheduler
        facts the driver needs, read while no other engine call can
        interleave."""
        outs = self.core.step()
        return outs, self.core.has_active, self.core.next_arrival()

    async def _drive_forever(self):
        core = self.core
        retries = 0
        try:
            while True:
                self._wake.clear()
                try:
                    outs, active, arrival = await self._call(
                        self._step_once)
                    retries = 0
                except EngineFault:
                    raise          # engine state suspect: broadcast + die
                except RequestError as err:
                    # Request-isolatable: fail THAT request, keep serving.
                    if err.uid is not None:
                        await self._fail_uid(err.uid, err)
                    elif isinstance(err, MemoryError):
                        await self._fail_head(err)
                    else:
                        raise EngineFault(
                            "request-isolatable failure named no "
                            f"request: {err!r}") from err
                    continue
                except MemoryError as err:    # legacy bare page-budget
                    await self._fail_head(err)
                    continue
                except Exception as err:      # noqa: BLE001 — supervised
                    retries += 1
                    self.restarts += 1
                    if retries > self._max_restarts:
                        raise EngineFault(
                            f"driver exhausted {self._max_restarts} step "
                            f"retries; last failure: {err!r}") from err
                    await asyncio.sleep(
                        self._restart_backoff * (1 << (retries - 1)))
                    continue
                for out in outs:
                    q = self._queues.get(out.uid)
                    if q is None:
                        continue
                    q.put_nowait(out)
                    if out.finished:
                        del self._queues[out.uid]
                if outs or active:
                    await asyncio.sleep(0)      # yield to consumers
                    continue
                # Idle: nothing decoding. Wait for the next open-loop
                # arrival or a new submission, whichever comes first
                # (every submission sets the wake event AFTER its
                # add_request lands, and we cleared it BEFORE stepping,
                # so a submission racing this check still wakes us).
                timeout = (max(1e-4, arrival - time.time())
                           if arrival is not None else None)
                try:
                    await asyncio.wait_for(self._wake.wait(),
                                           timeout=timeout)
                except asyncio.TimeoutError:
                    pass
        except asyncio.CancelledError:
            raise
        except BaseException as err:  # noqa: BLE001 — broadcast, re-raise
            self._error = err
            for uid, q in self._queues.items():
                q.put_nowait(err)
            self._queues.clear()
            raise

    async def _fail_uid(self, uid, err: BaseException):
        """Typed-fail ONE request: abort it on the engine and deliver the
        error to its stream; the driver and every other request keep
        running."""
        def _abort():
            self.core.abort(uid)
            self.core.reap_done()

        await self._call(_abort)
        q = self._queues.pop(uid, None)
        if q is not None:
            q.put_nowait(err)

    async def _fail_head(self, err: MemoryError):
        """step() proved the queue head can never fit: fail THAT request
        and keep serving the rest. A MemoryError with NO queue head
        cannot be pinned on a request — the allocator state itself is
        suspect, so escalate to an EngineFault (broadcast by the
        driver's outer handler) instead of dying opaquely."""
        def _head_uid():
            return self.core.queue[0].uid if self.core.queue else None

        uid = await self._call(_head_uid)
        if uid is None:
            raise EngineFault(
                "step() raised MemoryError with no queue head to "
                f"attribute it to — allocator state is suspect: {err}"
            ) from err
        await self._fail_uid(uid, err)

    # -- submission --------------------------------------------------------
    async def _submit(self, prompt, params, max_new_tokens, priority):
        self._ensure_driver()
        self._uid = max(self._uid, self.core._uid_counter)
        uid, self._uid = self._uid, self._uid + 1
        q: asyncio.Queue = asyncio.Queue()
        self._queues[uid] = q             # registered BEFORE the engine
        try:                              # sees the request
            req = await self._call(
                self.core.add_request, prompt, params, uid=uid,
                max_new_tokens=max_new_tokens, priority=priority)
        except BaseException:
            self._queues.pop(uid, None)
            raise
        self._wake.set()
        return req, q

    async def _drain(self, req: Request,
                     q: asyncio.Queue) -> AsyncIterator[StepOutput]:
        try:
            while True:
                item = await q.get()
                if isinstance(item, BaseException):
                    raise item
                yield item
                if item.finished:
                    await self._call(self.core.reap_done)
                    return
        finally:
            if not req.finished and self._error is None \
                    and not self._closed:
                await self.abort(req.uid)

    # -- public API --------------------------------------------------------
    async def generate(self, prompt,
                       params: Optional[SamplingParams] = None, *,
                       max_new_tokens: Optional[int] = None,
                       priority: int = 0) -> RequestOutput:
        """Submit ONE prompt and await its completion (concurrency comes
        from ``asyncio.gather`` over many calls — they share the batch)."""
        req, q = await self._submit(prompt, params, max_new_tokens,
                                    priority)
        async for _ in self._drain(req, q):
            pass
        return self._output_of(req)

    def stream(self, prompt, params: Optional[SamplingParams] = None, *,
               max_new_tokens: Optional[int] = None,
               priority: int = 0) -> AsyncIterator[StepOutput]:
        """Submit ONE prompt and yield its ``StepOutput`` chunks as the
        driver produces them; the final chunk has ``finished=True`` (an
        out-of-band ``abort()`` delivers an empty terminal chunk).
        Abandoning the iterator (``break`` / ``aclose()``) aborts the
        request — a dropped stream never pins a slot or its pages."""
        async def _gen():
            req, q = await self._submit(prompt, params, max_new_tokens,
                                        priority)
            drain = self._drain(req, q)
            try:
                async for chunk in drain:
                    yield chunk
            finally:
                # ``async for`` does NOT close its iterator on early
                # exit; without this, an abandoned stream's abort (in
                # _drain's finally) would wait for the event loop's
                # async-gen GC finalizer instead of running inside
                # ``aclose()``.
                await drain.aclose()

        return _gen()

    async def abort(self, uid) -> bool:
        """Cancel a queued or running request; its open stream (if any)
        receives an empty terminal chunk with ``finish_reason =
        "aborted"``. Returns False for unknown/finished uids."""
        ok = await self._call(self.core.abort, uid)
        await self._call(self.core.reap_done)
        q = self._queues.pop(uid, None)
        if q is not None:
            q.put_nowait(StepOutput(uid, [], True, FINISH_ABORT))
        return ok

    # -- telemetry ---------------------------------------------------------
    async def metrics(self):
        """Engine metrics snapshot (registry + driver-restart counter
        folded in); None when ``EngineConfig.telemetry == "off"``.
        Serialized through the engine executor like every core call."""
        def _snap():
            if self.core.tel.enabled:
                self.core.tel.gauge(
                    "driver_restarts", self.restarts,
                    help="Supervised step() retries by the async driver")
            return self.core.metrics()

        return await self._call(_snap)

    async def metrics_text(self):
        """Prometheus text exposition (None when telemetry is off)."""
        def _text():
            if self.core.tel.enabled:
                self.core.tel.gauge(
                    "driver_restarts", self.restarts,
                    help="Supervised step() retries by the async driver")
            return self.core.metrics_text()

        return await self._call(_text)

    async def timeline(self, uid):
        """Per-request lifecycle timeline (None when unknown or
        telemetry is off)."""
        return await self._call(self.core.request_timeline, uid)

    async def step_trace(self):
        """Chrome-trace JSON object of recorded step spans."""
        return await self._call(self.core.step_trace)

    async def tier_stats(self):
        """Hierarchical KV tier counters (per-tier residency, transition
        totals, prefetch hits/misses); None on non-paged engines."""
        return await self._call(self.core.tier_stats)

    def _output_of(self, req: Request) -> RequestOutput:
        text = (self.detokenizer(list(req.generated))
                if self.detokenizer is not None else "")
        return RequestOutput(
            uid=req.uid, prompt_token_ids=list(map(int, req.prompt)),
            token_ids=list(req.generated), finish_reason=req.finish_reason,
            text=text, cached_tokens=req.cached_tokens,
            prefill_tokens=max(req.prefill_tokens, 0), request=req)

    # -- lifecycle ---------------------------------------------------------
    async def close(self):
        """Cancel the driver, abort in-flight requests, and shut the
        executor down. Idempotent."""
        if self._closed:
            return
        self._closed = True
        if self._driver is not None and not self._driver.done():
            self._driver.cancel()
            try:
                await self._driver
            except (asyncio.CancelledError, Exception):
                pass
        for uid, q in list(self._queues.items()):
            await self._call(self.core.abort, uid)
            q.put_nowait(StepOutput(uid, [], True, FINISH_ABORT))
        self._queues.clear()
        await self._call(self.core.reap_done)
        self._exec.shutdown(wait=True)

    async def __aenter__(self) -> "AsyncLLM":
        return self

    async def __aexit__(self, *exc) -> None:
        await self.close()
