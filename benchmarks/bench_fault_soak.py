"""Robustness lane: seeded fault-injection soak with deep auditing.

Not a paper figure — the acceptance gate for the serving tier's failure
model. One scripted workload (shared-prefix families, CHAI snapshot
duplicates, priority preemption, scripted aborts) runs fault-free and
under a plan covering every injection surface, with ``audit_level=
"deep"`` so the invariant auditor re-verifies pool conservation,
refcounts, phases, and device block tables after EVERY step.

Claim checks:
  - ``drained``         every request ends completed or typed-failed
  - ``no_leaks``        idle-engine audit empty, pools conserve
  - ``plan_fired``      the plan exercised >= 4 distinct fault surfaces
  - ``token_parity``    untouched completed requests are bitwise equal
                        to the fault-free run
  - ``replayable``      re-running the faulted soak reproduces the
                        injector firing log byte-for-byte
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import save_result
from repro.configs.base import get_config, reduced
from repro.models import transformer as tfm
from repro.serving.engine import EngineConfig
from repro.serving.faults import FaultInjector, FaultSpec
from repro.serving.soak import run_soak, run_soak_pair

PLAN = [
    FaultSpec("pool.alloc", mode="transient", count=1),
    FaultSpec("pool.alloc", mode="error", uid=5, count=1),
    FaultSpec("swap.corrupt", mode="corrupt", count=1),
    FaultSpec("snapshot.restore", mode="error", count=1),
    FaultSpec("relay.residency", mode="error", count=1),
    FaultSpec("step.logits", mode="nan", uid=16, count=1),
]

TERMINAL = {"length", "stop", "aborted", "error"}


def _fresh_plan():
    return [FaultSpec(s.site, s.mode, s.step, s.uid, s.count, s.p)
            for s in PLAN]


def run():
    cfg = reduced(get_config("chai-llama-7b"), n_layers=2, d_model=32,
                  d_ff=64, vocab=128).replace(dtype="float32")
    cfg = cfg.with_chai(enabled=True, warmup_tokens=3)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    ecfg = EngineConfig(batch_slots=3, max_seq=64, page_size=8,
                        prefix_cache=True, relay_decode=True,
                        audit_level="deep")

    t0 = time.time()
    out = run_soak_pair(cfg, params, ecfg, specs=_fresh_plan(),
                        fault_seed=0, seed=3, n_requests=24)
    pair_s = time.time() - t0
    clean, faulted = out["clean"], out["faulted"]

    t0 = time.time()
    replay = run_soak(cfg, params, ecfg,
                      faults=FaultInjector(_fresh_plan(), seed=0), seed=3)
    replay_s = time.time() - t0

    fired = {f["site"] for f in
             faulted["fault_stats"]["injector"]["fired"]}
    finishes = {r["finish"] for r in faulted["requests"].values()}
    checks = {
        "drained": (faulted["unfinished"] == []
                    and finishes <= TERMINAL),
        "no_leaks": faulted["leaks"] == [] and clean["leaks"] == [],
        "plan_fired": len(fired) >= 4,
        "token_parity": (bool(out["parity"])
                         and out["mismatches"] == []),
        "replayable": (replay["fault_stats"]["injector"]
                       == faulted["fault_stats"]["injector"]
                       and replay["requests"] == faulted["requests"]),
    }
    payload = {
        "proxy_note": "tiny CPU model; the failure-model guarantees "
                      "under test are hardware-independent",
        "plan": faulted["fault_stats"]["injector"]["specs"],
        "fired": faulted["fault_stats"]["injector"]["fired"],
        "clean_steps": clean["steps"],
        "faulted_steps": faulted["steps"],
        "audit_steps": faulted["fault_stats"]["audit_steps"],
        "quarantined": faulted["fault_stats"]["quarantined"],
        "relay_dissolved": faulted["fault_stats"]["relay_dissolved"],
        "swap_checksum_failures":
            faulted["fault_stats"]["swap_checksum_failures"],
        "parity_uids": out["parity"],
        "mismatch_uids": out["mismatches"],
        "finishes": {uid: r["finish"]
                     for uid, r in sorted(faulted["requests"].items())},
        "seconds": {"pair": round(pair_s, 1),
                    "replay": round(replay_s, 1)},
        "claim_check": checks,
    }
    save_result("bench_fault_soak", payload)
    return payload


if __name__ == "__main__":
    out = run()
    print({k: v for k, v in out["claim_check"].items()})
