"""Substrate tests: data pipeline, checkpointing, compression, sharding."""
import os

import numpy as np
import pytest

try:    # property tests run when hypothesis is installed (the [test]
        # extra); a bare CPU env still collects and runs everything else.
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpoint import CheckpointManager
from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.optim import adamw, compression


# ----------------------------------------------------------------- data ----
def test_pipeline_deterministic():
    cfg = DataConfig(vocab_size=256, seq_len=32, global_batch=8, seed=7)
    a = SyntheticPipeline(cfg).batch(3)
    b = SyntheticPipeline(cfg).batch(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])
    c = SyntheticPipeline(cfg).batch(4)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_pipeline_labels_are_shifted_tokens():
    cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=4)
    b = SyntheticPipeline(cfg).batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_pipeline_learnable_structure():
    """Markov streams: the empirical conditional entropy of (tok -> next)
    is far below log2(V) — a model can learn it."""
    cfg = DataConfig(vocab_size=64, seq_len=256, global_batch=16)
    b = SyntheticPipeline(cfg).batch(0)
    pairs = {}
    toks, labs = b["tokens"], b["labels"]
    for row_t, row_l in zip(toks, labs):
        for t, l in zip(row_t, row_l):
            pairs.setdefault(int(t), []).append(int(l))
    # average number of distinct successors per observed state is small
    branching = np.mean([len(set(v)) for v in pairs.values()])
    assert branching < 8, branching   # vs 64 for uniform noise


def test_pipeline_host_slicing():
    cfg = DataConfig(vocab_size=128, seq_len=8, global_batch=8)
    p = SyntheticPipeline(cfg)
    s0 = p.batch(5, host_id=0, n_hosts=2)
    s1 = p.batch(5, host_id=1, n_hosts=2)
    assert s0["tokens"].shape == (4, 8)
    assert not np.array_equal(s0["tokens"], s1["tokens"])


# ----------------------------------------------------------- checkpoint ----
def _tree(rng):
    return {"a": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32),
            "b": {"c": jnp.arange(5, dtype=jnp.int32),
                  "d": jnp.asarray(rng.normal(size=(2,)), jnp.bfloat16)}}


def test_checkpoint_roundtrip(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree(rng)
    mgr.save(10, tree, extra={"data_batch": 10})
    out, extra = mgr.restore(10, jax.tree.map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    assert extra == {"data_batch": 10}


def test_checkpoint_keep_n_and_latest(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree(rng)
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_crash_leaves_no_partial(tmp_path, rng):
    """A tmp dir from a crashed save is invisible to discovery and GC'd."""
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree(rng)
    mgr.save(1, tree)
    os.makedirs(os.path.join(str(tmp_path), "2.tmp.crashed"))
    assert mgr.all_steps() == [1]
    mgr.save(3, tree)                       # triggers GC of stale tmp
    assert not any(".tmp." in n for n in os.listdir(str(tmp_path)))


def test_checkpoint_shape_mismatch_raises(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"a": jnp.zeros((3,))})
    with pytest.raises(ValueError):
        mgr.restore(1, {"a": jnp.zeros((4,))})


def test_checkpoint_reshard_on_restore(tmp_path, rng):
    """Restore accepts target shardings (single-device here: replicated)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32)}
    mgr.save(1, tree)
    sh = {"w": NamedSharding(mesh, P("data"))}
    out, _ = mgr.restore(1, tree, shardings=sh)
    assert out["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))


# ----------------------------------------------------------- compression ----
def test_quantize_roundtrip_bound(rng):
    g = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
    q, scale = compression._quantize(g)
    err = np.abs(np.asarray(compression._dequantize(q, scale) - g))
    assert err.max() <= float(scale) / 2 + 1e-7   # half-ULP of int8 grid


def test_error_feedback_accumulates_unbiased(rng):
    """Repeatedly compressing the same gradient with error feedback: the
    *running mean* of dequantized outputs converges to the true value
    (plain rounding would leave a persistent bias)."""
    g = jnp.asarray(rng.normal(size=(128,)) * 1e-3, jnp.float32)
    r = jnp.zeros_like(g)
    outs = []
    for _ in range(64):
        gin = g + r
        q, s = compression._quantize(gin)
        deq = compression._dequantize(q, s)
        r = gin - deq
        outs.append(np.asarray(deq))
    mean = np.mean(outs, axis=0)
    np.testing.assert_allclose(mean, np.asarray(g), rtol=0.05,
                               atol=float(np.abs(g).max()) * 0.05)


def test_compressed_psum_single_pod_identity(rng):
    """On a 1-pod mesh the compressed psum reduces over a trivial axis;
    output must equal the int8-quantized gradient (residual carries the
    rest)."""
    mesh = jax.make_mesh((1,), ("pod",))
    g = {"w": jnp.asarray(rng.normal(size=(16,)), jnp.float32)}
    r = {"w": jnp.zeros((16,), jnp.float32)}

    fn = compression.wrap_pod_manual(
        lambda gg, rr: compression.compressed_psum(gg, rr, "pod"),
        mesh,
        in_specs=(jax.tree.map(lambda _: jax.sharding.PartitionSpec(), g),
                  jax.tree.map(lambda _: jax.sharding.PartitionSpec(), r)),
        out_specs=(jax.tree.map(lambda _: jax.sharding.PartitionSpec(), g),
                   jax.tree.map(lambda _: jax.sharding.PartitionSpec(), r)))
    mean, res = fn(g, r)
    np.testing.assert_allclose(np.asarray(mean["w"] + res["w"]),
                               np.asarray(g["w"]), rtol=1e-5, atol=1e-6)


# -------------------------------------------------------------- sharding ----
def test_rules_divisibility_fallback():
    from repro.sharding import rules
    mesh = jax.make_mesh((1,), ("model",))
    # 1-device mesh: everything unsharded
    spec = rules.spec_for((8, 64), ("heads", "head_dim"), mesh)
    assert spec == jax.sharding.PartitionSpec()


def _rules_divisibility_body(dims, names):
    """Property: any spec produced divides the dims it shards."""
    from repro.sharding import rules
    n = min(len(dims), len(names))
    dims, names = dims[:n], names[:n]
    mesh = jax.make_mesh((1,), ("data",))   # container: 1 device
    spec = rules.spec_for(tuple(dims), tuple(names), mesh)
    # with a single device no axis may be assigned at all
    assert all(s is None for s in spec)


if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(dims=st.lists(st.sampled_from([1, 2, 3, 5, 8, 16, 48, 256]),
                         min_size=1, max_size=4),
           names=st.lists(st.sampled_from(
               ["batch", "heads", "mlp", "vocab", "embed", None]),
               min_size=1, max_size=4))
    def test_rules_never_violate_divisibility(dims, names):
        _rules_divisibility_body(dims, names)
else:
    def test_rules_never_violate_divisibility():
        pytest.importorskip("hypothesis")   # randomized search needs it;
        # the pinned grid below still exercises the property.


@pytest.mark.parametrize("dims,names", [
    ((8,), ("heads",)), ((1, 256), ("batch", "embed")),
    ((3, 5, 16), ("mlp", None, "vocab")), ((48, 2), ("embed", "heads")),
])
def test_rules_divisibility_pinned(dims, names):
    """Hypothesis-free pinned cases so the property holds on bare envs."""
    _rules_divisibility_body(list(dims), list(names))


def test_adamw_decreases_loss_quadratic():
    """AdamW on a convex quadratic reaches near-zero."""
    w = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    opt = adamw.init(w)

    def loss(p):
        return jnp.sum(jnp.square(p["w"]))

    for _ in range(200):
        g = jax.grad(loss)(w)
        w, opt, _ = adamw.update(g, opt, w, lr=0.1, weight_decay=0.0)
    assert float(loss(w)) < 1e-2
