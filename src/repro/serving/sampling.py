"""Per-request sampling configuration + host-side finish conditions.

``SamplingParams`` is the public knob set a request carries through the
engine: temperature / top-k / top-p with a per-request PRNG seed, stop
token ids, stop strings (matched against a detokenizer the engine owns),
and the generation budget. The device-side sampler itself lives in
``repro.launch.steps.make_sampler`` — one batched jit shared by both
schedulers — and draws its key as ``fold_in(PRNGKey(seed), n)`` where
``n`` is the number of tokens the REQUEST has sampled so far, never the
slot id or engine step. That makes seeded runs reproducible across the
continuous and cohort schedulers (and across slot placements / restarts):
token ``n`` of a request depends only on ``(seed, n, logits)``.

``temperature == 0`` is greedy decode, bit-identical to the engine's
historical ``argmax`` path — CHAI snapshot capture/replay and every
cross-layout parity guarantee key on it (``SamplingParams.greedy``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

FINISH_LENGTH = "length"      # max_new_tokens reached
FINISH_STOP = "stop"          # stop token id or stop string matched
FINISH_ABORT = "aborted"      # abort() mid-flight (queued or running)
FINISH_ERROR = "error"        # quarantined by a typed RequestError
#                               (Request.error carries the message)


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decode parameters.

    temperature  0 = greedy (bitwise-identical to argmax); > 0 scales
                 logits before the categorical draw.
    top_k        keep only the k highest logits (0 = full vocabulary).
    top_p        nucleus sampling: keep the smallest prefix of the
                 descending-probability vocab whose mass reaches top_p
                 (1.0 = off). Applied after top_k.
    seed         per-request PRNG seed; token n draws from
                 fold_in(PRNGKey(seed), n) — scheduler-independent.
    stop_token_ids  finish ("stop") when the last sampled token is one
                 of these; the stop token is kept in the output.
    stop         stop strings, matched against the engine detokenizer's
                 rendering of the generated tokens (requires the engine
                 to be built with a detokenizer).
    max_new_tokens  generation budget; finish reason "length".
    """
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0
    stop_token_ids: Tuple[int, ...] = ()
    stop: Tuple[str, ...] = ()
    max_new_tokens: int = 32

    def __post_init__(self):
        object.__setattr__(self, "stop_token_ids",
                           tuple(int(t) for t in self.stop_token_ids))
        object.__setattr__(self, "stop", tuple(self.stop))
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, "
                             f"got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0 (0 = off), "
                             f"got {self.top_k}")
        if not 0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, "
                             f"got {self.max_new_tokens}")

    @property
    def greedy(self) -> bool:
        return self.temperature == 0


# Extra trailing tokens decoded beyond the longest stop string: slack for
# tokenizers where a token renders to fewer bytes than one character (BPE
# continuation pieces, held-back incomplete UTF-8 sequences).
_HELD_BACK_TOKENS = 4


def _stop_window(params: SamplingParams) -> int:
    """Tail-window size (in tokens) that bounds every stop-string match
    completed by the newest token: the longest stop is ``L`` characters,
    a token renders to >= 1 character in the common case, and
    ``_HELD_BACK_TOKENS`` covers the byte-thin stragglers."""
    return max(len(s) for s in params.stop) + _HELD_BACK_TOKENS


def finish_reason(token_ids: Sequence[int], params: SamplingParams,
                  max_new_tokens: int,
                  detokenizer: Optional[Callable] = None) -> str:
    """Finish condition after the LAST appended token: "stop" (stop token
    id, or a stop string appearing in the detokenized output), "length"
    (budget exhausted), or "" (keep decoding). Stop wins over length when
    both trigger on the same token.

    Stop-string matching is INCREMENTAL: this is called once per appended
    token (the engine's per-step check and ``scan_finish`` both do), so a
    match completing at token n must involve text the newest token
    contributed. Only the trailing ``_stop_window(params)`` tokens are
    re-detokenized — O(len(stop)) per token instead of re-rendering the
    whole output (O(n^2) per request). Matches confined to older text
    were already caught by the call that appended their final token."""
    if token_ids:
        if params.stop_token_ids and \
                int(token_ids[-1]) in params.stop_token_ids:
            return FINISH_STOP
        if params.stop and detokenizer is not None:
            tail = list(token_ids)[-_stop_window(params):]
            text = detokenizer(tail)
            if any(s in text for s in params.stop):
                return FINISH_STOP
    if len(token_ids) >= max_new_tokens:
        return FINISH_LENGTH
    return ""


def scan_finish(token_ids: Sequence[int], params: SamplingParams,
                max_new_tokens: int,
                detokenizer: Optional[Callable] = None
                ) -> Tuple[List[int], str]:
    """Scan a token list from the front and truncate at the FIRST finish
    condition — the batch-append path (snapshot replay, cohort lockstep
    output) must land on exactly the tokens the incremental per-token
    check would have kept. Returns (possibly-truncated tokens, reason);
    reason is "" only when no condition has triggered yet."""
    out: List[int] = []
    for t in token_ids:
        out.append(int(t))
        r = finish_reason(out, params, max_new_tokens, detokenizer)
        if r:
            return out, r
    return out, ""
