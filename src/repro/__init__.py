"""repro — CHAI (Clustered Head Attention) production JAX framework.

Public API:
  repro.configs.base.get_config / list_configs / reduced
  repro.models.transformer   — forward_fullseq / decode_step / init_params
  repro.core                 — CHAI clustering, policies, cache layouts
  repro.serving              — ServingEngine (CHAI phase machine)
  repro.train                — Trainer (fault-tolerant loop)
  repro.launch               — mesh / dryrun / roofline / CLI drivers
  repro.kernels              — Pallas TPU kernels + jnp oracles
"""
__version__ = "1.0.0"
