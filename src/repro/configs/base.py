"""Model/arch configuration system.

Every assigned architecture is expressed as a ``ModelConfig``. Layer
heterogeneity (local/global attention, dense/MoE FFN, recurrent blocks) is
encoded as per-layer type strings so the transformer stack can build stacked
parameter groups and dispatch with ``lax.cond`` inside a scan.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence

# Layer mixer kinds.
ATTN_GLOBAL = "attn_global"
ATTN_LOCAL = "attn_local"   # sliding-window / local attention
RGLRU = "rglru"             # RecurrentGemma recurrent block
RWKV = "rwkv"               # RWKV-6 time-mix

# FFN kinds.
FFN_DENSE = "dense"
FFN_MOE = "moe"


@dataclass(frozen=True)
class CHAIConfig:
    """CHAI (Clustered Head Attention) configuration.

    ``cluster_counts`` is the offline elbow-selected number of clusters per
    layer (padded/stored per attention layer). ``k_max`` is the static compile
    width. ``warmup_tokens`` is the number of MHA decode steps observed before
    cluster-membership identification (paper: 5).
    """
    enabled: bool = False
    # Per-attention-layer cluster counts; if empty, derived by fraction.
    cluster_counts: tuple = ()
    # Fallback: fraction of query heads kept per layer if cluster_counts empty.
    cluster_fraction: float = 0.57
    warmup_tokens: int = 5
    kmeans_iters: int = 12
    # Feature window: how many trailing prefix positions feed clustering.
    feature_window: int = 256
    # 0 = paper behaviour (freeze after warmup); >0 = beyond-paper periodic
    # reclustering interval in decoded tokens.
    recluster_interval: int = 0
    # Ablation: also share V of the representative head (Table 4, CHAI-QKV).
    share_values: bool = False


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | audio | hybrid | ssm | vlm
    n_layers: int
    d_model: int
    n_heads: int                # query heads (0 => attention-free arch)
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 => d_model // n_heads
    layer_types: tuple = ()     # per-layer mixer kind; default all ATTN_GLOBAL
    ffn_types: tuple = ()       # per-layer FFN kind; default all FFN_DENSE
    window_size: int = 4096     # sliding window for ATTN_LOCAL
    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0           # per-expert hidden dim
    capacity_factor: float = 1.25
    # --- activations / norms ---
    activation: str = "silu"    # silu | gelu | relu2
    gated_mlp: bool = True      # False => 2-matrix MLP (nemotron relu2)
    norm_eps: float = 1e-6
    # --- attention details ---
    rope_theta: float = 10000.0
    attn_logit_softcap: float = 0.0   # gemma2-style tanh softcap on scores
    final_logit_softcap: float = 0.0  # softcap on LM logits
    qk_norm: bool = False
    # --- recurrent (RG-LRU / RWKV) ---
    rnn_width: int = 0          # RG-LRU recurrent width (0 => d_model)
    conv_width: int = 4         # RecurrentGemma temporal conv width
    rwkv_head_dim: int = 64
    # --- frontend stub ---
    frontend: str = "none"      # none | audio | vision
    tie_embeddings: bool = False
    # --- KV cache quantization (beyond-paper perf knob, §Perf cell 3) ---
    # "" = model dtype; "int8" = per-(head,position) symmetric int8 for the
    # *global* K/V caches (decode is HBM-bound on cache reads: ~2x bytes).
    kv_cache_dtype: str = ""
    # --- CHAI ---
    chai: CHAIConfig = field(default_factory=CHAIConfig)
    # Attention flavour is derivable: full attention in every layer?
    dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if not self.layer_types:
            kind = RWKV if self.family == "ssm" else ATTN_GLOBAL
            object.__setattr__(self, "layer_types", (kind,) * self.n_layers)
        if not self.ffn_types:
            kind = FFN_MOE if self.n_experts > 0 else FFN_DENSE
            object.__setattr__(self, "ffn_types", (kind,) * self.n_layers)
        assert len(self.layer_types) == self.n_layers, self.name
        assert len(self.ffn_types) == self.n_layers, self.name
        if self.rnn_width == 0:
            object.__setattr__(self, "rnn_width", self.d_model)

    # ---- derived -----------------------------------------------------
    @property
    def attn_layer_ids(self):
        return tuple(i for i, t in enumerate(self.layer_types)
                     if t in (ATTN_GLOBAL, ATTN_LOCAL))

    @property
    def n_attn_layers(self):
        return len(self.attn_layer_ids)

    @property
    def n_global_layers(self):
        return sum(1 for t in self.layer_types if t == ATTN_GLOBAL)

    @property
    def n_local_layers(self):
        return sum(1 for t in self.layer_types if t == ATTN_LOCAL)

    @property
    def n_rec_layers(self):
        return sum(1 for t in self.layer_types if t == RGLRU)

    @property
    def n_rwkv_layers(self):
        return sum(1 for t in self.layer_types if t == RWKV)

    @property
    def n_dense_ffn(self):
        return sum(1 for t in self.ffn_types if t == FFN_DENSE)

    @property
    def n_moe_ffn(self):
        return sum(1 for t in self.ffn_types if t == FFN_MOE)

    @property
    def n_rwkv_heads(self):
        return self.d_model // self.rwkv_head_dim

    @property
    def q_per_kv(self):
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def is_mha(self):
        """True when every query head has its own K/V (paper's setting)."""
        return self.n_heads > 0 and self.n_heads == self.n_kv_heads

    @property
    def sub_quadratic(self):
        """True if no layer needs an unbounded dense KV cache."""
        return all(t != ATTN_GLOBAL for t in self.layer_types)

    @property
    def supports_long_context(self):
        """long_500k eligibility: SSM / hybrid / sliding-window-major."""
        return self.family in ("ssm", "hybrid") or (
            self.n_local_layers > 0 or self.family == "dense" and False)

    def chai_cluster_counts(self):
        """Per-attention-layer cluster counts (static)."""
        import math
        n = self.n_attn_layers
        if n == 0:
            return ()
        if self.chai.cluster_counts:
            assert len(self.chai.cluster_counts) == n
            return tuple(self.chai.cluster_counts)
        # Fraction fallback, but never below n_kv_heads (GQA group floor) and
        # mimic the paper's depth profile: early layers keep more clusters.
        out = []
        for j in range(n):
            depth = j / max(n - 1, 1)
            frac = self.chai.cluster_fraction
            # paper: early layers high k (little redundancy), later layers low
            f = min(1.0, frac * (1.35 - 0.7 * depth))
            k = max(1, math.ceil(f * self.n_heads))
            if self.n_kv_heads > 1 and self.n_heads != self.n_kv_heads:
                k = max(k, self.n_kv_heads)  # block-diagonal GQA constraint
            out.append(min(k, self.n_heads))
        return tuple(out)

    @property
    def k_max(self):
        counts = self.chai_cluster_counts()
        return max(counts) if counts else 0

    def with_chai(self, **kw):
        return dataclasses.replace(self, chai=dataclasses.replace(self.chai, **kw))

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)

    def param_count(self):
        """Analytic parameter count N (embeddings included once)."""
        c = self
        n = c.vocab_size * c.d_model  # embed
        if not c.tie_embeddings:
            n += c.vocab_size * c.d_model
        qkv_out = c.n_heads * c.head_dim
        kv_out = c.n_kv_heads * c.head_dim
        attn = (c.d_model * qkv_out + 2 * c.d_model * kv_out
                + qkv_out * c.d_model)
        n_mats = 3 if c.gated_mlp else 2
        dense_ffn = n_mats * c.d_model * c.d_ff
        moe_ffn = (c.n_experts * 3 * c.d_model * c.moe_d_ff
                   + c.n_shared_experts * 3 * c.d_model * c.moe_d_ff
                   + c.d_model * c.n_experts)
        rg = 0
        if c.n_rec_layers:
            w = c.rnn_width
            rg = (2 * c.d_model * w + w * c.d_model + c.conv_width * w + 2 * w
                  + 2 * w)
        rwkv = 0
        if c.n_rwkv_layers:
            rwkv = 6 * c.d_model * c.d_model + 2 * c.d_model * c.d_ff
        for lt, ft in zip(c.layer_types, c.ffn_types):
            if lt in (ATTN_GLOBAL, ATTN_LOCAL):
                n += attn
            elif lt == RGLRU:
                n += rg
            elif lt == RWKV:
                n += rwkv
            if lt != RWKV:  # rwkv includes its own channel-mix as "ffn"
                n += dense_ffn if ft == FFN_DENSE else moe_ffn
            n += 2 * c.d_model  # norms
        return n

    def active_param_count(self):
        """Active params per token (MoE: only routed top-k + shared)."""
        c = self
        if c.n_moe_ffn == 0:
            return self.param_count()
        full = self.param_count()
        moe_total = c.n_moe_ffn * c.n_experts * 3 * c.d_model * c.moe_d_ff
        moe_active = c.n_moe_ffn * c.top_k * 3 * c.d_model * c.moe_d_ff
        return full - moe_total + moe_active


# ----------------------------------------------------------------------
# Shapes assigned to the LM-transformer pool.
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k":    ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k":  ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k":   ShapeConfig("long_500k", 524288, 1, "decode"),
}

def reduced(cfg: ModelConfig, *, n_layers=None, d_model=64, n_heads=None,
            d_ff=128, vocab=256, window=16, n_experts=8, top_k=2,
            moe_d_ff=32, rnn_width=64, dtype="float32") -> ModelConfig:
    """Scaled-down same-family config for CPU smoke tests.

    Preserves the layer-type pattern (sliced/tiled to n_layers), GQA ratio,
    MoE-ness, frontend kind — everything structural."""
    if n_layers is None:
        n_layers = min(cfg.n_layers, 4)
    lt = (cfg.layer_types * n_layers)[:n_layers]
    # keep at least one of each kind present in the original
    kinds = list(dict.fromkeys(cfg.layer_types))
    lt = list(lt)
    for j, kind in enumerate(kinds):
        if kind not in lt and j < n_layers:
            lt[j] = kind
    ft = list((cfg.ffn_types * n_layers)[:n_layers])
    for kind in dict.fromkeys(cfg.ffn_types):
        if kind not in ft:
            ft[-1] = kind
    if n_heads is None:
        n_heads = max(4, min(8, cfg.n_heads)) if cfg.n_heads else 0
    n_kv = max(1, n_heads // max(cfg.q_per_kv, 1)) if cfg.n_heads else 0
    return dataclasses.replace(
        cfg,
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=d_model // max(n_heads, 1) if n_heads else 0,
        d_ff=d_ff,
        vocab_size=vocab,
        layer_types=tuple(lt),
        ffn_types=tuple(ft),
        window_size=window,
        n_experts=n_experts if cfg.n_experts else 0,
        top_k=min(top_k, n_experts) if cfg.n_experts else 0,
        moe_d_ff=moe_d_ff if cfg.n_experts else 0,
        rnn_width=rnn_width if cfg.n_rec_layers else 0,
        rwkv_head_dim=16,
        dtype=dtype,
    )


_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs():
    _ensure_loaded()
    return sorted(_REGISTRY)


_LOADED = False


def _ensure_loaded():
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    from repro.configs import (  # noqa: F401
        nemotron_4_15b, gemma2_9b, gemma3_4b, h2o_danube_1_8b,
        qwen3_moe_30b_a3b, deepseek_moe_16b, musicgen_large,
        recurrentgemma_9b, rwkv6_1_6b, internvl2_76b, chai_llama_7b)
