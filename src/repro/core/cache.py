"""CHAI KV-cache layouts: full (MHA warmup), clustered (steady state), and
the *unified per-slot* layout used by the continuous-batching engine.

``compact_kv`` is the paper's "remove the Key tokens associated [with pruned
heads]" step (§3.5): after membership identification, the dense K cache is
gathered down to representative rows. Run it as a donated jit so the full
cache's buffer is released on device.

The unified layout (``unified_state_structs``) keeps the dense K/V buffers
(``kg``/``vg``) and the clustered buffers (``kg_chai``, plus scales /
``vg_chai`` variants) resident side by side, with a per-slot ``phase``
vector. Each batch slot independently walks PREFILL -> WARMUP -> CLUSTER ->
STEADY: ``insert_slot`` writes a freshly prefilled request into one slot,
``compact_kv_slot`` gathers that slot's representative K rows into the
clustered cache (donated slot-indexed gather), and the mixed-phase decode
step commits each attention path's cache writes under a per-slot write
mask (mask-and-select inside one jit; see models/transformer.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.clustering import chai_widths
from repro.models.transformer import decode_state_structs
from repro.sharding.rules import Ax

# Per-slot lifecycle phases (paper Fig 10). PREFILL and CLUSTER are
# transient (they happen synchronously inside a host-driven jit call); the
# device-resident ``phase`` vector only ever holds FREE / WARMUP / STEADY.
PHASE_FREE = 0
PHASE_PREFILL = 1
PHASE_WARMUP = 2
PHASE_CLUSTER = 3
PHASE_STEADY = 4


def quant_rows(x):
    """Symmetric int8 over the last axis. x: (..., hd) ->
    (int8 same-shape, f32 scale (...))."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequant_rows(q, scale):
    return q.astype(jnp.float32) * scale[..., None]


def chai_state_structs(cfg: ModelConfig, batch: int, max_seq: int):
    """Decode-state structs with the clustered K cache (MHA archs only --
    GQA archs keep the plain state)."""
    shapes, logical = decode_state_structs(cfg, batch, max_seq)
    if not (cfg.is_mha and cfg.chai.enabled):
        return shapes, logical
    k_max, _ = chai_widths(cfg)
    dt = shapes["kg"].dtype
    ng, b, _, s, hd = shapes["kg"].shape
    shapes = dict(shapes)
    logical = dict(logical)
    shapes.pop("kg")
    kg_ax = logical.pop("kg")
    shapes["kg_chai"] = jax.ShapeDtypeStruct((ng, b, k_max, s, hd), dt)
    logical["kg_chai"] = Ax("layers", "batch", "clusters", "seq", "head_dim")
    if cfg.kv_cache_dtype == "int8":
        shapes.pop("kg_scale")
        logical.pop("kg_scale")
        shapes["kg_chai_scale"] = jax.ShapeDtypeStruct((ng, b, k_max, s),
                                                       jnp.float32)
        logical["kg_chai_scale"] = Ax("layers", "batch", "clusters", "seq")
    if cfg.chai.share_values:
        shapes.pop("vg")
        logical.pop("vg")
        shapes["vg_chai"] = jax.ShapeDtypeStruct((ng, b, k_max, s, hd), dt)
        logical["vg_chai"] = Ax("layers", "batch", "clusters", "seq",
                                "head_dim")
    return shapes, logical


def init_chai_state(cfg: ModelConfig, batch: int, max_seq: int):
    shapes, _ = chai_state_structs(cfg, batch, max_seq)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)


def add_score_buffer(state, cfg: ModelConfig, batch: int):
    """Attach the warmup score-accumulation buffer (nA, B, H, Wf)."""
    s = state["kg"].shape[3] if "kg" in state else state["kl"].shape[3]
    wf = min(cfg.chai.feature_window, int(s))
    state = dict(state)
    state["chai_scores"] = jnp.zeros(
        (cfg.n_attn_layers, batch, cfg.n_heads, wf), jnp.float32)
    return state


def pop_score_buffer(state):
    state = dict(state)
    scores = state.pop("chai_scores")
    return state, scores


def compact_kv(state, chai_ctx, cfg: ModelConfig):
    """Convert a full MHA decode state into the clustered layout.

    state["kg"]: (nG, B, H, S, hd); ctx reps: (nA, B, k) or (nA, k).
    Returns a new state with kg_chai (and vg_chai under share_values).
    Donate ``state`` when jitting to free the dense K cache in place.
    """
    if not (cfg.is_mha and cfg.chai.enabled):
        return state
    reps = chai_ctx["reps"]
    batched = reps.ndim == 3
    kg = state["kg"]                                  # (nG, B, H, S, hd)
    ng, b, h, s, hd = kg.shape
    k_max = reps.shape[-1]
    # All-global MHA archs: attention layer i == global layer i.
    r = reps if batched else jnp.broadcast_to(reps[:, None, :], (ng, b, k_max))
    idx = r[..., None, None]                          # (nG, B, k, 1, 1)
    kg_chai = jnp.take_along_axis(kg, idx, axis=2)
    new_state = {k: v for k, v in state.items()
                 if k not in ("kg", "kg_scale")}
    new_state["kg_chai"] = kg_chai
    if cfg.kv_cache_dtype == "int8" and "kg_scale" in state:
        new_state["kg_chai_scale"] = jnp.take_along_axis(
            state["kg_scale"], r[..., None], axis=2)
    if cfg.chai.share_values:
        vg_chai = jnp.take_along_axis(state["vg"], idx, axis=2)
        new_state.pop("vg")
        new_state["vg_chai"] = vg_chai
    return new_state


# ---------------------------------------------------------------------------
# Unified per-slot layout (continuous batching)
# ---------------------------------------------------------------------------

def unified_state_structs(cfg: ModelConfig, batch: int, max_seq: int, *,
                          chai: bool = True):
    """Decode-state structs for the continuous-batching engine.

    Dense (``kg``/``vg``) and clustered (``kg_chai``) caches are BOTH
    resident so warmup and steady slots coexist in one batch; ``phase``
    tracks each slot's lifecycle stage and ``chai_scores`` accumulates
    warmup clustering features per slot.
    """
    shapes, logical = decode_state_structs(cfg, batch, max_seq)
    shapes, logical = dict(shapes), dict(logical)
    shapes["phase"] = jax.ShapeDtypeStruct((batch,), jnp.int32)
    logical["phase"] = Ax("batch")
    if not (chai and cfg.chai.enabled and cfg.k_max > 0):
        return shapes, logical
    wf = min(cfg.chai.feature_window, max_seq)
    shapes["chai_scores"] = jax.ShapeDtypeStruct(
        (cfg.n_attn_layers, batch, cfg.n_heads, wf), jnp.float32)
    logical["chai_scores"] = Ax("layers", "batch", "heads", None)
    if cfg.is_mha and "kg" in shapes:
        k_max, _ = chai_widths(cfg)
        dt = shapes["kg"].dtype
        ng, b, _, s, hd = shapes["kg"].shape
        shapes["kg_chai"] = jax.ShapeDtypeStruct((ng, b, k_max, s, hd), dt)
        logical["kg_chai"] = Ax("layers", "batch", "clusters", "seq",
                                "head_dim")
        if cfg.kv_cache_dtype == "int8":
            shapes["kg_chai_scale"] = jax.ShapeDtypeStruct(
                (ng, b, k_max, s), jnp.float32)
            logical["kg_chai_scale"] = Ax("layers", "batch", "clusters",
                                          "seq")
        if cfg.chai.share_values:
            shapes["vg_chai"] = jax.ShapeDtypeStruct((ng, b, k_max, s, hd),
                                                     dt)
            logical["vg_chai"] = Ax("layers", "batch", "clusters", "seq",
                                    "head_dim")
    return shapes, logical


def init_unified_state(cfg: ModelConfig, batch: int, max_seq: int, *,
                       chai: bool = True):
    shapes, _ = unified_state_structs(cfg, batch, max_seq, chai=chai)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)


def insert_slot(state, mini, slot, *, phase=PHASE_WARMUP):
    """Write a freshly prefilled batch=1 decode state into batch slot
    ``slot`` of a unified state and reset the slot's CHAI bookkeeping.
    Donate ``state`` when jitting (in-place slot update on device).
    """
    state = dict(state)
    for k, v in mini.items():
        axis = 0 if state[k].ndim == 1 else 1
        state[k] = jax.lax.dynamic_update_index_in_dim(
            state[k], v.astype(state[k].dtype), slot, axis)
    if "chai_scores" in state:
        nA, _, h, wf = state["chai_scores"].shape
        state["chai_scores"] = jax.lax.dynamic_update_index_in_dim(
            state["chai_scores"], jnp.zeros((nA, 1, h, wf), jnp.float32),
            slot, 1)
    state["phase"] = state["phase"].at[slot].set(phase)
    return state


def compact_kv_slot(state, slot_ctx, cfg: ModelConfig, slot):
    """Per-slot compaction (unified layout): gather ONE batch slot's
    representative K rows from the dense cache into the clustered cache
    and advance that slot's phase to STEADY.

    ``slot_ctx``: batch-free ctx for this request (reps (nA, k)). Donate
    ``state`` when jitting — the gather updates the clustered buffers in
    place; the dense buffers stay resident for the other slots.
    """
    state = dict(state)
    if cfg.is_mha and cfg.chai.enabled and "kg_chai" in state:
        reps = slot_ctx["reps"]                           # (nA, k)

        def gather(dense, clustered, tail_dims):
            row = jax.lax.dynamic_index_in_dim(dense, slot, 1,
                                               keepdims=False)
            idx = reps.reshape(reps.shape + (1,) * tail_dims)
            g = jnp.take_along_axis(row, idx, axis=1)
            return jax.lax.dynamic_update_index_in_dim(clustered, g, slot, 1)

        # All-global MHA archs: attention layer i == global layer i.
        state["kg_chai"] = gather(state["kg"], state["kg_chai"], 2)
        if cfg.kv_cache_dtype == "int8":
            state["kg_chai_scale"] = gather(state["kg_scale"],
                                            state["kg_chai_scale"], 1)
        if cfg.chai.share_values:
            state["vg_chai"] = gather(state["vg"], state["vg_chai"], 2)
    state["phase"] = state["phase"].at[slot].set(PHASE_STEADY)
    return state


def reset_slot(state, slot):
    """Retire a slot: mark FREE and rewind its write position."""
    state = dict(state)
    state["phase"] = state["phase"].at[slot].set(PHASE_FREE)
    state["pos"] = state["pos"].at[slot].set(0)
    return state


def unified_kv_bytes(cfg: ModelConfig, batch: int, seq: int, *,
                     chai: bool = True):
    """Resident KV bytes of the continuous engine's unified layout.

    Unlike the analytic ``kv_cache_bytes`` (cohort steady state: the
    dense cache is freed after compaction), the unified layout keeps
    dense AND clustered buffers allocated — summed exactly from the
    layout's own structs."""
    import numpy as np
    shapes, _ = unified_state_structs(cfg, batch, seq, chai=chai)
    kv_keys = ("kg", "vg", "kg_scale", "vg_scale", "kl", "vl",
               "kg_chai", "kg_chai_scale", "vg_chai")
    return int(sum(np.prod(s.shape) * s.dtype.itemsize
                   for k, s in shapes.items() if k in kv_keys))


def kv_cache_bytes(cfg: ModelConfig, batch: int, seq: int, *,
                   chai: bool = False):
    """Analytic steady-state KV-cache size in bytes (paper Fig 11)."""
    if cfg.n_attn_layers == 0:
        return 0
    if cfg.kv_cache_dtype == "int8":
        esize = 1 + 4 / cfg.head_dim      # int8 row + f32 scale per row
    else:
        esize = jnp.dtype(cfg.dtype).itemsize
    hd = cfg.head_dim
    k_max, _ = chai_widths(cfg)
    total = 0
    for lt in cfg.layer_types:
        if lt == "attn_global":
            k_rows = k_max if (chai and cfg.is_mha and cfg.chai.enabled) \
                else cfg.n_kv_heads
            v_rows = (k_max if (chai and cfg.is_mha and
                                cfg.chai.share_values) else cfg.n_kv_heads)
            total += int(batch * (k_rows + v_rows) * seq * hd * esize)
        elif lt == "attn_local":
            w = min(cfg.window_size, seq)
            total += int(batch * 2 * cfg.n_kv_heads * w * hd * esize)
    return total
