"""Pallas TPU kernels for Clustered Head Attention (the paper's core op).

Decomposition (DESIGN.md §3.2):
  1. ``chai_qk``      — raw scores for the R representative heads only
                        (R <= H: the compute CHAI removes). GQA: rep j reads
                        the K tile of its group j // reps_per_group via a
                        static index_map; MHA reads the clustered K cache.
  2. ``row_softmax``  — masked softmax over each (b, rep) row (row fits
                        VMEM; one pass).
  3. ``chai_av``      — the broadcast-and-accumulate: head h gathers the A
                        tile of its cluster via a **scalar-prefetched**
                        ``h2c`` index map (TPU-idiomatic dynamic gather, as
                        in paged-attention kernels) and multiplies with its
                        own V tile. Per-head V is preserved (Table 4).

Why not one fused kernel: normalized A for head h requires the rep's full
row max/denominator, which is only known after the last S tile; splitting at
the (B, R, S) score tensor costs one extra HBM round-trip of size S*R —
~R/(H*hd) of the cache traffic (<1%) — and keeps every kernel single-pass.

Paged variants (``paged_chai_qk`` / ``paged_chai_av``): K/V live in page
pools addressed through scalar-prefetched int32 block tables (one S-tile ==
one page), composing the ``chai_av`` head->cluster gather with the
paged-attention page gather — the serving engine's clustered pages stream
straight from the ``PagePool`` layout without densification.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _interpret_default():
    return jax.default_backend() == "cpu"


# ------------------------------------------------------------------ QK ----
def _qk_kernel(pos_ref, q_ref, k_ref, o_ref, *, scale, ts, window):
    b = pl.program_id(0)
    s = pl.program_id(2)
    q = q_ref[0, 0, :].astype(jnp.float32)[None, :]        # (1, hd)
    k = k_ref[0, 0].astype(jnp.float32)                    # (Ts, hd)
    sc = jnp.dot(k, q.T, preferred_element_type=jnp.float32) * scale
    idx = s * ts + jax.lax.broadcasted_iota(jnp.int32, (ts, 1), 0)
    pos = pos_ref[b]
    valid = idx <= pos
    if window:
        valid &= (pos - idx) < window
    sc = jnp.where(valid, sc, NEG_INF)
    o_ref[0, 0, :] = sc[:, 0]


def chai_qk(q_rep, k_cache, pos, *, reps_per_group=1, window=0, ts=512,
            interpret=None):
    """q_rep: (B, R, hd); k_cache: (B, KV, S, hd) with KV*reps_per_group==R
    (MHA clustered cache: KV==R, reps_per_group==1). -> raw scores (B,R,S)."""
    if interpret is None:
        interpret = _interpret_default()
    b, r_total, hd = q_rep.shape
    s = k_cache.shape[2]
    ts = min(ts, s)
    assert s % ts == 0
    scale = 1.0 / math.sqrt(hd)
    kernel = functools.partial(_qk_kernel, scale=scale, ts=ts, window=window)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, r_total, s // ts),
            in_specs=[
                pl.BlockSpec((1, 1, hd), lambda bb, rr, ss, pos_r:
                             (bb, rr, 0)),
                pl.BlockSpec((1, 1, ts, hd), lambda bb, rr, ss, pos_r:
                             (bb, rr // reps_per_group, ss, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, ts), lambda bb, rr, ss, pos_r:
                                   (bb, rr, ss)),
        ),
        out_shape=jax.ShapeDtypeStruct((b, r_total, s), jnp.float32),
        interpret=interpret,
    )(pos.astype(jnp.int32), q_rep, k_cache)


# ------------------------------------------------------------- softmax ----
def _softmax_kernel(x_ref, o_ref):
    x = x_ref[0, 0, :]
    m = jnp.maximum(jnp.max(x), -1e30)
    p = jnp.exp(x - m)
    o_ref[0, 0, :] = p / jnp.maximum(jnp.sum(p), 1e-37)


def row_softmax(scores, *, interpret=None):
    """scores: (B, R, S) raw (already masked) -> normalized A (B, R, S)."""
    if interpret is None:
        interpret = _interpret_default()
    b, r, s = scores.shape
    return pl.pallas_call(
        _softmax_kernel,
        grid=(b, r),
        in_specs=[pl.BlockSpec((1, 1, s), lambda bb, rr: (bb, rr, 0))],
        out_specs=pl.BlockSpec((1, 1, s), lambda bb, rr: (bb, rr, 0)),
        out_shape=jax.ShapeDtypeStruct((b, r, s), jnp.float32),
        interpret=interpret,
    )(scores)


# ------------------------------------------------------- paged QK ---------
def _paged_qk_kernel(pos_ref, bt_ref, q_ref, k_ref, o_ref, *, scale, page,
                     window):
    b = pl.program_id(0)
    s = pl.program_id(2)               # logical page index
    q = q_ref[0, 0, :].astype(jnp.float32)[None, :]        # (1, hd)
    k = k_ref[0, 0].astype(jnp.float32)                    # (page, hd)
    sc = jnp.dot(k, q.T, preferred_element_type=jnp.float32) * scale
    idx = s * page + jax.lax.broadcasted_iota(jnp.int32, (page, 1), 0)
    pos = pos_ref[b]
    valid = idx <= pos
    if window:
        valid &= (pos - idx) < window
    o_ref[0, 0, :] = jnp.where(valid, sc, NEG_INF)[:, 0]


def paged_chai_qk(q_rep, k_pool, bt, pos, *, reps_per_group=1, window=0,
                  interpret=None):
    """Paged clustered scores. q_rep: (B, R, hd); k_pool: (nP, KV, page,
    hd) page pool with KV * reps_per_group == R (MHA clustered pool:
    KV == k_max, reps_per_group == 1); bt: (B, P) int32 block table;
    pos: (B,). Returns raw scores (B, R, P*page) — feed ``row_softmax``."""
    if interpret is None:
        interpret = _interpret_default()
    b, r_total, hd = q_rep.shape
    page = k_pool.shape[2]
    n_pages = bt.shape[1]
    s = n_pages * page
    scale = 1.0 / math.sqrt(hd)
    kernel = functools.partial(_paged_qk_kernel, scale=scale, page=page,
                               window=window)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(b, r_total, n_pages),
            in_specs=[
                pl.BlockSpec((1, 1, hd), lambda bb, rr, ss, pos_r, bt_r:
                             (bb, rr, 0)),
                pl.BlockSpec((1, 1, page, hd),
                             lambda bb, rr, ss, pos_r, bt_r:
                             (bt_r[bb, ss], rr // reps_per_group, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, page),
                                   lambda bb, rr, ss, pos_r, bt_r:
                                   (bb, rr, ss)),
        ),
        out_shape=jax.ShapeDtypeStruct((b, r_total, s), jnp.float32),
        interpret=interpret,
    )(pos.astype(jnp.int32), bt.astype(jnp.int32), q_rep, k_pool)


# ------------------------------------------------------- int8 QK ----------
def _qk_i8_kernel(pos_ref, q_ref, k_ref, ks_ref, o_ref, *, scale, ts,
                  window):
    """Fused int8-dequant scores: K tile loads 1 byte/elem from HBM and
    dequantizes in VMEM (the memory-bound decode's byte saving happens on
    the HBM->VMEM stream, which is exactly what BlockSpec tiles)."""
    b = pl.program_id(0)
    s = pl.program_id(2)
    q = q_ref[0, 0, :].astype(jnp.float32)[None, :]        # (1, hd)
    k = k_ref[0, 0].astype(jnp.float32)                    # (Ts, hd) int8
    krow = ks_ref[0, 0].astype(jnp.float32)[:, None]       # (Ts, 1) scales
    sc = jnp.dot(k, q.T, preferred_element_type=jnp.float32)
    sc = sc * krow * scale
    idx = s * ts + jax.lax.broadcasted_iota(jnp.int32, (ts, 1), 0)
    pos = pos_ref[b]
    valid = idx <= pos
    if window:
        valid &= (pos - idx) < window
    o_ref[0, 0, :] = jnp.where(valid, sc, NEG_INF)[:, 0]


def chai_qk_i8(q_rep, k_cache_i8, k_scale, pos, *, reps_per_group=1,
               window=0, ts=512, interpret=None):
    """int8 variant of ``chai_qk``. k_cache_i8: (B, KV, S, hd) int8;
    k_scale: (B, KV, S) f32 per-row scales. Returns raw scores (B, R, S).
    """
    if interpret is None:
        interpret = _interpret_default()
    b, r_total, hd = q_rep.shape
    s = k_cache_i8.shape[2]
    ts = min(ts, s)
    assert s % ts == 0
    scale = 1.0 / math.sqrt(hd)
    kernel = functools.partial(_qk_i8_kernel, scale=scale, ts=ts,
                               window=window)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, r_total, s // ts),
            in_specs=[
                pl.BlockSpec((1, 1, hd), lambda bb, rr, ss, pos_r:
                             (bb, rr, 0)),
                pl.BlockSpec((1, 1, ts, hd), lambda bb, rr, ss, pos_r:
                             (bb, rr // reps_per_group, ss, 0)),
                pl.BlockSpec((1, 1, ts), lambda bb, rr, ss, pos_r:
                             (bb, rr // reps_per_group, ss)),
            ],
            out_specs=pl.BlockSpec((1, 1, ts), lambda bb, rr, ss, pos_r:
                                   (bb, rr, ss)),
        ),
        out_shape=jax.ShapeDtypeStruct((b, r_total, s), jnp.float32),
        interpret=interpret,
    )(pos.astype(jnp.int32), q_rep, k_cache_i8, k_scale)


# ------------------------------------------------------------------ AV ----
def _av_kernel(h2c_ref, a_ref, v_ref, o_ref, acc_scr, *, n_tiles):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    a = a_ref[0, 0, :].astype(jnp.float32)[None, :]        # (1, Ts)
    v = v_ref[0, 0].astype(jnp.float32)                    # (Ts, hd)
    acc_scr[...] += jnp.dot(a, v, preferred_element_type=jnp.float32)

    @pl.when(s == n_tiles - 1)
    def _fin():
        o_ref[0, 0, :] = acc_scr[0, :].astype(o_ref.dtype)


def chai_av(a, v_cache, h2c, *, ts=512, interpret=None):
    """a: (B, R, S) normalized clustered scores; v_cache: (B, H, S, hd);
    h2c: (B, H) int32 head -> A-row map (scalar-prefetched: drives the A
    BlockSpec index_map). Returns (B, H, hd) fp32."""
    if interpret is None:
        interpret = _interpret_default()
    b, h, s, hd = v_cache.shape
    if h2c.ndim == 1:
        h2c = jnp.broadcast_to(h2c, (b, h))
    ts = min(ts, s)
    assert s % ts == 0
    n_tiles = s // ts
    kernel = functools.partial(_av_kernel, n_tiles=n_tiles)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, h, n_tiles),
            in_specs=[
                pl.BlockSpec((1, 1, ts), lambda bb, hh, ss, h2c_r:
                             (bb, h2c_r[bb, hh], ss)),
                pl.BlockSpec((1, 1, ts, hd), lambda bb, hh, ss, h2c_r:
                             (bb, hh, ss, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, hd), lambda bb, hh, ss, h2c_r:
                                   (bb, hh, 0)),
            scratch_shapes=[pltpu.VMEM((1, hd), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, hd), jnp.float32),
        interpret=interpret,
    )(h2c.astype(jnp.int32), a, v_cache)


# ------------------------------------------------------- paged AV ---------
def _paged_av_kernel(h2c_ref, bt_ref, a_ref, v_ref, o_ref, acc_scr, *,
                     n_tiles):
    # Same accumulate as _av_kernel; both scalar refs are consumed by the
    # index_maps (A row via h2c, V page via the block table).
    _av_kernel(h2c_ref, a_ref, v_ref, o_ref, acc_scr, n_tiles=n_tiles)


def paged_chai_av(a, v_pool, bt_v, h2c, *, interpret=None):
    """Paged broadcast-and-accumulate: head h reads the A row of its
    cluster (scalar-prefetched ``h2c``) and its own V rows from the page
    pool (scalar-prefetched block table) — the two gathers compose in
    one index_map pair. a: (B, R, S) normalized clustered scores with
    S == P * page; v_pool: (nP, H, page, hd); bt_v: (B, P) int32;
    h2c: (B, H) or (H,) int32. Returns (B, H, hd) fp32."""
    if interpret is None:
        interpret = _interpret_default()
    _, h, page, hd = v_pool.shape
    b = a.shape[0]
    if h2c.ndim == 1:
        h2c = jnp.broadcast_to(h2c, (b, h))
    n_pages = bt_v.shape[1]
    assert a.shape[2] == n_pages * page, (a.shape, n_pages, page)
    kernel = functools.partial(_paged_av_kernel, n_tiles=n_pages)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(b, h, n_pages),
            in_specs=[
                pl.BlockSpec((1, 1, page),
                             lambda bb, hh, ss, h2c_r, bt_r:
                             (bb, h2c_r[bb, hh], ss)),
                pl.BlockSpec((1, 1, page, hd),
                             lambda bb, hh, ss, h2c_r, bt_r:
                             (bt_r[bb, ss], hh, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, hd),
                                   lambda bb, hh, ss, h2c_r, bt_r:
                                   (bb, hh, 0)),
            scratch_shapes=[pltpu.VMEM((1, hd), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, hd), jnp.float32),
        interpret=interpret,
    )(h2c.astype(jnp.int32), bt_v.astype(jnp.int32), a, v_pool)
