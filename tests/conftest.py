"""Shared fixtures. NOTE: no XLA device-count flag here on purpose —
smoke tests and benches must see the real single CPU device; only
launch/dryrun.py (its own process) forces 512 placeholder devices."""
import numpy as np
import pytest

import jax


@pytest.fixture(scope="session", autouse=True)
def _x64_off():
    jax.config.update("jax_enable_x64", False)


@pytest.fixture()
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
