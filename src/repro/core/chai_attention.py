"""Clustered Head Attention — the paper's core op (decode path).

Score computation + softmax run only for representative heads; attention
weights broadcast to member heads via a gather; V stays per-head
(paper Table 4: pruning V loses accuracy; ``share_values`` implements the
CHAI-QKV ablation).

MHA archs additionally store a *clustered K cache* (k_max rows instead of
H) — the paper's 21.4% KV-memory saving. GQA archs keep the per-group K
cache (DESIGN.md §4) and get the compute-only saving.

The attention math itself runs as ONE fused Pallas launch per decode step
(``repro.kernels.ops.chai_decode_attention`` /
``paged_chai_decode_attention``): online-softmax clustered scores +
h2c-broadcast AV, streaming dense tiles or block-table pages through VMEM
with in-kernel int8 dequant — no (B, R, S) score tensor and, on the paged
layout, no densifying page gather. ``decode_ts`` (the engine passes its
page size) pins the dense tile size to the paged page size so every KV
layout performs bit-identical arithmetic (cross-layout greedy parity).
The pure-jnp math is kept as the fallback for shapes the kernel does not
cover (attention logit softcap, local ring caches) and as the reference
path (``USE_FUSED_DECODE = False``). Every int8 layout — dense and paged,
MHA and GQA — carries a real per-(row, position) scale gather; the fused
dispatch passes scales unconditionally (the historical dense-GQA
code-reinterpret corner is gone).

ctx arrays may be shared across the batch (ndim without B) or per-request
(batched) — see repro.core.clustering.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models.layers import apply_rope, rms_norm, softcap

# Module switch: tests flip this to pin fused-vs-jnp token parity; the
# engine honors it at trace time (each ServingEngine builds fresh jits).
USE_FUSED_DECODE = True


def _rope1(x, pos, theta):
    """x: (B, n, hd) single-token heads; pos: (B,)."""
    return apply_rope(x[:, None], pos[:, None], theta)[:, 0]


def _qk_norm(x, scale, cfg):
    return rms_norm(x, scale, cfg.norm_eps) if cfg.qk_norm else x


def chai_decode_attention(xn, p, cfg, state, idxs, chai_ctx, *, local,
                          write_mask=None, decode_ts=0, relay=None):
    """xn: (B, d) normed hidden. Returns (out (B, H, hd), new_state).

    ``write_mask`` (B,) bool: cache rows are committed only for masked
    slots (the mixed-phase continuous step runs this path alongside the
    plain MHA path on one batch). ``decode_ts``: S-tile size for the
    fused dense kernel (0 = whole sequence; the engine passes its page
    size so dense and paged layouts tile identically).

    ``relay`` (shared-prefix relay decode, paged+fused layouts): pytree
    of group-batched arrays — see ``_relay_prefix_state`` for the
    layout. Grouped slots' fused decode runs SUFFIX-ONLY (rolled block
    tables + shifted ``pos``) with ``emit_state=True``, one
    group-batched prefix pass runs per layer over the resident copy of
    the shared pages, and the two (m, l, acc) triples merge by
    online-softmax combine before the finalize. Non-grouped slots carry
    the empty prefix state — the exact merge identity. The jnp fallback
    ignores ``relay`` harmlessly: block tables still hold the prefix
    pages, so the densified full-attention math is already complete."""
    if cfg.is_mha and not local:
        return _chai_mha_decode(xn, p, cfg, state, idxs, chai_ctx,
                                write_mask, decode_ts=decode_ts,
                                relay=relay)
    if not cfg.is_mha:
        return _chai_gqa_decode(xn, p, cfg, state, idxs, chai_ctx,
                                local=local, write_mask=write_mask,
                                decode_ts=decode_ts, relay=relay)
    # MHA arch with a local layer (none of the assigned archs hit this):
    from repro.models.transformer import _plain_decode_attention
    return _plain_decode_attention(xn, p, cfg, state, idxs, local=local,
                                   write_mask=write_mask)


def _fused_ok(cfg):
    """The fused kernel covers everything the engine serves — the
    gemma2-style attention-logit softcap is applied in-kernel between
    QK-scale and the online-softmax update (static ``softcap`` flag)."""
    return USE_FUSED_DECODE


def _dense_ts(decode_ts, s):
    return decode_ts if decode_ts and s % decode_ts == 0 else s


def _layer_ctx(chai_ctx, attn_idx):
    return jax.tree.map(
        lambda a: jax.lax.dynamic_index_in_dim(a, attn_idx, 0,
                                               keepdims=False), chai_ctx)


# ------------------------------------------------- shared-prefix relay -----
def _roll_bt(bt, shift):
    """Rotate each slot's block-table row left by ``shift`` pages, so the
    kernel's logical page 0 is the slot's first PRIVATE (post-prefix)
    page. With ``pos`` shifted down by the prefix length, the wrapped
    prefix entries reappearing at the tail sit at token indices
    > pos - plen and are masked by the kernel's validity test."""
    p = bt.shape[1]
    idx = (jnp.arange(p, dtype=jnp.int32)[None, :] + shift[:, None]) % p
    return jnp.take_along_axis(bt, idx, axis=1)


def _relay_prefix_state(relay, idxs, q_rep, *, acc_rows, use_v_scale,
                        softcap, ts):
    """One group-batched prefix-attention pass for this layer, scattered
    back to batch rows as an unfinalized (m, l, acc) online-softmax
    state.

    ``relay`` layout (engine-built, host-side):
      k / v            (nG, G, KV/VR, Sp, hd)  resident copies of the
                       shared dense pages (one contiguous view per group)
      k_scale/v_scale  (nG, G, rows, Sp)       int8 scales (optional)
      plen             (G,)                    shared prefix length
      members          (G, Nmax)               slot index per member
      k_row/a_row/v_row (nA, G, NR / A)        per-layer row-routing maps
                       (rep -> K row, acc row -> score row, acc row ->
                       V row) — this is where the h2c broadcast lives,
                       deferred out of the prefix compute
      gid/midx/len     (B,)                    slot -> (group, member)
      in_group         (B,) bool               grouped-slot mask

    Non-members scatter the empty state (m = NEG_INF, l = acc = 0) —
    the exact bitwise merge identity, so ungrouped slots pass through
    the merge unchanged. ``use_v_scale=False`` rides share_values int8
    V codes scale-less, mirroring the clustered-pool reinterpret."""
    from repro.kernels import ops as kops
    from repro.kernels.chai_attention import NEG_INF
    from repro.models.transformer import tree_index
    kp = tree_index(relay["k"], idxs["global"])
    vp = tree_index(relay["v"], idxs["global"])
    ks = (tree_index(relay["k_scale"], idxs["global"])
          if "k_scale" in relay else None)
    vs = (tree_index(relay["v_scale"], idxs["global"])
          if use_v_scale and "v_scale" in relay else None)
    k_row = tree_index(relay["k_row"], idxs["attn"])
    a_row = tree_index(relay["a_row"], idxs["attn"])
    v_row = tree_index(relay["v_row"], idxs["attn"])
    g, nmax = relay["members"].shape
    _, r, hd = q_rep.shape
    qg = q_rep[relay["members"]].reshape(g, nmax * r, hd)
    m, l, acc = kops.relay_prefix_attention(
        qg, kp, vp, k_row, a_row, v_row, relay["plen"],
        k_scale=ks, v_scale=vs, ts=ts, softcap=softcap)
    gid, midx, ing = relay["gid"], relay["midx"], relay["in_group"]
    m_pb = jnp.where(ing[:, None], m.reshape(g, nmax, r)[gid, midx],
                     NEG_INF)
    l_pb = jnp.where(ing[:, None], l.reshape(g, nmax, r)[gid, midx], 0.0)
    acc_pb = jnp.where(ing[:, None, None],
                       acc.reshape(g, nmax, acc_rows, hd)[gid, midx], 0.0)
    return m_pb, l_pb, acc_pb


# ---------------------------------------------------------------- MHA ------
def _chai_mha_decode(xn, p, cfg, state, idxs, chai_ctx, write_mask=None, *,
                     decode_ts=0, relay=None):
    from repro.models.transformer import _masked_rows, tree_index, \
        tree_update
    b, d = xn.shape
    ar = jnp.arange(b)
    hd, h = cfg.head_dim, cfg.n_heads
    pos = state["pos"]
    ctx = _layer_ctx(chai_ctx, idxs["attn"])
    reps, h2c = ctx["reps"], ctx["h2c"]
    batched = reps.ndim == 2                      # (B, k) vs (k,)
    share_v = cfg.chai.share_values

    if batched:
        # Per-request membership: project all heads, gather activations.
        q = jnp.einsum("bd,dhe->bhe", xn, p["wq"])
        k = jnp.einsum("bd,dhe->bhe", xn, p["wk"])
        if cfg.qk_norm:
            q = _qk_norm(q, p["q_norm"], cfg)
            k = _qk_norm(k, p["k_norm"], cfg)
        q_rep = jnp.take_along_axis(q, reps[..., None], axis=1)
        k_rep = jnp.take_along_axis(k, reps[..., None], axis=1)
    else:
        # Shared membership: gather weight rows (skips pruned projections —
        # the paper's full compute saving).
        wq_r = jnp.take(p["wq"], reps, axis=1)    # (d, k, hd)
        wk_r = jnp.take(p["wk"], reps, axis=1)
        q_rep = jnp.einsum("bd,dke->bke", xn, wq_r)
        k_rep = jnp.einsum("bd,dke->bke", xn, wk_r)
        if cfg.qk_norm:
            q_rep = _qk_norm(q_rep, p["q_norm"], cfg)
            k_rep = _qk_norm(k_rep, p["k_norm"], cfg)
    q_rep = _rope1(q_rep, pos, cfg.rope_theta)
    k_rep = _rope1(k_rep, pos, cfg.rope_theta)

    int8 = cfg.kv_cache_dtype == "int8"
    if int8:
        from repro.core.cache import dequant_rows, quant_rows
    paged = "cp" in state
    if paged:
        from repro.core.cache import gather_pages
        from repro.models.transformer import (_paged_write_rows,
                                              paged_token_coords)
        mask = functools.partial(_masked_rows, write_mask)

    # Clustered K cache update (k rows, not H). The fused kernel reads
    # the raw (possibly int8) buffers directly, so the dequantized /
    # page-gathered dense views are only built on the jnp fallback path.
    ksc = csc = None
    if paged:
        cp = tree_index(state["cp"], idxs["global"])      # (nP, k, page, hd)
        page = cp.shape[2]
        pk, row = paged_token_coords(state["bt_kc"], pos, page)
        if int8:
            kq, ks = quant_rows(k_rep)
            cp = _paged_write_rows(cp, pk, row, kq, mask)
            csc = tree_index(state["cp_scale"], idxs["global"])
            csc = _paged_write_rows(csc, pk, row, ks, mask)
        else:
            cp = _paged_write_rows(cp, pk, row, k_rep, mask)
    else:
        kc = tree_index(state["kg_chai"], idxs["global"])   # (B, k, S, hd)
        if int8:
            kq, ks = quant_rows(k_rep)
            kc = kc.at[ar, :, pos, :].set(
                _masked_rows(write_mask, kq, kc[ar, :, pos, :]))
            ksc = tree_index(state["kg_chai_scale"], idxs["global"])
            ksc = ksc.at[ar, :, pos].set(
                _masked_rows(write_mask, ks, ksc[ar, :, pos]))
        else:
            kc = kc.at[ar, :, pos, :].set(
                _masked_rows(write_mask, k_rep.astype(kc.dtype),
                             kc[ar, :, pos, :]))

    # V: full per-head (or clustered for the CHAI-QKV ablation).
    vsc = vsp = None
    if share_v:
        if batched:
            v = jnp.einsum("bd,dhe->bhe", xn, p["wv"])
            v_new = jnp.take_along_axis(v, reps[..., None], axis=1)
        else:
            wv_r = jnp.take(p["wv"], reps, axis=1)
            v_new = jnp.einsum("bd,dke->bke", xn, wv_r)
        if paged:
            # Clustered V pages live in the same cp pool (scale-less,
            # mirroring the unified vg_chai gather).
            pv, vrow = paged_token_coords(state["bt_vc"], pos, page)
            cp = _paged_write_rows(cp, pv, vrow, v_new, mask)
        else:
            vc = tree_index(state["vg_chai"], idxs["global"])
            vc = vc.at[ar, :, pos, :].set(
                _masked_rows(write_mask, v_new.astype(vc.dtype),
                             vc[ar, :, pos, :]))
    else:
        v_new = jnp.einsum("bd,dhe->bhe", xn, p["wv"])
        if paged:
            vp = tree_index(state["kvp"], idxs["global"])
            pv, vrow = paged_token_coords(state["bt_vg"], pos, page)
            if int8:
                vq, vs = quant_rows(v_new)
                vp = _paged_write_rows(vp, pv, vrow, vq, mask)
                vsp = tree_index(state["kvp_scale"], idxs["global"])
                vsp = _paged_write_rows(vsp, pv, vrow, vs, mask)
            else:
                vp = _paged_write_rows(vp, pv, vrow, v_new, mask)
        else:
            vc = tree_index(state["vg"], idxs["global"])
            if int8:
                vq, vs = quant_rows(v_new)
                vc = vc.at[ar, :, pos, :].set(
                    _masked_rows(write_mask, vq, vc[ar, :, pos, :]))
                vsc = tree_index(state["vg_scale"], idxs["global"])
                vsc = vsc.at[ar, :, pos].set(
                    _masked_rows(write_mask, vs, vsc[ar, :, pos]))
            else:
                vc = vc.at[ar, :, pos, :].set(
                    _masked_rows(write_mask, v_new.astype(vc.dtype),
                                 vc[ar, :, pos, :]))

    gather_idx = h2c if batched else jnp.broadcast_to(h2c, (b, h))
    if _fused_ok(cfg):
        # One fused Pallas launch: scores + online softmax + h2c AV.
        from repro.kernels import ops as kops
        cap = float(cfg.attn_logit_softcap or 0.0)
        if paged:
            relay_on = relay is not None
            bt_kc = state["bt_kc"]
            bt_v = state["bt_vc"] if share_v else state["bt_vg"]
            pos_k = pos
            if relay_on:
                # Suffix-only fused decode: rolled tables + shifted pos
                # drop the prefix pages from this launch; the group-
                # batched prefix pass below covers them once per group.
                shift = relay["len"] // page
                bt_kc = _roll_bt(bt_kc, shift)
                bt_v = _roll_bt(bt_v, shift)
                pos_k = pos - relay["len"]
            if share_v:
                out = kops.paged_chai_decode_attention(
                    q_rep, cp, bt_kc, cp, bt_v,
                    gather_idx, pos_k, k_scale_pool=csc, share_values=True,
                    softcap=cap, emit_state=relay_on)
            else:
                out = kops.paged_chai_decode_attention(
                    q_rep, cp, bt_kc, vp, bt_v,
                    gather_idx, pos_k, k_scale_pool=csc, v_scale_pool=vsp,
                    softcap=cap, emit_state=relay_on)
            if relay_on:
                pref = _relay_prefix_state(
                    relay, idxs, q_rep,
                    acc_rows=q_rep.shape[1] if share_v else h,
                    use_v_scale=not share_v, softcap=cap, ts=decode_ts)
                out = kops.finalize_decode_state(
                    kops.merge_decode_states(out, pref, gather_idx,
                                             share_values=share_v),
                    gather_idx, share_values=share_v)
        else:
            out = kops.chai_decode_attention(
                q_rep, kc, vc, gather_idx, pos, k_scale=ksc, v_scale=vsc,
                share_values=share_v,
                ts=_dense_ts(decode_ts, kc.shape[2]), softcap=cap)
    else:
        # jnp fallback (softcap configs / reference path): densify and
        # dequantize, then the pre-fusion three-step math.
        if paged:
            kc_f = gather_pages(cp, state["bt_kc"])
            if int8:
                kc_f = dequant_rows(kc_f, gather_pages(csc,
                                                       state["bt_kc"]))
            if share_v:
                vc_f = gather_pages(cp, state["bt_vc"])
            else:
                vc_f = gather_pages(vp, state["bt_vg"])
                if int8:
                    vc_f = dequant_rows(vc_f, gather_pages(
                        vsp, state["bt_vg"]))
        else:
            kc_f = dequant_rows(kc, ksc) if int8 else kc
            if share_v:
                vc_f = vc
            else:
                vc_f = dequant_rows(vc, vsc) if int8 else vc
        s = kc_f.shape[2]
        scale = 1.0 / math.sqrt(hd)
        sc = jnp.einsum("bke,bkse->bks", q_rep.astype(jnp.float32),
                        kc_f.astype(jnp.float32)) * scale
        sc = softcap(sc, cfg.attn_logit_softcap)
        kv_pos = jnp.arange(s, dtype=jnp.int32)
        valid = kv_pos[None, :] <= pos[:, None]
        sc = jnp.where(valid[:, None, :], sc, attn_mod.NEG_INF)
        a = jax.nn.softmax(sc, axis=-1)                     # (B, k, S)

        if share_v:
            out_rep = jnp.einsum("bks,bksd->bkd", a,
                                 vc_f.astype(jnp.float32))
            out = jnp.take_along_axis(out_rep, gather_idx[..., None],
                                      axis=1)
        else:
            a_full = jnp.take_along_axis(a, gather_idx[..., None], axis=1)
            out = jnp.einsum("bhs,bhsd->bhd", a_full,
                             vc_f.astype(jnp.float32))

    state = dict(state)
    if paged:
        state["cp"] = tree_update(state["cp"], idxs["global"], cp)
        if int8:
            state["cp_scale"] = tree_update(state["cp_scale"],
                                            idxs["global"], csc)
        if not share_v:
            state["kvp"] = tree_update(state["kvp"], idxs["global"], vp)
            if int8:
                state["kvp_scale"] = tree_update(state["kvp_scale"],
                                                 idxs["global"], vsp)
    else:
        state["kg_chai"] = tree_update(state["kg_chai"], idxs["global"], kc)
        if int8:
            state["kg_chai_scale"] = tree_update(state["kg_chai_scale"],
                                                 idxs["global"], ksc)
            if not share_v:
                state["vg_scale"] = tree_update(state["vg_scale"],
                                                idxs["global"], vsc)
        if share_v:
            state["vg_chai"] = tree_update(state["vg_chai"], idxs["global"],
                                           vc)
        else:
            state["vg"] = tree_update(state["vg"], idxs["global"], vc)
    return out.astype(xn.dtype), state


# ---------------------------------------------------------------- GQA ------
def _chai_gqa_decode(xn, p, cfg, state, idxs, chai_ctx, *, local,
                     write_mask=None, decode_ts=0, relay=None):
    from repro.models.transformer import _masked_rows, tree_index, \
        tree_update
    b, d = xn.shape
    ar = jnp.arange(b)
    hd, h, n_kv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    qpk = cfg.q_per_kv
    pos = state["pos"]
    ctx = _layer_ctx(chai_ctx, idxs["attn"])
    reps, cluster_of = ctx["reps"], ctx["cluster_of"]   # (.., KV, r/qpk)
    batched = reps.ndim == 3
    r = reps.shape[-1]

    if batched:
        q = jnp.einsum("bd,dhe->bhe", xn, p["wq"]).reshape(b, n_kv, qpk, hd)
        if cfg.qk_norm:
            q = _qk_norm(q, p["q_norm"], cfg)
        q_rep = jnp.take_along_axis(q, reps[..., None], axis=2)
    else:
        wq_g = p["wq"].reshape(d, n_kv, qpk, hd)
        idx = jnp.broadcast_to(reps[None, ..., None], (d, n_kv, r, hd))
        wq_r = jnp.take_along_axis(wq_g, idx, axis=2)   # (d, KV, r, hd)
        q_rep = jnp.einsum("bd,dkre->bkre", xn, wq_r)
        if cfg.qk_norm:
            q_rep = _qk_norm(q_rep, p["q_norm"], cfg)
    q_rep = apply_rope(q_rep.reshape(b, 1, n_kv * r, hd),
                       pos[:, None], cfg.rope_theta).reshape(b, n_kv, r, hd)

    # K/V: per-group projections unchanged (no K saving for GQA).
    k_new = jnp.einsum("bd,dke->bke", xn, p["wk"])
    if cfg.qk_norm:
        k_new = _qk_norm(k_new, p["k_norm"], cfg)
    k_new = _rope1(k_new, pos, cfg.rope_theta)
    v_new = jnp.einsum("bd,dke->bke", xn, p["wv"])

    paged = not local and "kvp" in state
    # Fused one-launch decode covers the global paths; the local ring
    # cache keeps the jnp math (ring-ordered kv positions). The dense
    # GQA int8 layout carries a real per-row scale gather exactly like
    # the paged path (the historical no-scales code-reinterpret corner
    # is gone), so the fused dispatch passes scales everywhere.
    fused = _fused_ok(cfg) and not local
    int8 = cfg.kv_cache_dtype == "int8"

    def _flat_qrep_h2c():
        gather_idx = (cluster_of if batched
                      else jnp.broadcast_to(cluster_of, (b, n_kv, qpk)))
        q_flat = q_rep.reshape(b, n_kv * r, hd)
        h2c_flat = (jnp.arange(n_kv, dtype=jnp.int32)[None, :, None] * r
                    + gather_idx).reshape(b, h)
        return q_flat, h2c_flat

    if local:
        w = state["kl"].shape[3]
        kc = tree_index(state["kl"], idxs["local"])
        vc = tree_index(state["vl"], idxs["local"])
        slot = jnp.mod(pos, w)
        kc = kc.at[ar, :, slot, :].set(
            _masked_rows(write_mask, k_new.astype(kc.dtype),
                         kc[ar, :, slot, :]))
        vc = vc.at[ar, :, slot, :].set(
            _masked_rows(write_mask, v_new.astype(vc.dtype),
                         vc[ar, :, slot, :]))
        kv_pos = jax.vmap(lambda pp: attn_mod.ring_positions(pp + 1, w))(pos)
        window = cfg.window_size
        kc_f, vc_f = kc, vc     # local rings are never quantized
    elif paged:
        # GQA paged: K and V stay page-resident in the dense pool for the
        # whole request (no clustered cache — compute-only saving).
        from repro.models.transformer import (_paged_global_write,
                                              _paged_global_update)
        if fused:
            state, pool, spool = _paged_global_write(
                state, idxs, k_new, v_new, pos, write_mask, cfg)
            q_flat, h2c_flat = _flat_qrep_h2c()
            from repro.kernels import ops as kops
            cap = float(cfg.attn_logit_softcap or 0.0)
            relay_on = relay is not None
            bt_kg, bt_vg, pos_k = state["bt_kg"], state["bt_vg"], pos
            if relay_on:
                shift = relay["len"] // pool.shape[2]
                bt_kg = _roll_bt(bt_kg, shift)
                bt_vg = _roll_bt(bt_vg, shift)
                pos_k = pos - relay["len"]
            out = kops.paged_chai_decode_attention(
                q_flat, pool, bt_kg, pool, bt_vg,
                h2c_flat, pos_k, k_scale_pool=spool, v_scale_pool=spool,
                reps_per_group=r, softcap=cap, emit_state=relay_on)
            if relay_on:
                pref = _relay_prefix_state(relay, idxs, q_flat,
                                           acc_rows=h, use_v_scale=True,
                                           softcap=cap, ts=decode_ts)
                out = kops.finalize_decode_state(
                    kops.merge_decode_states(out, pref, h2c_flat),
                    h2c_flat)
            return out.astype(xn.dtype), state
        state, kc, vc = _paged_global_update(state, idxs, k_new, v_new,
                                             pos, write_mask, cfg)
        s = kc.shape[2]
        kv_pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        window = 0
        kc_f, vc_f = kc, vc     # already dequantized dense views
    else:
        s = state["kg"].shape[3]
        kc = tree_index(state["kg"], idxs["global"])
        vc = tree_index(state["vg"], idxs["global"])
        ksc = vsc = None
        if int8:
            from repro.core.cache import dequant_rows, quant_rows
            kq, ks = quant_rows(k_new)
            vq, vs = quant_rows(v_new)
            kc = kc.at[ar, :, pos, :].set(
                _masked_rows(write_mask, kq, kc[ar, :, pos, :]))
            vc = vc.at[ar, :, pos, :].set(
                _masked_rows(write_mask, vq, vc[ar, :, pos, :]))
            ksc = tree_index(state["kg_scale"], idxs["global"])
            vsc = tree_index(state["vg_scale"], idxs["global"])
            ksc = ksc.at[ar, :, pos].set(
                _masked_rows(write_mask, ks, ksc[ar, :, pos]))
            vsc = vsc.at[ar, :, pos].set(
                _masked_rows(write_mask, vs, vsc[ar, :, pos]))
        else:
            kc = kc.at[ar, :, pos, :].set(
                _masked_rows(write_mask, k_new.astype(kc.dtype),
                             kc[ar, :, pos, :]))
            vc = vc.at[ar, :, pos, :].set(
                _masked_rows(write_mask, v_new.astype(vc.dtype),
                             vc[ar, :, pos, :]))
        kv_pos = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32), (b, s))
        window = 0

        def _commit_dense(state):
            state = dict(state)
            state["kg"] = tree_update(state["kg"], idxs["global"], kc)
            state["vg"] = tree_update(state["vg"], idxs["global"], vc)
            if int8:
                state["kg_scale"] = tree_update(state["kg_scale"],
                                                idxs["global"], ksc)
                state["vg_scale"] = tree_update(state["vg_scale"],
                                                idxs["global"], vsc)
            return state

        if fused:
            q_flat, h2c_flat = _flat_qrep_h2c()
            from repro.kernels import ops as kops
            out = kops.chai_decode_attention(
                q_flat, kc, vc, h2c_flat, pos, k_scale=ksc, v_scale=vsc,
                reps_per_group=r, ts=_dense_ts(decode_ts, s),
                softcap=float(cfg.attn_logit_softcap or 0.0))
            return out.astype(xn.dtype), _commit_dense(state)
        if int8:
            kc_f, vc_f = dequant_rows(kc, ksc), dequant_rows(vc, vsc)
        else:
            kc_f, vc_f = kc, vc

    scale = 1.0 / math.sqrt(hd)
    sc = jnp.einsum("bkre,bkse->bkrs", q_rep.astype(jnp.float32),
                    kc_f.astype(jnp.float32)) * scale
    sc = softcap(sc, cfg.attn_logit_softcap)
    valid = (kv_pos >= 0) & (kv_pos <= pos[:, None])
    if window:
        valid &= (pos[:, None] - kv_pos) < window
    sc = jnp.where(valid[:, None, None, :], sc, attn_mod.NEG_INF)
    a = jax.nn.softmax(sc, axis=-1)                     # (B, KV, r, S)

    gather_idx = (cluster_of if batched
                  else jnp.broadcast_to(cluster_of, (b, n_kv, qpk)))
    a_full = jnp.take_along_axis(a, gather_idx[..., None], axis=2)
    out = jnp.einsum("bkgs,bksd->bkgd", a_full, vc_f.astype(jnp.float32))
    out = out.reshape(b, h, hd)

    if local:
        state = dict(state)
        state["kl"] = tree_update(state["kl"], idxs["local"], kc)
        state["vl"] = tree_update(state["vl"], idxs["local"], vc)
    elif not paged:     # paged: _paged_global_update already committed
        state = _commit_dense(state)
    return out.astype(xn.dtype), state
