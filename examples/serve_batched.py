"""End-to-end driver: continuous-batching CHAI serving under Poisson load.

Trains a small model on the synthetic corpus (so generations are
meaningful), then serves the SAME Poisson-arrival workload (exponential
inter-arrival gaps, mixed output lengths) through:

  * the slot-level ``continuous`` scheduler (per-slot CHAI phase machine,
    slots admitted/retired independently), and
  * the legacy ``cohort`` scheduler (lockstep phases; head-of-line
    blocking by the longest request in each cohort),

reporting per-request TTFT and request throughput for each, plus the
CHAI-vs-MHA comparison (KV bytes, greedy-token agreement).

  PYTHONPATH=src python examples/serve_batched.py
"""
import time

import numpy as np

from repro.configs.base import get_config, reduced
from repro.data.pipeline import DataConfig
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.workload import poisson_workload
from repro.train.trainer import Trainer, TrainerConfig


def make_workload(pipe, *, n_req=12, prompt_len=24, mean_gap_s=0.02,
                  new_tokens=(8, 128), seed=0):
    """(arrival_delay, prompt, max_new) tuples — the shared mixed-length
    Poisson distribution (repro.serving.workload) with prompts from the
    synthetic corpus."""
    rng = np.random.default_rng(seed)
    arrivals, lens = poisson_workload(rng, n_req, mean_gap_s=mean_gap_s,
                                      new_tokens=new_tokens)
    return [(float(arrivals[i]),
             pipe.batch(2000 + i)["tokens"][0, :prompt_len],
             int(lens[i]))
            for i in range(n_req)]


def serve(cfg, params, workload, *, scheduler, use_chai, slots=6,
          max_seq=192):
    eng = ServingEngine(cfg, params,
                        EngineConfig(batch_slots=slots, max_seq=max_seq,
                                     scheduler=scheduler,
                                     use_chai=use_chai))
    # Two identical passes: the first warms every jit so the reported
    # numbers reflect steady-state serving, not XLA compile time.
    for _ in (0, 1):
        t0 = time.time()
        batch = [eng.submit(prompt, max_new_tokens=max_new, uid=i,
                            arrival_delay=delay)
                 for i, (delay, prompt, max_new) in enumerate(workload)]
        steps0 = eng.steps_executed
        eng.run()
        wall = time.time() - t0
    ttfts = np.array([r.ttft for r in batch])
    n_tok = sum(len(r.generated) for r in batch)
    span = max(r.t_done for r in batch) - min(r.t_arrival for r in batch)
    return {
        "gen": {r.uid: r.generated for r in batch},
        "wall_s": wall,
        "req_per_s": len(batch) / span,
        "tok_per_s": n_tok / wall,
        "ttft_ms_mean": 1e3 * float(ttfts.mean()),
        "ttft_ms_p95": 1e3 * float(np.percentile(ttfts, 95)),
        # paged engines drain their pools on retire, so the footprint is
        # the run's high-water allocated-page bytes; dense layouts report
        # their constant residency
        "kv_bytes": int(eng.kv_bytes_peak() if eng.paged
                        else eng.kv_bytes()),
        "kv_steady": int(eng.kv_bytes(chai=eng.chai_on)),   # analytic
        "decode_steps": eng.steps_executed - steps0,
    }


def main():
    cfg = reduced(get_config("chai-llama-7b"), n_layers=2, d_model=64,
                  n_heads=8, d_ff=128, vocab=256).replace(dtype="float32")
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)
    print("training a small LM on the synthetic corpus ...")
    tr = Trainer(cfg, data, TrainerConfig(
        total_steps=80, ckpt_every=10**9, log_every=40,
        ckpt_dir="/tmp/serve_batched_ckpt",
        lr_kw=dict(peak=3e-3, warmup=8, total=80)))
    state, metrics = tr.run()
    params = state["params"]
    cfg_chai = cfg.with_chai(enabled=True,
                             cluster_counts=(5,) * cfg.n_attn_layers)
    workload = make_workload(tr.pipe)

    print("\nserving the Poisson workload: continuous scheduler ...")
    cont = serve(cfg_chai, params, workload, scheduler="continuous",
                 use_chai=True)
    print("serving the Poisson workload: cohort scheduler ...")
    coh = serve(cfg_chai, params, workload, scheduler="cohort",
                use_chai=True)
    print("serving the Poisson workload: continuous, CHAI off ...")
    mha = serve(cfg, params, workload, scheduler="continuous",
                use_chai=False)

    keys = ("wall_s", "req_per_s", "tok_per_s", "ttft_ms_mean",
            "ttft_ms_p95", "kv_bytes")
    print(f"\n{'':14}{'continuous':>12}{'cohort':>12}{'cont-MHA':>12}")
    for key in keys:
        print(f"{key:14}{cont[key]:>12.2f}{coh[key]:>12.2f}"
              f"{mha[key]:>12.2f}")

    agree_sched = np.mean([np.mean(np.asarray(cont["gen"][u]) ==
                                   np.asarray(coh["gen"][u]))
                           for u in cont["gen"]])
    agree_chai = np.mean([np.mean(np.asarray(cont["gen"][u]) ==
                                  np.asarray(mha["gen"][u]))
                          for u in cont["gen"]])
    print(f"\ntoken parity continuous vs cohort:   {agree_sched:.1%}")
    print(f"greedy-token agreement CHAI vs MHA:  {agree_chai:.1%}")
    # steady-state analytic saving; the continuous engine's paged layout
    # realizes it at the allocator level too (kv_bytes row = peak
    # allocated-page bytes, which drops as dense pages free at
    # compaction)
    print(f"KV saving (CHAI vs MHA, steady):     "
          f"{1 - coh['kv_steady'] / mha['kv_steady']:.1%}")
    print(f"throughput gain continuous/cohort:   "
          f"{cont['req_per_s'] / coh['req_per_s']:.2f}x")


if __name__ == "__main__":
    main()
