"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), TPU v5e constants:
  compute    = per_device_HLO_FLOPs / peak_FLOPs_per_chip
  memory     = per_device_HLO_bytes / HBM_bw_per_chip
  collective = per_device_collective_bytes / ICI_link_bw

cost_analysis() is per-device for SPMD executables (verified empirically),
so per-chip division is already done. Collective bytes are parsed from the
post-optimization HLO text: for each all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute instruction we count
max(input_bytes, output_bytes) — the wire-side size of the transfer.
"""
from __future__ import annotations

import re
from dataclasses import dataclass

# TPU v5e per-chip constants (from the assignment).
PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
# matches e.g.:  %foo = (bf16[2,3]{1,0}, ...) all-reduce(...)
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str):
    """Per-device bytes moved by collectives + per-kind breakdown.

    ``-done`` ops carry the same shape as their ``-start``; count starts
    (and plain sync ops) only.
    """
    per_kind = {k: 0 for k in _COLLECTIVES}
    count = 0
    for m in _INSTR_RE.finditer(hlo_text):
        out_shape, kind = m.group(1), m.group(2)
        line = m.group(0)
        if "-done(" in line:
            continue
        # operand shapes: everything inside the call parens on this line
        line_end = hlo_text.find("\n", m.end())
        operands = hlo_text[m.end():line_end if line_end > 0 else None]
        in_bytes = _shape_bytes(operands)
        out_bytes = _shape_bytes(out_shape)
        per_kind[kind] += max(in_bytes, out_bytes)
        count += 1
    return sum(per_kind.values()), per_kind, count


@dataclass
class Roofline:
    flops_per_dev: float
    bytes_per_dev: float
    coll_bytes_per_dev: float
    coll_breakdown: dict
    n_collectives: int

    @property
    def t_compute(self):
        return self.flops_per_dev / PEAK_FLOPS

    @property
    def t_memory(self):
        return self.bytes_per_dev / HBM_BW

    @property
    def t_collective(self):
        return self.coll_bytes_per_dev / ICI_BW

    @property
    def bottleneck(self):
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_max(self):
        return max(self.t_compute, self.t_memory, self.t_collective)

    def as_dict(self):
        return {
            "flops_per_dev": self.flops_per_dev,
            "bytes_per_dev": self.bytes_per_dev,
            "coll_bytes_per_dev": self.coll_bytes_per_dev,
            "coll_breakdown": self.coll_breakdown,
            "n_collectives": self.n_collectives,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
        }


def analyze(compiled) -> Roofline:
    ca = compiled.cost_analysis()
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    cb, breakdown, n = collective_bytes(compiled.as_text())
    return Roofline(flops, byts, float(cb), breakdown, n)


def model_flops(cfg, shape):
    """Analytic MODEL_FLOPS: 6*N*D train, 2*N*D per generated/processed
    token at inference (N = active params)."""
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch   # decode: 1 token/request
