"""Benchmark harness: one bench per paper table/figure.

  python -m benchmarks.run            # all benches
  python -m benchmarks.run --only bench_kv_memory,bench_flops
  python -m benchmarks.run --list     # available bench names

Each bench saves JSON under benchmarks/results/ and returns a dict with a
``claim_check`` section verifying the paper's claims (or their CPU-proxy
analogues — labeled). The end-of-run summary is printed AND written to
benchmarks/results/summary.json (CI uploads results/*.json as artifacts).
Exit code is non-zero if any claim check fails.
"""
from __future__ import annotations

import argparse
import importlib
import json
import os
import time
import traceback

BENCHES = {
    "bench_accuracy_proxy": "Tables 1-3 (greedy agreement, logit fidelity)",
    "bench_qkv_ablation": "Table 4 (CHAI-QKV share_values ablation)",
    "bench_flops": "Figs 1/14 (attention FLOP ratios)",
    "bench_elbow": "Fig 8 (per-layer elbow cluster counts)",
    "bench_membership": "Fig 9 (membership churn)",
    "bench_kv_memory": "Fig 11 + paged-allocator lane",
    "bench_latency": "Fig 12 + scheduler / fused-kernel / prefix_reuse "
                     "lanes",
    "bench_cluster_dist": "Fig 13 (cluster size distribution)",
    "bench_fault_soak": "robustness lane (seeded fault soak, deep audit)",
    "bench_telemetry_overhead": "observability lane (tier cost contract, "
                                "trace/metrics export round-trips)",
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated bench names (default: all)")
    ap.add_argument("--list", action="store_true",
                    help="print available bench names and exit")
    args = ap.parse_args(argv)
    if args.list:
        for name, desc in BENCHES.items():
            print(f"{name:24s} {desc}")
        return 0
    names = args.only.split(",") if args.only else list(BENCHES)

    failures, summaries = [], {}
    for name in names:
        t0 = time.time()
        print(f"=== {name} ===", flush=True)
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            result = mod.run()
            checks = result.get("claim_check", {})
            bad = {k: v for k, v in checks.items()
                   if isinstance(v, bool) and not v}
            status = "ok" if not bad else f"CLAIM-FAIL {sorted(bad)}"
            if bad:
                failures.append(name)
            summaries[name] = {"status": status, "checks": checks,
                               "seconds": round(time.time() - t0, 1)}
            print(f"  {status} ({summaries[name]['seconds']}s)")
            for k, v in checks.items():
                print(f"    {k}: {v}")
        except Exception as e:
            failures.append(name)
            summaries[name] = {"status": f"ERROR {e}"}
            traceback.print_exc()
    print("\n=== summary ===")
    print(json.dumps(summaries, indent=1, default=str))
    results_dir = os.path.join(os.path.dirname(__file__), "results")
    os.makedirs(results_dir, exist_ok=True)
    summary_path = os.path.join(results_dir, "summary.json")
    # A partial (--only) run MERGES into the existing summary so it
    # cannot silently drop the other benches' recorded claim checks;
    # a full run replaces it. Exit code reflects THIS run only.
    if args.only and os.path.exists(summary_path):
        try:
            with open(summary_path) as f:
                merged = json.load(f).get("benches", {})
        except (json.JSONDecodeError, OSError):
            merged = {}
        merged.update(summaries)
        summaries = merged
    # The artifact's failures field must describe EVERY recorded entry
    # (merged ones included), not just this invocation's.
    all_failures = sorted(n for n, s in summaries.items()
                          if not str(s.get("status", "")).startswith("ok"))
    with open(summary_path, "w") as f:
        json.dump({"benches": summaries, "failures": all_failures},
                  f, indent=1, default=str)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
