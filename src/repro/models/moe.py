"""Mixture-of-Experts FFN: top-k routing, capacity-based scatter dispatch.

TPU-idiomatic "dropping" MoE (GShard/MaxText style): tokens are scattered
into an (E, C, d) buffer (C = capacity), expert FFNs run as a single batched
einsum over the expert dim (shardable on the "model"/expert-parallel axis),
and results gather back with combine weights. Tokens over capacity drop to
the residual path. Includes shared experts (DeepSeekMoE) and the standard
load-balance + router-z auxiliary losses.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat
from repro.models.layers import activation_fn


def expert_capacity(n_tokens, cfg):
    cap = int(cfg.top_k * n_tokens * cfg.capacity_factor / cfg.n_experts)
    return max(8, -(-cap // 8) * 8)  # round up to multiple of 8, floor 8


def moe_ffn_ragged(x, p, cfg):
    """Dropless MoE via sort-by-expert + ``jax.lax.ragged_dot``.

    Exact (no capacity drops) and sequence-length independent — the serving
    engine's path, so prefill / decode / full-forward agree bitwise on
    routing. x: (B, T, d) -> (B, T, d).
    """
    b, t, d = x.shape
    n = b * t
    e, k = cfg.n_experts, cfg.top_k
    xf = x.reshape(n, d)
    act = activation_fn(cfg.activation)

    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)          # (N, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    flat_e = expert_ids.reshape(-1)                          # (N*k,)
    order = jnp.argsort(flat_e)                              # stable
    tok_of = order // k                                      # source token
    xs = xf[tok_of]                                          # (N*k, d)
    group_sizes = jnp.bincount(flat_e, length=e).astype(jnp.int32)

    g = jax.lax.ragged_dot(xs, p["w_gate"], group_sizes)
    u = jax.lax.ragged_dot(xs, p["w_up"], group_sizes)
    yb = jax.lax.ragged_dot((act(g) * u).astype(xs.dtype), p["w_down"],
                            group_sizes)

    # Unsort and combine.
    unsorted = jnp.zeros((n * k, d), yb.dtype).at[order].set(yb)
    y = (unsorted.reshape(n, k, d).astype(jnp.float32)
         * gate_vals[..., None]).sum(axis=1).astype(x.dtype)

    if cfg.n_shared_experts > 0:
        sg = jnp.einsum("nd,df->nf", xf, p["shared_gate"])
        su = jnp.einsum("nd,df->nf", xf, p["shared_up"])
        y = y + jnp.einsum("nf,fd->nd", act(sg) * su, p["shared_down"])
    return y.reshape(b, t, d)


def moe_ffn_ep(x, p, cfg, ctx, *, return_aux=False):
    """Expert-parallel MoE: shard_map + all-to-all dispatch (GShard-style).

    Why: GSPMD cannot partition the capacity-buffer scatter (data-dependent
    indices crossing shards) and falls back to replicating tokens to every
    expert shard — measured 100–140 GiB/device all-gathers on the MoE train
    cells (EXPERIMENTS.md §Dry-run). This path makes the dispatch explicit:

      tokens sharded (batch over data axes, seq over the model axis)
      -> local top-k routing (router weights replicated)
      -> per-expert capacity buffer (E, C_loc, d), C_loc ~ k*n_loc*cf/E
      -> all_to_all over the model axis: (E, C_loc, d) -> (E_loc, M*C_loc, d)
      -> batched expert FFN with the *local* expert weights (E_loc, d, f)
      -> reverse all_to_all -> local unscatter + combine weights
      shared experts: tensor-parallel over the model axis (psum of partials)

    x: (B, T, d) with B divisible by prod(batch_axes) and T by the model
    axis. Falls back to ``moe_ffn`` when no mesh context / not divisible.
    """
    from jax.sharding import PartitionSpec as P

    mesh = ctx.mesh
    m = ctx.axis_size(ctx.model_axis)
    dp = 1
    for a in ctx.batch_axes:
        dp *= ctx.axis_size(a)
    b, t, d = x.shape
    e, k, f = cfg.n_experts, cfg.top_k, cfg.moe_d_ff
    if b % dp or t % m or e % m:
        return moe_ffn(x, p, cfg, return_aux=return_aux)
    act = activation_fn(cfg.activation)
    batch_spec = ctx.batch_axes if len(ctx.batch_axes) > 1 \
        else ctx.batch_axes[0]
    maxis = ctx.model_axis
    n_loc = (b // dp) * (t // m)
    cap = expert_capacity(n_loc, cfg)

    def body(xb, router, w_gate, w_up, w_down, shared):
        # xb: (B/dp, T/m, d); experts: (E/m, d, f); router: (d, E)
        bl, tl, _ = xb.shape
        n = bl * tl
        xf = xb.reshape(n, d)
        logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32),
                            router.astype(jnp.float32))
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_ids = jax.lax.top_k(probs, k)
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9)

        # local capacity scatter (identical math to moe_ffn)
        onehot = jax.nn.one_hot(expert_ids, e, dtype=jnp.int32)
        flat_oh = onehot.reshape(n * k, e)
        pos_in_expert = (jnp.cumsum(flat_oh, axis=0) - flat_oh)
        pos = (pos_in_expert * flat_oh).sum(-1).reshape(n, k)
        keep = pos < cap
        flat_idx = jnp.where(keep, expert_ids * cap + pos, e * cap)
        buf = jnp.zeros((e * cap + 1, d), dtype=xb.dtype)
        src = jnp.repeat(xf[:, None, :], k, axis=1).reshape(n * k, d)
        buf = buf.at[flat_idx.reshape(-1)].set(src, mode="drop")
        buf = buf[: e * cap].reshape(e, cap, d)

        # DISPATCH: (E, C, d) -> (E/m, m*C, d) across the model axis
        recv = jax.lax.all_to_all(buf, maxis, split_axis=0, concat_axis=1,
                                  tiled=True)
        g = jnp.einsum("ecd,edf->ecf", recv, w_gate)
        u = jnp.einsum("ecd,edf->ecf", recv, w_up)
        yb = jnp.einsum("ecf,efd->ecd", act(g) * u, w_down)
        # COMBINE: reverse all-to-all back to the owning token shard
        yb = jax.lax.all_to_all(yb, maxis, split_axis=1, concat_axis=0,
                                tiled=True)

        ybf = jnp.concatenate(
            [yb.reshape(e * cap, d), jnp.zeros((1, d), yb.dtype)], axis=0)
        gathered = ybf[flat_idx.reshape(-1)].reshape(n, k, d)
        w = (gate_vals * keep.astype(gate_vals.dtype))[..., None]
        y = (gathered.astype(jnp.float32) * w).sum(1).astype(xb.dtype)

        # shared experts: dense + small -> weights replicated, computed
        # per token shard. (TP partials would psum across the model axis,
        # but that axis shards *tokens* here — partials would mix shards.)
        if shared is not None:
            sg, su, sd = shared
            hs = act(jnp.einsum("nd,df->nf", xf, sg)) \
                * jnp.einsum("nd,df->nf", xf, su)
            y = y + jnp.einsum("nf,fd->nd", hs, sd).astype(xb.dtype)

        me = probs.mean(axis=0)
        ce = jax.nn.one_hot(expert_ids[:, 0], e).mean(axis=0)
        lb = e * jnp.sum(me * ce)
        z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
        # aux losses averaged over every shard
        all_axes = (*ctx.batch_axes, maxis)
        lb = jax.lax.pmean(lb, all_axes)
        z = jax.lax.pmean(z, all_axes)
        return y.reshape(bl, tl, d), lb, z

    shared_specs = None
    shared_args = None
    if cfg.n_shared_experts > 0:
        shared_specs = (P(None, None), P(None, None), P(None, None))
        shared_args = (p["shared_gate"], p["shared_up"], p["shared_down"])

    fn = compat.shard_map(
        body, mesh=mesh,
        in_specs=(P(batch_spec, maxis, None),        # x: batch + seq shard
                  P(None, None),                      # router replicated
                  P(maxis, None, None),               # experts sharded on E
                  P(maxis, None, None),
                  P(maxis, None, None),
                  shared_specs),
        out_specs=(P(batch_spec, maxis, None), P(), P()),
        check_vma=False)
    y, lb, z = fn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"],
                  shared_args)
    if return_aux:
        return y, {"load_balance": lb, "router_z": z}
    return y


def moe_ffn(x, p, cfg, *, return_aux=False):
    """x: (B, T, d). p: layer-indexed MoE params.

    Returns y (B, T, d) and (optionally) aux loss dict.
    """
    b, t, d = x.shape
    n = b * t
    e, k = cfg.n_experts, cfg.top_k
    xf = x.reshape(n, d)
    act = activation_fn(cfg.activation)

    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)          # (N, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)              # renormalize

    cap = expert_capacity(n, cfg)
    # Position of each (token, choice) within its expert, by priority order.
    onehot = jax.nn.one_hot(expert_ids, e, dtype=jnp.int32)  # (N, k, E)
    flat_oh = onehot.reshape(n * k, e)
    pos_in_expert = (jnp.cumsum(flat_oh, axis=0) - flat_oh)  # (N*k, E)
    pos = (pos_in_expert * flat_oh).sum(-1).reshape(n, k)    # (N, k)
    keep = pos < cap                                         # (N, k)

    flat_idx = expert_ids * cap + pos                        # (N, k)
    flat_idx = jnp.where(keep, flat_idx, e * cap)            # overflow slot

    # Scatter tokens into the expert buffer (E*C+1, d); last row = dropped.
    buf = jnp.zeros((e * cap + 1, d), dtype=x.dtype)
    src = jnp.repeat(xf[:, None, :], k, axis=1).reshape(n * k, d)
    buf = buf.at[flat_idx.reshape(-1)].set(src, mode="drop")
    buf = buf[: e * cap].reshape(e, cap, d)

    # Batched expert FFN: (E, C, d) x (E, d, f) -> (E, C, f)
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    yb = jnp.einsum("ecf,efd->ecd", act(g) * u, p["w_down"])  # (E, C, d)

    # Gather back with combine weights.
    ybf = jnp.concatenate(
        [yb.reshape(e * cap, d), jnp.zeros((1, d), yb.dtype)], axis=0)
    gathered = ybf[flat_idx.reshape(-1)].reshape(n, k, d)
    w = (gate_vals * keep.astype(gate_vals.dtype))[..., None]
    y = (gathered.astype(jnp.float32) * w).sum(axis=1).astype(x.dtype)

    # Shared experts (DeepSeekMoE): dense FFN over all tokens, added.
    if cfg.n_shared_experts > 0:
        sg = jnp.einsum("nd,df->nf", xf, p["shared_gate"])
        su = jnp.einsum("nd,df->nf", xf, p["shared_up"])
        y = y + jnp.einsum("nf,fd->nd", act(sg) * su, p["shared_down"])

    y = y.reshape(b, t, d)
    if not return_aux:
        return y
    # Load-balance loss (Switch): E * sum_e f_e * P_e; and router z-loss.
    me = probs.mean(axis=0)                                   # (E,)
    ce = jax.nn.one_hot(expert_ids[:, 0], e).mean(axis=0)     # top-1 fraction
    lb = e * jnp.sum(me * ce)
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return y, {"load_balance": lb, "router_z": z}
