"""Head-score correlation analysis (paper Figs 2, 6, 7).

Pearson cross-correlation between per-head attention-score vectors — the
paper's evidence for head redundancy and the feature underlying clustering.
"""
from __future__ import annotations

import jax.numpy as jnp


def head_correlation(scores):
    """scores: (H, F) per-head feature vectors -> (H, H) Pearson corr."""
    x = scores.astype(jnp.float32)
    x = x - x.mean(-1, keepdims=True)
    norm = jnp.sqrt(jnp.sum(jnp.square(x), -1, keepdims=True))
    xh = x / jnp.maximum(norm, 1e-12)
    return xh @ xh.T


def mean_abs_offdiag(corr):
    """Scalar redundancy summary of a correlation matrix."""
    h = corr.shape[0]
    mask = 1.0 - jnp.eye(h, dtype=corr.dtype)
    return jnp.sum(jnp.abs(corr) * mask) / jnp.maximum(mask.sum(), 1.0)
