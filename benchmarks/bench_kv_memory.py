"""Paper Fig 11: K,V-cache memory, MHA vs CHAI, across sequence lengths.

Three lanes:
  1. **Analytic** — exact steady-state bytes for the full LLaMA-7B config
     (the paper's model) and every assigned MHA-regime arch. The paper's
     21.4% saving comes from dropping non-representative K rows; V is
     kept (Table 4).
  2. **Paged allocator** — the continuous-batching engine with
     ``kv_layout="paged"`` on a tiny MHA model: resident (allocated-page)
     bytes sampled across PREFILL -> WARMUP -> CLUSTER -> STEADY. The
     claim check asserts the saving is *realized by the allocator*:
     steady-state paged-CHAI bytes fall below the dense-MHA rectangle
     the dense layouts keep resident (the unified layout exceeds it).
  3. **Tier transitions** — a prefix-family workload past device
     capacity on three engines: A (pressured + host offload), B
     (pressured, HBM-only), C (unpressured reference). Claims: (a)
     demoted-then-promoted requests in A emit bitwise-identical greedy
     tokens vs the all-HBM run C, with at least one host->hot
     promotion; (b) A's effective prefix-cache hit tokens exceed the
     HBM-only baseline B under the same pressure (the host tier turns
     evictions into reuse). The hot/host/compressed byte trajectory
     comes from ``kv_bytes_history``; a tiny-host variant exercises the
     int4 compressed rung."""
from __future__ import annotations

import numpy as np

import jax

from benchmarks.common import save_result
from repro.configs.base import get_config, list_configs, reduced
from repro.core.cache import kv_cache_bytes, unified_kv_bytes
from repro.models import transformer as tfm
from repro.serving import invariants
from repro.serving.engine import EngineConfig, EngineCore, ServingEngine
from repro.serving.sampling import SamplingParams


def _paged_allocator_lane(slots=2, max_seq=64, page_size=16, n_req=4):
    """PREFILL->STEADY allocated-bytes trajectory of the paged engine."""
    cfg = reduced(get_config("chai-llama-7b"), n_layers=2, d_model=32,
                  d_ff=64, vocab=64).replace(dtype="float32")
    cfg = cfg.with_chai(enabled=True, warmup_tokens=3)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params,
                        EngineConfig(batch_slots=slots, max_seq=max_seq,
                                     kv_layout="paged",
                                     page_size=page_size))
    rng = np.random.default_rng(0)
    for i in range(n_req):
        eng.submit(rng.integers(0, cfg.vocab_size, size=8),
                   max_new_tokens=24, uid=i)
    eng.run()
    hist = eng.kv_bytes_history
    dense_mha = unified_kv_bytes(cfg, slots, max_seq, chai=False)
    dense_unified = unified_kv_bytes(cfg, slots, max_seq, chai=True)
    # steady state = every occupied slot past CLUSTER (no warmup slot is
    # holding dense K pages); churn steps with a fresh WARMUP admission
    # are transient and excluded. No steady sample means the workload
    # never exercised the saving — fail loudly rather than report a
    # vacuous (drained-engine) number.
    steady = [h for h in hist
              if h.get("n_warmup") == 0 and h.get("n_steady", 0) > 0]
    if not steady:
        raise RuntimeError(
            "paged allocator lane produced no steady-state sample "
            f"(warmup_tokens={cfg.chai.warmup_tokens}, history={hist}); "
            "the claim check would be vacuous")
    steady_bytes = max(h["kv_bytes"] for h in steady)
    return {
        "note": "allocated-page bytes from the serving engine's PagePool "
                "accounting (tiny model; layout-level numbers, not "
                "hardware-level)",
        "workload": {"slots": slots, "max_seq": max_seq,
                     "page_size": page_size, "n_req": n_req,
                     "prompt_len": 8, "max_new": 24},
        "timeline": hist,
        "peak_bytes": eng.kv_bytes_peak(),
        "steady_chai_bytes": steady_bytes,
        "dense_mha_bytes": dense_mha,
        "dense_unified_bytes": dense_unified,
        "paged_steady_saving_vs_dense_mha":
            1 - steady_bytes / dense_mha,
    }


def _tier_lane(page_size=8, num_pages=12):
    """Hierarchical KV tiers under device pressure: demote / promote
    round trips, reuse uplift vs an HBM-only pool, byte trajectory."""
    cfg = reduced(get_config("chai-llama-7b"), n_layers=2, d_model=32,
                  d_ff=64, vocab=64).replace(dtype="float32")
    cfg = cfg.with_chai(enabled=True, warmup_tokens=3)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    # Prefix family past device capacity + extensions that route later
    # matches through the (by then demoted/evicted) suffix leaves.
    rng = np.random.default_rng(0)
    prefix = rng.integers(1, 64, size=2 * page_size).tolist()
    base = [prefix + rng.integers(1, 64, size=page_size).tolist()
            for _ in range(4)]
    ext = [p + rng.integers(1, 64, size=page_size).tolist()
           for p in base[:2]]
    workload = base + ext

    def run_engine(**kw):
        core = EngineCore(cfg, params,
                          EngineConfig(batch_slots=1, max_seq=64,
                                       page_size=page_size,
                                       prefix_cache=True, **kw))
        toks = {}
        for p in workload:
            r = core.add_request(list(p), SamplingParams(max_new_tokens=8))
            while core.has_work():   # serialize: maximal reuse per prompt
                core.step()
            assert r.finish_reason == "length", r.finish_reason
            toks[r.uid] = list(r.generated)
        return core, toks

    pressured = dict(num_pages=num_pages)
    a, a_toks = run_engine(kv_offload=True, host_pages=64,
                           tier_prefetch=False, **pressured)
    b, b_toks = run_engine(**pressured)            # HBM-only baseline
    c, c_toks = run_engine()                       # unpressured reference
    # Tiny host pool: demotions overflow onto the int4 compressed rung.
    comp, _ = run_engine(kv_offload=True, host_pages=2,
                         compressed_pages=32, tier_prefetch=False,
                         **pressured)

    a_stats, b_stats = a.prefix_stats(), b.prefix_stats()
    transitions = a.tier_stats()["transitions"]
    trajectory = [{k: h.get(k, 0) for k in
                   ("step", "kv_bytes", "host_bytes", "compressed_bytes")}
                  for h in a.kv_bytes_history]
    comp_traj = [h.get("compressed_bytes", 0)
                 for h in comp.kv_bytes_history]
    return {
        "note": "tiny-model tier ladder; byte numbers are layout-level "
                "(PagePool accounting), not hardware-level",
        "workload": {"prompts": len(workload), "page_size": page_size,
                     "device_pages": num_pages, "prompt_blocks": "3-4"},
        "trajectory": trajectory,
        "transitions": transitions,
        "offload": {"demoted_blocks": a_stats["demoted_blocks"],
                    "promoted_blocks": a_stats["promoted_blocks"],
                    "demoted_snapshots": a_stats["demoted_snapshots"],
                    "tokens_reused": a_stats["tokens_reused"]},
        "hbm_only": {"evicted_blocks": b_stats["evicted_blocks"],
                     "tokens_reused": b_stats["tokens_reused"]},
        "compressed_peak_bytes": max(comp_traj, default=0),
        "claims": {
            # (a) demoted-then-promoted requests replay bitwise
            "promoted_bitwise_vs_all_hbm":
                a_toks == c_toks
                and transitions.get("host->hot/dense", 0) > 0,
            # (b) the host tier turns evictions into cache hits
            "reuse_tokens_above_hbm_only":
                a_stats["tokens_reused"] > b_stats["tokens_reused"],
            "compressed_tier_exercised": max(comp_traj, default=0) > 0,
            "leak_free_after_drain":
                invariants.audit_leaks(a) == []
                and invariants.audit_leaks(comp) == [],
        },
    }


def run():
    seqs = [256, 512, 1024, 2048, 4096]
    per_arch = {}
    for arch in list_configs():
        cfg = get_config(arch)
        if cfg.n_attn_layers == 0 or not cfg.is_mha:
            continue                      # GQA/SSM: no K-cache saving
        rows = {}
        for s in seqs:
            full = kv_cache_bytes(cfg, 1, s, chai=False)
            ch = kv_cache_bytes(cfg, 1, s, chai=True)
            rows[str(s)] = {"mha_bytes": full, "chai_bytes": ch,
                            "saving_frac": 1 - ch / full}
        per_arch[arch] = rows

    paged = _paged_allocator_lane()
    tiers = _tier_lane()
    llama = per_arch["chai-llama-7b"]["2048"]
    result = {
        "note": "exact analytic bytes; MHA-regime archs only (GQA archs "
                "get compute-only wins, DESIGN.md §4)",
        "per_arch": per_arch,
        "paged_allocator": paged,
        "kv_tiers": tiers,
        "paper_claim": "LLaMA-7B seq 2048: ~1.2 GB KV cache, up to 21.4% "
                       "saving",
        "claim_check": {
            "llama_kv_GB_at_2048": llama["mha_bytes"] / 2**30,
            "llama_saving_frac": llama["saving_frac"],
            "saving_in_paper_range": 0.10 <= llama["saving_frac"] <= 0.30,
            "kv_close_to_1.2GB": 0.8 <= llama["mha_bytes"] / 2**30 <= 1.6,
            # the tentpole: the allocator (not just the formula) realizes
            # the saving — steady paged-CHAI below the dense-MHA
            # rectangle, which the unified layout exceeds
            "paged_steady_below_dense_mha":
                paged["steady_chai_bytes"] < paged["dense_mha_bytes"],
            "unified_layout_exceeds_dense_mha":
                paged["dense_unified_bytes"] > paged["dense_mha_bytes"],
            "compaction_frees_pages":
                paged["steady_chai_bytes"] < paged["peak_bytes"],
            # the tier ladder: bitwise promotion, reuse uplift vs an
            # HBM-only pool, the int4 rung exercised, zero leaks
            **{f"tier_{k}": v for k, v in tiers["claims"].items()},
        },
    }
    save_result("bench_kv_memory", result)
    return result


if __name__ == "__main__":
    print(run())
