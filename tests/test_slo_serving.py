"""SLO serving tier: chunked prefill, priority preemption, AsyncLLM.

Three correctness claims, each against an uninterrupted reference run on
a fresh engine with identical pools and jits:

* chunked prefill is a pure latency knob — greedy tokens match the
  monolithic prefill exactly, with and without radix prefix hits;
* preempt-then-resume is bitwise-exact — the KV swap restores the
  victim's pages and per-slot state, so the resumed decode continues the
  SAME chain (recompute could not: CHAI decode approximates full
  attention, so replayed prefills diverge from the decode-written KV);
* the asyncio front door serializes one engine under many concurrent
  streams, and a mid-stream abort delivers an empty terminal chunk and
  returns every page.
"""
import asyncio

import numpy as np
import pytest

import jax

from repro.configs.base import get_config, reduced
from repro.models import transformer as tfm
from repro.serving.api import LLM
from repro.serving.async_api import AsyncLLM
from repro.serving.engine import EngineConfig
from repro.serving.sampling import FINISH_ABORT, SamplingParams

MHA_ARCH = "chai-llama-7b"      # is_mha=True: clustered K pages (cp)
GQA_ARCH = "nemotron-4-15b"     # GQA: CHAI clusters query heads only
GREEDY = SamplingParams(max_new_tokens=10)

_params_cache = {}


def _cfg(arch):
    cfg = reduced(get_config(arch), n_layers=2, d_model=32, d_ff=64,
                  vocab=64).replace(dtype="float32")
    return cfg.with_chai(enabled=True, warmup_tokens=3)


def _model(arch):
    if arch not in _params_cache:
        cfg = _cfg(arch)
        _params_cache[arch] = (cfg,
                               tfm.init_params(cfg, jax.random.PRNGKey(0)))
    return _params_cache[arch]


def _pool_counters(core):
    out = {"dense": core.dense_pool.counters()}
    if core.chai_pool is not None:
        out["chai"] = core.chai_pool.counters()
    return out


# ---------------------------------------------------------------------------
# chunked prefill == monolithic prefill (greedy, paged)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", [MHA_ARCH, GQA_ARCH])
def test_chunked_prefill_greedy_parity(arch):
    """Chunking a 40-token prompt into page-multiple pieces must not
    change a single greedy token, on MHA-CHAI and GQA-CHAI alike."""
    cfg, params = _model(arch)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=40) for _ in range(3)]
    kw = dict(batch_slots=2, max_seq=128, page_size=16)
    outs = {}
    for chunk in (0, 16):
        llm = LLM(cfg, params, EngineConfig(prefill_chunk_tokens=chunk,
                                            **kw))
        outs[chunk] = [o.token_ids for o in llm.generate(prompts, GREEDY)]
        assert not llm.core.has_work()
        assert llm.core.dense_pool.pages_in_use == 0
    assert outs[16] == outs[0], (arch, outs)


def test_chunked_prefill_parity_with_radix_hits():
    """A chunked prefill downstream of a radix-cache hit starts on a
    page boundary mid-prompt; tokens and hit accounting must match the
    monolithic engine's."""
    cfg, params = _model(MHA_ARCH)
    kw = dict(batch_slots=2, max_seq=128, page_size=16, prefix_cache=True)
    mono = LLM(cfg, params, EngineConfig(**kw))
    chnk = LLM(cfg, params, EngineConfig(prefill_chunk_tokens=16, **kw))
    rng = np.random.default_rng(1)
    base = rng.integers(0, cfg.vocab_size, size=48)
    ext = np.concatenate([base,
                          rng.integers(0, cfg.vocab_size, size=40)])
    m1 = mono.generate(base, GREEDY)[0].token_ids
    m2 = mono.generate(ext, GREEDY)[0]
    c1 = chnk.generate(base, GREEDY)[0].token_ids
    c2 = chnk.generate(ext, GREEDY)[0]
    assert c1 == m1
    assert c2.token_ids == m2.token_ids
    assert c2.cached_tokens == m2.cached_tokens > 0
    assert c2.prefill_tokens == m2.prefill_tokens


def test_chunked_prefill_rejected_for_local_attention():
    """Chunk starts are only page-aligned for pure global attention;
    sliding-window archs must refuse the knob instead of mis-slotting
    their ring buffers."""
    cfg, params = _model(MHA_ARCH)
    cfg = cfg.replace(layer_types=("attn_local", "attn_global"))
    with pytest.raises(ValueError, match="chunk"):
        LLM(cfg, params, EngineConfig(batch_slots=2, max_seq=128,
                                      page_size=16,
                                      prefill_chunk_tokens=16))


# ---------------------------------------------------------------------------
# priority preemption: swap-out / swap-in is bitwise-exact
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", [MHA_ARCH, GQA_ARCH])
def test_preempt_resume_identical_output(arch):
    """Under a page budget that fits one request, a higher-priority
    arrival evicts the running request mid-STEADY; after the KV swap
    back in, BOTH requests must equal their uninterrupted references."""
    cfg, params = _model(arch)
    sp = SamplingParams(max_new_tokens=12)
    kw = dict(batch_slots=2, max_seq=128, page_size=16, num_pages=10,
              num_chai_pages=10)
    rng = np.random.default_rng(0)
    p_low = rng.integers(0, cfg.vocab_size, size=40)
    p_high = rng.integers(0, cfg.vocab_size, size=40)
    ref = LLM(cfg, params, EngineConfig(**kw))
    want_low = ref.generate(p_low, sp)[0].token_ids
    want_high = ref.generate(p_high, sp)[0].token_ids

    llm = LLM(cfg, params, EngineConfig(**kw))
    core = llm.core
    base = _pool_counters(core)
    r_low = core.add_request(p_low, sp, priority=0)
    for _ in range(6):              # decode into STEADY before the storm
        core.step()
    assert len(r_low.generated) >= 3 and not r_low.finished
    r_high = core.add_request(p_high, sp, priority=5)
    while not (r_low.finished and r_high.finished):
        core.step()
    assert r_low.preemptions == 1
    assert r_high.preemptions == 0
    assert r_low.generated == want_low, (arch, r_low.generated, want_low)
    assert r_high.generated == want_high
    core.reap_done()
    assert _pool_counters(core) == base


@pytest.mark.parametrize("steps", [1, 0], ids=["warmup", "prefill"])
def test_preempt_in_early_phase(steps):
    """Eviction during WARMUP swaps the score rings too; eviction of a
    not-yet-sampled PREFILL slot restarts from scratch. Either way the
    victim's final tokens match its uninterrupted run."""
    cfg, params = _model(MHA_ARCH)
    sp = SamplingParams(max_new_tokens=12)
    kw = dict(batch_slots=2, max_seq=128, page_size=16, num_pages=10,
              num_chai_pages=10)
    rng = np.random.default_rng(2)
    p1 = rng.integers(0, cfg.vocab_size, size=40)
    p2 = rng.integers(0, cfg.vocab_size, size=40)
    ref = LLM(cfg, params, EngineConfig(**kw))
    w1 = ref.generate(p1, sp)[0].token_ids
    w2 = ref.generate(p2, sp)[0].token_ids
    llm = LLM(cfg, params, EngineConfig(**kw))
    core = llm.core
    r1 = core.add_request(p1, sp, priority=0)
    for _ in range(steps + 1):
        core.step()
    r2 = core.add_request(p2, sp, priority=9)
    while not (r1.finished and r2.finished):
        core.step()
    assert r1.preemptions >= 1
    assert r1.generated == w1
    assert r2.generated == w2


def test_preemption_storm_pool_baseline():
    """Five requests with strictly increasing priorities arrive back to
    back on a one-request page budget: a chain of evictions. Everything
    finishes full-length and the pools return refcount-exactly."""
    cfg, params = _model(MHA_ARCH)
    sp = SamplingParams(max_new_tokens=10)
    kw = dict(batch_slots=2, max_seq=128, page_size=16, num_pages=10,
              num_chai_pages=10)
    llm = LLM(cfg, params, EngineConfig(**kw))
    rng = np.random.default_rng(3)
    llm.generate(rng.integers(0, cfg.vocab_size, size=40), sp)  # warm jits
    core = llm.core
    base = _pool_counters(core)
    reqs = [core.add_request(rng.integers(0, cfg.vocab_size, size=40),
                             sp, priority=k) for k in range(5)]
    while not all(r.finished for r in reqs):
        core.step()
    core.reap_done()
    assert all(len(r.generated) == sp.max_new_tokens for r in reqs)
    assert core.preemptions >= 1
    assert _pool_counters(core) == base


def test_preemption_off_means_fifo():
    """``preemption=False`` keeps the old behaviour: the high-priority
    arrival waits for a free slot instead of evicting."""
    cfg, params = _model(MHA_ARCH)
    sp = SamplingParams(max_new_tokens=10)
    llm = LLM(cfg, params, EngineConfig(batch_slots=1, max_seq=128,
                                        page_size=16, preemption=False))
    core = llm.core
    rng = np.random.default_rng(4)
    r1 = core.add_request(rng.integers(0, cfg.vocab_size, size=24), sp,
                          priority=0)
    core.step()
    r2 = core.add_request(rng.integers(0, cfg.vocab_size, size=24), sp,
                          priority=9)
    while not (r1.finished and r2.finished):
        core.step()
    assert core.preemptions == 0
    assert r1.preemptions == r2.preemptions == 0


# ---------------------------------------------------------------------------
# AsyncLLM: concurrent streams + mid-stream aborts on one engine
# ---------------------------------------------------------------------------
def test_async_concurrent_streams_with_aborts():
    """32 concurrent ``stream()`` coroutines share one continuous batch;
    8 of them abort after their first chunk. Surviving streams must be
    token-identical to the synchronous engine; aborted streams end in an
    empty ``finish_reason="aborted"`` chunk (the driver runs ahead of
    consumers, so earlier chunks may still carry tokens) and every page
    comes back."""
    cfg, params = _model(MHA_ARCH)
    sp = SamplingParams(max_new_tokens=8)
    kw = dict(batch_slots=4, max_seq=128, page_size=16)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=8) for _ in range(32)]
    sync = LLM(cfg, params, EngineConfig(**kw))
    want = [sync.generate(p, sp)[0].token_ids for p in prompts]

    async def _stream(llm, i):
        abort_me = i % 4 == 3
        chunks = []
        async for c in llm.stream(prompts[i], sp):
            chunks.append(c)
            if abort_me and len(chunks) == 1:
                assert await llm.abort(c.uid)
        toks = [t for c in chunks for t in c.token_ids]
        assert chunks[-1].finished
        if abort_me:
            assert chunks[-1].finish_reason == FINISH_ABORT
            assert not chunks[-1].token_ids
            assert len(toks) < sp.max_new_tokens
        else:
            assert toks == want[i], (i, toks, want[i])
        return toks

    async def main():
        async with AsyncLLM(cfg, params, EngineConfig(**kw)) as llm:
            base = _pool_counters(llm.core)
            await asyncio.gather(
                *[_stream(llm, i) for i in range(len(prompts))])
            assert not llm.core.has_work()
            assert _pool_counters(llm.core) == base

    asyncio.run(main())


def test_async_abandoned_stream_releases_slot():
    """Breaking out of a stream (generator close) aborts the request —
    a dropped connection never pins a slot or its pages."""
    cfg, params = _model(MHA_ARCH)
    sp = SamplingParams(max_new_tokens=8)
    kw = dict(batch_slots=2, max_seq=128, page_size=16)
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, size=8)

    async def main():
        async with AsyncLLM(cfg, params, EngineConfig(**kw)) as llm:
            base = _pool_counters(llm.core)
            it = llm.stream(prompt, sp)
            first = await it.__anext__()
            assert not first.finished
            await it.aclose()
            # the abort lands synchronously in aclose(); the driver
            # settles on its next wakeups
            for _ in range(50):
                if not llm.core.has_work():
                    break
                await asyncio.sleep(0.01)
            assert not llm.core.has_work()
            assert _pool_counters(llm.core) == base
            # the engine still serves fresh work afterwards
            out = await llm.generate(prompt, sp)
            assert len(out.token_ids) == sp.max_new_tokens

    asyncio.run(main())
