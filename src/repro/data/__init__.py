from repro.data.pipeline import DataConfig, SyntheticPipeline, calibration_batches  # noqa: F401
