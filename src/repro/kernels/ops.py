"""Jit'd dispatch wrappers over the Pallas kernels.

On CPU (this container) kernels run with interpret=True; on TPU they lower
to Mosaic. ``chai_decode_attention`` / ``paged_chai_decode_attention`` are
the public decode ops: ONE fused Pallas launch per decode step (online
softmax over rep-head scores + h2c-broadcast AV, int8 dequant in VMEM) —
the pre-fusion three-kernel pipeline survives only as the oracle in
``repro.kernels.ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import chai_attention as ck
from repro.kernels import flash_attention as fk


@functools.partial(jax.jit, static_argnames=("window", "ts", "interpret"))
def flash_decode_attention(q, k_cache, v_cache, pos, *, window=0, ts=512,
                           interpret=None):
    return fk.flash_decode(q, k_cache, v_cache, pos, window=window, ts=ts,
                           interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("window", "tq", "ts", "softcap",
                                    "emit_state", "interpret"))
def flash_prefill_attention(q, k, v, offset=0, *, window=0, tq=256, ts=512,
                            softcap=0.0, emit_state=False, interpret=None):
    """``offset`` is a regular (traceable) argument: the prefix-cache
    suffix prefill varies it per request without retracing. ``softcap``
    is static — a python float baked into the kernel (0 = off).
    ``emit_state`` returns the head-major mergeable (m, l, acc) triple
    instead of the finalized output (see ``merge_prefill_states``)."""
    return fk.flash_prefill(q, k, v, offset=offset, window=window, tq=tq,
                            ts=ts, softcap=softcap, emit_state=emit_state,
                            interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("softcap", "tq", "interpret"))
def paged_prefix_attention(q, kv_pool, bt_k, bt_v, plen, *,
                           k_scale_pool=None, v_scale_pool=None,
                           softcap=0.0, tq=256, interpret=None):
    """Suffix-prefill prefix pass over block-table pages: q (B, T, H, hd)
    suffix queries attend every cached prefix position (< plen, (B,)
    int32) streaming only real pages — no slot-capacity densify. Returns
    the head-major mergeable (m, l, acc) triple; combine with the
    ``flash_prefill_attention(..., emit_state=True)`` suffix pass via
    ``merge_prefill_states`` and normalize with
    ``finalize_prefill_state``."""
    return fk.paged_prefix_attend(q, kv_pool, bt_k, bt_v, plen,
                                  k_scale_pool=k_scale_pool,
                                  v_scale_pool=v_scale_pool,
                                  softcap=softcap, tq=tq,
                                  interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("reps_per_group", "share_values",
                                    "window", "ts", "softcap", "emit_state",
                                    "interpret"))
def chai_decode_attention(q_rep, k_cache, v_cache, h2c, pos, *,
                          k_scale=None, v_scale=None, reps_per_group=1,
                          share_values=False, window=0, ts=512, softcap=0.0,
                          emit_state=False, interpret=None):
    """The paper's decode op — ONE fused Pallas launch. q_rep: (B, R, hd)
    rep-head queries; k_cache: (B, KVk, S, hd) (clustered for MHA:
    KVk==R); v_cache: (B, KVv, S, hd) per-head / per-group / clustered
    (share_values) V; h2c: (B, H) or (H,) flat head->rep-row map; pos:
    (B,). int8 caches pass per-row ``k_scale``/``v_scale`` (B, rows, S).
    Returns (B, H, hd) fp32; no (B, R, S) scores touch HBM."""
    return ck.chai_fused_decode(q_rep, k_cache, v_cache, h2c, pos,
                                k_scale=k_scale, v_scale=v_scale,
                                reps_per_group=reps_per_group,
                                share_values=share_values, window=window,
                                ts=ts, softcap=softcap,
                                emit_state=emit_state, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_decode_attention(q, kv_pool, bt_k, bt_v, pos, *, window=0,
                           interpret=None):
    """Paged flash decode over a block-table page pool. q: (B, H, hd);
    kv_pool: (nP, KV, page, hd); bt_k/bt_v: (B, P) int32; pos: (B,).
    Returns (B, H, hd) fp32."""
    return fk.paged_decode(q, kv_pool, bt_k, bt_v, pos, window=window,
                           interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("reps_per_group", "share_values",
                                    "window", "softcap", "emit_state",
                                    "interpret"))
def paged_chai_decode_attention(q_rep, k_pool, bt_k, v_pool, bt_v, h2c,
                                pos, *, k_scale_pool=None,
                                v_scale_pool=None, reps_per_group=1,
                                share_values=False, window=0, softcap=0.0,
                                emit_state=False, interpret=None):
    """The paper's decode op over the serving engine's paged layout — ONE
    fused Pallas launch streaming pages through VMEM (no densifying
    gather). q_rep: (B, R, hd); k_pool: (nP, KVk, page, hd) clustered
    pages (MHA: KVk == k_max) or the dense pool (GQA); v_pool:
    (nP, KVv, page, hd) per-head V pages, or the clustered pool under
    ``share_values``; bt_k/bt_v: (B, P) int32 block tables; h2c: (B, H)
    or (H,). int8 pools pass the mirror-shaped scale pools. Returns
    (B, H, hd) fp32."""
    return ck.paged_chai_fused_decode(
        q_rep, k_pool, bt_k, v_pool, bt_v, h2c, pos,
        k_scale_pool=k_scale_pool, v_scale_pool=v_scale_pool,
        reps_per_group=reps_per_group, share_values=share_values,
        window=window, softcap=softcap, emit_state=emit_state,
        interpret=interpret)


@functools.partial(jax.jit, static_argnames=("ts", "softcap", "interpret"))
def relay_prefix_attention(q, k, v, k_row, a_row, v_row, plen, *,
                           k_scale=None, v_scale=None, ts=0, softcap=0.0,
                           interpret=None):
    """ONE batched shared-prefix attention pass per relay group (the
    RelayAttention idea keyed by radix node): member rep queries stack
    along one row axis so the packed resident prefix streams HBM->VMEM
    once per GROUP, not once per slot — decode cost for N slots sharing a
    system prompt drops from O(N * prefix) to O(prefix) per step. Returns
    the mergeable (m, l, acc) triple; combine with the suffix
    ``emit_state`` triple via ``merge_decode_states`` and normalize with
    ``finalize_decode_state``."""
    return ck.relay_prefix_decode(q, k, v, k_row, a_row, v_row, plen,
                                  k_scale=k_scale, v_scale=v_scale, ts=ts,
                                  softcap=softcap, interpret=interpret)


# ----------------------------------------- online-softmax state merging ----
def _bcast_h2c(h2c, b):
    if h2c.ndim == 1:
        h2c = jnp.broadcast_to(h2c, (b, h2c.shape[0]))
    return h2c


def merge_decode_states(s1, s2, h2c, *, share_values=False):
    """Online-softmax combine of two mergeable decode-state triples.

    Each state is (m (B, R), l (B, R), acc (B, rows_acc, hd)) as emitted
    by the fused decode kernels under ``emit_state`` (rows_acc == H, or R
    under ``share_values``). The combine is the flash-attention identity:
    m = max(m1, m2); l = l1*e^(m1-m) + l2*e^(m2-m); acc likewise, with
    the per-rep rescale broadcast to member-head acc rows through
    ``h2c``. An empty state (m = NEG_INF, l = 0, acc = 0) is the EXACT
    identity: the other side's m is kernel-clamped >= -1e30, so its
    rescale is e^0 == 1.0 bitwise and the empty side contributes 0."""
    m1, l1, acc1 = s1
    m2, l2, acc2 = s2
    b = m1.shape[0]
    m = jnp.maximum(m1, m2)
    c1 = jnp.exp(m1 - m)
    c2 = jnp.exp(m2 - m)
    l = l1 * c1 + l2 * c2
    if share_values:
        a1, a2 = c1, c2            # acc rows are the rep rows themselves
    else:
        h2c = _bcast_h2c(h2c, b)
        a1 = jnp.take_along_axis(c1, h2c, axis=1)     # (B, H)
        a2 = jnp.take_along_axis(c2, h2c, axis=1)
    acc = acc1 * a1[..., None] + acc2 * a2[..., None]
    return m, l, acc


def finalize_decode_state(state, h2c, *, share_values=False):
    """Normalize a (possibly merged) decode-state triple to (B, H, hd)
    fp32. Bitwise-identical to the fused kernels' in-kernel one-hot
    finalize: the one-hot matmul there sums exactly one nonzero term per
    row, which is this gather."""
    m, l, acc = state
    b = m.shape[0]
    h2c = _bcast_h2c(h2c, b)
    if share_values:
        out_r = acc / jnp.maximum(l, 1e-37)[..., None]
        return jnp.take_along_axis(out_r, h2c[..., None], axis=1)
    l_full = jnp.take_along_axis(l, h2c, axis=1)
    return acc / jnp.maximum(l_full, 1e-37)[..., None]


def merge_prefill_states(s1, s2):
    """Online-softmax combine of two head-major prefill-state triples
    (m (B, H, T), l (B, H, T), acc (B, H, T, hd)) — the prefix pass
    (``paged_prefix_attention``) and the causal suffix self-attention
    pass (``flash_prefill_attention(emit_state=True)``). An all-masked
    prefix (plen == 0, the cold first chunk) merges as the exact
    identity."""
    m1, l1, acc1 = s1
    m2, l2, acc2 = s2
    m = jnp.maximum(m1, m2)
    c1 = jnp.exp(m1 - m)
    c2 = jnp.exp(m2 - m)
    l = l1 * c1 + l2 * c2
    acc = acc1 * c1[..., None] + acc2 * c2[..., None]
    return m, l, acc


def finalize_prefill_state(state, dtype=jnp.float32):
    """Normalize a head-major prefill-state triple to (B, T, H, hd)."""
    m, l, acc = state
    out = acc / jnp.maximum(l, 1e-37)[..., None]
    return out.transpose(0, 2, 1, 3).astype(dtype)


def decode_flop_estimate(b, h, r, s, hd, *, share_values=False, window=0):
    """Analytic decode-attention FLOPs: clustered scores + AV.

    ``share_values``: the CHAI-QKV ablation prunes V rows too, so AV is
    R·S·hd, not H·S·hd. ``window``: sliding-window attention touches at
    most ``window`` positions, so effective S = min(S, window)."""
    s_eff = min(s, window) if window else s
    av_rows = r if share_values else h
    scores = 2.0 * b * r * s_eff * hd
    av = 2.0 * b * av_rows * s_eff * hd
    return scores + av


# --- fused-vs-pipeline analytic lane (benchmarks/bench_latency.py) ---------
def decode_launch_count(fused=True):
    """Kernel launches per CHAI decode step: the fused path is ONE
    ``pallas_call``; the retired pipeline was QK -> row softmax -> AV."""
    return 1 if fused else 3


def decode_hbm_bytes_estimate(b, h, r, s, hd, *, cache_bytes=4,
                              share_values=False, window=0, fused=True):
    """Analytic HBM bytes moved by one CHAI decode-attention step.

    Both paths stream the same cache tiles (K: R rep rows; V: H per-head
    rows, or R under ``share_values``) plus the (negligible) q/out
    vectors. The three-kernel pipeline additionally round-trips the
    (B, R, S) fp32 score tensor through HBM three times (QK write,
    softmax read+write) and re-reads the normalized rows per member head
    (B, H, S) in AV — exactly the traffic fusion deletes."""
    s_eff = min(s, window) if window else s
    v_rows = r if share_values else h
    cache = b * (r + v_rows) * s_eff * hd * cache_bytes
    qout = b * (r + h) * hd * 4
    total = cache + qout
    if not fused:
        total += b * r * s_eff * 4 * 3        # scores: write, read, write
        total += b * h * s_eff * 4            # AV reads A row per head
    return float(total)


# --- relay shared-prefix analytic lane (benchmarks/bench_latency.py) -------
def relay_prefix_hbm_bytes_estimate(k_rows, v_rows, prefix_len, hd, *,
                                    cache_bytes=4, int8_scales=False):
    """HBM bytes one relay group streams for its shared-prefix pass per
    decode step — independent of the member count N by construction: the
    packed resident prefix (k_rows + v_rows KV rows x prefix_len x hd)
    is read ONCE per group. Per-member q/acc traffic is O(N * R * hd),
    negligible against O(prefix) and excluded here exactly as
    ``decode_hbm_bytes_estimate`` treats its q/out vectors. Contrast with
    the non-relay cost: each of the N slots re-streams the same prefix
    through its own block table, N x this figure."""
    total = (k_rows + v_rows) * prefix_len * hd * cache_bytes
    if int8_scales:
        total += (k_rows + v_rows) * prefix_len * 4
    return float(total)


def relay_prefix_mxu_pass_estimate(n_members, r, prefix_len, *, ts,
                                   lanes=128):
    """Systolic-array passes over the prefix for one relay group's QK.

    The member rep rows batch along the MXU row axis, so the pass count
    is flat in N until N * R exceeds one ``lanes``-row tile — the
    hardware-cost spelling of "prefix attention is O(prefix), not
    O(N * prefix)". The per-request baseline is N launches of
    ceil(R / lanes) * ceil(prefix / ts) passes each."""
    import math
    return (math.ceil(max(n_members, 1) * r / lanes)
            * math.ceil(max(prefix_len, 1) / ts))
