"""Runtime invariant auditor for the serving engine.

``audit(core)`` returns a list of violation strings (empty = clean).
The engine runs it at the end of every ``step()`` (``EngineConfig.
audit_level``): ``"basic"`` (the default) covers the cheap host-side
checks — allocator conservation, refcount accounting, phase-machine
legality, prefix-cache lock/residency consistency — and ``"deep"``
additionally pulls the device block tables and phase vector and checks
them against the host bookkeeping (no freed or null-aliased writable
pages, device/host phase agreement). A non-empty audit raises
``EngineFault`` from ``step()``.

The invariants, spelled out:

* **Pool conservation** — for each ``PagePool``: free list + pages with
  a live refcount == capacity; no duplicate or null entries on the free
  list; every refcount strictly positive.
* **Reference accounting** — total outstanding references per pool ==
  references held by slot page lists + references held by the radix
  tree / snapshots (``PrefixCache.held_pages``). Nothing else may hold
  a page.
* **Phase legality** — empty slots are ``FREE`` with no pages, locks,
  or progress; occupied slots are in {PREFILL, WARMUP, STEADY}, a
  PREFILL slot has a chunked-prefill cursor, and progress counters stay
  within the request's budget.
* **Relay residency** — every cache entry a slot has locked is really
  locked (lock count >= 1) and, for radix nodes, its page pair still
  carries a live refcount. (A locked node merely *marked* evicted is
  survivable by design: relay groups dissolve and the slot decodes from
  its own page references — only freed-while-pinned pages are a breach.)
* **Block-table validity (deep)** — each slot's device block-table row
  mirrors its host page list exactly, the tail is the null sink, and
  every mapped page has a live refcount (no freed page reachable by a
  write).
* **Tier conservation** — each host/compressed ``HostPagePool``
  satisfies the same conservation law as the device pools, every
  referenced host page carries a stored payload (and vice versa — no
  orphaned payloads), and the outstanding host-tier references are
  exactly explained by demoted prefix-cache entries plus swapped-out
  (queued) requests' resume payloads.
* **NaN/Inf logits** are guarded separately on the decode hot path
  (``EngineCore._decode``) where the logits are in hand; the offending
  slot is quarantined rather than failing the audit.

``audit_leaks(core)`` is the between-tests gate (see
``tests/conftest.py``): on an idle engine every page reference must be
explained by the prefix cache and no cache entry may still be locked.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core import cache as chai_cache


def _audit_pool(name: str, pool, out: List[str]):
    free = pool._free
    rc = pool._rc
    if len(set(free)) != len(free):
        out.append(f"{name}: duplicate pages on the free list")
    if chai_cache.NULL_PAGE in free:
        out.append(f"{name}: null page on the free list")
    bad_rc = [p for p, c in rc.items()
              if c <= 0 or not (0 < p < pool.num_pages)]
    if bad_rc:
        out.append(f"{name}: invalid refcount entries {sorted(bad_rc)}")
    overlap = set(free) & set(rc)
    if overlap:
        out.append(f"{name}: pages both free and referenced "
                   f"{sorted(overlap)}")
    if len(free) + len(rc) != pool.capacity:
        out.append(f"{name}: conservation broken — {len(free)} free + "
                   f"{len(rc)} live != capacity {pool.capacity}")


def _slot_refs(core):
    """(dense, chai) page references held by slot page lists."""
    dense = chai = 0
    for pages in core._slot_pages:
        dense += len(pages.get("kg", ())) + len(pages.get("vg", ()))
        chai += len(pages.get("kc", ())) + len(pages.get("vc", ()))
    return dense, chai


def _audit_refs(core, out: List[str]):
    slot_dense, slot_chai = _slot_refs(core)
    cache_dense = cache_chai = 0
    if core.prefix_cache is not None:
        cache_dense, cache_chai = core.prefix_cache.held_pages()
    for name, pool, held in (
            ("dense_pool", core.dense_pool, slot_dense + cache_dense),
            ("chai_pool", core.chai_pool, slot_chai + cache_chai)):
        if pool is None:
            continue
        refs = int(sum(pool._rc.values()))
        if refs != held:
            out.append(f"{name}: {refs} outstanding references but "
                       f"slots+cache account for {held}")


def _tier_held(core):
    """Host/compressed page references explained by demoted prefix-cache
    entries and swapped-out (queued) requests, keyed ``(tier, kind)``."""
    from repro.serving import kv_tiers as kv_tiers_mod
    held = {}

    def add(tier, pages_by_pk):
        for pk, pages in pages_by_pk.items():
            key = (tier, kv_tiers_mod.POOL_OF[pk])
            held[key] = held.get(key, 0) + len(pages)

    cache = core.prefix_cache
    demoted = (kv_tiers_mod.TIER_HOST, kv_tiers_mod.TIER_COMP)
    if cache is not None:
        stack = [cache.root]
        while stack:
            node = stack.pop()
            for c in node.children.values():
                stack.append(c)
                if c.tier in demoted:
                    add(c.tier, c.tier_pages)
        for snap in cache._snapshots.values():
            if snap.tier in demoted:
                add(snap.tier, snap.tier_pages)
    for req in core.queue:
        rs = req.resume_state
        if rs and rs.get("tier_pages"):
            add(kv_tiers_mod.TIER_HOST, rs["tier_pages"])
    return held


def _audit_tiers(core, out: List[str]):
    """Host/compressed pool conservation, payload/refcount agreement,
    and cross-tier reference accounting."""
    tiers = getattr(core, "tiers", None)
    if tiers is None:
        return
    from repro.serving import kv_tiers as kv_tiers_mod
    tier_of = {"host": kv_tiers_mod.TIER_HOST,
               "compressed": kv_tiers_mod.TIER_COMP}
    held = _tier_held(core)
    for tname, by_kind in (("host", tiers.host),
                           ("compressed", tiers.comp)):
        for kind, pool in by_kind.items():
            if pool is None:
                continue
            name = f"{tname}_pool[{kind}]"
            _audit_pool(name, pool, out)
            live, stored = set(pool._rc), set(pool._data)
            orphans = sorted(stored - live)
            if orphans:
                out.append(f"{name}: orphaned payloads for pages "
                           f"{orphans}")
            missing = sorted(live - stored)
            if missing:
                out.append(f"{name}: referenced pages with no payload "
                           f"{missing}")
            refs = int(sum(pool._rc.values()))
            want = held.get((tier_of[tname], kind), 0)
            if refs != want:
                out.append(f"{name}: {refs} outstanding references but "
                           f"demoted entries + swapped-out requests "
                           f"account for {want}")


def _audit_phases(core, out: List[str]):
    legal_occupied = (chai_cache.PHASE_PREFILL, chai_cache.PHASE_WARMUP,
                      chai_cache.PHASE_STEADY)
    for i, req in enumerate(core._slot_req):
        phase = int(core._phases[i])
        if req is None:
            if phase != chai_cache.PHASE_FREE:
                out.append(f"slot {i}: empty but phase {phase}")
            if core._slot_count[i]:
                out.append(f"slot {i}: empty but count "
                           f"{core._slot_count[i]}")
            if core.paged and core._slot_pages[i]:
                out.append(f"slot {i}: empty but holds pages "
                           f"{sorted(core._slot_pages[i])}")
            if core._slot_locked[i]:
                out.append(f"slot {i}: empty but holds cache locks")
            continue
        if phase not in legal_occupied:
            out.append(f"slot {i}: uid={req.uid} illegal phase {phase}")
        if phase == chai_cache.PHASE_PREFILL \
                and core._slot_prefill_state[i] is None:
            out.append(f"slot {i}: uid={req.uid} PREFILL without a "
                       "chunked-prefill cursor")
        budget = req.max_new_tokens
        if not 0 <= core._slot_count[i] <= budget:
            out.append(f"slot {i}: uid={req.uid} count "
                       f"{core._slot_count[i]} outside [0, {budget}]")


def _audit_locks(core, out: List[str]):
    from repro.serving.prefix_cache import BlockNode
    for i, locked in enumerate(core._slot_locked):
        for e in locked:
            if e.locks < 1:
                out.append(f"slot {i}: pinned cache entry with lock "
                           f"count {e.locks}")
            # A locked node marked ``evicted`` is survivable BY DESIGN
            # (relay groups dissolve; the slot holds its own page refs)
            # — the breach is a pinned block whose PAGES were freed.
            if isinstance(e, BlockNode) and core.dense_pool is not None:
                for kind, page in (("kg", e.kg_page), ("vg", e.vg_page)):
                    if core.dense_pool.refcount(int(page)) < 1:
                        out.append(
                            f"slot {i}: pinned radix block's {kind} "
                            f"page {page} was freed while locked "
                            "(relay residency breach)")


def _audit_device(core, out: List[str]):
    """Deep mode: device block tables + phase vector vs host truth."""
    st = core._dev_state
    if st is None:
        return
    bt_of = {"kg": "bt_kg", "vg": "bt_vg", "kc": "bt_kc", "vc": "bt_vc"}
    pool_of = {"kg": core.dense_pool, "vg": core.dense_pool,
               "kc": core.chai_pool, "vc": core.chai_pool}
    tables = {k: np.asarray(st[v]) for k, v in bt_of.items() if v in st}
    for i in range(core.ecfg.batch_slots):
        for kind, bt in tables.items():
            if kind in ("kc", "vc") \
                    and int(core._phases[i]) != chai_cache.PHASE_STEADY:
                # Clustered pages are RESERVED at admission (host page
                # list) but their block-table rows are written only at
                # the CLUSTER transition / snapshot restore — before
                # STEADY the device row is legitimately empty.
                continue
            row = bt[i]
            want = list(core._slot_pages[i].get(kind, ()))
            got = [int(p) for p in row[:len(want)]]
            if got != [int(p) for p in want]:
                out.append(f"slot {i}: bt_{kind} row {got} != host "
                           f"pages {want}")
                continue
            tail = row[len(want):]
            if want and (tail != chai_cache.NULL_PAGE).any():
                out.append(f"slot {i}: bt_{kind} tail not nulled past "
                           f"{len(want)} pages")
            dead = [int(p) for p in want
                    if pool_of[kind].refcount(int(p)) < 1]
            if dead:
                out.append(f"slot {i}: bt_{kind} maps freed pages "
                           f"{dead}")
    if "phase" in st:
        dev_phase = np.asarray(st["phase"])
        for i in range(core.ecfg.batch_slots):
            host = int(core._phases[i])
            dev = int(dev_phase[i])
            # Chunked mid-PREFILL slots park the device phase at FREE so
            # the interleaved decode skips them; otherwise host==device.
            want = (chai_cache.PHASE_FREE
                    if host in (chai_cache.PHASE_FREE,
                                chai_cache.PHASE_PREFILL) else host)
            if dev != want:
                out.append(f"slot {i}: device phase {dev} != expected "
                           f"{want} (host {host})")


def audit(core, *, deep: bool = False) -> List[str]:
    """Audit one ``EngineCore``; returns violation strings (empty =
    clean). Safe to call between steps at any time."""
    out: List[str] = []
    if getattr(core, "_slot_req", None) is None:
        return out          # cohort engines carry no slot machinery
    if core.paged:
        _audit_pool("dense_pool", core.dense_pool, out)
        if core.chai_pool is not None:
            _audit_pool("chai_pool", core.chai_pool, out)
        _audit_refs(core, out)
        _audit_tiers(core, out)
    _audit_phases(core, out)
    _audit_locks(core, out)
    if deep and core.paged:
        _audit_device(core, out)
    return out


def audit_leaks(core) -> List[str]:
    """Leak gate for an IDLE engine (no active slots, empty queue):
    every outstanding page reference must be a prefix-cache reference
    and no cache entry may still be locked. Host/compressed tier pools
    are covered by the ``audit()`` call below — with an empty queue the
    cross-tier check demands every host page be owned by a demoted
    cache entry, so orphaned host pages fail the gate too. Used by the
    autouse conftest fixture around every serving-tier test."""
    out = audit(core)
    if core.has_active or core.queue:
        return out          # not idle: conservation checks only
    for name, pool, cache_held in _idle_expectations(core):
        refs = int(sum(pool._rc.values()))
        if refs != cache_held:
            out.append(f"{name}: {refs - cache_held} leaked page "
                       f"reference(s) on an idle engine "
                       f"({refs} held, cache explains {cache_held})")
    if core.prefix_cache is not None:
        locked = _locked_entries(core.prefix_cache)
        if locked:
            out.append(f"prefix cache: {locked} dangling lock(s) on an "
                       "idle engine")
    return out


def _idle_expectations(core):
    cache_dense = cache_chai = 0
    if core.prefix_cache is not None:
        cache_dense, cache_chai = core.prefix_cache.held_pages()
    pairs = []
    if core.dense_pool is not None:
        pairs.append(("dense_pool", core.dense_pool, cache_dense))
    if core.chai_pool is not None:
        pairs.append(("chai_pool", core.chai_pool, cache_chai))
    return pairs


def _locked_entries(cache) -> int:
    n = 0
    stack = [cache.root]
    while stack:
        node = stack.pop()
        for c in node.children.values():
            n += c.locks > 0
            stack.append(c)
    for snap in cache._snapshots.values():
        n += snap.locks > 0
    return n
