"""ShapeDtypeStruct input specs for every (arch x shape) dry-run cell.

No device allocation — shardable, weak-type-correct stand-ins. Frontend-stub
archs (musicgen, internvl2) receive precomputed frame/patch embeddings per
the assignment.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.sharding.rules import Ax


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig):
    b, t = shape.global_batch, shape.seq_len
    if cfg.frontend != "none":
        shapes = {"embeddings": jax.ShapeDtypeStruct((b, t, cfg.d_model),
                                                     jnp.bfloat16),
                  "labels": jax.ShapeDtypeStruct((b, t), jnp.int32)}
        logical = {"embeddings": Ax("batch", None, "embed"),
                   "labels": Ax("batch", None)}
    else:
        shapes = {"tokens": jax.ShapeDtypeStruct((b, t), jnp.int32),
                  "labels": jax.ShapeDtypeStruct((b, t), jnp.int32)}
        logical = {"tokens": Ax("batch", None), "labels": Ax("batch", None)}
    return shapes, logical


def prefill_input_specs(cfg: ModelConfig, shape: ShapeConfig):
    b, t = shape.global_batch, shape.seq_len
    if cfg.frontend != "none":
        return ({"embeddings": jax.ShapeDtypeStruct((b, t, cfg.d_model),
                                                    jnp.bfloat16)},
                {"embeddings": Ax("batch", None, "embed")})
    return ({"tokens": jax.ShapeDtypeStruct((b, t), jnp.int32)},
            {"tokens": Ax("batch", None)})


def decode_token_specs(cfg: ModelConfig, shape: ShapeConfig):
    b = shape.global_batch
    if cfg.frontend != "none":
        return ({"embeddings": jax.ShapeDtypeStruct((b, cfg.d_model),
                                                    jnp.bfloat16)},
                {"embeddings": Ax("batch", "embed")})
    return ({"tokens": jax.ShapeDtypeStruct((b,), jnp.int32)},
            {"tokens": Ax("batch")})
