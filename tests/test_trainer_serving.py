"""Integration: fault-tolerant trainer + CHAI serving engine end-to-end."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, reduced
from repro.data.pipeline import DataConfig
from repro.models import transformer as tfm
from repro.serving.engine import EngineConfig, ServingEngine
from repro.train.trainer import Trainer, TrainerConfig


def _tiny_cfg():
    return reduced(get_config("chai-llama-7b"), n_layers=2, d_model=32,
                   n_heads=4, d_ff=64, vocab=128).replace(dtype="float32")


def _data_cfg(vocab):
    return DataConfig(vocab_size=vocab, seq_len=32, global_batch=4)


# ---------------------------------------------------------------- train ----
def test_trainer_loss_decreases(tmp_path):
    cfg = _tiny_cfg()
    tcfg = TrainerConfig(total_steps=30, ckpt_every=100, log_every=100,
                         ckpt_dir=str(tmp_path))
    tr = Trainer(cfg, _data_cfg(cfg.vocab_size), tcfg)
    state = tr.init_state()
    batch0 = tr.pipe.global_batch_array(0)
    _, m0 = tr._one_step(state, batch0)
    state, metrics = tr.run()
    assert float(metrics["loss"]) < float(m0["loss"]) - 0.3


def test_trainer_restart_resumes_exactly(tmp_path):
    """Train 20 straight vs 10 + restart + 10: identical final loss
    (stateless-seeded data + checkpointed optimizer => bitwise resume)."""
    cfg = _tiny_cfg()
    d = _data_cfg(cfg.vocab_size)

    t1 = Trainer(cfg, d, TrainerConfig(total_steps=20, ckpt_every=100,
                                       log_every=100,
                                       ckpt_dir=str(tmp_path / "a")))
    _, m_straight = t1.run()

    kw = dict(total_steps=20, ckpt_every=10, log_every=100,
              ckpt_dir=str(tmp_path / "b"))
    t2 = Trainer(cfg, d, TrainerConfig(**kw))
    t2.run(max_steps=10)                      # "crash" after step 10
    t3 = Trainer(cfg, d, TrainerConfig(**kw))  # fresh process restarts
    _, m_resumed = t3.run()
    np.testing.assert_allclose(float(m_straight["loss"]),
                               float(m_resumed["loss"]), rtol=1e-5)


def test_trainer_straggler_hook_fires(tmp_path):
    cfg = _tiny_cfg()
    seen = []
    tr = Trainer(cfg, _data_cfg(cfg.vocab_size),
                 TrainerConfig(total_steps=8, ckpt_every=100, log_every=100,
                               ckpt_dir=str(tmp_path), straggler_factor=2.0),
                 step_delay_hook=lambda s: 0.5 if s == 5 else 0.0,
                 on_straggler=lambda s, dt: seen.append(s))
    tr.run()
    assert 5 in seen


def test_microbatched_matches_fused(tmp_path):
    """Gradient accumulation (2 microbatches) == fused step (same batch)."""
    from repro.launch import steps as steps_mod
    from repro.optim import adamw
    from repro.train import train_step as ts_mod
    cfg = _tiny_cfg()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16)),
                                   jnp.int32)}
    fused = steps_mod.make_train_step(cfg, remat=False)
    micro = ts_mod.make_microbatched_train_step(cfg, n_micro=2, remat=False)
    p1, _, m1 = fused(params, adamw.init(params), batch)
    p2, _, m2 = micro(params, adamw.init(params), batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


# ---------------------------------------------------------------- serve ----
def _engine(cfg, **kw):
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    ecfg = EngineConfig(batch_slots=2, max_seq=64, **kw)
    return ServingEngine(cfg, params, ecfg)


def test_engine_generates_all_requests(rng):
    cfg = _tiny_cfg().with_chai(enabled=True)
    eng = _engine(cfg)
    for i in range(4):
        eng.submit(rng.integers(0, cfg.vocab_size, size=8),
                   max_new_tokens=10, uid=i)
    done = eng.run()
    assert len(done) == 4
    for r in done:
        assert len(r.generated) == 10
        assert r.ttft >= 0 and r.latency >= r.ttft


def test_engine_warmup_matches_mha(rng):
    """Tokens generated during the MHA warmup phase are identical with
    CHAI on and off (CHAI only kicks in after warmup_tokens)."""
    cfg = _tiny_cfg().with_chai(enabled=True, warmup_tokens=5)
    prompts = [rng.integers(0, cfg.vocab_size, size=8) for _ in range(2)]

    outs = {}
    for use_chai in (True, False):
        eng = _engine(cfg, use_chai=use_chai)
        for i, p in enumerate(prompts):
            eng.submit(p, max_new_tokens=8, uid=i)
        done = sorted(eng.run(), key=lambda r: r.uid)
        outs[use_chai] = [r.generated for r in done]
    warm = cfg.chai.warmup_tokens
    for g_chai, g_mha in zip(outs[True], outs[False]):
        assert g_chai[:warm + 1] == g_mha[:warm + 1]


def test_engine_deadline_redispatch(rng):
    """A cohort that blows its deadline is re-queued, then completes."""
    cfg = _tiny_cfg().with_chai(enabled=True)
    eng = _engine(cfg, cohort_deadline_s=0.0)   # everything times out
    eng.submit(rng.integers(0, cfg.vocab_size, size=8), max_new_tokens=4)
    # run() re-queues once; flip the deadline so the retry succeeds
    orig = eng._run_cohort

    def patched(cohort):
        eng.ecfg.cohort_deadline_s = 300.0
        return orig(cohort)

    # first attempt raises TimeoutError internally; retry path succeeds
    try:
        eng._run_cohort([eng.queue[0]])
    except TimeoutError:
        pass
    eng._run_cohort = patched
    done = eng.run()
    assert len(done) == 1 and len(done[0].generated) == 4


def test_engine_kv_bytes_reports_saving():
    cfg = reduced(get_config("chai-llama-7b")).with_chai(enabled=True)
    eng = _engine(cfg.replace(dtype="float32"))
    assert eng.kv_bytes(chai=True) < eng.kv_bytes(chai=False)
