"""Frontend API: LLM.generate / LLM.stream / abort / multi-turn Session.

Acceptance (ISSUE 5): ``LLM.stream()`` yields tokens incrementally (the
first chunk arrives before the request completes), ``abort()`` mid-stream
frees all pages (pool counters return to baseline), and a 3-turn
``Session`` reuses cached prefix pages so later turns prefill only the
new suffix — all under both greedy and seeded-sampling SamplingParams.
"""
import numpy as np
import pytest

import jax

from repro.configs.base import get_config, reduced
from repro.models import transformer as tfm
from repro.serving.api import LLM, Session
from repro.serving.engine import EngineConfig
from repro.serving.sampling import SamplingParams

MHA_ARCH = "chai-llama-7b"      # clustered CHAI (snapshot fast path)
GQA_ARCH = "nemotron-4-15b"     # dense pages survive to retirement

GREEDY = SamplingParams(max_new_tokens=10)
SEEDED = SamplingParams(temperature=0.8, top_k=16, top_p=0.95, seed=5,
                        max_new_tokens=10)


def _cfg(arch=MHA_ARCH):
    cfg = reduced(get_config(arch), n_layers=2, d_model=32, d_ff=64,
                  vocab=64).replace(dtype="float32")
    return cfg.with_chai(enabled=True, warmup_tokens=3)


def _llm(cfg, **ecfg_kw):
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    return LLM(cfg, params, EngineConfig(batch_slots=2, max_seq=128,
                                         page_size=16, **ecfg_kw))


def _pool_counters(core):
    out = {"dense": core.dense_pool.counters()}
    if core.chai_pool is not None:
        out["chai"] = core.chai_pool.counters()
    return out


@pytest.mark.parametrize("sp", [GREEDY, SEEDED], ids=["greedy", "seeded"])
def test_generate_batch_and_single(sp):
    cfg = _cfg()
    llm = _llm(cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=8) for _ in range(3)]
    outs = llm.generate(prompts, sp)
    assert len(outs) == 3
    for o in outs:
        assert len(o.token_ids) == sp.max_new_tokens
        assert o.finish_reason == "length"
    # single-prompt call: same engine, same params -> same tokens
    again = llm.generate(prompts[0], sp)
    assert len(again) == 1
    assert again[0].token_ids == outs[0].token_ids


@pytest.mark.parametrize("sp", [GREEDY, SEEDED], ids=["greedy", "seeded"])
def test_stream_yields_tokens_incrementally(sp):
    """First chunk arrives strictly before the request finishes; chunks
    concatenate to exactly the generate() output; the final chunk is
    flagged finished."""
    cfg = _cfg()
    llm = _llm(cfg)
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, size=8)
    want = llm.generate(prompt, sp)[0].token_ids

    chunks = list(llm.stream(prompt, sp))
    assert len(chunks) > 1                      # incremental, not one blob
    assert not chunks[0].finished               # first token precedes EOS
    assert chunks[-1].finished
    assert chunks[-1].finish_reason == "length"
    got = [t for c in chunks for t in c.token_ids]
    assert got == want


@pytest.mark.parametrize("sp", [GREEDY, SEEDED], ids=["greedy", "seeded"])
def test_abort_mid_stream_frees_all_pages(sp):
    """Acceptance: abort() mid-stream ends the iterator and returns the
    pool counters to their pre-request baseline."""
    cfg = _cfg()
    llm = _llm(cfg)
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab_size, size=8)
    llm.generate(prompt, sp)                    # warm the jits
    base = _pool_counters(llm.core)

    it = llm.stream(rng.integers(0, cfg.vocab_size, size=8), sp)
    first = next(it)
    assert not first.finished
    assert llm.abort(first.uid) is True
    tail = list(it)                 # ends with an empty terminal chunk
    assert len(tail) == 1 and tail[0].finished
    assert tail[0].finish_reason == "aborted" and tail[0].token_ids == []
    assert _pool_counters(llm.core) == base
    assert not llm.core.has_work()


@pytest.mark.parametrize("sp", [GREEDY, SEEDED], ids=["greedy", "seeded"])
def test_abandoned_stream_aborts_and_frees_slot(sp):
    """Regression: breaking out of (or dropping) a stream iterator
    aborts its request — an abandoned stream cannot pin a batch slot or
    its pages, and later generate() calls are not starved."""
    cfg = _cfg()
    llm = _llm(cfg)
    rng = np.random.default_rng(9)
    llm.generate(rng.integers(0, cfg.vocab_size, size=8), sp)  # warm jits
    base = _pool_counters(llm.core)
    uids = []
    for _ in range(3):              # more abandoned streams than slots
        it = llm.stream(rng.integers(0, cfg.vocab_size, size=8), sp)
        uids.append(next(it).uid)
        it.close()                  # same as break-ing out of the loop
    assert _pool_counters(llm.core) == base
    assert not llm.core.has_work()
    aborted = [r for r in llm.core.reap_done() if r.uid in uids]
    assert [r.finish_reason for r in aborted] == ["aborted"] * 3
    # an iterator dropped BEFORE its first __next__ enqueues nothing
    # (submission happens when iteration begins)
    llm.stream(rng.integers(0, cfg.vocab_size, size=8), sp).close()
    assert not llm.core.queue and not llm.core.has_work()
    # the engine still serves normally afterwards
    out = llm.generate(rng.integers(0, cfg.vocab_size, size=8), sp)[0]
    assert len(out.token_ids) == sp.max_new_tokens


def test_stream_never_drops_tokens_under_concurrent_drivers():
    """Regression: chunks are cut against the Request's token list, so a
    stream loses nothing when OTHER frontend calls drive the shared core
    — a concurrent generate() completing the streamed request, and two
    interleaved streams, both deliver every token."""
    cfg = _cfg()
    llm = _llm(cfg)
    rng = np.random.default_rng(8)
    p1 = rng.integers(0, cfg.vocab_size, size=8)
    p2 = rng.integers(0, cfg.vocab_size, size=8)
    want1 = llm.generate(p1, GREEDY)[0].token_ids
    want2 = llm.generate(p2, GREEDY)[0].token_ids

    # (a) a generate() call runs the streamed request to completion
    # before the stream is consumed: the stream must still replay it all
    it = llm.stream(p1, GREEDY)
    llm.generate(p2, GREEDY)
    chunks = list(it)
    assert [t for c in chunks for t in c.token_ids] == want1
    assert chunks[-1].finished

    # (b) two interleaved streams: alternate consumption, no loss
    it1, it2 = llm.stream(p1, GREEDY), llm.stream(p2, GREEDY)
    got1, got2 = [], []
    done1 = done2 = False
    while not (done1 and done2):
        if not done1:
            c = next(it1, None)
            if c is None:
                done1 = True
            else:
                got1 += c.token_ids
        if not done2:
            c = next(it2, None)
            if c is None:
                done2 = True
            else:
                got2 += c.token_ids
    assert got1 == want1 and got2 == want2


def test_stream_interleaves_with_background_requests():
    """A stream driven beside queued requests advances them too: the
    shared core keeps continuous batching across frontend calls."""
    cfg = _cfg()
    llm = _llm(cfg)
    rng = np.random.default_rng(3)
    p_bg = rng.integers(0, cfg.vocab_size, size=8)
    p_st = rng.integers(0, cfg.vocab_size, size=8)
    bg = llm.core.add_request(p_bg, GREEDY)
    chunks = list(llm.stream(p_st, GREEDY))
    assert [t for c in chunks for t in c.token_ids] != []
    assert bg.finished                          # background rode along
    assert len(bg.generated) == GREEDY.max_new_tokens


@pytest.mark.slow
@pytest.mark.parametrize("sp", [GREEDY, SEEDED], ids=["greedy", "seeded"])
def test_three_turn_session_reuses_prefix_pages(sp):
    """Acceptance: a 3-turn Session over a prefix-cached engine serves
    later turns from cached pages — turn 2/3 prefill strictly less than
    their prompts (pages saved > 0). On a GQA arch retiring slots index
    their FULL sequence, so turn N+1 prefills only the new user message
    (up to block rounding)."""
    cfg = _cfg(GQA_ARCH)
    llm = _llm(cfg, prefix_cache=True)
    ses = Session(llm, sp)
    rng = np.random.default_rng(4)
    ps = llm.core.ecfg.page_size
    turn1 = ses.send(rng.integers(0, cfg.vocab_size, size=32))
    assert turn1.cached_tokens == 0
    saved_pages = 0
    for _ in (2, 3):
        msg = rng.integers(0, cfg.vocab_size, size=6)
        hist_len = len(ses.history)
        out = ses.send(msg)
        assert out.cached_tokens > 0                      # reuse happened
        assert out.prefill_tokens < hist_len + len(msg)   # not a cold run
        # full-sequence indexing: only the tail past the last cached
        # block boundary is forwarded — the new message + block remainder
        assert out.prefill_tokens <= len(msg) + ps
        saved_pages += out.cached_tokens // ps
    assert saved_pages > 0
    assert len(ses.turns) == 3
    assert len(ses.history) == (32 + 6 + 6
                                + 3 * sp.max_new_tokens)
    llm.core.prefix_cache.clear()
    assert llm.core.dense_pool.pages_in_use == 0


@pytest.mark.slow
def test_session_on_clustered_mha_arch_still_saves():
    """On the MHA+CHAI arch dense K pages are freed at compaction, so
    full-sequence indexing is skipped — but turn N+1 still aliases the
    PROMPT blocks of earlier turns (cached_tokens > 0)."""
    cfg = _cfg(MHA_ARCH)
    llm = _llm(cfg, prefix_cache=True)
    ses = Session(llm, GREEDY)
    rng = np.random.default_rng(5)
    ses.send(rng.integers(0, cfg.vocab_size, size=32))
    out2 = ses.send(rng.integers(0, cfg.vocab_size, size=6))
    assert out2.cached_tokens > 0
    assert out2.prefill_tokens < len(ses.turns[1].prompt_token_ids)
    llm.core.prefix_cache.clear()
    assert llm.core.dense_pool.pages_in_use == 0
    assert llm.core.chai_pool.pages_in_use == 0


def test_llm_detokenizer_stop_strings_and_text():
    cfg = _cfg()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    detok = lambda ids: " ".join(map(str, ids))
    llm = LLM(cfg, params, EngineConfig(batch_slots=2, max_seq=64),
              detokenizer=detok)
    rng = np.random.default_rng(6)
    prompt = rng.integers(0, cfg.vocab_size, size=8)
    probe = llm.generate(prompt, SamplingParams(max_new_tokens=8))[0]
    assert probe.text == detok(probe.token_ids)
    stop = " ".join(map(str, probe.token_ids[3:5]))
    out = llm.generate(prompt, SamplingParams(max_new_tokens=8,
                                              stop=(stop,)))[0]
    assert out.finish_reason == "stop"
    assert len(out.token_ids) == 5              # truncated at the match
    # stop strings without a detokenizer are rejected at submission
    bare = _llm(cfg)
    with pytest.raises(ValueError):
        bare.generate(prompt, SamplingParams(stop=("x",)))


def test_uid_monotonic_no_collision_after_retirement():
    """Satellite: default uids come from a monotonic counter — they can
    no longer collide after retirement interleaving (the old default was
    len(queue) + len(done), which repeats once requests retire)."""
    cfg = _cfg()
    llm = _llm(cfg)
    rng = np.random.default_rng(7)
    uids = []
    for _ in range(3):
        out = llm.generate(rng.integers(0, cfg.vocab_size, size=8),
                           SamplingParams(max_new_tokens=2))
        uids.append(out[0].uid)
    assert len(set(uids)) == 3
    # explicit uids bump the counter past themselves
    req = llm.core.add_request(rng.integers(0, cfg.vocab_size, size=8),
                               SamplingParams(max_new_tokens=2), uid=50)
    nxt = llm.core.add_request(rng.integers(0, cfg.vocab_size, size=8),
                               SamplingParams(max_new_tokens=2))
    assert req.uid == 50 and nxt.uid == 51
    while llm.core.has_work():
        llm.core.step()
