"""Engine telemetry: registry semantics, exporters, tiers, spans,
lifecycle timelines, decode healing, and the new kernel fault sites.

The cost contract (off-tier jaxpr identity, basic-tier overhead bound)
is gated end-to-end by ``benchmarks/bench_telemetry_overhead.py``; here
the jaxpr-identity claim gets a fast unit check and everything else is
exercised at the Python level.
"""
import asyncio
import json

import numpy as np
import pytest

import jax

from repro.configs.base import get_config, reduced
from repro.launch.steps import jaxpr_text
from repro.models import transformer as tfm
from repro.serving import exporters
from repro.serving.async_api import AsyncLLM
from repro.serving.engine import EngineConfig, EngineCore
from repro.serving.faults import FaultInjector, FaultSpec
from repro.serving.sampling import SamplingParams
from repro.serving.telemetry import (MetricsRegistry, NullTelemetry,
                                     Telemetry, make_telemetry,
                                     summarize_timeline)

ARCH = "chai-llama-7b"
GREEDY = SamplingParams(max_new_tokens=8)

_params_cache = {}


def _model():
    if ARCH not in _params_cache:
        cfg = reduced(get_config(ARCH), n_layers=2, d_model=32, d_ff=64,
                      vocab=64).replace(dtype="float32")
        cfg = cfg.with_chai(enabled=True, warmup_tokens=3)
        _params_cache[ARCH] = (cfg,
                               tfm.init_params(cfg, jax.random.PRNGKey(0)))
    return _params_cache[ARCH]


def _ecfg(**kw):
    kw.setdefault("batch_slots", 2)
    kw.setdefault("max_seq", 64)
    kw.setdefault("page_size", 8)
    kw.setdefault("audit_level", "deep")
    kw.setdefault("telemetry", "trace")
    return EngineConfig(**kw)


def _drain(core, max_steps=400):
    outs = []
    for _ in range(max_steps):
        if not core.has_work():
            return outs
        outs.extend(core.step())
    raise AssertionError(f"engine did not drain in {max_steps} steps")


def _prompts(n, length=(6, 14), seed=0, vocab=64):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, vocab, size=int(rng.integers(*length))).tolist()
            for _ in range(n)]


def _counter_value(snap, name, **labels):
    total = 0.0
    for s in snap["counters"].get(name, {"series": []})["series"]:
        if all(s["labels"].get(k) == v for k, v in labels.items()):
            total += s["value"]
    return total


# ---------------------------------------------------------------------------
# registry semantics (pure units)
# ---------------------------------------------------------------------------
def test_registry_counter_gauge_histogram_series():
    reg = MetricsRegistry()
    reg.counter("req_total", 2, labels={"kind": "cold"}, help="h")
    reg.counter("req_total", labels={"kind": "cold"})
    reg.counter("req_total", labels={"kind": "warm"})
    reg.gauge("depth", 4)
    reg.gauge("depth", 7)                     # gauges overwrite
    reg.observe("lat_seconds", 0.003, buckets=(0.001, 0.01, 0.1))
    reg.observe("lat_seconds", 5.0, buckets=(0.001, 0.01, 0.1))
    reg.observe("lat_seconds", float("nan"), buckets=(0.001, 0.01, 0.1))
    snap = reg.snapshot()
    assert _counter_value(snap, "req_total", kind="cold") == 3
    assert _counter_value(snap, "req_total", kind="warm") == 1
    assert snap["gauges"]["depth"]["series"][0]["value"] == 7
    h = snap["histograms"]["lat_seconds"]["series"][0]
    assert h["count"] == 2 and h["sum"] == pytest.approx(5.003)
    assert h["counts"] == [0, 1, 0, 1]        # NaN dropped, 5.0 -> +Inf
    json.dumps(snap)                          # snapshot is JSON-ready
    with pytest.raises(ValueError):
        reg.counter("neg_total", -1)


def test_registry_merge_adds_counters_and_buckets():
    a, b = MetricsRegistry(), MetricsRegistry()
    for reg, n in ((a, 2), (b, 5)):
        reg.counter("req_total", n, labels={"kind": "x"})
        reg.observe("lat_seconds", 0.002, buckets=(0.001, 0.01))
        reg.gauge("depth", n)
    a.merge(b.snapshot())
    snap = a.snapshot()
    assert _counter_value(snap, "req_total", kind="x") == 7
    h = snap["histograms"]["lat_seconds"]["series"][0]
    assert h["count"] == 2 and h["counts"][1] == 2
    # merged gauges read as cross-shard totals
    assert snap["gauges"]["depth"]["series"][0]["value"] == 7
    bad = MetricsRegistry()
    bad.observe("lat_seconds", 0.002, buckets=(0.5,))
    with pytest.raises(ValueError):
        a.merge(bad.snapshot())


def test_summarize_timeline_derivations():
    evs = [
        {"uid": 1, "ev": "enqueue", "t": 10.0},
        {"uid": 1, "ev": "admit", "t": 10.5},
        {"uid": 1, "ev": "phase", "t": 10.5, "phase": "PREFILL"},
        {"uid": 1, "ev": "first_token", "t": 11.0},
        {"uid": 1, "ev": "tokens", "t": 11.0, "n": 1},
        {"uid": 1, "ev": "tokens", "t": 11.2, "n": 1},
        {"uid": 1, "ev": "preempt", "t": 11.3},
        {"uid": 1, "ev": "tokens", "t": 11.6, "n": 1},
        {"uid": 1, "ev": "finish", "t": 11.7, "reason": "length"},
    ]
    s = summarize_timeline(evs)
    assert s["queue_s"] == pytest.approx(0.5)
    assert s["ttft_s"] == pytest.approx(1.0)
    assert s["latency_s"] == pytest.approx(1.7)
    assert s["n_tokens"] == 3 and s["preemptions"] == 1
    assert s["itl_s"] == [pytest.approx(0.2), pytest.approx(0.4)]
    assert s["phases"] == ["PREFILL"] and s["finish_reason"] == "length"


def test_make_telemetry_tiers():
    assert isinstance(make_telemetry("off"), NullTelemetry)
    assert not make_telemetry("off").enabled
    assert isinstance(make_telemetry("basic"), Telemetry)
    assert make_telemetry("trace").tracing
    assert not make_telemetry("basic").tracing
    with pytest.raises(ValueError):
        make_telemetry("verbose")
    cfg, params = _model()
    with pytest.raises(ValueError):
        EngineCore(cfg, params, _ecfg(telemetry="loud"))
    with pytest.raises(ValueError):
        EngineCore(cfg, params, _ecfg(decode_heal_steps=-1))


# ---------------------------------------------------------------------------
# exporters (pure units)
# ---------------------------------------------------------------------------
def test_prometheus_text_roundtrip():
    reg = MetricsRegistry()
    reg.counter("req_total", 3, labels={"kind": "cold"}, help="requests")
    reg.gauge("depth", 2, help="queue depth")
    reg.observe("lat_seconds", 0.002, buckets=(0.001, 0.01), help="lat")
    text = exporters.to_prometheus(reg.snapshot())
    parsed = exporters.parse_prometheus(text)
    samples = {(n, tuple(sorted(l.items()))): v
               for n, l, v in parsed["samples"]}
    assert samples[("req_total", (("kind", "cold"),))] == 3
    assert samples[("depth", ())] == 2
    assert parsed["types"]["req_total"] == "counter"
    assert parsed["types"]["lat_seconds"] == "histogram"
    # histogram buckets are cumulative and end at +Inf == _count
    assert samples[("lat_seconds_bucket", (("le", "0.001"),))] == 0
    assert samples[("lat_seconds_bucket", (("le", "0.01"),))] == 1
    assert samples[("lat_seconds_bucket", (("le", "+Inf"),))] == 1
    assert samples[("lat_seconds_count", ())] == 1
    with pytest.raises(ValueError):
        exporters.parse_prometheus("not a metric line at all{")


def test_chrome_trace_roundtrip_and_validation():
    spans = [{"name": "step", "step": 3, "t0": 1.0, "t1": 1.5,
              "args": {"slots": 2}, "error": False},
             {"name": "sample", "step": 3, "t0": 1.1, "t1": 1.2,
              "args": {}, "error": True}]
    obj = exporters.to_chrome_trace(spans)
    evs = exporters.from_chrome_trace(json.dumps(obj))
    assert [e["name"] for e in evs] == ["step", "sample"]
    assert evs[0]["ph"] == "X" and evs[0]["dur"] == pytest.approx(5e5)
    assert evs[0]["args"]["step"] == 3 and evs[0]["args"]["slots"] == 2
    assert evs[1]["args"]["error"] is True
    with pytest.raises(ValueError):
        exporters.from_chrome_trace('{"no": "traceEvents"}')
    with pytest.raises(ValueError):
        exporters.from_chrome_trace(
            {"traceEvents": [{"ph": "X", "ts": 0, "pid": 0, "tid": 0}]})


def test_jsonl_events_roundtrip():
    evs = [{"uid": 2, "ev": "enqueue", "t": 5.0},
           {"uid": 1, "ev": "enqueue", "t": 4.0}]
    text = exporters.events_jsonl(evs)
    back = exporters.read_jsonl(text)
    assert [e["uid"] for e in back] == [1, 2]     # globally time-ordered


# ---------------------------------------------------------------------------
# engine integration: tiers, spans, timelines
# ---------------------------------------------------------------------------
def test_off_tier_is_noop():
    cfg, params = _model()
    core = EngineCore(cfg, params, _ecfg(telemetry="off"))
    reqs = [core.add_request(p, GREEDY) for p in _prompts(2)]
    _drain(core)
    assert all(r.finish_reason == "length" for r in reqs)
    assert core.metrics() is None and core.metrics_text() is None
    assert core.request_timeline(reqs[0].uid) is None
    assert core.step_trace()["traceEvents"] == []
    assert isinstance(core.tel, NullTelemetry)


def test_off_tier_decode_step_jaxpr_identical():
    """The telemetry tier never reaches the device program (fast unit
    variant of the bench gate): identical decode-step jaxpr text for an
    off engine and a trace engine."""
    cfg, params = _model()
    off = EngineCore(cfg, params, _ecfg(telemetry="off"))
    trc = EngineCore(cfg, params, _ecfg(telemetry="trace"))
    off.add_request(_prompts(1)[0], GREEDY)
    _drain(off)
    ex = (off.params, {"tokens": off._next_tok_dev}, off._dev_state)
    assert jaxpr_text(off._mha_step, *ex) == jaxpr_text(trc._mha_step, *ex)
    cex = ex + (off._dev_ctx,)
    assert (jaxpr_text(off._chai_step, *cex)
            == jaxpr_text(trc._chai_step, *cex))


def test_trace_tier_step_spans_cover_stages():
    cfg, params = _model()
    core = EngineCore(cfg, params, _ecfg())
    [core.add_request(p, GREEDY) for p in _prompts(2)]
    _drain(core)
    by_step = {}
    for sp in core.tel.spans:
        by_step.setdefault(sp["step"], []).append(sp["name"])
    decode_steps = {s: n for s, n in by_step.items()
                    if "decode.dispatch" in n}
    assert decode_steps, by_step
    for names in decode_steps.values():
        assert names.count("admit") >= 1
        for stage in ("cluster", "decode.dispatch", "sample", "retire",
                      "step", "audit"):
            assert names.count(stage) == 1, (stage, names)
    # step ordinals are unique per step() call, monotone
    steps = sorted(by_step)
    assert steps == list(range(steps[0], steps[0] + len(steps)))
    # basic tier records no spans at all
    core2 = EngineCore(cfg, params, _ecfg(telemetry="basic"))
    core2.add_request(_prompts(1)[0], GREEDY)
    _drain(core2)
    assert core2.tel.spans == []


def test_request_timeline_lifecycle_and_metrics():
    cfg, params = _model()
    core = EngineCore(cfg, params, _ecfg(telemetry="basic",
                                         prefix_cache=True))
    reqs = [core.add_request(p, GREEDY) for p in _prompts(3, seed=2)]
    _drain(core)
    for r in reqs:
        tl = core.request_timeline(r.uid)
        names = [e["ev"] for e in tl["events"]]
        assert names[0] == "enqueue" and names[-1] == "finish"
        assert "admit" in names and "first_token" in names
        s = tl["summary"]
        assert s["n_tokens"] == len(r.generated) == 8
        assert s["finish_reason"] == "length"
        assert 0 <= s["queue_s"] and 0 <= s["ttft_s"] <= s["latency_s"]
        # CHAI phase walk appears on the timeline in engine order
        phases = [p for p in s["phases"]
                  if p in ("PREFILL", "WARMUP", "CLUSTER", "STEADY")]
        assert phases == ["PREFILL", "WARMUP", "CLUSTER", "STEADY"], s
    snap = core.metrics()
    assert _counter_value(snap, "requests_finished_total",
                          reason="length") == 3
    assert _counter_value(snap, "tokens_generated_total") == 24
    assert _counter_value(snap, "cluster_transitions_total") == 3
    assert snap["gauges"]["engine_active_slots"]["series"][0]["value"] == 0
    hist = snap["histograms"]["request_ttft_seconds"]["series"][0]
    assert hist["count"] == 3
    parsed = exporters.parse_prometheus(core.metrics_text())
    assert ("engine_steps_total" in parsed["types"]
            and parsed["types"]["request_ttft_seconds"] == "histogram")
    assert core.request_timeline(10**9) is None


def test_timeline_preempt_and_resume_events():
    cfg, params = _model()
    core = EngineCore(cfg, params, _ecfg(batch_slots=2, telemetry="basic",
                                         preemption=True))
    low = [core.add_request(p, SamplingParams(max_new_tokens=10))
           for p in _prompts(2, seed=4)]
    core.step()
    core.step()
    hi = core.add_request(_prompts(1, seed=5)[0],
                          SamplingParams(max_new_tokens=4), priority=1)
    _drain(core)
    assert hi.finish_reason == "length"
    victim = next(r for r in low if r.preemptions > 0)
    tl = core.request_timeline(victim.uid)
    names = [e["ev"] for e in tl["events"]]
    assert "preempt" in names and "resume" in names
    assert tl["summary"]["preemptions"] == victim.preemptions
    snap = core.metrics()
    assert _counter_value(snap, "preemptions_total") >= 1


# ---------------------------------------------------------------------------
# decode healing (satellite 1)
# ---------------------------------------------------------------------------
def test_decode_heals_after_clean_steps_with_parity():
    """``decode_heal_steps=N``: a transient fused-decode failure degrades
    to the jnp reference path, then N consecutive clean decode steps
    flip the engine back to the fused jits — tokens bitwise match the
    fault-free run across the degrade AND the heal."""
    cfg, params = _model()
    prompts = _prompts(2, seed=6)

    def run(faults, heal):
        core = EngineCore(cfg, params,
                          _ecfg(telemetry="basic",
                                decode_heal_steps=heal), faults=faults)
        reqs = [core.add_request(p, SamplingParams(max_new_tokens=12))
                for p in prompts]
        _drain(core)
        return core, reqs

    _, clean = run(None, 3)
    inj = FaultInjector([FaultSpec("kernel.decode", count=1)], seed=0)
    core, reqs = run(inj, 3)
    fs = core.fault_stats()
    assert fs["decode_fallbacks"] == 1
    assert fs["decode_heals"] == 1
    assert fs["degraded_decode"] is False       # healed before drain
    for c, f in zip(clean, reqs):
        assert list(f.generated) == list(c.generated)
    snap = core.metrics()
    assert _counter_value(snap, "decode_heals_total") == 1
    assert _counter_value(snap, "decode_fallbacks_total") == 1
    assert (snap["gauges"]["engine_degraded_decode"]["series"][0]["value"]
            == 0)


def test_decode_heal_resets_on_refire():
    """A kernel arm that keeps firing while degraded pins the engine on
    the reference path: every firing resets the clean-step count, so
    with an unlimited arm the engine must NOT heal."""
    cfg, params = _model()
    inj = FaultInjector([FaultSpec("kernel.decode", count=-1)], seed=0)
    core = EngineCore(cfg, params, _ecfg(decode_heal_steps=2),
                      faults=inj)
    core.add_request(_prompts(1)[0], GREEDY)
    _drain(core)
    fs = core.fault_stats()
    assert fs["degraded_decode"] is True and fs["decode_heals"] == 0


def test_decode_heal_disabled_by_default():
    cfg, params = _model()
    assert EngineConfig().decode_heal_steps == 0
    inj = FaultInjector([FaultSpec("kernel.decode", count=1)], seed=0)
    core = EngineCore(cfg, params, _ecfg(), faults=inj)
    core.add_request(_prompts(1)[0], GREEDY)
    _drain(core)
    fs = core.fault_stats()
    assert fs["degraded_decode"] is True and fs["decode_heals"] == 0


# ---------------------------------------------------------------------------
# new kernel fault sites (satellite 2)
# ---------------------------------------------------------------------------
def test_kernel_prefill_fault_quarantines_request():
    """An injected prefill-kernel failure quarantines THAT request at
    admission; the other request decodes to parity with a clean run."""
    cfg, params = _model()
    prompts = _prompts(2, seed=8)

    def run(faults):
        core = EngineCore(cfg, params, _ecfg(telemetry="basic"),
                          faults=faults)
        reqs = [core.add_request(p, GREEDY) for p in prompts]
        _drain(core)
        return core, reqs

    _, clean = run(None)
    inj = FaultInjector([FaultSpec("kernel.prefill", uid=0, count=1)],
                        seed=0)
    core, reqs = run(inj)
    assert [f["site"] for f in inj.fired] == ["kernel.prefill"]
    assert reqs[0].finish_reason == "error"
    assert "prefill" in reqs[0].error
    assert reqs[1].finish_reason == "length"
    assert list(reqs[1].generated) == list(clean[1].generated)
    assert core.fault_stats()["quarantined"] == 1
    snap = core.metrics()
    assert _counter_value(snap, "faults_injected_total",
                          site="kernel.prefill") == 1
    assert _counter_value(snap, "requests_quarantined_total") == 1


def test_kernel_cluster_fault_quarantines_transitioning_request():
    """An injected clustering-kernel failure at the WARMUP->CLUSTER edge
    quarantines the transitioning request BEFORE the pools mutate; the
    other slot keeps decoding to parity."""
    cfg, params = _model()
    prompts = _prompts(2, seed=9)

    def run(faults):
        core = EngineCore(cfg, params, _ecfg(telemetry="basic"),
                          faults=faults)
        reqs = [core.add_request(p, GREEDY) for p in prompts]
        _drain(core)
        return core, reqs

    _, clean = run(None)
    inj = FaultInjector([FaultSpec("kernel.cluster", uid=1, count=1)],
                        seed=0)
    core, reqs = run(inj)
    assert [f["site"] for f in inj.fired] == ["kernel.cluster"]
    assert reqs[1].finish_reason == "error"
    assert "cluster" in reqs[1].error
    assert reqs[0].finish_reason == "length"
    assert list(reqs[0].generated) == list(clean[0].generated)
    assert core.fault_stats()["quarantined"] == 1
    snap = core.metrics()
    assert _counter_value(snap, "faults_injected_total",
                          site="kernel.cluster") == 1
    # the quarantine landed on the victim's timeline
    tl = core.request_timeline(reqs[1].uid)
    assert "quarantine" in [e["ev"] for e in tl["events"]]


def test_soak_report_carries_telemetry_section():
    from repro.serving.soak import run_soak
    cfg, params = _model()
    ecfg = _ecfg(batch_slots=3, prefix_cache=True, telemetry="trace")
    report = run_soak(cfg, params, ecfg, seed=3, n_requests=8)
    tel = report["telemetry"]
    assert tel["metrics"]["counters"]["engine_steps_total"]
    assert tel["chrome_trace"]["traceEvents"]
    assert tel["timelines"]
    json.dumps(report, default=float)           # report stays JSON-ready
    off = run_soak(cfg, params, _ecfg(batch_slots=3, prefix_cache=True,
                                      telemetry="off"),
                   seed=3, n_requests=8)
    assert "telemetry" not in off
    # telemetry never perturbs the deterministic sections
    assert off["requests"] == report["requests"]


# ---------------------------------------------------------------------------
# async front door (satellite 3's engine-side accessors)
# ---------------------------------------------------------------------------
def test_async_metrics_and_timeline_accessors():
    cfg, params = _model()
    rng = np.random.default_rng(3)
    prompt = rng.integers(1, cfg.vocab_size, size=8).tolist()

    async def main():
        kw = dict(batch_slots=2, max_seq=64, page_size=8,
                  telemetry="trace")
        async with AsyncLLM(cfg, params, EngineConfig(**kw)) as llm:
            out = await llm.generate(prompt, GREEDY)
            assert len(out.token_ids) == 8
            text = await llm.metrics_text()
            parsed = exporters.parse_prometheus(text)
            names = {s[0] for s in parsed["samples"]}
            assert {"requests_finished_total", "driver_restarts",
                    "tokens_generated_total"} <= names
            tl = await llm.timeline(out.uid)
            assert tl["summary"]["n_tokens"] == 8
            assert await llm.timeline(10**9) is None
            trace = await llm.step_trace()
            assert any(e["name"] == "decode.dispatch"
                       for e in trace["traceEvents"])
        async with AsyncLLM(cfg, params, EngineConfig(
                batch_slots=2, max_seq=64, page_size=8,
                telemetry="off")) as llm_off:
            await llm_off.generate(prompt, GREEDY)
            assert await llm_off.metrics() is None
            assert await llm_off.metrics_text() is None

    asyncio.run(main())
