"""Step-driven serving core with a per-slot CHAI phase machine.

Request lifecycle (paper Fig 10), tracked PER BATCH SLOT:

    PREFILL  --(batch=1 full forward; KV rows written into the slot)-->
    WARMUP   --(MHA decode steps; per-head attention scores accumulate
                into the slot's clustering-feature buffer)-->
    CLUSTER  --(per-slot K-Means membership identification; the slot's
                dense K rows are compacted to representative rows — the
                paper's 21.4% KV saving — via a donated slot-indexed
                gather)-->
    STEADY   --(Clustered Head Attention decode until a finish condition)

plus three out-of-band edges:

* ABORT — ``abort(uid)`` cancels a request at any phase (or still
  queued), returning every page it held to the pools.
* chunked PREFILL self-loop — with ``EngineConfig.prefill_chunk_tokens``
  set, a long prompt forwards one page-aligned chunk per ``step()``
  instead of monolithically, so a prompt storm cannot stall the decoding
  slots for its whole length (greedy tokens are unchanged; paged layout,
  global-attention archs only).
* PREEMPT / RESUME — with ``EngineConfig.preemption`` (default on), a
  strictly-higher-``priority`` arrival that cannot be admitted for page
  budget evicts the lowest-priority running slot: the victim's pages and
  per-slot state are swapped to the host, its pages freed, and the
  request requeued at the front; re-admission swaps everything back into
  fresh pages and continues the SAME decode chain bitwise. (The swap is
  correctness, not just speed: CHAI decode approximates full attention,
  so recomputing the victim's generated tokens by prefill would diverge
  from the decode-written KV.) A mid-PREFILL victim restarts instead.

The engine is layered:

* ``EngineCore`` — owns the device state, page pools, prefix cache, and
  ONE public scheduling primitive: ``step()`` runs exactly one scheduler
  iteration (admit arrived requests into free slots -> cluster/compact
  slots whose warmup completed -> one mixed-phase batched decode ->
  retire finished slots) and returns a ``StepOutput`` per request that
  produced tokens. ``add_request`` enqueues with per-request
  ``SamplingParams`` (temperature / top-k / top-p / seed / stops);
  ``abort`` cancels mid-flight, refcount-exactly. Callers drive the loop
  themselves — streaming frontends yield between steps.
* ``repro.serving.api`` — the user-facing ``LLM.generate`` /
  ``LLM.stream`` / ``Session`` frontend over ``step()``.
* ``ServingEngine`` — the historical ``submit()`` / ``run()`` batch
  surface, now a thin compatibility wrapper that loops ``step()``.

Sampling is one batched device jit (``repro.launch.steps.make_sampler``)
shared by both schedulers; ``temperature=0`` slots take the raw-logits
argmax, so greedy decode is bitwise-identical to the historical greedy
path (CHAI snapshot capture/replay stays gated to greedy requests).
Seeded draws key on (request seed, tokens sampled so far) — reproducible
across schedulers and slot placements.

Two schedulers (``EngineConfig.scheduler``):

* ``"continuous"`` (default) — slot-level continuous batching, the
  step-driven core above. A fixed pool of batch slots (static shapes for
  XLA) holds requests at *different* phases simultaneously; the decode
  step is one jit that routes each slot to the MHA or CHAI attention
  path according to the per-slot ``phase`` vector (mask-and-select),
  host-dispatching to the cheaper all-MHA / all-CHAI jits when no slot
  is mid-transition.

  Two KV layouts (``EngineConfig.kv_layout``):

  - ``"paged"`` (default) — block-table paged KV
    (``repro.core.cache.paged_state_structs``). Admission is
    page-budget-based, and the CLUSTER transition frees the slot's dense
    K pages back to the ``PagePool`` the moment the representative rows
    are gathered into clustered pages — steady-state CHAI occupies less
    allocator memory than dense MHA (the paper's 21.4%-class saving in
    ``kv_bytes()``).
  - ``"dense"`` — the legacy *unified per-slot layout*
    (``unified_state_structs``), kept for parity testing.

* ``"cohort"`` — the legacy lockstep path
  (``repro.serving.cohort.CohortSchedulerMixin``), kept for A/B parity
  testing.

Every Request records arrival, admission (slot id + engine step), first
token, and completion, so per-request TTFT / ITL / latency and engine
throughput fall out directly. On-CPU usage: reduced configs; the same
engine code drives TPU meshes by passing ``mesh`` + shardings.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import cache as chai_cache
from repro.core import clustering
from repro.launch import steps as steps_mod
from repro.serving import exporters as exporters_mod
from repro.serving import faults as faults_mod
from repro.serving import invariants as invariants_mod
from repro.serving import kv_tiers as kv_tiers_mod
from repro.serving import sampling as sampling_mod
from repro.serving import telemetry as telemetry_mod
from repro.serving.cohort import CohortSchedulerMixin
from repro.serving.faults import (CapacityError, EngineFault, FaultInjector,
                                  InjectedFault, QuarantineError,
                                  RequestError, SnapshotRestoreError,
                                  ValidationError)
from repro.serving.sampling import SamplingParams

#: phase id -> timeline-event name (serving/telemetry.py lifecycle)
_PHASE_NAMES = {
    chai_cache.PHASE_FREE: "FREE",
    chai_cache.PHASE_PREFILL: "PREFILL",
    chai_cache.PHASE_WARMUP: "WARMUP",
    chai_cache.PHASE_CLUSTER: "CLUSTER",
    chai_cache.PHASE_STEADY: "STEADY",
}


@dataclasses.dataclass(eq=False)       # identity semantics: the queue and
class Request:                         # abort() membership-test Requests
    uid: int
    prompt: np.ndarray                 # (T,) int32
    max_new_tokens: int = 32
    sampling: SamplingParams = dataclasses.field(
        default_factory=SamplingParams)
    priority: int = 0                  # preemption class: higher outranks
    # -- filled by the engine --
    generated: Optional[List[int]] = None
    finish_reason: str = ""            # "" while in flight; "length" |
    #                                    "stop" | "aborted" when done
    t_enqueue: float = 0.0
    t_arrival: float = 0.0             # Poisson workloads: earliest admit
    t_first_token: float = 0.0
    t_done: float = 0.0
    slot: int = -1                     # continuous: slot the request ran in
    admit_step: int = -1               # continuous: engine step at admission
    retire_step: int = -1              # continuous: engine step at retire
    # -- prefix cache --
    cache_hit: str = ""                # "" | "prefix" | "snapshot" | "replay"
    cached_tokens: int = 0             # prompt tokens served from cache
    prefill_tokens: int = -1           # tokens actually forwarded (prefill)
    # -- failure taxonomy --
    error: str = ""                    # quarantine message when
    #                                    finish_reason == "error"
    # -- preemption --
    preemptions: int = 0               # times this request lost its slot
    # Host-swapped slot state (phase/count, per-slot columns, page
    # contents, CHAI membership) captured at eviction; consumed by the
    # swap-in admission. None for fresh and mid-PREFILL-evicted requests.
    resume_state: Optional[dict] = dataclasses.field(default=None,
                                                     repr=False)

    @property
    def finished(self) -> bool:
        return bool(self.finish_reason)

    @property
    def ttft(self):
        return self.t_first_token - self.t_arrival

    @property
    def latency(self):
        return self.t_done - self.t_arrival


@dataclasses.dataclass
class StepOutput:
    """Per-request result of one ``EngineCore.step()``: the token ids
    emitted for this request THIS step (one decode token; several at a
    snapshot/replay admission), and whether the request just finished."""
    uid: int
    token_ids: List[int]
    finished: bool = False
    finish_reason: str = ""


@dataclasses.dataclass
class EngineConfig:
    batch_slots: int = 4               # slot-pool / cohort size (static)
    max_seq: int = 256                 # KV capacity per slot (static)
    # Default SamplingParams for requests submitted without one:
    # greedy=True -> temperature 0 (the historical behaviour);
    # greedy=False -> temperature 1.0. Requests carrying explicit
    # SamplingParams ignore this flag entirely.
    greedy: bool = True
    scheduler: str = "continuous"      # "continuous" | "cohort"
    cohort_deadline_s: float = 120.0   # cohort straggler re-dispatch
    use_chai: bool = True
    # -- KV layout (continuous scheduler only) --------------------------
    # "paged": block-table page pool; a slot's dense K pages are FREED at
    # compaction, so steady-state CHAI occupies less allocator memory
    # than dense MHA (the paper's saving, realized). "dense": the legacy
    # unified per-slot rectangles (dense + clustered resident together).
    kv_layout: str = "paged"           # "paged" | "dense"
    page_size: int = 16                # tokens per page (divides max_seq)
    # Pool capacities in pages, INCLUDING the reserved null page 0.
    # 0 = auto: worst case for batch_slots requests of max_seq tokens
    # (admission is then never page-limited — shrink to exercise the
    # page-budget admission path).
    num_pages: int = 0                 # dense K/V pool
    num_chai_pages: int = 0            # clustered pool (MHA+CHAI archs)
    # -- shared-prefix KV reuse (paged layout only) ---------------------
    # Radix-tree prefix cache over token blocks: admission aliases the
    # longest cached block-prefix into the slot's block tables and
    # prefills only the uncached suffix; for MHA+CHAI archs a GREEDY
    # request whose FULL prompt was served before resumes from a CHAI
    # snapshot (membership + clustered pages) and enters STEADY directly.
    # Retiring slots that still hold their dense pages (GQA /
    # use_chai=False) index their FULL sequence (prompt + generated), so
    # a multi-turn Session's next turn prefills only the new user
    # message. Cached pages are refcounted, copy-on-write, LRU-evicted
    # under pressure.
    prefix_cache: bool = False
    # -- SLO-aware scheduling (continuous + paged) ----------------------
    # Chunked prefill (Sarathi-style): a prompt longer than this
    # forwards at most ``prefill_chunk_tokens`` per ``step()`` (rounded
    # up to a page multiple), interleaved with running decodes — a long
    # prompt no longer stalls every concurrent stream for its whole
    # monolithic prefill, bounding inter-token latency. 0 = monolithic.
    # Global-attention-only archs (same constraint as prefix_cache:
    # local rings / recurrent state cannot be rebuilt suffix-only).
    prefill_chunk_tokens: int = 0
    # Priority preemption: when the arrived queue head outranks a
    # running request and the pools cannot cover it, the lowest-priority
    # running slot is preempted — its pages return refcount-exactly via
    # the abort path's free, and the request re-queues right behind the
    # preemptor carrying its progress cursor (generated tokens, CHAI
    # membership / warmup scores), so resumed decoding continues where
    # it stopped instead of failing. Equal priorities never preempt.
    preemption: bool = True
    # -- shared-prefix relay decode (prefix_cache + paged + CHAI) -------
    # Compute system-prompt attention once per batch: STEADY slots
    # admitted through the same radix chain group on their deepest
    # shared node with >= relay_min_group members; each decode step runs
    # ONE group-batched prefix-attention pass per layer over a resident
    # contiguous copy of the shared pages (rep rows only — the
    # head->cluster broadcast is deferred to the merge), while each
    # slot's fused decode covers only its private suffix pages; the two
    # online-softmax states merge before the finalize. Per-step prefix
    # attention cost is O(prefix), independent of the group size.
    # Grouped tokens match the per-request decode path token-for-token
    # (the two-phase merge reorders float accumulation); ungrouped slots
    # stay BITWISE identical to relay_decode=False.
    relay_decode: bool = False
    relay_min_group: int = 2       # smallest group worth a prefix pass
    # -- runtime self-checks (serving/invariants.py) --------------------
    # "basic" (default): cheap host-side checks after every step() —
    # pool conservation, refcount accounting, phase legality, cache
    # lock/residency consistency. "deep": additionally pull the device
    # block tables + phase vector and verify them against the host
    # bookkeeping. "off": no auditing (benchmark hot loops). A failed
    # audit raises EngineFault (the engine state itself is suspect).
    audit_level: str = "basic"     # "off" | "basic" | "deep"
    # -- telemetry (serving/telemetry.py) -------------------------------
    # "off" (default): NullTelemetry — every hook is a no-op behind an
    # ``enabled`` guard, and the decode step stays jaxpr-identical to an
    # uninstrumented engine (claim-checked by bench_telemetry_overhead).
    # "basic": MetricsRegistry counters/gauges/histograms + per-request
    # lifecycle timelines (TTFT / ITL / queue time). "trace":
    # additionally records structured spans for every step() stage,
    # exportable as a Chrome trace (``step_trace()``).
    telemetry: str = "off"         # "off" | "basic" | "trace"
    # -- degraded-decode healing ----------------------------------------
    # After a kernel-path failure flips ``degraded_decode`` the engine
    # stays on the jnp reference jits. With decode_heal_steps = N > 0 it
    # reverts to the fused path after N consecutive clean decode steps
    # (no kernel.decode fault observed); each revert counts in
    # ``decode_heals``. 0 (default) = never heal (the historical
    # permanently-degraded behaviour).
    decode_heal_steps: int = 0
    # -- hierarchical KV tiers (serving/kv_tiers.py; paged layout) ------
    # Every paged engine owns a TierManager: preemption swap-out always
    # routes victim payloads through its host page pool. kv_offload
    # additionally turns prefix-cache eviction into DEMOTION — under
    # pool pressure unlocked radix leaves / CHAI snapshots move to host
    # pages instead of dropping (the LRU ladder walks hot -> host ->
    # compressed int4 -> gone), and a hit on a demoted entry promotes it
    # back into fresh device pages (bitwise-identical greedy replay).
    kv_offload: bool = False
    # Host / compressed pool sizes in usable pages PER KIND (dense and
    # clustered pools each get this many). 0 = auto: host covers 2x the
    # device pool; the int4 pool matches the host pool. Only radix
    # nodes ride the compressed rung (snapshots replay bitwise).
    host_pages: int = 0
    compressed_pages: int = 0
    # Admission-time prefetch: add_request queues the promotion of the
    # demoted entries the request will hit; step() drains a bounded
    # number per iteration ahead of the admission (synchronous
    # promotion remains the fallback on a miss).
    tier_prefetch: bool = True
    # A hit on an int4-compressed entry: False (default) drops the
    # entry and re-plans cold (still bitwise — prefill recomputes);
    # True promotes the dequantized approximation (bench arm).
    lossy_promote: bool = False


#: planner sentinel: a demoted entry was dropped mid-plan (failed
#: promotion / compressed-tier hit) — the tree changed, plan again.
_REPLAN = object()


class EngineCore(CohortSchedulerMixin):
    """Device-state owner + one-iteration scheduler (``step()``).

    ``detokenizer``: optional ``List[int] -> str`` used to match
    ``SamplingParams.stop`` strings against the generated tokens.
    """

    def __init__(self, cfg: ModelConfig, params, ecfg: EngineConfig, *,
                 detokenizer: Optional[Callable] = None,
                 faults: Optional[FaultInjector] = None):
        assert cfg.n_attn_layers > 0 or not ecfg.use_chai, \
            "CHAI needs attention layers"
        assert ecfg.scheduler in ("continuous", "cohort"), ecfg.scheduler
        assert ecfg.kv_layout in ("paged", "dense"), ecfg.kv_layout
        if ecfg.audit_level not in ("off", "basic", "deep"):
            raise ValueError(f"audit_level must be off|basic|deep, "
                             f"got {ecfg.audit_level!r}")
        if ecfg.decode_heal_steps < 0:
            raise ValueError("decode_heal_steps must be >= 0, got "
                             f"{ecfg.decode_heal_steps}")
        # telemetry tier validation happens inside make_telemetry
        self.tel = telemetry_mod.make_telemetry(ecfg.telemetry)
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        self.detokenizer = detokenizer
        # -- fault containment / robustness --------------------------------
        self.faults = faults           # None = no injection sites active
        self.quarantined = 0           # requests typed-failed ("error")
        self.audit_steps = 0           # step()s that ran the auditor
        self.degraded_decode = False   # fused/relay path failed: jnp now
        self.decode_fallbacks = 0      # kernel-path failures survived
        self.decode_heals = 0          # degraded->fused reverts (healing)
        self._heal_clean = 0           # consecutive clean degraded steps
        self._decode_fault_hit = False  # kernel.decode fired this step
        self.relay_dissolved = 0       # relay groups dissolved by fault
        self.swap_checksum_failures = 0
        self.offload_checksum_failures = 0   # corrupted promotions caught
        self.prefetch_hits = 0         # demoted hit found already promoted
        self.prefetch_misses = 0       # demoted hit promoted synchronously
        self._jnp_steps = None         # lazily-built degraded decode jits
        self._fault_blocked = False    # last plan blocked by injection
        self.queue: deque = deque()
        self.done: List[Request] = []
        self.redispatched = 0
        self.steps_executed = 0        # continuous: batched decode steps
        self._step_calls = 0           # every _step_inner entry (spans)
        self._span_step = -1           # step ordinal current spans carry
        b, s = ecfg.batch_slots, ecfg.max_seq

        chai_on = ecfg.use_chai and cfg.chai.enabled and cfg.k_max > 0
        self.chai_on = chai_on
        # Paged layout: continuous scheduler over global-attention KV
        # (archs without global layers have nothing to page).
        self.paged = (ecfg.scheduler == "continuous"
                      and ecfg.kv_layout == "paged"
                      and cfg.n_global_layers > 0)
        # MHA+CHAI archs carry the clustered page pool.
        self.chai_clustered = (self.paged and chai_on and cfg.is_mha)
        self.dense_pool = None
        self.chai_pool = None
        # Paged allocated-bytes trajectory (benchmarks/tests). Bounded:
        # recording stops at _HISTORY_MAX entries (the PREFILL->STEADY
        # head is what the benches read); the peak is a running int.
        self.kv_bytes_history: List[dict] = []
        self._kv_peak = 0
        if self.paged:
            assert s % ecfg.page_size == 0, (s, ecfg.page_size)
            p_slot = s // ecfg.page_size
            self._slot_pages_max = p_slot
            n_dense = ecfg.num_pages or (2 * b * p_slot + 1)
            self.dense_pool = chai_cache.PagePool(n_dense, ecfg.page_size)
            if self.chai_clustered:
                share = 2 if cfg.chai.share_values else 1
                n_chai = ecfg.num_chai_pages or (share * b * p_slot + 1)
                self.chai_pool = chai_cache.PagePool(n_chai, ecfg.page_size)
        # -- shared-prefix KV reuse ---------------------------------------
        self.prefix_cache = None
        if ecfg.prefix_cache:
            if not self.paged:
                raise ValueError("prefix_cache requires the paged KV "
                                 "layout on the continuous scheduler")
            if (cfg.n_local_layers or cfg.n_rec_layers
                    or cfg.n_rwkv_layers):
                # Local rings / recurrent state depend on the whole
                # prefix but are not paged — a suffix-only prefill
                # cannot rebuild them.
                raise ValueError(
                    "prefix_cache supports global-attention-only archs "
                    f"(got {cfg.name!r} with local/recurrent layers)")
            from repro.serving.prefix_cache import PrefixCache
            self.prefix_cache = PrefixCache(self.dense_pool,
                                            self.chai_pool, ecfg.page_size)
        # -- hierarchical KV tiers (serving/kv_tiers.py) ------------------
        # Built for EVERY paged engine: preemption swap-out always routes
        # its victim payloads through the host pool. Prefix-cache
        # demotion (eviction -> host instead of drop) additionally needs
        # ecfg.kv_offload.
        self.tiers = None
        self._prefetch_q: deque = deque()
        self._prefetch_ids: set = set()
        if ecfg.kv_offload and not self.paged:
            raise ValueError("kv_offload requires the paged KV layout "
                             "on the continuous scheduler")
        if self.paged:
            host_d = ecfg.host_pages or 2 * self.dense_pool.capacity
            host_c = 0
            if self.chai_pool is not None:
                host_c = ecfg.host_pages or 2 * self.chai_pool.capacity
            self.tiers = kv_tiers_mod.TierManager(
                ecfg.page_size,
                host_pages={"dense": host_d, "chai": host_c},
                # Only radix nodes compress, and nodes hold dense pages
                # only — the clustered kind never rides the int4 rung.
                comp_pages={"dense": ecfg.compressed_pages or host_d,
                            "chai": 0},
                on_transition=self._tel_tier_transition)
            if self.prefix_cache is not None:
                self.prefix_cache.tiers = self.tiers
                self.tiers.drop_hook = self.prefix_cache.drop_demoted
                self.tiers.droppable_hook = self.prefix_cache._droppable
                if ecfg.kv_offload:
                    self.prefix_cache.demote_hook = self._demote_entry
        # -- chunked prefill (page-aligned chunks; paged layout only) -----
        self._chunk = 0
        if ecfg.prefill_chunk_tokens and self.paged:
            if (cfg.n_local_layers or cfg.n_rec_layers
                    or cfg.n_rwkv_layers):
                raise ValueError(
                    "prefill_chunk_tokens supports global-attention-only "
                    f"archs (got {cfg.name!r} with local/recurrent "
                    "layers): chunk forwards cannot rebuild local rings "
                    "or recurrent state from earlier chunks")
            ps = ecfg.page_size
            self._chunk = -(-ecfg.prefill_chunk_tokens // ps) * ps
        # Device state persists across step()/run() calls: paged, so
        # cached pages keep their contents between request waves; dense,
        # so the step-driven core never rebuilds mid-stream (retired
        # slots rewind pos — stale rows are masked exactly like the zero
        # tail). None until the first continuous step.
        self._dev_state = None
        self._dev_ctx = None
        self.cluster_transitions = 0   # CLUSTER phase transitions executed
        # -- step-driven scheduler state (continuous) ---------------------
        self._uid_counter = 0          # monotonic: uids never collide
        self._requests: dict = {}      # uid -> Request (abort lookup)
        self._slot_req: List[Optional[Request]] = [None] * b
        self._slot_count = [0] * b          # tokens generated this admission
        self._slot_pages: List[dict] = [{} for _ in range(b)]  # page ids
        self._slot_locked: List[list] = [[] for _ in range(b)]  # cache pins
        # chunked prefill cursors: {"req", "tokens", "cursor"} per slot
        self._slot_prefill_state: List[Optional[dict]] = [None] * b
        self.preemptions = 0           # slots reclaimed for priority
        self._next_tok = np.zeros((b,), np.int32)   # host mirror
        self._next_tok_dev = jnp.zeros((b,), jnp.int32)
        self._tok_dirty = False
        self._phases = np.full((b,), chai_cache.PHASE_FREE, np.int32)
        # Per-slot SamplingParams device vectors (FREE slots sample
        # greedily — their tokens are never recorded). Host mirrors are
        # re-uploaded only after an admission/retire edited them.
        self._samp_host = {"temperature": np.zeros((b,), np.float32),
                           "top_k": np.zeros((b,), np.int32),
                           "top_p": np.ones((b,), np.float32),
                           "seed": np.zeros((b,), np.uint32)}
        self._samp_dev = None
        self._samp_dirty = True
        # jax.jit wrappers are lazy (no tracing until the first call), so
        # both schedulers' steps are declared here unconditionally.
        # decode_ts = page_size pins the fused CHAI kernel's dense tile
        # size to the paged page size, so every layout/scheduler performs
        # bit-identical attention arithmetic (cross-layout token parity).
        self._sampler = jax.jit(steps_mod.make_sampler())
        # All-greedy fast path: the full sampler computes its sampling
        # lane (argsort + softmax + PRNG) for every slot and discards it
        # via jnp.where on greedy rows — host-dispatch a bare argmax when
        # NO slot is sampling (the engine default), exactly like the
        # phase-mix step dispatch. Bitwise-identical to the sampler's
        # greedy lane (both argmax the raw f32 logits).
        self._argmax = jax.jit(
            lambda lg: jnp.argmax(lg, axis=-1).astype(jnp.int32))
        # Always-on NaN/Inf logits guard: one reduction per row — a
        # non-finite row quarantines ITS slot; decode rows are
        # independent, so the others are bitwise-untouched.
        self._finite_rows = jax.jit(
            lambda lg: jnp.isfinite(jnp.max(lg, axis=-1)))
        self._mha_step = jax.jit(
            steps_mod.make_serve_step(cfg, chai=False,
                                      decode_ts=ecfg.page_size),
            donate_argnums=(2,))
        self._prefill = jax.jit(steps_mod.make_serve_prefill(cfg, b, s))
        reset_maker = (steps_mod.make_paged_slot_reset if self.paged
                       else steps_mod.make_slot_reset)
        self._reset_slot = jax.jit(reset_maker(cfg), donate_argnums=(0,))
        self._slot_prefills: dict = {}       # pow2 length bucket -> jit
        self._suffix_prefills: dict = {}     # suffix bucket -> jit
        self._chunk_prefills: dict = {}      # chunk bucket -> jit
        self._cohort_buckets: set = set()    # pow2 buckets seen (observab.)
        self._cluster_slot = None            # built lazily (identify hook)
        self._swap_fns = None                # preemption KV swap (out, in)
        if self.paged:
            self._restore_snapshot = jax.jit(
                steps_mod.make_snapshot_restore(cfg), donate_argnums=(0,))
            self._copy_page = {
                kind: jax.jit(steps_mod.make_page_copy(cfg, kind),
                              donate_argnums=(0,))
                for kind in ("dense", "chai")}
            # Tier demote/promote: one-page gather / scatter jits (the
            # page id is traced — one trace per kind).
            self._fetch_page = {"dense": jax.jit(
                steps_mod.make_page_fetch(cfg, "dense"))}
            self._put_page = {"dense": jax.jit(
                steps_mod.make_page_put(cfg, "dense"),
                donate_argnums=(0,))}
            if self.chai_clustered:
                self._fetch_page["chai"] = jax.jit(
                    steps_mod.make_page_fetch(cfg, "chai"))
                self._put_page["chai"] = jax.jit(
                    steps_mod.make_page_put(cfg, "chai"),
                    donate_argnums=(0,))
            self._set_ctx = jax.jit(clustering.update_ctx_slot,
                                    donate_argnums=(0,))
        if chai_on:
            self._chai_step = jax.jit(
                steps_mod.make_serve_step(cfg, chai=True,
                                          decode_ts=ecfg.page_size),
                donate_argnums=(2,))
            self._mixed_step = jax.jit(
                steps_mod.make_mixed_step(cfg, decode_ts=ecfg.page_size),
                donate_argnums=(2,))
            self._compact = jax.jit(steps_mod.make_compact_step(cfg),
                                    donate_argnums=(0,))
            self._identify = jax.jit(
                lambda sc: clustering.identify_membership(sc, cfg))
        # -- shared-prefix relay decode -----------------------------------
        # Host caches keyed by a clustering-context version: the per-slot
        # head->cluster maps feeding the relay row maps change only at
        # CLUSTER transitions, snapshot restores and preemption swap-ins,
        # so row maps / host ctx mirrors are rebuilt only when the
        # version moves (not every step).
        self._ctx_version = 0
        self._ctx_host_cache = None    # (version, {name: np.ndarray})
        self._relay_rows_cache = None  # (key, {k_row, a_row, v_row})
        self._pack_prefix = {}         # chain length -> resident-pack jit
        self.relay_steps = 0           # decode steps that ran the relay
        self.relay_grouped_slots = 0   # cumulative grouped-slot count
        # Mixed-batch sampling sub-batch (greedy slots skip the sampling
        # lane): row gather / scatter-over-argmax helpers.
        self._take_rows = jax.jit(lambda a, idx: a[idx])
        self._put_rows = jax.jit(lambda a, idx, v: a.at[idx].set(v))
        self.relay_decode = False
        if ecfg.relay_decode:
            if not (self.paged and chai_on and ecfg.prefix_cache):
                raise ValueError(
                    "relay_decode requires prefix_cache on the paged "
                    "layout with CHAI enabled (the relay groups STEADY "
                    "slots by their locked radix chain)")
            self.relay_decode = True
            # One jit; jax retraces per relay signature (G, Nmax, Sp) —
            # group shapes recur across steps so the trace cache holds.
            self._relay_step = jax.jit(
                steps_mod.make_relay_step(cfg, decode_ts=ecfg.page_size),
                donate_argnums=(2,))

    # -- public API --------------------------------------------------------
    def default_sampling(self) -> SamplingParams:
        return (SamplingParams() if self.ecfg.greedy
                else SamplingParams(temperature=1.0))

    def add_request(self, prompt, sampling: Optional[SamplingParams] = None,
                    *, max_new_tokens: Optional[int] = None, uid=None,
                    arrival_delay: float = 0.0,
                    priority: int = 0) -> Request:
        """Enqueue a request with per-request ``SamplingParams``.

        ``max_new_tokens`` (when given) overrides
        ``sampling.max_new_tokens``. ``arrival_delay`` (seconds from now)
        models open-loop arrivals: the scheduler will not admit the
        request before its arrival time. ``priority``: preemption class —
        under page pressure a strictly-higher-priority arrival may
        reclaim a running lower-priority slot (``EngineConfig.preemption``).
        Default uids come from a monotonic engine counter (explicit uids
        bump it past themselves, so later defaults can never collide with
        retired requests)."""
        sp = sampling if sampling is not None else self.default_sampling()
        max_new = (max_new_tokens if max_new_tokens is not None
                   else sp.max_new_tokens)
        if len(prompt) + max_new > self.ecfg.max_seq:
            raise ValidationError(
                f"prompt ({len(prompt)}) + max_new_tokens "
                f"({max_new}) exceeds max_seq "
                f"({self.ecfg.max_seq}): the KV capacity (dense slot or "
                f"page budget) cannot hold the request", uid=uid)
        if sp.stop and self.detokenizer is None:
            raise ValidationError(
                "SamplingParams.stop strings need an engine "
                "detokenizer (EngineCore(detokenizer=...))", uid=uid)
        if uid is None:
            uid = self._uid_counter
        self._uid_counter = max(self._uid_counter, int(uid) + 1)
        req = Request(uid=uid, prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=max_new, sampling=sp,
                      priority=int(priority))
        req.t_enqueue = time.time()
        req.t_arrival = req.t_enqueue + arrival_delay
        req.generated = []
        self.queue.append(req)
        self._requests[uid] = req
        if (self.paged and self.ecfg.kv_offload
                and self.ecfg.tier_prefetch
                and self.prefix_cache is not None):
            # Admission-time prefetch: queue the demoted entries this
            # prompt will hit for promotion ahead of the planning step.
            self._queue_prefetch(req)
        if self.tel.enabled:
            self.tel.counter("requests_submitted_total",
                             help="Requests enqueued via add_request")
            self.tel.gauge("engine_queue_depth", len(self.queue),
                           help="Requests waiting in the arrival queue")
            self.tel.event(req.uid, "enqueue", t=req.t_enqueue,
                           prompt_tokens=int(len(req.prompt)),
                           max_new_tokens=int(max_new),
                           priority=int(priority))
        return req

    def _done(self, req: Request):
        """Finalize a request: move it to ``done`` and drop the abort
        lookup entry (unless a newer request reused the uid), so a
        long-lived core does not grow per request served. ``done`` itself
        accumulates for the batch ``run()`` surface; step-driven
        frontends keep it bounded via ``reap_done()``."""
        self.done.append(req)
        if self._requests.get(req.uid) is req:
            del self._requests[req.uid]
        if self.tel.enabled:
            self._tel_finish(req)

    def reap_done(self) -> List[Request]:
        """Return AND clear the finished-request list. Long-lived
        frontends (``LLM``) call this after collecting their outputs;
        the legacy ``ServingEngine.run()`` surface leaves ``done``
        accumulating across calls instead."""
        out, self.done = self.done, []
        return out

    def abort(self, uid) -> bool:
        """Cancel a request: a queued request is dropped before touching
        the device; a running one retires immediately — its pages (and
        prefix-cache locks) return refcount-exactly, its slot resets, and
        concurrent slots are untouched. Tokens generated so far stay on
        the Request (``finish_reason="aborted"``). Returns False for
        unknown / already-finished uids."""
        req = self._requests.get(uid)
        if req is None or req.finished:
            return False
        if req in self.queue:
            self.queue.remove(req)
            self._free_resume(req)      # swapped-out victims hold host pages
            req.finish_reason = sampling_mod.FINISH_ABORT
            req.t_done = time.time()
            req.retire_step = self.steps_executed
            self._done(req)
            return True
        for i, r in enumerate(self._slot_req):
            if r is req:
                self._retire_slot(i, sampling_mod.FINISH_ABORT)
                return True
        return False

    @property
    def has_active(self) -> bool:
        return any(r is not None for r in self._slot_req)

    def has_work(self) -> bool:
        return bool(self.queue) or self.has_active

    def next_arrival(self) -> Optional[float]:
        """Earliest queued arrival time (callers sleep until it when
        ``step()`` makes no progress), or None when the queue is empty."""
        return self.queue[0].t_arrival if self.queue else None

    def step(self) -> List[StepOutput]:
        """Run exactly ONE scheduler iteration: advance one prefill chunk
        for every mid-prefill slot, admit arrived requests into free
        slots (prefix-cache planning and priority preemption included),
        run CLUSTER transitions for slots whose warmup just completed,
        execute one mixed-phase batched decode + sample, and retire slots
        that hit a finish condition. Returns one ``StepOutput`` per
        request that emitted tokens. Non-blocking: with no admissible
        work it returns ``[]`` (use ``next_arrival()`` to wait); with the
        engine idle and the queue head unserviceable even after draining
        the prefix cache, raises ``CapacityError`` (a ``MemoryError``,
        exactly like the page-budget gate always has, now carrying the
        uid). Request-isolatable failures (injected faults, swap-in
        corruption, non-finite logits) never raise: the offending
        request is QUARANTINED — typed ``StepOutput`` with
        ``finish_reason="error"``, pages released refcount-exactly — and
        the batch keeps running. ``EngineConfig.audit_level`` gates an
        invariant audit after the iteration; a violation raises
        ``EngineFault``."""
        tel = self.tel
        if tel.enabled:
            t0 = time.perf_counter()
        with tel.span("step", step=self._step_calls):
            outs = self._step_inner()
        if self.ecfg.audit_level != "off" \
                and self.ecfg.scheduler == "continuous":
            self.audit_steps += 1
            with tel.span("audit", step=self._span_step):
                vio = invariants_mod.audit(
                    self, deep=self.ecfg.audit_level == "deep")
            if vio:
                raise EngineFault(
                    f"invariant audit failed at step "
                    f"{self.steps_executed}", violations=vio)
        if tel.enabled:
            tel.observe("engine_step_seconds", time.perf_counter() - t0,
                        help="Wall time of one step() iteration")
            tel.counter("engine_steps_total",
                        help="step() iterations executed")
            tel.gauge("engine_queue_depth", len(self.queue),
                      help="Requests waiting in the arrival queue")
            tel.gauge("engine_active_slots",
                      sum(1 for r in self._slot_req if r is not None),
                      help="Batch slots holding a live request")
        return outs

    def _step_inner(self) -> List[StepOutput]:
        if self.ecfg.scheduler != "continuous":
            raise RuntimeError("step() drives the continuous scheduler; "
                               "cohort engines run via run()")
        outs: List[StepOutput] = []
        self._ensure_dev_state()
        if self._prefetch_q:
            self._drain_prefetch()
        b = self.ecfg.batch_slots
        drained = False
        self._fault_blocked = False
        tel = self.tel
        self._span_step = self._step_calls
        self._step_calls += 1
        self._advance_prefills(outs)
        while True:
            with tel.span("admit", step=self._span_step):
                blocked = self._admit(outs)
            active = [i for i in range(b)
                      if self._slot_req[i] is not None
                      and self._phases[i] != chai_cache.PHASE_PREFILL]
            if active:
                break
            if self.has_active:
                return outs        # only mid-prefill slots: progress made
            if not self.queue or not blocked:
                return outs        # idle, or waiting on future arrivals
            if self._fault_blocked:
                return outs        # injected transient: retry next step
            # The failed plan ran with the engine idle (no retire can
            # intervene between the attempt and here). Drain the prefix
            # cache and retry once — only if even an empty cache cannot
            # cover the request is it impossible.
            if not drained and self.prefix_cache is not None and (
                    self.prefix_cache.num_blocks
                    or self.prefix_cache.num_snapshots):
                self.prefix_cache.clear()
                drained = True
                continue
            head = self.queue[0]
            n = self._pages_for(head)
            if self.dense_pool.free_pages < 2 * n:
                raise CapacityError(
                    f"request uid={head.uid} needs {2 * n} "
                    f"dense pages; pool capacity "
                    f"{self.dense_pool.capacity}", uid=head.uid)
            share = 2 if self.cfg.chai.share_values else 1
            raise CapacityError(
                f"request uid={head.uid} needs {n * share} "
                f"clustered pages; pool capacity "
                f"{self.chai_pool.capacity}", uid=head.uid)
        with tel.span("cluster", step=self._span_step):
            self._cluster_transitions(active, outs)
        # A kernel.cluster quarantine may have retired slots mid-list.
        active = [i for i in active if self._slot_req[i] is not None]
        if active:
            outs.extend(self._decode(active))
        return outs

    # -- fault injection / quarantine --------------------------------------
    def _fault(self, site: str, uid: int = -1):
        """Consult the fault injector at a named site; None when no
        injector is armed or nothing fires."""
        if self.faults is None:
            return None
        spec = self.faults.fire(site, step=self.steps_executed, uid=uid)
        if spec is not None and self.tel.enabled:
            self.tel.counter("faults_injected_total", site=site,
                             mode=spec.mode,
                             help="Injected faults that fired, by site")
        return spec

    def _quarantine_queued(self, req: Request, err: RequestError,
                           outs: List[StepOutput]):
        """Typed-fail a request that is still queued (or was just popped):
        no device state to unwind — record the error and finish it."""
        if req in self.queue:
            self.queue.remove(req)
        self._free_resume(req)  # swapped-out victims hold host pages
        req.finish_reason = sampling_mod.FINISH_ERROR
        req.error = str(err)
        req.t_done = time.time()
        req.retire_step = self.steps_executed
        self.quarantined += 1
        if self.tel.enabled:
            self.tel.event(req.uid, "quarantine", reason=str(err))
        self._done(req)
        outs.append(StepOutput(req.uid, [], True,
                               sampling_mod.FINISH_ERROR))

    def _abort_admission(self, i: int, req: Request, gen0: int,
                         hit0: tuple):
        """Unwind a failed ``_admit_to_slot``: free the plan's pages and
        locks refcount-exactly, reset the slot on device, and rewind the
        request's progress records to their pre-admission values."""
        self._slot_prefill_state[i] = None
        self._phases[i] = chai_cache.PHASE_FREE
        self._slot_count[i] = 0
        self._dev_state = self._reset_slot(self._dev_state, jnp.int32(i))
        self._free_pages(self._slot_pages[i])
        if self._slot_locked[i]:
            self.prefix_cache.unlock(self._slot_locked[i])
            self._slot_locked[i] = []
        req.generated = req.generated[:gen0]
        req.cache_hit, req.cached_tokens, req.prefill_tokens = hit0

    # -- telemetry hooks (all callers guard on self.tel.enabled) -----------
    def _tel_admit(self, i: int, req: Request, plan: dict, resumed: bool):
        """Admission succeeded: labeled admit counter, queue-wait
        histogram, CHAI cache-hit token counters, timeline event."""
        tel = self.tel
        kind = "swap" if resumed else plan["kind"]
        tel.counter("requests_admitted_total", kind=kind,
                    help="Slot admissions by plan kind")
        tel.observe("request_queue_seconds",
                    max(0.0, time.time() - req.t_enqueue),
                    help="Enqueue-to-admission wait")
        tel.event(req.uid, "resume" if resumed else "admit", slot=i,
                  kind=kind, step=self.steps_executed,
                  cached_tokens=int(req.cached_tokens))
        if req.cache_hit == "prefix":
            tel.counter("prefix_hit_tokens_total", req.cached_tokens,
                        help="Prompt tokens served from the radix cache")
        elif req.cache_hit == "snapshot":
            tel.counter("snapshot_hit_tokens_total", req.cached_tokens,
                        help="Prompt tokens served from CHAI snapshots")
            # Snapshot admissions land in STEADY with warmup tokens
            # already emitted: their first token happened here.
            if req.generated and req.t_first_token:
                tel.event(req.uid, "first_token", t=req.t_first_token)
                tel.observe("request_ttft_seconds",
                            max(0.0, req.t_first_token - req.t_enqueue),
                            help="Enqueue-to-first-token latency")
                tel.counter("tokens_generated_total", len(req.generated),
                            help="Generated tokens emitted")
                tel.token(req.uid, n=len(req.generated),
                          t=req.t_first_token)

    def _tel_finish(self, req: Request):
        """Request reached a terminal state (retire, abort, quarantine,
        replay): reason-labeled counter, latency histogram, timeline
        seal."""
        tel = self.tel
        reason = req.finish_reason or "unknown"
        tel.counter("requests_finished_total", reason=reason,
                    help="Requests finished, by finish_reason")
        if req.error:
            tel.counter("requests_quarantined_total",
                        help="Requests typed-failed and quarantined")
        if req.t_done and req.t_enqueue:
            tel.observe("request_latency_seconds",
                        max(0.0, req.t_done - req.t_enqueue),
                        help="Enqueue-to-completion latency")
        data = {"reason": reason,
                "tokens": len(req.generated or ()),
                "preemptions": int(req.preemptions)}
        if req.error:
            data["error"] = req.error
        if req.cache_hit:
            data["cache_hit"] = req.cache_hit
        tel.event(req.uid, "finish", t=req.t_done or None, **data)
        tel.finish(req.uid)

    def _tel_clusters(self, i: int):
        """Per-layer cluster-count gauges from slot ``i``'s freshly
        written clustering context (one small device fetch per CLUSTER
        transition — never on the per-step path)."""
        ctx = {k: np.asarray(v[:, i]) for k, v in self._dev_ctx.items()}
        if "h2c" in ctx:                      # MHA: (nA, H) head->cluster
            h2c = ctx["h2c"]
            for layer in range(h2c.shape[0]):
                self.tel.gauge("chai_clusters", len(np.unique(h2c[layer])),
                               layer=layer,
                               help="Clusters per attention layer at the "
                                    "latest CLUSTER transition")
        elif "cluster_of" in ctx:             # GQA: (nA, KV, qpk)
            co = ctx["cluster_of"]
            for layer in range(co.shape[0]):
                n = sum(int(len(np.unique(co[layer, g])))
                        for g in range(co.shape[1]))
                self.tel.gauge("chai_clusters", n, layer=layer,
                               help="Clusters per attention layer at the "
                                    "latest CLUSTER transition")

    def _refresh_gauges(self):
        """Point-in-time gauges recomputed at scrape time."""
        tel = self.tel
        tel.gauge("engine_queue_depth", len(self.queue),
                  help="Requests waiting in the arrival queue")
        tel.gauge("engine_active_slots",
                  sum(1 for r in self._slot_req if r is not None),
                  help="Batch slots holding a live request")
        tel.gauge("engine_degraded_decode", int(self.degraded_decode),
                  help="1 while decode runs the jnp reference fallback")
        if self.paged:
            tel.gauge("kv_bytes_allocated", self.kv_bytes(),
                      help="Allocated KV bytes right now")
            tel.gauge("dense_pages_in_use", self.dense_pool.pages_in_use,
                      help="Dense-pool pages in use")
            if self.chai_pool is not None:
                tel.gauge("chai_pages_in_use",
                          self.chai_pool.pages_in_use,
                          help="Clustered-pool pages in use")
            if self.tiers is not None:
                help_tier = "KV pages resident per tier and pool kind"
                tel.gauge("kv_tier_pages", self.dense_pool.pages_in_use,
                          tier="hot", kind="dense", help=help_tier)
                if self.chai_pool is not None:
                    tel.gauge("kv_tier_pages",
                              self.chai_pool.pages_in_use,
                              tier="hot", kind="chai", help=help_tier)
                for (tier, kind), n in self.tiers.tier_pages().items():
                    tel.gauge("kv_tier_pages", n, tier=tier, kind=kind,
                              help=help_tier)

    def metrics(self):
        """JSON-ready metrics snapshot (refreshes point-in-time gauges
        first). None when ``EngineConfig.telemetry == "off"``."""
        if not self.tel.enabled:
            return None
        self._refresh_gauges()
        return self.tel.snapshot()

    def metrics_text(self):
        """Prometheus text exposition of ``metrics()`` (None when
        telemetry is off)."""
        snap = self.metrics()
        return None if snap is None else exporters_mod.to_prometheus(snap)

    def request_timeline(self, uid):
        """Lifecycle timeline (events + derived TTFT/ITL/queue summary)
        for one request uid; None when unknown or telemetry is off."""
        return self.tel.timeline(uid)

    def step_trace(self):
        """Chrome-trace JSON object of the recorded step spans (empty
        below the "trace" tier)."""
        return exporters_mod.to_chrome_trace(self.tel.spans)

    # -- continuous scheduler ----------------------------------------------
    @staticmethod
    def _prompt_bucket(t: int, cap: int) -> int:
        """Next power of two >= t, capped at max_seq."""
        b = 1
        while b < t:
            b <<= 1
        return min(b, cap)

    def _slot_prefill_fn(self, bucket: int):
        """One compiled prefill per power-of-two prompt-length BUCKET
        (prompts are right-padded to the bucket; the tail is masked via
        the traced ``true_len``), so prefill retraces are O(log max_seq)
        instead of O(distinct prompt lengths)."""
        fn = self._slot_prefills.get(bucket)
        if fn is None:
            maker = (steps_mod.make_paged_slot_prefill if self.paged
                     else steps_mod.make_slot_prefill)
            fn = jax.jit(maker(self.cfg, self.ecfg.max_seq),
                         donate_argnums=(3,))
            self._slot_prefills[bucket] = fn
        return fn

    def _padded_prompt(self, prompt):
        """Right-pad a prompt to its bucket; returns (tokens (1, bucket),
        true_len scalar). The jit cache key is the padded array's shape,
        so the bucket is computed in exactly one place."""
        t = len(prompt)
        bucket = self._prompt_bucket(t, self.ecfg.max_seq)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :t] = prompt
        return jnp.asarray(toks), jnp.int32(t)

    def _suffix_prefill_fn(self, bucket: int):
        """One compiled suffix prefill per suffix-length bucket (the
        cached-prefix length rides in as a traced scalar)."""
        fn = self._suffix_prefills.get(bucket)
        if fn is None:
            fn = jax.jit(steps_mod.make_paged_suffix_prefill(
                self.cfg, self.ecfg.max_seq), donate_argnums=(4,))
            self._suffix_prefills[bucket] = fn
        return fn

    def _padded_suffix(self, suffix, prefix_len: int):
        """Right-pad an uncached suffix to its bucket. The bucket must
        keep ``prefix_len + bucket`` within max_seq (padded cache writes
        must stay inside the slot's logical pages); when the power-of-two
        bucket would overflow, fall back to the suffix's page-multiple —
        a key that depends only on the suffix length, NOT on prefix_len,
        so the jit-key set stays O(log max_seq + max_seq/page_size)
        instead of one compile per distinct cached-prefix length."""
        t = len(suffix)
        ps = self.ecfg.page_size
        bucket = self._prompt_bucket(t, self.ecfg.max_seq)
        if bucket > self.ecfg.max_seq - prefix_len:
            bucket = chai_cache.pages_needed(t, ps) * ps
        assert t <= bucket <= self.ecfg.max_seq - prefix_len, \
            (bucket, t, prefix_len)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :t] = suffix
        return jnp.asarray(toks), jnp.int32(t)

    def _chunk_prefill_fn(self, bucket: int):
        """One compiled chunk prefill per chunk-length bucket (start
        position and final-chunk phase ride in as traced scalars)."""
        fn = self._chunk_prefills.get(bucket)
        if fn is None:
            fn = jax.jit(steps_mod.make_paged_chunk_prefill(
                self.cfg, self.ecfg.max_seq), donate_argnums=(4,))
            self._chunk_prefills[bucket] = fn
        return fn

    def _cluster_fn(self):
        # Built on first use so a monkeypatched ``_identify`` hook (tests,
        # CHAI-static ablations) is honored.
        if self._cluster_slot is None:
            maker = (steps_mod.make_paged_slot_cluster if self.paged
                     else steps_mod.make_slot_cluster)
            self._cluster_slot = jax.jit(maker(self.cfg, self._identify),
                                         donate_argnums=(0, 1))
        return self._cluster_slot

    def _swap_fns_get(self):
        """(swap_out, swap_in) jits for preemption KV swap — one trace
        per arch (page vectors are fixed-length, null-padded)."""
        if self._swap_fns is None:
            out, inn = steps_mod.make_slot_swap(self.cfg)
            self._swap_fns = (jax.jit(out),
                              jax.jit(inn, donate_argnums=(0,)))
        return self._swap_fns

    # -- sampling (host <-> device) ----------------------------------------
    def _set_slot_sampling(self, slot: int, sp: SamplingParams):
        h = self._samp_host
        h["temperature"][slot] = sp.temperature
        h["top_k"][slot] = sp.top_k
        h["top_p"][slot] = sp.top_p
        h["seed"][slot] = np.uint32(sp.seed)
        self._samp_dirty = True

    def _sample_first(self, logits, req: Request) -> int:
        """Sample a request's FIRST token from its prefill logits (count
        0 — the same draw the cohort scheduler makes for its row)."""
        sp = req.sampling
        if sp.greedy:
            return int(np.asarray(self._argmax(logits))[0])
        out = self._sampler(
            logits,
            jnp.asarray([sp.temperature], jnp.float32),
            jnp.asarray([sp.top_k], jnp.int32),
            jnp.asarray([sp.top_p], jnp.float32),
            jnp.asarray(np.asarray([sp.seed], np.uint32)),
            jnp.zeros((1,), jnp.int32))
        return int(np.asarray(out)[0])

    def _finish_of(self, req: Request) -> str:
        return sampling_mod.finish_reason(req.generated, req.sampling,
                                          req.max_new_tokens,
                                          self.detokenizer)

    # -- paged-pool bookkeeping (host side) --------------------------------
    def _pages_for(self, req) -> int:
        """Logical pages a request can touch over its lifetime."""
        n = chai_cache.pages_needed(
            len(req.prompt) + req.max_new_tokens, self.ecfg.page_size)
        return min(n, self._slot_pages_max)

    def _try_alloc(self, req):
        """Page-budget admission: allocate the request's dense K + V pages
        (and reserve its clustered pages, so the CLUSTER transition can
        never deadlock mid-flight). Returns a page dict or None if the
        pools cannot cover it yet."""
        n = self._pages_for(req)
        chai_n = self._chai_pages_per(n)
        if self.dense_pool.free_pages < 2 * n:
            return None
        if chai_n and self.chai_pool.free_pages < chai_n:
            return None
        pages = {"kg": self.dense_pool.alloc(n),
                 "vg": self.dense_pool.alloc(n)}
        if self.chai_clustered:
            pages["kc"] = self.chai_pool.alloc(n)
            if self.cfg.chai.share_values:
                pages["vc"] = self.chai_pool.alloc(n)
        return pages

    def _free_pages(self, pages: dict):
        for key, pool in (("kg", self.dense_pool), ("vg", self.dense_pool),
                          ("kc", self.chai_pool), ("vc", self.chai_pool)):
            if key in pages:
                pool.free(pages.pop(key))

    def _page_vec(self, pages):
        """Null-padded (P,) int32 device vector of a page list."""
        vec = np.zeros((self._slot_pages_max,), np.int32)
        vec[:len(pages)] = pages
        return jnp.asarray(vec)

    # -- prefix-cache admission planning (host side) -----------------------
    def _chai_pages_per(self, n: int) -> int:
        if not self.chai_clustered:
            return 0
        return n * (2 if self.cfg.chai.share_values else 1)

    def _eligible_snapshot(self, req):
        """The single gate for the CHAI snapshot fast path (used by the
        admit loop's replay check AND the planner — one definition, no
        divergence): paged + cache on + clustered CHAI + a GREEDY request
        (replay correctness rests on greedy determinism; sampling
        requests take the block-prefix path instead). Preempted requests
        (generated tokens already emitted) never replay — their tokens
        must continue, not repeat."""
        if req.generated:
            return None
        if (self.paged and self.prefix_cache is not None
                and self.chai_clustered and req.sampling.greedy):
            return self.prefix_cache.snapshot_for(req.prompt)
        return None

    def _pool_space(self, dense_need: int, chai_need: int) -> bool:
        """True when the pools can cover the request, evicting unlocked
        prefix-cache entries (LRU) if that is what it takes."""
        ok = (self.dense_pool.free_pages >= dense_need
              and (not chai_need
                   or self.chai_pool.free_pages >= chai_need))
        if ok or self.prefix_cache is None:
            return ok
        return self.prefix_cache.evict_until(dense_free=dense_need,
                                             chai_free=chai_need)

    def _plan_admission(self, req):
        """Build an admission plan for the queue head, mutating the pools
        (alloc + incref) and locking the cache entries it aliases.
        Returns None when the pools cannot cover the request yet.

        kinds: "cold" (no reuse), "prefix" (longest cached block-prefix
        aliased, suffix prefilled), "snapshot" (full prompt cached with a
        CHAI snapshot: enter STEADY directly). The replay fast path
        (snapshot covers max_new_tokens entirely — host-side, no slot)
        is handled by the admit loop before planning. Preempted requests
        (``resume_state`` set) take the swap-in plan instead: fresh pages
        matching what the slot held, restored bitwise — no prefill."""
        spec = self._fault("pool.alloc", uid=req.uid)
        if spec is not None:
            if spec.mode == "error":
                raise QuarantineError(
                    f"injected allocator failure for uid={req.uid}",
                    uid=req.uid)
            self._fault_blocked = True
            return None     # transient: the plan retries next step
        cache = self.prefix_cache
        if req.resume_state is not None:
            return self._plan_swap_in(req)
        snap = self._eligible_snapshot(req)
        if snap is not None:
            plan = self._plan_snapshot(req, snap)
            if plan is _REPLAN:
                # A demoted snapshot failed promotion and was dropped:
                # plan again from scratch (bounded — each replan removed
                # at least one cache entry).
                return self._plan_admission(req)
            if plan is not None:
                return plan
            return None         # a cold plan needs strictly more pages
        matched = cache.match(req.prompt) if cache is not None else []
        if matched:
            plan = self._plan_prefix(req, matched)
            if plan is _REPLAN:
                return self._plan_admission(req)
            if plan is not None:
                return plan
            return None
        n = self._pages_for(req)
        if not self._pool_space(2 * n, self._chai_pages_per(n)):
            return None     # even LRU eviction cannot cover it yet
        pages = self._try_alloc(req)
        if pages is None:
            return None
        return {"kind": "cold", "pages": pages, "locked": []}

    def _plan_swap_in(self, req):
        """Allocate fresh pages matching exactly what the preempted slot
        held per pool kind (a clustered STEADY victim, e.g., holds no
        dense K pages); the swap-in restores the saved contents into
        them. Never more pages than the original admission, so a request
        that was admitted once can always be planned again."""
        want = req.resume_state["npages"]
        dense_need = want.get("kg", 0) + want.get("vg", 0)
        chai_need = want.get("kc", 0) + want.get("vc", 0)
        if not self._pool_space(dense_need, chai_need):
            return None
        pages = {}
        for kind, pool in (("kg", self.dense_pool), ("vg", self.dense_pool),
                           ("kc", self.chai_pool), ("vc", self.chai_pool)):
            if want.get(kind):
                pages[kind] = pool.alloc(want[kind])
        return {"kind": "swap", "pages": pages, "locked": []}

    def _plan_prefix(self, req, matched):
        """Alias ``matched`` block pages; allocate fresh pages for the
        suffix + generation headroom (and the full clustered reservation,
        as on the cold path)."""
        cache = self.prefix_cache
        n = self._pages_for(req)
        n_m = min(len(matched), n)
        matched = matched[:n_m]
        chai_n = self._chai_pages_per(n)
        cache.lock(matched)     # pin before eviction can run
        demoted = [m for m in matched
                   if m.tier != kv_tiers_mod.TIER_HOT]
        for m in matched:
            if m.prefetched:
                m.prefetched = False
                self.prefetch_hits += 1
                if self.tel.enabled:
                    self.tel.counter(
                        "prefetch_hits_total",
                        help="Demoted entries promoted before the "
                             "planner needed them")
        # Promoted nodes each need 2 fresh dense pages on top of the
        # suffix allocation.
        if not self._pool_space(2 * (n - n_m) + 2 * len(demoted), chai_n):
            cache.unlock(matched)
            return None
        for m in demoted:
            if self.ecfg.tier_prefetch and self.ecfg.kv_offload:
                self.prefetch_misses += 1
                if self.tel.enabled:
                    self.tel.counter(
                        "prefetch_misses_total",
                        help="Demoted entries promoted synchronously "
                             "at plan time")
            if not self._promote_entry(m, uid=req.uid):
                cache.unlock(matched)   # dropped entries stay evicted
                return _REPLAN
        kg_fresh = self.dense_pool.alloc(n - n_m)
        vg_fresh = self.dense_pool.alloc(n - n_m)
        kg_alias = [m.kg_page for m in matched]
        vg_alias = [m.vg_page for m in matched]
        self.dense_pool.incref(kg_alias)
        self.dense_pool.incref(vg_alias)
        pages = {"kg": kg_alias + kg_fresh, "vg": vg_alias + vg_fresh}
        if self.chai_clustered:
            pages["kc"] = self.chai_pool.alloc(n)
            if self.cfg.chai.share_values:
                pages["vc"] = self.chai_pool.alloc(n)
        null = [chai_cache.NULL_PAGE] * n_m
        return {"kind": "prefix", "pages": pages, "locked": matched,
                "prefix_len": n_m * self.ecfg.page_size,
                "scatter_kg": null + kg_fresh,
                "scatter_vg": null + vg_fresh}

    def _plan_snapshot(self, req, snap):
        """Resume from a CHAI snapshot: share its full pages, copy its
        partial tail page(s) (copy-on-write), allocate headroom for the
        remaining generation, and enter STEADY directly."""
        cache = self.prefix_cache
        share = self.cfg.chai.share_values
        ps = self.ecfg.page_size
        n = self._pages_for(req)
        p_full, rem = divmod(snap.pos, ps)
        dense_need = 0 if share else (n - p_full)
        chai_need = (n - p_full) * (2 if share else 1)
        cache.lock([snap])
        if snap.prefetched:
            snap.prefetched = False
            self.prefetch_hits += 1
            if self.tel.enabled:
                self.tel.counter(
                    "prefetch_hits_total",
                    help="Demoted entries promoted before the planner "
                         "needed them")
        extra_d = extra_c = 0
        if snap.tier != kv_tiers_mod.TIER_HOT:
            extra_d = len(snap.tier_pages.get("vg", ()))
            extra_c = (len(snap.tier_pages.get("kc", ()))
                       + len(snap.tier_pages.get("vc", ())))
        if not self._pool_space(dense_need + extra_d,
                                chai_need + extra_c):
            cache.unlock([snap])
            return None
        if snap.tier != kv_tiers_mod.TIER_HOT:
            if self.ecfg.tier_prefetch and self.ecfg.kv_offload:
                self.prefetch_misses += 1
                if self.tel.enabled:
                    self.tel.counter(
                        "prefetch_misses_total",
                        help="Demoted entries promoted synchronously "
                             "at plan time")
            if not self._promote_entry(snap, uid=req.uid):
                cache.unlock([snap])    # dropped — re-plan cold
                return _REPLAN
        copies = []     # (pool kind, src physical page, dst physical page)
        pages = {}
        if not share:
            vg_fresh = self.dense_pool.alloc(n - p_full)
            self.dense_pool.incref(snap.vg_pages[:p_full])
            pages["vg"] = snap.vg_pages[:p_full] + vg_fresh
            if rem:
                copies.append(("dense", snap.vg_pages[p_full], vg_fresh[0]))
        kc_fresh = self.chai_pool.alloc(n - p_full)
        self.chai_pool.incref(snap.kc_pages[:p_full])
        pages["kc"] = snap.kc_pages[:p_full] + kc_fresh
        if rem:
            copies.append(("chai", snap.kc_pages[p_full], kc_fresh[0]))
        if share:
            vc_fresh = self.chai_pool.alloc(n - p_full)
            self.chai_pool.incref(snap.vc_pages[:p_full])
            pages["vc"] = snap.vc_pages[:p_full] + vc_fresh
            if rem:
                copies.append(("chai", snap.vc_pages[p_full], vc_fresh[0]))
        return {"kind": "snapshot", "snapshot": snap, "pages": pages,
                "locked": [snap], "copies": copies}

    _HISTORY_MAX = 1 << 16

    def _record_kv_bytes(self, phases=None):
        bytes_now = self.kv_bytes()
        self._kv_peak = max(self._kv_peak, bytes_now)
        if self.tel.enabled:
            self.tel.gauge("kv_bytes_allocated", bytes_now,
                           help="Allocated KV bytes right now")
            self.tel.gauge("dense_pages_in_use",
                           self.dense_pool.pages_in_use,
                           help="Dense-pool pages in use")
            if self.chai_pool is not None:
                self.tel.gauge("chai_pages_in_use",
                               self.chai_pool.pages_in_use,
                               help="Clustered-pool pages in use")
        if len(self.kv_bytes_history) >= self._HISTORY_MAX:
            return
        rec = {
            "step": self.steps_executed,
            "kv_bytes": bytes_now,
            "dense_pages": self.dense_pool.pages_in_use,
            "chai_pages": (self.chai_pool.pages_in_use
                           if self.chai_pool else 0),
        }
        if self.tiers is not None:
            tb = self.tiers.tier_bytes()
            rec["host_bytes"] = tb.get(kv_tiers_mod.TIER_HOST, 0)
            rec["compressed_bytes"] = tb.get(kv_tiers_mod.TIER_COMP, 0)
        if phases is not None:
            rec["n_warmup"] = int((phases == chai_cache.PHASE_WARMUP).sum())
            rec["n_steady"] = int((phases == chai_cache.PHASE_STEADY).sum())
        self.kv_bytes_history.append(rec)

    def _ensure_dev_state(self):
        """Continuous-scheduler device state, built once and kept across
        ``step()``/``run()`` calls (paged: prefix-cache pages survive
        between request waves; dense: retired slots rewind ``pos`` so
        stale rows are masked like the zero tail)."""
        cfg, ecfg = self.cfg, self.ecfg
        b = ecfg.batch_slots
        if self._dev_state is None:
            if self.paged:
                self._dev_state = chai_cache.init_paged_state(
                    cfg, b, ecfg.max_seq, page_size=ecfg.page_size,
                    dense_pages=self.dense_pool.num_pages,
                    chai_pages=(self.chai_pool.num_pages if self.chai_pool
                                else 0),
                    chai=self.chai_on)
            else:
                self._dev_state = chai_cache.init_unified_state(
                    cfg, b, ecfg.max_seq, chai=self.chai_on)
            self._dev_ctx = (clustering.init_batched_ctx(cfg, b)
                             if self.chai_on else None)
        return self._dev_state, self._dev_ctx

    def _replay_request(self, req, snap):
        """Serve a request entirely from a CHAI snapshot's replayed warmup
        tokens: no slot, no pages, no device work at all."""
        now = time.time()
        toks, reason = sampling_mod.scan_finish(
            snap.tokens[:req.max_new_tokens], req.sampling,
            req.max_new_tokens, self.detokenizer)
        req.generated = toks
        req.finish_reason = reason or sampling_mod.FINISH_LENGTH
        req.cache_hit = "replay"
        req.cached_tokens = len(req.prompt)
        req.prefill_tokens = 0
        req.t_first_token = now
        req.t_done = time.time()
        req.admit_step = req.retire_step = self.steps_executed
        self.prefix_cache.stats["snapshot_hits"] += 1
        self.prefix_cache.stats["tokens_reused"] += len(req.prompt)
        if self.tel.enabled:
            tel = self.tel
            tel.counter("requests_admitted_total", kind="replay",
                        help="Slot admissions by plan kind")
            tel.counter("snapshot_hit_tokens_total", len(req.prompt),
                        help="Prompt tokens served from CHAI snapshots")
            tel.event(req.uid, "admit", kind="replay", slot=-1,
                      step=self.steps_executed,
                      cached_tokens=int(req.cached_tokens))
            tel.event(req.uid, "first_token", t=req.t_first_token)
            tel.observe("request_ttft_seconds",
                        max(0.0, req.t_first_token - req.t_enqueue),
                        help="Enqueue-to-first-token latency")
            tel.counter("tokens_generated_total", len(toks),
                        help="Generated tokens emitted")
            tel.token(req.uid, n=len(toks), t=req.t_first_token)
        self._done(req)

    def _capture_snapshot(self, slot, req, pages):
        """Capture the slot's STEADY-entry state (membership, clustered K
        pages, dense V pages, warmup tokens) keyed by its full prompt.
        Full pages are shared (incref); the partial tail page — which the
        still-running slot keeps writing — is copied, copy-on-write.
        Skipped (not an error) when the pools cannot spare the copies."""
        from repro.serving.prefix_cache import ChaiSnapshot
        cache = self.prefix_cache
        key = tuple(int(t) for t in req.prompt)
        if cache.snapshot_for(key) is not None:
            return
        cfg, ps = self.cfg, self.ecfg.page_size
        share = cfg.chai.share_values
        warm = cfg.chai.warmup_tokens
        pos_steady = len(req.prompt) + warm
        p_full, rem = divmod(pos_steady, ps)
        dense_copies = 1 if (rem and not share) else 0
        chai_copies = (2 if share else 1) if rem else 0
        if not self._pool_space(dense_copies, chai_copies):
            return
        vg_pages, vc_pages = [], []
        if not share:
            vg_pages = list(pages["vg"][:p_full])
            self.dense_pool.incref(vg_pages)
        kc_pages = list(pages["kc"][:p_full])
        self.chai_pool.incref(kc_pages)
        if share:
            vc_pages = list(pages["vc"][:p_full])
            self.chai_pool.incref(vc_pages)
        if rem:
            if not share:
                [dst] = self.dense_pool.alloc(1)
                self._dev_state = self._copy_page["dense"](
                    self._dev_state, jnp.int32(pages["vg"][p_full]),
                    jnp.int32(dst))
                vg_pages.append(dst)
            [dst] = self.chai_pool.alloc(1)
            self._dev_state = self._copy_page["chai"](
                self._dev_state, jnp.int32(pages["kc"][p_full]),
                jnp.int32(dst))
            kc_pages.append(dst)
            if share:
                [dst] = self.chai_pool.alloc(1)
                self._dev_state = self._copy_page["chai"](
                    self._dev_state, jnp.int32(pages["vc"][p_full]),
                    jnp.int32(dst))
                vc_pages.append(dst)
        slot_ctx = {k: np.asarray(v[:, slot])
                    for k, v in self._dev_ctx.items()}
        cache.add_snapshot(ChaiSnapshot(
            prompt=key, pos=pos_steady,
            tokens=list(req.generated[:warm + 1]), ctx=slot_ctx,
            vg_pages=vg_pages, kc_pages=kc_pages, vc_pages=vc_pages))

    # -- KV tier ops (demote / promote / prefetch) -------------------------
    def _tel_tier_transition(self, frm: str, to: str, kind: str, n: int):
        """TierManager transition callback -> Prometheus counter."""
        if self.tel.enabled:
            self.tel.counter("tier_transitions_total", n,
                             help="KV page transitions between tiers",
                             **{"from": frm, "to": to})

    @staticmethod
    def _entry_device_pages(entry) -> dict:
        """Device pages an entry owns, keyed by pool key (kg/vg/kc/vc)."""
        if hasattr(entry, "kg_page"):  # radix node
            return {"kg": [entry.kg_page], "vg": [entry.vg_page]}
        out = {}
        if entry.vg_pages:
            out["vg"] = list(entry.vg_pages)
        if entry.kc_pages:
            out["kc"] = list(entry.kc_pages)
        if entry.vc_pages:
            out["vc"] = list(entry.vc_pages)
        return out

    def _demote_entry(self, entry) -> bool:
        """Move an unlocked prefix-cache entry's device pages to the host
        tier. Called by PrefixCache._evict_one under device pool pressure
        (the victim is already off the LRU). Returns False to fall back
        to a plain drop. Pages are gathered to host BEFORE the device
        refs are released, so a False return never loses data."""
        if self.tiers is None or self._dev_state is None:
            return False
        spec = self._fault("offload.out")
        if spec is not None and spec.mode != "corrupt":
            return False  # demotion declined -> plain drop
        refs = self._entry_device_pages(entry)
        need = {}
        for pk, pages in refs.items():
            kind = kv_tiers_mod.POOL_OF[pk]
            need[kind] = need.get(kind, 0) + len(pages)
        if not self.tiers.make_room(need):
            return False
        payloads = {}
        for pk, pages in refs.items():
            kind = kv_tiers_mod.POOL_OF[pk]
            fetch = self._fetch_page.get(kind)
            if fetch is None:
                return False
            payloads[pk] = [jax.device_get(
                fetch(self._dev_state, jnp.int32(p))) for p in pages]
        self.tiers.store_entry(entry, payloads)
        if spec is not None and spec.mode == "corrupt":
            # Damage the stored host copy AFTER the CRC stamp, so the
            # promotion path detects it (corrupt_arrays mutates the
            # payload dicts the host pool holds).
            tree = {pk: {str(j): p for j, p in enumerate(pl)}
                    for pk, pl in payloads.items()}
            faults_mod.corrupt_arrays(tree, seed=self.faults.seed)
        # Host copy is safe: release the device references.
        for pk, pages in refs.items():
            kind = kv_tiers_mod.POOL_OF[pk]
            pool = self.dense_pool if kind == "dense" else self.chai_pool
            pool.free(pages)
            self.tiers.record("hot", "host", kind, len(pages))
        if hasattr(entry, "vg_pages"):  # snapshot: page ids now live in
            entry.vg_pages = []         # entry.tier_pages
            entry.kc_pages = []
            entry.vc_pages = []
        return True

    def _promote_entry(self, entry, *, uid: int = -1) -> bool:
        """Bring a demoted entry back into fresh device pages. The caller
        must have verified device pool headroom (``_pool_space``) first.
        Returns False — with the entry DROPPED — on checksum mismatch, an
        injected ``offload.in`` fault, or a compressed entry when lossy
        promotion is off; the caller re-plans the request cold."""
        cache = self.prefix_cache
        t0 = time.perf_counter()
        frm = entry.tier
        if frm == kv_tiers_mod.TIER_COMP and not self.ecfg.lossy_promote:
            cache.drop_demoted(entry)
            return False
        failed = self._fault("offload.in", uid) is not None
        if not failed and not self.tiers.verify_entry(entry):
            self.offload_checksum_failures += 1
            failed = True
        if failed:
            cache.drop_demoted(entry)
            return False
        payloads = self.tiers.fetch_entry(entry)
        new_pages = {}
        for pk, pl in payloads.items():
            kind = kv_tiers_mod.POOL_OF[pk]
            pool = self.dense_pool if kind == "dense" else self.chai_pool
            pages = pool.alloc(len(pl))
            put = self._put_page[kind]
            for p, payload in zip(pages, pl):
                dev = {k: jnp.asarray(v) for k, v in payload.items()
                       if k in ("data", "scale")}
                self._dev_state = put(self._dev_state, jnp.int32(p), dev)
            new_pages[pk] = pages
        self.tiers.release_entry(entry)
        if hasattr(entry, "kg_page"):
            entry.kg_page = new_pages["kg"][0]
            entry.vg_page = new_pages["vg"][0]
            cache.stats["promoted_blocks"] += 1
        else:
            entry.vg_pages = new_pages.get("vg", [])
            entry.kc_pages = new_pages.get("kc", [])
            entry.vc_pages = new_pages.get("vc", [])
            cache.stats["promoted_snapshots"] += 1
        entry.tier = kv_tiers_mod.TIER_HOT
        entry.tier_crc = 0
        for pk, pages in new_pages.items():
            kind = kv_tiers_mod.POOL_OF[pk]
            self.tiers.record(frm, "hot", kind, len(pages))
        cache._lru_file(entry)  # no-op while the entry is locked
        if self.tel.enabled:
            self.tel.observe("promote_wait_seconds",
                             time.perf_counter() - t0,
                             help="Host->device promotion latency")
        return True

    def _queue_prefetch(self, req: Request):
        """At admission time, look up which demoted prefix-cache entries
        this prompt will hit and queue them for promotion ahead of the
        planning step (drained by ``_step_inner``)."""
        cache = self.prefix_cache
        targets = []
        snap = self._eligible_snapshot(req)
        if snap is not None and snap.tier != kv_tiers_mod.TIER_HOT:
            targets = [snap]
        else:
            matched = cache.match(req.prompt)
            targets = [m for m in matched
                       if m.tier != kv_tiers_mod.TIER_HOT]
        for e in targets:
            if id(e) in self._prefetch_ids or e.prefetched:
                continue
            self._prefetch_ids.add(id(e))
            self._prefetch_q.append(e)

    def _drain_prefetch(self, budget: int = 4):
        """Promote up to ``budget`` queued entries into free device pages.
        Never evicts to make room — if the pools are full the queue waits
        (the synchronous fallback in the planners still covers the hit)."""
        while self._prefetch_q and budget > 0:
            e = self._prefetch_q.popleft()
            self._prefetch_ids.discard(id(e))
            if (e.tier == kv_tiers_mod.TIER_HOT
                    or getattr(e, "evicted", False) or e.locks):
                continue
            if (e.tier == kv_tiers_mod.TIER_COMP
                    and not self.ecfg.lossy_promote):
                continue
            counts = self.tiers._entry_page_counts(e)
            dense_need = counts.get("dense", 0)
            chai_need = counts.get("chai", 0)
            if (self.dense_pool.counters()["free"] < dense_need
                    or (chai_need and self.chai_pool.counters()["free"]
                        < chai_need)):
                self._prefetch_q.appendleft(e)
                self._prefetch_ids.add(id(e))
                return
            if self._promote_entry(e):
                e.prefetched = True
            budget -= 1

    def _free_resume(self, req: Request):
        """Release the host-tier pages backing a preempted request's
        resume payload (quarantine / abort while swapped out)."""
        rs = req.resume_state
        if not rs or "tier_pages" not in rs or self.tiers is None:
            return
        for pk, pages in rs["tier_pages"].items():
            kind = kv_tiers_mod.POOL_OF[pk]
            self.tiers.free_pages(kind, pages)
            self.tiers.record("host", "gone", kind, len(pages))
        rs["tier_pages"] = {}

    # -- step internals ----------------------------------------------------
    def _admit(self, outs: List[StepOutput]) -> bool:
        """Fill free slots from the arrived FIFO prefix while the page
        budget covers prompt + generation headroom (prefix-cache hits
        alias shared pages and need fewer). When the head outranks a
        running request and the pools cannot cover it, preempt the
        lowest-priority slot and retry the plan. Returns True when the
        queue head had arrived but could not be planned (page-blocked)."""
        now = time.time()
        blocked = False
        while self.queue and self.queue[0].t_arrival <= now:
            head = self.queue[0]
            snap = self._eligible_snapshot(head)
            if snap is not None and \
                    head.max_new_tokens <= len(snap.tokens):
                # Snapshot covers the whole request: serve it host-side
                # without occupying a slot.
                req = self.queue.popleft()
                self._replay_request(req, snap)
                outs.append(StepOutput(req.uid, list(req.generated), True,
                                       req.finish_reason))
                continue
            free_slots = [i for i in range(self.ecfg.batch_slots)
                          if self._slot_req[i] is None]
            if not free_slots and not self._try_preempt(head):
                break
            if not free_slots:      # preemption just freed a slot
                continue
            try:
                plan = (self._plan_admission(head) if self.paged
                        else {"kind": "cold", "pages": {}, "locked": []})
            except RequestError as err:
                self._quarantine_queued(head, err, outs)
                continue
            if plan is None:        # FIFO holds until pages free up
                if not self._fault_blocked and self._try_preempt(head):
                    continue        # pages reclaimed — retry the plan
                blocked = True
                break
            i = free_slots[0]
            req = self.queue.popleft()
            resumed = bool(req.generated)
            gen0 = len(req.generated)
            hit0 = (req.cache_hit, req.cached_tokens, req.prefill_tokens)
            try:
                self._admit_to_slot(i, req, plan)
            except SnapshotRestoreError:
                # Recoverable: unwind the admission, drop the damaged
                # snapshot, and re-plan the request cold next iteration
                # (greedy tokens are unchanged — snapshot replay is a
                # parity guarantee, not a correctness dependency).
                self._abort_admission(i, req, gen0, hit0)
                self.prefix_cache.drop_snapshot(plan["snapshot"])
                self.queue.appendleft(req)
                continue
            except RequestError as err:
                self._abort_admission(i, req, gen0, hit0)
                self._quarantine_queued(req, err, outs)
                continue
            if req.generated and not req.t_first_token:
                req.t_first_token = time.time()
            req.slot, req.admit_step = i, self.steps_executed
            self._slot_req[i] = req
            self._set_slot_sampling(i, req.sampling)
            if self.tel.enabled:
                self._tel_admit(i, req, plan, resumed)
            if resumed:
                continue    # tokens so far were already emitted/checked
            trunc, reason = sampling_mod.scan_finish(
                req.generated, req.sampling, req.max_new_tokens,
                self.detokenizer)
            if reason:
                req.generated = trunc
                self._retire_slot(i, reason)
            if req.generated or reason:
                # Chunked admissions have no first token yet — their
                # StepOutput comes from the final chunk.
                outs.append(StepOutput(req.uid, list(req.generated),
                                       bool(reason), reason))
        return blocked

    def _admit_to_slot(self, i: int, req: Request, plan: dict):
        """Place ``req`` into free slot ``i`` according to ``plan``,
        mutating the device state and the slot bookkeeping."""
        self._slot_pages[i] = plan.get("pages", {})
        self._slot_locked[i] = plan.get("locked", [])
        if plan["kind"] == "snapshot":
            snap = plan["snapshot"]
            if self._fault("snapshot.restore", uid=req.uid) is not None:
                raise SnapshotRestoreError(
                    f"injected snapshot-restore failure for "
                    f"uid={req.uid}", uid=req.uid)
            st = self._dev_state
            for kind, src, dst in plan["copies"]:
                st = self._copy_page[kind](st, jnp.int32(src),
                                           jnp.int32(dst))
            null = self._page_vec([])
            st = self._restore_snapshot(
                st, jnp.int32(i), null,
                self._page_vec(self._slot_pages[i].get("vg", [])),
                self._page_vec(self._slot_pages[i].get("kc", [])),
                self._page_vec(self._slot_pages[i].get("vc", [])),
                jnp.int32(snap.pos))
            self._dev_state = st
            dev_ctx = {k: jnp.asarray(v) for k, v in snap.ctx.items()}
            self._dev_ctx = self._set_ctx(self._dev_ctx, dev_ctx,
                                          jnp.int32(i))
            self._ctx_version += 1
            req.generated.extend(snap.tokens)
            req.cache_hit = "snapshot"
            req.cached_tokens = len(req.prompt)
            req.prefill_tokens = 0
            self._phases[i] = chai_cache.PHASE_STEADY
            self._slot_count[i] = len(snap.tokens)
            self.prefix_cache.stats["snapshot_hits"] += 1
            self.prefix_cache.stats["tokens_reused"] += len(req.prompt)
            self._next_tok[i] = snap.tokens[-1]
            self._tok_dirty = True
            if self.tel.enabled:
                self.tel.event(req.uid, "phase", phase="STEADY", slot=i)
            return
        if plan["kind"] == "swap":
            self._swap_in_slot(i, req)
            return
        self._phases[i] = chai_cache.PHASE_PREFILL
        if self._fault("kernel.prefill", uid=req.uid) is not None:
            raise QuarantineError(
                f"injected prefill-kernel failure for uid={req.uid}",
                uid=req.uid)
        if self.tel.enabled:
            self.tel.event(req.uid, "phase", phase="PREFILL", slot=i)
        prompt = req.prompt
        if plan["kind"] == "prefix":
            pre = plan["prefix_len"]
            req.cache_hit = "prefix"
            req.cached_tokens = pre
            req.prefill_tokens = len(prompt) - pre
            self.prefix_cache.stats["partial_hits"] += 1
            self.prefix_cache.stats["tokens_reused"] += pre
            self.prefix_cache.stats["tokens_prefilled"] += \
                req.prefill_tokens
        else:
            pre = 0
            req.prefill_tokens = len(prompt)
            if self.prefix_cache is not None:
                self.prefix_cache.stats["misses"] += 1
                self.prefix_cache.stats["tokens_prefilled"] += len(prompt)
        if self._chunk and len(prompt) - pre > self._chunk:
            # Chunked prefill: run the first chunk now; step() advances
            # one chunk per iteration until _finish_prefill fires.
            self._slot_prefill_state[i] = {"req": req, "tokens": prompt,
                                           "cursor": pre}
            self._advance_chunk(i)
            return
        if plan["kind"] == "prefix":
            toks, true_len = self._padded_suffix(prompt[pre:], pre)
            fn = self._suffix_prefill_fn(toks.shape[1])
            logits, st = fn(
                self.params, toks, true_len, jnp.int32(pre),
                self._dev_state, jnp.int32(i),
                self._page_vec(plan["scatter_kg"]),
                self._page_vec(plan["scatter_vg"]),
                self._page_vec(self._slot_pages[i]["kg"]),
                self._page_vec(self._slot_pages[i]["vg"]))
        else:
            toks, true_len = self._padded_prompt(prompt)
            prefill = self._slot_prefill_fn(toks.shape[1])
            if self.paged:
                logits, st = prefill(
                    self.params, toks, true_len, self._dev_state,
                    jnp.int32(i),
                    self._page_vec(self._slot_pages[i]["kg"]),
                    self._page_vec(self._slot_pages[i]["vg"]))
            else:
                logits, st = prefill(self.params, toks, true_len,
                                     self._dev_state, jnp.int32(i))
        self._dev_state = st
        self._finish_prefill(i, req, logits)

    def _advance_prefills(self, outs: List[StepOutput]):
        """Forward ONE page-aligned chunk for every mid-prefill slot —
        chunked prefill's per-step progress, interleaved with the batched
        decode of the other slots. A slot whose final chunk completes
        enters WARMUP and emits its first token here."""
        for i in range(self.ecfg.batch_slots):
            st = self._slot_prefill_state[i]
            if st is None:
                continue
            req = st["req"]
            self._advance_chunk(i)
            if self._slot_prefill_state[i] is not None:
                continue                    # more chunks to go
            # final chunk fired: the first token was just sampled
            reason = self._finish_of(req)
            if reason:
                self._retire_slot(i, reason)
            outs.append(StepOutput(req.uid, [req.generated[-1]],
                                   bool(reason), reason))

    def _advance_chunk(self, i: int):
        """Prefill the next chunk of slot ``i``'s pending tokens. Chunk
        starts are page-aligned (the chunk size is a page multiple and
        radix-aliased prefixes are whole pages), so each chunk's scatter
        touches exactly its own page range; intermediate chunks park the
        device phase at FREE so the interleaved decode skips the slot."""
        st = self._slot_prefill_state[i]
        eff, cur = st["tokens"], st["cursor"]
        end = min(cur + self._chunk, len(eff))
        final = end == len(eff)
        toks, true_len = self._padded_suffix(eff[cur:end], cur)
        ps = self.ecfg.page_size
        lo, hi = cur // ps, chai_cache.pages_needed(end, ps)
        pages = self._slot_pages[i]

        def scatter(page_list):
            return [p if lo <= j < hi else chai_cache.NULL_PAGE
                    for j, p in enumerate(page_list)]

        fn = self._chunk_prefill_fn(toks.shape[1])
        phase = (chai_cache.PHASE_WARMUP if final
                 else chai_cache.PHASE_FREE)
        logits, self._dev_state = fn(
            self.params, toks, true_len, jnp.int32(cur),
            self._dev_state, jnp.int32(i),
            self._page_vec(scatter(pages["kg"])),
            self._page_vec(scatter(pages["vg"])),
            self._page_vec(pages["kg"]),
            self._page_vec(pages["vg"]),
            jnp.int32(phase))
        st["cursor"] = end
        if final:
            self._slot_prefill_state[i] = None
            self._finish_prefill(i, st["req"], logits)

    def _finish_prefill(self, i: int, req: Request, logits):
        """Prefill completed (monolithic, or a chunked prefill's final
        chunk): index the prompt into the prefix cache, enter WARMUP, and
        sample the request's first token."""
        if self.paged and self.prefix_cache is not None:
            self.prefix_cache.insert(req.prompt,
                                     self._slot_pages[i]["kg"],
                                     self._slot_pages[i]["vg"])
        self._phases[i] = chai_cache.PHASE_WARMUP
        self._slot_count[i] = 1
        tok = self._sample_first(logits, req)
        req.generated.append(tok)
        first = not req.t_first_token
        if first:
            req.t_first_token = time.time()
        self._next_tok[i] = tok
        self._tok_dirty = True
        if self.tel.enabled:
            tel = self.tel
            tel.event(req.uid, "phase", phase="WARMUP", slot=i)
            if req.prefill_tokens > 0:
                tel.counter("prefill_tokens_total", req.prefill_tokens,
                            help="Prompt tokens actually forwarded")
            if first:
                tel.event(req.uid, "first_token", t=req.t_first_token)
                tel.observe("request_ttft_seconds",
                            max(0.0, req.t_first_token - req.t_enqueue),
                            help="Enqueue-to-first-token latency")
            tel.counter("tokens_generated_total",
                        help="Generated tokens emitted")
            tel.token(req.uid, t=req.t_first_token if first else None)

    # -- priority preemption -----------------------------------------------
    def _swap_in_slot(self, i: int, req: Request):
        """Resume a preempted request: upload its saved per-slot columns
        and page contents into the freshly allocated pages, rebuild the
        block tables, and restore its CHAI membership — the slot decodes
        on bitwise the state it was evicted with. Integrity: the payload
        carries the CRC32 stamped at swap-out; a mismatch (host-side
        corruption) quarantines the request BEFORE any device mutation."""
        resume = req.resume_state
        if self._fault("swap.in", uid=req.uid) is not None:
            raise QuarantineError(
                f"injected swap-in failure for uid={req.uid}", uid=req.uid)
        if self._fault("offload.in", uid=req.uid) is not None:
            raise QuarantineError(
                f"injected host-tier fetch failure for uid={req.uid}",
                uid=req.uid)
        tier_payloads = {
            k: self.tiers.fetch_pages(kv_tiers_mod.POOL_OF[k], pg)
            for k, pg in resume["tier_pages"].items()}
        tree = {k: {str(j): p for j, p in enumerate(pl)}
                for k, pl in tier_payloads.items()}
        crc = resume.get("crc")
        if crc is not None and faults_mod.checksum_arrays(
                {"cols": resume["cols"], "pools": tree}) != crc:
            self.swap_checksum_failures += 1
            raise QuarantineError(
                f"swap-in checksum mismatch for uid={req.uid}: the "
                "host-side resume payload was corrupted while swapped "
                "out", uid=req.uid)
        # Rebuild the padded pool upload from the per-page host copies.
        pools_np = {k: np.zeros(shape, dtype)
                    for k, (shape, dtype) in resume["pool_meta"].items()}
        for k, pl in tier_payloads.items():
            sk = k + "_scale"
            for j, p in enumerate(pl):
                pools_np[k][:, j] = p["data"]
                if "scale" in p and sk in pools_np:
                    pools_np[sk][:, j] = p["scale"]
        for k, pg in resume["tier_pages"].items():
            kind = kv_tiers_mod.POOL_OF[k]
            self.tiers.free_pages(kind, pg)
            self.tiers.record("host", "hot", kind, len(pg))
        resume["tier_pages"] = {}
        req.resume_state = None
        pages = self._slot_pages[i]
        vecs = [self._page_vec(pages.get(k, []))
                for k in ("kg", "vg", "kc", "vc")]
        _, swap_in = self._swap_fns_get()
        cols = {k: jnp.asarray(v) for k, v in resume["cols"].items()}
        pools = {k: jnp.asarray(v) for k, v in pools_np.items()}
        self._dev_state = swap_in(self._dev_state, jnp.int32(i), cols,
                                  pools, *vecs, *vecs)
        if self.chai_on:
            dev_ctx = {k: jnp.asarray(v) for k, v in resume["ctx"].items()}
            self._dev_ctx = self._set_ctx(self._dev_ctx, dev_ctx,
                                          jnp.int32(i))
            self._ctx_version += 1
        self._phases[i] = resume["phase"]
        self._slot_count[i] = resume["count"]
        self._next_tok[i] = req.generated[-1]
        self._tok_dirty = True
        self._record_kv_bytes(self._phases)

    def _try_preempt(self, head: Request) -> bool:
        """Reclaim the lowest-priority running slot that ``head``
        strictly outranks (ties never preempt). Returns True when a slot
        was preempted — the caller retries its admission plan."""
        if not (self.ecfg.preemption and self.paged):
            return False
        victims = [i for i in range(self.ecfg.batch_slots)
                   if self._slot_req[i] is not None
                   and self._slot_req[i].priority < head.priority]
        if not victims:
            return False
        # Lowest priority first; among equals the most recent admission
        # loses (least progress thrown away).
        i = min(victims, key=lambda j: (self._slot_req[j].priority,
                                        -self._slot_req[j].admit_step))
        if (self.tiers is not None
                and int(self._phases[i]) != chai_cache.PHASE_PREFILL):
            # The victim's payload lands in the host tier: make room
            # there first (compressing / dropping demoted cache entries
            # LRU-first). No room -> no preemption this step.
            pages = self._slot_pages[i]
            need = {}
            d = len(pages.get("kg", ())) + len(pages.get("vg", ()))
            c = len(pages.get("kc", ())) + len(pages.get("vc", ()))
            if d:
                need["dense"] = d
            if c:
                need["chai"] = c
            if need and not self.tiers.make_room(need):
                return False
        self._preempt_slot(i)
        return True

    def _preempt_slot(self, i: int):
        """Evict slot ``i`` WITHOUT finishing its request: swap its KV to
        the host (per-slot state columns + page contents — generated
        tokens stay on the Request), free every page refcount-exactly via
        the abort path's mechanics, and re-queue the request right behind
        the current queue head. A mid-PREFILL victim has no decode state
        worth saving and simply restarts its prefill. The victim's pages
        are indexed into the prefix cache first, so — if they survive the
        preemptor's own allocation — OTHER requests sharing the prompt
        prefix can still alias them."""
        r = self._slot_req[i]
        phase = int(self._phases[i])
        if phase != chai_cache.PHASE_PREFILL:
            pages = self._slot_pages[i]
            vecs = [self._page_vec(pages.get(k, []))
                    for k in ("kg", "vg", "kc", "vc")]
            swap_out, _ = self._swap_fns_get()
            with self.tel.span("preempt.swap", step=self._span_step,
                               slot=i):
                cols, pools = swap_out(self._dev_state, jnp.int32(i),
                                       *vecs)
            pools_host = jax.device_get(pools)
            npages = {k: len(pages.get(k, ()))
                      for k in ("kg", "vg", "kc", "vc")}
            # Split the padded pool gathers into per-real-page payloads
            # (copies, so the big padded arrays are released) — these go
            # into the SAME host page pool prefix-cache demotion uses.
            payloads = {}
            for k in ("kg", "vg", "kc", "vc"):
                if k not in pools_host or not npages[k]:
                    continue
                sk = k + "_scale"
                pl = []
                for j in range(npages[k]):
                    p = {"data": np.array(pools_host[k][:, j])}
                    if sk in pools_host:
                        p["scale"] = np.array(pools_host[sk][:, j])
                    pl.append(p)
                payloads[k] = pl
            resume = {
                "phase": phase, "count": self._slot_count[i],
                "cols": jax.device_get(cols),
                "npages": npages,
                # Padded shapes/dtypes to rebuild the swap-in upload.
                "pool_meta": {k: (v.shape, v.dtype)
                              for k, v in pools_host.items()},
            }
            if self.chai_on:
                resume["ctx"] = {k: np.asarray(v[:, i])
                                 for k, v in self._dev_ctx.items()}
            # Integrity stamp: swap-in verifies this before touching the
            # device, so host-side damage to the payload quarantines the
            # request instead of restoring corrupted KV.
            tree = {k: {str(j): p for j, p in enumerate(pl)}
                    for k, pl in payloads.items()}
            resume["crc"] = faults_mod.checksum_arrays(
                {"cols": resume["cols"], "pools": tree})
            if self._fault("swap.corrupt", uid=r.uid) is not None:
                faults_mod.corrupt_arrays(tree, seed=self.faults.seed)
            tier_pages = {}
            for k, pl in payloads.items():
                kind = kv_tiers_mod.POOL_OF[k]
                tier_pages[k] = self.tiers.store_pages(kind, pl)
                self.tiers.record("hot", "host", kind, len(pl))
            resume["tier_pages"] = tier_pages
            r.resume_state = resume
            if self.prefix_cache is not None:
                self._index_retired(r, self._slot_pages[i])
        r.preemptions += 1
        self.preemptions += 1
        self._slot_prefill_state[i] = None
        self._slot_req[i] = None
        self._phases[i] = chai_cache.PHASE_FREE
        self._slot_count[i] = 0
        self._dev_state = self._reset_slot(self._dev_state, jnp.int32(i))
        self._free_pages(self._slot_pages[i])
        if self._slot_locked[i]:
            self.prefix_cache.unlock(self._slot_locked[i])
            self._slot_locked[i] = []
        self._samp_host["temperature"][i] = 0.0
        self._samp_dirty = True
        self.queue.insert(min(1, len(self.queue)), r)
        if self.tel.enabled:
            self.tel.counter("preemptions_total",
                             help="Slots reclaimed for a higher-priority "
                                  "arrival")
            self.tel.event(r.uid, "preempt", slot=i,
                           phase=_PHASE_NAMES.get(phase, str(phase)),
                           step=self.steps_executed)

    def _cluster_transitions(self, active, outs: List[StepOutput]):
        """CLUSTER + compact slots whose warmup just completed; paged:
        the slot's dense K pages return to the pool here. An injected
        ``kernel.cluster`` fault quarantines the transitioning request
        BEFORE clustering mutates the pools (``outs`` receives its typed
        StepOutput); other slots keep decoding."""
        if not self.chai_on:
            return
        cfg = self.cfg
        warm = cfg.chai.warmup_tokens
        for i in active:
            if not (self._slot_count[i] == warm + 1
                    and self._phases[i] == chai_cache.PHASE_WARMUP):
                continue
            req = self._slot_req[i]
            if self._fault("kernel.cluster", uid=req.uid) is not None:
                self._retire_slot(
                    i, sampling_mod.FINISH_ERROR, index=False,
                    error=f"injected cluster-transition failure for "
                          f"uid={req.uid}")
                outs.append(StepOutput(req.uid, [], True,
                                       sampling_mod.FINISH_ERROR))
                continue
            self._phases[i] = chai_cache.PHASE_CLUSTER
            self.cluster_transitions += 1
            if self.tel.enabled:
                self.tel.counter("cluster_transitions_total",
                                 help="WARMUP->CLUSTER->STEADY "
                                      "transitions executed")
                self.tel.event(req.uid, "phase", phase="CLUSTER", slot=i)
            if self.paged:
                kc_vec = self._page_vec(self._slot_pages[i].get("kc", []))
                vc_vec = self._page_vec(self._slot_pages[i].get("vc", []))
                self._dev_state, self._dev_ctx = self._cluster_fn()(
                    self._dev_state, self._dev_ctx, jnp.int32(i),
                    kc_vec, vc_vec)
                self._ctx_version += 1
                if (self.prefix_cache is not None
                        and self.chai_clustered
                        and self._slot_req[i].sampling.greedy):
                    self._capture_snapshot(i, self._slot_req[i],
                                           self._slot_pages[i])
                if self.chai_clustered:
                    freed = len(self._slot_pages[i]["kg"])
                    self.dense_pool.free(self._slot_pages[i].pop("kg"))
                    if cfg.chai.share_values:
                        freed += len(self._slot_pages[i]["vg"])
                        self.dense_pool.free(self._slot_pages[i].pop("vg"))
                    if self.tel.enabled:
                        self.tel.counter(
                            "chai_dense_pages_freed_total", freed,
                            help="Dense pages freed at compaction (the "
                                 "paper's KV saving, realized)")
                self._record_kv_bytes(self._phases)
            else:
                self._dev_state, self._dev_ctx = self._cluster_fn()(
                    self._dev_state, self._dev_ctx, jnp.int32(i))
                self._ctx_version += 1
            self._phases[i] = chai_cache.PHASE_STEADY
            if self.tel.enabled:
                self.tel.event(req.uid, "phase", phase="STEADY", slot=i)
                self._tel_clusters(i)

    # -- shared-prefix relay decode ----------------------------------------
    def _ctx_host(self):
        """np mirror of the clustering context, rebuilt only when the
        ctx version moved (CLUSTER transition / snapshot restore /
        preemption swap-in)."""
        if (self._ctx_host_cache is None
                or self._ctx_host_cache[0] != self._ctx_version):
            self._ctx_host_cache = (
                self._ctx_version,
                {k: np.asarray(v) for k, v in self._dev_ctx.items()})
        return self._ctx_host_cache[1]

    def _pack_prefix_fn(self, n_pages):
        """Jit that copies ``n_pages`` dense-pool prefix pages into a
        contiguous ``(nG, rows, n_pages*page, hd)`` resident view (+ int8
        scale planes). A copy, not an alias: relay steps donate the
        state, and cached views must survive the buffer reuse."""
        fn = self._pack_prefix.get(n_pages)
        if fn is None:
            def pack(state, kg, vg):
                def view(bt):
                    g = state["kvp"][:, bt]     # (nG, p0, rows, page, hd)
                    ng, p, rows, page, hd = g.shape
                    return (g.transpose(0, 2, 1, 3, 4)
                            .reshape(ng, rows, p * page, hd))
                out = {"k": view(kg), "v": view(vg)}
                if state.get("kvp_scale") is not None:
                    def sview(bt):
                        sg = state["kvp_scale"][:, bt]
                        ng, p, rows, page = sg.shape
                        return (sg.transpose(0, 2, 1, 3)
                                .reshape(ng, rows, p * page))
                    out["k_scale"] = sview(kg)
                    out["v_scale"] = sview(vg)
                return out
            fn = jax.jit(pack)
            self._pack_prefix[n_pages] = fn
        return fn

    def _resident_view(self, chain):
        """Packed resident copy of a radix chain's shared pages, cached
        on the deepest node and keyed by the chain's page identity.
        Prefix pages are immutable while cached (COW re-plans divergent
        writers onto fresh pages; eviction flips ``node.evicted`` and
        drops ``node.resident``), so the cache survives across steps."""
        node = chain[-1]
        key = (tuple(n.kg_page for n in chain),
               tuple(n.vg_page for n in chain))
        if node.resident is None or node.resident[0] != key:
            fn = self._pack_prefix_fn(len(chain))
            node.resident = (key, fn(self._dev_state,
                                     jnp.asarray(key[0], jnp.int32),
                                     jnp.asarray(key[1], jnp.int32)))
        return node.resident[1]

    def _relay_row_maps(self, groups, nmax):
        """Per-layer kernel row maps for the grouped prefix pass (see
        ``repro.core.chai_attention._relay_prefix_state`` for the layout
        contract). Host numpy, cached per (ctx version, membership):
        padded member entries keep index 0 — their rows compute garbage
        the per-slot scatter discards."""
        key = (self._ctx_version,
               tuple((id(g["node"]), tuple(g["members"])) for g in groups))
        if (self._relay_rows_cache is not None
                and self._relay_rows_cache[0] == key):
            return self._relay_rows_cache[1]
        ctx = self._ctx_host()
        cfg = self.cfg
        G = len(groups)
        if cfg.is_mha:
            reps, h2c = ctx["reps"], ctx["h2c"]   # (nA,B,R), (nA,B,H)
            nA, _, R = reps.shape
            H = h2c.shape[-1]
            share = cfg.chai.share_values
            A = nmax * (R if share else H)
            k_row = np.zeros((nA, G, nmax * R), np.int32)
            a_row = np.zeros((nA, G, A), np.int32)
            v_row = np.zeros((nA, G, A), np.int32)
            for g, grp in enumerate(groups):
                for j, slot in enumerate(grp["members"]):
                    # Prefix K = the slot's rep rows gathered from the
                    # chain's DENSE pages (bitwise == the clustered rows
                    # the suffix pass reads: compaction is a gather).
                    k_row[:, g, j * R:(j + 1) * R] = reps[:, slot]
                    if share:
                        # share_values: acc stays per-rep; V gathers the
                        # rep's dense row (scale-less under int8 — the
                        # codes were moved into cp, not requantized).
                        a_row[:, g, j * R:(j + 1) * R] = \
                            j * R + np.arange(R, dtype=np.int32)
                        v_row[:, g, j * R:(j + 1) * R] = reps[:, slot]
                    else:
                        a_row[:, g, j * H:(j + 1) * H] = \
                            j * R + h2c[:, slot]
                        v_row[:, g, j * H:(j + 1) * H] = \
                            np.arange(H, dtype=np.int32)
        else:
            reps = ctx["reps"]                  # (nA, B, KV, r)
            cluster_of = ctx["cluster_of"]      # (nA, B, KV, qpk)
            nA, _, n_kv, r = reps.shape
            qpk = cluster_of.shape[-1]
            H = n_kv * qpk
            rt = n_kv * r
            k_row = np.zeros((nA, G, nmax * rt), np.int32)
            a_row = np.zeros((nA, G, nmax * H), np.int32)
            v_row = np.zeros((nA, G, nmax * H), np.int32)
            kv_of_rep = np.repeat(np.arange(n_kv, dtype=np.int32), r)
            kv_of_head = np.repeat(np.arange(n_kv, dtype=np.int32), qpk)
            for g, grp in enumerate(groups):
                for j, slot in enumerate(grp["members"]):
                    k_row[:, g, j * rt:(j + 1) * rt] = kv_of_rep
                    h2c_flat = (np.arange(n_kv, dtype=np.int32)
                                [None, :, None] * r
                                + cluster_of[:, slot]).reshape(nA, H)
                    a_row[:, g, j * H:(j + 1) * H] = j * rt + h2c_flat
                    v_row[:, g, j * H:(j + 1) * H] = kv_of_head
        maps = {"k_row": jnp.asarray(k_row), "a_row": jnp.asarray(a_row),
                "v_row": jnp.asarray(v_row)}
        self._relay_rows_cache = (key, maps)
        return maps

    def _build_relay(self, active):
        """Form shared-prefix relay groups over the STEADY slots.

        Slots admitted through the radix prefix cache keep their matched
        chain pinned in ``_slot_locked``; each slot picks the DEEPEST
        chain node shared by >= ``relay_min_group`` eligible slots, and
        slots that picked the same node form one group. Returns the
        relay dict consumed by ``make_relay_step`` (``None`` -> plain
        phase-mix dispatch): group-batched resident prefix views + row
        maps + per-slot scatter coords. Non-grouped slots ride along
        with ``in_group=False`` / ``len=0`` — the merge identity keeps
        them bitwise-identical to the non-relay path."""
        from repro.core import chai_attention as chai_mod
        from repro.serving.prefix_cache import BlockNode
        if not chai_mod.USE_FUSED_DECODE or self.degraded_decode:
            return None       # jnp fallback attends full tables already
        min_g = max(1, self.ecfg.relay_min_group)
        chains = {}
        for i in active:
            if self._phases[i] != chai_cache.PHASE_STEADY:
                continue
            locked = self._slot_locked[i]
            if not locked or not all(isinstance(e, BlockNode)
                                     for e in locked):
                continue      # snapshot pins / no radix plan
            if any(e.evicted for e in locked):
                continue      # chain lost pages since admission
            chains[i] = locked
        if len(chains) < min_g:
            return None
        counts: dict = {}
        for chain in chains.values():
            for node in chain:
                counts[id(node)] = counts.get(id(node), 0) + 1
        by_node: dict = {}
        for i, chain in sorted(chains.items()):
            pick = None
            for depth, node in enumerate(chain, start=1):
                if counts[id(node)] >= min_g:
                    pick = (node, depth)        # deepest wins
            if pick is None:
                continue
            node, depth = pick
            grp = by_node.setdefault(
                id(node), {"node": node, "depth": depth, "members": []})
            grp["members"].append(i)
        groups = [g for g in by_node.values()
                  if len(g["members"]) >= min_g]
        if not groups:
            return None
        if self._fault("relay.residency") is not None:
            # Dissolve the groups formed this step to the per-request
            # decode path — grouped-vs-ungrouped is token-identical, so
            # dissolving is always safe.
            self.relay_dissolved += 1
            if self.tel.enabled:
                self.tel.counter("relay_dissolved_total",
                                 help="Relay groups dissolved by an "
                                      "injected residency fault")
            return None
        ps = self.ecfg.page_size
        b = self.ecfg.batch_slots
        nmax = max(len(g["members"]) for g in groups)
        packs = [self._resident_view(chains[g["members"][0]][:g["depth"]])
                 for g in groups]
        sp_max = max(p["k"].shape[2] for p in packs)

        def stack(name):
            arrs = []
            for p in packs:
                a = p[name]
                pad = sp_max - a.shape[2]
                if pad:     # zero tail; plen masks it in the kernel
                    widths = [(0, 0)] * a.ndim
                    widths[2] = (0, pad)
                    a = jnp.pad(a, widths)
                arrs.append(a)
            return jnp.stack(arrs, axis=1)

        relay = {"k": stack("k"), "v": stack("v")}
        if "k_scale" in packs[0]:
            relay["k_scale"] = stack("k_scale")
            relay["v_scale"] = stack("v_scale")
        members = np.zeros((len(groups), nmax), np.int32)
        plen_g = np.zeros((len(groups),), np.int32)
        gid = np.zeros((b,), np.int32)
        midx = np.zeros((b,), np.int32)
        plen_b = np.zeros((b,), np.int32)
        ing = np.zeros((b,), bool)
        for g, grp in enumerate(groups):
            plen_g[g] = grp["depth"] * ps
            for j, slot in enumerate(grp["members"]):
                members[g, j] = slot
                gid[slot] = g
                midx[slot] = j
                plen_b[slot] = plen_g[g]
                ing[slot] = True
        relay.update(self._relay_row_maps(groups, nmax))
        relay.update({
            "plen": jnp.asarray(plen_g), "members": jnp.asarray(members),
            "gid": jnp.asarray(gid), "midx": jnp.asarray(midx),
            "len": jnp.asarray(plen_b), "in_group": jnp.asarray(ing)})
        self.relay_grouped_slots += int(ing.sum())
        if self.tel.enabled:
            self.tel.counter("relay_groups_formed_total", len(groups),
                             help="Shared-prefix relay groups formed")
            self.tel.counter("relay_grouped_slots_total", int(ing.sum()),
                             help="Slot-steps decoded through a relay "
                                  "group")
        return relay

    def _decode(self, active) -> List[StepOutput]:
        """One batched decode step; host-dispatch the cheapest jit that
        covers the current phase mix, then one batched sample. The token
        and SamplingParams vectors live on device between steps; host
        mirrors are re-uploaded only after an admission/retire edited
        them."""
        outs: List[StepOutput] = []
        tel = self.tel
        step_no = self._span_step
        b = self.ecfg.batch_slots
        if self._tok_dirty:
            self._next_tok_dev = jnp.asarray(self._next_tok)
            self._tok_dirty = False
        inputs = {"tokens": self._next_tok_dev}
        occupied = self._phases[self._phases != chai_cache.PHASE_FREE]
        with tel.span("relay.form", step=step_no):
            relay = self._build_relay(active) if self.relay_decode \
                else None
        self._decode_fault_hit = False
        try:
            with tel.span("decode.dispatch", step=step_no,
                          degraded=self.degraded_decode):
                logits, state = self._dispatch_decode(inputs, relay,
                                                      occupied)
        except Exception as err:
            if isinstance(err, EngineFault):
                raise
            # Kernel-path failure (injected or real): fall back to the
            # jnp reference jits for this engine and retry the step
            # (``decode_heal_steps`` can revert later). Safe on CPU
            # (buffer donation is a no-op there); donating backends
            # would need a state re-upload first.
            self.degraded_decode = True
            self.decode_fallbacks += 1
            self._heal_clean = 0
            if tel.enabled:
                tel.counter("decode_fallbacks_total",
                            help="Fused-decode failures survived via the "
                                 "jnp reference fallback")
                tel.gauge("engine_degraded_decode", 1,
                          help="1 while decode runs the jnp reference "
                               "fallback")
            try:
                with tel.span("decode.dispatch", step=step_no,
                              degraded=True, retry=True):
                    logits, state = self._dispatch_decode(inputs, None,
                                                          occupied)
            except Exception as err2:
                raise EngineFault(
                    "decode failed on the fused path AND the jnp "
                    f"reference fallback: {err2!r} "
                    f"(original failure: {err!r})") from err2
        else:
            if self.degraded_decode and self.ecfg.decode_heal_steps > 0:
                self._maybe_heal()
        if self.faults is not None:
            for i in active:
                if self._fault("step.logits",
                               uid=self._slot_req[i].uid) is not None:
                    logits = logits.at[i].set(jnp.nan)
        sample_cm = tel.span("sample", step=step_no)
        sample_cm.__enter__()
        finite = np.asarray(self._finite_rows(logits))
        self._dev_state = state
        temps = self._samp_host["temperature"]
        if not temps.any():
            tok_dev = self._argmax(logits)      # all-greedy fast path
        else:
            counts = np.zeros((b,), np.int32)
            for i in active:
                counts[i] = len(self._slot_req[i].generated)
            rows = np.nonzero(temps > 0.0)[0]
            nb = 1 << (len(rows) - 1).bit_length()
            if nb < b:
                # Mixed batch: greedy slots skip the sampling lane
                # (argsort + softmax + PRNG) entirely — the sampler runs
                # on a gathered power-of-two sub-batch of the sampling
                # rows, scattered over the batched argmax. Bitwise-
                # identical to the full sampler: each row's draw depends
                # only on that row's (logits, params, seed, count), and
                # greedy rows argmax the same raw f32 logits either way.
                idx = np.full((nb,), rows[0], np.int32)   # pad: dup row0
                idx[:len(rows)] = rows
                idx_dev = jnp.asarray(idx)
                drawn = self._sampler(
                    self._take_rows(logits, idx_dev),
                    jnp.asarray(temps[idx]),
                    jnp.asarray(self._samp_host["top_k"][idx]),
                    jnp.asarray(self._samp_host["top_p"][idx]),
                    jnp.asarray(self._samp_host["seed"][idx]),
                    jnp.asarray(counts[idx]))
                tok_dev = self._put_rows(self._argmax(logits), idx_dev,
                                         drawn)
            else:
                if self._samp_dirty:
                    self._samp_dev = {k: jnp.asarray(v)
                                      for k, v in self._samp_host.items()}
                    self._samp_dirty = False
                tok_dev = self._sampler(logits,
                                        self._samp_dev["temperature"],
                                        self._samp_dev["top_k"],
                                        self._samp_dev["top_p"],
                                        self._samp_dev["seed"],
                                        jnp.asarray(counts))
        self._next_tok_dev = tok_dev
        toks = np.asarray(tok_dev)
        self._next_tok[:] = toks
        sample_cm.__exit__(None, None, None)
        self.steps_executed += 1
        retire_cm = tel.span("retire", step=step_no)
        retire_cm.__enter__()
        for i in active:
            r = self._slot_req[i]
            if not finite[i]:
                # NaN/Inf logits: the slot's sampled token is garbage —
                # quarantine this request; rows are independent, so the
                # other slots' draws are exactly what they would have
                # been.
                self._retire_slot(
                    i, sampling_mod.FINISH_ERROR, index=False,
                    error=f"non-finite logits for uid={r.uid} at step "
                          f"{self.steps_executed - 1}")
                outs.append(StepOutput(r.uid, [], True,
                                       sampling_mod.FINISH_ERROR))
                continue
            r.generated.append(int(toks[i]))
            self._slot_count[i] += 1
            if tel.enabled:
                tel.counter("tokens_generated_total",
                            help="Generated tokens emitted")
                tel.token(r.uid)
            reason = self._finish_of(r)
            if reason:
                self._retire_slot(i, reason)
            outs.append(StepOutput(r.uid, [int(toks[i])], bool(reason),
                                   reason))
        retire_cm.__exit__(None, None, None)
        if self.paged:
            self._record_kv_bytes(self._phases)
        return outs

    def _dispatch_decode(self, inputs, relay, occupied):
        """Host-dispatch the cheapest step jit covering the phase mix
        (relay -> all-CHAI -> all-MHA -> mixed). ``degraded_decode``
        swaps in the jnp reference jits (``_jnp_decode_steps``) — same
        makers, traced with the fused kernels disabled."""
        state = self._dev_state
        if self._fault("kernel.decode") is not None:
            self._decode_fault_hit = True
            if not self.degraded_decode:
                raise InjectedFault("kernel.decode")
        if relay is not None:
            self.relay_steps += 1
            return self._relay_step(self.params, inputs, state,
                                    self._dev_ctx, relay)
        if self.degraded_decode:
            steps = self._jnp_decode_steps()
            mha = steps["mha"]
            chai, mixed = steps.get("chai"), steps.get("mixed")
        else:
            mha = self._mha_step
            chai = self._chai_step if self.chai_on else None
            mixed = self._mixed_step if self.chai_on else None
        if not self.chai_on:
            return mha(self.params, inputs, state)
        if (occupied == chai_cache.PHASE_STEADY).all():
            return chai(self.params, inputs, state, self._dev_ctx)
        if (occupied == chai_cache.PHASE_WARMUP).all():
            return mha(self.params, inputs, state)
        return mixed(self.params, inputs, state, self._dev_ctx)

    def _maybe_heal(self):
        """Degraded-decode healing: after ``decode_heal_steps``
        consecutive clean decode steps (dispatch succeeded and the
        kernel.decode injector stayed quiet), revert to the fused jits.
        A firing arm — even one masked by the degraded path — resets the
        clean-step count."""
        if self._decode_fault_hit:
            self._heal_clean = 0
            return
        self._heal_clean += 1
        if self._heal_clean < self.ecfg.decode_heal_steps:
            return
        self.degraded_decode = False
        self.decode_heals += 1
        self._heal_clean = 0
        if self.tel.enabled:
            self.tel.counter("decode_heals_total",
                             help="Degraded decode reverted to the fused "
                                  "kernel path")
            self.tel.gauge("engine_degraded_decode", 0,
                           help="1 while decode runs the jnp reference "
                                "fallback")

    def _jnp_decode_steps(self):
        """Degraded decode jits, built lazily on the first kernel-path
        failure: the SAME step makers, but the module flag that routes
        decode attention to the fused Pallas kernels is held False while
        each jit traces, so the whole phase mix runs on the jnp
        reference path (token-parity with the fused path; the relay is
        skipped — ``_build_relay`` returns None while degraded)."""
        if self._jnp_steps is None:
            from repro.core import chai_attention as chai_mod

            def unfused(fn):
                def wrapped(*args):
                    prev = chai_mod.USE_FUSED_DECODE
                    chai_mod.USE_FUSED_DECODE = False
                    try:
                        return fn(*args)
                    finally:
                        chai_mod.USE_FUSED_DECODE = prev
                return wrapped

            cfg, ts = self.cfg, self.ecfg.page_size
            steps = {"mha": jax.jit(
                unfused(steps_mod.make_serve_step(cfg, chai=False,
                                                  decode_ts=ts)),
                donate_argnums=(2,))}
            if self.chai_on:
                steps["chai"] = jax.jit(
                    unfused(steps_mod.make_serve_step(cfg, chai=True,
                                                      decode_ts=ts)),
                    donate_argnums=(2,))
                steps["mixed"] = jax.jit(
                    unfused(steps_mod.make_mixed_step(cfg, decode_ts=ts)),
                    donate_argnums=(2,))
            self._jnp_steps = steps
        return self._jnp_steps

    def _retire_slot(self, i: int, reason: str, *, error: str = "",
                     index: bool = True):
        """Retire/abort slot ``i``: finalize the request, index its full
        sequence into the prefix cache (when the slot still holds its
        dense pages), reset the slot on device, and return every page it
        held to the pools (refcount-exact; shared pages survive while the
        cache or concurrent slots reference them). Quarantine retires
        pass ``error`` (recorded on the Request) and ``index=False`` —
        a damaged sequence must never seed the prefix cache."""
        r = self._slot_req[i]
        r.generated = r.generated[:r.max_new_tokens]
        r.finish_reason = reason
        r.error = error
        if error:
            self.quarantined += 1
            if self.tel.enabled:
                self.tel.event(r.uid, "quarantine", reason=error)
        r.t_done = time.time()
        r.retire_step = self.steps_executed
        self._done(r)
        self._slot_req[i] = None
        self._phases[i] = chai_cache.PHASE_FREE
        self._slot_count[i] = 0
        if index and self.paged and self.prefix_cache is not None:
            self._index_retired(r, self._slot_pages[i])
        self._dev_state = self._reset_slot(self._dev_state, jnp.int32(i))
        if self.paged:      # block tables are nulled; pages go back
            self._free_pages(self._slot_pages[i])
            if self._slot_locked[i]:
                self.prefix_cache.unlock(self._slot_locked[i])
                self._slot_locked[i] = []
        self._samp_host["temperature"][i] = 0.0     # FREE slots: greedy
        self._samp_dirty = True

    def _index_retired(self, req: Request, pages: dict):
        """Retire-time radix insertion: index the slot's FULL sequence
        (prompt + generated) so a follow-up turn — ``Session`` chat over
        the same history — prefills only its new suffix. Decode wrote K/V
        for every token except the last sampled one, and only slots that
        still hold their dense K AND V pages have a complete paged record
        (clustered-CHAI slots freed dense K at compaction; their reuse
        path is the prompt-keyed snapshot instead)."""
        if "kg" not in pages or "vg" not in pages:
            return
        seq = list(map(int, req.prompt)) + list(req.generated[:-1])
        self.prefix_cache.insert(seq, pages["kg"], pages["vg"])

    # -- metrics ------------------------------------------------------------
    def fault_stats(self):
        """Robustness counters + the injector's replayable plan/firing
        log (None when no injector is armed)."""
        return {"quarantined": self.quarantined,
                "audit_steps": self.audit_steps,
                "degraded_decode": self.degraded_decode,
                "decode_fallbacks": self.decode_fallbacks,
                "decode_heals": self.decode_heals,
                "relay_dissolved": self.relay_dissolved,
                "swap_checksum_failures": self.swap_checksum_failures,
                "offload_checksum_failures": self.offload_checksum_failures,
                "injector": (self.faults.report()
                             if self.faults is not None else None)}

    def prefix_stats(self):
        """Prefix-cache counters + current residency (empty when the
        cache is off)."""
        if self.prefix_cache is None:
            return {}
        dense_held, chai_held = self.prefix_cache.held_pages()
        return {**self.prefix_cache.stats,
                "blocks": self.prefix_cache.num_blocks,
                "snapshots": self.prefix_cache.num_snapshots,
                "dense_page_refs": dense_held,
                "chai_page_refs": chai_held}

    def tier_stats(self):
        """Hierarchical KV tier counters: per-tier residency, transition
        totals, and prefetch hit/miss counts (None when the engine has
        no tiers — i.e. the dense layout)."""
        if self.tiers is None:
            return None
        out = self.tiers.stats()
        out["prefetch_hits"] = self.prefetch_hits
        out["prefetch_misses"] = self.prefetch_misses
        out["offload_checksum_failures"] = self.offload_checksum_failures
        return out

    def kv_bytes(self, *, chai: Optional[bool] = None):
        """KV-cache bytes. With explicit ``chai=``: the paper's ANALYTIC
        steady-state size (Fig 11 A/B comparisons) — hardware-independent,
        unchanged by the engine's layout. With no argument: this engine's
        actual footprint for the continuous scheduler —

        * ``kv_layout="paged"``: allocated-page bytes right now (pages in
          use x page bytes + the non-paged local rings). This falls when
          a slot's dense pages are freed at compaction, so steady-state
          CHAI reports LESS than the dense-MHA rectangle — the paper's
          saving realized by the allocator. ``kv_bytes_history`` records
          the trajectory; ``kv_bytes_capacity()`` gives the pools' total
          reservation.
        * ``kv_layout="dense"``: the unified layout's constant residency
          (dense + clustered rectangles side by side — MORE than plain
          MHA; this over-count is what the paged layout removes).
        """
        if chai is None and self.ecfg.scheduler == "continuous":
            if self.paged:
                return chai_cache.paged_kv_bytes(
                    self.cfg, self.ecfg.page_size,
                    self.dense_pool.pages_in_use,
                    self.chai_pool.pages_in_use if self.chai_pool else 0,
                    batch=self.ecfg.batch_slots, max_seq=self.ecfg.max_seq)
            return chai_cache.unified_kv_bytes(
                self.cfg, self.ecfg.batch_slots, self.ecfg.max_seq,
                chai=self.chai_on)
        chai = self.chai_on if chai is None else chai
        return chai_cache.kv_cache_bytes(
            self.cfg, self.ecfg.batch_slots, self.ecfg.max_seq, chai=chai)

    def kv_bytes_peak(self):
        """Paged: high-water allocated bytes over the run (O(1): a
        running maximum, not a history scan)."""
        if not self.paged:
            return 0
        return max(self._kv_peak, self.kv_bytes())

    def kv_bytes_capacity(self):
        """Paged: bytes if every pool page were in use (the device-side
        reservation); dense layouts: the resident footprint."""
        if not self.paged:
            return self.kv_bytes()
        return chai_cache.paged_kv_bytes(
            self.cfg, self.ecfg.page_size, self.dense_pool.capacity,
            self.chai_pool.capacity if self.chai_pool else 0,
            batch=self.ecfg.batch_slots, max_seq=self.ecfg.max_seq)

    def throughput(self):
        """Completed requests per second of engine wall time."""
        if not self.done:
            return 0.0
        t0 = min(r.t_arrival for r in self.done)
        t1 = max(r.t_done for r in self.done)
        return len(self.done) / max(t1 - t0, 1e-9)


class ServingEngine(EngineCore):
    """Historical batch surface — a thin compatibility wrapper over the
    step-driven ``EngineCore``: ``submit()`` enqueues (optionally with
    ``sampling=SamplingParams(...)``), ``run()`` loops ``step()`` until
    the queue drains. New code should prefer ``repro.serving.api.LLM``
    (generate / stream / abort / Session) or drive ``step()`` directly.
    """

    def submit(self, prompt, max_new_tokens=32, uid=None, *,
               arrival_delay: float = 0.0,
               sampling: Optional[SamplingParams] = None):
        """Enqueue a request (see ``EngineCore.add_request``)."""
        return self.add_request(prompt, sampling,
                                max_new_tokens=max_new_tokens, uid=uid,
                                arrival_delay=arrival_delay)

    def run(self):
        """Drain the queue; returns completed requests."""
        if self.ecfg.scheduler == "cohort":
            return self._run_cohort_loop()
        while self.has_work():
            outs = self.step()
            if not outs and not self.has_active and self.queue:
                # open-loop idle: wait for the next arrival
                time.sleep(max(1e-4,
                               self.queue[0].t_arrival - time.time()))
        return self.done
