"""K-Means in pure JAX (jit/vmap-able, static k, deterministic init).

Used online per request for CHAI cluster-membership identification
(paper §3.3) and offline for elbow analysis (§3.2). Initialization is
deterministic greedy farthest-point (no PRNG needed at serving time);
Lloyd iterations run under ``lax.fori_loop``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _pairwise_sq_dists(x, c):
    """x: (n, f); c: (k, f) -> (n, k)."""
    x2 = jnp.sum(jnp.square(x), -1, keepdims=True)
    c2 = jnp.sum(jnp.square(c), -1)
    return x2 + c2[None, :] - 2.0 * (x @ c.T)


def farthest_point_init(x, k):
    """Deterministic k-center init: start at the point farthest from the
    mean, then greedily add the point farthest from chosen centers."""
    n, f = x.shape
    d0 = jnp.sum(jnp.square(x - x.mean(0)), -1)
    first = jnp.argmax(d0)
    centers = jnp.zeros((k, f), x.dtype).at[0].set(x[first])
    mind = jnp.sum(jnp.square(x - x[first]), -1)

    def body(i, carry):
        centers, mind = carry
        nxt = jnp.argmax(mind)
        centers = centers.at[i].set(x[nxt])
        d = jnp.sum(jnp.square(x - x[nxt]), -1)
        return centers, jnp.minimum(mind, d)

    centers, _ = jax.lax.fori_loop(1, k, body, (centers, mind))
    return centers


def kmeans(x, k: int, iters: int = 12):
    """Lloyd's algorithm. x: (n, f) fp32. Returns (assign (n,), centers (k,f),
    error: sum of squared distances)."""
    x = x.astype(jnp.float32)
    centers0 = farthest_point_init(x, k)

    def body(_, centers):
        d = _pairwise_sq_dists(x, centers)              # (n, k)
        assign = jnp.argmin(d, -1)
        onehot = jax.nn.one_hot(assign, k, dtype=jnp.float32)  # (n, k)
        counts = onehot.sum(0)                          # (k,)
        sums = onehot.T @ x                             # (k, f)
        new = jnp.where(counts[:, None] > 0,
                        sums / jnp.maximum(counts[:, None], 1.0), centers)
        return new

    centers = jax.lax.fori_loop(0, iters, body, centers0)
    d = _pairwise_sq_dists(x, centers)
    assign = jnp.argmin(d, -1)
    err = jnp.sum(jnp.min(d, -1))
    return assign, centers, err


def representatives(x, assign, centers, k: int):
    """Representative member per cluster = member closest to its center.

    Returns (reps (k,) int32 — indices into x; valid (k,) bool)."""
    d = _pairwise_sq_dists(x, centers)                  # (n, k)
    member = jax.nn.one_hot(assign, k, dtype=jnp.bool_)  # (n, k)
    d_masked = jnp.where(member, d, jnp.inf)
    reps = jnp.argmin(d_masked, axis=0).astype(jnp.int32)
    valid = member.any(axis=0)
    # Empty clusters: point the rep at member 0 (never referenced).
    return jnp.where(valid, reps, 0), valid
