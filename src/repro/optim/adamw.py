"""AdamW with global-norm clipping and cosine schedule (optax-free).

Optimizer state shards exactly like the parameters (the moment pytrees reuse
the param logical axes), so the dry-run's in_shardings cover it for free.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.compat import opt_barrier


class AdamWState(NamedTuple):
    step: jnp.ndarray          # () int32
    m: dict
    v: dict


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def state_structs(param_structs, param_logical):
    """ShapeDtypeStructs + logical axes matching ``init`` (for the dry-run)."""
    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)
    shapes = AdamWState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        m=jax.tree.map(f32, param_structs),
        v=jax.tree.map(f32, param_structs))
    from repro.sharding.rules import Ax
    logical = AdamWState(step=Ax(), m=param_logical, v=param_logical)
    return shapes, logical


def cosine_lr(step, *, peak=3e-4, warmup=100, total=10000, floor=0.1):
    warm = peak * (step + 1) / warmup
    frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0,
                    1.0)
    cos = peak * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
    return jnp.where(step < warmup, warm, cos)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(grads, state: AdamWState, params, *, lr=None, b1=0.9, b2=0.95,
           eps=1e-8, weight_decay=0.1, clip_norm=1.0):
    step = state.step + 1
    if lr is None:
        lr = cosine_lr(state.step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state.m, grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g),
                     state.v, grads)
    t = step.astype(jnp.float32)
    mhat_c = 1.0 / (1 - b1 ** t)
    vhat_c = 1.0 / (1 - b2 ** t)

    def upd(p, m_, v_):
        u = (m_ * mhat_c) / (jnp.sqrt(v_ * vhat_c) + eps)
        u = u + weight_decay * p.astype(jnp.float32)
        out = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
        # barrier: keep the f32->bf16 convert BEFORE the ZeRO all-gather
        # (XLA otherwise hoists the convert past it and gathers f32 —
        # 2x wire bytes; EXPERIMENTS.md §Perf iteration 4).
        return opt_barrier(out)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, AdamWState(step=step, m=m, v=v), {
        "grad_norm": gnorm, "lr": lr}
