"""Batched serving engine with the CHAI phase machine.

Request lifecycle (paper Fig 10):

    PREFILL  --(full MHA forward, fills dense KV cache)-->
    WARMUP   --(``warmup_tokens`` MHA decode steps; per-head attention
                scores accumulate into a feature buffer)-->
    CLUSTER  --(K-Means membership identification per request; the dense
                K cache is **compacted** to representative rows — the
                paper's 21.4% KV saving — via a donated jit)-->
    STEADY   --(Clustered Head Attention decode until EOS/max_tokens)

The engine runs *slot-batched continuous decode*: a fixed number of batch
slots (static shapes for XLA), a FIFO queue, and per-slot phase tracking.
All slots advance together every step; slots in WARMUP use the MHA step,
slots in STEADY the CHAI step. Because phase-switch requires a cache-layout
change (MHA archs), the engine keeps batch *cohorts*: requests admitted
together move through phases together (bucketed admission). This matches
the paper's serving setting (all-MHA decode for 5 tokens, then CHAI).

Straggler/deadline mitigation: each cohort has a decode deadline; cohorts
that exceed it (slow host, preempted chip) are re-dispatched onto a fresh
cohort from the still-queued state (generated tokens are kept).

On-CPU usage: reduced configs; the same engine code drives TPU meshes by
passing ``mesh`` + shardings.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import cache as chai_cache
from repro.core import clustering
from repro.launch import steps as steps_mod
from repro.models import transformer as tfm


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # (T,) int32
    max_new_tokens: int = 32
    # -- filled by the engine --
    generated: Optional[List[int]] = None
    t_enqueue: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0

    @property
    def ttft(self):
        return self.t_first_token - self.t_enqueue

    @property
    def latency(self):
        return self.t_done - self.t_enqueue


@dataclasses.dataclass
class EngineConfig:
    batch_slots: int = 4               # cohort size (static)
    max_seq: int = 256                 # KV capacity (static)
    greedy: bool = True
    cohort_deadline_s: float = 120.0   # straggler re-dispatch deadline
    use_chai: bool = True


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, ecfg: EngineConfig):
        assert cfg.n_attn_layers > 0 or not ecfg.use_chai, \
            "CHAI needs attention layers"
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        self.queue: deque = deque()
        self.done: List[Request] = []
        self.redispatched = 0
        b, s = ecfg.batch_slots, ecfg.max_seq

        chai_on = ecfg.use_chai and cfg.chai.enabled and cfg.k_max > 0
        self.chai_on = chai_on
        self._prefill = jax.jit(steps_mod.make_serve_prefill(cfg, b, s))
        self._mha_step = jax.jit(steps_mod.make_serve_step(cfg, chai=False),
                                 donate_argnums=(2,))
        if chai_on:
            self._chai_step = jax.jit(
                steps_mod.make_serve_step(cfg, chai=True),
                donate_argnums=(2,))
            self._compact = jax.jit(steps_mod.make_compact_step(cfg),
                                    donate_argnums=(0,))
            self._identify = jax.jit(
                lambda sc: clustering.identify_membership(sc, cfg))

    # -- public API --------------------------------------------------------
    def submit(self, prompt, max_new_tokens=32, uid=None):
        req = Request(uid=uid if uid is not None else len(self.queue)
                      + len(self.done),
                      prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=max_new_tokens)
        req.t_enqueue = time.time()
        req.generated = []
        self.queue.append(req)
        return req

    def run(self):
        """Drain the queue; returns completed requests."""
        while self.queue:
            cohort = [self.queue.popleft()
                      for _ in range(min(self.ecfg.batch_slots,
                                         len(self.queue)))]
            try:
                self._run_cohort(cohort)
            except TimeoutError:
                # cohort exceeded its deadline: re-dispatch unfinished
                self.redispatched += len(cohort)
                for r in cohort:
                    if len(r.generated) < r.max_new_tokens:
                        self.queue.append(r)
                    else:
                        self.done.append(r)
        return self.done

    # -- cohort execution ----------------------------------------------------
    def _pad_prompts(self, cohort):
        b, s = self.ecfg.batch_slots, self.ecfg.max_seq
        t = max(len(r.prompt) for r in cohort)
        toks = np.zeros((b, t), np.int32)
        for i, r in enumerate(cohort):
            toks[i, t - len(r.prompt):] = r.prompt    # left-pad
        return jnp.asarray(toks), t

    def _run_cohort(self, cohort):
        cfg, ecfg = self.cfg, self.ecfg
        deadline = time.time() + ecfg.cohort_deadline_s
        tokens, t = self._pad_prompts(cohort)
        logits, state = self._prefill(self.params, {"tokens": tokens})
        t_first = time.time()
        for r in cohort:
            r.t_first_token = t_first
        next_tok = self._sample(logits)
        self._record(cohort, next_tok)

        warm = cfg.chai.warmup_tokens if self.chai_on else 0
        max_new = max(r.max_new_tokens for r in cohort)

        # ---- WARMUP: MHA decode, accumulating clustering features ----
        if self.chai_on:
            state = chai_cache.add_score_buffer(state, cfg,
                                                ecfg.batch_slots)
        step = 1
        while step < max_new and step <= warm:
            if time.time() > deadline:
                raise TimeoutError
            logits, state = self._mha_step(
                self.params, {"tokens": next_tok}, state)
            next_tok = self._sample(logits)
            self._record(cohort, next_tok)
            step += 1

        # ---- CLUSTER + COMPACT: membership ID, K-cache gather ----
        ctx = None
        if self.chai_on and step <= max_new:
            state, scores = chai_cache.pop_score_buffer(state)
            ctx = self._identify(scores)
            state = self._compact(state, ctx)

        # ---- STEADY: Clustered Head Attention decode ----
        while step < max_new:
            if time.time() > deadline:
                raise TimeoutError
            if ctx is not None:
                logits, state = self._chai_step(
                    self.params, {"tokens": next_tok}, state, ctx)
            else:
                logits, state = self._mha_step(
                    self.params, {"tokens": next_tok}, state)
            next_tok = self._sample(logits)
            self._record(cohort, next_tok)
            step += 1

        t_done = time.time()
        for r in cohort:
            r.generated = r.generated[:r.max_new_tokens]
            r.t_done = t_done
            self.done.append(r)

    def _sample(self, logits):
        if self.ecfg.greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        raise NotImplementedError("sampling beyond greedy")

    @staticmethod
    def _record(cohort, next_tok):
        toks = np.asarray(next_tok)
        for i, r in enumerate(cohort):
            r.generated.append(int(toks[i]))

    # -- metrics ------------------------------------------------------------
    def kv_bytes(self, *, chai: Optional[bool] = None):
        chai = self.chai_on if chai is None else chai
        return chai_cache.kv_cache_bytes(
            self.cfg, self.ecfg.batch_slots, self.ecfg.max_seq, chai=chai)
