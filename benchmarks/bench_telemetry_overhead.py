"""Telemetry lane: the observability tiers' cost contract.

Not a paper figure — the acceptance gate for the engine telemetry
subsystem (``EngineConfig.telemetry = off|basic|trace``). Three claims:

  - ``off_tier_jaxpr_identical``  the tier knob NEVER reaches the
        device program: the decode-step jits of an ``off`` engine, a
        ``trace`` engine, and a freshly built step fn produce
        byte-identical jaxpr text (MHA and CHAI steps both). Telemetry
        is host-side bookkeeping by construction — provably zero
        hot-path (compiled) cost when off.
  - ``basic_overhead_bounded``    wall-clock: draining the SAME
        scripted workload with ``basic`` telemetry stays within a
        generous envelope of the ``off`` run (counter bumps + lifecycle
        events only; advisory on shared CPU runners, so the bound is
        loose by design).
  - ``trace_roundtrip``           a ``trace``-tier drain exports a
        Chrome-trace object that round-trips through JSON and the
        ``from_chrome_trace`` loader, and every decode-bearing step
        ordinal carries the full stage-span set: >=1 ``admit`` and
        exactly one ``cluster`` / ``decode.dispatch`` / ``sample`` /
        ``retire`` (fault-free run, so no retry spans).
  - ``prometheus_parses``         the same engine's text exposition
        parses under the format-0.0.4 grammar and its
        ``tokens_generated_total`` agrees with the per-request token
        count ground truth.
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np

from benchmarks.common import save_result
from repro.configs.base import get_config, reduced
from repro.launch import steps as steps_mod
from repro.launch.steps import jaxpr_text
from repro.models import transformer as tfm
from repro.serving import exporters
from repro.serving.engine import EngineConfig, EngineCore
from repro.serving.sampling import SamplingParams

STAGES_ONCE = ("cluster", "decode.dispatch", "sample", "retire")


def _model():
    cfg = reduced(get_config("chai-llama-7b"), n_layers=2, d_model=32,
                  d_ff=64, vocab=128).replace(dtype="float32")
    cfg = cfg.with_chai(enabled=True, warmup_tokens=3)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _ecfg(tier):
    return EngineConfig(batch_slots=3, max_seq=64, page_size=8,
                        prefix_cache=True, telemetry=tier)


def _workload(seed=0, n=8, vocab=128):
    rng = np.random.default_rng(seed)
    return [(rng.integers(1, vocab, size=int(rng.integers(6, 14))).tolist(),
             int(rng.integers(8, 16))) for _ in range(n)]


def _drain(core, wl, *, time_steps=False):
    """Submit the scripted workload up front, step to drain; returns
    (per-step seconds list, total generated tokens)."""
    reqs = [core.add_request(p, SamplingParams(max_new_tokens=m))
            for p, m in wl]
    ts = []
    while core.has_work():
        t0 = time.perf_counter()
        core.step()
        if time_steps:
            ts.append(time.perf_counter() - t0)
    return ts, sum(len(r.generated) for r in reqs)


def run():
    cfg, params = _model()
    wl = _workload()

    # -- claim 1: the telemetry tier never reaches the device program --
    eng_off = EngineCore(cfg, params, _ecfg("off"))
    eng_trc = EngineCore(cfg, params, _ecfg("trace"))
    _drain(eng_off, wl)             # populate _dev_state for tracing
    ex = (eng_off.params, {"tokens": eng_off._next_tok_dev},
          eng_off._dev_state)
    fresh_mha = jax.jit(steps_mod.make_serve_step(
        cfg, chai=False, decode_ts=eng_off.ecfg.page_size),
        donate_argnums=(2,))
    mha_txts = [jaxpr_text(fn, *ex) for fn in
                (eng_off._mha_step, eng_trc._mha_step, fresh_mha)]
    chai_ex = ex + (eng_off._dev_ctx,)
    fresh_chai = jax.jit(steps_mod.make_serve_step(
        cfg, chai=True, decode_ts=eng_off.ecfg.page_size),
        donate_argnums=(2,))
    chai_txts = [jaxpr_text(fn, *chai_ex) for fn in
                 (eng_off._chai_step, eng_trc._chai_step, fresh_chai)]
    jaxpr_identical = (len(set(mha_txts)) == 1
                       and len(set(chai_txts)) == 1)

    # -- claim 2: basic-tier wall-clock overhead stays bounded ---------
    # Both engines drain the workload once for jit warmup, then the
    # timed pass runs the identical workload again (prefix cache makes
    # the second pass cheaper in BOTH engines identically).
    timings = {}
    for tier in ("off", "basic"):
        core = EngineCore(cfg, params, _ecfg(tier))
        _drain(core, wl)                          # warm every jit
        ts, _ = _drain(core, wl, time_steps=True)
        timings[tier] = float(np.median(ts))
    # Loose envelope: per-step host work is a handful of dict bumps and
    # one timeline append; anything past 1.5x + 5ms is a regression.
    overhead_ok = timings["basic"] <= timings["off"] * 1.5 + 0.005

    # -- claims 3+4: trace export round-trip + Prometheus grammar ------
    eng = EngineCore(cfg, params, _ecfg("trace"))
    _, n_tokens = _drain(eng, wl)
    chrome = eng.step_trace()
    loaded = exporters.from_chrome_trace(json.dumps(chrome))
    by_step: dict = {}
    for evt in loaded:
        step = evt.get("args", {}).get("step", -1)
        by_step.setdefault(step, []).append(evt["name"])
    decode_steps = {s: names for s, names in by_step.items()
                    if "decode.dispatch" in names}
    stage_ok = bool(decode_steps) and all(
        names.count("admit") >= 1
        and all(names.count(st) == 1 for st in STAGES_ONCE)
        for names in decode_steps.values())
    roundtrip_ok = (stage_ok
                    and len(loaded) == len(chrome["traceEvents"])
                    and all(e["ph"] == "X" and e["dur"] >= 0
                            for e in loaded))

    parsed = exporters.parse_prometheus(eng.metrics_text())
    tok_total = sum(v for name, _, v in parsed["samples"]
                    if name == "tokens_generated_total")
    # The KV-tier residency gauges must survive the exposition round
    # trip: every paged engine reports per-(tier, kind) page counts.
    sample_names = {name for name, _, _ in parsed["samples"]}
    prom_ok = (len(parsed["samples"]) > 0
               and int(tok_total) == n_tokens
               and "kv_tier_pages" in sample_names)

    payload = {
        "proxy_note": "tiny CPU model; the jaxpr-identity and export "
                      "round-trip claims are hardware-independent, the "
                      "overhead bound is advisory wall clock",
        "step_s_median": timings,
        "decode_steps_traced": len(decode_steps),
        "trace_events": len(loaded),
        "prometheus_samples": len(parsed["samples"]),
        "tokens_generated": n_tokens,
        "claim_check": {
            "off_tier_jaxpr_identical": jaxpr_identical,
            "basic_overhead_bounded": overhead_ok,
            "trace_roundtrip": roundtrip_ok,
            "prometheus_parses": prom_ok,
        },
    }
    save_result("bench_telemetry_overhead", payload)
    return payload


if __name__ == "__main__":
    out = run()
    print(out["claim_check"])
