"""Per-request sampling: SamplingParams validation, the batched device
sampler, and scheduler-independence of seeded runs.

The load-bearing guarantees:

* ``temperature=0`` is BITWISE the raw-logits argmax — the engine's
  historical greedy path — regardless of top_k/top_p/seed, so every
  greedy parity/snapshot-replay guarantee survives the sampler.
* Token n of a request draws from ``fold_in(PRNGKey(seed), n)``: seeded
  temperature/top-k/top-p runs are reproducible run-to-run AND across
  the continuous and cohort schedulers (different slot placements,
  different batch shapes — same tokens).
* Stop token ids and max_new_tokens finish requests identically under
  both schedulers.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, reduced
from repro.launch import steps as steps_mod
from repro.models import transformer as tfm
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.sampling import SamplingParams, finish_reason, scan_finish

MHA_ARCH = "chai-llama-7b"


def _cfg():
    cfg = reduced(get_config(MHA_ARCH), n_layers=2, d_model=32, d_ff=64,
                  vocab=64).replace(dtype="float32")
    return cfg.with_chai(enabled=True, warmup_tokens=3)


def _run(cfg, scheduler, subs, *, slots=2, **ecfg_kw):
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params,
                        EngineConfig(batch_slots=slots, max_seq=64,
                                     scheduler=scheduler, **ecfg_kw))
    for i, (prompt, sp) in enumerate(subs):
        eng.submit(prompt, max_new_tokens=sp.max_new_tokens, uid=i,
                   sampling=sp)
    done = eng.run()
    assert len(done) == len(subs)
    return {r.uid: r for r in done}


# ------------------------------------------------------------ unit ---------
def test_sampling_params_validation():
    SamplingParams(temperature=0.7, top_k=5, top_p=0.9)     # ok
    with pytest.raises(ValueError):
        SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError):
        SamplingParams(top_k=-1)
    with pytest.raises(ValueError):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError):
        SamplingParams(top_p=1.5)
    with pytest.raises(ValueError):
        SamplingParams(max_new_tokens=0)
    assert SamplingParams().greedy
    assert not SamplingParams(temperature=0.5).greedy


def test_finish_reason_stop_and_length():
    sp = SamplingParams(stop_token_ids=(9,))
    assert finish_reason([1, 2], sp, 8) == ""
    assert finish_reason([1, 9], sp, 8) == "stop"
    assert finish_reason([1, 2], sp, 2) == "length"
    # stop wins when both trigger on the same token
    assert finish_reason([1, 9], sp, 2) == "stop"
    toks, reason = scan_finish([1, 9, 3, 4], sp, 8)
    assert toks == [1, 9] and reason == "stop"
    # stop strings via a detokenizer
    detok = lambda ids: " ".join(map(str, ids))
    sps = SamplingParams(stop=("2 3",))
    toks, reason = scan_finish([1, 2, 3, 4], sps, 8, detok)
    assert toks == [1, 2, 3] and reason == "stop"


def test_sampler_temperature_zero_is_bitwise_argmax():
    """The device sampler's greedy lane == raw-logits argmax, bit for
    bit, independent of the other knobs (the old engine ``_sample``)."""
    sampler = jax.jit(steps_mod.make_sampler())
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(8, 64)), jnp.float32)
    old_greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    for k, p, seed in ((0, 1.0, 0), (3, 0.5, 7), (64, 0.01, 123)):
        out = sampler(logits,
                      jnp.zeros((8,), jnp.float32),
                      jnp.full((8,), k, jnp.int32),
                      jnp.full((8,), p, jnp.float32),
                      jnp.full((8,), seed, jnp.uint32),
                      jnp.arange(8, dtype=jnp.int32))
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(old_greedy))


def test_sampler_top_k_top_p_restrict_support():
    """top_k=1 == argmax even at high temperature; top-k/top-p masks
    keep draws inside the allowed support; draws are seed-deterministic
    and vary with the count."""
    sampler = jax.jit(steps_mod.make_sampler())
    rng = np.random.default_rng(1)
    logits = jnp.asarray(rng.normal(size=(4, 64)), jnp.float32)
    ones = jnp.ones((4,), jnp.float32)

    def draw(temp, k, p, seed, count):
        return np.asarray(sampler(
            logits, ones * temp, jnp.full((4,), k, jnp.int32),
            ones * p, jnp.full((4,), seed, jnp.uint32),
            jnp.full((4,), count, jnp.int32)))

    np.testing.assert_array_equal(
        draw(5.0, 1, 1.0, 0, 0), np.asarray(jnp.argmax(logits, -1)))
    top8 = np.argsort(-np.asarray(logits), axis=-1)[:, :8]
    for seed in range(5):
        toks = draw(1.0, 8, 1.0, seed, 0)
        assert all(toks[i] in top8[i] for i in range(4))
    # deterministic per (seed, count); different counts decorrelate
    np.testing.assert_array_equal(draw(1.0, 0, 0.9, 3, 5),
                                  draw(1.0, 0, 0.9, 3, 5))
    samples = {tuple(draw(1.5, 0, 1.0, 3, c)) for c in range(8)}
    assert len(samples) > 1


# ------------------------------------------------- engine-level parity -----
@pytest.mark.slow
def test_seeded_sampling_reproducible_across_schedulers():
    """Same prompts + per-request (temperature, top_k, top_p, seed):
    token-for-token identical under the continuous scheduler (paged AND
    dense layouts) and the cohort scheduler, and across repeat runs."""
    cfg = _cfg()
    rng = np.random.default_rng(2)
    sps = [SamplingParams(temperature=0.8, top_k=16, top_p=0.95,
                          seed=100 + i, max_new_tokens=m)
           for i, m in enumerate((12, 5, 9, 7))]
    subs = [(rng.integers(0, cfg.vocab_size, size=8), sp) for sp in sps]
    cont = _run(cfg, "continuous", subs)
    cont2 = _run(cfg, "continuous", subs)
    dense = _run(cfg, "continuous", subs, kv_layout="dense")
    coh = _run(cfg, "cohort", subs)
    for uid in cont:
        assert cont[uid].generated == cont2[uid].generated, uid   # rerun
        assert cont[uid].generated == dense[uid].generated, uid   # layout
        assert cont[uid].generated == coh[uid].generated, uid     # sched
        assert len(cont[uid].generated) == sps[uid].max_new_tokens
        assert cont[uid].finish_reason == "length"
    # different seeds actually diverge (the sampler is not greedy)
    alt = [(p, SamplingParams(temperature=0.8, top_k=16, top_p=0.95,
                              seed=sp.seed + 1000,
                              max_new_tokens=sp.max_new_tokens))
           for p, sp in subs]
    cont_alt = _run(cfg, "continuous", alt)
    assert any(cont_alt[u].generated != cont[u].generated for u in cont)


@pytest.mark.slow
def test_temperature_zero_engine_matches_legacy_greedy():
    """An explicit temperature=0 SamplingParams (whatever the other
    knobs say) generates exactly the tokens the default greedy submit()
    path does — the bit-identical guarantee snapshot replay rests on."""
    cfg = _cfg()
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, size=8) for _ in range(3)]
    legacy = _run(cfg, "continuous",
                  [(p, SamplingParams(max_new_tokens=10)) for p in prompts])
    explicit = _run(cfg, "continuous",
                    [(p, SamplingParams(temperature=0.0, top_k=5,
                                        top_p=0.5, seed=42,
                                        max_new_tokens=10))
                     for p in prompts])
    for uid in legacy:
        assert legacy[uid].generated == explicit[uid].generated, uid


@pytest.mark.slow
def test_stop_tokens_finish_identically_across_schedulers():
    """A stop token retires the request early (reason "stop", stop token
    kept) with identical truncation under both schedulers."""
    cfg = _cfg()
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab_size, size=8) for _ in range(3)]
    # pick stop ids from an unconstrained greedy run so they actually hit
    probe = _run(cfg, "continuous",
                 [(p, SamplingParams(max_new_tokens=12)) for p in prompts])
    stops = tuple(int(probe[u].generated[5]) for u in probe)
    sps = [SamplingParams(stop_token_ids=stops, max_new_tokens=12)
           for _ in prompts]
    cont = _run(cfg, "continuous", list(zip(prompts, sps)))
    coh = _run(cfg, "cohort", list(zip(prompts, sps)))
    hit_early = 0
    for uid in cont:
        assert cont[uid].generated == coh[uid].generated, uid
        assert cont[uid].finish_reason == coh[uid].finish_reason, uid
        if cont[uid].finish_reason == "stop":
            hit_early += 1
            assert cont[uid].generated[-1] in stops
            assert len(cont[uid].generated) < 12
    assert hit_early > 0        # the stop ids were chosen to trigger
