"""Paper Table 4: pruning Q,K only (CHAI) vs Q,K,V (CHAI-QKV).

Sharing V loses fidelity — measured as attention-output cosine + greedy
agreement through the serving engine."""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from benchmarks.common import collect_qkv, save_result, tiny_trained
from repro.core.policy import apply_policy
from repro.serving.engine import EngineConfig, ServingEngine


def _agreement(cfg, params, pipe, share_values):
    c = cfg.with_chai(enabled=True, cluster_counts=(5,) * cfg.n_attn_layers,
                      share_values=share_values)
    eng = ServingEngine(c, params, EngineConfig(batch_slots=2, max_seq=128))
    for i in range(4):
        eng.submit(pipe.batch(600 + i)["tokens"][0, :24],
                   max_new_tokens=16, uid=i)
    return {r.uid: r.generated for r in eng.run()}


def run():
    cfg, params, pipe, _ = tiny_trained()
    toks = jnp.asarray(pipe.batch(500)["tokens"][:4, :48])
    qkvs = collect_qkv(cfg, params, toks)

    def fid(policy):
        cos = []
        for q, k, v in qkvs:
            base = apply_policy("mha", q, k, v).out
            out = apply_policy(policy, q, k, v, n_clusters=5).out
            a = np.asarray(out, np.float64).ravel()
            b = np.asarray(base, np.float64).ravel()
            cos.append(a @ b / (np.linalg.norm(a) * np.linalg.norm(b)))
        return float(np.mean(cos))

    # end-to-end: greedy agreement vs MHA engine
    eng_mha = ServingEngine(cfg, params,
                            EngineConfig(batch_slots=2, max_seq=128,
                                         use_chai=False))
    for i in range(4):
        eng_mha.submit(pipe.batch(600 + i)["tokens"][0, :24],
                       max_new_tokens=16, uid=i)
    mha = {r.uid: r.generated for r in eng_mha.run()}
    chai = _agreement(cfg, params, pipe, share_values=False)
    qkv = _agreement(cfg, params, pipe, share_values=True)

    def agree(gen):
        return float(np.mean([
            np.mean(np.asarray(mha[u]) == np.asarray(gen[u])) for u in mha]))

    result = {
        "proxy_note": "Table 4 ablation on trained tiny LM",
        "fidelity_chai": fid("chai"),
        "fidelity_chai_qkv": fid("chai-qkv"),
        "greedy_agreement_chai": agree(chai),
        "greedy_agreement_chai_qkv": agree(qkv),
        "paper_claim": "pruning V too (CHAI-QKV) costs extra accuracy "
                       "(Table 4: Arc-C 47.0 -> 41.29)",
        "claim_check": {
            "qkv_worse_fidelity": fid("chai-qkv") <= fid("chai") + 1e-6,
            "qkv_worse_or_equal_agreement": agree(qkv) <= agree(chai) + 0.05,
        },
    }
    save_result("bench_qkv_ablation", result)
    return result


if __name__ == "__main__":
    print(run())
