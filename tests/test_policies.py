"""Policy layer (paper Tables 1-4, Figs 1/14 comparisons)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.policy import POLICIES, apply_policy


def _qkv(rng, b=2, t=16, h=8, hd=16):
    mk = lambda: jnp.asarray(rng.normal(size=(b, t, h, hd)), jnp.float32)
    return mk(), mk(), mk()


def test_all_policies_run_and_shape(rng):
    q, k, v = _qkv(rng)
    for pol in POLICIES:
        kw = dict(n_clusters=4)
        if pol == "chai-static":
            kw.update(h2c_static=jnp.arange(8) % 4,
                      reps_static=jnp.arange(4))
        out = apply_policy(pol, q, k, v, **kw)
        assert out.out.shape == q.shape, pol
        assert bool(jnp.isfinite(out.out).all()), pol
        assert float(out.score_flops) > 0, pol


def test_chai_with_h_clusters_equals_mha(rng):
    """k == H: clustering is a permutation; output == MHA exactly."""
    q, k, v = _qkv(rng, h=4)
    mha = apply_policy("mha", q, k, v)
    chai = apply_policy("chai", q, k, v, n_clusters=4)
    np.testing.assert_allclose(np.asarray(chai.out), np.asarray(mha.out),
                               rtol=1e-4, atol=1e-4)


def test_chai_exact_on_duplicated_heads(rng):
    """Heads sharing identical Q,K cluster together losslessly."""
    b, t, h, hd = 2, 16, 8, 16
    q, k, v = _qkv(rng, b=b, t=t, h=h, hd=hd)
    # heads 0-3 identical, 4-7 identical -> 2 true clusters
    q = q.at[:, :, 1:4].set(q[:, :, :1])
    k = k.at[:, :, 1:4].set(k[:, :, :1])
    q = q.at[:, :, 5:].set(q[:, :, 4:5])
    k = k.at[:, :, 5:].set(k[:, :, 4:5])
    mha = apply_policy("mha", q, k, v)
    chai = apply_policy("chai", q, k, v, n_clusters=2)
    np.testing.assert_allclose(np.asarray(chai.out), np.asarray(mha.out),
                               rtol=1e-4, atol=1e-4)
    assert float(chai.score_flops) < float(mha.score_flops)


def test_flops_ordering(rng):
    """CHAI with fewer clusters does fewer score flops; DejaVu at sparsity
    s saves s of head flops."""
    q, k, v = _qkv(rng)
    f_mha = float(apply_policy("mha", q, k, v).score_flops)
    f4 = float(apply_policy("chai", q, k, v, n_clusters=4).score_flops)
    f2 = float(apply_policy("chai", q, k, v, n_clusters=2).score_flops)
    assert f2 < f4 < f_mha
    f_dv = float(apply_policy("dejavu", q, k, v, sparsity=0.5).score_flops)
    assert f_dv == pytest.approx(0.5 * f_mha)


def test_chai_qkv_differs_from_chai(rng):
    """Sharing V (Table 4 ablation) changes the output (accuracy cost)."""
    q, k, v = _qkv(rng)
    a = apply_policy("chai", q, k, v, n_clusters=3)
    b = apply_policy("chai-qkv", q, k, v, n_clusters=3)
    assert not np.allclose(np.asarray(a.out), np.asarray(b.out))


def test_spatten_masks_tokens(rng):
    q, k, v = _qkv(rng)
    out = apply_policy("spatten", q, k, v, token_keep=0.5, sparsity=0.25)
    kept = np.asarray(out.info["kept_tokens"])
    assert kept.sum(axis=-1).max() <= 8   # 50% of 16
