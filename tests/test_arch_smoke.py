"""Per-architecture smoke tests (assignment requirement).

For each of the 10 assigned archs: instantiate a REDUCED same-family
config, run one forward pass AND one train step on CPU, assert output
shapes + finite values. The FULL configs are exercised allocation-free by
the dry-run (launch/dryrun.py).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, get_config, list_configs, reduced
from repro.launch import inputs as inp
from repro.launch import steps as steps_mod
from repro.models import transformer as tfm
from repro.optim import adamw

ASSIGNED = [a for a in list_configs() if a != "chai-llama-7b"]


def _reduced(arch):
    return reduced(get_config(arch)).replace(dtype="float32")


@pytest.mark.parametrize("arch", ASSIGNED)
def test_forward_shapes_and_finite(arch, rng):
    cfg = _reduced(arch)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    b, t = 2, 16
    if cfg.frontend != "none":
        x = jnp.asarray(rng.normal(size=(b, t, cfg.d_model)), jnp.float32)
    else:
        x = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, t)), jnp.int32)
    logits, _, aux = tfm.forward_fullseq(params, cfg, x)
    assert logits.shape == (b, t, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), arch
    assert bool(jnp.isfinite(aux["load_balance"])), arch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_train_step_no_nans(arch, rng):
    cfg = _reduced(arch)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw.init(params)
    b, t = 2, 16
    batch = {"labels": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (b, t)), jnp.int32)}
    if cfg.frontend != "none":
        batch["embeddings"] = jnp.asarray(
            rng.normal(size=(b, t, cfg.d_model)), jnp.float32)
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, t)), jnp.int32)
    step = jax.jit(steps_mod.make_train_step(cfg, remat=False))
    params2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"])), arch
    assert np.isfinite(float(metrics["grad_norm"])), arch
    assert int(opt2.step) == 1
    # at least one parameter actually moved
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b_))
        for a, b_ in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved, arch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_decode_step_runs(arch, rng):
    cfg = _reduced(arch)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 32
    state = tfm.init_decode_state(cfg, b, s)
    if cfg.frontend != "none":
        emb = jnp.asarray(rng.normal(size=(b, cfg.d_model)), jnp.float32)
        logits, st = tfm.decode_step(params, cfg, None, state,
                                     embeddings=emb)
    else:
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b,)), jnp.int32)
        logits, st = tfm.decode_step(params, cfg, toks, state)
    assert logits.shape == (b, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), arch
    assert int(st["pos"][0]) == 1


def test_all_full_configs_construct():
    """Every registered full config builds and self-validates; CHAI widths
    are consistent; param counts are in the right ballpark (±40% of the
    nominal model size — embeddings and per-arch details shift it)."""
    nominal = {"nemotron-4-15b": 15e9, "gemma2-9b": 9e9, "gemma3-4b": 4e9,
               "h2o-danube-1.8b": 1.8e9, "qwen3-moe-30b-a3b": 30e9,
               "deepseek-moe-16b": 16e9, "musicgen-large": 3.3e9,
               "recurrentgemma-9b": 9e9, "rwkv6-1.6b": 1.6e9,
               "internvl2-76b": 76e9, "chai-llama-7b": 7e9}
    for name in list_configs():
        cfg = get_config(name)
        n = cfg.param_count()
        lo, hi = 0.5 * nominal[name], 1.5 * nominal[name]
        assert lo < n < hi, (name, n)
        if cfg.n_attn_layers and cfg.chai.enabled:
            counts = cfg.chai_cluster_counts()
            assert len(counts) == cfg.n_attn_layers
            assert all(1 <= k <= cfg.n_heads for k in counts)
            # paper: later layers at most as many clusters as early ones
            assert counts[-1] <= counts[0]
        if cfg.family == "moe":
            assert cfg.active_param_count() < cfg.param_count()


def test_input_specs_cover_all_cells():
    """input_specs exist for every (arch x eligible shape) with the right
    leading dims."""
    from repro.launch.dryrun import eligible_shapes
    for arch in ASSIGNED:
        cfg = get_config(arch)
        for shape_name in eligible_shapes(arch):
            shape = SHAPES[shape_name]
            if shape.kind == "train":
                specs, _ = inp.train_input_specs(cfg, shape)
                leaf = next(iter(specs.values()))
                assert leaf.shape[0] == shape.global_batch
                assert leaf.shape[1] == shape.seq_len
            elif shape.kind == "prefill":
                specs, _ = inp.prefill_input_specs(cfg, shape)
                leaf = next(iter(specs.values()))
                assert leaf.shape[:2] == (shape.global_batch, shape.seq_len)
            else:
                specs, _ = inp.decode_token_specs(cfg, shape)
                leaf = next(iter(specs.values()))
                assert leaf.shape[0] == shape.global_batch
