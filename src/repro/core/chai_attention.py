"""Clustered Head Attention — the paper's core op (decode path).

Score computation + softmax run only for representative heads; attention
weights broadcast to member heads via a gather; V stays per-head
(paper Table 4: pruning V loses accuracy; ``share_values`` implements the
CHAI-QKV ablation).

MHA archs additionally store a *clustered K cache* (k_max rows instead of
H) — the paper's 21.4% KV-memory saving. GQA archs keep the per-group K
cache (DESIGN.md §4) and get the compute-only saving.

ctx arrays may be shared across the batch (ndim without B) or per-request
(batched) — see repro.core.clustering.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models.layers import apply_rope, rms_norm, softcap


def _rope1(x, pos, theta):
    """x: (B, n, hd) single-token heads; pos: (B,)."""
    return apply_rope(x[:, None], pos[:, None], theta)[:, 0]


def _qk_norm(x, scale, cfg):
    return rms_norm(x, scale, cfg.norm_eps) if cfg.qk_norm else x


def chai_decode_attention(xn, p, cfg, state, idxs, chai_ctx, *, local,
                          write_mask=None):
    """xn: (B, d) normed hidden. Returns (out (B, H, hd), new_state).

    ``write_mask`` (B,) bool: cache rows are committed only for masked
    slots (the mixed-phase continuous step runs this path alongside the
    plain MHA path on one batch)."""
    if cfg.is_mha and not local:
        return _chai_mha_decode(xn, p, cfg, state, idxs, chai_ctx,
                                write_mask)
    if not cfg.is_mha:
        return _chai_gqa_decode(xn, p, cfg, state, idxs, chai_ctx,
                                local=local, write_mask=write_mask)
    # MHA arch with a local layer (none of the assigned archs hit this):
    from repro.models.transformer import _plain_decode_attention
    return _plain_decode_attention(xn, p, cfg, state, idxs, local=local,
                                   write_mask=write_mask)


def _layer_ctx(chai_ctx, attn_idx):
    return jax.tree.map(
        lambda a: jax.lax.dynamic_index_in_dim(a, attn_idx, 0,
                                               keepdims=False), chai_ctx)


# ---------------------------------------------------------------- MHA ------
def _chai_mha_decode(xn, p, cfg, state, idxs, chai_ctx, write_mask=None):
    from repro.models.transformer import _masked_rows, tree_index, \
        tree_update
    b, d = xn.shape
    ar = jnp.arange(b)
    hd, h = cfg.head_dim, cfg.n_heads
    pos = state["pos"]
    ctx = _layer_ctx(chai_ctx, idxs["attn"])
    reps, h2c = ctx["reps"], ctx["h2c"]
    batched = reps.ndim == 2                      # (B, k) vs (k,)
    share_v = cfg.chai.share_values

    if batched:
        # Per-request membership: project all heads, gather activations.
        q = jnp.einsum("bd,dhe->bhe", xn, p["wq"])
        k = jnp.einsum("bd,dhe->bhe", xn, p["wk"])
        if cfg.qk_norm:
            q = _qk_norm(q, p["q_norm"], cfg)
            k = _qk_norm(k, p["k_norm"], cfg)
        q_rep = jnp.take_along_axis(q, reps[..., None], axis=1)
        k_rep = jnp.take_along_axis(k, reps[..., None], axis=1)
    else:
        # Shared membership: gather weight rows (skips pruned projections —
        # the paper's full compute saving).
        wq_r = jnp.take(p["wq"], reps, axis=1)    # (d, k, hd)
        wk_r = jnp.take(p["wk"], reps, axis=1)
        q_rep = jnp.einsum("bd,dke->bke", xn, wq_r)
        k_rep = jnp.einsum("bd,dke->bke", xn, wk_r)
        if cfg.qk_norm:
            q_rep = _qk_norm(q_rep, p["q_norm"], cfg)
            k_rep = _qk_norm(k_rep, p["k_norm"], cfg)
    q_rep = _rope1(q_rep, pos, cfg.rope_theta)
    k_rep = _rope1(k_rep, pos, cfg.rope_theta)

    int8 = cfg.kv_cache_dtype == "int8"
    if int8:
        from repro.core.cache import dequant_rows, quant_rows
    paged = "cp" in state
    if paged:
        from repro.core.cache import gather_pages
        from repro.models.transformer import (_paged_write_rows,
                                              paged_token_coords)
        mask = functools.partial(_masked_rows, write_mask)

    # Clustered K cache update (k rows, not H).
    if paged:
        cp = tree_index(state["cp"], idxs["global"])      # (nP, k, page, hd)
        page = cp.shape[2]
        pk, row = paged_token_coords(state["bt_kc"], pos, page)
        if int8:
            kq, ks = quant_rows(k_rep)
            cp = _paged_write_rows(cp, pk, row, kq, mask)
            csc = tree_index(state["cp_scale"], idxs["global"])
            csc = _paged_write_rows(csc, pk, row, ks, mask)
            kc_f = dequant_rows(gather_pages(cp, state["bt_kc"]),
                                gather_pages(csc, state["bt_kc"]))
        else:
            cp = _paged_write_rows(cp, pk, row, k_rep, mask)
            kc_f = gather_pages(cp, state["bt_kc"])
        s = kc_f.shape[2]
    else:
        kc = tree_index(state["kg_chai"], idxs["global"])   # (B, k, S, hd)
        if int8:
            kq, ks = quant_rows(k_rep)
            kc = kc.at[ar, :, pos, :].set(
                _masked_rows(write_mask, kq, kc[ar, :, pos, :]))
            ksc = tree_index(state["kg_chai_scale"], idxs["global"])
            ksc = ksc.at[ar, :, pos].set(
                _masked_rows(write_mask, ks, ksc[ar, :, pos]))
            kc_f = dequant_rows(kc, ksc)
        else:
            kc = kc.at[ar, :, pos, :].set(
                _masked_rows(write_mask, k_rep.astype(kc.dtype),
                             kc[ar, :, pos, :]))
            kc_f = kc
        s = kc.shape[2]

    # V: full per-head (or clustered for the CHAI-QKV ablation).
    if share_v:
        if batched:
            v = jnp.einsum("bd,dhe->bhe", xn, p["wv"])
            v_new = jnp.take_along_axis(v, reps[..., None], axis=1)
        else:
            wv_r = jnp.take(p["wv"], reps, axis=1)
            v_new = jnp.einsum("bd,dke->bke", xn, wv_r)
        if paged:
            # Clustered V pages live in the same cp pool (scale-less,
            # mirroring the unified vg_chai gather).
            pv, vrow = paged_token_coords(state["bt_vc"], pos, page)
            cp = _paged_write_rows(cp, pv, vrow, v_new, mask)
            vc_f = gather_pages(cp, state["bt_vc"])
        else:
            vc = tree_index(state["vg_chai"], idxs["global"])
            vc = vc.at[ar, :, pos, :].set(
                _masked_rows(write_mask, v_new.astype(vc.dtype),
                             vc[ar, :, pos, :]))
            vc_f = vc
    else:
        v_new = jnp.einsum("bd,dhe->bhe", xn, p["wv"])
        if paged:
            vp = tree_index(state["kvp"], idxs["global"])
            pv, vrow = paged_token_coords(state["bt_vg"], pos, page)
            if int8:
                vq, vs = quant_rows(v_new)
                vp = _paged_write_rows(vp, pv, vrow, vq, mask)
                vsp = tree_index(state["kvp_scale"], idxs["global"])
                vsp = _paged_write_rows(vsp, pv, vrow, vs, mask)
                vc_f = dequant_rows(gather_pages(vp, state["bt_vg"]),
                                    gather_pages(vsp, state["bt_vg"]))
            else:
                vp = _paged_write_rows(vp, pv, vrow, v_new, mask)
                vc_f = gather_pages(vp, state["bt_vg"])
        else:
            vc = tree_index(state["vg"], idxs["global"])
            if int8:
                vq, vs = quant_rows(v_new)
                vc = vc.at[ar, :, pos, :].set(
                    _masked_rows(write_mask, vq, vc[ar, :, pos, :]))
                vsc = tree_index(state["vg_scale"], idxs["global"])
                vsc = vsc.at[ar, :, pos].set(
                    _masked_rows(write_mask, vs, vsc[ar, :, pos]))
                vc_f = dequant_rows(vc, vsc)
            else:
                vc = vc.at[ar, :, pos, :].set(
                    _masked_rows(write_mask, v_new.astype(vc.dtype),
                                 vc[ar, :, pos, :]))
                vc_f = vc

    scale = 1.0 / math.sqrt(hd)
    sc = jnp.einsum("bke,bkse->bks", q_rep.astype(jnp.float32),
                    kc_f.astype(jnp.float32)) * scale
    sc = softcap(sc, cfg.attn_logit_softcap)
    kv_pos = jnp.arange(s, dtype=jnp.int32)
    valid = kv_pos[None, :] <= pos[:, None]
    sc = jnp.where(valid[:, None, :], sc, attn_mod.NEG_INF)
    a = jax.nn.softmax(sc, axis=-1)                     # (B, k, S)

    if share_v:
        out_rep = jnp.einsum("bks,bksd->bkd", a, vc_f.astype(jnp.float32))
        gather_idx = h2c if batched else jnp.broadcast_to(h2c, (b, h))
        out = jnp.take_along_axis(out_rep, gather_idx[..., None], axis=1)
    else:
        gather_idx = h2c if batched else jnp.broadcast_to(h2c, (b, h))
        a_full = jnp.take_along_axis(a, gather_idx[..., None], axis=1)
        out = jnp.einsum("bhs,bhsd->bhd", a_full, vc_f.astype(jnp.float32))

    state = dict(state)
    if paged:
        state["cp"] = tree_update(state["cp"], idxs["global"], cp)
        if int8:
            state["cp_scale"] = tree_update(state["cp_scale"],
                                            idxs["global"], csc)
        if not share_v:
            state["kvp"] = tree_update(state["kvp"], idxs["global"], vp)
            if int8:
                state["kvp_scale"] = tree_update(state["kvp_scale"],
                                                 idxs["global"], vsp)
    else:
        state["kg_chai"] = tree_update(state["kg_chai"], idxs["global"], kc)
        if int8:
            state["kg_chai_scale"] = tree_update(state["kg_chai_scale"],
                                                 idxs["global"], ksc)
            if not share_v:
                state["vg_scale"] = tree_update(state["vg_scale"],
                                                idxs["global"], vsc)
        if share_v:
            state["vg_chai"] = tree_update(state["vg_chai"], idxs["global"],
                                           vc)
        else:
            state["vg"] = tree_update(state["vg"], idxs["global"], vc)
    return out.astype(xn.dtype), state


# ---------------------------------------------------------------- GQA ------
def _chai_gqa_decode(xn, p, cfg, state, idxs, chai_ctx, *, local,
                     write_mask=None):
    from repro.models.transformer import _masked_rows, tree_index, \
        tree_update
    b, d = xn.shape
    ar = jnp.arange(b)
    hd, h, n_kv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    qpk = cfg.q_per_kv
    pos = state["pos"]
    ctx = _layer_ctx(chai_ctx, idxs["attn"])
    reps, cluster_of = ctx["reps"], ctx["cluster_of"]   # (.., KV, r/qpk)
    batched = reps.ndim == 3
    r = reps.shape[-1]

    if batched:
        q = jnp.einsum("bd,dhe->bhe", xn, p["wq"]).reshape(b, n_kv, qpk, hd)
        if cfg.qk_norm:
            q = _qk_norm(q, p["q_norm"], cfg)
        q_rep = jnp.take_along_axis(q, reps[..., None], axis=2)
    else:
        wq_g = p["wq"].reshape(d, n_kv, qpk, hd)
        idx = jnp.broadcast_to(reps[None, ..., None], (d, n_kv, r, hd))
        wq_r = jnp.take_along_axis(wq_g, idx, axis=2)   # (d, KV, r, hd)
        q_rep = jnp.einsum("bd,dkre->bkre", xn, wq_r)
        if cfg.qk_norm:
            q_rep = _qk_norm(q_rep, p["q_norm"], cfg)
    q_rep = apply_rope(q_rep.reshape(b, 1, n_kv * r, hd),
                       pos[:, None], cfg.rope_theta).reshape(b, n_kv, r, hd)

    # K/V: per-group projections unchanged (no K saving for GQA).
    k_new = jnp.einsum("bd,dke->bke", xn, p["wk"])
    if cfg.qk_norm:
        k_new = _qk_norm(k_new, p["k_norm"], cfg)
    k_new = _rope1(k_new, pos, cfg.rope_theta)
    v_new = jnp.einsum("bd,dke->bke", xn, p["wv"])

    paged = not local and "kvp" in state
    if local:
        w = state["kl"].shape[3]
        kc = tree_index(state["kl"], idxs["local"])
        vc = tree_index(state["vl"], idxs["local"])
        slot = jnp.mod(pos, w)
        kc = kc.at[ar, :, slot, :].set(
            _masked_rows(write_mask, k_new.astype(kc.dtype),
                         kc[ar, :, slot, :]))
        vc = vc.at[ar, :, slot, :].set(
            _masked_rows(write_mask, v_new.astype(vc.dtype),
                         vc[ar, :, slot, :]))
        kv_pos = jax.vmap(lambda pp: attn_mod.ring_positions(pp + 1, w))(pos)
        window = cfg.window_size
    elif paged:
        # GQA paged: K and V stay page-resident in the dense pool for the
        # whole request (no clustered cache — compute-only saving).
        from repro.models.transformer import _paged_global_update
        state, kc, vc = _paged_global_update(state, idxs, k_new, v_new,
                                             pos, write_mask, cfg)
        s = kc.shape[2]
        kv_pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        window = 0
    else:
        s = state["kg"].shape[3]
        kc = tree_index(state["kg"], idxs["global"])
        vc = tree_index(state["vg"], idxs["global"])
        kc = kc.at[ar, :, pos, :].set(
            _masked_rows(write_mask, k_new.astype(kc.dtype),
                         kc[ar, :, pos, :]))
        vc = vc.at[ar, :, pos, :].set(
            _masked_rows(write_mask, v_new.astype(vc.dtype),
                         vc[ar, :, pos, :]))
        kv_pos = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32), (b, s))
        window = 0

    scale = 1.0 / math.sqrt(hd)
    sc = jnp.einsum("bkre,bkse->bkrs", q_rep.astype(jnp.float32),
                    kc.astype(jnp.float32)) * scale
    sc = softcap(sc, cfg.attn_logit_softcap)
    valid = (kv_pos >= 0) & (kv_pos <= pos[:, None])
    if window:
        valid &= (pos[:, None] - kv_pos) < window
    sc = jnp.where(valid[:, None, None, :], sc, attn_mod.NEG_INF)
    a = jax.nn.softmax(sc, axis=-1)                     # (B, KV, r, S)

    gather_idx = (cluster_of if batched
                  else jnp.broadcast_to(cluster_of, (b, n_kv, qpk)))
    a_full = jnp.take_along_axis(a, gather_idx[..., None], axis=2)
    out = jnp.einsum("bkgs,bksd->bkgd", a_full, vc.astype(jnp.float32))
    out = out.reshape(b, h, hd)

    state = dict(state)
    if local:
        state["kl"] = tree_update(state["kl"], idxs["local"], kc)
        state["vl"] = tree_update(state["vl"], idxs["local"], vc)
    elif not paged:     # paged: _paged_global_update already committed
        state["kg"] = tree_update(state["kg"], idxs["global"], kc)
        state["vg"] = tree_update(state["vg"], idxs["global"], vc)
    return out.astype(xn.dtype), state
