"""Seeded fault-injection soak (the robustness acceptance criterion).

One scripted 24-request workload — shared-prefix families, CHAI
snapshot duplicates, priority preemption, scripted aborts — is driven
twice through an identically-configured engine with ``audit_level=
"deep"``: once fault-free and once under a plan spanning every
injection surface (allocator failure, swap-payload corruption, failed
snapshot restore, relay-residency fault, NaN logits). The faulted run
must:

* drain completely — every request ends completed or typed-failed,
* leak nothing — pool counters clean, idle-engine audit empty,
* pass the deep invariant audit after every single step (a violation
  raises ``EngineFault`` and fails the soak outright),
* leave every untouched completed request bitwise-identical to the
  fault-free run (greedy tokens are schedule-invariant),
* produce a byte-identical injector firing log when replayed.
"""
import jax
import pytest

from repro.configs.base import get_config, reduced
from repro.models import transformer as tfm
from repro.serving.engine import EngineConfig
from repro.serving.faults import FaultSpec
from repro.serving.soak import run_soak, run_soak_pair

TERMINAL = {"length", "stop", "aborted", "error"}

#: one arm per injection surface; uid/step constraints deliberately
#: loose so every arm is guaranteed eligible somewhere in the workload
PLAN = [
    FaultSpec("pool.alloc", mode="transient", count=1),
    FaultSpec("pool.alloc", mode="error", uid=5, count=1),
    FaultSpec("swap.corrupt", mode="corrupt", count=1),
    FaultSpec("snapshot.restore", mode="error", count=1),
    FaultSpec("relay.residency", mode="error", count=1),
    FaultSpec("step.logits", mode="nan", uid=16, count=1),
]


def _setup():
    cfg = reduced(get_config("chai-llama-7b"), n_layers=2, d_model=32,
                  d_ff=64, vocab=128).replace(dtype="float32")
    cfg = cfg.with_chai(enabled=True, warmup_tokens=3)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    ecfg = EngineConfig(batch_slots=3, max_seq=64, page_size=8,
                        prefix_cache=True, relay_decode=True,
                        audit_level="deep")
    return cfg, params, ecfg


@pytest.mark.slow
def test_fault_soak_drains_clean_with_token_parity():
    cfg, params, ecfg = _setup()
    out = run_soak_pair(cfg, params, ecfg, specs=PLAN, fault_seed=0,
                        seed=3, n_requests=24)
    clean, faulted = out["clean"], out["faulted"]

    # fault-free control is itself clean
    assert clean["unfinished"] == [] and clean["leaks"] == []
    assert clean["fault_stats"]["quarantined"] == 0

    # every request ended in a typed terminal state; nothing leaked
    assert faulted["unfinished"] == []
    assert faulted["leaks"] == []
    finishes = {uid: r["finish"] for uid, r in faulted["requests"].items()}
    assert set(finishes.values()) <= TERMINAL, finishes
    for uid, r in faulted["requests"].items():
        if r["finish"] == "error":
            assert r["error"], f"uid {uid} typed-failed without a message"
    for pool in ("dense", "chai"):
        c = faulted["counters"][pool]
        if c is not None:
            # drained engine: only prefix-cache references remain, and
            # in_use pages are exactly the referenced ones
            assert c["refs"] >= c["in_use"] >= 0

    # the plan actually exercised the surfaces it names
    fired = {f["site"] for f in
             faulted["fault_stats"]["injector"]["fired"]}
    assert {"pool.alloc", "snapshot.restore",
            "relay.residency", "step.logits"} <= fired, fired
    fs = faulted["fault_stats"]
    assert fs["quarantined"] >= 1                 # NaN and/or swap arms
    assert fs["relay_dissolved"] >= 1
    assert fs["audit_steps"] >= faulted["steps"]  # deep audit every step

    # untouched completed requests are bitwise identical to fault-free
    assert out["parity"], "parity set unexpectedly empty"
    assert out["mismatches"] == [], out["mismatches"]


@pytest.mark.slow
def test_fault_soak_firing_log_replays_byte_identical():
    """Same (workload seed, plan, fault seed) twice => identical firing
    logs AND identical per-request outcomes — the injector is pure in
    its inputs, never in wall clock or process state."""
    cfg, params, ecfg = _setup()

    def run():
        from repro.serving.faults import FaultInjector
        specs = [FaultSpec(s.site, s.mode, s.step, s.uid, s.count, s.p)
                 for s in PLAN]
        return run_soak(cfg, params, ecfg,
                        faults=FaultInjector(specs, seed=0), seed=3)

    a, b = run(), run()
    assert a["fault_stats"]["injector"] == b["fault_stats"]["injector"]
    assert a["requests"] == b["requests"]
    assert a["steps"] == b["steps"]
