"""Robustness layer: typed failure taxonomy, deterministic fault
injection, invariant auditing, per-request quarantine, and the
supervised AsyncLLM driver.

Contract under test: a request-isolatable failure (injected or real)
ends exactly ONE request with a typed ``finish_reason="error"`` while
every untouched greedy request generates bitwise-identical tokens to a
fault-free run; engine-level corruption raises ``EngineFault`` instead
of silently continuing; and all fault paths return pages
refcount-exactly (the autouse conftest leak gate audits every engine
built here).
"""
import asyncio

import numpy as np
import pytest

import jax

from repro.configs.base import get_config, reduced
from repro.models import transformer as tfm
from repro.serving import invariants
from repro.serving.async_api import AsyncLLM
from repro.serving.engine import EngineConfig, EngineCore
from repro.serving.faults import (
    CapacityError,
    EngineFault,
    FaultInjector,
    FaultSpec,
    QuarantineError,
    RequestError,
    SnapshotRestoreError,
    ValidationError,
    checksum_arrays,
    corrupt_arrays,
)
from repro.serving.sampling import FINISH_ERROR, SamplingParams

ARCH = "chai-llama-7b"          # MHA+CHAI: exercises snapshots + kc/vc
GREEDY = SamplingParams(max_new_tokens=8)

_params_cache = {}


def _model():
    if ARCH not in _params_cache:
        cfg = reduced(get_config(ARCH), n_layers=2, d_model=32, d_ff=64,
                      vocab=64).replace(dtype="float32")
        cfg = cfg.with_chai(enabled=True, warmup_tokens=3)
        _params_cache[ARCH] = (cfg,
                               tfm.init_params(cfg, jax.random.PRNGKey(0)))
    return _params_cache[ARCH]


def _ecfg(**kw):
    kw.setdefault("batch_slots", 2)
    kw.setdefault("max_seq", 64)
    kw.setdefault("page_size", 8)
    kw.setdefault("audit_level", "deep")
    return EngineConfig(**kw)


def _drain(core, max_steps=400):
    outs = []
    for _ in range(max_steps):
        if not core.has_work():
            return outs
        outs.extend(core.step())
    raise AssertionError(f"engine did not drain in {max_steps} steps")


def _prompts(n, length=(6, 14), seed=0, vocab=64):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, vocab, size=int(rng.integers(*length))).tolist()
            for _ in range(n)]


# ---------------------------------------------------------------------------
# taxonomy + injector + integrity helpers (pure units)
# ---------------------------------------------------------------------------
def test_fault_taxonomy_backcompat_bases():
    """New typed errors must still be catchable as the historical types
    (MemoryError for the page budget, ValueError for add_request)."""
    cap = CapacityError("full", uid=7)
    assert isinstance(cap, MemoryError) and isinstance(cap, RequestError)
    assert cap.uid == 7
    val = ValidationError("bad", uid=3)
    assert isinstance(val, ValueError) and isinstance(val, RequestError)
    assert isinstance(QuarantineError("q"), RequestError)
    assert isinstance(SnapshotRestoreError("s"), RequestError)
    ef = EngineFault("broken", violations=["a", "b"])
    assert isinstance(ef, RuntimeError)
    assert not isinstance(ef, RequestError)
    assert "a" in str(ef) and "b" in str(ef)


def test_faultspec_validates_site_mode_p():
    with pytest.raises(ValueError):
        FaultSpec("no.such.site")
    with pytest.raises(ValueError):
        FaultSpec("pool.alloc", mode="explode")
    with pytest.raises(ValueError):
        FaultSpec("pool.alloc", p=0.0)
    with pytest.raises(ValueError):
        FaultSpec("pool.alloc", p=1.5)


def test_fault_injector_is_deterministic_and_replayable():
    """Same (seed, plan, call sequence) => byte-identical firing log;
    gating on step/uid/count behaves exactly as specified."""
    specs = [FaultSpec("pool.alloc", mode="transient", step=4),
             FaultSpec("swap.in", uid=9, count=2),
             FaultSpec("step.logits", mode="nan", p=0.4, count=-1)]
    calls = ([("pool.alloc", s, u) for s in range(6) for u in (1, 9)]
             + [("swap.in", 5, u) for u in (1, 9, 9, 9)]
             + [("step.logits", s, 2) for s in range(30)])

    def run():
        # fresh specs per run so count bookkeeping never crosses runs
        inj = FaultInjector(
            [FaultSpec(s.site, s.mode, s.step, s.uid, s.count, s.p)
             for s in specs], seed=11)
        log = []
        for site, step, uid in calls:
            spec = inj.fire(site, step=step, uid=uid)
            log.append(None if spec is None else spec.mode)
        return log, inj.report()

    log_a, rep_a = run()
    log_b, rep_b = run()
    assert log_a == log_b
    assert rep_a == rep_b
    # step gate: pool.alloc fired exactly once, at step 4
    pool = [f for f in rep_a["fired"] if f["site"] == "pool.alloc"]
    assert [f["step"] for f in pool] == [4]
    # uid + count gate: swap.in fired twice, only for uid 9
    swap = [f for f in rep_a["fired"] if f["site"] == "swap.in"]
    assert len(swap) == 2 and all(f["uid"] == 9 for f in swap)
    # probabilistic arm fired some-but-not-all of 30 eligible calls
    nan = [f for f in rep_a["fired"] if f["site"] == "step.logits"]
    assert 0 < len(nan) < 30


def test_fault_payload_checksum_detects_corruption():
    """The swap-out integrity stamp: corrupting any leaf of the resume
    payload changes the CRC; corruption is deterministic in the seed and
    works on read-only (device_get-style) leaves."""
    def payload():
        a = np.arange(24, dtype=np.float32).reshape(4, 6)
        a.setflags(write=False)
        return {"cols": {"k": a},
                "pools": {"kg": np.ones((2, 3), np.float32)}}

    base = checksum_arrays(payload())
    assert base == checksum_arrays(payload())        # order/shape stable
    t1, t2 = payload(), payload()
    assert corrupt_arrays(t1, seed=5) and corrupt_arrays(t2, seed=5)
    assert checksum_arrays(t1) != base
    assert checksum_arrays(t1) == checksum_arrays(t2)  # seeded => identical


# ---------------------------------------------------------------------------
# engine quarantine paths
# ---------------------------------------------------------------------------
def test_validation_error_is_typed_and_catchable_as_valueerror():
    cfg, params = _model()
    core = EngineCore(cfg, params, _ecfg(max_seq=32))
    with pytest.raises(ValidationError):
        core.add_request(list(range(1, 30)), GREEDY, max_new_tokens=20)
    with pytest.raises(ValueError):                   # legacy catch
        core.add_request(list(range(1, 30)), GREEDY, max_new_tokens=20)
    assert not core.has_work()


def test_nan_logits_quarantine_isolates_one_request():
    """A poisoned logits row typed-fails ITS slot; the other slots keep
    decoding and produce the exact fault-free tokens."""
    cfg, params = _model()
    prompts = _prompts(3, seed=1)

    def run(faults):
        core = EngineCore(cfg, params, _ecfg(batch_slots=3), faults=faults)
        reqs = [core.add_request(p, GREEDY) for p in prompts]
        _drain(core)
        return core, reqs

    clean_core, clean = run(None)
    inj = FaultInjector([FaultSpec("step.logits", mode="nan",
                                   uid=clean[1].uid)], seed=0)
    core, reqs = run(inj)
    assert reqs[1].finish_reason == FINISH_ERROR
    assert "non-finite logits" in reqs[1].error
    for k in (0, 2):
        assert reqs[k].finish_reason == clean[k].finish_reason
        assert list(reqs[k].generated) == list(clean[k].generated)
    fs = core.fault_stats()
    assert fs["quarantined"] == 1
    assert fs["injector"]["fired"][0]["site"] == "step.logits"
    assert clean_core.fault_stats()["quarantined"] == 0


def test_pool_alloc_fault_quarantines_queued_request():
    """mode="error" at the admission planner typed-fails the queued
    request before it touches any device state."""
    cfg, params = _model()
    prompts = _prompts(3, seed=2)
    inj = FaultInjector([FaultSpec("pool.alloc", mode="error", uid=1)],
                        seed=0)
    core = EngineCore(cfg, params, _ecfg(batch_slots=3), faults=inj)
    reqs = [core.add_request(p, GREEDY) for p in prompts]
    outs = _drain(core)
    assert reqs[1].finish_reason == FINISH_ERROR and reqs[1].error
    assert all(r.finish_reason == "length" for r in (reqs[0], reqs[2]))
    terminal = [o for o in outs if o.uid == reqs[1].uid and o.finished]
    assert terminal and terminal[0].finish_reason == FINISH_ERROR


def test_pool_alloc_transient_fault_only_delays_admission():
    """mode="transient" blocks the plan for one step; the request is
    retried, completes, and (being untouched otherwise) matches the
    fault-free tokens. It must NOT trigger preemption or the impossible-
    head CapacityError."""
    cfg, params = _model()
    prompts = _prompts(2, seed=3)

    def run(faults):
        core = EngineCore(cfg, params, _ecfg(), faults=faults)
        reqs = [core.add_request(p, GREEDY) for p in prompts]
        _drain(core)
        return reqs

    clean = run(None)
    inj = FaultInjector([FaultSpec("pool.alloc", mode="transient",
                                   count=2)], seed=0)
    faulted = run(inj)
    for c, f in zip(clean, faulted):
        assert f.finish_reason == "length" == c.finish_reason
        assert list(f.generated) == list(c.generated)


def test_swap_corruption_fault_is_quarantined_at_swap_in():
    """Preemption swap-out stamps a CRC; an injected payload corruption
    is caught at swap-in BEFORE any device mutation and the victim is
    quarantined — the preemptor and the pool are untouched."""
    cfg, params = _model()
    rng = np.random.default_rng(4)
    inj = FaultInjector([FaultSpec("swap.corrupt", mode="corrupt")],
                        seed=0)
    core = EngineCore(cfg, params,
                      _ecfg(batch_slots=1, prefix_cache=True),
                      faults=inj)
    victim = core.add_request(rng.integers(1, 64, size=12).tolist(),
                              SamplingParams(max_new_tokens=12))
    for _ in range(4):
        core.step()
    preemptor = core.add_request(rng.integers(1, 64, size=6).tolist(),
                                 SamplingParams(max_new_tokens=4),
                                 priority=1)
    _drain(core)
    assert preemptor.finish_reason == "length"
    assert victim.finish_reason == FINISH_ERROR
    assert "checksum mismatch" in victim.error
    fs = core.fault_stats()
    assert fs["swap_checksum_failures"] == 1
    assert fs["quarantined"] == 1
    assert core.preemptions == 1


def test_snapshot_restore_fault_recovers_by_replanning_cold():
    """An injected CHAI-snapshot restore failure drops the snapshot and
    re-plans the admission cold — the duplicate request still completes
    with the exact tokens a fault-free duplicate run produces."""
    cfg, params = _model()
    rng = np.random.default_rng(5)
    prompt = rng.integers(1, 64, size=16).tolist()

    def run(faults):
        core = EngineCore(cfg, params, _ecfg(prefix_cache=True),
                          faults=faults)
        first = core.add_request(list(prompt), GREEDY)
        _drain(core)
        assert core.prefix_stats()["snapshots"] >= 1
        dup = core.add_request(list(prompt), GREEDY)
        _drain(core)
        return core, first, dup

    _, _, dup_clean = run(None)
    inj = FaultInjector([FaultSpec("snapshot.restore", count=1)], seed=0)
    core, first, dup = run(inj)
    assert [f["site"] for f in inj.fired] == ["snapshot.restore"]
    assert dup.finish_reason == "length"
    assert list(dup.generated) == list(dup_clean.generated)
    assert core.fault_stats()["quarantined"] == 0    # recovered, not failed


def test_kernel_fault_degrades_to_reference_decode_with_parity():
    """An injected fused-decode failure flips the engine into the jnp
    reference path for the rest of its life; greedy tokens are identical
    (the reference path IS the parity oracle)."""
    cfg, params = _model()
    prompts = _prompts(2, seed=6)

    def run(faults):
        core = EngineCore(cfg, params, _ecfg(), faults=faults)
        reqs = [core.add_request(p, GREEDY) for p in prompts]
        _drain(core)
        return core, reqs

    _, clean = run(None)
    inj = FaultInjector([FaultSpec("kernel.decode", count=1)], seed=0)
    core, reqs = run(inj)
    fs = core.fault_stats()
    assert fs["degraded_decode"] is True
    assert fs["decode_fallbacks"] == 1
    assert fs["quarantined"] == 0
    for c, f in zip(clean, reqs):
        assert list(f.generated) == list(c.generated)


def test_relay_residency_fault_dissolves_groups_not_requests():
    """A relay-formation fault falls back to per-request decode for that
    step; nobody fails and tokens match the relay-free run."""
    cfg, params = _model()
    rng = np.random.default_rng(7)
    shared = rng.integers(1, 64, size=16).tolist()
    prompts = [shared + rng.integers(1, 64, size=3).tolist()
               for _ in range(2)]

    def run(faults, relay):
        core = EngineCore(cfg, params,
                          _ecfg(prefix_cache=True, relay_decode=relay),
                          faults=faults)
        # seed the radix tree so the family members below admit through
        # the SAME cached chain (relay groups form on shared radix nodes)
        core.add_request(shared + [1, 2], GREEDY)
        _drain(core)
        reqs = [core.add_request(p, GREEDY) for p in prompts]
        _drain(core)
        return core, reqs

    _, clean = run(None, relay=False)
    inj = FaultInjector([FaultSpec("relay.residency", count=-1)], seed=0)
    core, reqs = run(inj, relay=True)
    assert core.fault_stats()["relay_dissolved"] >= 1
    for c, f in zip(clean, reqs):
        assert f.finish_reason == "length"
        assert list(f.generated) == list(c.generated)


# ---------------------------------------------------------------------------
# invariant auditor
# ---------------------------------------------------------------------------
def test_invariant_audit_clean_on_live_and_idle_engine():
    cfg, params = _model()
    core = EngineCore(cfg, params, _ecfg(prefix_cache=True))
    for p in _prompts(2, seed=8):
        core.add_request(p, GREEDY)
    core.step()
    assert invariants.audit(core, deep=True) == []
    _drain(core)
    assert invariants.audit_leaks(core) == []
    # every step() call was audited (prefill-only steps included, so the
    # audit count dominates the batched-decode step count)
    assert core.fault_stats()["audit_steps"] >= core.steps_executed > 0


@pytest.mark.no_leak_gate
def test_invariant_audit_detects_pool_corruption():
    """Deliberately break pool conservation mid-flight: the next step()
    must raise EngineFault naming the violation instead of decoding on
    corrupt state."""
    cfg, params = _model()
    core = EngineCore(cfg, params, _ecfg())
    core.add_request(_prompts(1, seed=9)[0], GREEDY)
    core.step()
    # a page that is both free and referenced: conservation + overlap
    page = next(iter(core.dense_pool._rc))
    core.dense_pool._free.append(page)
    with pytest.raises(EngineFault) as ei:
        core.step()
    assert ei.value.violations
    assert any("dense_pool" in v for v in ei.value.violations)


@pytest.mark.no_leak_gate
def test_invariant_audit_detects_leaked_reference():
    """A page reference nothing accounts for (the classic quarantine-
    path bug) is caught by the refcount audit."""
    cfg, params = _model()
    core = EngineCore(cfg, params, _ecfg())
    core.add_request(_prompts(1, seed=10)[0], GREEDY)
    core.step()
    [page] = core.dense_pool.alloc(1)          # held by nobody
    vio = invariants.audit(core)
    assert any("outstanding references" in v for v in vio)
    with pytest.raises(EngineFault):
        core.step()


# ---------------------------------------------------------------------------
# AsyncLLM supervision
# ---------------------------------------------------------------------------
def test_async_capacity_fault_fails_only_its_stream():
    """A request that can NEVER fit typed-fails its own stream
    (CapacityError, still catchable as MemoryError); a concurrent small
    request on the same engine completes normally."""
    cfg, params = _model()
    rng = np.random.default_rng(11)
    big = rng.integers(1, 64, size=40).tolist()   # needs 12 dense pages
    small = rng.integers(1, 64, size=6).tolist()  # needs 4 dense pages
    ecfg = _ecfg(batch_slots=2, num_pages=8, num_chai_pages=16)

    async def main():
        async with AsyncLLM(cfg, params, ecfg) as llm:
            async def run(p, n):
                try:
                    return await llm.generate(p, max_new_tokens=n)
                except MemoryError as err:
                    return err
            return await asyncio.gather(run(big, 8), run(small, 4))

    r_big, r_small = asyncio.run(main())
    assert isinstance(r_big, CapacityError)
    assert r_small.finish_reason == "length"
    assert len(r_small.token_ids) == 4


def test_async_supervised_restart_recovers_from_transient_faults():
    """Non-typed step() failures are retried with backoff; the driver
    keeps the stream alive and the request completes."""
    cfg, params = _model()
    prompt = _prompts(1, seed=12)[0]

    async def main():
        async with AsyncLLM(cfg, params, _ecfg(),
                            restart_backoff=0.001) as llm:
            real = llm.core.step
            calls = {"n": 0}

            def flaky():
                calls["n"] += 1
                if calls["n"] <= 2:
                    raise RuntimeError("transient executor glitch")
                return real()

            llm.core.step = flaky
            out = await llm.generate(prompt, max_new_tokens=5)
            return out, calls["n"], llm.restarts

    out, n_calls, restarts = asyncio.run(main())
    assert out.finish_reason == "length" and len(out.token_ids) == 5
    assert n_calls >= 3
    assert restarts == 2


def test_async_exhausted_retries_broadcast_engine_fault():
    cfg, params = _model()
    prompt = _prompts(1, seed=13)[0]

    async def main():
        llm = AsyncLLM(cfg, params, _ecfg(), max_restarts=1,
                       restart_backoff=0.001)
        try:
            def dead():
                raise RuntimeError("persistent engine failure")
            llm.core.step = dead
            with pytest.raises(EngineFault, match="exhausted"):
                await llm.generate(prompt, max_new_tokens=4)
            assert llm.restarts == 2        # 1 retry + the fatal attempt
        finally:
            await llm.close()

    asyncio.run(main())


def test_async_unattributable_memoryerror_is_engine_fault():
    """A bare MemoryError with NO queue head cannot be pinned on a
    request — the old code crashed the driver on queue[0]; now it
    escalates to a typed EngineFault broadcast."""
    cfg, params = _model()
    prompt = _prompts(1, seed=14)[0]

    async def main():
        llm = AsyncLLM(cfg, params, _ecfg())
        try:
            real = llm.core.step
            state = {"fired": False}

            def spurious():
                if (not state["fired"] and not llm.core.queue
                        and llm.core.has_active):
                    state["fired"] = True
                    raise MemoryError("spurious allocator failure")
                return real()

            llm.core.step = spurious
            with pytest.raises(EngineFault, match="no queue head"):
                await llm.generate(prompt, max_new_tokens=6)
            assert state["fired"]
        finally:
            await llm.close()

    asyncio.run(main())


def test_async_quarantine_stream_gets_typed_terminal_output():
    """An in-flight quarantine (NaN logits) is NOT a driver failure: the
    stream receives a terminal chunk with finish_reason="error" and the
    driver keeps serving the other stream."""
    cfg, params = _model()
    prompts = _prompts(2, seed=15)
    inj = FaultInjector([FaultSpec("step.logits", mode="nan", uid=0)],
                        seed=0)

    async def main():
        async with AsyncLLM(cfg, params, _ecfg(), faults=inj) as llm:
            outs = await asyncio.gather(
                llm.generate(prompts[0], max_new_tokens=6),
                llm.generate(prompts[1], max_new_tokens=6))
            return outs, llm.core.fault_stats()

    (o0, o1), fs = asyncio.run(main())
    assert o0.finish_reason == FINISH_ERROR
    assert o1.finish_reason == "length" and len(o1.token_ids) == 6
    assert fs["quarantined"] == 1


# -- KV-tier offload faults (serving/kv_tiers.py wiring) --------------------

def _family_workload(rng):
    """Prefix-family prompts + extensions that route later matches
    through demoted suffix leaves (the tier promotion path)."""
    prefix = rng.integers(1, 64, size=16).tolist()
    base = [prefix + rng.integers(1, 64, size=8).tolist()
            for _ in range(4)]
    ext = [p + rng.integers(1, 64, size=8).tolist() for p in base[:2]]
    return base + ext


def _run_offload_workload(cfg, params, workload, faults, **kw):
    core = EngineCore(cfg, params,
                      _ecfg(batch_slots=1, prefix_cache=True, **kw),
                      faults=faults)
    toks = []
    for p in workload:
        r = core.add_request(list(p), GREEDY)
        _drain(core)
        assert r.finish_reason == "length"
        toks.append(list(r.generated))
    return core, toks


def test_offload_corruption_is_caught_at_promotion_and_replanned():
    """``offload.out`` mode="corrupt" damages the host-tier copy AFTER
    its CRC stamp. The promotion path catches the mismatch, drops ONLY
    the damaged entry, and re-plans the request cold — greedy tokens
    are unchanged and nothing is quarantined (losing a cache entry is
    recovery, not failure)."""
    cfg, params = _model()
    workload = _family_workload(np.random.default_rng(20))
    _, clean = _run_offload_workload(cfg, params, workload, None)
    inj = FaultInjector([FaultSpec("offload.out", mode="corrupt",
                                   count=-1)], seed=0)
    core, toks = _run_offload_workload(
        cfg, params, workload, inj, kv_offload=True, num_pages=12,
        host_pages=64, tier_prefetch=False)
    assert any(f["site"] == "offload.out" for f in inj.fired)
    assert core.tier_stats()["offload_checksum_failures"] > 0
    st = core.prefix_stats()
    assert st["promoted_blocks"] == 0 and st["promoted_snapshots"] == 0
    assert core.fault_stats()["quarantined"] == 0
    assert toks == clean


def test_offload_out_noncorrupt_mode_declines_demotion():
    """Any non-corrupt ``offload.out`` mode makes the engine decline the
    demotion: the victim drops outright (always safe) and the workload
    completes with fault-free tokens and zero host-tier residency."""
    cfg, params = _model()
    workload = _family_workload(np.random.default_rng(21))
    _, clean = _run_offload_workload(cfg, params, workload, None)
    inj = FaultInjector([FaultSpec("offload.out", mode="error",
                                   count=-1)], seed=0)
    core, toks = _run_offload_workload(
        cfg, params, workload, inj, kv_offload=True, num_pages=12,
        host_pages=64, tier_prefetch=False)
    st = core.prefix_stats()
    assert st["demoted_blocks"] == 0 and st["demoted_snapshots"] == 0
    assert st["evicted_blocks"] + st["evicted_snapshots"] > 0
    assert core.tiers.tier_pages() == {
        k: 0 for k in core.tiers.tier_pages()}
    assert toks == clean


def test_offload_in_fault_at_promotion_replans_cold():
    """An injected ``offload.in`` failure at cache-entry promotion
    drops the entry and re-plans cold — same recovery contract as the
    snapshot-restore fault: tokens unchanged, nothing quarantined."""
    cfg, params = _model()
    workload = _family_workload(np.random.default_rng(22))
    _, clean = _run_offload_workload(cfg, params, workload, None)
    inj = FaultInjector([FaultSpec("offload.in", count=1)], seed=0)
    core, toks = _run_offload_workload(
        cfg, params, workload, inj, kv_offload=True, num_pages=12,
        host_pages=64, tier_prefetch=False)
    assert [f["site"] for f in inj.fired] == ["offload.in"]
    assert core.fault_stats()["quarantined"] == 0
    assert toks == clean


def test_offload_in_fault_at_swap_in_quarantines_victim():
    """The same ``offload.in`` site at preemption swap-in is NOT
    recoverable per-entry (the payload is a live request's KV): the
    victim alone is quarantined — parity with the ``swap.in`` arm —
    and its host-tier pages are released refcount-exactly."""
    cfg, params = _model()
    rng = np.random.default_rng(23)
    inj = FaultInjector([FaultSpec("offload.in", uid=0)], seed=0)
    core = EngineCore(cfg, params, _ecfg(batch_slots=1,
                                         prefix_cache=True), faults=inj)
    victim = core.add_request(rng.integers(1, 64, size=12).tolist(),
                              SamplingParams(max_new_tokens=12))
    for _ in range(4):
        core.step()
    preemptor = core.add_request(rng.integers(1, 64, size=6).tolist(),
                                 SamplingParams(max_new_tokens=4),
                                 priority=1)
    _drain(core)
    assert preemptor.finish_reason == "length"
    assert victim.finish_reason == FINISH_ERROR
    assert "host-tier fetch failure" in victim.error
    assert core.fault_stats()["quarantined"] == 1
    assert all(p.pages_in_use == 0 for p in core.tiers.host.values()
               if p is not None)
